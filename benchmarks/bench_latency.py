"""Paper Fig. 9: per-operation latency, static count-based window.

Reports (a) exact ⊗-invocations per round — worst case is the paper's
headline claim — and (b) wall-clock per jitted round.  Expect: Two-Stacks
variants show rare O(n) spikes (max ≫ p50); DABA/DABA Lite worst ≈ median.

``latency_kll_us`` rows carry the same wall-clock distribution through the
streaming KLL sketch the live observability layer serves
(:class:`repro.obs.registry.KLLHistogram` — what ``/metrics`` exposes as
p50/p95/p99) next to the exact worst case, so the sketch the dashboards
show is validated against ``np.percentile`` ground truth per PR.  None of
these rows carries ``items_per_s``; they are informational, never
regression-gated.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import ALGOS, OPERATORS, count_rounds, pctile_row, time_rounds


def kll_row(name: str, lat_s: np.ndarray, scale: float = 1e6) -> str:
    """Latency row with p50/p95/p99 from the obs KLL sketch plus the exact
    worst case: ``<name>,p50=..,p95=..,p99=..,worst=..`` (units = us)."""
    from repro.obs.registry import KLLHistogram

    h = KLLHistogram("bench", quantiles=(0.5, 0.95, 0.99))
    h.observe_many(np.asarray(lat_s, float) * scale)
    q = np.asarray(h.quantile_values()).ravel()
    worst = float(np.asarray(lat_s, float).max() * scale)
    return (f"{name},p50={q[0]:.2f},p95={q[1]:.2f},p99={q[2]:.2f},"
            f"worst={worst:.2f}")


def _flatfit_counts(op_name, window, rounds):
    """FlatFIT rounds (evict, insert, compressing query) — paper §7 set."""
    from repro.core import counting, flatfit

    m, ctr = counting(OPERATORS[op_name]())
    st = flatfit.init(m, window + 2)
    for i in range(window):
        st = flatfit.insert(m, st, float(i % 97))
    counts = np.empty(rounds, np.int64)
    vals = np.random.default_rng(0).uniform(0, 97, rounds)
    for i in range(rounds):
        ctr.reset()
        st = flatfit.evict(m, st)
        st = flatfit.insert(m, st, float(vals[i]))
        _, st = flatfit.query_mut(m, st)
        counts[i] = ctr.count
    return counts


def main(window=2**12, rounds=1500, operators=("sum", "geomean", "bloom")):
    rows = []
    for op_name in operators:
        for algo in ALGOS:
            if algo == "recalc":
                continue  # O(n) per query; covered by throughput bench
            counts = count_rounds(algo, OPERATORS[op_name](), min(window, 256), rounds // 4)
            rows.append(
                f"latency_combines,{op_name},{algo},"
                f"p50={np.percentile(counts, 50):.0f},p99={np.percentile(counts, 99):.0f},"
                f"max={counts.max()}"
            )
        counts = _flatfit_counts(op_name, min(window, 256), rounds // 4)
        rows.append(
            f"latency_combines,{op_name},flatfit,"
            f"p50={np.percentile(counts, 50):.0f},p99={np.percentile(counts, 99):.0f},"
            f"max={counts.max()}"
        )
        for algo in ALGOS:
            if algo == "recalc":
                continue
            lat = time_rounds(algo, OPERATORS[op_name](), window, rounds)
            rows.append(f"latency_wall_us,{op_name},{algo}," + pctile_row("", lat).lstrip(","))
            rows.append(kll_row(f"latency_kll_us,{op_name},{algo}", lat))
    for r in rows:
        print(r)
    return rows


if __name__ == "__main__":
    main()
