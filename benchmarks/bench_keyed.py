"""Keyed window store: fused mixed-key bulk path vs per-key Python loop.

The multi-tenant workload: T Zipf-distributed ``(key, x)`` events over K
live keys, each key maintaining its own count-``window`` sliding aggregate.
Engines:

  * ``per_key_loop``: the obvious baseline — a Python dict of single
    DABA-Lite windows, one eager insert/evict/query dispatch per element
    (timed on a truncated stream and scaled; the per-item cost is constant);
  * ``bulk``: :class:`repro.core.keyed.KeyedChunkedStream` — stable sort by
    key, segment boundaries, directory admission, and segment-wise carry
    updates fused into ONE jitted dispatch per chunk.

Sweeps K ∈ {256, 4k, 64k} × chunk sizes.  Rows use the repo CSV style::

    keyed,sum,bulk,K=4096,window=256,chunk=4096,T=65536,items_per_s=...
    keyed,sum,speedup,K=4096,window=256,x=...
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import daba_lite, monoids
from repro.core.keyed import KeyedChunkedStream
from repro.data.stream import KeyedEventStream


def _events(T, K, seed=0):
    s = KeyedEventStream(T, K, zipf_a=1.2, integer_values=True, seed=seed)
    keys, _, xs = s.arrival()
    return keys, xs


def bulk_throughput(monoid, window, K, T, chunk, repeats=2):
    keys, xs = _events(T, K)
    eng = KeyedChunkedStream(monoid, window, slots=K, chunk=chunk)
    st, ys = eng.stream(keys, xs)  # compile + warm
    jax.block_until_ready(ys)
    t0 = time.perf_counter()
    for _ in range(repeats):
        st, ys = eng.stream(keys, xs)
        jax.block_until_ready(ys)
    return repeats * T / (time.perf_counter() - t0)


def per_key_loop_throughput(monoid, window, K, T):
    """Dict of single eager DABA-Lite windows, one per key — the per-element
    per-key dispatch cost the bulk path amortizes away."""
    keys, xs = _events(T, K)
    keys_np, xs_np = np.asarray(keys), np.asarray(xs)
    states: dict = {}
    t0 = time.perf_counter()
    for i in range(T):
        k = int(keys_np[i])
        s = states.get(k)
        if s is None:
            s = daba_lite.init(monoid, window + 2)
        s = daba_lite.insert(monoid, s, int(xs_np[i]))
        if int(daba_lite.size(s)) > window:
            s = daba_lite.evict(monoid, s)
        daba_lite.query(monoid, s)
        states[k] = s
    return T / (time.perf_counter() - t0)


def main(Ks=(256, 4096, 65536), window=256, chunks=(1024, 4096), T=65536,
         loop_T=1500):
    """``loop_T``: the per-key loop is timed on a truncated stream and
    scaled — its per-item cost is constant and 64k eager dispatches would
    dominate the benchmark wall clock."""
    rows = []
    monoid = monoids.sum_monoid(jnp.int32)

    def emit(row):
        rows.append(row)
        print(row, flush=True)

    for K in Ks:
        thr_loop = per_key_loop_throughput(monoid, window, K, min(T, loop_T))
        emit(
            f"keyed,sum,per_key_loop,K={K},window={window},T={T},"
            f"items_per_s={thr_loop:.0f}"
        )
        best = 0.0
        for chunk in chunks:
            thr = bulk_throughput(monoid, window, K, T, chunk)
            best = max(best, thr)
            emit(
                f"keyed,sum,bulk,K={K},window={window},chunk={chunk},T={T},"
                f"items_per_s={thr:.0f}"
            )
        emit(
            f"keyed,sum,speedup,K={K},window={window},T={T},"
            f"x={best / thr_loop:.1f}"
        )
    return rows


if __name__ == "__main__":
    main()
