"""Keyed window store: fused mixed-key bulk path vs per-key Python loop.

The multi-tenant workload: T Zipf-distributed ``(key, x)`` events over K
live keys, each key maintaining its own count-``window`` sliding aggregate.
Engines:

  * ``per_key_loop``: the obvious baseline — a Python dict of single
    DABA-Lite windows, one eager insert/evict/query dispatch per element
    (timed on a truncated stream and scaled; the per-item cost is constant);
  * ``bulk``: :class:`repro.core.keyed.KeyedChunkedStream` — stable sort by
    key, segment boundaries, vectorized admission, and ONE batched carry
    scatter fused into a single jitted dispatch per chunk.  Timed in the
    WARM steady state: the key set is already admitted, the state is
    threaded through repeats (donation keeps the carry scatter in-place),
    and every chunk takes the all-hit admission fast path — the regime a
    long-lived store lives in;
  * ``bulk_cold``: the same stream into a FRESH state per repeat — every
    chunk pays batched admission for its genuinely-new keys (cold-ingest
    honesty row; compilation is excluded).

Bulk rows carry ``roofline_frac``: measured items/s over the memory-bound
items/s bound of :func:`repro.roofline.analysis.keyed_update_cost`.

Sweeps K ∈ {256, 4k, 64k} × chunk sizes.  Rows use the repo CSV style::

    keyed,sum,bulk,K=4096,window=256,chunk=4096,T=65536,items_per_s=...,roofline_frac=...
    keyed,sum,speedup,K=4096,window=256,T=65536,x=...

``tune()`` sweeps chunk sizes per (K, window) and emits the best
configuration per combination (the ``--tune`` mode of benchmarks.run).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import daba_lite, monoids
from repro.core.keyed import KeyedChunkedStream
from repro.data.stream import KeyedEventStream
from repro.roofline.analysis import keyed_horizon_cost, keyed_update_cost


def _events(T, K, seed=0):
    s = KeyedEventStream(T, K, zipf_a=1.2, integer_values=True, seed=seed)
    keys, _, xs = s.arrival()
    return keys, xs


def bulk_throughput(monoid, window, K, T, chunk, repeats=3):
    """Warm steady-state items/s: keys admitted, state threaded through
    repeats, admission on the fast path, carry scatter in-place."""
    keys, xs = _events(T, K)
    eng = KeyedChunkedStream(monoid, window, slots=K, chunk=chunk)
    st, ys = eng.stream(keys, xs)  # compile + admit the key set
    st, ys = eng.stream(keys, xs, state=st)  # settle into steady state
    jax.block_until_ready(ys)
    t0 = time.perf_counter()
    for _ in range(repeats):
        st, ys = eng.stream(keys, xs, state=st)
        jax.block_until_ready(ys)
    return repeats * T / (time.perf_counter() - t0)


def bulk_cold_throughput(monoid, window, K, T, chunk, repeats=2):
    """Cold-ingest items/s: a fresh state per repeat, so chunks pay batched
    admission for their new keys (compilation excluded via a warm-up pass)."""
    keys, xs = _events(T, K)
    eng = KeyedChunkedStream(monoid, window, slots=K, chunk=chunk)
    _, ys = eng.stream(keys, xs)  # compile only
    jax.block_until_ready(ys)
    t0 = time.perf_counter()
    for _ in range(repeats):
        _, ys = eng.stream(keys, xs)  # state=None → fresh init each time
        jax.block_until_ready(ys)
    return repeats * T / (time.perf_counter() - t0)


def bulk_horizon_throughput(monoid, window, horizon, K, T, chunk, repeats=3):
    """Warm steady-state items/s in event-time ``horizon=`` mode: same
    protocol as :func:`bulk_throughput` plus per-row timestamps (replayed
    ts stay per-key non-decreasing across repeats — equal is allowed)."""
    s = KeyedEventStream(T, K, zipf_a=1.2, integer_values=True, seed=0)
    keys, ts, xs = s.arrival()
    eng = KeyedChunkedStream(monoid, window, slots=K, chunk=chunk,
                             horizon=horizon)
    st, ys = eng.stream(keys, xs, ts=ts)  # compile + admit the key set
    st, ys = eng.stream(keys, xs, ts=ts, state=st)
    jax.block_until_ready(ys)
    t0 = time.perf_counter()
    for _ in range(repeats):
        st, ys = eng.stream(keys, xs, ts=ts, state=st)
        jax.block_until_ready(ys)
    return repeats * T / (time.perf_counter() - t0)


def per_key_loop_throughput(monoid, window, K, T):
    """Dict of single eager DABA-Lite windows, one per key — the per-element
    per-key dispatch cost the bulk path amortizes away."""
    keys, xs = _events(T, K)
    keys_np, xs_np = np.asarray(keys), np.asarray(xs)
    states: dict = {}
    t0 = time.perf_counter()
    for i in range(T):
        k = int(keys_np[i])
        s = states.get(k)
        if s is None:
            s = daba_lite.init(monoid, window + 2)
        s = daba_lite.insert(monoid, s, int(xs_np[i]))
        if int(daba_lite.size(s)) > window:
            s = daba_lite.evict(monoid, s)
        daba_lite.query(monoid, s)
        states[k] = s
    return T / (time.perf_counter() - t0)


def _roofline_frac(thr, chunk, window):
    bound = keyed_update_cost(chunk, window)["items_per_s_bound"]
    return thr / bound if bound > 0 else 0.0


def main(Ks=(256, 4096, 65536), window=256, chunks=(1024, 4096), T=65536,
         loop_T=1500, big_windows=(4096,), big_K=4096, big_chunk=1024,
         big_T=32768):
    """``loop_T``: the per-key loop is timed on a truncated stream and
    scaled — its per-item cost is constant and 64k eager dispatches would
    dominate the benchmark wall clock.

    ``big_windows``: large-window rows at K=``big_K`` for BOTH an
    invertible monoid (sum — prefix-scan fast path) and a non-invertible
    one (max — the segmented two-stacks flip sweep).  This is the regime
    where the retired log2(W) range-fold table was most expensive; the
    max row at window=4096 is the acceptance configuration."""
    rows = []
    monoid = monoids.sum_monoid(jnp.int32)

    def emit(row):
        rows.append(row)
        print(row, flush=True)

    for K in Ks:
        thr_loop = per_key_loop_throughput(monoid, window, K, min(T, loop_T))
        emit(
            f"keyed,sum,per_key_loop,K={K},window={window},T={T},"
            f"items_per_s={thr_loop:.0f}"
        )
        best = 0.0
        for chunk in chunks:
            thr = bulk_throughput(monoid, window, K, T, chunk)
            best = max(best, thr)
            emit(
                f"keyed,sum,bulk,K={K},window={window},chunk={chunk},T={T},"
                f"items_per_s={thr:.0f},"
                f"roofline_frac={_roofline_frac(thr, chunk, window):.3f}"
            )
            thr_cold = bulk_cold_throughput(monoid, window, K, T, chunk)
            emit(
                f"keyed,sum,bulk_cold,K={K},window={window},chunk={chunk},"
                f"T={T},items_per_s={thr_cold:.0f}"
            )
        emit(
            f"keyed,sum,speedup,K={K},window={window},T={T},"
            f"x={best / thr_loop:.1f}"
        )
    for W in big_windows:
        for mname, mono in (("sum", monoid),
                            ("max", monoids.max_monoid(jnp.int32))):
            thr = bulk_throughput(mono, W, big_K, big_T, big_chunk)
            emit(
                f"keyed,{mname},bulk,K={big_K},window={W},"
                f"chunk={big_chunk},T={big_T},items_per_s={thr:.0f},"
                f"roofline_frac={_roofline_frac(thr, big_chunk, W):.3f}"
            )
    # event-time horizon= row (informational, never gated — the first keyed
    # event-time baseline; max exercises the flip sweep with finger-search
    # span starts)
    hz = 1024.0
    thr = bulk_horizon_throughput(monoids.max_monoid(jnp.int32), window, hz,
                                  big_K, min(T, big_T), big_chunk)
    bound = keyed_horizon_cost(big_chunk, window)["items_per_s_bound"]
    emit(
        f"keyed,max,bulk_horizon,K={big_K},window={window},horizon={hz:.0f},"
        f"chunk={big_chunk},T={min(T, big_T)},items_per_s={thr:.0f},"
        f"roofline_frac={thr / bound if bound > 0 else 0.0:.3f}"
    )
    return rows


def tune(Ks=(256, 4096, 65536), window=256,
         chunks=(256, 512, 1024, 2048, 4096, 8192), T=65536):
    """Sweep chunk size per (backend, K, window); emit every point plus a
    ``best`` row per K — the autotuner behind ``benchmarks.run --tune``."""
    rows = []
    monoid = monoids.sum_monoid(jnp.int32)
    backend = jax.default_backend()

    def emit(row):
        rows.append(row)
        print(row, flush=True)

    for K in Ks:
        best_thr, best_chunk = 0.0, None
        for chunk in chunks:
            if chunk > T:
                continue
            thr = bulk_throughput(monoid, window, K, T, chunk, repeats=2)
            emit(
                f"keyed,sum,tune,backend={backend},K={K},window={window},"
                f"chunk={chunk},T={T},items_per_s={thr:.0f},"
                f"roofline_frac={_roofline_frac(thr, chunk, window):.3f}"
            )
            if thr > best_thr:
                best_thr, best_chunk = thr, chunk
        emit(
            f"keyed,sum,tune_best,backend={backend},K={K},window={window},"
            f"T={T},best_chunk={best_chunk},items_per_s={best_thr:.0f}"
        )
    return rows


if __name__ == "__main__":
    main()
