"""Beyond-paper: batched (SIMD/SPMD) SWAG — DESIGN.md §2.1.

B independent windows advance in lock-step under vmap.  DABA/DABA Lite do
uniform constant work per lane (cond → select); Two-Stacks' flip becomes a
``while_loop`` whose trip count is the max over lanes, so one lane's flip
stalls the whole batch — de-amortization is what makes the algorithm
vectorizable.  We measure compiled steps/s at several batch widths, plus the
dense VHGW kernel as the spatial-batch upper bound.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ALGORITHMS, monoids
from repro.core.batched import BatchedSWAG
from repro.kernels.sliding_window.ops import sliding_window_agg


def batched_throughput(algo_name, batch, window, steps=20_000):
    b = BatchedSWAG(ALGORITHMS[algo_name], monoids.max_monoid(), window + 2)
    st = b.init(batch)
    chunk = min(steps, 5000)
    xs = jnp.asarray(
        np.random.default_rng(0).standard_normal((chunk, batch)), jnp.float32
    )
    run = jax.jit(lambda st: b.stream(st, xs, window)[0])
    st = run(st)
    jax.block_until_ready(jax.tree.leaves(st)[0])
    done, t0 = 0, time.perf_counter()
    while done < steps:
        st = run(st)
        done += chunk
    jax.block_until_ready(jax.tree.leaves(st)[0])
    wall = time.perf_counter() - t0
    return done * batch / wall  # window-updates per second


def main(batches=(16, 256), window=64, steps=6_000):
    rows = []
    for algo in ["daba_lite", "daba", "two_stacks_lite"]:
        for b in batches:
            thr = batched_throughput(algo, b, window, steps)
            rows.append(
                f"batched,max,{algo},batch={b},window={window},updates_per_s={thr:.0f}"
            )
            print(rows[-1], flush=True)
    # dense spatial form: the VHGW Pallas kernel (interpret mode on CPU)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((64, 8192)), jnp.float32)
    f = jax.jit(lambda x: sliding_window_agg(x, window, "max"))
    f(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        f(x).block_until_ready()
    wall = (time.perf_counter() - t0) / 3
    rows.append(
        f"batched,max,vhgw_kernel,batch=64x8192,window={window},"
        f"updates_per_s={64 * 8192 / wall:.0f}"
    )
    print(rows[-1])
    return rows


if __name__ == "__main__":
    main()
