"""Chunked bulk engine vs per-element stream: the throughput of §8.2 in bulk.

Same (T, B) stream, same count-based window, two engines:

  * ``per_element``: ``BatchedSWAG.stream`` with the ``lax.scan`` path —
    worst-case O(1) combines per element, but one sequential dispatch per
    element;
  * ``chunked``: :class:`repro.core.chunked.ChunkedStream` — the Pallas
    sliding_window/suffix_scan kernels amortize the whole chunk into ~3
    combines per element of log-depth vector work;
  * ``*_warm``: the same comparison starting from a LIVE (full) window —
    the chunked side pays the warm-carry extraction plus the final-state
    rebuild (state_to_carry / bulk evict+insert) on top of the stream.

Plus the OUT-OF-ORDER event-time rows: the same values under a time-horizon
window, streamed through :class:`repro.core.event_time.EventTimeChunkedStream`
at disorder fractions {0, 0.1, 0.5} (lateness bounded by the engine slack) —
``eventtime_d<frac>`` rows — against a per-element
:class:`~repro.core.event_time.TimestampedWindow` scan of the sorted stream
(``eventtime_per_element``).

Rows use the bench_throughput.py CSV style:
``chunked,<op>,<engine>,window=<w>,T=<T>,items_per_s=<n>``.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ALGORITHMS, monoids
from repro.core.batched import BatchedSWAG
from repro.core.chunked import ChunkedStream
from repro.core.event_time import EventTimeChunkedStream, TimestampedWindow
from repro.data.stream import DisorderedEventStream

OPERATORS = {
    "sum": lambda: monoids.sum_monoid(),
    "max": lambda: monoids.max_monoid(),
}


def _stream(T, B, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).uniform(0, 97, (T, B)).astype(np.float32)
    )


def per_element_throughput(monoid, window, T, B, algo_name="daba_lite", repeats=2):
    b = BatchedSWAG(ALGORITHMS[algo_name], monoid, window + 4)
    xs = _stream(T, B)
    run = jax.jit(lambda st, xs: b.stream(st, xs, window, chunked=False)[1])
    ys = run(b.init(B), xs)  # compile + warm
    jax.block_until_ready(ys)
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(run(b.init(B), xs))
    return repeats * T * B / (time.perf_counter() - t0)


def chunked_throughput(monoid, window, T, B, chunk=None, repeats=2):
    eng = ChunkedStream(monoid, window, chunk)
    xs = _stream(T, B)
    jax.block_until_ready(eng.stream(xs))  # compile + warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(eng.stream(xs))
    return repeats * T * B / (time.perf_counter() - t0)


def _warm_state(b, window, T, B):
    """A live, full window per lane (the warm-carry protocol's input)."""
    st = b.init(B)
    st, _ = b.stream(st, _stream(window, B, seed=123), window, chunked=False)
    return st


def warm_throughput(monoid, window, T, B, chunked, algo_name="daba_lite", repeats=2):
    """BatchedSWAG.stream from a warm state: ``chunked=None`` auto-routes
    through the bulk engine (carry extraction + final-state rebuild
    included in the timing); ``chunked=False`` is the per-element scan."""
    b = BatchedSWAG(ALGORITHMS[algo_name], monoid, window + 4)
    warm = _warm_state(b, window, T, B)
    xs = _stream(T, B)
    if chunked is False:
        run = jax.jit(lambda st, xs: b.stream(st, xs, window, chunked=False))
    else:
        run = lambda st, xs: b.stream(st, xs, window)  # host chunk loop
    # block on the full (state, ys) tuple so the final-state rebuild is
    # actually awaited, not just the window outputs
    jax.block_until_ready(run(warm, xs))  # compile + warm caches
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(run(warm, xs))
    return repeats * T * B / (time.perf_counter() - t0)


def _ooo_stream(T, B, disorder, slack, seed=7):
    s = DisorderedEventStream(
        T, B, mean_gap=1.0, disorder=disorder, slack=slack, seed=seed
    )
    return s.arrival()


def eventtime_throughput(monoid, horizon, T, B, disorder, slack,
                         chunk=1024, repeats=2):
    """Bulk out-of-order engine items/s at a given disorder fraction (the
    timing covers sort/release/range-fold AND the final output compaction)."""
    ts, xs = _ooo_stream(T, B, disorder, slack)
    eng = EventTimeChunkedStream(
        monoid,
        horizon,
        slack=slack,
        chunk=chunk,
        capacity=2 * int(horizon) + 64,
        buffer=max(4 * int(slack) + 16, 64),
    )
    eng.stream(ts, xs)  # compile + warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        eng.stream(ts, xs)
    return repeats * T * B / (time.perf_counter() - t0)


def eventtime_per_element_throughput(monoid, horizon, T, B,
                                     algo_name="daba_lite"):
    """Per-element TimestampedWindow scan of the sorted stream (B lanes run
    as one batched insert per step would; here the eager single-lane cost
    is measured and scaled — the sequential dispatch is the bottleneck)."""
    ts, xs = DisorderedEventStream(T, B, mean_gap=1.0, disorder=0.0,
                                   slack=0.0, seed=7).in_order()
    ts_np, xs_np = np.asarray(ts), np.asarray(xs)
    win = TimestampedWindow(
        ALGORITHMS[algo_name], monoid, horizon, capacity=2 * int(horizon) + 64
    )
    t0 = time.perf_counter()
    for i in range(T):
        win.insert(float(ts_np[i]), jnp.asarray(xs_np[i, 0]))
        win.query()
    return T * B / (time.perf_counter() - t0)


def main(window=1024, T=100_000, B=8, operators=("sum",), pe_T=20_000,
         ooo_T=30_000, ooo_horizon=256, ooo_pe_T=1_500,
         disorders=(0.0, 0.1, 0.5)):
    """``pe_T``: the per-element path is timed on a truncated stream and
    scaled — 100k sequential scan steps would dominate the benchmark run
    while measuring the same per-item cost.  ``ooo_*``: the event-time
    (out-of-order) rows — horizon ≈ window in expectation (unit mean gap),
    disorder-fraction sweep with slack = horizon / 16."""
    rows = []

    def emit(op_name, eng, thr):
        rows.append(
            f"chunked,{op_name},{eng},window={window},T={T},items_per_s={thr:.0f}"
        )
        print(rows[-1], flush=True)

    for op_name in operators:
        monoid = OPERATORS[op_name]()
        thr_pe = per_element_throughput(monoid, window, min(T, pe_T), B)
        thr_ch = chunked_throughput(monoid, window, T, B)
        emit(op_name, "per_element", thr_pe)
        emit(op_name, "chunked", thr_ch)
        rows.append(
            f"chunked,{op_name},speedup,window={window},T={T},x={thr_ch / thr_pe:.1f}"
        )
        print(rows[-1], flush=True)
        thr_pe_w = warm_throughput(monoid, window, min(T, pe_T), B, chunked=False)
        thr_ch_w = warm_throughput(monoid, window, T, B, chunked=None)
        emit(op_name, "per_element_warm", thr_pe_w)
        emit(op_name, "chunked_warm", thr_ch_w)
        rows.append(
            f"chunked,{op_name},speedup_warm,window={window},T={T},"
            f"x={thr_ch_w / thr_pe_w:.1f}"
        )
        print(rows[-1], flush=True)

        # out-of-order event-time rows: disorder sweep + per-element baseline
        slack = max(ooo_horizon / 16, 1.0)
        thr_pe_ev = eventtime_per_element_throughput(
            monoid, ooo_horizon, min(T, ooo_pe_T), B
        )
        rows.append(
            f"chunked,{op_name},eventtime_per_element,window={ooo_horizon},"
            f"T={ooo_T},items_per_s={thr_pe_ev:.0f}"
        )
        print(rows[-1], flush=True)
        for d in disorders:
            thr_ev = eventtime_throughput(
                monoid, ooo_horizon, ooo_T, B, disorder=d, slack=slack
            )
            rows.append(
                f"chunked,{op_name},eventtime_d{d},window={ooo_horizon},"
                f"T={ooo_T},disorder={d},items_per_s={thr_ev:.0f}"
            )
            print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    main()
