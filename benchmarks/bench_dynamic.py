"""Paper Fig. 11: dynamic windows — the fill-and-drain pattern.

Insert+query until the window reaches n, then evict until 0, repeat, via a
single compiled lax.scan with masked ops (the JAX form of a dynamic window).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ALGOS, OPERATORS
from repro.core import ALGORITHMS


def fill_drain_throughput(algo_name, monoid, n, total_items):
    algo = ALGORITHMS[algo_name]

    def step(carry, x):
        st, filling = carry
        sz = algo.size(st)
        do_insert = filling & (sz < n)
        st = jax.lax.cond(
            do_insert, lambda s: algo.insert(monoid, s, x), lambda s: s, st
        )
        st = jax.lax.cond(
            ~filling & (sz > 0), lambda s: algo.evict(monoid, s), lambda s: s, st
        )
        q = algo.query(monoid, st)
        sz = algo.size(st)
        filling = jnp.where(sz >= n, False, jnp.where(sz <= 0, True, filling))
        return (st, filling), q

    chunk = min(total_items, 50_000)
    xs = jnp.asarray(np.random.default_rng(0).uniform(0, 97, chunk), jnp.float32)
    run = jax.jit(
        lambda c: jax.lax.scan(step, c, xs)[0], donate_argnums=0
    )
    carry = (algo.init(monoid, n + 2), jnp.asarray(True))
    carry = run(carry)
    jax.block_until_ready(jax.tree.leaves(carry)[0])
    done, t0 = 0, time.perf_counter()
    while done < total_items:
        carry = run(carry)
        done += chunk
    jax.block_until_ready(jax.tree.leaves(carry)[0])
    return done / (time.perf_counter() - t0)


def main(windows=(2**4, 2**8), items=60_000, operators=("sum", "geomean")):
    rows = []
    for op_name in operators:
        for algo in ALGOS:
            if algo == "recalc":
                continue
            for w in windows:
                thr = fill_drain_throughput(algo, OPERATORS[op_name](), w, items)
                rows.append(
                    f"dynamic,{op_name},{algo},window={w},items_per_s={thr:.0f}"
                )
                print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    main()
