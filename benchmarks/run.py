"""Benchmark driver — one function per paper table/figure.

Prints ``name,...,derived`` CSV lines AND writes a machine-readable
``BENCH_<name>.json`` per benchmark (parsed rows + backend + timestamp) so
the perf trajectory is comparable across PRs (``--out-dir`` to redirect,
``--no-json`` to disable).  Scales are reduced for the single-core CPU
container (see benchmarks/common.py); EXPERIMENTS.md records a full run's
output.

  Fig 9  → bench_latency      per-op latency + exact ⊗-count distributions
  Fig 10 → bench_throughput   throughput vs window size (static)
  Fig 11 → bench_dynamic      fill-and-drain dynamic windows
  Fig 12 → bench_eventtime    event-time windows, bursty stream
  §2.1   → bench_batched      SIMD/vmap batched SWAG (beyond paper)
  §8.2   → bench_chunked      chunked bulk engine vs per-element stream
  beyond → bench_keyed        keyed window store: K per-key windows, bulk
  §Roofline → roofline_table  rendered from experiments/dryrun/*.json
"""

import argparse
import datetime
import json
import pathlib
import sys


def parse_rows(rows) -> list:
    """CSV benchmark rows → dicts: ``k=v`` fields typed as floats where
    possible, bare fields collected under ``labels``."""
    parsed = []
    for row in rows or []:
        rec = {"raw": str(row), "labels": []}
        for part in str(row).split(","):
            if "=" in part:
                k, v = part.split("=", 1)
                try:
                    rec[k] = float(v)
                except ValueError:
                    rec[k] = v
            else:
                rec["labels"].append(part)
        parsed.append(rec)
    return parsed


def emit_json(name: str, rows, out_dir: str = ".") -> pathlib.Path:
    """Write ``BENCH_<name>.json``: parsed rows + backend, so the perf
    trajectory (items/s per window/T/engine) is tracked across PRs."""
    import jax

    payload = {
        "bench": name,
        "backend": jax.default_backend(),
        "generated": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "rows": parse_rows(rows),
    }
    path = pathlib.Path(out_dir) / f"BENCH_{name}.json"
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {path}")
    return path


# rows with these labels are informational, not regression-gated: the
# per-key Python loop and the Fig-12 relvar rows time host Python-loop
# dispatch overhead (noisy across machines), speedup/tune rows carry no
# items_per_s of their own, and bulk_horizon is the first keyed event-time
# baseline (no committed history to gate against yet)
_COMPARE_SKIP_LABELS = {"per_key_loop", "relvar", "speedup", "tune",
                        "tune_best", "bulk_horizon"}


def _row_key(rec: dict):
    """Identity of a benchmark row for --compare matching: its bare labels
    plus every ``k=v`` parameter EXCEPT the measured outputs."""
    drop = {"raw", "labels", "items_per_s", "x", "roofline_frac"}
    params = tuple(sorted(
        (k, v) for k, v in rec.items() if k not in drop
    ))
    return (tuple(rec.get("labels", ())), params)


def compare_rows(current_rows, baseline_path: str, threshold: float) -> int:
    """Diff current rows against a committed BENCH JSON: rows are matched
    by labels+parameters and FAIL when ``items_per_s`` falls below
    ``threshold ×`` the baseline.  Returns the number of failures (and
    counts zero matched rows as a failure — a silently-empty gate guards
    nothing)."""
    with open(baseline_path) as f:
        baseline = json.load(f)
    base = {}
    for rec in baseline.get("rows", []):
        if "items_per_s" not in rec:
            continue
        if _COMPARE_SKIP_LABELS & set(rec.get("labels", ())):
            continue
        base[_row_key(rec)] = rec["items_per_s"]
    matched = failures = 0
    for rec in parse_rows(current_rows):
        key = _row_key(rec)
        if key not in base or "items_per_s" not in rec:
            continue
        matched += 1
        ratio = rec["items_per_s"] / base[key] if base[key] > 0 else 1.0
        status = "OK" if ratio >= threshold else "REGRESSION"
        if ratio < threshold:
            failures += 1
        print(
            f"# compare {status}: {rec['raw']}  "
            f"baseline={base[key]:.0f} ratio={ratio:.2f} "
            f"(threshold {threshold})"
        )
    if matched == 0:
        print(f"# compare FAILED: no rows matched {baseline_path}")
        return 1
    print(f"# compare: {matched} rows matched, {failures} regressions")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: latency,throughput,dynamic,eventtime,"
                         "batched,chunked,keyed,service,roofline")
    ap.add_argument("--quick", action="store_true", help="reduced sizes")
    ap.add_argument("--out-dir", default=".",
                    help="directory for BENCH_<name>.json summaries")
    ap.add_argument("--no-json", action="store_true",
                    help="skip the JSON summaries")
    ap.add_argument("--tune", action="store_true",
                    help="autotune mode: sweep chunk size per (backend, K, "
                         "window) for the keyed store and emit the best "
                         "configuration (writes BENCH_keyed_tune.json)")
    ap.add_argument("--compare", default=None, metavar="BASELINE.json",
                    help="diff this run's rows against a committed BENCH "
                         "JSON (matched by labels+params) and exit non-zero "
                         "on items/s regressions; per_key_loop rows are "
                         "informational and never gated")
    ap.add_argument("--threshold", type=float, default=0.8,
                    help="minimum current/baseline items_per_s ratio for "
                         "--compare (default 0.8)")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None
    all_rows: list = []

    def on(name):
        return want is None or name in want

    def done(name, rows):
        all_rows.extend(rows or [])
        if not args.no_json:
            # --quick runs smaller configurations: write (and stamp) them as
            # BENCH_<name>_quick.json so a quick run can never clobber the
            # committed full-scale baselines
            emit_json(name + ("_quick" if args.quick else ""), rows,
                      args.out_dir)

    if args.tune:
        from benchmarks import bench_keyed

        print("# autotune — keyed chunk sweep")
        if args.quick:
            rows = bench_keyed.tune(Ks=(256, 65536),
                                    chunks=(512, 1024, 4096), T=16384)
        else:
            rows = bench_keyed.tune()
        done("keyed_tune", rows)
        if args.compare:
            sys.exit(1 if compare_rows(rows, args.compare, args.threshold)
                     else 0)
        return

    from benchmarks import (
        bench_batched,
        bench_chunked,
        bench_dynamic,
        bench_eventtime,
        bench_keyed,
        bench_latency,
        bench_throughput,
        roofline_table,
    )

    if on("latency"):
        print("# Fig 9 — latency")
        if args.quick:
            rows = bench_latency.main(window=2**8, rounds=800, operators=("sum",))
        else:
            rows = bench_latency.main()
        done("latency", rows)
    if on("throughput"):
        print("# Fig 10 — throughput (static windows)")
        if args.quick:
            rows = bench_throughput.main(windows=(2**4,), items=50_000,
                                         operators=("sum",))
        else:
            rows = bench_throughput.main()
        done("throughput", rows)
    if on("dynamic"):
        print("# Fig 11 — throughput (dynamic fill-and-drain)")
        if args.quick:
            rows = bench_dynamic.main(windows=(2**4,), items=30_000,
                                      operators=("sum",))
        else:
            rows = bench_dynamic.main()
        done("dynamic", rows)
    if on("eventtime"):
        print("# Fig 12 — event-time windows (synthetic bursty stream)")
        if args.quick:
            # bulk rows keep horizon=1024 so CI gates the constant-combine
            # flip-sweep regime, not just the small-window one
            rows = bench_eventtime.main(n_items=2000, horizons=(256, 1024),
                                        bulk_T=12000)
        else:
            rows = bench_eventtime.main()
        done("eventtime", rows)
    if on("batched"):
        print("# beyond-paper — batched/SIMD SWAG")
        if args.quick:
            rows = bench_batched.main(batches=(16,), steps=4000)
        else:
            rows = bench_batched.main()
        done("batched", rows)
    if on("chunked"):
        print("# §8.2 — chunked bulk engine vs per-element stream")
        if args.quick:
            rows = bench_chunked.main(window=2**8, T=20_000, B=4, pe_T=5_000,
                                      ooo_T=8_000, ooo_horizon=64, ooo_pe_T=600)
        else:
            rows = bench_chunked.main()
        done("chunked", rows)
    if on("keyed"):
        print("# beyond-paper — keyed window store (per-key windows, bulk)")
        if args.quick:
            # K=64k rides along at reduced T so CI exercises the very
            # cliff the fused hot path exists to kill; the window=4096
            # max row rides along (reduced T) so CI gates the flip-sweep
            # acceptance configuration too
            rows = bench_keyed.main(Ks=(256, 4096, 65536), chunks=(1024,),
                                    T=16384, loop_T=400, big_T=8192)
        else:
            rows = bench_keyed.main()
        done("keyed", rows)
    if on("service"):
        from benchmarks import bench_service

        print("# beyond-paper — multi-tenant analytics service (live HTTP)")
        if args.quick:
            rows = bench_service.main(tenants=2, n_per_tenant=6000,
                                      batch=128, universe=256,
                                      quota_rows=1500)
        else:
            rows = bench_service.main()
        done("service", rows)
    if on("roofline"):
        print("# §Roofline — dry-run derived table")
        rows = roofline_table.main()
        done("roofline", rows)

    if args.compare:
        sys.exit(1 if compare_rows(all_rows, args.compare, args.threshold)
                 else 0)


if __name__ == "__main__":
    main()
