"""Benchmark driver — one function per paper table/figure.

Prints ``name,...,derived`` CSV lines.  Scales are reduced for the single-
core CPU container (see benchmarks/common.py); EXPERIMENTS.md records a full
run's output.

  Fig 9  → bench_latency      per-op latency + exact ⊗-count distributions
  Fig 10 → bench_throughput   throughput vs window size (static)
  Fig 11 → bench_dynamic      fill-and-drain dynamic windows
  Fig 12 → bench_eventtime    event-time windows, bursty stream
  §2.1   → bench_batched      SIMD/vmap batched SWAG (beyond paper)
  §8.2   → bench_chunked      chunked bulk engine vs per-element stream
  §Roofline → roofline_table  rendered from experiments/dryrun/*.json
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: latency,throughput,dynamic,eventtime,"
                         "batched,chunked,roofline")
    ap.add_argument("--quick", action="store_true", help="reduced sizes")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    def on(name):
        return want is None or name in want

    from benchmarks import (
        bench_batched,
        bench_chunked,
        bench_dynamic,
        bench_eventtime,
        bench_latency,
        bench_throughput,
        roofline_table,
    )

    if on("latency"):
        print("# Fig 9 — latency")
        if args.quick:
            bench_latency.main(window=2**8, rounds=800, operators=("sum",))
        else:
            bench_latency.main()
    if on("throughput"):
        print("# Fig 10 — throughput (static windows)")
        if args.quick:
            bench_throughput.main(windows=(2**4,), items=50_000, operators=("sum",))
        else:
            bench_throughput.main()
    if on("dynamic"):
        print("# Fig 11 — throughput (dynamic fill-and-drain)")
        if args.quick:
            bench_dynamic.main(windows=(2**4,), items=30_000, operators=("sum",))
        else:
            bench_dynamic.main()
    if on("eventtime"):
        print("# Fig 12 — event-time windows (synthetic bursty stream)")
        bench_eventtime.main(n_items=2000 if args.quick else 6000)
    if on("batched"):
        print("# beyond-paper — batched/SIMD SWAG")
        if args.quick:
            bench_batched.main(batches=(16,), steps=4000)
        else:
            bench_batched.main()
    if on("chunked"):
        print("# §8.2 — chunked bulk engine vs per-element stream")
        if args.quick:
            bench_chunked.main(window=2**8, T=20_000, B=4, pe_T=5_000)
        else:
            bench_chunked.main()
    if on("roofline"):
        print("# §Roofline — dry-run derived table")
        roofline_table.main()


if __name__ == "__main__":
    main()
