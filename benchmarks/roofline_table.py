"""Render the §Roofline table from the dry-run JSON records."""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

COLS = (
    "arch,shape,mesh,chips,t_compute_ms,t_memory_ms,t_collective_ms,"
    "bottleneck,useful_frac,roofline_frac,note"
)


def load_all(dry_dir=None):
    recs = []
    for path in sorted(glob.glob(os.path.join(dry_dir or DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def render(recs) -> list[str]:
    rows = [COLS]
    for r in recs:
        if r.get("skipped"):
            rows.append(
                f"{r['arch']},{r['shape']},{r['mesh']},-,-,-,-,skip,-,-,"
                f"\"{r['note']}\""
            )
            continue
        rows.append(
            f"{r['arch']},{r['shape']},{r['mesh']},{r['chips']},"
            f"{r['t_compute']*1e3:.2f},{r['t_memory']*1e3:.2f},"
            f"{r['t_collective']*1e3:.2f},{r['bottleneck']},"
            f"{r['useful_fraction']:.3f},{r['roofline_fraction']:.4f},"
        )
    return rows


def main():
    recs = load_all()
    if not recs:
        print("roofline,no dry-run records found — run repro.launch.dryrun first")
        return []
    rows = render(recs)
    for row in rows:
        print(row)
    return rows


if __name__ == "__main__":
    main()
