"""Paper Fig. 12: event-time windows on bursty real-like data.

The DEBS 2012 manufacturing dataset is not available offline; we synthesize a
statistically similar stream (≈100 Hz arrivals, bursty inter-arrival times,
occasional gaps causing bulk evictions) and maintain a τ-second event-time
window of the paper's Query-2-style aggregation (relative variation =
windowed variance / mean, via the Welford-merge monoid).

Reported: items/s and the per-round ⊗-count distribution — bulk evictions
make ALL algorithms pay O(k) for k expired items (matching the paper's
observation that bulk evictions equalize max latency), but per-eviction cost
stays O(1) only for DABA/DABA Lite.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import ALGORITHMS, counting, monoids


def synth_event_stream(n, seed=0):
    rng = np.random.default_rng(seed)
    # bursty arrivals: mixture of 100 Hz base rate and pauses
    gaps = rng.exponential(0.01, n)
    pause = rng.random(n) < 0.001
    gaps[pause] += rng.exponential(2.0, pause.sum())
    times = np.cumsum(gaps)
    vals = 50 + 10 * np.sin(times / 60) + rng.standard_normal(n)
    return times, vals


def run_eventtime(algo_name, tau, n_items=20_000):
    m, ctr = counting(monoids.variance_monoid())
    algo = ALGORITHMS[algo_name]
    cap = 4096
    st = algo.init(m, cap)
    times, vals = synth_event_stream(n_items)
    ts_buf = []
    counts = np.empty(n_items, np.int64)
    t0 = time.perf_counter()
    for i in range(n_items):
        ctr.reset()
        if len(ts_buf) >= cap - 1:  # capacity guard (host-side resize point)
            st = algo.evict(m, st)
            ts_buf.pop(0)
        st = algo.insert(m, st, float(vals[i]))
        ts_buf.append(times[i])
        while ts_buf and ts_buf[0] < times[i] - tau:
            st = algo.evict(m, st)
            ts_buf.pop(0)
        algo.query(m, st)
        counts[i] = ctr.count
    wall = time.perf_counter() - t0
    return n_items / wall, counts


def main(tau=10.0, n_items=6000):
    rows = []
    for algo in ["two_stacks_lite", "daba", "daba_lite"]:
        thr, counts = run_eventtime(algo, tau, n_items)
        rows.append(
            f"eventtime,relvar,{algo},tau={tau},items_per_s={thr:.0f},"
            f"combines_p50={np.percentile(counts, 50):.0f},"
            f"combines_p99={np.percentile(counts, 99):.0f},"
            f"combines_max={counts.max()}"
        )
        print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    main()
