"""Paper Fig. 12: event-time windows on bursty real-like data.

The DEBS 2012 manufacturing dataset is not available offline; we synthesize a
statistically similar stream (≈100 Hz arrivals, bursty inter-arrival times,
occasional gaps causing bulk evictions) and maintain a τ-second event-time
window of the paper's Query-2-style aggregation (relative variation =
windowed variance / mean, via the Welford-merge monoid).

Reported: items/s and the per-round ⊗-count distribution — bulk evictions
make ALL algorithms pay O(k) for k expired items (matching the paper's
observation that bulk evictions equalize max latency), but per-eviction cost
stays O(1) only for DABA/DABA Lite.

A second, jitted section benchmarks the BULK event-time engine
(:class:`repro.core.event_time.EventTimeChunkedStream`) on a disordered
stream across horizons, for both an invertible monoid (sum — prefix-scan
fast path) and a non-invertible one (max — the segmented two-stacks flip
sweep, constant combines per released element).  Bulk rows carry
``roofline_frac`` against
:func:`repro.roofline.analysis.eventtime_release_cost` and are the rows the
CI ``--compare`` gate tracks (the per-element Fig-12 rows time host Python
loops and are informational only)::

    eventtime,max,bulk,horizon=1024,chunk=1024,T=30000,B=8,items_per_s=...
    eventtime,sum,disorder,d=16,horizon=256,chunk=1024,T=30000,B=8,...

The ``disorder`` rows are the adaptivity sweep of the disorder-adaptive
release path (:mod:`repro.core.ooo_index`): d = 0 must ride the no-sort
fast branch, d ∈ {16, 256} the bounded merge; ``roofline_frac`` uses the
distance-aware release model.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import ALGORITHMS, counting, monoids
from repro.core.event_time import EventTimeChunkedStream
from repro.data.stream import DisorderedEventStream
from repro.roofline.analysis import eventtime_release_cost


def synth_event_stream(n, seed=0):
    rng = np.random.default_rng(seed)
    # bursty arrivals: mixture of 100 Hz base rate and pauses
    gaps = rng.exponential(0.01, n)
    pause = rng.random(n) < 0.001
    gaps[pause] += rng.exponential(2.0, pause.sum())
    times = np.cumsum(gaps)
    vals = 50 + 10 * np.sin(times / 60) + rng.standard_normal(n)
    return times, vals


def run_eventtime(algo_name, tau, n_items=20_000):
    m, ctr = counting(monoids.variance_monoid())
    algo = ALGORITHMS[algo_name]
    cap = 4096
    st = algo.init(m, cap)
    times, vals = synth_event_stream(n_items)
    ts_buf = []
    counts = np.empty(n_items, np.int64)
    t0 = time.perf_counter()
    for i in range(n_items):
        ctr.reset()
        if len(ts_buf) >= cap - 1:  # capacity guard (host-side resize point)
            st = algo.evict(m, st)
            ts_buf.pop(0)
        st = algo.insert(m, st, float(vals[i]))
        ts_buf.append(times[i])
        while ts_buf and ts_buf[0] < times[i] - tau:
            st = algo.evict(m, st)
            ts_buf.pop(0)
        algo.query(m, st)
        counts[i] = ctr.count
    wall = time.perf_counter() - t0
    return n_items / wall, counts


def bulk_throughput(monoid, horizon, T, B, chunk=1024, disorder=0.1,
                    slack=None, repeats=3, seed=7):
    """Best-of-``repeats`` items/s for the bulk event-time engine on a
    disordered stream (best-of beats machine noise; the engine is jitted
    and state-free across repeats).  ``slack`` bounds lateness (and so the
    out-of-order distance); defaults to horizon / 16."""
    if slack is None:
        slack = max(float(horizon) / 16, 1.0)
    s = DisorderedEventStream(T, B, mean_gap=1.0, disorder=disorder,
                              slack=slack, seed=seed)
    ts, xs = s.arrival()
    eng = EventTimeChunkedStream(
        monoid, float(horizon), slack=slack, chunk=chunk,
        capacity=2 * int(horizon) + 64,
        buffer=max(4 * int(slack) + 16, 64),
    )
    out = eng.stream(ts, xs)  # compile
    jax.block_until_ready(jax.tree.leaves(out)[0])
    best = 0.0
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = eng.stream(ts, xs)
        jax.block_until_ready(jax.tree.leaves(out)[0])
        best = max(best, T * B / (time.perf_counter() - t0))
    return best


def _roofline_frac(thr, chunk, horizon, B, distance=0):
    bound = eventtime_release_cost(
        chunk, 2 * int(horizon) + 64, distance=distance, batch=B
    )["items_per_s_bound"]
    return thr / bound if bound > 0 else 0.0


def main(tau=10.0, n_items=6000, horizons=(256, 1024, 2048), bulk_T=30000,
         bulk_B=8, bulk_chunk=1024, disorder_ds=(0, 16, 256)):
    """``disorder_ds``: the adaptivity sweep — out-of-order distance d per
    row (d = 0 is the no-sort ``lax.cond`` fast branch; d > 0 streams are
    50% late rows with lateness, hence displacement, bounded by slack = d).
    The d = 0 row shares its configuration (horizon=256, slack=16, seed,
    capacity/buffer formulas) with the committed ``chunked,sum,
    eventtime_d0.0`` row, so the two are directly comparable across PRs."""
    rows = []
    for algo in ["two_stacks_lite", "daba", "daba_lite"]:
        thr, counts = run_eventtime(algo, tau, n_items)
        rows.append(
            f"eventtime,relvar,{algo},tau={tau},items_per_s={thr:.0f},"
            f"combines_p50={np.percentile(counts, 50):.0f},"
            f"combines_p99={np.percentile(counts, 99):.0f},"
            f"combines_max={counts.max()}"
        )
        print(rows[-1], flush=True)
    for name, monoid in (("sum", monoids.sum_monoid()),
                         ("max", monoids.max_monoid())):
        for h in horizons:
            # disorder 0.1 bounded by slack = h/16 → distance ≈ h//16
            thr = bulk_throughput(monoid, h, bulk_T, bulk_B, chunk=bulk_chunk)
            frac = _roofline_frac(thr, bulk_chunk, h, bulk_B,
                                  distance=int(h) // 16)
            rows.append(
                f"eventtime,{name},bulk,horizon={h},chunk={bulk_chunk},"
                f"T={bulk_T},B={bulk_B},items_per_s={thr:.0f},"
                f"roofline_frac={frac:.3f}"
            )
            print(rows[-1], flush=True)
    # the adaptivity sweep: fixed horizon, out-of-order distance d per row
    for d in disorder_ds:
        monoid = monoids.sum_monoid()
        h = 256
        slack = float(max(d, 16))
        thr = bulk_throughput(monoid, h, bulk_T, bulk_B, chunk=bulk_chunk,
                              disorder=0.0 if d == 0 else 0.5, slack=slack)
        frac = _roofline_frac(thr, bulk_chunk, h, bulk_B, distance=d)
        rows.append(
            f"eventtime,sum,disorder,d={d},horizon={h},chunk={bulk_chunk},"
            f"T={bulk_T},B={bulk_B},items_per_s={thr:.0f},"
            f"roofline_frac={frac:.3f}"
        )
        print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    main()
