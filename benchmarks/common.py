"""Shared benchmark machinery.

The paper's C++ benchmarks (§7) measure per-operation wall time over 10M+
rounds.  On this CPU-only container we measure two complementary signals:

  * wall-clock per round for JIT-compiled op sequences (dispatch-dominated
    but comparable across algorithms), and
  * exact ⊗-invocation counts per operation (hardware-independent — the
    quantity the paper's theorems bound, and the dominant cost when the
    operator is expensive, e.g. bloom).

Scales are reduced (10M → 20k rounds; window 2^14 → 2^12 default) to fit the
single-core budget; the relative ordering matches the paper's findings.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ALGORITHMS, counting, monoids

OPERATORS = {
    # the paper's cost spectrum: cheap / medium / expensive
    "sum": lambda: monoids.sum_monoid(),
    "geomean": lambda: monoids.geomean_monoid(),
    "bloom": lambda: monoids.bloom_monoid(num_words=64),
}

ALGOS = ["two_stacks", "two_stacks_lite", "daba", "daba_lite", "recalc"]


def make_round_fn(algo_name: str, monoid, jit: bool = True):
    """One paper round: evict, insert, query (static window)."""
    algo = ALGORITHMS[algo_name]

    def round_fn(state, v):
        state = algo.evict(monoid, state)
        state = algo.insert(monoid, state, v)
        q = algo.query(monoid, state)
        return state, q

    return jax.jit(round_fn) if jit else round_fn


def fill(algo_name, monoid, n, cap):
    algo = ALGORITHMS[algo_name]
    st = algo.init(monoid, cap)
    ins = jax.jit(lambda s, v: algo.insert(monoid, s, v))
    for i in range(n):
        st = ins(st, jnp.float32(i % 97))
    return st


def time_rounds(algo_name, monoid, window, rounds, warmup=200):
    """Per-round wall latencies (seconds)."""
    st = fill(algo_name, monoid, window, window + 2)
    rf = make_round_fn(algo_name, monoid)
    vals = np.random.default_rng(0).uniform(0, 97, rounds + warmup).astype(np.float32)
    for i in range(warmup):
        st, q = rf(st, vals[i])
    jax.block_until_ready(q)
    lat = np.empty(rounds)
    for i in range(rounds):
        t0 = time.perf_counter()
        st, q = rf(st, vals[warmup + i])
        jax.block_until_ready(q)
        lat[i] = time.perf_counter() - t0
    return lat


def count_rounds(algo_name, base_monoid, window, rounds):
    """Exact ⊗-invocations per round (evict+insert+query), eager."""
    m, ctr = counting(base_monoid)
    algo = ALGORITHMS[algo_name]
    st = algo.init(m, window + 2)
    for i in range(window):
        st = algo.insert(m, st, float(i % 97))
    counts = np.empty(rounds, np.int64)
    vals = np.random.default_rng(0).uniform(0, 97, rounds)
    for i in range(rounds):
        ctr.reset()
        st = algo.evict(m, st)
        st = algo.insert(m, st, float(vals[i]))
        algo.query(m, st)
        counts[i] = ctr.count
    return counts


def scan_throughput(algo_name, monoid, window, total_items, batch=1):
    """Whole-stream compiled throughput (items/s) via lax.scan."""
    algo = ALGORITHMS[algo_name]

    def step(st, x):
        st = algo.evict(monoid, st)
        st = algo.insert(monoid, st, x)
        return st, algo.query(monoid, st)

    chunk = min(total_items, 50_000)
    xs = jnp.asarray(
        np.random.default_rng(0).uniform(0, 97, chunk).astype(np.float32)
    )
    run = jax.jit(lambda st: jax.lax.scan(step, st, xs)[0])
    st = fill(algo_name, monoid, window, window + 2)
    st = run(st)  # compile + warm
    jax.block_until_ready(jax.tree.leaves(st)[0])
    done, t0 = 0, time.perf_counter()
    while done < total_items:
        st = run(st)
        done += chunk
    jax.block_until_ready(jax.tree.leaves(st)[0])
    return done / (time.perf_counter() - t0)


def pctile_row(name, arr, scale=1e6):
    a = np.asarray(arr, float) * scale
    return (f"{name},min={a.min():.2f},p50={np.percentile(a, 50):.2f},"
            f"p99={np.percentile(a, 99):.2f},max={a.max():.2f}")
