"""Paper Fig. 10: throughput vs window size, static count-based windows."""

from __future__ import annotations

from benchmarks.common import ALGOS, OPERATORS, scan_throughput


def main(windows=(2**2, 2**6, 2**10), items=100_000, operators=("sum", "bloom")):
    rows = []
    for op_name in operators:
        for algo in ALGOS:
            if algo == "recalc" and op_name == "bloom":
                continue  # O(n·bloom) per query: prohibitively slow, as expected
            for w in windows:
                thr = scan_throughput(algo, OPERATORS[op_name](), w, items)
                rows.append(
                    f"throughput,{op_name},{algo},window={w},items_per_s={thr:.0f}"
                )
                print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    main()
