"""Multi-tenant analytics service under live HTTP load (beyond paper).

The full production path, end to end: per-tenant Zipf-keyed load
generators (:class:`repro.data.stream.MultiTenantEventStream`) POST JSON
batches over real HTTP into a live :class:`repro.service.http
.ServiceHTTPServer`, whose consumer thread drains the tenant queues in
batched round-robin into ONE shared keyed window engine.  Reported:

  * ``ingest`` — sustained accepted events/s across all tenant clients
    (wall clock from first POST to last row queryable, warm engine) —
    the regression-gated row;
  * ``latency`` — ingest→queryable p50/p95/p99 per accepted batch
    (enqueue stamp → post-drain sync), from the service's exact ring;
  * ``quota`` — the noisy-neighbor scenario: one tenant drives past its
    token-bucket quota and collects 429s while an in-quota tenant runs
    untouched; the in-quota tenant's window folds are asserted BIT-EXACT
    against an offline :class:`repro.core.keyed.KeyedChunkedStream`
    replay of exactly its accepted rows (``bitexact=1`` in the row).

Rows use the repo CSV style::

    service,ingest,tenants=4,batch=256,rows=...,chunk=1024,window=256,items_per_s=...
    service,latency,tenants=4,batch=256,p50_ms=...,p95_ms=...,p99_ms=...
    service,quota,throttled_rows=...,good_rows=...,bitexact=1
"""

from __future__ import annotations

import json
import threading
import time
import urllib.error
import urllib.request

import jax.numpy as jnp
import numpy as np

from repro.core.keyed import KeyedChunkedStream
from repro.core.monoids import get_monoid
from repro.data.stream import MultiTenantEventStream
from repro.service import AnalyticsService, ServiceConfig, ServiceHTTPServer


def _post(url, doc):
    req = urllib.request.Request(
        url, json.dumps(doc).encode(), {"Content-Type": "application/json"}
    )
    try:
        with urllib.request.urlopen(req, timeout=60) as r:
            return r.status
    except urllib.error.HTTPError as e:
        e.read()
        return e.code


def _pump(url, tenant, batches, codes):
    """One tenant's HTTP client: POST every batch, record status codes."""
    out = []
    for keys, ts, xs in batches:
        out.append(_post(f"{url}/ingest", {
            "tenant": tenant,
            "keys": keys.tolist(),
            "ts": ts.tolist(),
            "values": xs.tolist(),
        }))
    codes[tenant] = out


def _warmup(svc, url, cfg):
    """Compile every hot path (full-chunk dispatch, rollup, padded query)
    on a throwaway tenant, then clear the latency ring."""
    n = cfg.max_batch
    ts = np.linspace(0.0, 1.0, n)
    for i in range(2 * (cfg.chunk // n) + 2):
        keys = np.arange(n, dtype=np.int64) % 64
        code = _post(f"{url}/ingest", {
            "tenant": "_warmup", "keys": keys.tolist(),
            "ts": (ts + i).tolist(), "values": [1] * n,
        })
        assert code == 200, code
    assert svc.flush(timeout=300)
    svc.query("_warmup", keys=[0, 1])
    with svc._lock:
        svc._latencies.clear()


def _offline_folds(cfg, accepted, query_keys):
    """Oracle replay: the tenant's accepted rows through a fresh engine."""
    eng = KeyedChunkedStream(
        get_monoid(cfg.monoid), cfg.window, cfg.slots, cfg.chunk,
        horizon=cfg.horizon, donate=False,
    )
    keys = np.concatenate([b[0] for b in accepted]).astype(np.int32)
    ts = np.concatenate([b[1] for b in accepted]).astype(np.float32)
    xs = np.concatenate([b[2] for b in accepted]).astype(np.int32)
    state, _ = eng.stream(keys, xs, ts=ts)
    aggs, found = eng.query(state, jnp.asarray(query_keys, jnp.int32))
    return np.asarray(eng.monoid.lower(aggs)), np.asarray(found)


def ingest_throughput(tenants, n_per_tenant, universe, batch, chunk, window,
                      horizon, seed=0):
    """Sustained events/s + latency percentiles under concurrent tenant
    clients (quota effectively unlimited — this row measures the data
    path, not admission)."""
    cfg = ServiceConfig(
        window=window, horizon=horizon, slots=1 << 14, chunk=chunk,
        max_batch=batch, quota_rows_per_s=1e12, quota_burst=1e12,
        global_rows_hw=1 << 22, tenant_queue_batches=1 << 14,
    )
    gen = MultiTenantEventStream(tenants, n_per_tenant, universe, seed=seed)
    feeds = [list(gen.batches(i, batch)) for i in range(tenants)]
    svc = AnalyticsService(cfg)
    with ServiceHTTPServer(svc) as srv:
        _warmup(svc, srv.url, cfg)
        codes: dict = {}
        threads = [
            threading.Thread(target=_pump,
                             args=(srv.url, f"t{i}", feeds[i], codes))
            for i in range(tenants)
        ]
        t0 = time.perf_counter()
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert svc.flush(timeout=600)
        elapsed = time.perf_counter() - t0
        total = tenants * n_per_tenant
        for i in range(tenants):
            assert all(c == 200 for c in codes[f"t{i}"]), codes[f"t{i}"][:5]
        stats = svc.stats()
        lat = stats["ingest_to_queryable"]
        health = stats["per_tenant"]
        assert all(t["dropped_rows"] == 0 for t in health.values())
    return total / elapsed, lat


def quota_scenario(n_batches, batch, universe, seed=1):
    """Noisy neighbor: rate-limited bucket shared config; 'noisy' sends
    ~3x the burst, 'good' stays inside it.  Returns (throttled_rows,
    good_rows, bitexact) — bitexact compares the good tenant's served
    folds against the offline replay of its accepted rows."""
    burst = float(batch * n_batches)  # good (n_batches) fits; 3x does not
    cfg = ServiceConfig(
        window=64, horizon=16.0, slots=2048, chunk=max(256, batch),
        max_batch=batch, quota_rows_per_s=1.0, quota_burst=burst,
        global_rows_hw=1 << 22, tenant_queue_batches=1 << 14,
    )
    gen = MultiTenantEventStream(2, 3 * n_batches * batch, universe,
                                 seed=seed)
    noisy = list(gen.batches(0, batch))
    good = list(gen.batches(1, batch))[:n_batches]
    svc = AnalyticsService(cfg)
    with ServiceHTTPServer(svc) as srv:
        _warmup(svc, srv.url, cfg)
        accepted_good = []
        n_429 = 0
        for i, nb in enumerate(noisy):
            code = _post(f"{srv.url}/ingest", {
                "tenant": "noisy", "keys": nb[0].tolist(),
                "ts": nb[1].tolist(), "values": nb[2].tolist(),
            })
            n_429 += code == 429
            if i < len(good):
                gb = good[i]
                code = _post(f"{srv.url}/ingest", {
                    "tenant": "good", "keys": gb[0].tolist(),
                    "ts": gb[1].tolist(), "values": gb[2].tolist(),
                })
                assert code == 200, code  # in-quota tenant never throttled
                accepted_good.append(gb)
        assert n_429 > 0, "noisy tenant was never throttled"
        assert svc.flush(timeout=600)
        _, snap_noisy = svc.query("noisy")
        throttled = snap_noisy["counters"]["throttled_rows"]
        # bit-exactness of the good tenant, unaffected by the neighbor
        qk = np.unique(np.concatenate([b[0] for b in accepted_good]))[:64]
        _, snap = svc.query("good", keys=qk.tolist())
        vals, found = _offline_folds(cfg, accepted_good, qk)
        bitexact = all(
            snap["keys"][str(int(k))]["found"] == bool(found[i])
            and snap["keys"][str(int(k))]["fold"] == int(vals[i])
            for i, k in enumerate(qk)
        )
    good_rows = sum(b[0].shape[0] for b in accepted_good)
    return int(throttled), int(good_rows), int(bitexact)


def main(tenants=4, n_per_tenant=40_000, universe=2000, batch=256,
         chunk=1024, window=256, horizon=64.0, quota_rows=4096):
    rows = []

    def emit(row):
        print(row)
        rows.append(row)

    thr, lat = ingest_throughput(
        tenants, n_per_tenant, universe, batch, chunk, window, horizon
    )
    emit(f"service,ingest,tenants={tenants},batch={batch},"
         f"rows={tenants * n_per_tenant},chunk={chunk},window={window},"
         f"items_per_s={thr:.0f}")
    emit(f"service,latency,tenants={tenants},batch={batch},"
         f"p50_ms={lat.get('p50_ms', 0)},p95_ms={lat.get('p95_ms', 0)},"
         f"p99_ms={lat.get('p99_ms', 0)}")

    n_batches = max(2, quota_rows // batch)
    throttled, good_rows, bitexact = quota_scenario(
        n_batches, batch, universe
    )
    emit(f"service,quota,batch={batch},throttled_rows={throttled},"
         f"good_rows={good_rows},bitexact={bitexact}")
    assert bitexact == 1, "good tenant's folds diverged from offline replay"
    return rows


if __name__ == "__main__":
    main()
