"""Serving demo: continuous batching + windowed-state decode.

1. A DecodeEngine serves batched requests against a reduced llama model,
   surfacing per-request keyed telemetry windows.
2. Serve telemetry survives a restart: save_telemetry / restore_telemetry
   across a simulated engine replacement, with watermark continuity
   asserted (post-restore observations continue the saved event-time
   window instead of being dropped as late).
3. The beyond-paper feature: an RWKV-style windowed-state decode where the
   last-W-token SSM state is maintained by DABA Lite in worst-case O(1)
   combines per token — bounded-context decoding whose per-token cost and
   memory do not grow with history (the long_500k serving path).

    PYTHONPATH=src python examples/serve_windowed.py
"""

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core.windowed_state import ChunkedWindowedStateCell, WindowedStateCell
from repro.models.factory import reduced_config
from repro.models.transformer import build_model
from repro.serve.engine import DecodeEngine, Request


def continuous_batching():
    print("— continuous batching over 2 slots, 6 requests —")
    cfg = reduced_config(ARCHS["llama3.2-1b"])
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    eng = DecodeEngine(cfg, params, batch_slots=2, cache_len=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size, int(rng.integers(4, 16))).astype(np.int32),
                max_new=8)
        for i in range(6)
    ]
    for r in reqs:
        eng.submit(r)
    steps = 0
    while eng.step() or eng.queue:
        steps += 1
        if steps > 100:
            break
    print(f"  served {sum(r.done for r in reqs)}/6 requests in {steps} engine steps")
    print(f"  request 0 generated: {reqs[0].out}")
    rt = eng.request_telemetry()
    shown = sorted(r for r in rt if isinstance(r, int))[:3]
    for rid in shown:
        print(f"  request {rid}: {rt[rid]['tokens']} decoded tokens, "
              f"decode mean {rt[rid]['decode_ms_mean']:.1f} ms "
              f"(keyed per-request window)")


def telemetry_restart():
    print("\n— serve telemetry across a simulated restart —")
    cfg = reduced_config(ARCHS["llama3.2-1b"])
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    rng = np.random.default_rng(3)

    def serve_some(engine, n, rid0):
        for i in range(n):
            engine.submit(Request(
                rid=rid0 + i,
                prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                max_new=4,
            ))
        engine.run_until_drained(max_steps=60)

    eng = DecodeEngine(cfg, params, batch_slots=2, cache_len=32,
                       telemetry_window=32)
    serve_some(eng, 4, rid0=0)
    before = eng.telemetry()
    wm_before = eng._telem.last_timestamp()
    with tempfile.TemporaryDirectory() as ckpt_dir:
        eng.save_telemetry(ckpt_dir, step=1)
        del eng  # the "crash"

        eng2 = DecodeEngine(cfg, params, batch_slots=2, cache_len=32,
                            telemetry_window=32)
        eng2.restore_telemetry(ckpt_dir)
    wm_restored = eng2._telem.last_timestamp()
    # watermark continuity: the restored window resumes the saved stream
    assert abs(wm_restored - wm_before) < 1e-6, (wm_restored, wm_before)
    after = eng2.telemetry()
    assert after["decode_ms_p99"] == before["decode_ms_p99"]
    print(f"  restored watermark {wm_restored:.3f}s == saved {wm_before:.3f}s")

    # post-restore steps must land AFTER the watermark (not dropped as late)
    serve_some(eng2, 4, rid0=100)
    wm_after = eng2._telem.last_timestamp()
    assert wm_after >= wm_restored, (wm_after, wm_restored)
    assert eng2.telemetry()["telemetry_overflow"] == 0
    occ = eng2.telemetry()["slot_occupancy"]
    print(f"  post-restore watermark {wm_after:.3f}s (advanced, nothing "
          f"dropped); occupancy {np.round(occ, 2)}")


def windowed_state_decode():
    print("\n— windowed SSM state via DABA Lite (exact 256-token window) —")
    H, K, V, W = 4, 16, 16, 256
    cell = WindowedStateCell(H, K, V, W)
    st = cell.init()
    step = jax.jit(cell.update)
    rng = np.random.default_rng(1)
    d = jnp.asarray(rng.uniform(0.95, 1.0, (H, K, 1)), jnp.float32)
    # warm + time per-token cost at two very different history lengths
    for t in [100, 2000]:
        u = jnp.asarray(rng.standard_normal((H, K, V)), jnp.float32)
        while int(st.e - st.f) < min(t, W):
            st, out = step(st, d, u)
        t0 = time.perf_counter()
        for _ in range(50):
            st, out = step(st, d, u)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / 50 * 1e6
        print(f"  after ~{t:5d} tokens: {us:7.1f} µs/token (O(1): flat in history)")

    print("\n— coarse-grained 500k-scale window (chunk=4096, 16 chunks) —")
    cell2 = ChunkedWindowedStateCell(H, K, V, chunk=4096, window_chunks=16)
    st2 = cell2.init()
    step2 = jax.jit(cell2.update)
    u = jnp.asarray(rng.standard_normal((H, K, V)), jnp.float32)
    st2, out = step2(st2, d, u)  # compile
    t0 = time.perf_counter()
    for _ in range(200):
        st2, out = step2(st2, d, u)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / 200 * 1e6
    ring = cell2.window_chunks + 1
    print(f"  {us:.1f} µs/token; state memory = {ring} chunk aggregates "
          f"(not 65536 per-token maps) — paper §8.2 coarse-grained sliding")


if __name__ == "__main__":
    continuous_batching()
    telemetry_restart()
    windowed_state_decode()
