"""End-to-end training driver: any assigned arch, fault-tolerant loop,
DABA-Lite windowed telemetry inside the jitted step.

Default runs a reduced llama3.2-1b for 60 steps on CPU in ~a minute; pass
``--arch <id> --full`` to use the exact assigned config (sized for the
production mesh — on this CPU container use the dry-run instead).

    PYTHONPATH=src python examples/train_lm.py --steps 60
    PYTHONPATH=src python examples/train_lm.py --arch rwkv6-1.6b --steps 40
"""

import argparse

import jax

from repro.configs import ARCHS
from repro.data.stream import SyntheticStream
from repro.models.factory import reduced_config
from repro.optim.adamw import AdamW, warmup_cosine
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--full", action="store_true",
                    help="use the full assigned config (production-mesh sized)")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = ARCHS[args.arch] if args.full else reduced_config(ARCHS[args.arch])
    print(f"arch={cfg.name}  params≈{cfg.param_count()/1e6:.1f}M  "
          f"(active {cfg.active_param_count()/1e6:.1f}M)")

    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_every=max(args.steps // 3, 1),
        ckpt_dir=args.ckpt_dir,
        metric_window=32,
        log_every=5,
        compress_grads=args.compress_grads,
    )
    stream = SyntheticStream(cfg, batch=args.batch, seq=args.seq, seed=0)
    opt = AdamW(learning_rate=warmup_cosine(3e-3, args.steps // 10, args.steps))
    trainer = Trainer(cfg, tcfg, opt, stream)
    state = trainer.resume_or_init(jax.random.key(0))
    state = trainer.run(state)

    print(f"\ntrained to step {int(state.step)}; windowed telemetry "
          f"(DABA Lite, worst-case O(1)/step):")
    for h in trainer.history[-4:]:
        print(f"  step {h['step']:4d}  loss={h['loss']:.4f}  "
              f"win_mean={h['win/loss_mean']:.4f}  win_std={h['win/loss_std']:.4f}  "
              f"win_gnorm_max={h['win/gnorm_max']:.3f}")
    if trainer.straggler_events:
        print(f"straggler steps detected: {trainer.straggler_events}")


if __name__ == "__main__":
    main()
