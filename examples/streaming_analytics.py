"""Streaming analytics: the paper's aggregators over a live data stream.

Maintains, with worst-case O(1) updates per event:
  * a 60-second event-time window of relative variation (DEBS'12 Query-2
    style) via the Welford-merge variance monoid,
  * a windowed Bloom filter for "seen recently?" dedup (non-invertible OR
    monoid — subtract-on-evict is impossible, DABA Lite is required),
  * batched per-key windows (partition parallelism, paper §8.2) as one
    vmapped state, streamed in two warm-continued halves,
  * a unified WindowedTelemetry state: several named metrics in ONE
    product-monoid window — single dispatch per observation, chunked bulk
    ingest for whole batches.

    PYTHONPATH=src python examples/streaming_analytics.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import WindowedTelemetry, daba_lite, monoids
from repro.core.batched import BatchedSWAG


def event_time_relvar():
    print("— event-time window: relative variation over last τ=60 s —")
    m = monoids.variance_monoid()
    st = daba_lite.init(m, 1 << 12)
    rng = np.random.default_rng(0)
    times = np.cumsum(rng.exponential(0.5, 2000))
    vals = 50 + 10 * np.sin(times / 120) + rng.standard_normal(2000)
    buf = []
    for t, v in zip(times, vals):
        st = daba_lite.insert(m, st, float(v))
        buf.append(t)
        while buf and buf[0] < t - 60.0:
            st = daba_lite.evict(m, st)
            buf.pop(0)
    q = daba_lite.query(m, st)
    n = max(float(q["n"]), 1.0)
    mean, var = float(q["mu"]), float(q["m2"]) / n
    print(f"  events in window: {int(n)}   mean={mean:.2f}  relvar={var/mean:.4f}")


def windowed_dedup():
    print("\n— windowed Bloom dedup (last 128 doc ids) —")
    m = monoids.bloom_monoid(num_words=64)
    st = daba_lite.init(m, 130)
    for doc in range(200):
        st = daba_lite.insert(m, st, jnp.asarray(doc))
        if daba_lite.size(st) > 128:
            st = daba_lite.evict(m, st)
    filt = daba_lite.query(m, st)
    recent = [int(monoids.bloom_contains(filt, jnp.asarray(d))) for d in (199, 150, 80)]
    print(f"  seen(199)={bool(recent[0])}  seen(150)={bool(recent[1])}  "
          f"seen(80, evicted)={bool(recent[2])} (false positives possible)")


def per_key_windows():
    print("\n— 1024 per-key windows in lock-step (vmapped DABA Lite) —")
    b = BatchedSWAG(daba_lite, monoids.maxcount_monoid(), capacity=34)
    st = b.init(1024)
    xs = jnp.asarray(
        np.random.default_rng(1).integers(0, 100, (200, 1024)), jnp.float32
    )
    # Two warm-continued halves — the live windows carry across stream calls
    # (streams of T ≥ 2048 would auto-route through the chunked bulk engine).
    st, _ = b.stream(st, xs[:120], window=32)
    st, qs = b.stream(st, xs[120:], window=32)
    q = qs  # (T, batch) pytree of {m, c}
    print(f"  final per-key window max (first 5 keys): {np.asarray(q['m'][-1][:5])}")
    print(f"  their maxcounts:                        {np.asarray(q['c'][-1][:5])}")


def unified_telemetry():
    print("\n— unified windowed telemetry (one product-monoid state) —")
    telem = WindowedTelemetry(
        {
            "lat_mean": monoids.mean_monoid(),
            "lat_max": monoids.max_monoid(),
            "err_rate": monoids.mean_monoid(),
        },
        window=64,
    )
    rng = np.random.default_rng(7)
    for _ in range(40):  # single jitted dispatch per observation
        lat = float(rng.gamma(3.0, 2.0))
        telem.observe({"lat_mean": lat, "lat_max": lat,
                       "err_rate": float(rng.random() < 0.03)})
    # whole (C,) chunks stream through the bulk engine in one call
    burst = rng.gamma(9.0, 2.0, 64).astype(np.float32)
    telem.observe_bulk({"lat_mean": burst, "lat_max": burst,
                        "err_rate": np.zeros(64, np.float32)})
    s = telem.snapshot()  # one host transfer for every metric
    print(f"  windowed latency mean={float(s['lat_mean']):.2f}ms  "
          f"max={float(s['lat_max']):.2f}ms  err_rate={float(s['err_rate']):.3f}")


if __name__ == "__main__":
    event_time_relvar()
    windowed_dedup()
    per_key_windows()
    unified_telemetry()
