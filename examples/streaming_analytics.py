"""Streaming analytics: the paper's aggregators over a live data stream.

Maintains, with worst-case O(1) updates per event:
  * a 60-second event-time window of relative variation (DEBS'12 Query-2
    style) via the Welford-merge variance monoid,
  * a windowed Bloom filter for "seen recently?" dedup (non-invertible OR
    monoid — subtract-on-evict is impossible, DABA Lite is required),
  * batched per-key windows (partition parallelism, paper §8.2) as one
    vmapped state.

    PYTHONPATH=src python examples/streaming_analytics.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import daba_lite, monoids
from repro.core.batched import BatchedSWAG


def event_time_relvar():
    print("— event-time window: relative variation over last τ=60 s —")
    m = monoids.variance_monoid()
    st = daba_lite.init(m, 1 << 12)
    rng = np.random.default_rng(0)
    times = np.cumsum(rng.exponential(0.5, 2000))
    vals = 50 + 10 * np.sin(times / 120) + rng.standard_normal(2000)
    buf = []
    for t, v in zip(times, vals):
        st = daba_lite.insert(m, st, float(v))
        buf.append(t)
        while buf and buf[0] < t - 60.0:
            st = daba_lite.evict(m, st)
            buf.pop(0)
    q = daba_lite.query(m, st)
    n = max(float(q["n"]), 1.0)
    mean, var = float(q["mu"]), float(q["m2"]) / n
    print(f"  events in window: {int(n)}   mean={mean:.2f}  relvar={var/mean:.4f}")


def windowed_dedup():
    print("\n— windowed Bloom dedup (last 128 doc ids) —")
    m = monoids.bloom_monoid(num_words=64)
    st = daba_lite.init(m, 130)
    for doc in range(200):
        st = daba_lite.insert(m, st, jnp.asarray(doc))
        if daba_lite.size(st) > 128:
            st = daba_lite.evict(m, st)
    filt = daba_lite.query(m, st)
    recent = [int(monoids.bloom_contains(filt, jnp.asarray(d))) for d in (199, 150, 80)]
    print(f"  seen(199)={bool(recent[0])}  seen(150)={bool(recent[1])}  "
          f"seen(80, evicted)={bool(recent[2])} (false positives possible)")


def per_key_windows():
    print("\n— 1024 per-key windows in lock-step (vmapped DABA Lite) —")
    b = BatchedSWAG(daba_lite, monoids.maxcount_monoid(), capacity=34)
    st = b.init(1024)
    xs = jnp.asarray(
        np.random.default_rng(1).integers(0, 100, (200, 1024)), jnp.float32
    )
    st, qs = b.stream(st, xs, window=32)
    q = qs  # (T, batch) pytree of {m, c}
    print(f"  final per-key window max (first 5 keys): {np.asarray(q['m'][-1][:5])}")
    print(f"  their maxcounts:                        {np.asarray(q['c'][-1][:5])}")


if __name__ == "__main__":
    event_time_relvar()
    windowed_dedup()
    per_key_windows()
