"""Quickstart: worst-case O(1) sliding-window aggregation with DABA Lite.

Runs the paper's §2.3 maxcount trace, a jitted sliding-max over a stream,
and prints the ⊗-invocation counts that make DABA Lite worst-case O(1).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SWAG, counting, daba_lite, monoids, two_stacks


def paper_trace():
    print("— paper §2.3 maxcount trace —")
    win = SWAG(daba_lite, monoids.maxcount_monoid(), capacity=16)
    for v in [4, 5, 3, 4, 0, 4, 4]:
        win.insert(float(v))
    q = win.query()
    print(f"window=[4,5,3,4,0,4,4]  max={float(q['m'])}, maxcount={int(q['c'])}")
    win.evict()
    win.evict()  # drops the 5 — impossible to 'subtract out' (non-invertible)
    q = win.query()
    print(f"after 2 evictions        max={float(q['m'])}, maxcount={int(q['c'])}")
    win.insert(2.0)
    win.insert(6.0)
    q = win.query()
    print(f"after insert 2, 6        max={float(q['m'])}, maxcount={int(q['c'])}")


def jitted_sliding_max():
    print("\n— jitted sliding max over a stream (window 8) —")
    m = monoids.max_monoid()

    def step(st, x):
        st = daba_lite.insert(m, st, x)
        st = jax.lax.cond(
            daba_lite.size(st) > 8, lambda s: daba_lite.evict(m, s), lambda s: s, st
        )
        return st, daba_lite.query(m, st)

    xs = jnp.asarray(np.random.default_rng(0).standard_normal(1000), jnp.float32)
    _, ys = jax.lax.scan(step, daba_lite.init(m, 12), xs)
    ref = np.array([np.asarray(xs)[max(0, t - 7): t + 1].max() for t in range(1000)])
    print(f"1000 steps, max err vs numpy oracle: {np.abs(np.asarray(ys) - ref).max()}")


def worst_case_counts():
    print("\n— worst-case ⊗-invocations (the paper's headline) —")
    for name, algo, bound in [("two_stacks", two_stacks, "O(n)"),
                              ("daba_lite", daba_lite, "O(1)")]:
        m, ctr = counting(monoids.maxcount_monoid())
        st = algo.init(m, 64)
        worst = 0
        rng = np.random.default_rng(1)
        sz = 0
        for i in range(500):
            ctr.reset()
            if sz < 48 and (sz == 0 or rng.random() < 0.55):
                st = algo.insert(m, st, float(rng.integers(0, 9)))
                sz += 1
            else:
                st = algo.evict(m, st)
                sz -= 1
            worst = max(worst, ctr.count)
        print(f"{name:12s} worst ⊗/op over 500 ops: {worst:3d}   ({bound})")


if __name__ == "__main__":
    paper_trace()
    jitted_sliding_max()
    worst_case_counts()
