"""Serve the keyed window engine as a multi-tenant analytics service.

Starts a live :class:`repro.service.http.ServiceHTTPServer` on an
ephemeral port, drives two tenants over real HTTP — one politely inside
its token-bucket quota, one noisy enough to collect 429s — and reads back
per-tenant windowed snapshots, rollup sketches (value quantiles, distinct
keys, heavy hitters) and Prometheus metrics.  Everything stdlib + the
repo: no external client, no new dependencies.

    PYTHONPATH=src python examples/service_quickstart.py
"""

import json
import urllib.error
import urllib.request

import numpy as np

from repro.data.stream import MultiTenantEventStream
from repro.service import AnalyticsService, ServiceConfig, ServiceHTTPServer


def post(url, doc):
    req = urllib.request.Request(
        url, json.dumps(doc).encode(), {"Content-Type": "application/json"}
    )
    try:
        with urllib.request.urlopen(req, timeout=60) as r:
            return r.status, json.loads(r.read()), dict(r.headers)
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read() or b"{}"), dict(e.headers)


def get(url):
    with urllib.request.urlopen(url, timeout=60) as r:
        return r.read().decode()


def main():
    cfg = ServiceConfig(
        window=128,
        horizon=32.0,            # event-time: fold ts in (now-32, now]
        chunk=256,
        max_batch=128,
        quota_rows_per_s=50.0,   # tiny on purpose: the demo shows a 429
        quota_burst=1024.0,
    )
    svc = AnalyticsService(cfg)
    svc.attach_obs()             # per-tenant series + ingest→queryable KLL
    gen = MultiTenantEventStream(2, 2048, universe=64, seed=0,
                                 rate_scales=[1.0, 4.0])

    with ServiceHTTPServer(svc) as srv:
        print(f"service up on {srv.url}\n")

        # tenant "polite" stays inside the burst; "noisy" blows through it
        outcomes = {"polite": [], "noisy": []}
        for tenant, idx, n_batches in (("polite", 0, 8), ("noisy", 1, 16)):
            for keys, ts, xs in list(gen.batches(idx, 128))[:n_batches]:
                code, body, hdrs = post(f"{srv.url}/ingest", {
                    "tenant": tenant, "keys": keys.tolist(),
                    "ts": ts.tolist(), "values": xs.tolist(),
                })
                outcomes[tenant].append(code)
                if code == 429:
                    print(f"  {tenant}: throttled (429), "
                          f"Retry-After={hdrs['Retry-After']}s")
        print(f"\npolite: {outcomes['polite'].count(200)}/8 accepted; "
              f"noisy: {outcomes['noisy'].count(200)}/16 accepted, "
              f"{outcomes['noisy'].count(429)} throttled\n")

        # demo determinism: everything queryable before reading (the first
        # chunk pays the engine jit compile, hence the patience)
        assert svc.flush(timeout=600)

        snap = json.loads(get(f"{srv.url}/query?tenant=polite&top=5"))
        print("polite snapshot:")
        print(f"  live keys        : {snap['live_keys']}")
        print(f"  value quantiles  : {snap['value_quantiles']}")
        print(f"  distinct keys est: {snap['distinct_keys_est']:.1f}")
        print(f"  hottest keys     : {snap['hot_keys']}")
        hot = snap["hot_keys"][0][0]
        print(f"  window fold of hottest key {hot}: "
              f"{snap['keys'][str(hot)]['fold']}")
        print(f"  counters         : {snap['counters']}\n")

        stats = json.loads(get(f"{srv.url}/stats"))
        lat = stats["ingest_to_queryable"]
        print(f"service: {stats['drained_rows']} rows in {stats['chunks']} "
              f"fused chunks; ingest→queryable "
              f"p50={lat.get('p50_ms', 0):.1f}ms "
              f"p99={lat.get('p99_ms', 0):.1f}ms\n")

        metrics = get(f"{srv.url}/metrics")
        shown = [l for l in metrics.splitlines()
                 if l.startswith("repro_service_") and "tenant=" in l][:8]
        print("per-tenant Prometheus series (excerpt):")
        for line in shown:
            print(f"  {line}")


if __name__ == "__main__":
    main()
