"""Live observability demo: every engine reporting into one registry.

Replays :class:`repro.data.stream.KeyedEventStream` traffic (Zipf keys,
bounded disorder) through a keyed window engine, an event-time telemetry
window, and a tiny decode engine, all attached to the unified obs layer:

  * ``/metrics``  — Prometheus text exposition (``repro.obs.exporter``),
    one batched host sync per scrape;
  * terminal dashboard — throughput, p50/p95/p99, watermark lag,
    admission-branch rates, refreshed at 1 Hz (``--no-tty`` prints plain
    frames instead of redrawing);
  * chrome trace — per-chunk/per-step spans with roofline-apportioned
    stage sub-spans (``--trace-out``, load at https://ui.perfetto.dev).

    PYTHONPATH=src python examples/observability.py --steps 200
    PYTHONPATH=src python examples/observability.py --steps 50 --no-tty \
        --trace-out trace.json --metrics-out metrics.txt   # CI smoke
"""

import argparse
import sys
import time
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import monoids
from repro.core.keyed import KeyedChunkedStream
from repro.core.telemetry import WindowedTelemetry
from repro.data.stream import KeyedEventStream
from repro.obs import MetricsExporter, ObsConfig, default_registry
from repro.obs.dashboard import Dashboard
from repro.obs.trace import TraceRecorder


def build_serve_engine(obs):
    """A tiny real decode engine so serve series show up in /metrics."""
    from repro.configs import ARCHS
    from repro.models.factory import reduced_config
    from repro.models.transformer import init_params
    from repro.serve.engine import DecodeEngine, Request

    cfg = reduced_config(ARCHS["llama3.2-1b"])
    params = init_params(cfg, jax.random.key(0))
    eng = DecodeEngine(cfg, params, batch_slots=2, cache_len=64, obs=obs)
    return eng, Request


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=200,
                    help="chunks of keyed traffic to replay")
    ap.add_argument("--chunk", type=int, default=1024)
    ap.add_argument("--keys", type=int, default=4096, help="key universe")
    ap.add_argument("--slots", type=int, default=1024)
    ap.add_argument("--window", type=int, default=128)
    ap.add_argument("--port", type=int, default=0,
                    help="exporter port (0 = ephemeral)")
    ap.add_argument("--no-tty", action="store_true",
                    help="plain periodic frames instead of ANSI redraw")
    ap.add_argument("--no-serve", action="store_true",
                    help="skip the decode-engine section (faster)")
    ap.add_argument("--trace-out", default=None,
                    help="write chrome-trace JSON here at exit")
    ap.add_argument("--metrics-out", default=None,
                    help="self-scrape /metrics into this file at exit")
    ap.add_argument("--hold", type=float, default=0.0,
                    help="keep serving /metrics this many seconds after "
                         "the replay (lets an external scraper curl it)")
    args = ap.parse_args(argv)

    registry = default_registry()  # admission/combine groups pre-adopted
    trace = TraceRecorder(process_name="repro-observability")
    obs = ObsConfig(registry=registry, trace=trace, instrument_admission=True)

    exporter = MetricsExporter(registry, port=args.port).start()
    print(f"metrics: {exporter.url}")

    # keyed engine over Zipf traffic (admission-branch counters live)
    keyed = KeyedChunkedStream(
        monoids.sum_monoid(jnp.int32), window=args.window, slots=args.slots,
        chunk=args.chunk, obs=obs,
    )
    keyed.attach_obs(registry)
    kstate = keyed.init_state()

    # event-time chunk latency window (watermark lag / reorder occupancy)
    etel = WindowedTelemetry(
        {"chunk_ms": monoids.mean_monoid(),
         "chunk_ms_max": monoids.max_monoid()},
        horizon=30.0, capacity=512,
    )
    etel.attach_obs(registry, prefix="repro_pipeline")

    serve = None
    if not args.no_serve:
        print("building decode engine (serve series)...")
        serve, Request = build_serve_engine(obs)
        rid = 0

    stream = KeyedEventStream(
        args.steps * args.chunk, args.keys, disorder=0.2, seed=7
    )
    keys, ts, xs = stream.arrival()
    keys, ts, xs = np.asarray(keys), np.asarray(ts), np.asarray(xs)

    dash = Dashboard(registry, color=not args.no_tty and sys.stdout.isatty())
    t0 = time.perf_counter()
    last_frame = t0
    for step in range(args.steps):
        lo, hi = step * args.chunk, (step + 1) * args.chunk
        ck = jnp.asarray(keys[lo:hi])
        cx = jnp.asarray(xs[lo:hi])
        s0 = time.perf_counter()
        kstate, _, _ = keyed.process_chunk(kstate, ck, cx)
        chunk_ms = (time.perf_counter() - s0) * 1e3
        etel.observe(
            {"chunk_ms": jnp.float32(chunk_ms),
             "chunk_ms_max": jnp.float32(chunk_ms)},
            ts=time.perf_counter() - t0,
        )
        if serve is not None and step % 10 == 0:
            rid += 1
            serve.submit(Request(rid=rid, max_new=3,
                                 prompt=np.arange(4, dtype=np.int32)))
            serve.step()
        now = time.perf_counter()
        if now - last_frame >= 1.0:  # 1 Hz — the acceptance configuration
            last_frame = now
            if args.no_tty:
                print(f"-- step {step + 1}/{args.steps} --")
                print(dash.render_once())
            else:
                dash.tick()
    if serve is not None:
        serve.run_until_drained(max_steps=200)

    # final frame + summary
    frame = dash.render_once()
    if args.no_tty:
        print(frame)
    else:
        dash.tick()
    dt = time.perf_counter() - t0
    print(f"\nreplayed {args.steps * args.chunk} events in {dt:.2f}s "
          f"({args.steps * args.chunk / dt:,.0f} events/s)")

    if args.metrics_out:
        body = urllib.request.urlopen(exporter.url, timeout=10).read()
        with open(args.metrics_out, "wb") as f:
            f.write(body)
        n_series = sum(
            1 for line in body.decode().splitlines()
            if line and not line.startswith("#")
        )
        print(f"wrote {args.metrics_out} ({n_series} series)")
    if args.trace_out:
        trace.save(args.trace_out)
        print(f"wrote {args.trace_out} ({len(trace)} events)")
    if args.hold > 0:
        print(f"holding /metrics open for {args.hold:.0f}s...")
        time.sleep(args.hold)
    exporter.stop()


if __name__ == "__main__":
    main()
