"""Worst-case and amortized ⊗-invocation counts — Theorems 3, 7, 10, 13.

Counts are measured with an instrumented monoid in eager mode, where
``lazy_cond`` executes exactly the branch the paper's pseudocode would.
"""

import numpy as np
import pytest

from repro.core import ALGORITHMS, counting, monoids


def run_counted(algo_name, n_ops=800, maxwin=48, seed=7):
    algo = ALGORITHMS[algo_name]
    m, ctr = counting(monoids.maxcount_monoid())
    st = algo.init(m, 64)
    r = np.random.default_rng(seed)
    worst = {"insert": 0, "evict": 0, "query": 0}
    total = {"insert": 0, "evict": 0, "query": 0}
    count = {"insert": 0, "evict": 0, "query": 0}
    sz = 0
    for _ in range(n_ops):
        c = r.random()
        if sz == 0 or (c < 0.55 and sz < maxwin):
            op, fn = "insert", lambda s: algo.insert(m, s, float(r.integers(0, 5)))
            sz += 1
        elif c < 0.85:
            op, fn = "evict", lambda s: algo.evict(m, s)
            sz -= 1
        else:
            op, fn = "query", lambda s: (algo.query(m, s), s)[1]
        ctr.reset()
        st = fn(st)
        worst[op] = max(worst[op], ctr.count)
        total[op] += ctr.count
        count[op] += 1
    avg = {k: total[k] / max(count[k], 1) for k in total}
    return worst, avg


def test_daba_theorem_10():
    """DABA: ≤4 ⊗/insert, ≤3 ⊗/evict, ≤1 ⊗/query; avg 2.5 / 1.5."""
    worst, avg = run_counted("daba")
    assert worst["insert"] <= 4
    assert worst["evict"] <= 3
    assert worst["query"] <= 1
    assert avg["insert"] <= 2.8  # 2.5 + identity-combine slack
    assert avg["evict"] <= 1.8


def test_daba_lite_theorem_13():
    """DABA Lite: ≤3 ⊗/insert, ≤2 ⊗/evict, ≤1 ⊗/query; avg 2 / 1."""
    worst, avg = run_counted("daba_lite")
    assert worst["insert"] <= 3
    assert worst["evict"] <= 2
    assert worst["query"] <= 1
    assert avg["insert"] <= 2.3
    assert avg["evict"] <= 1.3


@pytest.mark.parametrize("algo_name", ["two_stacks", "two_stacks_lite"])
def test_two_stacks_theorems_3_7(algo_name):
    """Two-Stacks(-Lite): exactly 1 ⊗/insert and /query; evict amortized O(1)
    but worst-case O(n) — the flip latency spike DABA removes."""
    worst, avg = run_counted(algo_name)
    assert worst["insert"] == 1
    assert worst["query"] == 1
    assert worst["evict"] >= 20  # the O(n) flip happened
    assert avg["evict"] <= 1.5  # amortized O(1)


def test_daba_worst_case_independent_of_window():
    """The defining property: DABA's worst case does NOT grow with n."""
    for maxwin in [8, 64]:
        worst_d, _ = run_counted("daba", maxwin=min(maxwin, 48))
        assert worst_d["insert"] <= 4 and worst_d["evict"] <= 3
    # while Two-Stacks' worst case DOES grow with n
    w8, _ = run_counted("two_stacks", maxwin=8)
    w48, _ = run_counted("two_stacks", maxwin=48)
    assert w48["evict"] > w8["evict"]


def test_space_bounds():
    """Theorem 10 vs 13: DABA stores 2 ring buffers (vals+aggs ⇒ 2n);
    DABA Lite stores 1 (n) + aggRA + aggB (n+2)."""
    import jax

    m = monoids.sum_monoid()
    cap = 32
    daba_state = ALGORITHMS["daba"].init(m, cap)
    lite_state = ALGORITHMS["daba_lite"].init(m, cap)

    def agg_slots(state, ring_names, scalar_names):
        slots = 0
        for name in ring_names:
            slots += getattr(state, name).shape[0]
        slots += len(scalar_names)
        return slots

    assert agg_slots(daba_state, ["vals", "aggs"], []) == 2 * cap
    assert agg_slots(lite_state, ["deque"], ["agg_ra", "agg_b"]) == cap + 2
    # two-stacks lite: n+1
    ts_lite = ALGORITHMS["two_stacks_lite"].init(m, cap)
    assert agg_slots(ts_lite, ["deque"], ["agg_b"]) == cap + 1
    # two-stacks: 2n vals + 2n aggs buffers (stack arrays)
    ts = ALGORITHMS["two_stacks"].init(m, cap)
    n_leaves = sum(x.shape[0] for x in jax.tree.leaves(
        (ts.f_vals, ts.f_aggs, ts.b_vals, ts.b_aggs)))
    assert n_leaves == 4 * cap  # two stacks × (val + agg) buffers
