"""Event-time windowing: engine ≡ in-order reference, watermark edge cases.

The load-bearing property (the PR-3 tentpole acceptance): for ANY stream
whose disorder is bounded by the engine's slack, the bulk out-of-order
engine's released outputs equal — bit-exactly for integer/selection monoids,
including NON-commutative ones — the per-element in-order scan of the
timestamp-sorted stream.  Plus: watermark-driven bulk evictions
(TimestampedWindow), late-data policies, capacity overflow detection, the
range-fold primitive, and the DisorderedEventStream generator's lateness
bound.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import daba_lite, monoids, swag_base
from repro.core.chunked import ChunkedStream
from repro.core.event_time import (
    EventTimeChunkedStream,
    TimestampedWindow,
    flip_range_fold,
    fold_axis0,
    in_order_reference,
    range_fold,
    range_fold_invertible,
)
from repro.data.stream import DisorderedEventStream
from repro.obs import counters as obs_counters

rng = np.random.default_rng(7)


def _scalar_vals(shape, dtype=jnp.float32):
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.asarray(rng.integers(-9, 9, shape), dtype)
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _int_float_vals(shape):  # integer-valued floats: m4/argmax stay bit-exact
    return jnp.asarray(rng.integers(-9, 9, shape).astype(np.float32))


def _affine_vals(shape):
    return (
        jnp.asarray(rng.integers(-5, 5, shape), jnp.int32),
        jnp.asarray(rng.integers(-5, 5, shape), jnp.int32),
    )


def _argmax_vals(shape):
    return (
        _int_float_vals(shape),
        jnp.asarray(rng.integers(0, 1000, shape), jnp.int32),
    )


# ≥ 2 NON-commutative monoids verified bit-exactly (affine_i32: exact
# modular arithmetic; m4 + argmax: pure selection on integer-valued floats),
# plus invertible-fast-path and float-allclose coverage.
MONOID_CASES = {
    "sum_i32": (monoids.sum_monoid(jnp.int32),
                lambda s: _scalar_vals(s, jnp.int32), True),
    "affine_i32": (monoids.affine_int_monoid(), _affine_vals, True),
    "m4_int": (monoids.m4_monoid(), _int_float_vals, True),
    "argmax": (monoids.argmax_monoid(), _argmax_vals, True),
    "mean": (monoids.mean_monoid(), _scalar_vals, False),
}


def _assert_tree_close(a, b, exact, ctx=""):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        x, y = np.asarray(x), np.asarray(y)
        if exact:
            assert np.array_equal(x, y), (ctx, x, y)
        else:
            assert np.allclose(x, y, rtol=1e-4, atol=1e-4), (ctx, x, y)


def _disordered(T, disorder, slack, *, seed, int_ts=False):
    """(arrival_ts, arrival_order): lateness bounded by ``slack``."""
    r = np.random.default_rng(seed)
    if int_ts:
        ts = np.sort(r.integers(0, 3 * T, T)).astype(np.int32)
        delay = (r.random(T) < disorder) * r.integers(0, max(int(slack), 1), T)
    else:
        ts = np.sort(r.uniform(0, 2.0 * T, T)).astype(np.float32)
        delay = (r.random(T) < disorder) * r.uniform(0, slack, T)
    order = np.argsort(ts + delay, kind="stable")
    return ts[order], order


# ---------------------------------------------------------------------------
# Engine ≡ in-order reference whenever disorder ≤ slack
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mname", sorted(MONOID_CASES))
@pytest.mark.parametrize("disorder,slack", [(0.0, 0.0), (0.3, 9.0), (0.8, 25.0)])
def test_engine_matches_in_order_reference(mname, disorder, slack):
    m, mk, exact = MONOID_CASES[mname]
    T, B, horizon = 70, 2, 17.0
    # deterministic per-case seed (str hash is randomized per process)
    seed = sum(map(ord, mname)) * 100 + int(disorder * 10)
    ats, order = _disordered(T, disorder, slack, seed=seed)
    xs = mk((T, B))
    axs = jax.tree.map(lambda a: a[order], xs)
    eng = EventTimeChunkedStream(
        m, horizon, slack=slack, chunk=16, capacity=64, buffer=32
    )
    res = eng.stream(jnp.asarray(ats), axs)
    assert res.n_late == 0 and res.n_dropped == 0
    ref_ts, ref_ys = in_order_reference(m, ats, axs, horizon)
    assert np.array_equal(res.ts, ref_ts)
    _assert_tree_close(res.ys, ref_ys, exact, (mname, disorder, slack))


@pytest.mark.parametrize("mname", ["sum_i32", "affine_i32"])
def test_engine_integer_timestamps_bit_exact(mname):
    """Integer event times through the int32 sentinel arithmetic."""
    m, mk, _ = MONOID_CASES[mname]
    T, B = 60, 2
    ats, order = _disordered(T, 0.4, 6, seed=11, int_ts=True)
    axs = jax.tree.map(lambda a: a[order], mk((T, B)))
    eng = EventTimeChunkedStream(
        m, 9, slack=6, chunk=13, capacity=64, buffer=16, ts_dtype=jnp.int32
    )
    res = eng.stream(jnp.asarray(ats), axs)
    ref_ts, ref_ys = in_order_reference(m, ats, axs, 9)
    assert np.array_equal(res.ts, ref_ts)
    _assert_tree_close(res.ys, ref_ys, exact=True, ctx=mname)


def test_engine_ragged_chunks_and_tiny_chunk():
    """Chunk sizes that straddle T unevenly (C ∤ T, C=1) stay exact."""
    m, mk, _ = MONOID_CASES["affine_i32"]
    T, B = 41, 1
    ats, order = _disordered(T, 0.5, 7.0, seed=3)
    axs = jax.tree.map(lambda a: a[order], mk((T, B)))
    ref_ts, ref_ys = in_order_reference(m, ats, axs, 11.0)
    for C in (1, 5, 64):
        eng = EventTimeChunkedStream(
            m, 11.0, slack=7.0, chunk=C, capacity=64, buffer=32
        )
        res = eng.stream(jnp.asarray(ats), axs)
        assert np.array_equal(res.ts, ref_ts), C
        _assert_tree_close(res.ys, ref_ys, exact=True, ctx=C)


def test_disordered_event_stream_generator_equivalence():
    """The data-layer generator's lateness bound feeds the engine exactly."""
    stream = DisorderedEventStream(
        120, batch=2, disorder=0.4, slack=6.0, integer_values=True, seed=5
    )
    ats, axs = stream.arrival()
    assert stream.max_lateness() <= 6.0
    m = monoids.sum_monoid(jnp.int32)
    eng = EventTimeChunkedStream(
        m, 20.0, slack=6.0, chunk=32, capacity=128, buffer=32
    )
    res = eng.stream(ats, axs)
    ref_ts, ref_ys = in_order_reference(m, ats, axs, 20.0)
    assert np.array_equal(res.ts, ref_ts)
    _assert_tree_close(res.ys, ref_ys, exact=True)
    assert res.n_late == 0


def test_property_disorder_equivalence_hypothesis():
    """Hypothesis: ANY ts/value sequence with disorder ≤ slack reproduces
    the sorted in-order reference bit-exactly (non-commutative affine_i32)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    m = monoids.affine_int_monoid()

    @settings(max_examples=25, deadline=None)
    @given(data=st.data())
    def inner(data):
        T = data.draw(st.integers(2, 28))
        gaps = data.draw(
            st.lists(st.integers(0, 7), min_size=T, max_size=T)
        )
        ts = np.cumsum(np.asarray(gaps, np.int64)).astype(np.int32)
        slack = data.draw(st.integers(0, 10))
        delays = data.draw(
            st.lists(st.integers(0, max(slack, 0)), min_size=T, max_size=T)
        )
        order = np.argsort(ts + np.asarray(delays, np.int32), kind="stable")
        horizon = data.draw(st.integers(1, 12))
        a = np.asarray(
            data.draw(st.lists(st.integers(-4, 4), min_size=T, max_size=T)),
            np.int32,
        )
        b = np.asarray(
            data.draw(st.lists(st.integers(-4, 4), min_size=T, max_size=T)),
            np.int32,
        )
        xs = (jnp.asarray(a[:, None]), jnp.asarray(b[:, None]))
        axs = jax.tree.map(lambda v: v[order], xs)
        ats = ts[order]
        eng = EventTimeChunkedStream(
            m, horizon, slack=slack, chunk=8, capacity=T + 2, buffer=T + 2,
            ts_dtype=jnp.int32,
        )
        res = eng.stream(jnp.asarray(ats), axs)
        assert res.n_late == 0
        ref_ts, ref_ys = in_order_reference(m, ats, axs, horizon)
        assert np.array_equal(res.ts, ref_ts)
        _assert_tree_close(res.ys, ref_ys, exact=True)

    inner()


# ---------------------------------------------------------------------------
# Watermark edge cases
# ---------------------------------------------------------------------------


def test_gap_empties_window_completely():
    """A silence longer than the horizon evicts everything (empty window)."""
    m = monoids.sum_monoid(jnp.int32)
    ts = np.asarray([0, 1, 2, 50, 51, 200], np.float32)
    xs = jnp.asarray(np.arange(6, dtype=np.int32).reshape(6, 1) + 1)
    eng = EventTimeChunkedStream(m, 5.0, slack=0.0, chunk=4, capacity=8, buffer=4)
    res = eng.stream(jnp.asarray(ts), xs)
    assert np.asarray(res.ys)[:, 0].tolist() == [1, 3, 6, 4, 9, 6]
    # the terminal flush watermark (+inf) evicts the whole window...
    assert int(eng.window_fold(res.state)[0]) == 0
    # ...while an unflushed stream keeps the live tail
    live = eng.stream(jnp.asarray(ts), xs, flush=False)
    assert int(eng.window_fold(live.state)[0]) == 6


def test_empty_and_single_element_streams():
    m = monoids.sum_monoid(jnp.int32)
    eng = EventTimeChunkedStream(m, 5.0, chunk=4, capacity=8, buffer=4)
    res = eng.stream(jnp.zeros((0,), jnp.float32), jnp.zeros((0, 1), jnp.int32))
    assert res.ts.shape == (0,) and res.ys is None
    res = eng.stream(jnp.asarray([3.0]), jnp.asarray([[7]], jnp.int32))
    assert np.asarray(res.ys).ravel().tolist() == [7]


def test_empty_chunk_with_pending_buffer_refuses_silent_skip():
    """flush=True on an empty chunk cannot drain a pending buffer — the
    engine must say so instead of quietly dropping the pending outputs."""
    m = monoids.sum_monoid(jnp.int32)
    eng = EventTimeChunkedStream(m, 5.0, slack=3.0, chunk=4, capacity=8, buffer=4)
    part = eng.stream(
        jnp.asarray([0.0, 1.0, 2.0]), jnp.ones((3, 1), jnp.int32), flush=False
    )
    with pytest.raises(ValueError, match="pending"):
        eng.stream(
            jnp.zeros((0,), jnp.float32), jnp.zeros((0, 1), jnp.int32),
            state=part.state,
        )
    # the documented path drains it
    st, out = eng.flush(part.state, jnp.zeros((1, 1), jnp.int32))
    assert int(out["mask"].sum()) > 0


def test_all_late_chunk_policies():
    """A chunk arriving entirely below the watermark: drop / side_output
    discard it (flagged), merge folds it into the live window."""
    m = monoids.sum_monoid(jnp.int32)
    ts = np.asarray([10, 11, 12, 13, 1, 2, 3, 4], np.float32)
    xs = jnp.ones((8, 1), jnp.int32)
    for policy in ("drop", "side_output"):
        eng = EventTimeChunkedStream(
            m, 100.0, slack=0.0, chunk=4, capacity=16, buffer=4,
            late_policy=policy,
        )
        res = eng.stream(jnp.asarray(ts), xs, flush=False)
        assert res.n_late == 4 and res.n_dropped == 4
        assert res.late_rows.tolist() == [4, 5, 6, 7]
        assert np.asarray(res.ys).ravel().tolist() == [1, 2, 3, 4]
        assert int(eng.window_fold(res.state)[0]) == 4
    eng = EventTimeChunkedStream(
        m, 100.0, slack=0.0, chunk=4, capacity=16, buffer=4, late_policy="merge"
    )
    res = eng.stream(jnp.asarray(ts), xs, flush=False)
    assert res.n_late == 4 and res.n_dropped == 0
    assert int(eng.window_fold(res.state)[0]) == 8  # merged into the window


def test_merge_policy_drops_past_horizon_late_data():
    """Merge policy still drops late data older than the live horizon."""
    m = monoids.sum_monoid(jnp.int32)
    ts = np.asarray([100, 101, 102, 103, 1, 99, 102.5, 60], np.float32)
    xs = jnp.ones((8, 1), jnp.int32)
    eng = EventTimeChunkedStream(
        m, 10.0, slack=0.0, chunk=4, capacity=16, buffer=4, late_policy="merge"
    )
    res = eng.stream(jnp.asarray(ts), xs, flush=False)
    # ts=1 and ts=60 are beyond horizon -> dropped; 99, 102.5 merge
    assert res.n_dropped == 2
    assert int(eng.window_fold(res.state)[0]) == 6


def test_buffer_overflow_raises():
    m = monoids.sum_monoid(jnp.int32)
    eng = EventTimeChunkedStream(m, 10.0, slack=1000.0, chunk=4, capacity=8, buffer=2)
    with pytest.raises(RuntimeError, match="overflow"):
        eng.stream(
            jnp.asarray(np.arange(12, dtype=np.float32)),
            jnp.ones((12, 1), jnp.int32),
        )


def test_window_capacity_overflow_raises():
    m = monoids.sum_monoid(jnp.int32)
    eng = EventTimeChunkedStream(m, 1000.0, slack=0.0, chunk=8, capacity=4, buffer=8)
    with pytest.raises(RuntimeError, match="overflow"):
        eng.stream(
            jnp.asarray(np.arange(16, dtype=np.float32)),
            jnp.ones((16, 1), jnp.int32),
        )


# ---------------------------------------------------------------------------
# Per-element protocol + primitives
# ---------------------------------------------------------------------------


def test_timestamped_window_matches_reference():
    m, mk, _ = MONOID_CASES["affine_i32"]
    T = 40
    ts = np.sort(rng.uniform(0, 80, T)).astype(np.float32)
    xs = mk((T, 1))
    ref_ts, ref_ys = in_order_reference(m, ts, xs, 13.0)
    win = TimestampedWindow(daba_lite, m, horizon=13.0, capacity=64)
    for i in range(T):
        win.insert(float(ts[i]), jax.tree.map(lambda a: a[i, 0], xs))
        _assert_tree_close(
            win.query(), jax.tree.map(lambda a: a[i, 0], ref_ys), exact=True, ctx=i
        )


def test_timestamped_window_watermark_bulk_evict_and_order_check():
    m = monoids.sum_monoid(jnp.int32)
    win = TimestampedWindow(daba_lite, m, horizon=5.0, capacity=32)
    for t in range(8):
        win.insert(float(t), 1)
    assert win.size() == 5  # (7-5, 7] keeps ts 3..7
    evicted = win.advance(100.0)  # watermark jump: ONE bulk evict of the rest
    assert evicted == 5 and win.size() == 0
    assert int(m.lower(win.query())) == 0
    with pytest.raises(ValueError, match="event-time order"):
        win.insert(50.0, 1)  # below the 100.0 watermark path max


def test_range_fold_matches_naive():
    m = monoids.affine_int_monoid()
    M, Q = 23, 17
    arr = jax.vmap(m.lift)(
        (jnp.asarray(rng.integers(-4, 4, M), jnp.int32),
         jnp.asarray(rng.integers(-4, 4, M), jnp.int32))
    )
    starts = jnp.asarray(rng.integers(0, M, Q), jnp.int32)
    ends = jnp.asarray(
        np.minimum(np.asarray(starts) + rng.integers(-1, 9, Q), M - 1), jnp.int32
    )
    got = range_fold(m, arr, starts, ends)
    for q in range(Q):
        acc = m.identity()
        for i in range(int(starts[q]), int(ends[q]) + 1):
            acc = m.combine(acc, swag_base.tree_index(arr, i))
        _assert_tree_close(swag_base.tree_index(got, q), acc, exact=True, ctx=q)


def _flip_queries(M, layout, r):
    """Monotone query sets satisfying the flip invariant: ``ends`` strictly
    increasing, ``starts`` non-decreasing (module docstring)."""
    if layout == "singleton":  # every element its own single-entry window
        ends = np.arange(M, dtype=np.int32)
        return ends.copy(), ends
    if layout == "giant":  # one giant segment: every query starts at 0
        ends = np.sort(r.choice(M, size=min(M, 13), replace=False))
        return np.zeros_like(ends, np.int32), ends.astype(np.int32)
    if layout == "empty":  # every span empty → identity rows
        ends = np.sort(r.choice(M, size=min(M, 11), replace=False))
        return (ends + 1).astype(np.int32), ends.astype(np.int32)
    # random widths; max-accumulate keeps starts monotone (and ≤ ends,
    # since each ends[q'] - w[q'] ≤ ends[q'] ≤ ends[q])
    ends = np.sort(r.choice(M, size=min(M, 17), replace=False))
    starts = np.maximum.accumulate(ends - r.integers(0, M, ends.shape[0]))
    return np.clip(starts, 0, None).astype(np.int32), ends.astype(np.int32)


@pytest.mark.parametrize("mname", ["affine_i32", "m4_int", "argmax"])
@pytest.mark.parametrize("layout", ["random", "giant", "singleton", "empty"])
def test_flip_range_fold_matches_retired_table_and_naive(mname, layout):
    """The constant-combine flip sweep ≡ the retired doubling table ≡ the
    per-element loop, bit-exactly, on flip-invariant query sets — including
    non-commutative monoids, a single giant segment, every-element-its-own-
    window, and empty spans."""
    m, mk, _ = MONOID_CASES[mname]
    r = np.random.default_rng(sum(map(ord, mname + layout)))
    M = 29
    arr = jax.vmap(m.lift)(mk((M,)))
    starts, ends = _flip_queries(M, layout, r)
    got = flip_range_fold(m, arr, starts, ends)
    table = range_fold(m, arr, starts, ends)
    _assert_tree_close(got, table, exact=True, ctx=(mname, layout))
    for q in range(len(ends)):
        acc = m.identity()
        for i in range(int(starts[q]), int(ends[q]) + 1):
            acc = m.combine(acc, swag_base.tree_index(arr, i))
        _assert_tree_close(
            swag_base.tree_index(got, q), acc, exact=True,
            ctx=(mname, layout, q),
        )


def test_engine_gap_restart_and_giant_window_bit_exact():
    """Flip-sweep edge cases at engine level, non-commutative monoid:
    a horizon covering the whole stream (single giant segment — every
    released window starts at merge position 0) and a mid-stream time gap
    far beyond the horizon (bulk-evicts the ENTIRE window, restarting from
    empty), both bit-exact vs the in-order reference."""
    m, mk, _ = MONOID_CASES["affine_i32"]
    T, B = 48, 2
    ts = np.sort(rng.uniform(0, 20.0, T)).astype(np.float32)
    ts[T // 2:] += 500.0  # gap ≫ any horizon below: empty-window restart
    xs = mk((T, B))
    for horizon in (1e6, 7.0):  # giant window; ordinary window across the gap
        eng = EventTimeChunkedStream(
            m, horizon, slack=0.0, chunk=16, capacity=128, buffer=16
        )
        res = eng.stream(jnp.asarray(ts), xs)
        ref_ts, ref_ys = in_order_reference(m, ts, xs, horizon)
        assert np.array_equal(res.ts, ref_ts)
        _assert_tree_close(res.ys, ref_ys, exact=True, ctx=horizon)


def test_engine_all_late_chunk_bit_exact():
    """A chunk arriving entirely below the watermark (all-late) is dropped
    and counted without disturbing on-time outputs."""
    m, mk, _ = MONOID_CASES["affine_i32"]
    T, B, C = 32, 1, 8
    ts = np.sort(rng.uniform(0, 60.0, T)).astype(np.float32)
    xs = mk((T, B))
    # splice one whole chunk of ancient events into the middle of the stream
    late_ts = np.full(C, -100.0, np.float32)
    ats = np.concatenate([ts[:16], late_ts, ts[16:]])
    axs = jax.tree.map(
        lambda a: jnp.concatenate([a[:16], jnp.zeros((C,) + a.shape[1:],
                                                     a.dtype), a[16:]]), xs
    )
    eng = EventTimeChunkedStream(m, 9.0, slack=0.0, chunk=C, capacity=64,
                                 buffer=16)
    res = eng.stream(jnp.asarray(ats), axs)
    assert int(res.n_late) == C and int(res.n_dropped) == C
    ref_ts, ref_ys = in_order_reference(m, ts, xs, 9.0)
    assert np.array_equal(res.ts, ref_ts)
    _assert_tree_close(res.ys, ref_ys, exact=True)


def test_eventtime_combines_per_position_flat_in_horizon():
    """The constant-combine claim, measured at runtime: ⊗-invocations per
    swept merge position stay flat as the horizon (and window capacity)
    grow — the retired doubling table grew as log2(W+C)."""
    T, B, chunk, buffer = 512, 1, 64, 32
    ts = np.sort(rng.uniform(0, float(T), T)).astype(np.float32)
    xs = jnp.asarray(rng.standard_normal((T, B)), jnp.float32)
    per_pos = {}
    for horizon in (8.0, 64.0, 512.0):
        cap = 2 * int(horizon) + 32
        eng = EventTimeChunkedStream(
            monoids.max_monoid(), horizon, slack=0.0, chunk=chunk,
            capacity=cap, buffer=buffer, instrument_combines=True,
        )
        obs_counters.combines.reset()
        eng.stream(jnp.asarray(ts), xs)
        # each chunk sweeps M = capacity + buffer + chunk merge positions;
        # the chunk count is identical across horizons, so it cancels
        # (read() runs effects_barrier before snapshotting)
        per_pos[horizon] = (
            obs_counters.combines.read()["eventtime"] / (cap + buffer + chunk)
        )
    lo, hi = min(per_pos.values()), max(per_pos.values())
    assert lo > 0, per_pos  # the instrumentation actually fired
    assert hi <= 1.5 * lo, per_pos
    # absolute guard: the flip sweep measures ~38 here (9 chunk sweeps of
    # ~4.3 ⊗/position); re-adding a doubling table would roughly triple it
    assert hi <= 60, per_pos


def test_range_fold_invertible_matches_generic():
    m = monoids.sum_monoid(jnp.int32)
    M, Q = 19, 11
    arr = jax.vmap(m.lift)(jnp.asarray(rng.integers(-9, 9, M), jnp.int32))
    starts = jnp.asarray(rng.integers(0, M, Q), jnp.int32)
    ends = jnp.asarray(
        np.minimum(np.asarray(starts) + rng.integers(-1, 7, Q), M - 1), jnp.int32
    )
    a = range_fold(m, arr, starts, ends)
    b = range_fold_invertible(m, arr, starts, ends)
    _assert_tree_close(a, b, exact=True)


def test_fold_axis0_ordered():
    m = monoids.affine_int_monoid()
    vals = (jnp.asarray(rng.integers(-4, 4, 9), jnp.int32),
            jnp.asarray(rng.integers(-4, 4, 9), jnp.int32))
    lifted = jax.vmap(m.lift)(vals)
    acc = m.identity()
    for i in range(9):
        acc = m.combine(acc, swag_base.tree_index(lifted, i))
    _assert_tree_close(fold_axis0(m, lifted), acc, exact=True)


def test_chunked_stream_timestamped_factory():
    eng = ChunkedStream.timestamped(monoids.sum_monoid(), 5.0, chunk=8)
    assert isinstance(eng, EventTimeChunkedStream)
    res = eng.stream(
        jnp.asarray([0.0, 1.0, 2.0]), jnp.ones((3, 1), jnp.float32)
    )
    assert np.asarray(res.ys).ravel().tolist() == [1.0, 2.0, 3.0]


def test_stream_continuation_across_calls():
    """stream(state=...) continues a live event-time window."""
    m = monoids.sum_monoid(jnp.int32)
    ts = np.sort(rng.uniform(0, 50, 40)).astype(np.float32)
    xs = _scalar_vals((40, 1), jnp.int32)
    eng = EventTimeChunkedStream(m, 9.0, slack=0.0, chunk=8, capacity=32, buffer=8)
    full = eng.stream(jnp.asarray(ts), xs)
    st = eng.init_state(1)
    first = eng.stream(jnp.asarray(ts[:25]), xs[:25], state=st, flush=False)
    second = eng.stream(jnp.asarray(ts[25:]), xs[25:], state=first.state)
    got = np.concatenate([np.asarray(first.ys), np.asarray(second.ys)])
    assert np.array_equal(got, np.asarray(full.ys))


# ---------------------------------------------------------------------------
# Disorder-adaptive release path
# ---------------------------------------------------------------------------


def _adversarial_max_late(T, slack, *, seed):
    """Every other row delayed by EXACTLY ``slack``: each chunk mixes
    frontier rows with maximally-late ones, so no chunk is in-order and the
    bounded merge runs at its admissible-distance ceiling.  (An all-equal
    delay would leave the stream in-order — the alternation is the point.)"""
    r = np.random.default_rng(seed)
    ts = np.sort(r.integers(0, 3 * T, T)).astype(np.float32)
    delay = np.float32(slack) * (np.arange(T) % 2).astype(np.float32)
    order = np.argsort(ts + delay, kind="stable")
    return ts[order], order


@pytest.mark.parametrize("mname", ["affine_i32", "m4_int", "argmax"])
@pytest.mark.parametrize("disorder", [0.0, 0.1, 0.5, "max_late"])
def test_adaptive_release_path_bit_exact(mname, disorder):
    """The disorder-adaptive release path (no-sort compact merge at d = 0,
    bounded merge above) is invisible in the outputs: bit-exact vs the
    in-order reference for NON-commutative monoids across disorder levels —
    including the adversarial alternating maximally-late stream — with a
    ragged final chunk (T % chunk != 0)."""
    m, mk, _ = MONOID_CASES[mname]
    T, B, slack = 75, 2, 9.0
    seed = sum(map(ord, mname)) + (97 if disorder == "max_late"
                                   else int(disorder * 10))
    if disorder == "max_late":
        ats, order = _adversarial_max_late(T, slack, seed=seed)
    else:
        ats, order = _disordered(T, disorder, slack, seed=seed)
    xs = mk((T, B))
    axs = jax.tree.map(lambda a: a[order], xs)
    horizon = 21.0
    eng = EventTimeChunkedStream(m, horizon, slack=slack, chunk=16,
                                 capacity=160, buffer=64)
    res = eng.stream(jnp.asarray(ats), axs)
    assert res.n_late == 0 and res.n_dropped == 0
    ref_ts, ref_ys = in_order_reference(m, ats, axs, horizon)
    assert np.array_equal(res.ts, ref_ts)
    _assert_tree_close(res.ys, ref_ys, exact=True, ctx=(mname, disorder))


def test_release_branch_counters_zero_sorts_in_order():
    """Fast-path regression guard: an in-order stream must dispatch ZERO
    sorting (slow) release branches — every chunk, including the flush
    drain, rides the no-sort compact merge — while a disordered stream must
    take the slow branch at least once.  Branch taken is counted per chunk
    in ``obs.counters.releases`` when ``instrument_release=True``."""
    m = monoids.sum_monoid(jnp.int32)
    T, B = 96, 1
    ts = np.sort(rng.uniform(0, 200.0, T)).astype(np.float32)
    xs = _scalar_vals((T, B), jnp.int32)
    eng = EventTimeChunkedStream(m, 20.0, slack=4.0, chunk=16, capacity=96,
                                 buffer=32, instrument_release=True)
    obs_counters.releases.reset()
    res = eng.stream(jnp.asarray(ts), xs)
    counts = obs_counters.releases.read()  # read() barriers the callbacks
    assert counts["slow"] == 0
    assert counts["fast"] >= T // 16  # every full chunk counted
    ref_ts, ref_ys = in_order_reference(m, ts, xs, 20.0)
    assert np.array_equal(res.ts, ref_ts)
    _assert_tree_close(res.ys, ref_ys, exact=True)

    ats, order = _disordered(T, 0.5, 4.0, seed=3)
    axs = jax.tree.map(lambda a: a[order], xs)
    obs_counters.releases.reset()
    eng.stream(jnp.asarray(ats), axs)
    counts = obs_counters.releases.read()
    assert counts["slow"] > 0


def test_ooo_distance_gauges_track_measured_disorder():
    """``obs_metrics`` exposes the measured out-of-order distance of recent
    chunks: zero across an in-order stream, positive once a disordered one
    has been processed (the slow branch records the exact displacement)."""
    m = monoids.sum_monoid(jnp.int32)
    T = 64
    ts = np.sort(rng.uniform(0, 100.0, T)).astype(np.float32)
    xs = _scalar_vals((T, 1), jnp.int32)
    eng = EventTimeChunkedStream(m, 20.0, slack=8.0, chunk=16, capacity=96,
                                 buffer=64)
    res = eng.stream(jnp.asarray(ts), xs)
    metrics = eng.obs_metrics(res.state)
    assert int(metrics["ooo_distance_max"]) == 0
    assert float(metrics["ooo_distance_p95"]) == 0.0

    ats, order = _disordered(T, 0.5, 8.0, seed=11)
    axs = jax.tree.map(lambda a: a[order], xs)
    res = eng.stream(jnp.asarray(ats), axs)
    metrics = eng.obs_metrics(res.state)
    assert int(metrics["ooo_distance_max"]) > 0
