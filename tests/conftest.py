"""Process-level hygiene for the tier-1 suite.

XLA:CPU JIT-compiles every executable into freshly mmap'd code pages, and
the full suite compiles thousands of programs in ONE pytest process.  Linux
caps a process at ``vm.max_map_count`` (65530 by default) memory mappings;
once the JIT's mmap fails, LLVM segfaults the interpreter mid-compile —
observed reproducibly near the END of the full suite (at ~65.5k maps) while
every module passes in isolation.  Dropping JAX's compilation caches
between modules unmaps retired executables and keeps the mapping count
bounded; the per-module recompiles cost a few seconds over the whole run.
"""

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    yield
    jax.clear_caches()
