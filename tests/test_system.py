"""End-to-end system tests: the full stack wired together.

1. Streaming analytics: SWAG windows over a live data stream (the paper's
   use case) with dedup + normalization stats.
2. Train → checkpoint → resume → serve: a tiny LM end to end, with windowed
   telemetry maintained by DABA Lite inside the jitted step.
3. Serving engine: continuous batching matches standalone greedy decode.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core import daba_lite, monoids
from repro.data.stream import SyntheticStream, WindowedStreamStats
from repro.models.factory import make_smoke_batch, reduced_config
from repro.models.transformer import DecodeSpec, build_model
from repro.optim.adamw import AdamW, warmup_cosine
from repro.serve.engine import DecodeEngine, Request
from repro.train.trainer import Trainer, TrainerConfig


def test_streaming_analytics_pipeline():
    cfg = reduced_config(ARCHS["llama3.2-1b"])
    stream = SyntheticStream(cfg, batch=2, seq=32, seed=0)
    stats = WindowedStreamStats(window=4)
    seen = []
    for step in range(8):
        batch = stream.batch_at(step)
        snap = stats.observe_batch(batch["tokens"], doc_id=step)
        seen.append(snap)
    # windowed min/max/mean are finite and ordered
    s = seen[-1]
    assert s["win_tok_min"] <= s["win_tok_mean"] <= s["win_tok_max"]
    # dedup: recent docs hit the windowed bloom
    assert stats.seen_recently(7) and stats.seen_recently(5)


def test_train_checkpoint_resume_serve(tmp_path):
    cfg = reduced_config(ARCHS["llama3.2-1b"])
    tcfg = TrainerConfig(
        total_steps=8, ckpt_every=4, ckpt_dir=str(tmp_path),
        metric_window=8, log_every=2,
    )
    stream = SyntheticStream(cfg, batch=2, seq=16, seed=1)
    opt = AdamW(learning_rate=warmup_cosine(1e-3, 2, 8))
    trainer = Trainer(cfg, tcfg, opt, stream)
    state = trainer.run(trainer.fresh_state(jax.random.key(0)))
    assert int(state.step) == 8

    # resume continues from the checkpoint
    trainer2 = Trainer(cfg, tcfg, opt, stream)
    state2 = trainer2.resume_or_init(jax.random.key(0))
    assert int(state2.step) == 8

    # serve with the trained params
    eng = DecodeEngine(cfg, state.params, batch_slots=2, cache_len=32)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                max_new=4)
        for i in range(3)
    ]
    for r in reqs:
        eng.submit(r)
    for _ in range(30):
        if eng.step() == 0 and not eng.queue:
            break
    assert all(r.done and len(r.out) == 4 for r in reqs)


def test_engine_matches_standalone_decode():
    cfg = reduced_config(ARCHS["llama3.2-1b"])
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    eng = DecodeEngine(cfg, params, batch_slots=2, cache_len=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                           int(rng.integers(4, 12))).astype(np.int32),
                max_new=6)
        for i in range(5)
    ]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained(max_steps=80)
    assert all(r.done for r in reqs)
    assert sorted(r.rid for r in done) == [r.rid for r in reqs]
    r0 = reqs[0]
    spec = DecodeSpec(cache_len=64, local_cache_len=cfg.local_window, batch=1)
    lg, st = model.prefill(params, {"tokens": jnp.asarray(r0.prompt[None])}, spec)
    toks = [int(jnp.argmax(lg[0]))]
    for _ in range(5):
        lg, st = model.decode_step(params, st, jnp.asarray([toks[-1]], jnp.int32))
        toks.append(int(jnp.argmax(lg[0])))
    assert toks == r0.out


def test_windowed_telemetry_is_exact():
    """The in-train-step DABA-Lite loss window ≡ numpy over the same values."""
    from repro.train.metrics import (
        init_metric_windows,
        read_metric_windows,
        update_metric_windows,
    )

    mw = init_metric_windows(window=4)
    losses = [3.0, 2.5, 2.8, 2.0, 1.5, 9.0, 1.0]
    gnorms = [1.0, 1.1, 0.9, 5.0, 0.8, 0.7, 5.0]
    for l, g in zip(losses, gnorms):
        mw = update_metric_windows(mw, jnp.float32(l), jnp.float32(g))
    out = read_metric_windows(mw)
    last4_l = np.array(losses[-4:])
    last4_g = np.array(gnorms[-4:])
    assert abs(float(out["win/loss_mean"]) - last4_l.mean()) < 1e-5
    assert abs(float(out["win/loss_std"]) - last4_l.std()) < 1e-4
    assert float(out["win/gnorm_max"]) == last4_g.max()
    # 5.0 occurs twice in the window — the maxcount monoid counts both
    assert int(out["win/gnorm_max_count"]) == int((last4_g == last4_g.max()).sum()) == 2
    assert int(out["win/steps"]) == 4


def test_event_time_window():
    """Variable-sized (event-time) windows: the SWAG ADT supports arbitrary
    insert/evict interleaving (paper §7.3) — here driven by timestamps."""
    m = monoids.variance_monoid()
    st = daba_lite.init(m, 64)
    rng = np.random.default_rng(0)
    times = np.cumsum(rng.exponential(1.0, 100))
    vals = rng.standard_normal(100)
    tau = 10.0
    buf = []
    for t, v in zip(times, vals):
        st = daba_lite.insert(m, st, float(v))
        buf.append((t, v))
        while buf and buf[0][0] < t - tau:
            st = daba_lite.evict(m, st)
            buf.pop(0)
        q = daba_lite.query(m, st)
        ref = np.array([b[1] for b in buf])
        assert abs(float(q["mu"]) - ref.mean()) < 1e-4
        assert int(q["n"]) == len(ref)


def test_grad_accumulation_matches_full_batch():
    """accum_steps=2 train step ≡ accum_steps=1 on the same global batch."""
    from repro.train.train_step import init_train_state, make_train_step

    cfg = reduced_config(ARCHS["llama3.2-1b"])
    opt = AdamW(learning_rate=1e-3)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    batch = make_smoke_batch(cfg, jax.random.key(1), B=4, S=16)
    s1 = init_train_state(cfg, params, opt, metric_window=8)
    s2 = init_train_state(cfg, params, opt, metric_window=8)
    st1, m1 = jax.jit(make_train_step(cfg, opt, accum_steps=1))(s1, batch)
    st2, m2 = jax.jit(make_train_step(cfg, opt, accum_steps=2))(s2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    err = max(
        float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st2.params))
    )
    assert err < 1e-4, err
