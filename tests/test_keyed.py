"""Keyed window-store subsystem: per-key windows ≡ per-key per-element scans.

Covers the keyed tentpole:
  * ``KeyedChunkedStream`` outputs bit-exact vs a dict-of-single-windows
    per-element reference, for integer AND non-commutative monoids
    (``affine_i32``, ``m4``), across chunk splits / ragged chunks / warm
    continuation — plus a hypothesis property sweep;
  * ``KeyDirectory`` collision, LRU-eviction, and TTL-expiry edge cases;
  * window-lane reset on slot reuse (no cross-tenant leakage);
  * SWAG interop: ``export_states`` / ``adopt_states`` through the warm
    carry protocol;
  * ``ShardedKeyedStore``: hash-sharded key space over a 4-device mesh
    reproduces the single-store outputs with zero steady-state collectives
    (subprocess, host platform device count);
  * ``KeyedTelemetry`` per-key metrics + state_dict round trip.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import daba_lite, monoids
from repro.core.keyed import (
    KeyDirectory,
    KeyedChunkedStream,
    KeyedWindowStore,
    seg_suffix_scan,
)
from repro.core.telemetry import KeyedTelemetry
from repro.obs import counters as obs_counters

rng = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# Oracle
# ---------------------------------------------------------------------------


def per_key_reference(monoid, keys, vals, window):
    """Dict of per-key element lists; output = left-to-right fold of each
    key's last min(window, seen) lifted elements."""
    hist: dict = {}
    outs = []
    for k, v in zip(keys, vals):
        h = hist.setdefault(int(k), [])
        h.append(monoid.lift(v))
        if len(h) > window:
            h.pop(0)
        acc = h[0]
        for e in h[1:]:
            acc = monoid.combine(acc, e)
        outs.append(acc)
    return jax.tree.map(lambda *rows: jnp.stack(rows), *outs)


def _tree_equal(a, b):
    return all(
        bool(jnp.array_equal(x, y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def _scalar_vals(n, dtype=jnp.int32):
    return jnp.asarray(rng.integers(-9, 9, n), dtype)


def _affine_vals(n):
    return (
        jnp.asarray(rng.integers(-4, 4, n), jnp.int32),
        jnp.asarray(rng.integers(-5, 5, n), jnp.int32),
    )


MONOID_CASES = {
    "sum_i32": (lambda: monoids.sum_monoid(jnp.int32), _scalar_vals),
    "max_i32": (lambda: monoids.max_monoid(jnp.int32), _scalar_vals),
    "affine_i32": (lambda: monoids.affine_int_monoid(), _affine_vals),
    "m4": (lambda: monoids.m4_monoid(), lambda n: _scalar_vals(n, jnp.float32)),
}


def _val_list(vals):
    leaves = [np.asarray(l) for l in jax.tree.leaves(vals)]
    if isinstance(vals, tuple):
        return [tuple(int(l[i]) for l in leaves) for i in range(len(leaves[0]))]
    return list(leaves[0])


# ---------------------------------------------------------------------------
# Equivalence vs the per-element reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(MONOID_CASES))
@pytest.mark.parametrize("window,chunk", [(1, 16), (5, 16), (8, 64), (16, 8)])
def test_keyed_stream_matches_reference(name, window, chunk):
    make, gen = MONOID_CASES[name]
    m = make()
    T, U = 200, 13
    keys = rng.integers(0, U, T).astype(np.int32)
    vals = gen(T)
    eng = KeyedChunkedStream(m, window, slots=U + 3, chunk=chunk)
    _, ys = eng.stream(keys, vals)
    ref = per_key_reference(m, keys, _val_list(vals), window)
    assert _tree_equal(ys, ref)


@pytest.mark.parametrize("name", ["affine_i32", "m4"])
@pytest.mark.parametrize("layout", ["giant", "fresh_keys"])
def test_keyed_flip_sweep_edge_layouts(name, layout):
    """Flip-sweep edge cases through the full bulk path, non-commutative
    monoids, ragged final chunk: a single giant segment (every row one key)
    and every-row-a-new-key (C singleton segments per chunk), for both
    the W ≤ C (suffix+prefix) and W > C (prefix-only) sweep regimes."""
    make, gen = MONOID_CASES[name]
    m = make()
    T = 90  # chunk=32 → ragged 26-row final chunk
    if layout == "giant":
        keys = np.zeros(T, dtype=np.int32)
        slots = 4
    else:
        keys = np.arange(T, dtype=np.int32)
        slots = T + 2
    vals = gen(T)
    for window in (4, 48):
        eng = KeyedChunkedStream(m, window, slots=slots, chunk=32)
        _, ys = eng.stream(keys, vals)
        ref = per_key_reference(m, keys, _val_list(vals), window)
        assert _tree_equal(ys, ref), (name, layout, window)


def test_keyed_combines_per_element_flat_in_window():
    """The constant-combine claim, measured at runtime: sweep ⊗-invocations
    per chunk row do not grow with the window (the retired range-fold table
    added a log2(W) doubling-table factor).  Counts may DROP once W > C
    (the suffix half of the flip sweep is statically elided)."""
    C, K, rounds = 64, 16, 3
    m = monoids.max_monoid(jnp.int32)  # non-invertible → flip-sweep path
    keys = jnp.asarray(rng.integers(0, K, C), jnp.int32)
    xs = _scalar_vals(C)
    per_row = {}
    for W in (8, 64, 512):
        store = KeyedWindowStore(m, W, slots=K, instrument_combines=True)
        state = store.init_state()
        state, _, _ = store.update_chunk(state, keys, xs)  # admit + warm
        obs_counters.combines.reset()
        for _ in range(rounds):
            state, _, _ = store.update_chunk(state, keys, xs)
        # read() runs jax.effects_barrier() before snapshotting
        per_row[W] = obs_counters.combines.read()["keyed"] / (rounds * C)
    assert per_row[8] > 0, per_row  # the instrumentation actually fired
    assert per_row[64] <= 1.25 * per_row[8], per_row
    assert per_row[512] <= 1.25 * per_row[8], per_row


@pytest.mark.parametrize("name", ["sum_i32", "affine_i32"])
def test_keyed_warm_continuation(name):
    """Carries persist across stream() calls: two halves ≡ one stream."""
    make, gen = MONOID_CASES[name]
    m = make()
    T, U, W = 160, 7, 6
    keys = rng.integers(0, U, T).astype(np.int32)
    vals = gen(T)
    eng = KeyedChunkedStream(m, W, slots=U, chunk=32)
    st, y1 = eng.stream(keys[:90], jax.tree.map(lambda a: a[:90], vals))
    st, y2 = eng.stream(
        keys[90:], jax.tree.map(lambda a: a[90:], vals), state=st
    )
    both = jax.tree.map(lambda a, b: jnp.concatenate([a, b]), y1, y2)
    ref = per_key_reference(m, keys, _val_list(vals), W)
    assert _tree_equal(both, ref)


def test_keyed_masked_rows_ignored():
    m = monoids.sum_monoid(jnp.int32)
    eng = KeyedChunkedStream(m, 4, slots=8, chunk=8)
    keys = jnp.asarray([1, 2, 1, 2, 1, 2, 1, 2], jnp.int32)
    xs = jnp.arange(8, dtype=jnp.int32)
    mask = jnp.asarray([True, True, True, True, False, False, False, False])
    st, ys, info = eng.process_chunk(eng.init_state(), keys, xs, None, mask)
    ref = per_key_reference(m, [1, 2, 1, 2], [0, 1, 2, 3], 4)
    assert jnp.array_equal(ys[:4], ref)
    agg, found = eng.query(st, jnp.asarray([1, 2], jnp.int32))
    assert int(agg[0]) == 2 and int(agg[1]) == 4  # masked rows never folded
    assert int(st["n_seen"].sum()) == 4


def test_keyed_query_unknown_key_identity():
    m = monoids.sum_monoid(jnp.int32)
    eng = KeyedChunkedStream(m, 4, slots=4, chunk=4)
    st, _ = eng.stream(np.asarray([5], np.int32), jnp.asarray([7], jnp.int32))
    agg, found = eng.query(st, jnp.asarray([5, 6], jnp.int32))
    assert bool(found[0]) and not bool(found[1])
    assert int(agg[0]) == 7 and int(agg[1]) == 0


# ---------------------------------------------------------------------------
# Hypothesis property sweep
# ---------------------------------------------------------------------------


def test_keyed_stream_property():
    hyp = pytest.importorskip("hypothesis")
    st_mod = pytest.importorskip("hypothesis.strategies")
    given, settings, st = hyp.given, hyp.settings, st_mod

    @given(
        data=st.data(),
        name=st.sampled_from(sorted(MONOID_CASES)),
        window=st.integers(1, 9),
        chunk=st.integers(2, 24),
        universe=st.integers(1, 12),
    )
    @settings(max_examples=30, deadline=None)
    def run(data, name, window, chunk, universe):
        make, gen = MONOID_CASES[name]
        m = make()
        T = data.draw(st.integers(1, 60))
        local = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
        keys = local.integers(0, universe, T).astype(np.int32)
        if name == "affine_i32":
            vals = (
                jnp.asarray(local.integers(-4, 4, T), jnp.int32),
                jnp.asarray(local.integers(-5, 5, T), jnp.int32),
            )
        elif name == "m4":
            vals = jnp.asarray(local.integers(-9, 9, T), jnp.float32)
        else:
            vals = jnp.asarray(local.integers(-9, 9, T), jnp.int32)
        eng = KeyedChunkedStream(m, window, slots=universe + 1, chunk=chunk)
        _, ys = eng.stream(keys, vals)
        ref = per_key_reference(m, keys, _val_list(vals), window)
        assert _tree_equal(ys, ref)

    run()


def test_keyed_new_key_mix_property():
    """Chunks mixing 0 / few / many genuinely-new keys: the admission fast
    path (no new keys), small batched admissions, and admission-heavy
    chunks all reproduce the per-element reference bit-exactly."""
    hyp = pytest.importorskip("hypothesis")
    st_mod = pytest.importorskip("hypothesis.strategies")
    given, settings, st = hyp.given, hyp.settings, st_mod

    @given(
        data=st.data(),
        name=st.sampled_from(["sum_i32", "affine_i32"]),
        window=st.integers(1, 8),
        chunk=st.integers(4, 16),
    )
    @settings(max_examples=25, deadline=None)
    def run(data, name, window, chunk):
        make, gen = MONOID_CASES[name]
        m = make()
        local = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
        n_chunks = data.draw(st.integers(2, 6))
        # per-chunk count of NEVER-seen keys: 0 → all-hit fast path,
        # small → a one-round batched admission, chunk-many → every row new
        mixes = [
            data.draw(st.sampled_from([0, 1, 2, chunk]))
            for _ in range(n_chunks)
        ]
        next_new = 0
        keys = []
        for n_new in mixes:
            fresh = list(range(next_new, next_new + n_new))
            next_new += n_new
            pool = max(next_new, 1)
            old = local.integers(0, pool, chunk - n_new)
            ck = np.concatenate([np.asarray(fresh, np.int64), old])
            local.shuffle(ck)
            keys.append(ck)
        keys = np.concatenate(keys).astype(np.int32)
        T = len(keys)
        vals = gen(T) if name != "affine_i32" else (
            jnp.asarray(local.integers(-4, 4, T), jnp.int32),
            jnp.asarray(local.integers(-5, 5, T), jnp.int32),
        )
        eng = KeyedChunkedStream(m, window, slots=next_new + chunk + 1,
                                 chunk=chunk)
        _, ys = eng.stream(keys, vals)
        ref = per_key_reference(m, keys, _val_list(vals), window)
        assert _tree_equal(ys, ref)

    run()


# ---------------------------------------------------------------------------
# Admission fast path + seg-scan kernel dispatch
# ---------------------------------------------------------------------------


def test_admission_fast_path_taken_and_bit_exact():
    """Steady-state chunks with NO new keys must take the all-hit fast
    branch (no sequential admission work), counted via the trace-side
    instrumentation callback — and stay bit-exact vs the reference."""
    m = monoids.sum_monoid(jnp.int32)
    W, chunk, U = 5, 16, 8
    # chunk 0 contains the whole key universe (admits everything in one
    # slow-path pass); the following 6 chunks reuse only known keys
    warm = np.concatenate([np.arange(U), rng.integers(0, U, chunk - U)])
    warm = warm.astype(np.int32)
    keys = rng.integers(0, U, 6 * chunk).astype(np.int32)
    wvals, vals = _scalar_vals(chunk), _scalar_vals(6 * chunk)
    eng = KeyedChunkedStream(m, W, slots=U + 2, chunk=chunk,
                             instrument_admission=True)
    obs_counters.admission.reset()
    st, y0 = eng.stream(warm, wvals)
    st, ys = eng.stream(keys, vals, state=st)
    # read() flushes the debug callbacks (effects_barrier) before snapshotting
    counts = obs_counters.admission.read()
    assert counts["slow"] == 1, counts  # admitting chunk
    assert counts["fast"] == 6, counts  # steady state
    # the legacy module-level alias must stay the same live group
    from repro.core.keyed import ADMISSION_COUNTS

    assert ADMISSION_COUNTS is obs_counters.admission
    assert ADMISSION_COUNTS["fast"] == 6  # dict-compat read on the alias
    # the fast path must not change results: bit-exact vs the reference
    ref = per_key_reference(
        m, np.concatenate([warm, keys]),
        _val_list(jnp.concatenate([wvals, vals])), W,
    )
    got = jnp.concatenate([y0, ys])
    assert _tree_equal(got, ref)


def test_store_seg_kernel_matches_lax_path():
    """use_seg_kernel=True (Pallas segmented suffix scan, interpret mode on
    CPU) reproduces the default lax path bit-exactly at the store level."""
    m = monoids.sum_monoid(jnp.int32)
    W, chunk, U, T = 6, 32, 11, 300
    keys = rng.integers(0, U, T).astype(np.int32)
    vals = _scalar_vals(T)
    base = KeyedChunkedStream(m, W, slots=U + 1, chunk=chunk)
    kern = KeyedChunkedStream(m, W, slots=U + 1, chunk=chunk,
                              use_seg_kernel=True)
    _, y0 = base.stream(keys, vals)
    _, y1 = kern.stream(keys, vals)
    assert jnp.array_equal(y0, y1)
    ref = per_key_reference(m, keys, _val_list(vals), W)
    assert _tree_equal(y1, ref)
    # a pytree monoid has no scalar op → explicit kernel request is an error
    with pytest.raises(ValueError):
        KeyedWindowStore(monoids.affine_int_monoid(), W, slots=4,
                         use_seg_kernel=True)._seg_scan(
            jnp.zeros(4, bool), (jnp.zeros(4, jnp.int32),) * 2)


# ---------------------------------------------------------------------------
# Directory edge cases
# ---------------------------------------------------------------------------


def test_directory_lookup_and_collisions():
    d = KeyDirectory(slots=8, probes=16)
    st = d.init()
    touched = jnp.zeros((8,), bool)
    keys = [3, 11, 19, 27]  # likely colliding probe chains mod small table
    slots = {}
    for k in keys:
        st, touched, slot, new = d.admit_row(st, touched, k, 1.0)
        assert int(slot) >= 0 and bool(new)
        slots[k] = int(slot)
    assert len(set(slots.values())) == len(keys)  # distinct slots
    got, found = d.lookup(st, jnp.asarray(keys, jnp.int32))
    assert bool(found.all())
    assert [int(s) for s in got] == [slots[k] for k in keys]
    # re-admit finds, does not reallocate
    st, touched, slot, new = d.admit_row(st, touched, 19, 2.0)
    assert int(slot) == slots[19] and not bool(new)
    assert int(st["n_live"]) == len(keys)


def test_directory_lru_eviction_and_tombstone_reuse():
    d = KeyDirectory(slots=2, probes=8)
    st = d.init()
    t = jnp.zeros((2,), bool)
    st, t, s0, _ = d.admit_row(st, t, 100, 1.0)
    st, t, s1, _ = d.admit_row(st, t, 200, 2.0)
    # full; fresh chunk (touched resets) -> key 300 evicts LRU (key 100)
    t = jnp.zeros((2,), bool)
    st, t, s2, new = d.admit_row(st, t, 300, 3.0)
    assert int(s2) == int(s0) and bool(new)
    _, found = d.lookup(st, jnp.asarray([100], jnp.int32))
    assert not bool(found[0])  # tombstoned
    assert int(st["n_evicted"]) == 1
    # the probe chain still reaches key 200 through any tombstone
    got, found = d.lookup(st, jnp.asarray([200, 300], jnp.int32))
    assert bool(found.all()) and int(got[0]) == int(s1)
    # a chunk with every slot touched cannot evict: admission fails safely
    t = jnp.ones((2,), bool)
    st, t, s3, new = d.admit_row(st, t, 400, 4.0)
    assert int(s3) == -1 and not bool(new)
    assert int(st["n_failed"]) == 1


def test_directory_ttl_expire():
    d = KeyDirectory(slots=4)
    st = d.init()
    t = jnp.zeros((4,), bool)
    st, t, _, _ = d.admit_row(st, t, 1, 1.0)
    st, t, _, _ = d.admit_row(st, t, 2, 9.0)
    st, expired = d.expire(st, now=10.0, ttl=5.0)
    assert int(expired.sum()) == 1
    _, found = d.lookup(st, jnp.asarray([1, 2], jnp.int32))
    assert not bool(found[0]) and bool(found[1])
    assert int(st["n_live"]) == 1


def test_store_slot_reuse_resets_window():
    """An evicted tenant's aggregates must never leak into the new tenant."""
    m = monoids.sum_monoid(jnp.int32)
    store = KeyedWindowStore(m, window=4, slots=1)
    st = store.init_state()
    st, ys, _ = store.update_chunk(
        st, jnp.asarray([7, 7], jnp.int32), jnp.asarray([10, 20], jnp.int32)
    )
    assert int(ys[1]) == 30
    # new key evicts key 7 (only slot) and starts from scratch
    st, ys, info = store.update_chunk(
        st, jnp.asarray([8], jnp.int32), jnp.asarray([1], jnp.int32)
    )
    assert int(ys[0]) == 1
    agg, found = store.query(st, jnp.asarray([8, 7], jnp.int32))
    assert int(agg[0]) == 1 and not bool(found[1])
    assert int(st["n_seen"].sum()) == 1  # reset on reuse


def test_store_overflowing_chunk_drops_excess_keys():
    m = monoids.sum_monoid(jnp.int32)
    store = KeyedWindowStore(m, window=4, slots=2)
    st = store.init_state()
    keys = jnp.arange(6, dtype=jnp.int32)  # 6 distinct keys, 2 slots
    st, ys, info = store.update_chunk(st, keys, jnp.ones(6, jnp.int32))
    assert int(info["n_live"]) == 2
    assert int(st["n_dropped"]) == 4
    assert int(info["dropped"].sum()) == 4
    # dropped rows emit identities
    assert int(jnp.where(info["dropped"], ys, 0).sum()) == 0


def test_store_ttl_sweep_inside_update():
    m = monoids.sum_monoid(jnp.int32)
    store = KeyedWindowStore(m, window=4, slots=4, ttl=5.0)
    st = store.init_state()
    st, _, _ = store.update_chunk(
        st, jnp.asarray([1], jnp.int32), jnp.ones(1, jnp.int32), ts=1.0
    )
    st, _, _ = store.update_chunk(
        st, jnp.asarray([2], jnp.int32), jnp.ones(1, jnp.int32), ts=10.0
    )
    _, found = store.query(st, jnp.asarray([1, 2], jnp.int32))
    assert not bool(found[0]) and bool(found[1])


# ---------------------------------------------------------------------------
# SWAG interop through the carry protocol
# ---------------------------------------------------------------------------


def test_export_states_continue_per_element():
    """A key's window exported to DABA-Lite continues element-for-element."""
    m = monoids.affine_int_monoid()
    W = 6
    T, U = 80, 5
    keys = rng.integers(0, U, T).astype(np.int32)
    vals = _affine_vals(T)
    store = KeyedWindowStore(m, W, slots=U)
    st = store.init_state()
    st, _, _ = store.update_chunk(st, keys, vals)
    states, found = store.export_states(st, jnp.arange(U, dtype=jnp.int32), daba_lite)
    assert bool(found.all())
    # the reconstructed window holds the key's last W-1 elements: its query
    # must equal the reference fold of those elements
    vlist = _val_list(vals)
    for k in range(U):
        mine = [vlist[i] for i in range(T) if int(keys[i]) == k][-(W - 1):]
        acc = m.identity()
        for v in mine:
            acc = m.combine(acc, m.lift(v))
        got = daba_lite.query(m, jax.tree.map(lambda a: a[k], states))
        assert _tree_equal(got, acc)


def test_adopt_states_roundtrip():
    m = monoids.sum_monoid(jnp.int32)
    W = 5
    # build live per-element windows for 3 keys
    sts = []
    expected = []
    for k in range(3):
        s = daba_lite.init(m, W + 2)
        vals = rng.integers(-9, 9, 4 + k)
        for v in vals:
            s = daba_lite.insert(m, s, int(v))
            if int(daba_lite.size(s)) > W - 1:
                s = daba_lite.evict(m, s)
        sts.append(s)
        expected.append(int(daba_lite.query(m, s)))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *sts)
    store = KeyedWindowStore(m, W, slots=4)
    st = store.adopt_states(store.init_state(), jnp.asarray([10, 11, 12]), stacked, daba_lite)
    agg, found = store.query(st, jnp.asarray([10, 11, 12], jnp.int32))
    assert bool(found.all())
    assert [int(a) for a in agg] == expected


# ---------------------------------------------------------------------------
# Segmented suffix scan (unit)
# ---------------------------------------------------------------------------


def test_seg_suffix_scan_non_commutative():
    m = monoids.affine_int_monoid()
    vals = _affine_vals(9)
    lifted = jax.vmap(m.lift)(vals)
    ends = jnp.asarray([False, False, True, False, True, False, False, False, True])
    out = seg_suffix_scan(m, ends, lifted)
    segs = [(0, 2), (3, 4), (5, 8)]
    for a, b in segs:
        for i in range(a, b + 1):
            acc = jax.tree.map(lambda l: l[i], lifted)
            for j in range(i + 1, b + 1):
                acc = m.combine(acc, jax.tree.map(lambda l: l[j], lifted))
            got = jax.tree.map(lambda l: l[i], out)
            assert _tree_equal(got, acc)


# ---------------------------------------------------------------------------
# Keyed telemetry
# ---------------------------------------------------------------------------


def test_keyed_telemetry_and_state_dict():
    metrics = {"lat": monoids.mean_monoid(), "mx": monoids.max_monoid()}
    kt = KeyedTelemetry(metrics, window=3, slots=8)
    kt.observe_bulk(
        jnp.asarray([1, 2, 1, 1, 1], jnp.int32),
        {
            "lat": jnp.asarray([1.0, 5.0, 2.0, 3.0, 4.0]),
            "mx": jnp.asarray([1.0, 5.0, 2.0, 3.0, 4.0]),
        },
    )
    s = kt.snapshot([1, 2, 9])
    assert bool(s["found"][0]) and bool(s["found"][1]) and not bool(s["found"][2])
    assert abs(float(s["lat"][0]) - 3.0) < 1e-6  # window=3: mean(2,3,4)
    assert float(s["mx"][0]) == 4.0 and float(s["mx"][1]) == 5.0
    assert set(kt.live_keys()) == {1, 2}
    # round trip through state_dict
    kt2 = KeyedTelemetry(metrics, window=3, slots=8)
    kt2.load_state_dict(kt.state_dict())
    s2 = kt2.snapshot([1, 2])
    assert float(s2["lat"][0]) == float(s["lat"][0])
    # mismatched configuration is rejected
    kt3 = KeyedTelemetry(metrics, window=3, slots=16)
    with pytest.raises(ValueError):
        kt3.load_state_dict(kt.state_dict())


# ---------------------------------------------------------------------------
# Sharded store (multi-device subprocess)
# ---------------------------------------------------------------------------

_SUBPROCESS_SHARDED = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import monoids
    from repro.core.keyed import KeyedChunkedStream, ShardedKeyedStore

    rng = np.random.default_rng(1)
    T, U, W = 256, 40, 8
    keys = rng.integers(0, U, T).astype(np.int32)
    xs = rng.integers(-9, 9, T).astype(np.int32)
    m = monoids.sum_monoid(jnp.int32)

    mesh = jax.make_mesh((4,), ("data",))
    sh = ShardedKeyedStore(m, W, slots_per_shard=32, mesh=mesh, axis="data")
    state = sh.init_state()
    state, ys, owner = sh.update_chunk(state, jnp.asarray(keys), jnp.asarray(xs))
    y = ShardedKeyedStore.collect(ys, owner)

    eng = KeyedChunkedStream(m, window=W, slots=128, chunk=T)
    _, ref = eng.stream(keys, jnp.asarray(xs))
    assert jnp.array_equal(y, ref)
    # per-shard states are genuinely sharded on the leading axis
    assert state["carry"].sharding.spec[0] == "data"
    print("OK")
    """
)


def test_sharded_default_ts_keeps_recency():
    """Default (no ts) sharded updates must advance last_used via the
    per-shard tick: a hot key observed every chunk is never TTL-expired,
    and the untouched key (not a hot one) is the LRU/TTL victim."""
    from repro.core.keyed import ShardedKeyedStore

    m = monoids.sum_monoid(jnp.int32)
    mesh = jax.make_mesh((1,), ("data",))
    sh = ShardedKeyedStore(m, 4, slots_per_shard=8, mesh=mesh, axis="data",
                           ttl=5.0)
    st = sh.init_state()
    st, _, _ = sh.update_chunk(st, jnp.asarray([1, 2], jnp.int32),
                               jnp.ones(2, jnp.int32))
    for _ in range(8):  # key 1 stays hot; key 2 goes idle past the ttl
        st, _, _ = sh.update_chunk(st, jnp.asarray([1], jnp.int32),
                                   jnp.ones(1, jnp.int32))
    st1 = jax.tree.map(lambda a: a[0], st)
    agg, found = sh.store.query(st1, jnp.asarray([1, 2], jnp.int32))
    assert bool(found[0]), "hot key must survive TTL sweeps"
    assert not bool(found[1]), "idle key should expire"
    assert int(agg[0]) == 4  # window of the hot key's last 4 ones


def test_directory_lookup_negative_keys_never_found():
    d = KeyDirectory(slots=4)
    st = d.init()
    t = jnp.zeros((4,), bool)
    st, t, _, _ = d.admit_row(st, t, 0, 1.0)
    _, found = d.lookup(st, jnp.asarray([-1, -2, 0], jnp.int32))
    assert not bool(found[0]) and not bool(found[1]) and bool(found[2])


def test_sharded_keyed_store_4dev():
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SHARDED],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# Event-time horizon mode
# ---------------------------------------------------------------------------


def per_key_horizon_reference(monoid, keys, vals, ts, window, horizon):
    """Timestamped dict oracle for ``horizon=`` mode: each key keeps its
    last min(window, seen) (ts, lifted) pairs; output at row j folds — older
    operand LEFT — only the retained pairs with ``ts' > ts[j] - horizon``."""
    hist: dict = {}
    outs = []
    for k, v, t in zip(keys, vals, np.asarray(ts, np.float32)):
        h = hist.setdefault(int(k), [])
        h.append((float(t), monoid.lift(v)))
        if len(h) > window:
            h.pop(0)
        acc = monoid.identity()
        for tt, e in h:
            if tt > float(t) - horizon:
                acc = monoid.combine(acc, e)
        outs.append(acc)
    return jax.tree.map(lambda *rows: jnp.stack(rows), *outs)


@pytest.mark.parametrize("name", ["sum_i32", "max_i32", "affine_i32", "m4"])
@pytest.mark.parametrize("window,chunk,horizon", [
    (5, 16, 7.0),    # expiry inside count-capped spans
    (16, 8, 3.0),    # window > chunk: carry lanes cross chunk boundaries
    (1, 16, 2.0),    # degenerate count window
    (9, 16, 1000.0), # horizon never binds → count semantics
])
def test_keyed_horizon_matches_timestamped_reference(name, window, chunk,
                                                     horizon):
    """Event-time ``horizon=`` windows ≡ the per-key timestamped dict
    oracle, bit-exactly, for integer AND non-commutative monoids — both
    when expiry bites mid-carry and when the horizon never binds."""
    make, gen = MONOID_CASES[name]
    m = make()
    T, U = 200, 13
    keys = rng.integers(0, U, T).astype(np.int32)
    ts = np.cumsum(rng.integers(0, 3, T)).astype(np.float32)  # ties allowed
    vals = gen(T)
    eng = KeyedChunkedStream(m, window, slots=U + 3, chunk=chunk,
                             horizon=horizon)
    _, ys = eng.stream(keys, vals, ts=jnp.asarray(ts))
    ref = per_key_horizon_reference(m, keys, _val_list(vals), ts, window,
                                    horizon)
    assert _tree_equal(ys, ref)


def test_keyed_horizon_warm_continuation_expires_carry():
    """Chunk-boundary expiry: history admitted in an earlier stream() call
    is dropped by a later call's watermark purely through the ``carry_ts``
    lanes (ONE extra gather/scatter — the donation rule holds)."""
    m = monoids.sum_monoid(jnp.int32)
    eng = KeyedChunkedStream(m, 8, slots=4, chunk=4, horizon=5.0)
    keys = np.zeros(4, np.int32)
    st, ys = eng.stream(keys, jnp.ones(4, jnp.int32),
                        ts=jnp.asarray([0.0, 1.0, 2.0, 3.0]))
    assert np.asarray(ys).tolist() == [1, 2, 3, 4]
    # second call: ts=6 retains {2, 3, 6} (> 6 - 5 = 1); ts=100 only itself
    st, ys = eng.stream(keys[:2], jnp.ones(2, jnp.int32),
                        ts=jnp.asarray([6.0, 100.0]), state=st)
    assert np.asarray(ys).tolist() == [3, 1]


def test_keyed_horizon_property():
    """Hypothesis sweep: horizon mode ≡ the timestamped per-key oracle for
    ANY globally non-decreasing integer timestamp stream (ties included),
    any key mix, window, chunk split, and horizon."""
    hyp = pytest.importorskip("hypothesis")
    st_mod = pytest.importorskip("hypothesis.strategies")
    given, settings, st = hyp.given, hyp.settings, st_mod

    @given(
        data=st.data(),
        name=st.sampled_from(sorted(MONOID_CASES)),
        window=st.integers(1, 9),
        chunk=st.integers(2, 24),
        universe=st.integers(1, 8),
        horizon=st.integers(1, 20),
    )
    @settings(max_examples=30, deadline=None)
    def run(data, name, window, chunk, universe, horizon):
        make, gen = MONOID_CASES[name]
        m = make()
        T = data.draw(st.integers(1, 60))
        local = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
        keys = local.integers(0, universe, T).astype(np.int32)
        ts = np.cumsum(local.integers(0, 4, T)).astype(np.float32)
        if name == "affine_i32":
            vals = (
                jnp.asarray(local.integers(-4, 4, T), jnp.int32),
                jnp.asarray(local.integers(-5, 5, T), jnp.int32),
            )
        elif name == "m4":
            vals = jnp.asarray(local.integers(-9, 9, T), jnp.float32)
        else:
            vals = jnp.asarray(local.integers(-9, 9, T), jnp.int32)
        eng = KeyedChunkedStream(m, window, slots=universe + 1, chunk=chunk,
                                 horizon=float(horizon))
        _, ys = eng.stream(keys, vals, ts=jnp.asarray(ts))
        ref = per_key_horizon_reference(m, keys, _val_list(vals), ts, window,
                                        float(horizon))
        assert _tree_equal(ys, ref)

    run()
