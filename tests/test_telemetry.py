"""Unified windowed-telemetry layer: one product-monoid state, one dispatch.

Covers WindowedTelemetry (observe / observe_bulk / snapshot / functional
core), product_monoid, the rewritten WindowedStreamStats fused dispatch, and
the serve engine's windowed telemetry surface.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import monoids
from repro.core.telemetry import WindowedTelemetry

rng = np.random.default_rng(3)


def _metrics():
    return {
        "mean": monoids.mean_monoid(),
        "mn": monoids.min_monoid(),
        "mx": monoids.max_monoid(),
        "var": monoids.variance_monoid(),
    }


def _np_window_ref(vals, t, window):
    w = np.asarray(vals[max(0, t - window + 1): t + 1])
    return {"mean": w.mean(), "mn": w.min(), "mx": w.max(), "var": w.var()}


def test_product_monoid_laws():
    m = monoids.product_monoid(_metrics())
    xs = rng.standard_normal(5)
    lifted = [m.lift({"mean": x, "mn": x, "mx": x, "var": x}) for x in map(float, xs)]
    # identity is a two-sided unit
    for v in lifted:
        for combined in (m.combine(m.identity(), v), m.combine(v, m.identity())):
            for a, b in zip(jax.tree.leaves(combined), jax.tree.leaves(v)):
                assert np.allclose(np.asarray(a), np.asarray(b))
    # associativity (up to float reassociation)
    a, b, c = lifted[:3]
    left = m.combine(m.combine(a, b), c)
    right = m.combine(a, m.combine(b, c))
    for x, y in zip(jax.tree.leaves(left), jax.tree.leaves(right)):
        assert np.allclose(np.asarray(x), np.asarray(y), atol=1e-5)


def test_observe_matches_numpy_window():
    window = 6
    telem = WindowedTelemetry(_metrics(), window)
    vals = rng.standard_normal(20).astype(np.float32)
    for t, v in enumerate(vals):
        telem.observe({k: jnp.float32(v) for k in _metrics()})
        s = telem.snapshot()
        ref = _np_window_ref(vals, t, window)
        for k in ("mean", "mn", "mx", "var"):
            assert abs(float(s[k]) - ref[k]) < 1e-4, (t, k, s[k], ref[k])


def test_observe_bulk_matches_sequential_observe():
    window = 7
    t1 = WindowedTelemetry(_metrics(), window)
    t2 = WindowedTelemetry(_metrics(), window)
    vals = rng.standard_normal(23).astype(np.float32)
    for v in vals:
        t1.observe({k: jnp.float32(v) for k in _metrics()})
    outs = {}
    for lo in (0, 10):  # two ragged bulk chunks (10 then 13)
        chunk = vals[lo: lo + 10] if lo == 0 else vals[10:]
        outs = t2.observe_bulk({k: jnp.asarray(chunk) for k in _metrics()})
    s1, s2 = t1.snapshot(), t2.snapshot()
    for k in _metrics():
        assert abs(float(s1[k]) - float(s2[k])) < 1e-4, k
    # bulk also returns the per-step windowed outputs
    ref = _np_window_ref(vals, len(vals) - 2, window)
    assert abs(float(np.asarray(outs["mean"])[-2, 0]) - ref["mean"]) < 1e-4


def test_batched_lanes_are_independent():
    telem = WindowedTelemetry({"mx": monoids.max_monoid()}, window=4, batch=3)
    data = rng.standard_normal((10, 3)).astype(np.float32)
    for row in data:
        telem.observe({"mx": jnp.asarray(row)})
    s = telem.snapshot()
    assert np.allclose(np.asarray(s["mx"]), data[-4:].max(axis=0), atol=1e-6)


def test_functional_core_composes_into_jit():
    telem = WindowedTelemetry({"mx": monoids.max_monoid()}, window=4)
    state = telem.init_state()

    @jax.jit
    def roll(state, xs):
        def step(st, x):
            st = telem.update(st, {"mx": x})
            return st, telem.read(st)["mx"]

        return jax.lax.scan(step, state, xs)

    xs = jnp.asarray(rng.standard_normal(12), jnp.float32)
    _, out = roll(state, xs)
    ref = np.array([np.asarray(xs)[max(0, t - 3): t + 1].max() for t in range(12)])
    assert np.allclose(np.asarray(out)[:, 0], ref, atol=1e-6)


def test_observe_is_single_dispatch():
    telem = WindowedTelemetry(_metrics(), window=8)
    calls = []
    orig = telem._observe_jit
    telem._observe_jit = lambda *a: (calls.append(1), orig(*a))[1]
    telem.observe({k: 1.0 for k in _metrics()})
    telem.observe({k: 2.0 for k in _metrics()})
    assert calls == [1, 1]  # one jitted call per observation, nothing else


def test_kll_quantiles_through_telemetry_window():
    """The KLL sketch as a telemetry metric: windowed p50/p95 rank accuracy
    despite the chunked engine's combine reassociation."""
    window = 256
    telem = WindowedTelemetry(
        {"q": monoids.kll_monoid(k=128, levels=6)}, window
    )
    vals = rng.standard_normal(600).astype(np.float32)
    telem.observe_bulk({"q": jnp.asarray(vals)})
    est = np.asarray(telem.snapshot()["q"])  # (3,): p50/p95/p99
    win = vals[-window:]
    for e, q in zip(est, (0.5, 0.95, 0.99)):
        rank = (win <= e).mean()
        assert abs(rank - q) < 0.06, (q, e, rank)


def test_horizon_mode_matches_manual_event_window():
    """horizon= telemetry folds exactly the observations inside
    (now - horizon, now], independent of arrival cadence."""
    telem = WindowedTelemetry(
        {"mx": monoids.max_monoid(), "mean": monoids.mean_monoid()},
        horizon=5.0, capacity=32,
    )
    ts = np.cumsum(rng.uniform(0.5, 1.5, 20)).astype(np.float32)
    vals = rng.standard_normal(20).astype(np.float32)
    for t, v in zip(ts, vals):
        telem.observe({"mx": jnp.float32(v), "mean": jnp.float32(v)}, ts=float(t))
        s = telem.snapshot()
        in_win = vals[(ts > t - 5.0) & (ts <= t)]
        assert abs(float(s["mx"]) - in_win.max()) < 1e-6
        assert abs(float(s["mean"]) - in_win.mean()) < 1e-5
    # bulk ingest of the same in-order stream lands on the same state
    t2 = WindowedTelemetry(
        {"mx": monoids.max_monoid(), "mean": monoids.mean_monoid()},
        horizon=5.0, capacity=32,
    )
    outs = t2.observe_bulk(
        {"mx": jnp.asarray(vals), "mean": jnp.asarray(vals)}, ts=jnp.asarray(ts)
    )
    assert abs(float(t2.snapshot()["mx"]) - float(telem.snapshot()["mx"])) < 1e-6
    t_last = ts[-1]
    in_win = vals[(ts > t_last - 5.0) & (ts <= t_last)]
    # in-order + slack=0: released row i aligns with input row i
    assert abs(float(np.asarray(outs["mx"])[len(vals) - 1, 0]) - in_win.max()) < 1e-6


def test_window_and_horizon_are_exclusive():
    with pytest.raises(ValueError, match="exactly one"):
        WindowedTelemetry({"mx": monoids.max_monoid()})
    with pytest.raises(ValueError, match="exactly one"):
        WindowedTelemetry({"mx": monoids.max_monoid()}, 8, horizon=1.0)


@pytest.mark.parametrize("mode", ["count", "horizon"])
def test_state_dict_checkpoint_round_trip(mode, tmp_path):
    """Telemetry carries survive a save/restore through the checkpoint
    layer; a freshly-configured instance adopts them exactly."""
    from repro.train import checkpoint

    def make():
        if mode == "count":
            return WindowedTelemetry({"mx": monoids.max_monoid()}, 6)
        return WindowedTelemetry(
            {"mx": monoids.max_monoid()}, horizon=50.0, capacity=16
        )

    t1 = make()
    vals = rng.standard_normal(9).astype(np.float32)
    for i, v in enumerate(vals):
        t1.observe({"mx": jnp.float32(v)}, ts=float(i))
    checkpoint.save(t1.state_dict(), str(tmp_path), 3)
    t2 = make()
    t2.load_state_dict(checkpoint.restore(str(tmp_path), 3, like=t2.state_dict()))
    assert float(t2.snapshot()["mx"]) == float(t1.snapshot()["mx"])
    # the restored window keeps evolving identically
    t1.observe({"mx": jnp.float32(-9.0)}, ts=9.0)
    t2.observe({"mx": jnp.float32(-9.0)}, ts=9.0)
    assert float(t2.snapshot()["mx"]) == float(t1.snapshot()["mx"])
    if mode == "horizon":
        # the restored clock continues from the saved watermark, so a
        # default-ts observation is NOT dropped as late
        assert t2.last_timestamp() == 9.0
        t2.observe({"mx": jnp.float32(77.0)})
        assert float(t2.snapshot()["mx"]) == 77.0
    # structure mismatch is rejected
    t3 = WindowedTelemetry({"other": monoids.max_monoid()}, 6)
    with pytest.raises(ValueError, match="mismatch"):
        t3.load_state_dict(t2.state_dict())
    # same tree structure but different capacities/window is also rejected
    # (a silent load would run the engine with mismatched static shapes)
    if mode == "count":
        t4 = WindowedTelemetry({"mx": monoids.max_monoid()}, 12)
    else:
        t4 = WindowedTelemetry(
            {"mx": monoids.max_monoid()}, horizon=50.0, capacity=64
        )
    with pytest.raises(ValueError, match="shape mismatch"):
        t4.load_state_dict(t1.state_dict())


def test_horizon_bulk_with_slack_masks_unreleased_rows():
    """slack > 0 holds recent rows in the reorder buffer: their bulk-output
    rows must be identities (lowered to the monoid's empty value), never
    garbage pad folds — and outputs released by a LATER chunk's watermark
    advance (possibly more than that chunk's length) are all returned."""
    telem = WindowedTelemetry(
        {"s": monoids.sum_monoid(jnp.int32)}, horizon=100.0, slack=5.0,
        capacity=32, buffer=8,
    )
    # watermark = 10 - 5 = 5: rows at ts 9 and 10 wait in the buffer
    ts = jnp.asarray([0.0, 1.0, 2.0, 9.0, 10.0])
    outs = telem.observe_bulk(
        {"s": jnp.asarray([1, 1, 1, 1, 1], jnp.int32)}, ts=ts
    )
    got = np.asarray(outs["s"])[:, 0]
    assert got[:3].tolist() == [1, 2, 3]  # released, cumulative in-horizon
    assert (got[3:] == 0).all()  # held back by slack -> identity, not garbage
    # a 1-row follow-up chunk advances the watermark to 20, draining BOTH
    # pending rows: 2 released outputs from a 1-row chunk, none lost
    outs = telem.observe_bulk({"s": jnp.asarray([1], jnp.int32)},
                              ts=jnp.asarray([25.0]))
    got = np.asarray(outs["s"])[:, 0]
    assert got[:2].tolist() == [4, 5] and (got[2:] == 0).all()


def test_windowed_stream_stats_reference():
    from repro.data.stream import WindowedStreamStats

    stats = WindowedStreamStats(window=3)
    toks = rng.integers(0, 50, (5, 2, 8)).astype(np.int32)
    for step in range(5):
        snap = stats.observe_batch(jnp.asarray(toks[step]), doc_id=step)
    tf = toks.astype(np.float32)
    means = tf.reshape(5, -1).mean(axis=1)
    assert abs(snap["win_tok_mean"] - means[-3:].mean()) < 1e-4
    assert snap["win_tok_min"] == tf[-3:].min()
    assert snap["win_tok_max"] == tf[-3:].max()
    assert stats.seen_recently(4) and stats.seen_recently(2)


def test_serve_engine_telemetry_surface(tmp_path):
    from repro.configs import ARCHS
    from repro.models.factory import reduced_config
    from repro.serve.engine import DecodeEngine, Request

    cfg = reduced_config(ARCHS["llama3.2-1b"])
    model_rng = np.random.default_rng(0)
    from repro.models.transformer import build_model

    params = build_model(cfg).init_params(jax.random.key(0))
    eng = DecodeEngine(cfg, params, batch_slots=2, cache_len=32,
                       telemetry_window=16)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=model_rng.integers(
            0, cfg.vocab_size, 5).astype(np.int32), max_new=3))
    eng.run_until_drained(max_steps=40)
    t = eng.telemetry()
    assert t["slot_occupancy"].shape == (2,)
    assert np.all((t["slot_occupancy"] >= 0) & (t["slot_occupancy"] <= 1))
    assert t["slot_retire_rate"].shape == (2,)
    assert float(t["slot_retire_rate"].sum()) > 0  # requests retired
    assert t["decode_ms_max"] >= t["decode_ms_mean"] > 0
    # KLL tail-latency quantiles: ordered and inside the observed range
    assert 0 < t["decode_ms_p50"] <= t["decode_ms_p95"] <= t["decode_ms_p99"]
    assert t["decode_ms_p99"] <= t["decode_ms_max"] + 1e-6
    # telemetry survives a restart: save, restore into a fresh engine
    eng.save_telemetry(str(tmp_path), step=1)
    eng2 = DecodeEngine(cfg, params, batch_slots=2, cache_len=32,
                        telemetry_window=16)
    assert eng2.restore_telemetry(str(tmp_path)) == 1
    t2 = eng2.telemetry()
    assert np.allclose(t2["slot_occupancy"], t["slot_occupancy"])
    assert t2["decode_ms_p99"] == t["decode_ms_p99"]
    # single-slot engines must keep a working telemetry surface (the lane
    # axis is squeezed away at batch == 1)
    eng1 = DecodeEngine(cfg, params, batch_slots=1, cache_len=32,
                        telemetry_window=16)
    eng1.submit(Request(rid=9, prompt=model_rng.integers(
        0, cfg.vocab_size, 5).astype(np.int32), max_new=2))
    eng1.run_until_drained(max_steps=10)
    t1 = eng1.telemetry()
    assert t1["slot_occupancy"].shape == (1,)
    assert 0 < t1["decode_ms_p50"] <= t1["decode_ms_p99"]


def test_metric_windows_horizon_mode():
    """Event-time (`horizon=`) step metrics: only steps inside the last H
    seconds survive, unlike the count window."""
    from repro.train.metrics import (
        init_metric_windows,
        read_metric_windows,
        update_metric_windows,
    )

    mw = init_metric_windows(horizon=10.0)
    # 3 old steps at t=0..2, then 2 recent ones at t=20, 21
    data = [(0.0, 5.0, 1.0), (1.0, 6.0, 1.0), (2.0, 7.0, 1.0),
            (20.0, 2.0, 3.0), (21.0, 4.0, 3.0)]
    for ts, loss, g in data:
        mw = update_metric_windows(
            mw, jnp.float32(loss), jnp.float32(g), ts=ts, horizon=10.0
        )
    out = read_metric_windows(mw)
    # watermark 21 -> window (11, 21]: only the last two steps
    assert int(out["win/steps"]) == 2
    assert abs(float(out["win/loss_mean"]) - 3.0) < 1e-5
    assert float(out["win/gnorm_max"]) == 3.0
    assert int(out["win/gnorm_max_count"]) == 2
    # ts is mandatory in horizon mode
    with pytest.raises(ValueError):
        update_metric_windows(mw, jnp.float32(0), jnp.float32(0), horizon=10.0)


def test_time_window_horizon_straggler_baseline():
    from repro.train.metrics import TimeWindow

    tw = TimeWindow(horizon=60.0)
    for _ in range(10):
        stats = tw.observe(0.1)
    assert stats["n"] == 10 and abs(stats["mean"] - 0.1) < 1e-6
    assert not tw.is_straggler(0.1)


def test_train_step_metric_horizon():
    """metric_horizon= train step: ts threads through jit as a traced f32
    (ONE compile across calls) and the windowed stats cover the last H
    seconds of steps rather than the last N steps."""
    from repro.configs import ARCHS
    from repro.models.factory import make_smoke_batch, reduced_config
    from repro.models.transformer import build_model
    from repro.optim.adamw import AdamW
    from repro.train.train_step import init_train_state, make_train_step

    cfg = reduced_config(ARCHS["llama3.2-1b"])
    opt = AdamW(learning_rate=1e-3)
    params = build_model(cfg).init_params(jax.random.key(0))
    batch = make_smoke_batch(cfg, jax.random.key(1), B=2, S=16)
    st = init_train_state(cfg, params, opt, metric_horizon=30.0)
    step = jax.jit(make_train_step(cfg, opt, metric_horizon=30.0))
    # three steps in the first seconds, a long stall, then two more
    for ts in [0.0, 1.0, 2.0]:
        st, m = step(st, batch, jnp.float32(ts))
    assert int(m["win/steps"]) == 3
    for ts in [100.0, 101.0]:
        st, m = step(st, batch, jnp.float32(ts))
    # watermark 101, horizon 30 → window (71, 101]: only the last two
    assert int(m["win/steps"]) == 2
    assert step._cache_size() == 1  # ts is traced, not baked in
    # horizon mode refuses a ts-less call rather than silently degrading
    with pytest.raises(ValueError):
        make_train_step(cfg, opt, metric_horizon=30.0)(st, batch)


def test_trainer_metric_horizon_wiring(tmp_path):
    """TrainerConfig.metric_horizon reaches both the jitted step metrics
    and the straggler TimeWindow, and the loop stamps real timestamps."""
    from repro.configs import ARCHS
    from repro.data.stream import SyntheticStream
    from repro.models.factory import reduced_config
    from repro.optim.adamw import AdamW
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = reduced_config(ARCHS["llama3.2-1b"])
    tcfg = TrainerConfig(
        total_steps=4, ckpt_every=100, ckpt_dir=str(tmp_path),
        metric_window=8, metric_horizon=120.0, log_every=1,
    )
    stream = SyntheticStream(cfg, batch=2, seq=16, seed=1)
    trainer = Trainer(cfg, tcfg, AdamW(learning_rate=1e-3), stream)
    assert trainer.time_window.horizon == 120.0  # straggler side too
    state = trainer.run(trainer.fresh_state(jax.random.key(0)))
    assert int(state.step) == 4
    rec = trainer.history[-1]
    # all four steps fall inside the 120 s horizon
    assert rec["win/steps"] == 4
    assert np.isfinite(rec["win/loss_mean"])


def test_serve_engine_request_telemetry():
    from repro.configs import ARCHS
    from repro.models.factory import reduced_config
    from repro.models.transformer import build_model
    from repro.serve.engine import DecodeEngine, Request

    cfg = reduced_config(ARCHS["llama3.2-1b"])
    params = build_model(cfg).init_params(jax.random.key(0))
    eng = DecodeEngine(cfg, params, batch_slots=2, cache_len=32,
                       telemetry_window=16)
    prng = np.random.default_rng(0)
    max_new = {7: 3, 8: 5, 9: 2}
    for rid, n in max_new.items():
        eng.submit(Request(rid=rid, prompt=prng.integers(
            0, cfg.vocab_size, 5).astype(np.int32), max_new=n))
    eng.run_until_drained(max_steps=40)
    rt = eng.request_telemetry()
    # every request decoded max_new - 1 steps (prefill emits the first token)
    for rid, n in max_new.items():
        assert rid in rt, rt
        assert rt[rid]["tokens"] == n - 1
        assert rt[rid]["decode_ms_max"] >= rt[rid]["decode_ms_mean"] > 0
    assert rt["_counters"]["n_dropped"] == 0
    # the per-request keyed windows survive a save/restore round trip
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        eng.save_telemetry(d, step=3)
        eng2 = DecodeEngine(cfg, params, batch_slots=2, cache_len=32,
                            telemetry_window=16)
        assert eng2.restore_telemetry(d) == 3
    rt2 = eng2.request_telemetry()
    for rid in max_new:
        assert rt2[rid] == rt[rid]
