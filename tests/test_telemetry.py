"""Unified windowed-telemetry layer: one product-monoid state, one dispatch.

Covers WindowedTelemetry (observe / observe_bulk / snapshot / functional
core), product_monoid, the rewritten WindowedStreamStats fused dispatch, and
the serve engine's windowed telemetry surface.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import monoids
from repro.core.telemetry import WindowedTelemetry

rng = np.random.default_rng(3)


def _metrics():
    return {
        "mean": monoids.mean_monoid(),
        "mn": monoids.min_monoid(),
        "mx": monoids.max_monoid(),
        "var": monoids.variance_monoid(),
    }


def _np_window_ref(vals, t, window):
    w = np.asarray(vals[max(0, t - window + 1): t + 1])
    return {"mean": w.mean(), "mn": w.min(), "mx": w.max(), "var": w.var()}


def test_product_monoid_laws():
    m = monoids.product_monoid(_metrics())
    xs = rng.standard_normal(5)
    lifted = [m.lift({"mean": x, "mn": x, "mx": x, "var": x}) for x in map(float, xs)]
    # identity is a two-sided unit
    for v in lifted:
        for combined in (m.combine(m.identity(), v), m.combine(v, m.identity())):
            for a, b in zip(jax.tree.leaves(combined), jax.tree.leaves(v)):
                assert np.allclose(np.asarray(a), np.asarray(b))
    # associativity (up to float reassociation)
    a, b, c = lifted[:3]
    left = m.combine(m.combine(a, b), c)
    right = m.combine(a, m.combine(b, c))
    for x, y in zip(jax.tree.leaves(left), jax.tree.leaves(right)):
        assert np.allclose(np.asarray(x), np.asarray(y), atol=1e-5)


def test_observe_matches_numpy_window():
    window = 6
    telem = WindowedTelemetry(_metrics(), window)
    vals = rng.standard_normal(20).astype(np.float32)
    for t, v in enumerate(vals):
        telem.observe({k: jnp.float32(v) for k in _metrics()})
        s = telem.snapshot()
        ref = _np_window_ref(vals, t, window)
        for k in ("mean", "mn", "mx", "var"):
            assert abs(float(s[k]) - ref[k]) < 1e-4, (t, k, s[k], ref[k])


def test_observe_bulk_matches_sequential_observe():
    window = 7
    t1 = WindowedTelemetry(_metrics(), window)
    t2 = WindowedTelemetry(_metrics(), window)
    vals = rng.standard_normal(23).astype(np.float32)
    for v in vals:
        t1.observe({k: jnp.float32(v) for k in _metrics()})
    outs = {}
    for lo in (0, 10):  # two ragged bulk chunks (10 then 13)
        chunk = vals[lo: lo + 10] if lo == 0 else vals[10:]
        outs = t2.observe_bulk({k: jnp.asarray(chunk) for k in _metrics()})
    s1, s2 = t1.snapshot(), t2.snapshot()
    for k in _metrics():
        assert abs(float(s1[k]) - float(s2[k])) < 1e-4, k
    # bulk also returns the per-step windowed outputs
    ref = _np_window_ref(vals, len(vals) - 2, window)
    assert abs(float(np.asarray(outs["mean"])[-2, 0]) - ref["mean"]) < 1e-4


def test_batched_lanes_are_independent():
    telem = WindowedTelemetry({"mx": monoids.max_monoid()}, window=4, batch=3)
    data = rng.standard_normal((10, 3)).astype(np.float32)
    for row in data:
        telem.observe({"mx": jnp.asarray(row)})
    s = telem.snapshot()
    assert np.allclose(np.asarray(s["mx"]), data[-4:].max(axis=0), atol=1e-6)


def test_functional_core_composes_into_jit():
    telem = WindowedTelemetry({"mx": monoids.max_monoid()}, window=4)
    state = telem.init_state()

    @jax.jit
    def roll(state, xs):
        def step(st, x):
            st = telem.update(st, {"mx": x})
            return st, telem.read(st)["mx"]

        return jax.lax.scan(step, state, xs)

    xs = jnp.asarray(rng.standard_normal(12), jnp.float32)
    _, out = roll(state, xs)
    ref = np.array([np.asarray(xs)[max(0, t - 3): t + 1].max() for t in range(12)])
    assert np.allclose(np.asarray(out)[:, 0], ref, atol=1e-6)


def test_observe_is_single_dispatch():
    telem = WindowedTelemetry(_metrics(), window=8)
    calls = []
    orig = telem._observe_jit
    telem._observe_jit = lambda *a: (calls.append(1), orig(*a))[1]
    telem.observe({k: 1.0 for k in _metrics()})
    telem.observe({k: 2.0 for k in _metrics()})
    assert calls == [1, 1]  # one jitted call per observation, nothing else


def test_windowed_stream_stats_reference():
    from repro.data.stream import WindowedStreamStats

    stats = WindowedStreamStats(window=3)
    toks = rng.integers(0, 50, (5, 2, 8)).astype(np.int32)
    for step in range(5):
        snap = stats.observe_batch(jnp.asarray(toks[step]), doc_id=step)
    tf = toks.astype(np.float32)
    means = tf.reshape(5, -1).mean(axis=1)
    assert abs(snap["win_tok_mean"] - means[-3:].mean()) < 1e-4
    assert snap["win_tok_min"] == tf[-3:].min()
    assert snap["win_tok_max"] == tf[-3:].max()
    assert stats.seen_recently(4) and stats.seen_recently(2)


def test_serve_engine_telemetry_surface():
    from repro.configs import ARCHS
    from repro.models.factory import reduced_config
    from repro.serve.engine import DecodeEngine, Request

    cfg = reduced_config(ARCHS["llama3.2-1b"])
    model_rng = np.random.default_rng(0)
    from repro.models.transformer import build_model

    params = build_model(cfg).init_params(jax.random.key(0))
    eng = DecodeEngine(cfg, params, batch_slots=2, cache_len=32,
                       telemetry_window=16)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=model_rng.integers(
            0, cfg.vocab_size, 5).astype(np.int32), max_new=3))
    eng.run_until_drained(max_steps=40)
    t = eng.telemetry()
    assert t["slot_occupancy"].shape == (2,)
    assert np.all((t["slot_occupancy"] >= 0) & (t["slot_occupancy"] <= 1))
    assert t["slot_retire_rate"].shape == (2,)
    assert float(t["slot_retire_rate"].sum()) > 0  # requests retired
    assert t["decode_ms_max"] >= t["decode_ms_mean"] > 0
