"""Windowed SSM/linear-attention state cells (the beyond-paper serving path)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.windowed_state import (
    ChunkedWindowedStateCell,
    WindowedStateCell,
    reference_windowed_state,
)


def _rand(seed, T, H, K, V):
    rng = np.random.default_rng(seed)
    decays = jnp.asarray(rng.uniform(0.6, 1.0, (T, H, K, 1)), jnp.float32)
    updates = jnp.asarray(rng.standard_normal((T, H, K, V)), jnp.float32)
    return decays, updates


@pytest.mark.parametrize("W", [1, 3, 7])
def test_windowed_state_vs_oracle(W):
    T, H, K, V = 25, 2, 4, 3
    decays, updates = _rand(0, T, H, K, V)
    cell = WindowedStateCell(H, K, V, W)
    state, outs = jax.jit(cell.prefill)(cell.init(), decays, updates)
    ref = reference_windowed_state(decays, updates, W)
    assert float(jnp.abs(outs - ref).max()) < 1e-4


def test_windowed_state_evicts_exactly():
    """After W tokens of zero-update, the window state must be exactly 0 —
    impossible with inverse-based approaches when decay underflows."""
    H, K, V, W = 1, 2, 2, 4
    cell = WindowedStateCell(H, K, V, W)
    st = cell.init()
    # big burst, then decay-0 tokens with zero updates
    st, _ = cell.update(st, jnp.ones((H, K, 1)), jnp.full((H, K, V), 100.0))
    for _ in range(W):
        st, out = cell.update(st, jnp.zeros((H, K, 1)), jnp.zeros((H, K, V)))
    assert float(jnp.abs(out).max()) == 0.0


def test_chunked_windowed_state():
    """Coarse-grained window ≡ exact window at chunk-aligned positions."""
    T, H, K, V = 48, 2, 3, 2
    chunk, wc = 4, 3  # window = 12 tokens at chunk granularity
    decays, updates = _rand(1, T, H, K, V)
    cell = ChunkedWindowedStateCell(H, K, V, chunk, wc)
    st = cell.init()
    outs = []
    for t in range(T):
        st, o = cell.update(st, decays[t], updates[t])
        outs.append(o)
    outs = jnp.stack(outs)
    # at positions where a chunk just completed (t+1 ≡ 0 mod chunk), the
    # covered window is exactly the last wc*chunk tokens
    ref = reference_windowed_state(decays, updates, wc * chunk)
    for t in range(chunk * wc - 1, T, chunk):
        err = float(jnp.abs(outs[t] - ref[t]).max())
        assert err < 1e-4, (t, err)


@pytest.mark.parametrize("T", [48, 29, 7, 3])
def test_chunked_cell_vectorized_prefill(T):
    """Bulk prefill ≡ the sequential update loop, and the rebuilt state
    continues decoding identically (incl. ragged T: partial final chunk)."""
    H, K, V, chunk, wc = 2, 3, 2, 4, 3
    decays, updates = _rand(2, T, H, K, V)
    cell = ChunkedWindowedStateCell(H, K, V, chunk, wc)
    st_seq = cell.init()
    ref = []
    for t in range(T):
        st_seq, o = cell.update(st_seq, decays[t], updates[t])
        ref.append(o)
    st_bulk, outs = cell.prefill(cell.init(), decays, updates)
    assert float(jnp.abs(outs - jnp.stack(ref)).max()) < 1e-4
    # continue decoding across at least one full window turnover
    rng2 = np.random.default_rng(3)
    for _ in range(2 * chunk * wc):
        d = jnp.asarray(rng2.uniform(0.6, 1.0, (H, K, 1)), jnp.float32)
        u = jnp.asarray(rng2.standard_normal((H, K, V)), jnp.float32)
        st_seq, o1 = cell.update(st_seq, d, u)
        st_bulk, o2 = cell.update(st_bulk, d, u)
        assert float(jnp.abs(o1 - o2).max()) < 1e-4


def test_chunked_cell_prefill_warm_state_falls_back():
    """A warm (non-fresh) state routes through the sequential scan path."""
    H, K, V = 1, 2, 2
    cell = ChunkedWindowedStateCell(H, K, V, chunk=4, window_chunks=2)
    st = cell.init()
    st, _ = cell.update(st, jnp.full((H, K, 1), 0.9), jnp.ones((H, K, V)))
    decays, updates = _rand(4, 10, H, K, V)
    st_a, out_a = cell.prefill(st, decays, updates)
    ref = []
    st_b = st
    for t in range(10):
        st_b, o = cell.update(st_b, decays[t], updates[t])
        ref.append(o)
    assert float(jnp.abs(out_a - jnp.stack(ref)).max()) < 1e-5


def test_chunked_cell_is_jittable():
    H, K, V = 1, 2, 2
    cell = ChunkedWindowedStateCell(H, K, V, chunk=4, window_chunks=2)
    st = cell.init()
    step = jax.jit(cell.update)
    for t in range(20):
        st, o = step(st, jnp.full((H, K, 1), 0.9), jnp.ones((H, K, V)))
    assert bool(jnp.isfinite(o).all())
