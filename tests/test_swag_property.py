"""Property tests: every SWAG algorithm ≡ recalculate-from-scratch oracle
under arbitrary insert/evict/query interleavings (hypothesis-driven).

Uses the exact-arithmetic affine_i32 monoid (non-commutative, non-invertible,
wraparound int32 ⇒ bit-exact associativity), so oracle equality is asserted
bitwise — any ordering or pointer bug fails loudly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't break collection
from hypothesis import given, settings, strategies as st

from repro.core import ALGORITHMS, GENERAL_ALGORITHMS, monoids

CAP = 24


def ops_strategy():
    """Sequences of (op, value) with a bounded window, arbitrary interleaving."""

    return st.lists(
        st.tuples(st.sampled_from(["i", "i", "i", "e", "q"]),
                  st.tuples(st.integers(-99, 99), st.integers(-99, 99))),
        min_size=1, max_size=120,
    )


def run(algo, m, ops, lower):
    st_ = algo.init(m, CAP)
    sz = 0
    out = []
    for kind, val in ops:
        if kind == "i":
            if sz >= CAP - 1:
                continue
            st_ = algo.insert(m, st_, val)
            sz += 1
        elif kind == "e":
            if sz == 0:
                continue
            st_ = algo.evict(m, st_)
            sz -= 1
        else:
            out.append(np.asarray(lower(algo.query(m, st_))))
    out.append(np.asarray(lower(algo.query(m, st_))))
    return out


@pytest.mark.parametrize("algo_name", sorted(GENERAL_ALGORITHMS))
@settings(max_examples=25, deadline=None)
@given(ops=ops_strategy())
def test_matches_oracle_affine(algo_name, ops):
    m = monoids.affine_int_monoid()
    ref = run(ALGORITHMS["recalc"], m, ops, m.lower)
    got = run(ALGORITHMS[algo_name], m, ops, m.lower)
    for i, (a, b) in enumerate(zip(ref, got)):
        assert np.array_equal(a, b), f"query #{i}: {a} != {b}"


@pytest.mark.parametrize("algo_name", sorted(ALGORITHMS))
@settings(max_examples=15, deadline=None)
@given(ops=ops_strategy())
def test_matches_oracle_sum(algo_name, ops):
    m = monoids.sum_monoid(jnp.int32)
    ops = [(k, v[0]) for k, v in ops]
    ref = run(ALGORITHMS["recalc"], m, ops, m.lower)
    got = run(ALGORITHMS[algo_name], m, ops, m.lower)
    for a, b in zip(ref, got):
        assert np.array_equal(a, b)


@pytest.mark.parametrize("algo_name", sorted(GENERAL_ALGORITHMS))
def test_maxcount_paper_trace(algo_name):
    """The paper's §2.3 running example trace, verbatim."""
    m = monoids.maxcount_monoid()
    algo = ALGORITHMS[algo_name]
    s = algo.init(m, 16)
    for v in [4.0, 5.0, 3.0, 4.0, 0.0, 4.0, 4.0]:
        s = algo.insert(m, s, v)
    q = algo.query(m, s)
    assert float(q["m"]) == 5.0 and int(q["c"]) == 1
    s = algo.evict(m, s)  # drop 4 → max 5 × 1
    q = algo.query(m, s)
    assert float(q["m"]) == 5.0 and int(q["c"]) == 1
    s = algo.evict(m, s)  # drop 5 → max 4 × 3 (non-invertible step!)
    q = algo.query(m, s)
    assert float(q["m"]) == 4.0 and int(q["c"]) == 3
    s = algo.insert(m, s, 2.0)
    q = algo.query(m, s)
    assert float(q["m"]) == 4.0 and int(q["c"]) == 3
    s = algo.insert(m, s, 6.0)
    q = algo.query(m, s)
    assert float(q["m"]) == 6.0 and int(q["c"]) == 1


@pytest.mark.parametrize("algo_name", sorted(GENERAL_ALGORITHMS))
def test_fill_and_drain(algo_name):
    """The paper's dynamic-window pattern (§7.2): fill to n, drain to 0."""
    m = monoids.affine_int_monoid()
    algo = ALGORITHMS[algo_name]
    oracle = ALGORITHMS["recalc"]
    s, so = algo.init(m, CAP), oracle.init(m, CAP)
    for n in [1, 5, CAP - 1]:
        for i in range(n):
            v = (i + 1, 2 * i - 3)
            s, so = algo.insert(m, s, v), oracle.insert(m, so, v)
            assert np.array_equal(
                np.asarray(m.lower(algo.query(m, s))),
                np.asarray(m.lower(oracle.query(m, so))),
            )
        for _ in range(n):
            s, so = algo.evict(m, s), oracle.evict(m, so)
            assert np.array_equal(
                np.asarray(m.lower(algo.query(m, s))),
                np.asarray(m.lower(oracle.query(m, so))),
            )
