"""Bulk-op protocol + chunked streaming engine ≡ per-element reference.

Property-style equivalence: ``insert_bulk``/``evict_bulk`` (specialized and
fallback) and ``ChunkedStream`` must reproduce the per-element
``insert``/``evict``/``stream`` semantics for every algorithm, across
commutative/non-commutative and invertible/non-invertible monoids, with
ragged chunk sizes.  Integer monoids must match bit-exactly (associativity
is exact in modular arithmetic, so reassociation cannot change results);
float monoids up to combine reassociation (allclose).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ALGORITHMS, GENERAL_ALGORITHMS, monoids, swag_base
from repro.core.batched import BatchedSWAG
from repro.core.chunked import ChunkedStream, tree_sliding_window

rng = np.random.default_rng(0)


def _scalar_vals(shape, dtype=jnp.float32):
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.asarray(rng.integers(-9, 9, shape), dtype)
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _affine_vals(shape, dtype=jnp.int32):
    return (
        jnp.asarray(rng.integers(-5, 5, shape), dtype),
        jnp.asarray(rng.integers(-5, 5, shape), dtype),
    )


# name -> (monoid, value maker, exact?)   Deliberately spans the algebraic
# classes: commutative+invertible, commutative pytree, and two
# NON-commutative NON-invertible ones (one exact-integer, one float).
MONOID_CASES = {
    "sum_i32": (monoids.sum_monoid(jnp.int32),
                lambda s: _scalar_vals(s, jnp.int32), True),
    "mean": (monoids.mean_monoid(), _scalar_vals, False),
    "affine_i32": (monoids.affine_int_monoid(), _affine_vals, True),
    "m4": (monoids.m4_monoid(), _scalar_vals, False),
}


def _assert_tree_close(a, b, exact, ctx=""):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        x, y = np.asarray(x), np.asarray(y)
        if exact:
            assert np.array_equal(x, y), (ctx, x, y)
        else:
            assert np.allclose(x, y, rtol=1e-4, atol=1e-4), (ctx, x, y)


# ---------------------------------------------------------------------------
# insert_bulk / evict_bulk vs per-element, every algorithm
# ---------------------------------------------------------------------------

# Ragged bulk phases: (kind, count) — interleavings that cross flip points,
# empty the window completely, and leave partial windows behind.
PHASES = [
    [("i", 20), ("e", 7), ("i", 5), ("e", 3)],
    [("i", 3), ("e", 3), ("i", 8), ("e", 1), ("i", 2), ("e", 9)],
    [("i", 1), ("e", 1), ("i", 30), ("e", 30)],
]


@pytest.mark.parametrize("mname", sorted(MONOID_CASES))
@pytest.mark.parametrize("algo_name", sorted(ALGORITHMS))
def test_bulk_matches_per_element(algo_name, mname):
    m, mk, exact = MONOID_CASES[mname]
    if algo_name == "soe" and not m.invertible:
        pytest.skip("subtract-on-evict needs an invertible monoid")
    algo = ALGORITHMS[algo_name]
    for phases in PHASES:
        s_ref, s_bulk = algo.init(m, 64), algo.init(m, 64)
        for kind, n in phases:
            if kind == "i":
                vals = mk(n)
                for i in range(n):
                    s_ref = algo.insert(m, s_ref, swag_base.tree_index(vals, i))
                s_bulk = swag_base.insert_bulk(algo, m, s_bulk, vals)
            else:
                for _ in range(n):
                    s_ref = algo.evict(m, s_ref)
                s_bulk = swag_base.evict_bulk(algo, m, s_bulk, n)
            assert int(algo.size(s_bulk)) == int(algo.size(s_ref))
            _assert_tree_close(
                m.lower(algo.query(m, s_bulk)),
                m.lower(algo.query(m, s_ref)),
                exact, (algo_name, mname, phases),
            )
        # a bulk-produced state must keep behaving under per-element ops
        more = mk(5)
        for i in range(5):
            v = swag_base.tree_index(more, i)
            s_ref = algo.insert(m, s_ref, v)
            s_bulk = algo.insert(m, s_bulk, v)
        for _ in range(3):
            s_ref, s_bulk = algo.evict(m, s_ref), algo.evict(m, s_bulk)
        _assert_tree_close(
            m.lower(algo.query(m, s_bulk)),
            m.lower(algo.query(m, s_ref)),
            exact, (algo_name, mname, "followup"),
        )


@pytest.mark.parametrize("mname", sorted(MONOID_CASES))
def test_flatfit_bulk_matches_per_element(mname):
    """FlatFIT (eager, mutable — outside ALGORITHMS) conforms to the bulk-op
    protocol: insert_bulk/evict_bulk ≡ per-element loops, interleaved with
    compressing queries to exercise rewritten index chains."""
    from repro.core import flatfit

    m, mk, exact = MONOID_CASES[mname]
    for phases in PHASES:
        s_ref, s_bulk = flatfit.init(m, 64), flatfit.init(m, 64)
        for step, (kind, n) in enumerate(phases):
            if kind == "i":
                vals = mk(n)
                for i in range(n):
                    s_ref = flatfit.insert(m, s_ref, swag_base.tree_index(vals, i))
                s_bulk = flatfit.insert_bulk(m, s_bulk, vals)
            else:
                for _ in range(n):
                    s_ref = flatfit.evict(m, s_ref)
                s_bulk = flatfit.evict_bulk(m, s_bulk, n)
            if step % 2:  # compress one side only: results must not change
                flatfit.query_mut(m, s_bulk)
            assert flatfit.size(s_bulk) == flatfit.size(s_ref)
            _assert_tree_close(
                m.lower(flatfit.query(m, s_bulk)),
                m.lower(flatfit.query(m, s_ref)),
                exact, (mname, phases),
            )


def test_bulk_ops_jittable():
    m = monoids.sum_monoid()
    for algo_name, algo in ALGORITHMS.items():
        st = algo.init(m, 32)
        st = jax.jit(lambda s, v: swag_base.insert_bulk(algo, m, s, v))(
            st, jnp.arange(10, dtype=jnp.float32)
        )
        st = jax.jit(lambda s: swag_base.evict_bulk(algo, m, s, 4))(st)
        assert float(algo.query(m, st)) == sum(range(4, 10)), algo_name


# ---------------------------------------------------------------------------
# ChunkedStream vs per-element BatchedSWAG.stream
# ---------------------------------------------------------------------------


def _per_element_stream(algo, m, xs, window):
    b = BatchedSWAG(algo, m, window + 4)
    state = b.init(jax.tree.leaves(xs)[0].shape[1])
    _, ys = b.stream(state, xs, window, chunked=False)
    return ys


@pytest.mark.parametrize("algo_name", sorted(GENERAL_ALGORITHMS))
def test_chunked_stream_matches_every_algorithm(algo_name):
    """Same randomized (T, B) stream: chunked engine ≡ per-element scan."""
    T, B, w = 61, 3, 8
    xs = _scalar_vals((T, B))
    ref = _per_element_stream(GENERAL_ALGORITHMS[algo_name], monoids.sum_monoid(), xs, w)
    ys = ChunkedStream(monoids.sum_monoid(), w, chunk=16).stream(xs)
    _assert_tree_close(ys, ref, exact=False, ctx=algo_name)


@pytest.mark.parametrize("mname", sorted(MONOID_CASES))
@pytest.mark.parametrize(
    "T,B,w,C",
    [(50, 3, 7, 16), (40, 2, 5, 5), (33, 1, 8, 13), (20, 2, 12, 4), (25, 2, 30, 8)],
)
def test_chunked_stream_monoids_ragged_chunks(mname, T, B, w, C):
    """Ragged chunk sizes (C ∤ T, C < w, w > T) across monoid classes, both
    the Pallas-kernel path (scalar ops) and the generic pytree path."""
    m, mk, exact = MONOID_CASES[mname]
    xs = mk((T, B))
    ref = _per_element_stream(ALGORITHMS["daba_lite"], m, xs, w)
    ys = ChunkedStream(m, w, chunk=C).stream(xs)
    _assert_tree_close(ys, ref, exact, (mname, T, B, w, C))


def test_chunked_stream_kernel_path_is_used_for_scalar_ops():
    eng = ChunkedStream(monoids.sum_monoid(), 8)
    assert eng.op == "sum"
    eng = ChunkedStream(monoids.m4_monoid(), 8)
    assert eng.op is None  # pytree Agg -> generic associative_scan path


def test_batched_stream_chunked_routing():
    """stream(chunked=True) ≡ stream(chunked=False), including a usable
    final state (identical window contents → identical future behaviour)."""
    for algo_name, algo in GENERAL_ALGORITHMS.items():
        m = monoids.sum_monoid()
        b = BatchedSWAG(algo, m, 12)
        xs = _scalar_vals((60, 3))
        st_pe, ys_pe = b.stream(b.init(3), xs, 8, chunked=False)
        st_ch, ys_ch = b.stream(b.init(3), xs, 8, chunked=True)
        _assert_tree_close(ys_ch, ys_pe, exact=False, ctx=algo_name)
        _assert_tree_close(b.query(st_ch), b.query(st_pe), False, algo_name)
        more = _scalar_vals((3,))
        st_pe, st_ch = b.insert(st_pe, more), b.insert(st_ch, more)
        st_pe, st_ch = b.evict(st_pe), b.evict(st_ch)
        _assert_tree_close(b.query(st_ch), b.query(st_pe), False, algo_name)


def test_tree_sliding_window_matches_kernel_ref():
    from repro.kernels.sliding_window.ref import sliding_window_ref

    x = _scalar_vals((40, 2))
    m = monoids.max_monoid()
    y = tree_sliding_window(m, x, 6)  # (T, B) time-leading
    yr = sliding_window_ref(jnp.asarray(x).T, window=6, op="max").T
    assert jnp.array_equal(y, yr)
