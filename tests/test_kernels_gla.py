"""Fused chunked-GLA Pallas kernel vs the sequential-scan oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gla.ops import gla
from repro.kernels.gla.ref import gla_ref

rng = np.random.default_rng(0)


def _mk(B, T, H, K, V, variant):
    r = jnp.asarray(rng.standard_normal((B, T, H, K)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, H, K)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, H, V)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.9, 1.0, (B, T, H, K)), jnp.float32)
    u = (jnp.asarray(rng.standard_normal((H, K)) * 0.1, jnp.float32)
         if variant == "rwkv" else None)
    return r, k, v, a, u


@pytest.mark.parametrize("variant", ["mamba", "rwkv"])
@pytest.mark.parametrize(
    "B,T,H,K,V,L",
    [(2, 48, 3, 8, 8, 16), (1, 50, 2, 16, 8, 16),
     (2, 64, 2, 8, 16, 32), (1, 33, 1, 8, 8, 8)],
)
def test_gla_kernel_vs_oracle(variant, B, T, H, K, V, L):
    r, k, v, a, u = _mk(B, T, H, K, V, variant)
    o = gla(r, k, v, a, u, chunk=L, variant=variant)
    o_ref = gla_ref(r, k, v, a, u, variant=variant)
    assert float(jnp.abs(o - o_ref).max()) < 1e-3


def test_gla_kernel_matches_model_chunked():
    """Kernel ≡ the model substrate's gla_chunked (the CPU/TPU pair)."""
    from repro.models.ssm import gla_chunked

    B, T, H, K, V = 2, 64, 2, 8, 8
    r, k, v, a, _ = _mk(B, T, H, K, V, "mamba")
    o_kernel = gla(r, k, v, a, chunk=16, variant="mamba")
    s0 = jnp.zeros((B, H, K, V), jnp.float32)
    o_model, _ = gla_chunked(r, k, v, a, s0, chunk=16)
    assert float(jnp.abs(o_kernel - o_model).max()) < 1e-4


def test_gla_kernel_bf16():
    B, T, H, K, V = 1, 32, 2, 8, 8
    r, k, v, a, _ = _mk(B, T, H, K, V, "mamba")
    o = gla(r.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
            v.astype(jnp.bfloat16), a, chunk=16).astype(jnp.float32)
    o_ref = gla_ref(r, k, v, a)
    assert float(jnp.abs(o - o_ref).max()) < 0.15
