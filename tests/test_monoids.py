"""Monoid laws (associativity, identity) — hypothesis property tests."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't break collection
from hypothesis import given, settings, strategies as st

from repro.core import monoids

INT_VALS = st.integers(min_value=-1000, max_value=1000)


def tree_close(a, b, tol=1e-4):
    import jax

    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(
        np.allclose(np.asarray(x), np.asarray(y), rtol=tol, atol=tol)
        for x, y in zip(la, lb)
    )


CASES = [
    ("sum_i32", monoids.sum_monoid(jnp.int32), INT_VALS, True),
    ("max_i32", monoids.max_monoid(jnp.int32), INT_VALS, True),
    ("min_i32", monoids.min_monoid(jnp.int32), INT_VALS, True),
    ("maxcount", monoids.maxcount_monoid(jnp.float32),
     st.integers(0, 10).map(float), True),
    ("argmax", monoids.argmax_monoid(),
     st.tuples(st.integers(0, 10).map(float), st.integers(0, 100)), True),
    ("m4", monoids.m4_monoid(), st.integers(-50, 50).map(float), True),
    ("affine_i32", monoids.affine_int_monoid(),
     st.tuples(INT_VALS, INT_VALS), True),
    ("bloom", monoids.bloom_monoid(8), st.integers(0, 10_000), True),
    ("countmin", monoids.countmin_monoid(2, 16), st.integers(0, 10_000), True),
    ("hll", monoids.hll_monoid(16), st.integers(0, 10_000), True),
    # kll: with 3 lifted singletons no compaction triggers, so the merge is
    # a plain sorted union — associative and commutative bit-exactly
    ("kll", monoids.kll_monoid(k=32, levels=4),
     st.integers(-100, 100).map(float), True),
    ("mean", monoids.mean_monoid(), st.integers(-100, 100).map(float), False),
    ("geomean", monoids.geomean_monoid(),
     st.integers(1, 100).map(float), False),
    ("variance", monoids.variance_monoid(),
     st.integers(-20, 20).map(float), False),
    ("logsumexp", monoids.logsumexp_monoid(),
     st.integers(-20, 20).map(float), False),
]


@pytest.mark.parametrize("name,m,strat,exact", CASES, ids=[c[0] for c in CASES])
@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_associativity(name, m, strat, exact, data):
    a = m.lift(data.draw(strat))
    b = m.lift(data.draw(strat))
    c = m.lift(data.draw(strat))
    left = m.combine(m.combine(a, b), c)
    right = m.combine(a, m.combine(b, c))
    if exact:
        import jax

        assert all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(jax.tree.leaves(left), jax.tree.leaves(right))
        )
    else:
        assert tree_close(left, right)


@pytest.mark.parametrize("name,m,strat,exact", CASES, ids=[c[0] for c in CASES])
@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_identity(name, m, strat, exact, data):
    a = m.lift(data.draw(strat))
    assert tree_close(m.combine(m.identity(), a), a, tol=1e-6)
    assert tree_close(m.combine(a, m.identity()), a, tol=1e-6)


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_left_inverse(data):
    """inverse_front(lift(e) ⊗ r, lift(e)) == r for invertible monoids."""
    for m, strat in [
        (monoids.sum_monoid(jnp.int32), INT_VALS),
        (monoids.mean_monoid(), st.integers(-100, 100).map(float)),
        (monoids.countmin_monoid(2, 16), st.integers(0, 1000)),
    ]:
        e = m.lift(data.draw(strat))
        r = m.lift(data.draw(strat))
        combined = m.combine(e, r)
        recovered = m.inverse_front(combined, e)
        assert tree_close(recovered, r, tol=1e-5)


def test_noncommutative_monoids_are_noncommutative():
    """The monoids we rely on for order-sensitivity really are order-sensitive."""
    m = monoids.affine_int_monoid()
    a, b = m.lift((2, 3)), m.lift((5, 7))
    ab, ba = m.combine(a, b), m.combine(b, a)
    assert int(ab["b"]) != int(ba["b"])

    am = monoids.argmax_monoid()
    x, y = am.lift((1.0, 10)), am.lift((1.0, 20))
    assert int(am.combine(x, y)["i"]) == 10  # tie → older wins
    assert int(am.combine(y, x)["i"]) == 20


def test_bloom_membership():
    m = monoids.bloom_monoid(16)
    filt = m.identity()
    for v in [3, 17, 99]:
        filt = m.combine(filt, m.lift(v))
    for v in [3, 17, 99]:
        assert bool(monoids.bloom_contains(filt, jnp.asarray(v)))
    misses = sum(
        bool(monoids.bloom_contains(filt, jnp.asarray(v))) for v in range(1000, 1100)
    )
    assert misses < 10  # false-positive rate sanity


def test_countmin_estimate():
    m = monoids.countmin_monoid(4, 64)
    sk = m.identity()
    for v, n in [(5, 3), (9, 1)]:
        for _ in range(n):
            sk = m.combine(sk, m.lift(v))
    assert int(monoids.countmin_estimate(sk, 5)) >= 3
    assert int(monoids.countmin_estimate(sk, 9)) >= 1
