"""Monoid laws (associativity, identity) — hypothesis property tests."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't break collection
from hypothesis import given, settings, strategies as st

from repro.core import monoids

INT_VALS = st.integers(min_value=-1000, max_value=1000)


def tree_close(a, b, tol=1e-4):
    import jax

    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(
        np.allclose(np.asarray(x), np.asarray(y), rtol=tol, atol=tol)
        for x, y in zip(la, lb)
    )


CASES = [
    ("sum_i32", monoids.sum_monoid(jnp.int32), INT_VALS, True),
    ("max_i32", monoids.max_monoid(jnp.int32), INT_VALS, True),
    ("min_i32", monoids.min_monoid(jnp.int32), INT_VALS, True),
    ("maxcount", monoids.maxcount_monoid(jnp.float32),
     st.integers(0, 10).map(float), True),
    ("argmax", monoids.argmax_monoid(),
     st.tuples(st.integers(0, 10).map(float), st.integers(0, 100)), True),
    ("m4", monoids.m4_monoid(), st.integers(-50, 50).map(float), True),
    ("affine_i32", monoids.affine_int_monoid(),
     st.tuples(INT_VALS, INT_VALS), True),
    ("bloom", monoids.bloom_monoid(8), st.integers(0, 10_000), True),
    ("countmin", monoids.countmin_monoid(2, 16), st.integers(0, 10_000), True),
    ("hll", monoids.hll_monoid(16), st.integers(0, 10_000), True),
    # kll: with 3 lifted singletons no compaction triggers, so the merge is
    # a plain sorted union — associative and commutative bit-exactly
    ("kll", monoids.kll_monoid(k=32, levels=4),
     st.integers(-100, 100).map(float), True),
    # topk: with 3 lifted singletons no truncation triggers, and the
    # canonical (count desc, key asc) re-sort makes the merge bit-exact
    ("topk", monoids.topk_monoid(8), st.integers(0, 1000), True),
    ("mean", monoids.mean_monoid(), st.integers(-100, 100).map(float), False),
    ("geomean", monoids.geomean_monoid(),
     st.integers(1, 100).map(float), False),
    ("variance", monoids.variance_monoid(),
     st.integers(-20, 20).map(float), False),
    ("logsumexp", monoids.logsumexp_monoid(),
     st.integers(-20, 20).map(float), False),
]


@pytest.mark.parametrize("name,m,strat,exact", CASES, ids=[c[0] for c in CASES])
@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_associativity(name, m, strat, exact, data):
    a = m.lift(data.draw(strat))
    b = m.lift(data.draw(strat))
    c = m.lift(data.draw(strat))
    left = m.combine(m.combine(a, b), c)
    right = m.combine(a, m.combine(b, c))
    if exact:
        import jax

        assert all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(jax.tree.leaves(left), jax.tree.leaves(right))
        )
    else:
        assert tree_close(left, right)


@pytest.mark.parametrize("name,m,strat,exact", CASES, ids=[c[0] for c in CASES])
@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_identity(name, m, strat, exact, data):
    a = m.lift(data.draw(strat))
    assert tree_close(m.combine(m.identity(), a), a, tol=1e-6)
    assert tree_close(m.combine(a, m.identity()), a, tol=1e-6)


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_left_inverse(data):
    """inverse_front(lift(e) ⊗ r, lift(e)) == r for invertible monoids."""
    for m, strat in [
        (monoids.sum_monoid(jnp.int32), INT_VALS),
        (monoids.mean_monoid(), st.integers(-100, 100).map(float)),
        (monoids.countmin_monoid(2, 16), st.integers(0, 1000)),
    ]:
        e = m.lift(data.draw(strat))
        r = m.lift(data.draw(strat))
        combined = m.combine(e, r)
        recovered = m.inverse_front(combined, e)
        assert tree_close(recovered, r, tol=1e-5)


def test_noncommutative_monoids_are_noncommutative():
    """The monoids we rely on for order-sensitivity really are order-sensitive."""
    m = monoids.affine_int_monoid()
    a, b = m.lift((2, 3)), m.lift((5, 7))
    ab, ba = m.combine(a, b), m.combine(b, a)
    assert int(ab["b"]) != int(ba["b"])

    am = monoids.argmax_monoid()
    x, y = am.lift((1.0, 10)), am.lift((1.0, 20))
    assert int(am.combine(x, y)["i"]) == 10  # tie → older wins
    assert int(am.combine(y, x)["i"]) == 20


def test_bloom_membership():
    m = monoids.bloom_monoid(16)
    filt = m.identity()
    for v in [3, 17, 99]:
        filt = m.combine(filt, m.lift(v))
    for v in [3, 17, 99]:
        assert bool(monoids.bloom_contains(filt, jnp.asarray(v)))
    misses = sum(
        bool(monoids.bloom_contains(filt, jnp.asarray(v))) for v in range(1000, 1100)
    )
    assert misses < 10  # false-positive rate sanity


def test_topk_exact_below_capacity():
    """≤ k distinct keys → exact counts, heaviest first, key tie-break."""
    m = monoids.topk_monoid(4)
    agg = m.identity()
    for v in [1, 2, 1, 3, 1, 2, 1]:
        agg = m.combine(agg, m.lift(v))
    assert monoids.topk_items(agg) == [(1, 4), (2, 2), (3, 1)]


def test_topk_heavy_hitters_survive_truncation():
    """Keys heavier than the dropped tail stay resident past capacity."""
    import jax

    from repro.core.event_time import fold_axis0

    rng = np.random.default_rng(0)
    stream = np.concatenate(
        [np.full(500, 9), np.full(300, 13), rng.integers(100, 200, 400)]
    ).astype(np.int32)
    rng.shuffle(stream)
    m = monoids.topk_monoid(8)
    agg = fold_axis0(m, jax.vmap(m.lift)(jnp.asarray(stream)))
    items = monoids.topk_items(agg)
    assert items[0] == (9, 500)
    assert items[1] == (13, 300)


def test_topk_batched_combine():
    """Leading batch axes broadcast (the seg-scan calling convention)."""
    import jax

    m = monoids.topk_monoid(8)
    a = jax.tree.map(lambda x: jnp.stack([x, x]), m.lift(3))
    b = jax.tree.map(lambda x: jnp.stack([x, x]), m.lift(3))
    out = m.combine(a, b)
    assert out["keys"].shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(out["counts"][:, 0]), [2, 2])


def test_hll_estimate_tracks_cardinality():
    import jax

    from repro.core.event_time import fold_axis0

    m = monoids.hll_monoid(64)
    for n in (50, 1000, 10_000):
        agg = fold_axis0(m, jax.vmap(m.lift)(jnp.arange(n, dtype=jnp.int32)))
        est = float(monoids.hll_estimate(agg))
        assert abs(est - n) / n < 0.35, (n, est)


def test_countmin_estimate():
    m = monoids.countmin_monoid(4, 64)
    sk = m.identity()
    for v, n in [(5, 3), (9, 1)]:
        for _ in range(n):
            sk = m.combine(sk, m.lift(v))
    assert int(monoids.countmin_estimate(sk, 5)) >= 3
    assert int(monoids.countmin_estimate(sk, 9)) >= 1
