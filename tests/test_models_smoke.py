"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, shape + finiteness assertions, and prefill→decode consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.factory import make_smoke_batch, reduced_config
from repro.models.transformer import (
    DecodeSpec,
    build_model,
    forward,
    logits_fn,
)

KEY = jax.random.key(0)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch):
    rc = reduced_config(ARCHS[arch])
    model = build_model(rc)
    params = model.init_params(KEY)
    batch = make_smoke_batch(rc, KEY, B=2, S=16)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    # one grad step moves the loss
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes(arch):
    rc = reduced_config(ARCHS[arch])
    model = build_model(rc)
    params = model.init_params(KEY)
    batch = make_smoke_batch(rc, KEY, B=2, S=16)
    h = forward(params, rc, {k: v for k, v in batch.items() if k != "labels"})
    assert h.shape == (2, 16, rc.d_model)
    logits = logits_fn(params, rc, h)
    assert logits.shape == (2, 16, rc.padded_vocab)
    assert bool(jnp.isfinite(logits[..., : rc.vocab_size]).all())


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_consistency(arch):
    """Greedy decode with caches ≡ full-forward recompute (per-arch).

    MoE archs use a high capacity factor so the oracle doesn't drop tokens
    (capacity-based routing differs between batched prefill and single-token
    decode by design)."""
    rc = reduced_config(ARCHS[arch])
    if rc.num_experts:
        rc = dataclasses.replace(rc, capacity_factor=8.0)
    model = build_model(rc)
    params = model.init_params(KEY)
    S0, NDEC, B = 10, 3, 2
    batch = make_smoke_batch(rc, KEY, B=B, S=S0)
    spec = DecodeSpec(cache_len=S0 + NDEC, local_cache_len=rc.local_window, batch=B)
    pre = {k: v for k, v in batch.items() if k != "labels"}
    logits_p, st = model.prefill(params, pre, spec)
    assert logits_p.shape == (B, rc.padded_vocab)
    if rc.embed_inputs:
        # embed-input archs decode from token embeddings (frontend stub has
        # no token ids in the prompt) — just verify the decode path runs.
        tok = jnp.argmax(logits_p, -1).astype(jnp.int32)
        for _ in range(2):
            logits_p, st = model.decode_step(params, st, tok)
            tok = jnp.argmax(logits_p, -1).astype(jnp.int32)
        assert bool(jnp.isfinite(logits_p).all())
        return
    cur = batch["tokens"]
    tok = jnp.argmax(logits_p, -1).astype(jnp.int32)
    errs = []
    for _ in range(NDEC):
        cur = jnp.concatenate([cur, tok[:, None]], axis=1)
        ld, st = model.decode_step(params, st, tok)
        lf = logits_fn(params, rc, forward(params, rc, dict(pre, tokens=cur)))[:, -1]
        errs.append(float(jnp.abs(ld - lf).max()))
        tok = jnp.argmax(ld, -1).astype(jnp.int32)
    assert max(errs) < 5e-3, errs


def test_local_window_changes_gemma_attention():
    """gemma2's local layers must actually mask beyond the window."""
    rc = dataclasses.replace(
        reduced_config(ARCHS["gemma2-27b"]), local_window=4, num_layers=2
    )
    model = build_model(rc)
    params = model.init_params(KEY)
    batch = make_smoke_batch(rc, KEY, B=1, S=12)
    h1 = forward(params, rc, batch)
    # perturb a token far outside every local window of the last position
    t2 = batch["tokens"].at[0, 0].set((batch["tokens"][0, 0] + 1) % rc.vocab_size)
    h2 = forward(params, rc, dict(batch, tokens=t2))
    # global layers still see token 0, so hidden states differ...
    assert float(jnp.abs(h1[0, -1] - h2[0, -1]).max()) > 0
    # ...but with ALL layers local, the last position is unaffected
    rc_local = dataclasses.replace(rc, attn_pattern="local")
    h1l = forward(params, rc_local, batch)
    h2l = forward(params, rc_local, dict(batch, tokens=t2))
    assert float(jnp.abs(h1l[0, -1] - h2l[0, -1]).max()) == 0.0


def test_moe_load_balance_aux():
    from repro.models.mlp import init_moe_params, moe_block

    rc = reduced_config(ARCHS["grok-1-314b"])
    p = init_moe_params(KEY, rc, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, rc.d_model), jnp.float32)
    out, aux = moe_block(p, x, rc)
    assert out.shape == x.shape
    assert float(aux["lb_loss"]) > 0
    assert 0 < float(aux["max_load"]) <= 1.5


def test_param_count_analytic_close_to_actual():
    for arch in ["llama3.2-1b", "rwkv6-1.6b", "grok-1-314b"]:
        rc = reduced_config(ARCHS[arch])
        model = build_model(rc)
        params = model.init_params(KEY)
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = rc.param_count()
        assert abs(actual - analytic) / actual < 0.30, (arch, actual, analytic)
