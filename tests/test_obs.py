"""Observability layer: registry/scrape/render, zero-overhead gating,
exporter, chrome-trace recorder, KLL accuracy, checkpoint survival.

The contract under test (PR 8):

  * ``ObsConfig(enabled=False)`` is FREE — the traced computation of an
    instrumented engine is byte-identical to an uninstrumented one
    (jaxpr equality), so production can ship the hooks compiled out.
  * One scrape = one ``jax.effects_barrier`` + one batched transfer; the
    Prometheus rendering is well-formed text exposition 0.0.4.
  * Engine counters live INSIDE the engine state pytree, so they ride
    through ``state_dict``/``load_state_dict`` untouched.
"""

import json
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import monoids
from repro.core.keyed import KeyedWindowStore
from repro.core.telemetry import KeyedTelemetry
from repro.obs import ObsConfig, default_registry
from repro.obs.registry import KLLHistogram, MetricsRegistry, split_series

rng = np.random.default_rng(0)


def _chunk(C=32, U=8):
    keys = jnp.asarray(rng.integers(0, U, C), jnp.int32)
    xs = jnp.asarray(rng.integers(0, 100, C), jnp.int32)
    return keys, xs


# ---------------------------------------------------------------------------
# Zero-overhead gate: disabled obs must not touch the traced computation
# ---------------------------------------------------------------------------


def test_obs_disabled_jaxpr_byte_identical():
    """An ObsConfig with enabled=False — even with every instrument flag
    raised — must leave update_chunk's jaxpr identical to a store built
    with no obs at all.  This is the 'free when off' guarantee the
    acceptance bench (disabled within 2% of baseline) rests on."""
    m = monoids.sum_monoid(jnp.int32)
    keys, xs = _chunk()
    off = ObsConfig(enabled=False, registry=MetricsRegistry(),
                    instrument_admission=True, instrument_combines=True)
    plain = KeyedWindowStore(m, window=8, slots=16)
    gated = KeyedWindowStore(m, window=8, slots=16, obs=off)
    jx_plain = jax.make_jaxpr(plain.update_chunk)(
        plain.init_state(), keys, xs)
    jx_gated = jax.make_jaxpr(gated.update_chunk)(
        gated.init_state(), keys, xs)
    assert str(jx_plain) == str(jx_gated)


def test_obs_enabled_instrumentation_changes_jaxpr():
    """Sanity for the test above: with enabled=True the admission
    callback IS traced in, so the jaxprs must differ — otherwise the
    equality check proves nothing."""
    m = monoids.sum_monoid(jnp.int32)
    keys, xs = _chunk()
    on = ObsConfig(enabled=True, registry=MetricsRegistry(),
                   instrument_admission=True)
    plain = KeyedWindowStore(m, window=8, slots=16)
    inst = KeyedWindowStore(m, window=8, slots=16, obs=on)
    jx_plain = jax.make_jaxpr(plain.update_chunk)(
        plain.init_state(), keys, xs)
    jx_inst = jax.make_jaxpr(inst.update_chunk)(
        inst.init_state(), keys, xs)
    assert str(jx_plain) != str(jx_inst)


# ---------------------------------------------------------------------------
# Counters ride through checkpoint state
# ---------------------------------------------------------------------------


def test_counters_survive_state_dict_roundtrip():
    """Eviction/drop counters live in the engine state pytree, so a
    checkpoint restore onto a FRESH instance restores them exactly."""
    tel = KeyedTelemetry({"v": monoids.sum_monoid()}, window=4, slots=4)
    # universe 64 ≫ slots 4: forces evictions (and failed admissions once
    # the per-chunk distinct-key count exceeds the directory capacity)
    for _ in range(6):
        keys = rng.integers(0, 64, 32)
        tel.observe_bulk(keys, {"v": jnp.ones(32, jnp.float32)})
    before = tel.counters()
    assert before["n_evicted"] > 0, before

    sd = jax.device_get(tel.state_dict())  # host copy, like a checkpoint
    fresh = KeyedTelemetry({"v": monoids.sum_monoid()}, window=4, slots=4)
    assert fresh.counters()["n_evicted"] == 0
    fresh.load_state_dict(sd)
    assert fresh.counters() == before
    # and the restored instance keeps counting from there
    fresh.observe_bulk(rng.integers(0, 64, 32),
                       {"v": jnp.ones(32, jnp.float32)})
    assert fresh.counters()["n_evicted"] >= before["n_evicted"]


# ---------------------------------------------------------------------------
# Registry: scrape + Prometheus rendering
# ---------------------------------------------------------------------------


def test_registry_scrape_and_render():
    reg = MetricsRegistry()
    reg.gauge("repro_test_gauge", "a gauge").set(3.5)
    # counters are declared by base name; the scrape appends ``_total``
    c = reg.counter("repro_test_ops", "an op counter")
    c.inc()
    c.inc(2)
    h = reg.histogram("repro_test_ms", "latency", quantiles=(0.5, 0.99))
    h.observe_many(np.arange(100.0))
    reg.describe("repro_test_collected", "gauge", "from a collector")
    reg.register_collector(
        lambda: {"repro_test_collected": jnp.float32(7.0)})
    # a RAISING collector must be skipped, not poison the scrape
    # (donated-away state robustness)
    reg.register_collector(lambda: 1 / 0)

    snap = reg.scrape()
    assert snap["repro_test_gauge"] == 3.5
    assert snap["repro_test_ops_total"] == 3.0
    assert snap["repro_test_collected"] == 7.0

    text = reg.render()
    assert "# HELP repro_test_gauge a gauge" in text
    assert "# TYPE repro_test_gauge gauge" in text
    assert "# TYPE repro_test_ops_total counter" in text
    assert "# TYPE repro_test_ms summary" in text
    assert 'repro_test_ms{quantile="0.5"}' in text
    assert "repro_test_ms_count 100" in text
    # every non-comment line is `name{labels} value` with a float value
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        name, val = line.rsplit(" ", 1)
        float(val)  # must parse
        assert name[0].isalpha() or name[0] == "_", line


def test_split_series_inline_labels():
    assert split_series("repro_x") == ("repro_x", {})
    base, labels = split_series('repro_x{shard="3",zone="a"}')
    assert base == "repro_x"
    assert labels == {"shard": "3", "zone": "a"}


def test_counter_group_scrape_via_default_registry():
    """The module-global admission/combine groups are pre-adopted by the
    default registry and render with branch/engine labels."""
    from repro.obs import counters

    reg = default_registry()
    counters.admission.reset()
    counters.admission.bump("fast", 5)
    snap = reg.scrape()
    assert snap['swag_admission_branch_total{branch="fast"}'] == 5
    assert 'swag_admission_branch_total{branch="fast"} 5' in reg.render()
    counters.admission.reset()


# ---------------------------------------------------------------------------
# KLL sketch accuracy (what /metrics serves as p50/p95/p99)
# ---------------------------------------------------------------------------


def test_kll_quantiles_track_exact_percentiles():
    vals = rng.permutation(np.arange(10_000, dtype=np.float64))
    h = KLLHistogram("t", quantiles=(0.5, 0.95, 0.99))
    # feed in uneven host-side batches; drain() folds them in one dispatch
    for lo in range(0, 10_000, 1337):
        h.observe_many(vals[lo:lo + 1337])
    got = np.asarray(h.quantile_values()).ravel()
    want = np.percentile(vals, [50, 95, 99])
    # KLL at k=64 holds rank error well under 3% of n on this range
    np.testing.assert_allclose(got, want, atol=0.03 * 10_000)
    assert h.count == 10_000


# ---------------------------------------------------------------------------
# Exporter: live /metrics over HTTP
# ---------------------------------------------------------------------------


def test_exporter_serves_prometheus_text():
    from repro.obs.exporter import MetricsExporter

    reg = MetricsRegistry()
    reg.gauge("repro_exported_gauge", "g").set(1.0)
    with MetricsExporter(reg, port=0) as exp:
        body = urllib.request.urlopen(exp.url, timeout=10)
        assert body.headers["Content-Type"].startswith(
            "text/plain; version=0.0.4")
        text = body.read().decode()
        assert "repro_exported_gauge 1" in text
        ok = urllib.request.urlopen(
            f"http://127.0.0.1:{exp.port}/healthz", timeout=10)
        assert ok.read() == b"ok\n"
    # after stop() the port is closed
    with pytest.raises(Exception):
        urllib.request.urlopen(exp.url, timeout=2)


def test_exporter_concurrent_scrapes_while_engine_streams():
    """Two clients hammer /metrics while the engine ingests chunks: every
    response is well-formed, no scrape is lost, and the histogram's drained
    count equals everything observed (the drain pop→fold→assign race would
    silently drop folds here)."""
    import threading

    from repro.core.keyed import KeyedChunkedStream
    from repro.obs.exporter import MetricsExporter

    reg = MetricsRegistry()
    hist = reg.histogram("repro_scrape_race_seconds", "drain-race probe")
    eng = KeyedChunkedStream(
        monoids.sum_monoid(jnp.int32), 16, slots=32, chunk=32, donate=False
    )
    state = eng.init_state()

    def get_state():
        return state

    reg.register_collector(
        lambda: {"repro_live_probe": get_state()["dir"]["n_live"]}
    )

    stop = threading.Event()
    errs: list = []
    bodies: list = []

    def scrape_loop(url):
        try:
            while not stop.is_set():
                # generous timeout: early scrapes pay drain-jit compiles
                with urllib.request.urlopen(url, timeout=120) as r:
                    text = r.read().decode()
                assert "repro_live_probe" in text
                assert "repro_scrape_race_seconds_count" in text
                bodies.append(text)
        except Exception as e:  # pragma: no cover
            errs.append(e)

    n_chunks, per_chunk = 30, 8
    with MetricsExporter(reg, port=0) as exp:
        clients = [threading.Thread(target=scrape_loop, args=(exp.url,))
                   for _ in range(2)]
        for c in clients:
            c.start()
        for i in range(n_chunks):
            keys, xs = _chunk(32)
            state, _, _ = eng.process_chunk(state, keys, xs)
            hist.observe_many(np.full(per_chunk, 0.001 * (i + 1)))
        stop.set()
        for c in clients:
            c.join()
        assert not errs
        assert len(bodies) >= 2  # both clients actually scraped
        # every observation survived the concurrent drains
        import jax

        # weights: level-l items count 2**l — recover the total count
        agg = jax.device_get(hist.aggregate())
        weighted = sum(
            int(n) * (1 << l) for l, n in enumerate(np.asarray(agg["n"]))
        )
        assert weighted == n_chunks * per_chunk
        assert hist.count == n_chunks * per_chunk


def test_histogram_concurrent_drain_loses_nothing():
    """N threads drain while M threads observe: the sketch's weighted item
    count must equal the total observed (regression test for the unlocked
    ``_agg`` read-modify-write)."""
    import threading

    import jax

    h = KLLHistogram("h", k=64, levels=12)
    n_obs_threads, n_drain_threads, per_thread = 4, 4, 250
    start = threading.Barrier(n_obs_threads + n_drain_threads)
    done = threading.Event()

    def observe():
        start.wait()
        for i in range(per_thread):
            h.observe(float(i))

    def drain_loop():
        start.wait()
        while not done.is_set():
            h.drain()

    obs = [threading.Thread(target=observe) for _ in range(n_obs_threads)]
    drains = [threading.Thread(target=drain_loop)
              for _ in range(n_drain_threads)]
    for t in obs + drains:
        t.start()
    for t in obs:
        t.join()
    done.set()
    for t in drains:
        t.join()
    h.drain()
    agg = jax.device_get(h.aggregate())
    weighted = sum(
        int(n) * (1 << l) for l, n in enumerate(np.asarray(agg["n"]))
    )
    assert weighted == n_obs_threads * per_thread
    assert h.count == n_obs_threads * per_thread


# ---------------------------------------------------------------------------
# Chrome trace recorder
# ---------------------------------------------------------------------------


def test_trace_stage_spans_partition_parent(tmp_path):
    from repro.obs.trace import TraceRecorder

    tr = TraceRecorder(process_name="t")
    with tr.span("keyed.chunk", tid=1, args={"chunk": 64}) as args:
        args["rows"] = 64
    stages = {"sort": 600.0, "probe": 250.0, "sweep": 150.0}
    tr.add_stage_spans("keyed.chunk", ts_us=1000.0, dur_us=500.0,
                       stages=stages, tid=1)
    evs = tr.events()
    subs = [e for e in evs if e["name"].startswith("keyed.chunk/")]
    assert len(subs) == 3
    assert abs(sum(e["dur"] for e in subs) - 500.0) < 1.0
    assert abs(sum(e["args"]["roofline_frac"] for e in subs) - 1.0) < 1e-3
    assert all(e["args"]["modeled"] for e in subs)
    # sub-spans tile the parent interval: each starts where the last ended
    subs.sort(key=lambda e: e["ts"])
    for a, b in zip(subs, subs[1:]):
        assert abs((a["ts"] + a["dur"]) - b["ts"]) < 1.0

    path = tmp_path / "trace.json"
    tr.save(str(path))
    doc = json.loads(path.read_text())
    assert doc["traceEvents"]  # Perfetto-loadable envelope
    assert any(e["ph"] == "M" for e in doc["traceEvents"])  # process_name
    assert any(e["ph"] == "X" and e["name"] == "keyed.chunk"
               for e in doc["traceEvents"])


# ---------------------------------------------------------------------------
# End-to-end: instrumented keyed engine feeds the registry
# ---------------------------------------------------------------------------


def test_keyed_stream_attach_obs_end_to_end():
    from repro.core.keyed import KeyedChunkedStream

    reg = MetricsRegistry()
    obs = ObsConfig(registry=reg)
    eng = KeyedChunkedStream(monoids.sum_monoid(jnp.int32), window=8,
                             slots=8, chunk=32, obs=obs)
    eng.attach_obs(reg)
    state = eng.init_state()
    for _ in range(3):
        keys, xs = _chunk(C=32, U=32)  # universe ≫ slots → drops/evictions
        state, _, _ = eng.process_chunk(state, keys, xs)
    snap = reg.scrape()
    assert snap["repro_keyed_chunks_total"] == 3
    assert snap["repro_keyed_rows_total"] == 96
    assert snap["repro_keyed_live_keys"] == 8  # slots saturated
    assert snap["repro_keyed_evictions_total"] > 0
