"""Distributed pieces testable in-process: sharding rule validity for every
arch, compression math, and multi-device collectives via a subprocess (the
main process must keep the default 1-device CPU platform)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES
from repro.distributed.compression import (
    dequantize,
    ef_compress_tree,
    init_error_state,
    quantize,
)
from repro.distributed.sharding import param_pspecs
from repro.models import factory


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_pspecs_divisible(arch):
    """Every sharded dim must divide the 16-way model axis — for all archs,
    including the awkward ones (arctic H=56, qwen2-vl H=12, whisper V=51866,
    grok E=8)."""
    cfg = ARCHS[arch]
    shapes = factory.param_specs(cfg)
    specs = param_pspecs(cfg, shapes, tp=16)

    def check(path, leaf, spec):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax == "model":
                assert dim % 16 == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), shapes, specs,
        is_leaf=lambda x: hasattr(x, "shape"),
    )


@pytest.mark.parametrize("arch", ["arctic-480b", "grok-1-314b"])
def test_fsdp_pspecs_shard_big_leaves(arch):
    """With an fsdp mesh, every multi-MB leaf gains a data axis."""
    cfg = ARCHS[arch]
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    shapes = factory.param_specs(cfg)
    specs = param_pspecs(cfg, shapes, tp=16, fsdp_mesh=FakeMesh())

    bad = []

    def check(path, leaf, spec):
        if leaf.size >= (1 << 22):
            axes = set()
            for ax in tuple(spec):
                if isinstance(ax, tuple):
                    axes.update(ax)
                elif ax:
                    axes.add(ax)
            if "data" not in axes and "pod" not in axes:
                bad.append((path, leaf.shape, spec))

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), shapes, specs,
        is_leaf=lambda x: hasattr(x, "shape"),
    )
    assert not bad, bad


def test_quantize_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(4096), jnp.float32)
    q, s = quantize(x)
    err = float(jnp.abs(dequantize(q, s) - x).max())
    assert err <= float(s) * 0.51  # half-ulp of the int8 grid


def test_error_feedback_unbiased_over_time():
    """Accumulated EF output converges to the accumulated true gradient."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.standard_normal(512) * 1e-3, jnp.float32)
    errs = init_error_state({"g": g_true})
    total = jnp.zeros(512)
    for _ in range(64):
        out, errs = ef_compress_tree({"g": g_true}, errs)
        total = total + out["g"]
    rel = float(jnp.linalg.norm(total - 64 * g_true) / jnp.linalg.norm(64 * g_true))
    assert rel < 0.02, rel


_SUBPROCESS_COLLECTIVE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.distributed.compression import compressed_psum_mean
    mesh = jax.make_mesh((8,), ("dp",))
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 4096)), jnp.float32)
    f = shard_map(lambda x: compressed_psum_mean(x, "dp"),
                  mesh=mesh, in_specs=P("dp", None), out_specs=P("dp", None))
    y = f(x)
    ref = jnp.broadcast_to(x.mean(0, keepdims=True), x.shape)
    rel = float(jnp.abs(y - ref).max() / jnp.abs(ref).max())
    assert rel < 0.05, rel
    print("OK", rel)
    """
)


def test_compressed_ring_allreduce_8dev():
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_COLLECTIVE],
        capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd=__import__("os").path.dirname(__import__("os").path.dirname(__file__)),
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


_SUBPROCESS_SHARDED_TRAIN = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import ARCHS
    from repro.models.factory import reduced_config, make_smoke_batch
    from repro.models.transformer import init_params
    from repro.optim.adamw import AdamW
    from repro.train.train_step import init_train_state, make_train_step
    from repro.distributed.sharding import param_pspecs, make_shardings
    import dataclasses

    cfg = dataclasses.replace(reduced_config(ARCHS["llama3.2-1b"]), num_kv_heads=4)
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    opt = AdamW(learning_rate=1e-3)
    params = init_params(cfg, jax.random.key(0))
    state = init_train_state(cfg, params, opt, metric_window=8)
    batch = make_smoke_batch(cfg, jax.random.key(1), B=4, S=16)

    # sharded run on the 2x2 mesh
    with mesh:
        pspec = param_pspecs(cfg, jax.eval_shape(lambda: params), tp=2)
        sh_params = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, pspec)
        sh_state = dataclasses.replace(state, params=sh_params)
        step = jax.jit(make_train_step(cfg, opt))
        sh_state2, m_sharded = step(sh_state, batch)

    # single-device reference
    step1 = jax.jit(make_train_step(cfg, opt))
    state2, m_single = step1(state, batch)
    dl = abs(float(m_sharded["loss"]) - float(m_single["loss"]))
    dg = abs(float(m_sharded["grad_norm"]) - float(m_single["grad_norm"]))
    assert dl < 1e-3 and dg < 5e-2, (dl, dg)
    import numpy as np
    pa = jax.tree.leaves(sh_state2.params); pb = jax.tree.leaves(state2.params)
    err = max(float(jnp.abs(a - b).max()) for a, b in zip(pa, pb))
    assert err < 5e-2, err
    print("OK", dl, err)
    """
)


def test_sharded_train_step_matches_single_device():
    """DP×TP=2×2 sharded train step ≡ single-device step (loss/params)."""
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SHARDED_TRAIN],
        capture_output=True, text=True, timeout=600,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd=__import__("os").path.dirname(__import__("os").path.dirname(__file__)),
    )
    assert r.returncode == 0, (r.stdout[-800:], r.stderr[-2000:])
    assert "OK" in r.stdout


_SUBPROCESS_SHARDED_COUNTERS = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import monoids
    from repro.core.keyed import ShardedKeyedStore, shard_of_key
    from repro.obs.registry import MetricsRegistry

    mesh = jax.make_mesh((4,), ("data",))
    # slots_per_shard=4 with a 256-key universe: every shard is saturated,
    # evicting constantly and dropping rows whose chunk-local distinct-key
    # count overflows the tiny directory
    sh = ShardedKeyedStore(monoids.sum_monoid(jnp.int32), window=4,
                           slots_per_shard=4, mesh=mesh)
    state = sh.init_state()
    rng = np.random.default_rng(0)
    for _ in range(8):
        keys = jnp.asarray(rng.integers(0, 256, 64), jnp.int32)
        xs = jnp.ones(64, jnp.int32)
        state, ys, owner = sh.update_chunk(state, keys, xs)

    c = jax.device_get(sh.counters(state, per_shard=True))
    # the mesh-wide rollup must equal the per-shard sums, per counter
    for k in ("n_live", "n_evicted", "n_failed", "n_dropped"):
        assert int(c[k]) == int(np.sum(c["per_shard"][k])), (k, c)
    assert c["per_shard"]["n_live"].shape == (4,)
    assert int(c["n_live"]) == 16, c            # all 4x4 slots saturated
    assert int(c["n_evicted"]) > 0, c           # universe >> slots
    assert all(int(v) > 0 for v in c["per_shard"]["n_evicted"]), c

    # attach_obs: one scrape serves the rollup AND {shard="i"} series
    reg = MetricsRegistry()
    sh.attach_obs(reg, lambda: state)
    snap = reg.scrape()
    assert snap["repro_sharded_live_keys"] == 16, snap
    per = [snap['repro_sharded_evictions_total{shard="%d"}' % i]
           for i in range(4)]
    assert sum(per) == snap["repro_sharded_evictions_total"], (per, snap)
    print("OK", int(c["n_evicted"]), int(c["n_dropped"]))
    """
)


def test_sharded_keyed_counters_rollup_4dev():
    """Mesh-wide counter rollup over a 4-shard keyed store: the summed
    totals equal the per-shard values, and the obs collector exposes both
    (the pre-PR-8 blind spot: only shard-local scalars existed)."""
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SHARDED_COUNTERS],
        capture_output=True, text=True, timeout=600,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd=__import__("os").path.dirname(__import__("os").path.dirname(__file__)),
    )
    assert r.returncode == 0, (r.stdout[-800:], r.stderr[-2000:])
    assert "OK" in r.stdout
