"""FlatFIT (paper §7 comparison algorithm): correctness + amortized counts."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't break collection
from hypothesis import given, settings, strategies as st

from repro.core import ALGORITHMS, counting, flatfit, monoids

CAP = 32


def run_flatfit(m, ops, use_mut=True):
    state = flatfit.init(m, CAP)
    out = []
    for kind, val in ops:
        if kind == "i":
            if flatfit.size(state) >= CAP - 1:
                continue
            state = flatfit.insert(m, state, val)
        elif kind == "e":
            if flatfit.size(state) == 0:
                continue
            state = flatfit.evict(m, state)
        else:
            if use_mut:
                agg, state = flatfit.query_mut(m, state)
            else:
                agg = flatfit.query(m, state)
            out.append(np.asarray(m.lower(agg)))
    agg = flatfit.query(m, state)
    out.append(np.asarray(m.lower(agg)))
    return out


def run_oracle(m, ops):
    algo = ALGORITHMS["recalc"]
    s = algo.init(m, CAP)
    sz = 0
    out = []
    for kind, val in ops:
        if kind == "i":
            if sz >= CAP - 1:
                continue
            s = algo.insert(m, s, val)
            sz += 1
        elif kind == "e":
            if sz == 0:
                continue
            s = algo.evict(m, s)
            sz -= 1
        else:
            out.append(np.asarray(m.lower(algo.query(m, s))))
    out.append(np.asarray(m.lower(algo.query(m, s))))
    return out


@pytest.mark.parametrize("use_mut", [True, False], ids=["compressing", "pure"])
@settings(max_examples=25, deadline=None)
@given(ops=st.lists(
    st.tuples(st.sampled_from(["i", "i", "e", "q"]),
              st.tuples(st.integers(-99, 99), st.integers(-99, 99))),
    min_size=1, max_size=120))
def test_flatfit_matches_oracle(use_mut, ops):
    m = monoids.affine_int_monoid()
    assert all(
        np.array_equal(a, b)
        for a, b in zip(run_oracle(m, ops), run_flatfit(m, ops, use_mut))
    )


def test_flatfit_amortized_counts():
    """Insert/evict cost 0 ⊗; compressed queries amortize to O(1); repeated
    queries without interleaved ops cost exactly 1 re-walk of length ≤ 2."""
    m, ctr = counting(monoids.maxcount_monoid())
    state = flatfit.init(m, 256)
    r = np.random.default_rng(0)
    total, nq = 0, 0
    sz = 0
    worst = 0
    for i in range(2000):
        c = r.random()
        if sz == 0 or (c < 0.5 and sz < 200):
            state = flatfit.insert(m, state, float(r.integers(0, 9)))
            sz += 1
        elif c < 0.8:
            state = flatfit.evict(m, state)
            sz -= 1
        else:
            ctr.reset()
            _, state = flatfit.query_mut(m, state)
            total += ctr.count
            worst = max(worst, ctr.count)
            nq += 1
    assert nq > 100
    assert total / nq < 8.0  # amortized O(1)
    assert worst >= 10  # ...but worst-case O(n): the paper's contrast w/ DABA


def test_flatfit_compression_makes_requery_cheap():
    m, ctr = counting(monoids.sum_monoid())
    state = flatfit.init(m, 64)
    for i in range(40):
        state = flatfit.insert(m, state, float(i))
    ctr.reset()
    _, state = flatfit.query_mut(m, state)
    first = ctr.count
    ctr.reset()
    _, state = flatfit.query_mut(m, state)
    assert first >= 39  # full walk
    assert ctr.count <= 2  # compressed: single hop to tail
