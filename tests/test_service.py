"""Multi-tenant streaming analytics service (PR 10).

The contract under test:

  * **Bit-exact isolation**: a tenant's window folds served by the live
    service equal an offline :class:`repro.core.keyed.KeyedChunkedStream`
    replay of exactly that tenant's accepted rows — regardless of how the
    consumer interleaved other tenants' chunks, and even while a noisy
    neighbor is being throttled.
  * **Admission**: token-bucket quotas 429 with a ``Retry-After`` hint and
    touch nobody else's tokens; queue bounds and the global high-watermark
    503; malformed batches 400/413 without side effects on the engine.
  * **HTTP surface**: POST /ingest and GET /query,/stats,/healthz,/metrics
    over stdlib urllib against a live ephemeral-port server.
  * **Observability**: per-tenant labeled series appear in the Prometheus
    exposition and agree with the service's own counters.
"""

import json
import threading
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.keyed import KeyedChunkedStream
from repro.core.monoids import get_monoid
from repro.service import (
    AnalyticsService,
    ServiceConfig,
    ServiceHTTPServer,
    TokenBucket,
    validate_batch,
)

rng = np.random.default_rng(7)

CFG = dict(window=32, horizon=4.0, slots=128, chunk=128, max_batch=64,
           quota_rows_per_s=1e9, quota_burst=1e9, rollup=True,
           rollup_window=8, kll_k=16, kll_levels=4, hll_registers=16,
           topk_k=4, latency_ring=1024)


def _batches(n_batches, n=48, keys_hi=20, seed=0, t0=0.0):
    """Deterministic valid batches: non-decreasing ts across the list."""
    r = np.random.default_rng(seed)
    t = t0
    out = []
    for _ in range(n_batches):
        keys = r.integers(0, keys_hi, n)
        ts = np.sort(t + r.random(n) * 0.5)
        t = float(ts[-1])
        xs = r.integers(0, 100, n)
        out.append((keys, ts, xs))
    return out


def _offline_folds(cfg: ServiceConfig, batches, query_keys):
    """Oracle: replay accepted rows through a fresh KeyedChunkedStream
    (raw keys, same window/horizon) and query the same keys."""
    eng = KeyedChunkedStream(
        get_monoid(cfg.monoid), cfg.window, cfg.slots, cfg.chunk,
        horizon=cfg.horizon, donate=False,
    )
    state = eng.init_state()
    keys = np.concatenate([b[0] for b in batches]).astype(np.int32)
    ts = np.concatenate([b[1] for b in batches]).astype(np.float32)
    xs = np.concatenate([b[2] for b in batches]).astype(np.int32)
    state, _ = eng.stream(keys, xs, ts=ts, state=state)
    aggs, found = eng.query(state, jnp.asarray(query_keys, jnp.int32))
    return (np.asarray(eng.monoid.lower(aggs)), np.asarray(found))


# ---------------------------------------------------------------------------
# Admission primitives
# ---------------------------------------------------------------------------


def test_token_bucket_refill_and_retry_after():
    now = [0.0]
    b = TokenBucket(rate=10.0, burst=20.0, clock=lambda: now[0])
    ok, _ = b.try_take(20)
    assert ok
    ok, retry = b.try_take(5)
    assert not ok and retry == pytest.approx(0.5)
    now[0] += 0.5  # 5 tokens accrue
    ok, _ = b.try_take(5)
    assert ok
    assert b.tokens == pytest.approx(0.0)


def test_validate_batch_rejections():
    common = dict(max_batch=8, key_limit=16, last_ts=-np.inf,
                  value_dtype="i32")
    ok = lambda *a, **kw: validate_batch(*a, **{**common, **kw})
    assert ok([1], [0.0], [5])[0] is None
    assert ok([], [], [])[0] == 400                      # empty
    assert ok([1, 2], [0.0], [5, 6])[0] == 400           # ragged
    assert ok(list(range(9)), [0.0] * 9, [0] * 9)[0] == 413
    assert ok([16], [0.0], [1])[0] == 400                # key out of range
    assert ok([-1], [0.0], [1])[0] == 400
    assert ok([1], [np.inf], [1])[0] == 400              # non-finite ts
    assert ok([1, 2], [2.0, 1.0], [0, 0])[0] == 400      # decreasing ts
    assert ok([1], [0.5], [1], last_ts=1.0)[0] == 400    # behind watermark
    err, payload = ok([3], [1.5], [7])
    assert err is None
    k, t, x = payload
    assert k.dtype == np.int32 and t.dtype == np.float32
    assert x.dtype == np.int32


def test_config_validation():
    with pytest.raises(ValueError):
        ServiceConfig(key_bits=28, max_tenants=64)   # int32 overflow
    with pytest.raises(ValueError):
        ServiceConfig(max_batch=2048, chunk=1024)    # batch > chunk
    with pytest.raises(ValueError):
        ServiceConfig(value_dtype="f64")
    assert ServiceConfig(key_bits=20).key_limit == 1 << 20


# ---------------------------------------------------------------------------
# In-process service: correctness and isolation
# ---------------------------------------------------------------------------


def test_service_folds_match_offline_replay():
    """The tentpole bit-exactness claim, two interleaved tenants."""
    cfg = ServiceConfig(**CFG)
    qk = list(range(20))
    with AnalyticsService(cfg) as svc:
        ba = _batches(6, seed=1)
        bb = _batches(6, seed=2)
        for (ka, ta, xa), (kb, tb, xb) in zip(ba, bb):
            assert svc.ingest("a", ka, ta, xa)[0] == 200
            assert svc.ingest("b", kb, tb, xb)[0] == 200
        assert svc.flush()
        for name, batches in (("a", ba), ("b", bb)):
            code, snap = svc.query(name, keys=qk)
            assert code == 200
            vals, found = _offline_folds(cfg, batches, qk)
            for i, k in enumerate(qk):
                assert snap["keys"][str(k)]["found"] == bool(found[i])
                assert snap["keys"][str(k)]["fold"] == int(vals[i]), (name, k)


def test_quota_throttles_one_tenant_not_the_other():
    """Noisy neighbor 429s; the in-quota tenant's folds stay bit-exact."""
    # every tenant gets the same bucket (rate 1 row/s, burst 150 rows):
    # "good" stays inside the burst (3×48=144 rows), "noisy" blows through
    # it (5×48=240 rows → first 3 batches accepted, then 429s)
    cfg = ServiceConfig(**{**CFG, "quota_rows_per_s": 1.0,
                           "quota_burst": 150.0})
    qk = list(range(20))
    with AnalyticsService(cfg) as svc:
        good = _batches(3, n=48, seed=3)
        noisy = _batches(5, n=48, seed=4)
        codes = []
        for i, (kn, tn, xn) in enumerate(noisy):
            codes.append(svc.ingest("noisy", kn, tn, xn)[0])
            if i < len(good):
                assert svc.ingest("good", *good[i])[0] == 200
        assert codes.count(200) == 3 and codes.count(429) == 2
        assert svc.flush()
        _, snap_n = svc.query("noisy")
        assert snap_n["counters"]["throttled_rows"] == 2 * 48
        assert snap_n["counters"]["throttled_batches"] == 2
        # the good tenant never throttled, and its outputs are the offline
        # replay of its accepted rows — unaffected by the neighbor's 429s
        code, snap = svc.query("good", keys=qk)
        assert snap["counters"]["throttled_rows"] == 0
        assert snap["counters"]["ingested_rows"] == 3 * 48
        vals, found = _offline_folds(cfg, good, qk)
        for i, k in enumerate(qk):
            assert snap["keys"][str(k)]["found"] == bool(found[i])
            assert snap["keys"][str(k)]["fold"] == int(vals[i])


def test_retry_after_header_and_recovery():
    cfg = ServiceConfig(**{**CFG, "quota_rows_per_s": 1000.0,
                           "quota_burst": 10.0})
    with AnalyticsService(cfg) as svc:
        k, t, x = np.asarray([1] * 10), np.linspace(0, 1, 10), np.ones(10)
        assert svc.ingest("a", k, t, x)[0] == 200
        code, payload, hdrs = svc.ingest("a", k, t + 2, x)
        assert code == 429
        assert float(hdrs["Retry-After"]) >= 0
        assert payload["retry_after"] > 0


def test_backpressure_sheds_when_consumer_stalled():
    """With the consumer not running, bounded queues must 503, not grow."""
    cfg = ServiceConfig(**{**CFG, "tenant_queue_batches": 2,
                           "global_rows_hw": 10_000})
    svc = AnalyticsService(cfg)  # .start() never called: queues only fill
    batches = _batches(4, n=16, seed=5)
    codes = [svc.ingest("a", *b)[0] for b in batches]
    assert codes == [200, 200, 503, 503]
    assert svc._tenants["a"].shed == 2 * 16
    # global high-watermark trips even with queue room
    cfg2 = ServiceConfig(**{**CFG, "tenant_queue_batches": 100,
                            "global_rows_hw": 40})
    svc2 = AnalyticsService(cfg2)
    codes = [svc2.ingest("a", *b)[0] for b in _batches(4, n=16, seed=6)]
    assert codes == [200, 200, 503, 503]


def test_malformed_and_unknown():
    cfg = ServiceConfig(**CFG)
    with AnalyticsService(cfg) as svc:
        code, payload, _ = svc.ingest("a", [1, 2], [0.0], [1])
        assert code == 400
        code, _, _ = svc.ingest("a", [1], [1.0], [1])
        assert code == 200
        code, payload, _ = svc.ingest("a", [1], [0.5], [1])  # behind watermark
        assert code == 400
        assert svc.query("nope")[0] == 404
        svc.flush()
        assert svc.query("a", keys=[1 << 25])[0] == 400  # out of key space


def test_tenant_capacity():
    cfg = ServiceConfig(**{**CFG, "max_tenants": 2})
    with AnalyticsService(cfg) as svc:
        assert svc.ingest("a", [1], [0.0], [1])[0] == 200
        assert svc.ingest("b", [1], [0.0], [1])[0] == 200
        assert svc.ingest("c", [1], [0.0], [1])[0] == 503


def test_rollup_sketches_in_query():
    cfg = ServiceConfig(**CFG)
    with AnalyticsService(cfg) as svc:
        r = np.random.default_rng(0)
        # heavy key 3: half of all rows
        keys = np.where(r.random(64 * 4) < 0.5, 3, r.integers(0, 16, 64 * 4))
        ts = np.sort(r.random(64 * 4))
        xs = np.full(64 * 4, 7)
        for i in range(4):
            sl = slice(64 * i, 64 * (i + 1))
            assert svc.ingest("a", keys[sl], ts[sl], xs[sl])[0] == 200
        assert svc.flush()
        _, snap = svc.query("a", top=3)
        assert snap["hot_keys"][0][0] == 3           # heavy hitter surfaced
        assert snap["value_quantiles"]["p50"] == 7.0  # constant values
        assert 4 <= snap["distinct_keys_est"] <= 64   # coarse 16-reg sketch
        assert snap["live_keys"] >= 10
        # default key set = hottest keys
        assert str(3) in snap["keys"]


def test_stats_latency_percentiles():
    cfg = ServiceConfig(**CFG)
    with AnalyticsService(cfg) as svc:
        for b in _batches(3, seed=8):
            assert svc.ingest("a", *b)[0] == 200
        assert svc.flush()
        s = svc.stats()
        assert s["drained_rows"] == 3 * 48
        lat = s["ingest_to_queryable"]
        assert lat["count"] == 3
        assert 0 < lat["p50_ms"] <= lat["p99_ms"] <= lat["max_ms"]


# ---------------------------------------------------------------------------
# HTTP surface (live ephemeral-port server, stdlib client)
# ---------------------------------------------------------------------------


def _post(url, doc):
    req = urllib.request.Request(
        url, json.dumps(doc).encode(), {"Content-Type": "application/json"}
    )
    try:
        with urllib.request.urlopen(req, timeout=30) as r:
            return r.status, json.loads(r.read()), dict(r.headers)
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read() or b"{}"), dict(e.headers)


def _get(url):
    try:
        with urllib.request.urlopen(url, timeout=30) as r:
            return r.status, r.read().decode()
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode()


def test_http_end_to_end():
    cfg = ServiceConfig(**CFG)
    svc = AnalyticsService(cfg)
    svc.attach_obs(__import__("repro.obs.registry", fromlist=["x"])
                   .MetricsRegistry())
    with ServiceHTTPServer(svc) as srv:
        assert _get(f"{srv.url}/healthz")[1] == "ok\n"
        batches = _batches(4, seed=9)
        for k, t, x in batches:
            code, payload, _ = _post(f"{srv.url}/ingest", {
                "tenant": "web", "keys": k.tolist(), "ts": t.tolist(),
                "values": x.tolist(),
            })
            assert code == 200 and payload["accepted"] == 48
        assert svc.flush()
        code, body = _get(f"{srv.url}/query?tenant=web&keys=0,1,2&top=4")
        assert code == 200
        snap = json.loads(body)
        vals, found = _offline_folds(cfg, batches, [0, 1, 2])
        for i, k in enumerate([0, 1, 2]):
            assert snap["keys"][str(k)]["fold"] == int(vals[i])
        assert len(snap["hot_keys"]) <= 4
        # stats + malformed + unknown routes
        stats = json.loads(_get(f"{srv.url}/stats")[1])
        assert stats["per_tenant"]["web"]["ingested_rows"] == 4 * 48
        assert _post(f"{srv.url}/ingest", {"tenant": "web"})[0] == 400
        assert _get(f"{srv.url}/nope")[0] == 404
        assert _get(f"{srv.url}/query")[0] == 400
        # /metrics carries per-tenant labeled series matching counters
        code, text = _get(f"{srv.url}/metrics")
        assert code == 200
        line = [l for l in text.splitlines()
                if l.startswith('repro_service_ingested_rows_total{tenant="web"}')]
        assert line and float(line[0].split()[-1]) == 4 * 48
        assert "repro_service_ingest_to_queryable_seconds" in text
        line = [l for l in text.splitlines()
                if l.startswith("repro_service_store_live_keys ")]
        assert line and float(line[0].split()[-1]) > 0  # store health rides along
    assert svc._thread is None  # server owned the service lifecycle


def test_http_429_surfaces_retry_after():
    cfg = ServiceConfig(**{**CFG, "quota_rows_per_s": 1.0,
                           "quota_burst": 20.0})
    svc = AnalyticsService(cfg)
    with ServiceHTTPServer(svc) as srv:
        doc = {"tenant": "t", "keys": [1] * 16,
               "ts": list(np.linspace(0, 1, 16)), "values": [1] * 16}
        assert _post(f"{srv.url}/ingest", doc)[0] == 200
        doc["ts"] = list(np.linspace(2, 3, 16))
        code, payload, hdrs = _post(f"{srv.url}/ingest", doc)
        assert code == 429
        assert int(hdrs["Retry-After"]) >= 1


def test_http_concurrent_ingest_two_tenants():
    """Parallel handler threads → consistent accounting, no lost rows."""
    cfg = ServiceConfig(**CFG)
    with AnalyticsService(cfg) as svc, ServiceHTTPServer(svc) as srv:
        errs = []

        def pump(tenant, seed):
            try:
                for k, t, x in _batches(6, n=32, seed=seed):
                    code, _, _ = _post(f"{srv.url}/ingest", {
                        "tenant": tenant, "keys": k.tolist(),
                        "ts": t.tolist(), "values": x.tolist(),
                    })
                    assert code == 200
            except Exception as e:  # pragma: no cover
                errs.append(e)

        threads = [threading.Thread(target=pump, args=(f"t{i}", 10 + i))
                   for i in range(3)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert not errs
        assert svc.flush()
        stats = svc.stats()
        assert stats["drained_rows"] == 3 * 6 * 32
        for i in range(3):
            assert stats["per_tenant"][f"t{i}"]["queryable_rows"] == 6 * 32
