"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.local_attention.ops import local_attention
from repro.kernels.seg_scan.ops import seg_prefix_scan_op, seg_suffix_scan_op
from repro.kernels.seg_scan.ref import seg_prefix_scan_ref, seg_suffix_scan_ref
from repro.kernels.sliding_window.ops import sliding_window_agg
from repro.kernels.sliding_window.ref import sliding_window_ref
from repro.kernels.suffix_scan.ops import suffix_scan
from repro.kernels.suffix_scan.ref import suffix_scan_ref

rng = np.random.default_rng(0)

SWEEP = [(4, 64, 8), (3, 100, 7), (1, 17, 17), (5, 33, 5), (2, 256, 64), (2, 80, 2)]


@pytest.mark.parametrize("op", ["sum", "prod", "max", "min", "logsumexp"])
@pytest.mark.parametrize("B,T,w", SWEEP)
def test_sliding_window_f32(op, B, T, w):
    x = jnp.asarray(rng.standard_normal((B, T)), jnp.float32)
    y = sliding_window_agg(x, w, op)
    yr = sliding_window_ref(x, window=w, op=op)
    assert float(jnp.abs(y - yr).max()) < 3e-5


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.int32])
def test_sliding_window_dtypes(dtype):
    if dtype == jnp.int32:
        x = jnp.asarray(rng.integers(0, 10, (4, 50)), dtype)
    else:
        x = jnp.asarray(rng.standard_normal((4, 64)), dtype)
    for op in ["sum", "max"]:
        y = sliding_window_agg(x, 6, op).astype(jnp.float32)
        yr = sliding_window_ref(x, window=6, op=op).astype(jnp.float32)
        if dtype == jnp.int32 or op == "max":
            assert jnp.array_equal(y, yr), (dtype, op)
        else:  # bf16 sum: combine-order rounding differs (scan vs shifts)
            assert float(jnp.abs(y - yr).max()) < 0.15, (dtype, op)


def test_sliding_window_nd_input():
    x = jnp.asarray(rng.standard_normal((2, 3, 40)), jnp.float32)
    y = sliding_window_agg(x, 5, "max")
    yr = sliding_window_ref(x.reshape(6, 40), window=5, op="max").reshape(2, 3, 40)
    assert jnp.array_equal(y, yr)


@pytest.mark.parametrize("op", ["sum", "prod", "max", "logsumexp"])
@pytest.mark.parametrize("B,T,bt", [(4, 64, 16), (3, 100, 32), (1, 7, 256), (5, 513, 64)])
def test_suffix_scan(op, B, T, bt):
    x = jnp.asarray(rng.standard_normal((B, T)), jnp.float32)
    y = suffix_scan(x, op, block_t=bt)
    yr = suffix_scan_ref(x, op=op)
    assert float(jnp.abs(y - yr).max()) < 5e-5


SEG_LAYOUTS = ["random", "single", "singleton", "giant"]


def _seg_flags(layout, B, T):
    if layout == "random":
        return jnp.asarray(rng.random((B, T)) < 0.2)
    if layout == "single":  # one segment per row, closed at the end
        return jnp.zeros((B, T), bool).at[:, -1].set(True)
    if layout == "singleton":  # every element its own segment
        return jnp.ones((B, T), bool)
    return jnp.zeros((B, T), bool)  # giant: one never-closing segment


@pytest.mark.parametrize("op", ["sum", "prod", "max", "logsumexp"])
@pytest.mark.parametrize("layout", SEG_LAYOUTS)
@pytest.mark.parametrize("B,T,bt", [(4, 64, 16), (3, 100, 32), (1, 7, 256)])
def test_seg_suffix_scan_vs_ref(op, layout, B, T, bt):
    x = jnp.asarray(rng.standard_normal((B, T)), jnp.float32)
    f = _seg_flags(layout, B, T)
    y = seg_suffix_scan_op(x, f, op, block_t=bt)
    yr = seg_suffix_scan_ref(x, f, op=op)
    assert float(jnp.abs(y - yr).max()) < 5e-5


@pytest.mark.parametrize("op", ["sum", "max"])
@pytest.mark.parametrize("layout", SEG_LAYOUTS)
def test_seg_suffix_scan_vs_lax_fallback(op, layout):
    """Kernel ≡ the production associative_scan path of core.keyed."""
    from repro.core import monoids
    from repro.core.keyed import seg_suffix_scan

    m = {"sum": monoids.sum_monoid, "max": monoids.max_monoid}[op]()
    B, T = 3, 129
    x = jnp.asarray(rng.standard_normal((B, T)), jnp.float32)
    f = _seg_flags(layout, B, T)
    y = seg_suffix_scan_op(x, f, op, block_t=32)
    yl = jax.vmap(lambda xi, fi: seg_suffix_scan(m, fi, xi))(x, f)
    assert float(jnp.abs(y - yl).max()) < 5e-5


def test_seg_suffix_scan_int_exact():
    x = jnp.asarray(rng.integers(-9, 10, (2, 75)), jnp.int32)
    f = _seg_flags("random", 2, 75)
    y = seg_suffix_scan_op(x, f, "sum", block_t=16)
    yr = seg_suffix_scan_ref(x, f, op="sum")
    assert jnp.array_equal(y, yr)


def test_seg_suffix_scan_all_ends_is_identity_map():
    """Every element its own segment → the scan is the input itself."""
    x = jnp.asarray(rng.standard_normal((2, 40)), jnp.float32)
    y = seg_suffix_scan_op(x, jnp.ones((2, 40), bool), "sum")
    assert jnp.array_equal(y, x)


def test_seg_suffix_scan_no_ends_is_plain_suffix_scan():
    """One never-closing segment → coincides with the unsegmented kernel."""
    x = jnp.asarray(rng.standard_normal((2, 100)), jnp.float32)
    y = seg_suffix_scan_op(x, jnp.zeros((2, 100), bool), "sum", block_t=32)
    yu = suffix_scan(x, "sum", block_t=32)
    assert float(jnp.abs(y - yu).max()) < 5e-5


@pytest.mark.parametrize("op", ["sum", "prod", "max", "logsumexp"])
@pytest.mark.parametrize("layout", SEG_LAYOUTS)
@pytest.mark.parametrize("B,T,bt", [(4, 64, 16), (3, 100, 32), (1, 7, 256)])
def test_seg_prefix_scan_vs_ref(op, layout, B, T, bt):
    x = jnp.asarray(rng.standard_normal((B, T)), jnp.float32)
    f = _seg_flags(layout, B, T)  # reused as segment-START flags here
    y = seg_prefix_scan_op(x, f, op, block_t=bt)
    yr = seg_prefix_scan_ref(x, f, op=op)
    assert float(jnp.abs(y - yr).max()) < 5e-5


@pytest.mark.parametrize("op", ["sum", "max"])
@pytest.mark.parametrize("layout", SEG_LAYOUTS)
def test_seg_prefix_scan_vs_lax_fallback(op, layout):
    """Kernel ≡ the production associative_scan path of core.event_time."""
    from repro.core import monoids
    from repro.core.event_time import seg_prefix_scan

    m = {"sum": monoids.sum_monoid, "max": monoids.max_monoid}[op]()
    B, T = 3, 129
    x = jnp.asarray(rng.standard_normal((B, T)), jnp.float32)
    f = _seg_flags(layout, B, T)
    y = seg_prefix_scan_op(x, f, op, block_t=32)
    yl = jax.vmap(lambda xi, fi: seg_prefix_scan(m, fi, xi))(x, f)
    assert float(jnp.abs(y - yl).max()) < 5e-5


def test_seg_prefix_scan_int_exact():
    x = jnp.asarray(rng.integers(-9, 10, (2, 75)), jnp.int32)
    f = _seg_flags("random", 2, 75)
    y = seg_prefix_scan_op(x, f, "sum", block_t=16)
    yr = seg_prefix_scan_ref(x, f, op="sum")
    assert jnp.array_equal(y, yr)


def test_seg_prefix_scan_all_starts_is_identity_map():
    """Every element starts its own segment → the scan is the input itself."""
    x = jnp.asarray(rng.standard_normal((2, 40)), jnp.float32)
    y = seg_prefix_scan_op(x, jnp.ones((2, 40), bool), "sum")
    assert jnp.array_equal(y, x)


def test_seg_prefix_scan_no_starts_is_plain_prefix_scan():
    """No resets → coincides with the plain cumulative scan."""
    x = jnp.asarray(rng.standard_normal((2, 100)), jnp.float32)
    y = seg_prefix_scan_op(x, jnp.zeros((2, 100), bool), "sum", block_t=32)
    assert float(jnp.abs(y - jnp.cumsum(x, axis=1)).max()) < 5e-5


def test_suffix_scan_is_the_flip():
    """The kernel computes exactly Two-Stacks-Lite's flip invariant:
    deque[i] ← v_i ⊗ … ⊗ v_{n-1}."""
    from repro.core import monoids, two_stacks_lite as tsl

    m = monoids.sum_monoid()
    st = tsl.init(m, 16)
    vals = [1.0, 2.0, 3.0, 4.0, 5.0]
    for v in vals:
        st = tsl.insert(m, st, v)
    st = tsl.evict(m, st)  # forces the flip
    flipped = np.asarray(st.deque[1:5])  # after popFront
    kernel = np.asarray(suffix_scan(jnp.asarray([vals]), "sum"))[0]
    assert np.allclose(flipped, kernel[1:5])


@pytest.mark.parametrize(
    "B,Hq,Hkv,T,D,W,cap,blk",
    [
        (2, 4, 2, 64, 16, 16, 0.0, 16),
        (1, 2, 1, 100, 32, 24, 30.0, 32),
        (2, 2, 2, 37, 8, 8, 0.0, 16),
        (1, 4, 1, 128, 64, 128, 0.0, 32),  # window == T: full causal
        (1, 2, 2, 48, 16, 1000, 0.0, 16),  # window > T
    ],
)
def test_local_attention(B, Hq, Hkv, T, D, W, cap, blk):
    q = jnp.asarray(rng.standard_normal((B, Hq, T, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, T, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, T, D)), jnp.float32)
    o = local_attention(q, k, v, W, softcap=cap, block_q=blk, block_k=blk)
    o_ref = local_attention(q, k, v, W, softcap=cap, use_kernel=False)
    assert float(jnp.abs(o - o_ref).max()) < 3e-5


def test_local_attention_matches_model_blocked_attention():
    """Kernel ≡ the model's jnp blocked attention (the TPU/CPU pair)."""
    from repro.models.attention import blocked_attention

    B, H, T, D, W = 1, 2, 64, 16, 16
    q = jnp.asarray(rng.standard_normal((B, H, T, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, T, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, T, D)), jnp.float32)
    o_kernel = local_attention(q, k, v, W, block_q=16, block_k=16)
    o_model = blocked_attention(q, k, v, causal=True, window=W, q_chunk=16)
    assert float(jnp.abs(o_kernel - o_model).max()) < 3e-5
