"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.local_attention.ops import local_attention
from repro.kernels.sliding_window.ops import sliding_window_agg
from repro.kernels.sliding_window.ref import sliding_window_ref
from repro.kernels.suffix_scan.ops import suffix_scan
from repro.kernels.suffix_scan.ref import suffix_scan_ref

rng = np.random.default_rng(0)

SWEEP = [(4, 64, 8), (3, 100, 7), (1, 17, 17), (5, 33, 5), (2, 256, 64), (2, 80, 2)]


@pytest.mark.parametrize("op", ["sum", "prod", "max", "min", "logsumexp"])
@pytest.mark.parametrize("B,T,w", SWEEP)
def test_sliding_window_f32(op, B, T, w):
    x = jnp.asarray(rng.standard_normal((B, T)), jnp.float32)
    y = sliding_window_agg(x, w, op)
    yr = sliding_window_ref(x, window=w, op=op)
    assert float(jnp.abs(y - yr).max()) < 3e-5


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.int32])
def test_sliding_window_dtypes(dtype):
    if dtype == jnp.int32:
        x = jnp.asarray(rng.integers(0, 10, (4, 50)), dtype)
    else:
        x = jnp.asarray(rng.standard_normal((4, 64)), dtype)
    for op in ["sum", "max"]:
        y = sliding_window_agg(x, 6, op).astype(jnp.float32)
        yr = sliding_window_ref(x, window=6, op=op).astype(jnp.float32)
        if dtype == jnp.int32 or op == "max":
            assert jnp.array_equal(y, yr), (dtype, op)
        else:  # bf16 sum: combine-order rounding differs (scan vs shifts)
            assert float(jnp.abs(y - yr).max()) < 0.15, (dtype, op)


def test_sliding_window_nd_input():
    x = jnp.asarray(rng.standard_normal((2, 3, 40)), jnp.float32)
    y = sliding_window_agg(x, 5, "max")
    yr = sliding_window_ref(x.reshape(6, 40), window=5, op="max").reshape(2, 3, 40)
    assert jnp.array_equal(y, yr)


@pytest.mark.parametrize("op", ["sum", "prod", "max", "logsumexp"])
@pytest.mark.parametrize("B,T,bt", [(4, 64, 16), (3, 100, 32), (1, 7, 256), (5, 513, 64)])
def test_suffix_scan(op, B, T, bt):
    x = jnp.asarray(rng.standard_normal((B, T)), jnp.float32)
    y = suffix_scan(x, op, block_t=bt)
    yr = suffix_scan_ref(x, op=op)
    assert float(jnp.abs(y - yr).max()) < 5e-5


def test_suffix_scan_is_the_flip():
    """The kernel computes exactly Two-Stacks-Lite's flip invariant:
    deque[i] ← v_i ⊗ … ⊗ v_{n-1}."""
    from repro.core import monoids, two_stacks_lite as tsl

    m = monoids.sum_monoid()
    st = tsl.init(m, 16)
    vals = [1.0, 2.0, 3.0, 4.0, 5.0]
    for v in vals:
        st = tsl.insert(m, st, v)
    st = tsl.evict(m, st)  # forces the flip
    flipped = np.asarray(st.deque[1:5])  # after popFront
    kernel = np.asarray(suffix_scan(jnp.asarray([vals]), "sum"))[0]
    assert np.allclose(flipped, kernel[1:5])


@pytest.mark.parametrize(
    "B,Hq,Hkv,T,D,W,cap,blk",
    [
        (2, 4, 2, 64, 16, 16, 0.0, 16),
        (1, 2, 1, 100, 32, 24, 30.0, 32),
        (2, 2, 2, 37, 8, 8, 0.0, 16),
        (1, 4, 1, 128, 64, 128, 0.0, 32),  # window == T: full causal
        (1, 2, 2, 48, 16, 1000, 0.0, 16),  # window > T
    ],
)
def test_local_attention(B, Hq, Hkv, T, D, W, cap, blk):
    q = jnp.asarray(rng.standard_normal((B, Hq, T, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, T, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, T, D)), jnp.float32)
    o = local_attention(q, k, v, W, softcap=cap, block_q=blk, block_k=blk)
    o_ref = local_attention(q, k, v, W, softcap=cap, use_kernel=False)
    assert float(jnp.abs(o - o_ref).max()) < 3e-5


def test_local_attention_matches_model_blocked_attention():
    """Kernel ≡ the model's jnp blocked attention (the TPU/CPU pair)."""
    from repro.models.attention import blocked_attention

    B, H, T, D, W = 1, 2, 64, 16, 16
    q = jnp.asarray(rng.standard_normal((B, H, T, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, T, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, T, D)), jnp.float32)
    o_kernel = local_attention(q, k, v, W, block_q=16, block_k=16)
    o_model = blocked_attention(q, k, v, causal=True, window=W, q_chunk=16)
    assert float(jnp.abs(o_kernel - o_model).max()) < 3e-5
