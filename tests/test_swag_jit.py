"""jit / vmap / scan compatibility: traced execution ≡ eager execution."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ALGORITHMS, GENERAL_ALGORITHMS, monoids
from repro.core.batched import BatchedSWAG


@pytest.mark.parametrize("algo_name", sorted(GENERAL_ALGORITHMS))
def test_jit_matches_eager(algo_name):
    algo = ALGORITHMS[algo_name]
    m = monoids.maxcount_monoid()
    ins = jax.jit(lambda s, v: algo.insert(m, s, v))
    evi = jax.jit(lambda s: algo.evict(m, s))
    qry = jax.jit(lambda s: algo.query(m, s))
    s_j, s_e = algo.init(m, 16), algo.init(m, 16)
    r = np.random.default_rng(0)
    sz = 0
    for _ in range(120):
        c = r.random()
        if sz == 0 or (c < 0.55 and sz < 12):
            v = jnp.float32(r.integers(0, 6))
            s_j, s_e = ins(s_j, v), algo.insert(m, s_e, v)
            sz += 1
        else:
            s_j, s_e = evi(s_j), algo.evict(m, s_e)
            sz -= 1
        qj, qe = qry(s_j), algo.query(m, s_e)
        assert float(qj["m"]) == float(qe["m"])
        assert int(qj["c"]) == int(qe["c"])


@pytest.mark.parametrize("algo_name", ["daba", "daba_lite", "two_stacks_lite"])
def test_scan_sliding_window(algo_name):
    """lax.scan count-based sliding window ≡ numpy oracle."""
    algo = ALGORITHMS[algo_name]
    m = monoids.max_monoid()
    W = 8

    def step(st, x):
        st = algo.insert(m, st, x)
        st = jax.lax.cond(
            algo.size(st) > W, lambda s: algo.evict(m, s), lambda s: s, st
        )
        return st, algo.query(m, st)

    xs = jnp.asarray(np.random.default_rng(3).standard_normal(150), jnp.float32)
    _, ys = jax.lax.scan(step, algo.init(m, W + 4), xs)
    ref = np.array(
        [np.asarray(xs)[max(0, t - W + 1): t + 1].max() for t in range(150)],
        np.float32,
    )
    assert np.array_equal(np.asarray(ys), ref)


@pytest.mark.parametrize("algo_name", ["daba_lite", "daba", "two_stacks"])
def test_batched_swag(algo_name):
    b = BatchedSWAG(ALGORITHMS[algo_name], monoids.sum_monoid(), 16)
    st = b.init(5)
    xs = jnp.asarray(
        np.random.default_rng(1).standard_normal((40, 5)), jnp.float32
    )
    st, ys = jax.jit(lambda st, xs: b.stream(st, xs, 6))(st, xs)
    x = np.asarray(xs)
    ref = np.stack(
        [[x[max(0, t - 5): t + 1, l].sum() for l in range(5)] for t in range(40)]
    )
    assert np.allclose(np.asarray(ys), ref, atol=1e-4)


def test_batched_ragged_lanes():
    """Masked per-lane step: lanes slide at different phases."""
    b = BatchedSWAG(ALGORITHMS["daba_lite"], monoids.sum_monoid(), 16)
    st = b.init(3)
    vals = jnp.asarray([1.0, 10.0, 100.0])
    st = b.insert(st, vals)
    st = b.insert(st, vals)
    # evict only lane 1
    st = b.step(st, vals, jnp.array([False, False, False]),
                jnp.array([False, True, False]))
    q = np.asarray(b.query(st))
    assert np.allclose(q, [2.0, 10.0, 200.0])
    assert list(np.asarray(b.size(st))) == [2, 1, 2]


def test_pointer_rebase_long_stream():
    """Ring pointers survive many wraps (logical pointers are monotone)."""
    algo = ALGORITHMS["daba_lite"]
    m = monoids.sum_monoid(jnp.int32)

    def step(st, x):
        st = algo.insert(m, st, x)
        st = jax.lax.cond(
            algo.size(st) > 4, lambda s: algo.evict(m, s), lambda s: s, st
        )
        return st, algo.query(m, st)

    xs = jnp.ones((5000,), jnp.int32)
    _, ys = jax.lax.scan(step, algo.init(m, 8), xs)
    assert int(ys[-1]) == 4
