"""Warm-state carry protocol: chunked-from-warm ≡ per-element scan.

Covers the PR-2 tentpole:
  * ``state_to_carry`` specializations vs the generic evict/query oracle,
    for every algorithm × int/float/pytree/non-commutative monoids;
  * carry → state → carry round trips (exact for integer monoids) and live
    continuation of reconstructed states;
  * ``state_from_chunk`` (the vectorized final-state rebuild) vs bulk insert;
  * ``BatchedSWAG.stream`` warm routing: chunked ≡ per-element from live
    (and ragged per-lane) windows, across ragged chunk splits, both the
    Pallas-kernel path (scalar ops) and the generic pytree path;
  * the ragged-last-chunk identity padding reuses one compilation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ALGORITHMS, GENERAL_ALGORITHMS, monoids, swag_base
from repro.core.batched import BatchedSWAG
from repro.core.chunked import ChunkedStream

rng = np.random.default_rng(1)


def _scalar_vals(shape, dtype=jnp.float32):
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.asarray(rng.integers(-9, 9, shape), dtype)
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _affine_vals(shape, dtype=jnp.int32):
    return (
        jnp.asarray(rng.integers(-5, 5, shape), dtype),
        jnp.asarray(rng.integers(-5, 5, shape), dtype),
    )


# Spans the algebraic classes: commutative+invertible scalar (kernel path,
# exact int), commutative invertible pytree, and two NON-commutative
# NON-invertible monoids (one exact-integer, one float).
MONOID_CASES = {
    "sum_i32": (monoids.sum_monoid(jnp.int32),
                lambda s: _scalar_vals(s, jnp.int32), True),
    "mean": (monoids.mean_monoid(), _scalar_vals, False),
    "affine_i32": (monoids.affine_int_monoid(), _affine_vals, True),
    "m4": (monoids.m4_monoid(), _scalar_vals, False),
}


def _assert_tree_close(a, b, exact, ctx=""):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        x, y = np.asarray(x), np.asarray(y)
        if exact:
            assert np.array_equal(x, y), (ctx, x, y)
        else:
            assert np.allclose(x, y, rtol=1e-4, atol=1e-4), (ctx, x, y)


def _warm_single(algo, m, mk, n_ops, window, cap=64):
    """A live single-lane state after n_ops slides, plus the values seen."""
    vals = mk((n_ops,)) if n_ops else mk((1,))
    st = algo.init(m, cap)
    for i in range(n_ops):
        st = algo.insert(m, st, swag_base.tree_index(vals, i))
        if int(algo.size(st)) > window:
            st = algo.evict(m, st)
    return st, vals


# ---------------------------------------------------------------------------
# state_to_carry: specialization vs generic oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mname", sorted(MONOID_CASES))
@pytest.mark.parametrize("algo_name", sorted(ALGORITHMS))
def test_state_to_carry_matches_generic_oracle(algo_name, mname):
    m, mk, exact = MONOID_CASES[mname]
    if algo_name == "soe" and not m.invertible:
        pytest.skip("subtract-on-evict needs an invertible monoid")
    algo = ALGORITHMS[algo_name]
    for n_ops, window in [(0, 8), (3, 8), (8, 8), (25, 8), (13, 4), (5, 16)]:
        st, _ = _warm_single(algo, m, mk, n_ops, window)
        carry_s = algo.state_to_carry(m, st, window)
        carry_g = swag_base.generic_state_to_carry(algo, m, st, window)
        _assert_tree_close(carry_s, carry_g, exact, (algo_name, mname, n_ops, window))


# ---------------------------------------------------------------------------
# carry_to_state: round trip + live continuation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mname", sorted(MONOID_CASES))
@pytest.mark.parametrize("algo_name", sorted(ALGORITHMS))
def test_carry_round_trip_and_continuation(algo_name, mname):
    m, mk, exact = MONOID_CASES[mname]
    if algo_name == "soe" and not m.invertible:
        pytest.skip("subtract-on-evict needs an invertible monoid")
    algo = ALGORITHMS[algo_name]
    window, n_ops = 8, 20
    st, vals = _warm_single(algo, m, mk, n_ops, window)
    carry = swag_base.state_to_carry(algo, m, st, window)
    if algo_name == "recalc" and not (m.invertible and m.commutative):
        with pytest.raises(NotImplementedError):
            swag_base.carry_to_state(algo, m, carry, 64)
        return
    st2 = swag_base.carry_to_state(algo, m, carry, 64)
    # carry -> state -> carry is exact (same suffix folds)
    carry2 = swag_base.state_to_carry(algo, m, st2, window)
    _assert_tree_close(carry, carry2, exact, (algo_name, mname, "roundtrip"))
    # the reconstructed state keeps behaving like a per-element state seeded
    # with the same last window-1 elements the carry represents
    h = window - 1
    ref = algo.init(m, 64)
    for i in range(n_ops - h, n_ops):
        ref = algo.insert(m, ref, swag_base.tree_index(vals, i))
    assert int(algo.size(st2)) == int(algo.size(ref)) == h
    for step in range(h - 1):
        _assert_tree_close(
            m.lower(algo.query(m, st2)), m.lower(algo.query(m, ref)),
            exact, (algo_name, mname, "evict", step),
        )
        st2, ref = algo.evict(m, st2), algo.evict(m, ref)
    more = mk((4,))
    for i in range(4):
        v = swag_base.tree_index(more, i)
        st2, ref = algo.insert(m, st2, v), algo.insert(m, ref, v)
        _assert_tree_close(
            m.lower(algo.query(m, st2)), m.lower(algo.query(m, ref)),
            exact, (algo_name, mname, "insert", i),
        )


# ---------------------------------------------------------------------------
# FlatFIT (eager, outside ALGORITHMS) conforms to the carry protocol
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mname", sorted(MONOID_CASES))
def test_flatfit_state_to_carry_matches_history_truth(mname):
    """Carry extracted from a (compressed) FlatFIT buffer equals the suffix
    folds computed directly from the value history, and leaves the source
    state untouched (the sweep runs on a copy)."""
    from repro.core import flatfit

    m, mk, exact = MONOID_CASES[mname]
    window = 8
    st = flatfit.init(m, 64)
    hist: list = []
    vals = mk((25,))
    for i in range(25):
        st = flatfit.insert(m, st, swag_base.tree_index(vals, i))
        hist.append(i)
        if flatfit.size(st) > window:
            st = flatfit.evict(m, st)
            hist.pop(0)
        if i % 5 == 0:
            flatfit.query_mut(m, st)  # exercise compressed layouts
    carry = flatfit.state_to_carry(m, st, window)
    assert flatfit.size(st) == window  # extraction must not mutate
    h = window - 1
    for t in range(h):
        acc = m.identity()
        for j in hist[len(hist) - (h - t):]:
            acc = m.combine(acc, m.lift(swag_base.tree_index(vals, j)))
        _assert_tree_close(
            swag_base.tree_index(carry, t), acc, exact, (mname, t)
        )


@pytest.mark.parametrize("mname", sorted(MONOID_CASES))
def test_flatfit_carry_round_trip_and_continuation(mname):
    """carry → FlatFIT state (exact compressed-layout specialization, ANY
    monoid) → carry round-trips, and the rebuilt buffer keeps behaving like
    a per-element DABA Lite window seeded with the same elements."""
    from repro.core import flatfit

    m, mk, exact = MONOID_CASES[mname]
    window, n_ops = 8, 20
    st, vals = _warm_single(ALGORITHMS["daba_lite"], m, mk, n_ops, window)
    carry = swag_base.state_to_carry(ALGORITHMS["daba_lite"], m, st, window)
    ff = flatfit.carry_to_state(m, carry, 64)
    carry2 = flatfit.state_to_carry(m, ff, window)
    _assert_tree_close(carry, carry2, exact, (mname, "roundtrip"))
    h = window - 1
    ref = ALGORITHMS["daba_lite"].init(m, 64)
    for i in range(n_ops - h, n_ops):
        ref = ALGORITHMS["daba_lite"].insert(m, ref, swag_base.tree_index(vals, i))
    assert flatfit.size(ff) == h
    more = mk((4,))
    for i in range(4):
        v = swag_base.tree_index(more, i)
        ff = flatfit.insert(m, ff, v)
        ref = ALGORITHMS["daba_lite"].insert(m, ref, v)
        ff = flatfit.evict(m, ff)
        ref = ALGORITHMS["daba_lite"].evict(m, ref)
        _assert_tree_close(
            m.lower(flatfit.query(m, ff)),
            m.lower(ALGORITHMS["daba_lite"].query(m, ref)),
            exact, (mname, "continue", i),
        )


def test_flatfit_state_from_chunk_dispatcher():
    """The swag_base dispatcher reaches FlatFIT through carry_to_state: one
    suffix scan laid out as a compressed buffer ≡ bulk insert."""
    from repro.core import flatfit

    m, mk, exact = MONOID_CASES["affine_i32"]
    vals = mk((7,))
    st = swag_base.state_from_chunk(flatfit, m, vals, 32)
    ref = flatfit.insert_bulk(m, flatfit.init(m, 32), vals)
    assert flatfit.size(st) == flatfit.size(ref) == 7
    for _ in range(7):
        _assert_tree_close(
            m.lower(flatfit.query(m, st)), m.lower(flatfit.query(m, ref)),
            exact, "state_from_chunk",
        )
        st, ref = flatfit.evict(m, st), flatfit.evict(m, ref)


# ---------------------------------------------------------------------------
# state_from_chunk: vectorized rebuild ≡ bulk insert into fresh state
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mname", sorted(MONOID_CASES))
@pytest.mark.parametrize("algo_name", sorted(ALGORITHMS))
def test_state_from_chunk_matches_bulk_insert(algo_name, mname):
    m, mk, exact = MONOID_CASES[mname]
    if algo_name == "soe" and not m.invertible:
        pytest.skip("subtract-on-evict needs an invertible monoid")
    algo = ALGORITHMS[algo_name]
    for k in (1, 7, 12):
        vals = mk((k,))
        st = swag_base.state_from_chunk(algo, m, vals, 32)
        ref = swag_base.insert_bulk(algo, m, algo.init(m, 32), vals)
        assert int(algo.size(st)) == int(algo.size(ref)) == k
        for step in range(k):
            _assert_tree_close(
                m.lower(algo.query(m, st)), m.lower(algo.query(m, ref)),
                exact, (algo_name, mname, k, step),
            )
            st, ref = algo.evict(m, st), algo.evict(m, ref)


# ---------------------------------------------------------------------------
# BatchedSWAG.stream: warm routing ≡ per-element
# ---------------------------------------------------------------------------


def _warm_batched(algo, m, mk, B, window, n_warm, cap):
    b = BatchedSWAG(algo, m, cap)
    st = b.init(B)
    if n_warm:
        st, _ = b.stream(st, mk((n_warm, B)), window, chunked=False)
    return b, st


@pytest.mark.parametrize("mname", sorted(MONOID_CASES))
@pytest.mark.parametrize("algo_name", sorted(GENERAL_ALGORITHMS))
def test_warm_stream_chunked_matches_per_element(algo_name, mname):
    m, mk, exact = MONOID_CASES[mname]
    algo = GENERAL_ALGORITHMS[algo_name]
    window, B = 8, 3
    for n_warm, T, chunk in [(0, 37, 16), (3, 37, 16), (11, 41, 13), (8, 20, 4)]:
        b, st = _warm_batched(algo, m, mk, B, window, n_warm, cap=12)
        xs = mk((T, B))
        st_pe, ys_pe = b.stream(st, xs, window, chunked=False)
        st_ch, ys_ch = b.stream(st, xs, window, chunked=True, chunk=chunk)
        ctx = (algo_name, mname, n_warm, T, chunk)
        _assert_tree_close(ys_ch, ys_pe, exact, ctx)
        _assert_tree_close(b.query(st_ch), b.query(st_pe), exact, ctx)
        # the rebuilt final state keeps behaving
        more = mk((B,))
        st_pe, st_ch = b.insert(st_pe, more), b.insert(st_ch, more)
        st_pe, st_ch = b.evict(st_pe), b.evict(st_ch)
        _assert_tree_close(b.query(st_ch), b.query(st_pe), exact, ctx)


def test_warm_stream_ragged_lanes():
    """Per-lane warm sizes differ (masked fills) — carries are extracted and
    front-truncated per lane."""
    m = monoids.sum_monoid(jnp.int32)
    b = BatchedSWAG(ALGORITHMS["daba_lite"], m, 12)
    st = b.init(3)
    for t in range(6):
        do_ins = jnp.asarray([True, t < 2, t < 5])
        st = b.step(st, _scalar_vals((3,), jnp.int32), do_ins, jnp.zeros(3, bool))
    assert sorted(np.asarray(b.size(st)).tolist()) == [2, 5, 6]
    xs = _scalar_vals((41, 3), jnp.int32)
    st_pe, ys_pe = b.stream(st, xs, 8, chunked=False)
    st_ch, ys_ch = b.stream(st, xs, 8, chunked=True, chunk=16)
    _assert_tree_close(ys_ch, ys_pe, exact=True)
    _assert_tree_close(b.query(st_ch), b.query(st_pe), exact=True)


def test_auto_routing_includes_warm_states(monkeypatch):
    """A warm concrete state with T ≥ the auto threshold takes the chunked
    path (engine cache populated); oversized lanes fall back."""
    from repro.core import batched as batched_mod

    monkeypatch.setattr(batched_mod, "CHUNKED_AUTO_MIN_T", 32)
    m = monoids.sum_monoid(jnp.int32)
    b = BatchedSWAG(ALGORITHMS["daba_lite"], m, 12)
    st = b.init(2)
    st, _ = b.stream(st, _scalar_vals((10, 2), jnp.int32), 8, chunked=False)
    assert not b._chunked_engines
    xs = _scalar_vals((40, 2), jnp.int32)
    st_ch, ys_ch = b.stream(st, xs, 8)
    assert b._chunked_engines, "warm stream should auto-route through chunked"
    _, ys_pe = b.stream(st, xs, 8, chunked=False)
    _assert_tree_close(ys_ch, ys_pe, exact=True)


def test_warm_auto_routing_at_real_threshold_exact():
    """No monkeypatching: a warm stream at T ≥ CHUNKED_AUTO_MIN_T takes the
    chunked engine and matches the per-element scan bit-exactly (int sum)."""
    from repro.core.batched import CHUNKED_AUTO_MIN_T

    m = monoids.sum_monoid(jnp.int32)
    b = BatchedSWAG(ALGORITHMS["daba_lite"], m, 36)
    st = b.init(2)
    st, _ = b.stream(st, _scalar_vals((40, 2), jnp.int32), 32, chunked=False)
    xs = _scalar_vals((CHUNKED_AUTO_MIN_T + 100, 2), jnp.int32)
    st_auto, ys_auto = b.stream(st, xs, 32)  # auto: warm + long → chunked
    assert b._chunked_engines
    st_pe, ys_pe = b.stream(st, xs, 32, chunked=False)
    _assert_tree_close(ys_auto, ys_pe, exact=True)
    _assert_tree_close(b.query(st_auto), b.query(st_pe), exact=True)


def test_warm_stream_inside_jit_stays_per_element():
    """Traced states cannot take the host-side chunk loop — auto routing
    must quietly stay on the scan path under jit."""
    m = monoids.sum_monoid(jnp.int32)
    b = BatchedSWAG(ALGORITHMS["daba_lite"], m, 12)
    st = b.init(2)
    xs = _scalar_vals((40, 2), jnp.int32)

    @jax.jit
    def run(st, xs):
        return b.stream(st, xs, 8)[1]

    _assert_tree_close(run(st, xs), b.stream(st, xs, 8, chunked=False)[1], True)


# ---------------------------------------------------------------------------
# ragged last chunk: identity padding, single compilation
# ---------------------------------------------------------------------------


def test_ragged_last_chunk_reuses_one_compilation():
    m = monoids.sum_monoid(jnp.int32)
    eng = ChunkedStream(m, window=8, chunk=16)
    traces = []
    orig = eng._process_chunk_impl

    def counting_impl(carry, xs, mask=None):
        traces.append(jax.tree.leaves(xs)[0].shape)
        return orig(carry, xs, mask)

    eng._jitted_pc = jax.jit(counting_impl)
    xs = _scalar_vals((53, 2), jnp.int32)  # 3 full chunks + ragged 5
    ys = eng.stream(xs)
    assert len(traces) == 1, f"expected one trace, got shapes {traces}"
    ref = ChunkedStream(m, window=8, chunk=53).stream(xs)
    _assert_tree_close(ys, ref, exact=True)


def test_masked_chunk_positions_are_identity():
    """Masked positions act as monoid identity on both engine paths."""
    for m, mk, exact in [MONOID_CASES["sum_i32"], MONOID_CASES["mean"]]:
        eng = ChunkedStream(m, window=4, chunk=8)
        xs = mk((8, 2))
        mask = jnp.arange(8) < 5
        carry = eng.init_carry(2)
        _, y = eng.process_chunk(carry, xs, mask)
        ref = ChunkedStream(m, window=4, chunk=5).stream(
            jax.tree.map(lambda a: a[:5], xs)
        )
        _assert_tree_close(
            jax.tree.map(lambda a: a[:5], y), ref, exact, m.name
        )
