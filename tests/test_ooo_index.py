"""Unit tests for the vectorized finger-style tail index (core/ooo_index).

Each primitive is checked against a plain-numpy reference on randomized
inputs, with the sentinel/padding edge cases the engine relies on: live
prefixes shorter than the buffer, all-padding chunks, watermark splits that
release nothing/everything, tie discipline in the merges, and full-range
finger searches (the case an off-by-one in the binary-search round count
would miss).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import monoids, ooo_index

rng = np.random.default_rng(21)

TMAX = np.float32(np.finfo(np.float32).max)


def _padded(vals, total, fill):
    out = np.full(total, fill, np.float32)
    out[: len(vals)] = vals
    return out


# ---------------------------------------------------------------------------
# chunk_in_order
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "ts,frontier,want",
    [
        ([1.0, 2.0, 2.0, 5.0], 1.0, True),
        ([1.0, 2.0, 2.0, 5.0], 1.5, False),   # below frontier
        ([1.0, 3.0, 2.0, 5.0], 0.0, False),   # not sorted
        ([2.0, 3.0, TMAX, TMAX], 2.0, True),  # sentinel tail passes
        ([2.0, TMAX, 3.0, TMAX], 2.0, False), # interior hole fails
        ([TMAX, TMAX], 7.0, True),            # all-masked (flush) chunk
    ],
)
def test_chunk_in_order(ts, frontier, want):
    got = ooo_index.chunk_in_order(
        jnp.asarray(ts, jnp.float32), jnp.float32(frontier)
    )
    assert bool(got) is want


# ---------------------------------------------------------------------------
# displacement
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
def test_displacement_matches_brute_force(seed):
    r = np.random.default_rng(seed)
    n_live = int(r.integers(0, 12))
    P = n_live + int(r.integers(0, 5))
    ts = _padded(r.integers(0, 8, n_live).astype(np.float32), P, TMAX)
    order = np.argsort(ts, kind="stable")
    got = int(
        ooo_index.displacement(
            jnp.asarray(ts), jnp.asarray(order, jnp.int32), jnp.float32(TMAX)
        )
    )
    want = 0
    for i in range(n_live):
        want = max(want, int(np.sum(ts[:i] > ts[i])))
    assert got == want


# ---------------------------------------------------------------------------
# compact_perm / compact_sorted (the d = 0 no-sort merge)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
def test_compact_sorted_matches_stable_sort(seed):
    r = np.random.default_rng(100 + seed)
    K, C = 6, 5
    nb = int(r.integers(0, K + 1))
    buf = np.sort(r.uniform(0, 10, nb)).astype(np.float32)
    n_chunk = int(r.integers(0, C + 1))
    lo = buf[-1] if nb else 0.0  # chunk at/above the buffer (the frontier)
    chunk = np.sort(lo + r.uniform(0, 5, n_chunk)).astype(np.float32)
    buf_ts = _padded(buf, K, TMAX)
    ts_in = _padded(chunk, C, TMAX)
    buf_agg = _padded(r.integers(0, 9, nb).astype(np.float32), K, 0.0)
    chunk_agg = _padded(r.integers(0, 9, n_chunk).astype(np.float32), C, 0.0)

    pend_ts, pend_agg = ooo_index.compact_sorted(
        jnp.asarray(buf_ts), jnp.asarray(buf_agg),
        jnp.asarray(ts_in), jnp.asarray(chunk_agg),
        tmax=jnp.float32(TMAX), ident=jnp.float32(0.0),
    )
    # reference: stable sort of the concatenation (buffer first on ties)
    cat_ts = np.concatenate([buf_ts, ts_in])
    cat_agg = np.concatenate([buf_agg, chunk_agg])
    o = np.argsort(cat_ts, kind="stable")
    want_ts, want_agg = cat_ts[o], cat_agg[o]
    want_agg[want_ts >= TMAX] = 0.0
    assert np.array_equal(np.asarray(pend_ts), want_ts)
    assert np.array_equal(np.asarray(pend_agg), want_agg)


def test_sort_pending_tie_discipline():
    """Buffer rows precede same-ts chunk rows; chunk keeps arrival order."""
    buf_ts = jnp.asarray([2.0, 2.0, TMAX], jnp.float32)
    buf_agg = jnp.asarray([10.0, 11.0, 0.0])
    ts_in = jnp.asarray([2.0, 1.0, 2.0], jnp.float32)
    chunk_agg = jnp.asarray([20.0, 21.0, 22.0])
    pend_ts, pend_agg, _ = ooo_index.sort_pending(
        buf_ts, buf_agg, ts_in, chunk_agg
    )
    assert np.array_equal(
        np.asarray(pend_agg), [21.0, 10.0, 11.0, 20.0, 22.0, 0.0]
    )
    assert np.array_equal(
        np.asarray(pend_ts), [1.0, 2.0, 2.0, 2.0, 2.0, TMAX]
    )


# ---------------------------------------------------------------------------
# release_split
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_release_split_matches_reference(seed):
    r = np.random.default_rng(200 + seed)
    P, K = 9, 4
    n_live = int(r.integers(0, P + 1))
    live = np.sort(r.integers(0, 12, n_live)).astype(np.float32)
    pend_ts = _padded(live, P, TMAX)
    pend_agg = _padded(r.integers(1, 9, n_live).astype(np.float32), P, 0.0)
    wm = np.float32(r.integers(-1, 13))

    rel_ts, rel_agg, rel, buf_ts, buf_agg, ovf = ooo_index.release_split(
        jnp.asarray(pend_ts), jnp.asarray(pend_agg), jnp.float32(wm),
        buffer=K, tmax=jnp.float32(TMAX), ident=jnp.float32(0.0),
    )
    n_rel = int(np.sum(live <= wm))
    rest = live[n_rel:]
    assert np.array_equal(np.asarray(rel), np.arange(P) < n_rel)
    assert np.array_equal(np.asarray(rel_ts), _padded(live[:n_rel], P, TMAX))
    assert np.array_equal(
        np.asarray(rel_agg), _padded(pend_agg[:n_rel], P, 0.0)
    )
    assert np.array_equal(
        np.asarray(buf_ts), _padded(rest[:K], K, TMAX)
    )
    assert np.array_equal(
        np.asarray(buf_agg), _padded(pend_agg[n_rel:n_rel + min(len(rest), K)], K, 0.0)
    )
    assert int(ovf) == max(len(rest) - K, 0)


# ---------------------------------------------------------------------------
# rank_merge / append_merge
# ---------------------------------------------------------------------------

TS_MIN = np.float32(np.finfo(np.float32).min)


@pytest.mark.parametrize("seed", range(8))
def test_rank_merge_matches_stable_sort(seed):
    r = np.random.default_rng(300 + seed)
    W, P = 7, 5
    nw = int(r.integers(0, W + 1))
    nr = int(r.integers(0, P + 1))
    win = np.sort(r.integers(0, 8, nw)).astype(np.float32)
    rel = np.sort(r.integers(0, 8, nr)).astype(np.float32)
    win_ts = np.full(W, TS_MIN, np.float32)
    win_ts[W - nw:] = win  # window pads LEAD (TS_MIN in front)
    win_agg = np.zeros(W, np.float32)
    win_agg[W - nw:] = r.integers(1, 9, nw)
    rel_ts = _padded(rel, P, TMAX)
    rel_agg = _padded(r.integers(10, 19, nr).astype(np.float32), P, 0.0)

    mts, magg, pos_rel = ooo_index.rank_merge(
        jnp.asarray(win_ts), jnp.asarray(win_agg),
        jnp.asarray(rel_ts), jnp.asarray(rel_agg),
    )
    # reference: stable sort of [window, released] — window first on ties
    cat_ts = np.concatenate([win_ts, rel_ts])
    cat_agg = np.concatenate([win_agg, rel_agg])
    o = np.argsort(cat_ts, kind="stable")
    assert np.array_equal(np.asarray(mts), cat_ts[o])
    assert np.array_equal(np.asarray(magg), cat_agg[o])
    inv = np.argsort(o)
    assert np.array_equal(np.asarray(pos_rel), inv[W:])


def test_append_merge_positions():
    win_ts = jnp.asarray([TS_MIN, 1.0, 3.0], jnp.float32)
    win_agg = jnp.asarray([0.0, 5.0, 6.0])
    rel_ts = jnp.asarray([3.0, 4.0, TMAX], jnp.float32)
    rel_agg = jnp.asarray([7.0, 8.0, 0.0])
    mts, magg, pos_rel = ooo_index.append_merge(
        win_ts, win_agg, rel_ts, rel_agg
    )
    assert np.array_equal(np.asarray(mts), [TS_MIN, 1.0, 3.0, 3.0, 4.0, TMAX])
    assert np.array_equal(np.asarray(magg), [0.0, 5.0, 6.0, 7.0, 8.0, 0.0])
    assert np.array_equal(np.asarray(pos_rel), [3, 4, 5])


# ---------------------------------------------------------------------------
# seg_bounded_search
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("C", [1, 2, 3, 5, 8, 33, 64])
def test_seg_bounded_search_matches_reference(C):
    r = np.random.default_rng(400 + C)
    # per-segment sorted ts with random segment layout
    n_seg = int(r.integers(1, C + 1))
    heads = np.sort(r.choice(C, n_seg, replace=False))
    heads[0] = 0
    ts = np.empty(C, np.float32)
    bounds = list(heads) + [C]
    for s, e in zip(bounds[:-1], bounds[1:]):
        ts[s:e] = np.sort(r.integers(0, 6, e - s))
    sid = np.searchsorted(heads, np.arange(C), side="right") - 1
    lo = heads[sid]
    hi = np.arange(C)
    thr = r.integers(-1, 7, C).astype(np.float32)

    got = np.asarray(
        ooo_index.seg_bounded_search(
            jnp.asarray(ts), jnp.asarray(lo, jnp.int32),
            jnp.asarray(hi, jnp.int32), jnp.asarray(thr),
        )
    )
    for j in range(C):
        want = hi[j] + 1
        for i in range(lo[j], hi[j] + 1):
            if ts[i] > thr[j]:
                want = i
                break
        assert got[j] == want, (C, j, lo[j], hi[j], thr[j], ts[lo[j]:hi[j] + 1])


def test_seg_bounded_search_full_range_tiny():
    """C=2 full-range search — the case one missing bisection round breaks."""
    ts = jnp.asarray([1.0, 2.0], jnp.float32)
    got = ooo_index.seg_bounded_search(
        ts, jnp.asarray([0, 0], jnp.int32), jnp.asarray([1, 1], jnp.int32),
        jnp.asarray([1.5, 0.0], jnp.float32),
    )
    assert np.array_equal(np.asarray(got), [1, 0])
