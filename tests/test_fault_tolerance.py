"""Fault tolerance: checkpoint/restart determinism, failure injection +
recovery, elastic restore, straggler detection."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data.stream import SyntheticStream
from repro.models.factory import reduced_config
from repro.optim.adamw import AdamW
from repro.train import checkpoint
from repro.train.metrics import TimeWindow
from repro.train.trainer import FailureInjector, Trainer, TrainerConfig

ARCH = reduced_config(ARCHS["llama3.2-1b"])


def make_trainer(tmpdir, total=12, ckpt_every=4, fail_at=None):
    tcfg = TrainerConfig(
        total_steps=total,
        ckpt_every=ckpt_every,
        ckpt_dir=str(tmpdir),
        metric_window=8,
        log_every=1,
    )
    stream = SyntheticStream(ARCH, batch=2, seq=16, seed=0)
    return Trainer(
        ARCH, tcfg, AdamW(learning_rate=1e-3), stream,
        failure_injector=FailureInjector(fail_at),
    )


def params_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def test_checkpoint_roundtrip(tmp_path):
    t = make_trainer(tmp_path)
    state = t.fresh_state(jax.random.key(0))
    checkpoint.save(state, str(tmp_path), 0)
    restored = checkpoint.restore(str(tmp_path), 0, state)
    assert params_equal(state.params, restored.params)
    assert int(restored.step) == int(state.step)


def test_atomic_save_never_corrupts(tmp_path):
    """A crash mid-save must leave the previous checkpoint intact."""
    t = make_trainer(tmp_path)
    state = t.fresh_state(jax.random.key(0))
    checkpoint.save(state, str(tmp_path), 5)
    # simulate a crashed partial write: stray tmp dir
    os.makedirs(tmp_path / ".tmp_ckpt_crashed", exist_ok=True)
    (tmp_path / ".tmp_ckpt_crashed" / "arrays.npz").write_bytes(b"garbage")
    assert checkpoint.latest_step(str(tmp_path)) == 5
    restored = checkpoint.restore(str(tmp_path), 5, state)
    assert params_equal(state.params, restored.params)


def test_failure_recovery_bitwise_identical(tmp_path):
    """Train with an injected crash + restart ≡ uninterrupted run.

    The data stream is a pure function of step, so replay after restore from
    step-8 checkpoint reproduces the uninterrupted trajectory bitwise."""
    t_fail = make_trainer(tmp_path / "a", total=12, ckpt_every=4, fail_at={9})
    final_a = t_fail.run_with_recovery(jax.random.key(1))

    t_clean = make_trainer(tmp_path / "b", total=12, ckpt_every=4)
    final_b = t_clean.run(t_clean.fresh_state(jax.random.key(1)))

    assert int(final_a.step) == int(final_b.step) == 12
    assert params_equal(final_a.params, final_b.params)


def test_elastic_restore_new_mesh(tmp_path):
    """A checkpoint restores under different target shardings (here: the
    degenerate 1-device mesh with explicit shardings) — the elastic path."""
    t = make_trainer(tmp_path)
    state = t.fresh_state(jax.random.key(0))
    checkpoint.save(state, str(tmp_path), 0)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    restored = checkpoint.restore(str(tmp_path), 0, state, shardings)
    assert params_equal(state.params, restored.params)


def test_loss_decreases(tmp_path):
    t = make_trainer(tmp_path, total=30, ckpt_every=100)
    t.tcfg.log_every = 1
    t.run(t.fresh_state(jax.random.key(2)))
    losses = [h["loss"] for h in t.history]
    assert losses[-1] < losses[0], losses


def test_windowed_metrics_in_history(tmp_path):
    t = make_trainer(tmp_path, total=6, ckpt_every=100)
    t.tcfg.log_every = 1
    t.run(t.fresh_state(jax.random.key(3)))
    h = t.history[-1]
    assert "win/loss_mean" in h and np.isfinite(h["win/loss_mean"])
    assert h["win/gnorm_max"] >= 0
    assert h["win/steps"] >= 1


def test_straggler_detection():
    tw = TimeWindow(window=32)
    for _ in range(20):
        assert not tw.is_straggler(0.10 + np.random.default_rng(0).uniform(0, 0.005))
    assert tw.is_straggler(1.5)  # 15× the window mean → flagged


def test_stream_determinism():
    s1 = SyntheticStream(ARCH, batch=2, seq=16, seed=42)
    s2 = SyntheticStream(ARCH, batch=2, seq=16, seed=42)
    b1, b2 = s1.batch_at(7), s2.batch_at(7)
    assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = s1.batch_at(8)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
