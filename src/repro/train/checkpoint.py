"""Sharded checkpointing: atomic, resumable, elastic.

Format: one directory per step —
    ckpt_<step>/
        manifest.json     pytree structure + leaf dtypes/shapes + step
        arrays.npz        flattened leaves keyed by path

Writes go to a temp dir + atomic rename, so a crash mid-save never corrupts
the latest checkpoint (restart-safe).  ``restore`` rebuilds the pytree and
``jax.device_put``s each leaf to a *target sharding*, which may differ from
the sharding at save time — this is the elastic-rescale path: a checkpoint
written on one mesh restores onto any mesh whose axes divide the shapes
(tested in tests/test_fault_tolerance.py).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

_SEP = "/"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            p.key if isinstance(p, jax.tree_util.DictKey)
            else str(getattr(p, "idx", getattr(p, "name", p)))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save(tree: PyTree, directory: str, step: int) -> str:
    """Write ckpt_<step> atomically; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"ckpt_{step}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        flat = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": int(step),
            "keys": sorted(flat.keys()),
            "format": 1,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def save_async(tree: PyTree, directory: str, step: int) -> threading.Thread:
    """Checkpoint on a background thread (device→host copy happens first so
    training can proceed while the file write is in flight)."""
    host_tree = jax.tree.map(np.asarray, tree)
    t = threading.Thread(target=save, args=(host_tree, directory, step), daemon=True)
    t.start()
    return t


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("ckpt_"):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore(directory: str, step: int, like: PyTree, shardings: PyTree = None) -> PyTree:
    """Rebuild ``like``-structured pytree; optionally place with shardings
    (elastic restore onto a different mesh)."""
    path = os.path.join(directory, f"ckpt_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["step"] == step
    data = np.load(os.path.join(path, "arrays.npz"))

    paths_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for (kpath, leaf) in paths_like[0]:
        key = _SEP.join(
            p.key if isinstance(p, jax.tree_util.DictKey)
            else str(getattr(p, "idx", getattr(p, "name", p)))
            for p in kpath
        )
        arr = data[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(paths_like[1], leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(jnp.asarray(x), s), tree, shardings
        )
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree
