"""Fault-tolerant training loop: checkpoint/restart, elastic restore,
straggler detection, deterministic data replay.

Designed for preemptible fleets: every ``ckpt_every`` steps the full
TrainState is checkpointed (async, atomic); on startup the trainer resumes
from the latest checkpoint and replays the data stream from the saved step
(the stream is a pure function of step, so no reader state is needed).
``FailureInjector`` lets tests kill the loop at arbitrary steps and verify
bitwise-identical recovery.  Step durations feed a DABA-Lite window; steps
whose z-score exceeds the threshold are logged as stragglers (on a real
fleet this triggers hot-spare re-dispatch; here it is surfaced in metrics).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.data.stream import SyntheticStream
from repro.models.common import ModelConfig
from repro.optim.adamw import AdamW
from repro.train import checkpoint
from repro.train.metrics import TimeWindow
from repro.train.train_step import TrainState, init_train_state, make_train_step

log = logging.getLogger("repro.trainer")


class FailureInjector:
    """Test hook: raises SimulatedFailure at chosen steps (once each)."""

    def __init__(self, fail_at: Optional[set[int]] = None):
        self.fail_at = set(fail_at or ())

    def maybe_fail(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise SimulatedFailure(f"injected failure at step {step}")


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    metric_window: int = 64
    # metric_horizon=H switches BOTH the in-step metric windows and the
    # straggler baseline to event time (the last H seconds of wall clock
    # instead of the last metric_window steps) — exactly the regime where
    # stragglers make step counts and wall clock diverge.  The step
    # timestamp is threaded through the jitted step as an f32 argument.
    metric_horizon: Optional[float] = None
    straggler_z: float = 4.0
    compress_grads: bool = False
    log_every: int = 10


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainerConfig,
        optimizer: AdamW,
        stream: SyntheticStream,
        jit_fn: Callable = jax.jit,
        failure_injector: Optional[FailureInjector] = None,
        obs=None,
    ):
        self.cfg = cfg
        self.tcfg = tcfg
        self.optimizer = optimizer
        self.stream = stream
        self.failures = failure_injector or FailureInjector()
        self.time_window = TimeWindow(
            tcfg.metric_window, horizon=tcfg.metric_horizon
        )
        self.straggler_events: list[int] = []
        # obs: repro.obs.registry.ObsConfig — the loop already blocks on the
        # loss each step, so the hooks are free host-side appends; disabled
        # leaves the jitted step untouched either way
        self._obs = obs if (obs is not None and obs.enabled) else None
        self._obs_hist = None
        if self._obs is not None:
            reg = self._obs.resolved_registry()
            self._obs_hist = reg.histogram(
                "repro_train_step_ms", "train-step wall time (ms)"
            )
            self._obs_loss = reg.gauge("repro_train_loss", "latest step loss")
            self._obs_step = reg.gauge("repro_train_step", "current step")
            self._obs_stragglers = reg.counter(
                "repro_train_stragglers",
                "steps whose duration z-score exceeded the threshold",
            )
        self._step_fn = jit_fn(make_train_step(
            cfg, optimizer, tcfg.compress_grads,
            metric_horizon=tcfg.metric_horizon,
        ))
        # f32 holds ~7 significant digits: timestamps are anchored to the
        # trainer's start so hours-long runs keep sub-ms ts resolution
        self._ts_anchor = time.perf_counter()
        self._pending_ckpt = None
        self.history: list[dict] = []

    # -- state management ---------------------------------------------------

    def fresh_state(self, key) -> TrainState:
        from repro.models.transformer import init_params

        params = init_params(self.cfg, key)
        return init_train_state(
            self.cfg, params, self.optimizer,
            self.tcfg.metric_window, self.tcfg.compress_grads,
            metric_horizon=self.tcfg.metric_horizon,
        )

    def resume_or_init(self, key, shardings=None) -> TrainState:
        step = checkpoint.latest_step(self.tcfg.ckpt_dir)
        state = self.fresh_state(key)
        if step is None:
            log.info("no checkpoint found; starting fresh")
            return state
        log.info("resuming from checkpoint step %d", step)
        return checkpoint.restore(self.tcfg.ckpt_dir, step, state, shardings)

    # -- the loop -----------------------------------------------------------

    def run(self, state: TrainState, until: Optional[int] = None) -> TrainState:
        until = until if until is not None else self.tcfg.total_steps
        step = int(state.step)
        while step < until:
            batch = self.stream.batch_at(step)  # deterministic replay
            self.failures.maybe_fail(step)
            t0 = time.perf_counter()
            if self.tcfg.metric_horizon is not None:
                # pass ts as an f32 ARRAY so jit traces it (a Python float
                # would bake a new constant — and a recompile — every step)
                ts = jnp.float32(t0 - self._ts_anchor)
                state, metrics = self._step_fn(state, batch, ts)
            else:
                state, metrics = self._step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            step = int(state.step)
            straggler = self.time_window.is_straggler(dt, self.tcfg.straggler_z)
            if straggler:
                self.straggler_events.append(step)
                log.warning("straggler step %d: %.3fs", step, dt)
            if self._obs is not None:
                self._obs_hist.observe(dt * 1e3)
                self._obs_step.set(step)
                self._obs_loss.set(float(metrics["loss"]))
                if straggler:
                    self._obs_stragglers.inc()
                tr = self._obs.trace
                if tr is not None:
                    tr.complete("train.step", tr._now_us() - dt * 1e6,
                                dt * 1e6, tid=3, args={"step": step})
            if step % self.tcfg.log_every == 0:
                rec = {k: float(v) for k, v in metrics.items()}
                rec["step"] = step
                self.history.append(rec)
            if step % self.tcfg.ckpt_every == 0:
                if self._pending_ckpt is not None:
                    self._pending_ckpt.join()
                self._pending_ckpt = checkpoint.save_async(
                    state, self.tcfg.ckpt_dir, step
                )
        if self._pending_ckpt is not None:
            self._pending_ckpt.join()
            self._pending_ckpt = None
        return state

    def run_with_recovery(self, key, max_restarts: int = 3) -> TrainState:
        """Full fault-tolerant entry: resume, and on failure restart from the
        last checkpoint (bounded retries)."""
        for attempt in range(max_restarts + 1):
            state = self.resume_or_init(key)
            try:
                return self.run(state)
            except SimulatedFailure as e:
                log.warning("run attempt %d failed: %s; restarting", attempt, e)
        raise RuntimeError("exceeded max restarts")
