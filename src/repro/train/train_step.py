"""The fused training step: loss → grad → clip → AdamW → windowed telemetry.

``make_train_step(cfg, optimizer)`` returns a pure function
``(state, batch) -> (state, metrics)`` suitable for ``jax.jit`` with
in/out shardings from distributed/sharding.py.  Optional int8 error-feedback
gradient compression models the compressed DP all-reduce (the decompressed
values feed the update, so numerics match the wire format).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.compression import ef_compress_tree, init_error_state
from repro.models.common import ModelConfig
from repro.models.transformer import loss_fn
from repro.optim.adamw import AdamW, AdamWState
from repro.train.metrics import (
    init_metric_windows,
    read_metric_windows,
    update_metric_windows,
)

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TrainState:
    params: PyTree
    opt_state: AdamWState
    step: jax.Array
    metric_windows: PyTree
    compress_err: Optional[PyTree] = None


def init_train_state(
    cfg: ModelConfig,
    params: PyTree,
    optimizer: AdamW,
    metric_window: int = 128,
    compress: bool = False,
    *,
    metric_horizon: Optional[float] = None,
) -> TrainState:
    """``metric_horizon=H`` switches the step-metric windows to event time
    (last H seconds of wall clock) — pair it with the same ``metric_horizon``
    in :func:`make_train_step`, whose step then takes a ``ts`` argument."""
    if metric_horizon is not None:
        mw = init_metric_windows(horizon=metric_horizon)
    else:
        mw = init_metric_windows(metric_window)
    return TrainState(
        params=params,
        opt_state=optimizer.init(params),
        step=jnp.zeros((), jnp.int32),
        metric_windows=mw,
        compress_err=init_error_state(params) if compress else None,
    )


def make_train_step(
    cfg: ModelConfig,
    optimizer: AdamW,
    compress: bool = False,
    accum_steps: int = 1,
    *,
    metric_horizon: Optional[float] = None,
):
    """``accum_steps > 1`` splits the global batch into microbatches scanned
    sequentially with f32 gradient accumulation — activation memory scales
    with the microbatch while gradient/optimizer numerics are unchanged (one
    update per step).  This is how the 4k-seq × 256-batch train shapes fit
    16 GB/chip HBM (see EXPERIMENTS.md §Dry-run).

    ``metric_horizon=H`` makes the metric windows event-time: the returned
    step is ``(state, batch, ts) -> (state, metrics)`` where ``ts`` is the
    step's wall-clock timestamp in seconds (an f32 array so it stays a
    traced argument — the trainer anchors ``time.perf_counter`` at start
    and passes the offset), and the windowed loss/grad-norm stats cover
    the last H seconds instead of the last N steps."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True
        )(params)

    def train_step(state: TrainState, batch: dict, ts=None):
        if metric_horizon is not None and ts is None:
            raise ValueError(
                "metric_horizon is set: the train step needs the step's "
                "wall-clock timestamp — call step_fn(state, batch, ts)"
            )
        if accum_steps == 1:
            (loss, aux), grads = grads_of(state.params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:])
                if x.ndim >= 1 and x.shape[0] % accum_steps == 0
                else jnp.broadcast_to(x, (accum_steps,) + x.shape),
                batch,
            )
            if "positions" in batch and batch["positions"].ndim == 3:
                # (3, B, S) → microbatch over axis 1
                p = batch["positions"]
                micro["positions"] = jnp.moveaxis(
                    p.reshape(3, accum_steps, -1, p.shape[-1]), 1, 0
                )

            def one(carry, mb):
                gsum, lsum = carry
                (loss, _aux), g = grads_of(state.params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g
                )
                return (gsum, lsum + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (gsum, lsum), _ = jax.lax.scan(
                one, (zeros, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss = lsum / accum_steps

        err = state.compress_err
        if compress:
            grads, err = ef_compress_tree(grads, err)

        params, opt_state, stats = optimizer.update(
            grads, state.opt_state, state.params
        )
        if metric_horizon is not None:
            mw = update_metric_windows(
                state.metric_windows, loss, stats["grad_norm"],
                ts=ts, horizon=metric_horizon,
            )
        else:
            mw = update_metric_windows(
                state.metric_windows, loss, stats["grad_norm"]
            )
        metrics = {
            "loss": loss,
            "grad_norm": stats["grad_norm"],
            "lr": stats["lr"],
            **read_metric_windows(mw),
        }
        new_state = TrainState(
            params=params,
            opt_state=opt_state,
            step=state.step + 1,
            metric_windows=mw,
            compress_err=err,
        )
        return new_state, metrics

    return train_step
