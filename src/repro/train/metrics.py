"""Windowed training telemetry — the unified telemetry layer in the train loop.

Loss and gradient-norm statistics over a sliding window of recent steps are
maintained *inside* the jitted train step through the pure functional core of
:class:`repro.core.telemetry.WindowedTelemetry`: the three metrics (variance,
maxcount, max) live in ONE product-monoid state updated by the chunked
engine, so metric upkeep is one fused window update per step — uniform,
data-independent work (vectorized O(window) combines at O(log window)
depth; no data-dependent amortized spikes perturbing step time).  Monoids
used:

  * variance (Welford merge)       → windowed loss mean / stddev
  * maxcount                       → windowed grad-norm max + multiplicity
  * max                            → windowed step-time max (host-fed)

The same windowed mean/std powers straggler *detection* in the trainer: a
step whose duration z-scores far above the window is flagged (mitigation =
checkpoint + re-dispatch, which the fault-tolerance layer handles).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.monoids import max_monoid, maxcount_monoid, variance_monoid
from repro.core.telemetry import WindowedTelemetry

PyTree = Any

_LOSS_M = variance_monoid()
_GNORM_M = maxcount_monoid()
_TIME_M = max_monoid()
_TIME_IDENT = float(jnp.finfo(jnp.float32).min)  # identity of the max monoid


@functools.lru_cache(maxsize=None)
def _telemetry(window: int) -> WindowedTelemetry:
    return WindowedTelemetry(
        {"loss": _LOSS_M, "gnorm": _GNORM_M, "step_time": _TIME_M}, window
    )


def _window_of(mw: PyTree) -> int:
    # The window is static metadata recovered from the carry leaf SHAPES
    # (tail length = window - 1) — values may be tracers inside jit, shapes
    # never are.
    return jax.tree.leaves(mw["carry"])[0].shape[0] + 1


def init_metric_windows(window: int) -> PyTree:
    return _telemetry(int(window)).init_state()


def update_metric_windows(mw: PyTree, loss, grad_norm, step_time=None) -> PyTree:
    t = _telemetry(_window_of(mw))
    if step_time is None:
        step_time = _TIME_IDENT  # identity: leaves the windowed max untouched
    return t.update(
        mw, {"loss": loss, "gnorm": grad_norm, "step_time": step_time}
    )


def read_metric_windows(mw: PyTree) -> dict:
    last = jax.tree.map(lambda a: a[0], mw["last"])  # single-lane telemetry
    lq, gq = last["loss"], last["gnorm"]
    n = jnp.maximum(lq["n"], 1.0)
    return {
        "win/loss_mean": lq["mu"],
        "win/loss_std": jnp.sqrt(lq["m2"] / n),
        "win/gnorm_max": gq["m"],
        "win/gnorm_max_count": gq["c"],
        "win/steps": lq["n"].astype(jnp.int32),
        "win/time_max": last["step_time"],
    }


class TimeWindow:
    """Host-side (eager) sliding window over step durations for straggler
    detection — one jitted dispatch per observation via the telemetry layer
    (variance monoid), so the watchdog itself never causes a latency spike."""

    def __init__(self, window: int = 64):
        self.window = window
        self.telem = WindowedTelemetry({"t": variance_monoid()}, window)

    def observe(self, seconds: float) -> dict:
        self.telem.observe({"t": jnp.float32(seconds)})
        q = jax.device_get(self.telem.aggregate("t"))  # one transfer
        n = max(float(q["n"]), 1.0)
        mean = float(q["mu"])
        std = (float(q["m2"]) / n) ** 0.5
        z = 0.0 if std < 1e-9 else (seconds - mean) / std
        return {"mean": mean, "std": std, "zscore": z, "n": int(n)}

    def is_straggler(self, seconds: float, z_threshold: float = 4.0) -> bool:
        stats = self.observe(seconds)
        return stats["n"] >= 8 and stats["zscore"] > z_threshold
