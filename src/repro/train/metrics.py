"""Windowed training telemetry via DABA Lite — the paper inside the train loop.

Loss and gradient-norm statistics over a sliding window of recent steps are
maintained *inside* the jitted train step with worst-case O(1) monoid
combines per step (Theorem 13): metric upkeep adds constant, uniform work —
no amortized spikes perturbing step time.  Monoids used:

  * variance (Welford merge)       → windowed loss mean / stddev
  * maxcount                       → windowed grad-norm max + multiplicity
  * max                            → windowed step-time max (host-fed)

The same windowed mean/std powers straggler *detection* in the trainer: a
step whose duration z-scores far above the window is flagged (mitigation =
checkpoint + re-dispatch, which the fault-tolerance layer handles).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import daba_lite
from repro.core.monoids import max_monoid, maxcount_monoid, variance_monoid

PyTree = Any

_LOSS_M = variance_monoid()
_GNORM_M = maxcount_monoid()
_TIME_M = max_monoid()


def init_metric_windows(window: int) -> PyTree:
    cap = window + 1
    return {
        "window": jnp.asarray(window, jnp.int32),
        "loss": daba_lite.init(_LOSS_M, cap),
        "gnorm": daba_lite.init(_GNORM_M, cap),
        "step_time": daba_lite.init(_TIME_M, cap),
    }


def _slide(monoid, state, value, window):
    state = daba_lite.insert(monoid, state, value)
    return jax.lax.cond(
        daba_lite.size(state) > window,
        lambda s: daba_lite.evict(monoid, s),
        lambda s: s,
        state,
    )


def update_metric_windows(mw: PyTree, loss, grad_norm, step_time=None) -> PyTree:
    w = mw["window"]
    out = dict(mw)
    out["loss"] = _slide(_LOSS_M, mw["loss"], loss, w)
    out["gnorm"] = _slide(_GNORM_M, mw["gnorm"], grad_norm, w)
    if step_time is not None:
        out["step_time"] = _slide(_TIME_M, mw["step_time"], step_time, w)
    return out


def read_metric_windows(mw: PyTree) -> dict:
    lq = daba_lite.query(_LOSS_M, mw["loss"])
    gq = daba_lite.query(_GNORM_M, mw["gnorm"])
    n = jnp.maximum(lq["n"], 1.0)
    return {
        "win/loss_mean": lq["mu"],
        "win/loss_std": jnp.sqrt(lq["m2"] / n),
        "win/gnorm_max": gq["m"],
        "win/gnorm_max_count": gq["c"],
        "win/steps": lq["n"].astype(jnp.int32),
        "win/time_max": daba_lite.query(_TIME_M, mw["step_time"]),
    }


class TimeWindow:
    """Host-side (eager) sliding window over step durations for straggler
    detection — worst-case O(1) upkeep per step via DABA Lite + variance
    monoid, so the watchdog itself never causes a latency spike."""

    def __init__(self, window: int = 64):
        self.window = window
        self.m = variance_monoid()
        self.state = daba_lite.init(self.m, window + 1)

    def observe(self, seconds: float) -> dict:
        self.state = daba_lite.insert(self.m, self.state, seconds)
        if int(daba_lite.size(self.state)) > self.window:
            self.state = daba_lite.evict(self.m, self.state)
        q = daba_lite.query(self.m, self.state)
        n = max(float(q["n"]), 1.0)
        mean = float(q["mu"])
        std = (float(q["m2"]) / n) ** 0.5
        z = 0.0 if std < 1e-9 else (seconds - mean) / std
        return {"mean": mean, "std": std, "zscore": z, "n": int(n)}

    def is_straggler(self, seconds: float, z_threshold: float = 4.0) -> bool:
        stats = self.observe(seconds)
        return stats["n"] >= 8 and stats["zscore"] > z_threshold
