"""Windowed training telemetry — the unified telemetry layer in the train loop.

Loss and gradient-norm statistics over a sliding window of recent steps are
maintained *inside* the jitted train step through the pure functional core of
:class:`repro.core.telemetry.WindowedTelemetry`: the three metrics (variance,
maxcount, max) live in ONE product-monoid state updated by the chunked
engine, so metric upkeep is one fused window update per step — uniform,
data-independent work (vectorized O(window) combines at O(log window)
depth; no data-dependent amortized spikes perturbing step time).  Monoids
used:

  * variance (Welford merge)       → windowed loss mean / stddev
  * maxcount                       → windowed grad-norm max + multiplicity
  * max                            → windowed step-time max (host-fed)

The same windowed mean/std powers straggler *detection* in the trainer: a
step whose duration z-scores far above the window is flagged (mitigation =
checkpoint + re-dispatch, which the fault-tolerance layer handles).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.monoids import max_monoid, maxcount_monoid, variance_monoid
from repro.core.telemetry import WindowedTelemetry

PyTree = Any

_LOSS_M = variance_monoid()
_GNORM_M = maxcount_monoid()
_TIME_M = max_monoid()
_TIME_IDENT = float(jnp.finfo(jnp.float32).min)  # identity of the max monoid


@functools.lru_cache(maxsize=None)
def _telemetry(window, horizon=None) -> WindowedTelemetry:
    metrics = {"loss": _LOSS_M, "gnorm": _GNORM_M, "step_time": _TIME_M}
    if horizon is not None:
        return WindowedTelemetry(metrics, horizon=float(horizon))
    return WindowedTelemetry(metrics, int(window))


def _window_of(mw: PyTree) -> int:
    # The window is static metadata recovered from the carry leaf SHAPES
    # (tail length = window - 1) — values may be tracers inside jit, shapes
    # never are.
    return jax.tree.leaves(mw["carry"])[0].shape[0] + 1


def init_metric_windows(window=None, *, horizon=None) -> PyTree:
    """Metric-window state: ``window=N`` counts the last N steps;
    ``horizon=H`` keeps every step whose timestamp lies in the last H
    seconds (event time — under stragglers a count window silently
    stretches its wall-clock coverage; a horizon window keeps measuring
    the same real-time span).  Horizon mode threads a ``ts`` through
    :func:`update_metric_windows` and passes the SAME ``horizon=`` there
    (a float is not recoverable from state shapes, unlike the count
    window)."""
    return _telemetry(window, horizon).init_state()


def update_metric_windows(
    mw: PyTree, loss, grad_norm, step_time=None, *, ts=None, horizon=None
) -> PyTree:
    """One step's metrics into the window (pure; lives inside the jitted
    train step).  Count mode recovers the window from the carry shapes;
    event-time mode (``horizon=`` matching ``init_metric_windows``) needs
    the step's timestamp ``ts`` (seconds, e.g. anchored perf_counter)."""
    t = _telemetry(None if horizon is not None else _window_of(mw), horizon)
    if step_time is None:
        step_time = _TIME_IDENT  # identity: leaves the windowed max untouched
    values = {"loss": loss, "gnorm": grad_norm, "step_time": step_time}
    if horizon is not None:
        if ts is None:
            raise ValueError("event-time metric windows need ts= per update")
        return t.update(mw, values, ts)
    return t.update(mw, values)


def read_metric_windows(mw: PyTree) -> dict:
    last = jax.tree.map(lambda a: a[0], mw["last"])  # single-lane telemetry
    lq, gq = last["loss"], last["gnorm"]
    n = jnp.maximum(lq["n"], 1.0)
    return {
        "win/loss_mean": lq["mu"],
        "win/loss_std": jnp.sqrt(lq["m2"] / n),
        "win/gnorm_max": gq["m"],
        "win/gnorm_max_count": gq["c"],
        "win/steps": lq["n"].astype(jnp.int32),
        "win/time_max": last["step_time"],
    }


class TimeWindow:
    """Host-side (eager) sliding window over step durations for straggler
    detection — one jitted dispatch per observation via the telemetry layer
    (variance monoid), so the watchdog itself never causes a latency spike.

    ``horizon=H`` switches to an event-time window over the last H seconds
    of wall clock (observations stamped ``time.monotonic`` by the telemetry
    layer): the straggler baseline then covers a fixed real-time span
    instead of the last N steps — exactly when stragglers make step counts
    and wall clock diverge."""

    def __init__(self, window: int = 64, *, horizon=None):
        self.window = window
        self.horizon = horizon
        if horizon is not None:
            self.telem = WindowedTelemetry(
                {"t": variance_monoid()}, horizon=float(horizon)
            )
        else:
            self.telem = WindowedTelemetry({"t": variance_monoid()}, window)

    def observe(self, seconds: float) -> dict:
        self.telem.observe({"t": jnp.float32(seconds)})
        q = jax.device_get(self.telem.aggregate("t"))  # one transfer
        n = max(float(q["n"]), 1.0)
        mean = float(q["mu"])
        std = (float(q["m2"]) / n) ** 0.5
        z = 0.0 if std < 1e-9 else (seconds - mean) / std
        return {"mean": mean, "std": std, "zscore": z, "n": int(n)}

    def is_straggler(self, seconds: float, z_threshold: float = 4.0) -> bool:
        stats = self.observe(seconds)
        return stats["n"] >= 8 and stats["zscore"] > z_threshold
