"""Chrome-trace span recorder — Perfetto-loadable timelines of engine work.

Records ``trace_event`` JSON (the chrome://tracing / Perfetto format): "X"
complete events with microsecond ``ts``/``dur``, grouped by pid/tid.  Load
the saved file at https://ui.perfetto.dev or chrome://tracing.

Two span flavours:

  * **measured** — :meth:`TraceRecorder.span` wall-clocks a ``with`` block
    (a chunk step, a decode step, a scrape);
  * **modeled stage sub-spans** — a single jitted ``update_chunk`` dispatch
    executes sort→probe→admit→sweep→scatter fused on device, so the host
    cannot time the stages individually.  :meth:`add_stage_spans` splits a
    measured parent span *proportionally to the roofline byte model* of
    :mod:`repro.roofline.analysis` (each stage's share of modeled HBM
    traffic), attaching ``roofline_frac`` plus the modeled byte count as
    span args and marking them ``modeled: true``.  The sub-spans show
    where the memory-bound model says the time goes — they are a model,
    not a measurement, and are labelled as such.

The recorder is lock-protected (exporter/dashboard threads may flush while
an engine records) and bounded: beyond ``max_events`` new events are
dropped and counted, never grown without limit.
"""

from __future__ import annotations

import contextlib
import json
import threading
import time
from typing import Any, Dict, List, Optional


class TraceRecorder:
    """Collects chrome trace events; ``save()`` writes Perfetto JSON."""

    def __init__(self, *, process_name: str = "repro", max_events: int = 200_000):
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._t0 = time.perf_counter()
        self._pid = 1
        self.max_events = max_events
        self.n_dropped = 0
        self._emit_meta(process_name)

    def _emit_meta(self, process_name: str) -> None:
        self._events.append({
            "name": "process_name", "ph": "M", "pid": self._pid, "tid": 0,
            "args": {"name": process_name},
        })

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _push(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            if len(self._events) >= self.max_events:
                self.n_dropped += 1
                return
            self._events.append(ev)

    # -- recording ---------------------------------------------------------

    @contextlib.contextmanager
    def span(self, name: str, *, tid: int = 0,
             args: Optional[Dict[str, Any]] = None):
        """Wall-clock a block as an "X" complete event.  Yields a dict the
        block may mutate to add args after the fact; the event's ts/dur are
        filled on exit."""
        extra: Dict[str, Any] = dict(args or {})
        t0 = self._now_us()
        try:
            yield extra
        finally:
            t1 = self._now_us()
            self._push({
                "name": name, "ph": "X", "pid": self._pid, "tid": tid,
                "ts": t0, "dur": max(t1 - t0, 0.01), "args": extra,
            })

    def complete(self, name: str, ts_us: float, dur_us: float, *,
                 tid: int = 0, args: Optional[Dict[str, Any]] = None) -> None:
        """Record a complete event with explicit timing (already measured)."""
        self._push({
            "name": name, "ph": "X", "pid": self._pid, "tid": tid,
            "ts": ts_us, "dur": max(dur_us, 0.01), "args": dict(args or {}),
        })

    def instant(self, name: str, *, tid: int = 0,
                args: Optional[Dict[str, Any]] = None) -> None:
        self._push({
            "name": name, "ph": "i", "s": "t", "pid": self._pid, "tid": tid,
            "ts": self._now_us(), "args": dict(args or {}),
        })

    def counter(self, name: str, values: Dict[str, float], *,
                tid: int = 0) -> None:
        """A "C" counter event — renders as a stacked area track."""
        self._push({
            "name": name, "ph": "C", "pid": self._pid, "tid": tid,
            "ts": self._now_us(),
            "args": {k: float(v) for k, v in values.items()},
        })

    def add_stage_spans(self, parent_name: str, ts_us: float, dur_us: float,
                        stages: Dict[str, float], *, tid: int = 0,
                        args: Optional[Dict[str, Any]] = None) -> None:
        """Model-apportioned sub-spans under a measured parent interval.

        ``stages`` maps stage name → modeled bytes (e.g. the ``stages``
        dict of :func:`repro.roofline.analysis.keyed_update_cost`).  The
        parent duration is split proportionally; each sub-span carries
        ``roofline_frac`` (its share), ``modeled_bytes``, and
        ``modeled: true`` in args.
        """
        total = float(sum(stages.values()))
        if total <= 0 or dur_us <= 0:
            return
        cursor = ts_us
        shared = dict(args or {})
        for stage, b in stages.items():
            frac = float(b) / total
            d = dur_us * frac
            self._push({
                "name": f"{parent_name}/{stage}", "ph": "X",
                "pid": self._pid, "tid": tid, "ts": cursor,
                "dur": max(d, 0.01),
                "args": {"roofline_frac": round(frac, 4),
                         "modeled_bytes": float(b), "modeled": True,
                         **shared},
            })
            cursor += d

    # -- output ------------------------------------------------------------

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def to_json(self) -> Dict[str, Any]:
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
        return path

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)
