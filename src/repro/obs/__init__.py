"""Unified observability: counters, metrics registry, exporter, traces.

The spine every engine reports into (ROADMAP: streaming analytics
service → Prometheus-style endpoint + live dashboard).  Layout:

  counters.py   host counter groups; the ONE home of the
                effects-barrier-before-read discipline (absorbs the old
                ``ADMISSION_COUNTS`` / ``COMBINE_COUNTS`` module globals)
  registry.py   MetricsRegistry (counters/gauges/KLL histograms +
                collector pulls, ONE host sync per scrape), ObsConfig
                (the per-engine gate: disabled ⇒ byte-identical jaxpr)
  exporter.py   /metrics Prometheus text endpoint (stdlib http.server)
  trace.py      chrome-trace span recorder (Perfetto-loadable), with
                roofline-apportioned stage sub-spans
  dashboard.py  terminal live view (throughput, p50/p95/p99, watermark
                lag, admission rates)

Import cost: this package only pulls numpy + stdlib at import; jax is
imported lazily inside scrape/drain paths so ``import repro.obs`` stays
cheap for tooling.
"""

from repro.obs import counters
from repro.obs.counters import Counter, CounterGroup, read_all, reset_all
from repro.obs.registry import (
    Gauge,
    HostCounter,
    KLLHistogram,
    MetricsRegistry,
    ObsConfig,
    default_registry,
)

__all__ = [
    "counters",
    "Counter",
    "CounterGroup",
    "read_all",
    "reset_all",
    "Gauge",
    "HostCounter",
    "KLLHistogram",
    "MetricsRegistry",
    "ObsConfig",
    "default_registry",
    "MetricsExporter",
    "TraceRecorder",
    "Dashboard",
]


def __getattr__(name):
    # heavier surfaces resolve lazily so `import repro.obs` needs no
    # http.server / dashboard machinery until asked for
    if name == "MetricsExporter":
        from repro.obs.exporter import MetricsExporter

        return MetricsExporter
    if name == "TraceRecorder":
        from repro.obs.trace import TraceRecorder

        return TraceRecorder
    if name == "Dashboard":
        from repro.obs.dashboard import Dashboard

        return Dashboard
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
