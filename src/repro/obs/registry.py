"""Metrics registry: counters, gauges, KLL histograms, batched scrape.

The observability spine every engine reports into.  Three ingestion paths,
chosen so that NOTHING here ever adds a per-event device callback to a hot
path (the PR-2 telemetry lesson — per-event host roundtrips were 100×):

  * **host counters / gauges** (:class:`repro.obs.counters.CounterGroup`,
    :class:`Gauge`) — plain Python values, bumped from host driver code or
    from the engines' existing ``jax.debug.callback`` instrumentation;
  * **KLL histograms** (:class:`KLLHistogram`) — ``observe()`` appends to a
    host-side buffer (no dispatch); the buffered values are folded into the
    fixed-shape mergeable sketch of :func:`repro.core.monoids.kll_monoid`
    in ONE jitted dispatch at scrape time (or when the buffer fills);
  * **collectors** — callables registered by the engines that return a
    ``{series_name: value}`` dict of *device or host* scalars pulled
    straight from engine state.  The registry gathers every collector's
    tree and host-transfers it in ONE ``jax.device_get`` per scrape.

:meth:`MetricsRegistry.scrape` is therefore: one ``jax.effects_barrier()``
(flushing the counter-group debug callbacks — the discipline lives in
:mod:`repro.obs.counters`), one histogram drain, one batched device
transfer.  Engines in steady state pay nothing beyond the instrumentation
they were explicitly built with.

Series names follow Prometheus conventions (``repro_<engine>_<what>``,
``_total`` suffix for counters); a collector may attach labels inline:
``repro_keyed_shard_dropped_total{shard="2"}``.

:class:`ObsConfig` is the single gate engines take: ``enabled=False`` (or
``obs=None``) must leave the engine's traced computation byte-identical to
an uninstrumented build — the overhead tests assert jaxpr equality.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.obs.counters import CounterGroup

PyTree = Any

_QUANTILES = (0.5, 0.95, 0.99)


def _escape_help(s: str) -> str:
    return s.replace("\\", "\\\\").replace("\n", "\\n")


def _escape_label(s: str) -> str:
    return s.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _format_value(v: float) -> str:
    f = float(v)
    if np.isnan(f):
        return "NaN"
    if np.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    return repr(f)


def split_series(name: str) -> Tuple[str, Dict[str, str]]:
    """``'foo{a="1",b="x"}'`` → ``('foo', {'a': '1', 'b': 'x'})``."""
    if "{" not in name:
        return name, {}
    base, rest = name.split("{", 1)
    rest = rest.rstrip("}")
    labels: Dict[str, str] = {}
    for part in rest.split(","):
        if not part:
            continue
        k, v = part.split("=", 1)
        labels[k.strip()] = v.strip().strip('"')
    return base, labels


class Gauge:
    """A host-set gauge family; ``set()`` with optional labels."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._vals: Dict[Tuple[Tuple[str, str], ...], float] = {}

    def set(self, value: float, labels: Optional[Dict[str, str]] = None) -> None:
        key = tuple(sorted((labels or {}).items()))
        self._vals[key] = float(value)

    def samples(self) -> List[Tuple[Dict[str, str], float]]:
        return [(dict(k), v) for k, v in self._vals.items()]


class HostCounter:
    """A host-bumped monotone counter family (no labels)."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class KLLHistogram:
    """Latency/size distribution as a mergeable KLL sketch.

    ``observe(x)`` is host-append only; the buffer is folded into the
    fixed-shape sketch (:func:`repro.core.monoids.kll_monoid`) in one
    jitted dispatch per drain — padded to power-of-two lengths so a drifting
    buffer size reuses O(log) compilations.  Rendered as a Prometheus
    ``summary`` (quantile-labelled gauges + ``_count`` / ``_sum``).
    """

    def __init__(
        self,
        name: str,
        help: str = "",
        *,
        k: int = 64,
        levels: int = 8,
        quantiles: Tuple[float, ...] = _QUANTILES,
    ):
        from repro.core.monoids import kll_monoid

        self.name = name
        self.help = help
        self.quantiles = tuple(quantiles)
        self._m = kll_monoid(k=k, levels=levels, quantiles=self.quantiles)
        self._agg = self._m.identity()
        self._buf: List[float] = []
        self._lock = threading.Lock()
        self._drain_lock = threading.Lock()
        self.count = 0
        self.sum = 0.0
        self._drain_jits: Dict[int, Callable] = {}

    def observe(self, value: float) -> None:
        with self._lock:
            self._buf.append(float(value))
            self.count += 1
            self.sum += float(value)

    def observe_many(self, values) -> None:
        arr = np.asarray(values, np.float64).ravel()
        with self._lock:
            self._buf.extend(arr.tolist())
            self.count += arr.size
            self.sum += float(arr.sum())

    def _drain_fn(self, n: int) -> Callable:
        fn = self._drain_jits.get(n)
        if fn is None:
            import jax
            import jax.numpy as jnp

            from repro.core.event_time import fold_axis0

            m = self._m

            def drain(agg, vals, mask):
                lifted = jax.vmap(m.lift)(vals)
                ident = m.identity()
                lifted = jax.tree.map(
                    lambda a, i: jnp.where(
                        mask.reshape((-1,) + (1,) * (a.ndim - 1)),
                        a,
                        jnp.asarray(i, a.dtype),
                    ),
                    lifted,
                    ident,
                )
                return m.combine(agg, fold_axis0(m, lifted))

            fn = self._drain_jits[n] = jax.jit(drain)
        return fn

    def drain(self) -> None:
        """Fold the pending buffer into the sketch: ONE jitted dispatch.

        ``_drain_lock`` serializes the whole pop→fold→assign sequence:
        two concurrent scrapes would otherwise pop disjoint buffers but
        race the unlocked ``_agg`` read-modify-write, silently losing one
        fold.  ``observe()`` only ever takes the buffer lock, so the hot
        path never waits on a device dispatch."""
        with self._drain_lock:
            with self._lock:
                buf, self._buf = self._buf, []
            if not buf:
                return
            import jax.numpy as jnp

            n = 1
            while n < len(buf):
                n *= 2
            vals = np.zeros(n, np.float32)
            vals[: len(buf)] = buf
            mask = np.arange(n) < len(buf)
            self._agg = self._drain_fn(n)(
                self._agg, jnp.asarray(vals), jnp.asarray(mask)
            )

    def quantile_values(self):
        """Device array of the configured quantiles (drains first)."""
        from repro.core.monoids import kll_quantiles

        self.drain()
        return kll_quantiles(self._agg, self.quantiles)

    def aggregate(self) -> PyTree:
        """The raw mergeable sketch Agg (drains first) — checkpoint or
        cross-process merge payload."""
        self.drain()
        return self._agg


@dataclasses.dataclass
class ObsConfig:
    """The one gate engines consult before instrumenting anything.

    ``enabled=False`` — or passing ``obs=None`` — must leave the engine's
    traced computation byte-identical to an uninstrumented build: no debug
    callbacks, no extra outputs, donation intact.  The flags below opt into
    the jit-visible instrumentation the engines already support (admission
    branch callbacks; combine counting, which forces the lax sweep path) —
    they only take effect while ``enabled``.
    """

    enabled: bool = True
    registry: Optional["MetricsRegistry"] = None
    trace: Optional[Any] = None  # a repro.obs.trace.TraceRecorder
    instrument_admission: bool = False
    instrument_combines: bool = False

    @property
    def active(self) -> bool:
        return self.enabled

    def resolved_registry(self) -> "MetricsRegistry":
        return self.registry if self.registry is not None else default_registry()

    def admission_flag(self) -> bool:
        return self.enabled and self.instrument_admission

    def combines_flag(self) -> bool:
        return self.enabled and self.instrument_combines


class MetricsRegistry:
    """Registry + scrape: every metric family this process exposes.

    ``scrape()`` returns ``{series_name: float}`` after one effects
    barrier, one histogram drain per registered histogram, and ONE batched
    ``jax.device_get`` over every collector's pulled state.  ``render()``
    emits Prometheus text exposition format 0.0.4.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counter_groups: List[CounterGroup] = []
        self._gauges: Dict[str, Gauge] = {}
        self._counters: Dict[str, HostCounter] = {}
        self._histograms: Dict[str, KLLHistogram] = {}
        self._collectors: List[Callable[[], Dict[str, Any]]] = []
        self._descriptions: Dict[str, Tuple[str, str]] = {}  # name -> (type, help)

    # -- registration ------------------------------------------------------

    def gauge(self, name: str, help: str = "") -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name, help)
            return g

    def counter(self, name: str, help: str = "") -> HostCounter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = HostCounter(name, help)
            return c

    def histogram(self, name: str, help: str = "", **kll_kwargs) -> KLLHistogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = KLLHistogram(name, help, **kll_kwargs)
            return h

    def counter_group(self, group: CounterGroup) -> CounterGroup:
        """Adopt a :class:`repro.obs.counters.CounterGroup` (e.g. the
        admission/combine groups) into this registry's exposition."""
        with self._lock:
            if group not in self._counter_groups:
                self._counter_groups.append(group)
        return group

    def register_collector(self, fn: Callable[[], Dict[str, Any]]) -> None:
        """``fn()`` → ``{series_name: scalar}`` pulled at every scrape.
        Values may be live device arrays — the registry batches the host
        transfer.  Series names may carry inline labels
        (``name{shard="0"}``).  A collector that raises is skipped for that
        scrape (e.g. its engine state was donated away mid-flight)."""
        with self._lock:
            self._collectors.append(fn)

    def describe(self, name: str, type: str = "gauge", help: str = "") -> None:
        """Pre-declare TYPE/HELP for collector-produced series."""
        self._descriptions[name] = (type, help)

    # -- scrape ------------------------------------------------------------

    def scrape(self) -> Dict[str, float]:
        """Flat ``{series: value}`` snapshot — ONE effects barrier (via the
        counter groups), ONE batched device transfer for collectors."""
        import jax

        jax.effects_barrier()  # flush debug-callback counter bumps
        out: Dict[str, float] = {}
        with self._lock:
            groups = list(self._counter_groups)
            gauges = list(self._gauges.values())
            counters = list(self._counters.values())
            hists = list(self._histograms.values())
            collectors = list(self._collectors)
        for g in groups:
            for k, v in g._vals.items():
                out[f'{g.name}_total{{{g.label}="{_escape_label(k)}"}}'] = float(v)
        for c in counters:
            out[f"{c.name}_total"] = float(c.value)
        for g in gauges:
            for labels, v in g.samples():
                if labels:
                    lab = ",".join(
                        f'{k}="{_escape_label(str(vv))}"'
                        for k, vv in sorted(labels.items())
                    )
                    out[f"{g.name}{{{lab}}}"] = v
                else:
                    out[g.name] = v
        # collectors: pull every tree, transfer once; a failing collector
        # (donated-away state, torn-down engine) is skipped this scrape
        pulled: List[Dict[str, Any]] = []
        for fn in collectors:
            try:
                pulled.append(dict(fn()))
            except Exception:
                continue
        try:
            pulled = jax.device_get(pulled)
        except Exception:
            safe = []
            for d in pulled:
                try:
                    safe.append(jax.device_get(d))
                except Exception:
                    continue
            pulled = safe
        for d in pulled:
            for name, v in d.items():
                out[name] = float(np.asarray(v))
        # histograms last: drain (one dispatch each) then batch the
        # quantile transfers
        qvals = [h.quantile_values() for h in hists]
        qvals = jax.device_get(qvals)
        for h, qs in zip(hists, qvals):
            for q, v in zip(h.quantiles, np.asarray(qs).ravel()):
                out[f'{h.name}{{quantile="{q:g}"}}'] = float(v)
            out[f"{h.name}_count"] = float(h.count)
            out[f"{h.name}_sum"] = float(h.sum)
        return out

    # -- exposition --------------------------------------------------------

    def _family_meta(self, base: str) -> Tuple[str, str]:
        if base in self._descriptions:
            return self._descriptions[base]
        for g in self._counter_groups:
            if base == f"{g.name}_total":
                return "counter", g.help
        for name, c in self._counters.items():
            if base == f"{name}_total":
                return "counter", c.help
        if base in self._gauges:
            return "gauge", self._gauges[base].help
        for name, h in self._histograms.items():
            if base in (name, f"{name}_count", f"{name}_sum"):
                return "summary", h.help
        if base.endswith("_total"):
            return "counter", ""
        return "gauge", ""

    def render(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        samples = self.scrape()
        by_family: Dict[str, List[Tuple[str, float]]] = {}
        for series, value in samples.items():
            base, _ = split_series(series)
            # summary sub-series group under the histogram family name
            for h in self._histograms.values():
                if base in (f"{h.name}_count", f"{h.name}_sum"):
                    base = h.name
                    break
            by_family.setdefault(base, []).append((series, value))
        lines: List[str] = []
        for base in sorted(by_family):
            typ, help = self._family_meta(base)
            if help:
                lines.append(f"# HELP {base} {_escape_help(help)}")
            lines.append(f"# TYPE {base} {typ}")
            for series, value in sorted(by_family[base]):
                lines.append(f"{series} {_format_value(value)}")
        return "\n".join(lines) + "\n"


_DEFAULT: Optional[MetricsRegistry] = None
_DEFAULT_LOCK = threading.Lock()


def default_registry() -> MetricsRegistry:
    """The process-wide registry (created on first use, with the system
    counter groups pre-adopted)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            from repro.obs import counters as _counters

            _DEFAULT = MetricsRegistry()
            for g in _counters.GROUPS:
                _DEFAULT.counter_group(g)
        return _DEFAULT


class Timer:
    """Tiny context helper: ``with Timer() as t: ... ; t.ms``."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.dt = time.perf_counter() - self.t0
        self.ms = self.dt * 1e3
        return False
