"""Host-side counter groups — the ONE home of the effects-barrier-before-read
discipline.

Engines that need runtime branch/op counts (which ``lax.cond`` branch ran,
how many ⊗ a sweep really executed) bump these counters from
``jax.debug.callback`` hooks inside jitted code.  Callbacks are flushed
asynchronously, so a reader that grabs the Python value races the device —
EVERY read must be preceded by ``jax.effects_barrier()``.  That rule used to
be re-stated (and re-forgotten) at every ad-hoc module global
(``repro.core.keyed.ADMISSION_COUNTS``, ``repro.core.event_time
.COMBINE_COUNTS``); it now lives in exactly one place: :meth:`CounterGroup
.read` and :func:`read_all` barrier before touching the values, and the
metrics registry's scrape path goes through them.

A :class:`CounterGroup` is dict-like on purpose — the legacy globals are
kept as thin aliases of the groups below, so ``ADMISSION_COUNTS["fast"]``
keeps working — but new code should use :meth:`bump` / :meth:`read` /
:meth:`reset`.

This module depends on nothing inside :mod:`repro` (the core engines import
it at module load; anything heavier would be a cycle).
"""

from __future__ import annotations

import threading
from collections.abc import MutableMapping
from typing import Dict, Iterator, Tuple


class Counter:
    """A single monotone host counter (the eager per-op counting primitive —
    :func:`repro.core.monoids.counting` hands these out)."""

    __slots__ = ("count",)

    def __init__(self):
        self.count = 0

    def inc(self, n: int = 1) -> None:
        self.count += n

    def reset(self) -> None:
        self.count = 0


class CounterGroup(MutableMapping):
    """Named family of host counters, one label per key.

    ``name`` / ``label`` / ``help`` describe the family for Prometheus
    exposition (rendered as ``<name>_total{<label>="<key>"}``).  Keys are
    dynamic: bumping an unseen key creates it at 0 first, so callers never
    pre-declare.  Mutation is lock-protected — debug callbacks may fire from
    runtime threads.
    """

    def __init__(self, name: str, *, label: str = "kind", help: str = "",
                 keys: Tuple[str, ...] = ()):
        self.name = name
        self.label = label
        self.help = help
        self._lock = threading.Lock()
        self._vals: Dict[str, int] = {k: 0 for k in keys}

    # -- the API -----------------------------------------------------------

    def bump(self, key: str, n: int = 1) -> None:
        with self._lock:
            self._vals[key] = self._vals.get(key, 0) + n

    def read(self) -> Dict[str, int]:
        """Barrier-then-snapshot: flushes pending ``jax.debug`` callbacks
        (the one place the rule is enforced) and returns a plain dict."""
        _barrier()
        with self._lock:
            return dict(self._vals)

    def reset(self) -> None:
        _barrier()  # drain in-flight bumps so they don't land post-reset
        with self._lock:
            for k in self._vals:
                self._vals[k] = 0

    # -- dict compatibility (the legacy-alias surface) ---------------------
    # NOTE: plain item access does NOT barrier — it exists so legacy
    # ``COUNTS["key"]`` reads keep working verbatim (those call sites
    # already barrier manually).  Prefer read().

    def __getitem__(self, key: str) -> int:
        with self._lock:
            return self._vals[key]

    def __setitem__(self, key: str, value: int) -> None:
        with self._lock:
            self._vals[key] = int(value)

    def __delitem__(self, key: str) -> None:
        with self._lock:
            del self._vals[key]

    def __iter__(self) -> Iterator[str]:
        return iter(dict(self._vals))

    def __len__(self) -> int:
        return len(self._vals)

    def __repr__(self) -> str:
        return f"CounterGroup({self.name!r}, {self._vals!r})"


def _barrier() -> None:
    import jax

    jax.effects_barrier()


# ---------------------------------------------------------------------------
# The system-wide groups (the former module globals, one home)
# ---------------------------------------------------------------------------

# which admission branch KeyDirectory.admit_heads took per chunk
# (stores built with instrument_admission=True)
admission = CounterGroup(
    "swag_admission_branch",
    label="branch",
    help="keyed-store admission dispatches per lax.cond branch "
         "(fast = all-hit recency bump, slow = batched allocation rounds)",
    keys=("fast", "slow"),
)

# runtime ⊗ invocations in the instrumented flip sweeps
# (engines built with instrument_combines=True)
combines = CounterGroup(
    "swag_combines",
    label="engine",
    help="monoid combine invocations executed by instrumented flip sweeps, "
         "weighted by the static row count each combine touched",
    keys=("eventtime", "keyed"),
)

# which release branch EventTimeChunkedStream took per chunk
# (engines built with instrument_release=True): fast = in-order append at
# the frontier, zero sort dispatches; slow = bounded sort + rank merge
releases = CounterGroup(
    "swag_release_branch",
    label="branch",
    help="event-time release dispatches per lax.cond branch "
         "(fast = in-order frontier append, no sort; slow = bounded "
         "stable sort + rank merge of the trailing region)",
    keys=("fast", "slow"),
)

GROUPS: Tuple[CounterGroup, ...] = (admission, combines, releases)


def read_all() -> Dict[str, Dict[str, int]]:
    """One barrier, then a snapshot of every system counter group."""
    _barrier()
    return {g.name: dict(g._vals) for g in GROUPS}


def reset_all() -> None:
    _barrier()
    for g in GROUPS:
        with g._lock:
            for k in g._vals:
                g._vals[k] = 0
