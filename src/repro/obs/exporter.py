"""Prometheus text-exposition endpoint over stdlib ``http.server``.

``MetricsExporter(registry).start()`` serves::

    GET /metrics   → text format 0.0.4 (registry.render(): ONE scrape)
    GET /healthz   → "ok"

on a daemon thread; ``port=0`` binds an ephemeral port (read ``.port`` /
``.url`` after ``start()``).  Each ``/metrics`` hit performs exactly one
registry scrape — a scraper at 1 Hz costs one effects barrier + one batched
device transfer per second, nothing per event (the acceptance criterion:
keyed throughput within 10% with the exporter attached).

No dependencies beyond the stdlib; scrape errors return 500 with the
traceback body instead of killing the serving thread.
"""

from __future__ import annotations

import threading
import traceback
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class MetricsExporter:
    """Background HTTP server exposing a :class:`MetricsRegistry`."""

    def __init__(self, registry=None, *, host: str = "127.0.0.1",
                 port: int = 0):
        if registry is None:
            from repro.obs.registry import default_registry

            registry = default_registry()
        self.registry = registry
        self.host = host
        self._requested_port = port
        self._server: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "MetricsExporter":
        registry = self.registry

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (http.server API)
                if self.path.split("?")[0] == "/healthz":
                    body = b"ok\n"
                    self.send_response(200)
                    self.send_header("Content-Type", "text/plain")
                    self.send_header("Content-Length", str(len(body)))
                    self.end_headers()
                    self.wfile.write(body)
                    return
                if self.path.split("?")[0] != "/metrics":
                    self.send_response(404)
                    self.end_headers()
                    return
                try:
                    body = registry.render().encode("utf-8")
                    code, ctype = 200, CONTENT_TYPE
                except Exception:
                    body = traceback.format_exc().encode("utf-8")
                    code, ctype = 500, "text/plain"
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):  # quiet: no per-scrape stderr spam
                pass

        self._server = ThreadingHTTPServer(
            (self.host, self._requested_port), Handler
        )
        self._server.daemon_threads = True
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="obs-exporter", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
            self._server = None
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    # -- address -----------------------------------------------------------

    @property
    def port(self) -> int:
        if self._server is None:
            raise RuntimeError("exporter not started")
        return self._server.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def __enter__(self) -> "MetricsExporter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
