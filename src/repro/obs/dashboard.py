"""Terminal live dashboard over a :class:`MetricsRegistry`.

Renders a compact operator view — throughput, latency quantiles
(p50/p95/p99 from the KLL summaries), watermark lag, admission-branch
rates, live keys, drop/evict counters — refreshed in place with ANSI
escapes.  Counter *rates* are computed from deltas between consecutive
scrapes, so one ``Dashboard`` instance should own its refresh loop.

Modes:

  * ``run(seconds=…, interval=…)`` — clears and redraws a TTY at
    ``interval`` (default 1 Hz; one registry scrape per frame);
  * ``render_once()`` — one plain-text frame, no escapes (``--no-tty`` /
    CI logs).

The dashboard is a pure registry consumer: it works against any engine
combination that reports into the registry, locally or scraped over the
exporter's wire format.
"""

from __future__ import annotations

import sys
import time
from typing import Dict, List, Optional, Tuple

from repro.obs.registry import MetricsRegistry, split_series

_CLEAR = "\x1b[2J\x1b[H"
_BOLD = "\x1b[1m"
_DIM = "\x1b[2m"
_RESET = "\x1b[0m"


def _fmt(v: float) -> str:
    a = abs(v)
    if a >= 1e9:
        return f"{v / 1e9:.2f}G"
    if a >= 1e6:
        return f"{v / 1e6:.2f}M"
    if a >= 1e3:
        return f"{v / 1e3:.2f}k"
    if a == 0 or a >= 1:
        return f"{v:.2f}".rstrip("0").rstrip(".")
    return f"{v:.4g}"


class Dashboard:
    """Scrape → diff → render loop for the terminal."""

    def __init__(self, registry: Optional[MetricsRegistry] = None, *,
                 out=None, color: Optional[bool] = None):
        if registry is None:
            from repro.obs.registry import default_registry

            registry = default_registry()
        self.registry = registry
        self.out = out or sys.stdout
        self.color = self.out.isatty() if color is None else color
        self._prev: Optional[Dict[str, float]] = None
        self._prev_t: float = 0.0

    # -- framing -----------------------------------------------------------

    def _snapshot(self) -> Tuple[Dict[str, float], Dict[str, float]]:
        """One scrape; returns (samples, counter rates/s vs last frame)."""
        now = time.perf_counter()
        cur = self.registry.scrape()
        rates: Dict[str, float] = {}
        if self._prev is not None:
            dt = max(now - self._prev_t, 1e-9)
            for name, v in cur.items():
                base, _ = split_series(name)
                if base.endswith("_total") or base.endswith("_count"):
                    rates[name] = (v - self._prev.get(name, 0.0)) / dt
        self._prev, self._prev_t = cur, now
        return cur, rates

    def _style(self, s: str, code: str) -> str:
        return f"{code}{s}{_RESET}" if self.color else s

    def compose(self, cur: Dict[str, float],
                rates: Dict[str, float]) -> str:
        """One frame of text from a scrape + rate dict."""
        lines: List[str] = []
        title = "repro · live engine metrics"
        lines.append(self._style(title, _BOLD))
        lines.append(self._style(time.strftime("%H:%M:%S"), _DIM))
        lines.append("")

        # summaries: group quantile series per family
        summaries: Dict[str, Dict[str, float]] = {}
        plain: List[Tuple[str, float]] = []
        for name, v in sorted(cur.items()):
            base, labels = split_series(name)
            if "quantile" in labels:
                summaries.setdefault(base, {})[labels["quantile"]] = v
            else:
                plain.append((name, v))
        if summaries:
            lines.append(self._style("latency / distributions", _BOLD))
            for base, qs in summaries.items():
                qtxt = "  ".join(
                    f"p{float(q) * 100:g}={_fmt(v)}"
                    for q, v in sorted(qs.items(), key=lambda kv: float(kv[0]))
                )
                n = cur.get(f"{base}_count", 0.0)
                r = rates.get(f"{base}_count")
                rate = f"  {_fmt(r)}/s" if r is not None else ""
                lines.append(f"  {base:<44} {qtxt}  n={_fmt(n)}{rate}")
            lines.append("")

        # counters with rates, then gauges
        ctr = [(n, v) for n, v in plain
               if split_series(n)[0].endswith(("_total", "_count"))]
        gau = [(n, v) for n, v in plain
               if not split_series(n)[0].endswith(
                   ("_total", "_count", "_sum"))]
        if ctr:
            lines.append(self._style("counters", _BOLD))
            for name, v in ctr:
                r = rates.get(name)
                rate = f"  {_fmt(r)}/s" if r is not None else ""
                lines.append(f"  {name:<52} {_fmt(v):>10}{rate}")
            lines.append("")
        if gau:
            lines.append(self._style("gauges", _BOLD))
            for name, v in gau:
                lines.append(f"  {name:<52} {_fmt(v):>10}")
        return "\n".join(lines)

    # -- drive -------------------------------------------------------------

    def render_once(self) -> str:
        """One plain frame (also what ``--no-tty`` prints per tick)."""
        cur, rates = self._snapshot()
        frame = self.compose(cur, rates)
        return frame

    def tick(self) -> None:
        """Scrape and redraw in place (TTY mode)."""
        frame = self.render_once()
        if self.color:
            self.out.write(_CLEAR)
        self.out.write(frame + "\n")
        self.out.flush()

    def run(self, seconds: float, interval: float = 1.0) -> None:
        """Refresh loop for ``seconds`` at ``interval`` (1 Hz default —
        the attached-overhead acceptance configuration)."""
        deadline = time.perf_counter() + seconds
        while time.perf_counter() < deadline:
            self.tick()
            time.sleep(interval)
