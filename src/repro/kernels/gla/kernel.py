"""Pallas TPU kernel: fused chunked gated-linear-attention (GLA / SSD).

The §Perf conclusion for rwkv6 × train_4k: its roofline gap is pure memory
traffic — the unfused chunked-GLA chain (cumulative decays, normalized keys,
score matrices, state read/write) round-trips HBM between every op.  This
kernel fuses one chunk's entire computation into a single VMEM-resident body
and carries the (K, V) recurrent state in VMEM scratch across the sequential
chunk grid — the state never touches HBM between chunks.

Math per chunk (length L, Mamba-2 / inclusive-read convention):
    P_t   = ∏_{{j≤t}} a_j                       (cumulative decay, in-chunk)
    o_t   = (r_t ⊙ P_t)·S₀ + Σ_{{j≤t}} [(r_t⊙P_t)·(k_j/P_j)] v_j
    S_L   = P_L ⊙ S₀ + Σ_j ((P_L/P_j) ⊙ k_j) ⊗ v_j

Grid ``(B·H, T/L)`` — the chunk axis is innermost/sequential, state scratch
``(K, V)`` f32 persists across it (same carry pattern as kernels/suffix_scan).
Inputs are blocked as (1, L, K|V) VMEM tiles.  MXU does the three einsums;
the decay cumprod is a log-space cumsum on VPU lanes.

RWKV's pre-decay read + bonus-u variant differs only in using P_{{t-1}}, a
strict mask, and a diag(u) self term — exposed via ``variant=\"rwkv\"`` (the
bonus vector is passed as an extra (1, K) operand).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gla_kernel(r_ref, k_ref, v_ref, a_ref, u_ref, o_ref, s_ref,
                *, variant: str, L: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros(s_ref.shape, jnp.float32)

    r = r_ref[0].astype(jnp.float32)  # (L, K)
    k = k_ref[0].astype(jnp.float32)  # (L, K)
    v = v_ref[0].astype(jnp.float32)  # (L, V)
    a = a_ref[0].astype(jnp.float32)  # (L, K)

    logp = jnp.cumsum(jnp.log(jnp.maximum(a, 1e-12)), axis=0)  # (L, K)
    P = jnp.exp(logp)
    k_n = k / jnp.maximum(P, 1e-24)

    if variant == "rwkv":
        P_read = jnp.exp(logp - jnp.log(jnp.maximum(a, 1e-12)))  # P_{t-1}
        mask = jnp.tril(jnp.ones((L, L), jnp.float32), k=-1)
    else:
        P_read = P
        mask = jnp.tril(jnp.ones((L, L), jnp.float32))

    r_t = r * P_read  # (L, K)
    s0 = s_ref[...]  # (K, V) f32, VMEM-resident across chunks
    inter = jnp.dot(r_t, s0, preferred_element_type=jnp.float32)  # (L, V)
    scores = jnp.dot(r_t, k_n.T, preferred_element_type=jnp.float32)  # (L, L)
    scores = scores * mask
    intra = jnp.dot(scores, v, preferred_element_type=jnp.float32)  # (L, V)
    o = inter + intra
    if variant == "rwkv":
        u = u_ref[0].astype(jnp.float32)  # (1, K) bonus
        s_self = jnp.sum(r * u * k, axis=1, keepdims=True)  # (L, 1)
        o = o + s_self * v

    PL = P[-1:]  # (1, K)
    s_ref[...] = PL.T * s0 + jnp.dot(
        (k_n * PL).T, v, preferred_element_type=jnp.float32
    )
    o_ref[0] = o.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "variant", "interpret")
)
def gla_pallas(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    a: jax.Array,
    bonus_u: jax.Array | None = None,
    *,
    chunk: int = 64,
    variant: str = "mamba",
    interpret: bool = True,
) -> jax.Array:
    """Fused chunked GLA.  r,k,a: (B,T,H,K); v: (B,T,H,V) → (B,T,H,V).

    Zero initial state (add an inter-chunk prologue chunk to seed one).
    """
    B, T, H, K = r.shape
    V = v.shape[-1]
    L = min(chunk, T)
    Tp = math.ceil(T / L) * L

    def prep(x, fill=0.0):
        if Tp != T:
            x = jnp.pad(x, ((0, 0), (0, Tp - T), (0, 0), (0, 0)),
                        constant_values=fill)
        # (B,T,H,·) → (B·H, T, ·)
        return x.transpose(0, 2, 1, 3).reshape(B * H, Tp, x.shape[-1])

    rf, kf, vf = prep(r), prep(k), prep(v)
    af = prep(a, fill=1.0)
    if bonus_u is None:
        uf = jnp.zeros((B * H, 1, K), r.dtype)
    else:  # (H, K) → per (b,h) row
        uf = jnp.broadcast_to(bonus_u[None], (B, H, K)).reshape(B * H, 1, K)

    nc = Tp // L
    out = pl.pallas_call(
        functools.partial(_gla_kernel, variant=variant, L=L),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, L, K), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, L, K), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, L, V), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, L, K), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, 1, K), lambda bh, c: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, V), lambda bh, c: (bh, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tp, V), v.dtype),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, af, uf)
    out = out.reshape(B, H, Tp, V).transpose(0, 2, 1, 3)
    return out[:, :T]
