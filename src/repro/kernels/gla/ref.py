"""Oracle for the fused GLA kernel: the model substrate's own sequential scan
(repro.models.ssm.gla_sequential), which the chunked forms are tested against."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.ssm import gla_sequential


def gla_ref(r, k, v, a, bonus_u=None, variant: str = "mamba"):
    B, T, H, K = r.shape
    V = v.shape[-1]
    s0 = jnp.zeros((B, H, K, V), jnp.float32)
    bu = bonus_u if variant == "rwkv" else None
    out, _ = gla_sequential(
        r.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), a.astype(jnp.float32), s0, bonus_u=bu,
    )
    return out
