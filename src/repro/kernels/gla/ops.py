"""Public op: fused chunked GLA with kernel/oracle dispatch.

On TPU this is the drop-in fast path for the rwkv6/zamba2 recurrence — the
HBM round-trips of the unfused chunk chain (the §Perf cell-3 memory-term
bound) collapse into one VMEM-resident body with the state carried in
scratch.  On CPU it runs in interpret mode for validation.
"""

from __future__ import annotations

import jax

from repro.kernels.gla.kernel import gla_pallas
from repro.kernels.gla.ref import gla_ref


def gla(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    a: jax.Array,
    bonus_u: jax.Array | None = None,
    *,
    chunk: int = 64,
    variant: str = "mamba",
    use_kernel: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_kernel:
        return gla_pallas(
            r, k, v, a, bonus_u, chunk=chunk, variant=variant,
            interpret=interpret,
        )
    return gla_ref(r, k, v, a, bonus_u, variant=variant)
