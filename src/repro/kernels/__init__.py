# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# ops_registry.py is the ONE place elementwise monoid ops
# (sum/prod/min/max/logsumexp) are defined: every kernel and the
# chunked streaming engine (repro.core.chunked) dispatch through it —
# add new ops there and all bulk paths pick them up.
