"""Pure-jnp oracle for dense sliding-window aggregation.

O(T·w) work — slow but trivially correct: for each shift d ∈ [0, w) combine
the d-shifted stream.  Front-truncated windows (t < w-1) aggregate only the
available prefix, matching the SWAG ``query`` semantics during fill.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ops_registry import combine_fn, identity_for


def sliding_window_ref(x: jax.Array, *, window: int, op: str = "sum") -> jax.Array:
    if x.ndim != 2:
        raise ValueError(f"expected (B, T), got {x.shape}")
    comb = combine_fn(op)
    ident = identity_for(op, x.dtype)
    acc = x
    for d in range(1, window):
        shifted = jnp.concatenate(
            [jnp.full((x.shape[0], d), ident, x.dtype), x[:, :-d]], axis=1
        )
        # older operand LEFT (shifted is older)
        acc = comb(shifted, acc)
    return acc
