"""Pallas TPU kernel: dense sliding-window aggregation (VHGW / two-stacks-in-space).

Computes ``y[b, t] = x[b, t-w+1] ⊗ … ⊗ x[b, t]`` (front-truncated) for an
associative ⊗ in **3 combines per element independent of w** — the van
Herk–Gil–Werman scheme, which is exactly the paper's two-stacks decomposition
applied spatially (DESIGN.md §2.2):

  * pad the front with w identities → X' of length T + w,
  * per w-sized block of X': suffix scan S (the "front stack" aggregates) and
    prefix scan P (the "back stack" aggregates),
  * y[t] = S[t+1] ⊗ P[t+w]  — one stitch across the block boundary, the
    dense analogue of ``query() = Π_F ⊗ Π_B``.

Tiling: grid ``(B/Bt, T/w)``.  Output block ``(Bt, w)`` at ``(b, j)`` reads
two input blocks of X': block ``j`` (for S) and block ``j+1`` (for P) — both
``(Bt, w)`` resident in VMEM.  In-block scans are Hillis–Steele with
⌈log₂ w⌉ unrolled shift-combine steps on VPU lanes; no MXU use, the kernel is
bandwidth-bound by design (3 streams: 2 reads + 1 write).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Op tables live in the shared registry so every kernel and the chunked
# streaming engine agree on combine/identity; re-exported here for back-compat.
from repro.kernels.ops_registry import combine_fn, identity_for


def _shift_left(x: jax.Array, d: int, fill) -> jax.Array:
    """x[:, i] ← x[:, i+d], filling the tail with ``fill`` (identity)."""
    tail = jnp.full((x.shape[0], d), fill, x.dtype)
    return jnp.concatenate([x[:, d:], tail], axis=1)


def _shift_right(x: jax.Array, d: int, fill) -> jax.Array:
    head = jnp.full((x.shape[0], d), fill, x.dtype)
    return jnp.concatenate([head, x[:, :-d]], axis=1)


def _suffix_scan_block(x: jax.Array, op: str):
    """In-block inclusive suffix scan: S[i] = x[i] ⊗ … ⊗ x[-1]."""
    comb = combine_fn(op)
    ident = identity_for(op, x.dtype)
    w = x.shape[1]
    d = 1
    while d < w:
        x = comb(x, _shift_left(x, d, ident))
        d *= 2
    return x


def _prefix_scan_block(x: jax.Array, op: str):
    """In-block inclusive prefix scan: P[i] = x[0] ⊗ … ⊗ x[i]."""
    comb = combine_fn(op)
    ident = identity_for(op, x.dtype)
    w = x.shape[1]
    d = 1
    while d < w:
        x = comb(_shift_right(x, d, ident), x)
        d *= 2
    return x


def _vhgw_kernel(xa_ref, xb_ref, o_ref, *, op: str):
    xa = xa_ref[...]  # X' block j   : windows' left fragments  (suffix scan)
    xb = xb_ref[...]  # X' block j+1 : windows' right fragments (prefix scan)
    s = _suffix_scan_block(xa, op)
    p = _prefix_scan_block(xb, op)
    ident = identity_for(op, xa.dtype)
    # y[i] = S[i+1] ⊗ P[i]; at i = w-1 the shifted S is identity and the
    # window is exactly block j+1's prefix — identity-combine keeps it exact.
    o_ref[...] = combine_fn(op)(_shift_left(s, 1, ident), p)


@functools.partial(jax.jit, static_argnames=("window", "op", "block_b", "interpret"))
def sliding_window_pallas(
    x: jax.Array,
    *,
    window: int,
    op: str = "sum",
    block_b: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """Dense sliding-window aggregation over the last axis of ``x`` (B, T)."""
    if x.ndim != 2:
        raise ValueError(f"expected (B, T), got {x.shape}")
    B, T = x.shape
    w = int(window)
    if w <= 1:
        return x

    ident = identity_for(op, x.dtype)
    # Front-pad w identities; right-pad T to a multiple of w.
    T_pad = math.ceil(T / w) * w
    xp = jnp.full((B, T_pad + w), ident, x.dtype).at[:, w : w + T].set(x)
    Bt = min(block_b, B)
    B_pad = math.ceil(B / Bt) * Bt
    if B_pad != B:
        xp = jnp.concatenate(
            [xp, jnp.full((B_pad - B, T_pad + w), ident, x.dtype)], axis=0
        )

    grid = (B_pad // Bt, T_pad // w)
    out = pl.pallas_call(
        functools.partial(_vhgw_kernel, op=op),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bt, w), lambda b, j: (b, j)),      # X' block j
            pl.BlockSpec((Bt, w), lambda b, j: (b, j + 1)),  # X' block j+1
        ],
        out_specs=pl.BlockSpec((Bt, w), lambda b, j: (b, j)),
        out_shape=jax.ShapeDtypeStruct((B_pad, T_pad), x.dtype),
        interpret=interpret,
    )(xp, xp)
    return out[:B, :T]
