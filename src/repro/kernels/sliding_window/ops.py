"""Public op: dense sliding-window aggregation with kernel/oracle dispatch.

On TPU this routes to the Pallas VHGW kernel (3 combines/element, bandwidth
bound).  On CPU (this container) the kernel runs in ``interpret=True`` mode —
the same kernel body, executed in Python, used by tests to validate the TPU
tiling logic.  ``sliding_window_agg`` also accepts >2-D inputs by flattening
leading axes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.sliding_window.kernel import sliding_window_pallas
from repro.kernels.sliding_window.ref import sliding_window_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def sliding_window_agg(
    x: jax.Array,
    window: int,
    op: str = "sum",
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
    block_b: int = 8,
) -> jax.Array:
    """``y[..., t] = x[..., t-w+1] ⊗ … ⊗ x[..., t]`` along the last axis."""
    if interpret is None:
        interpret = _default_interpret()
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    if use_kernel:
        y = sliding_window_pallas(
            x2, window=window, op=op, block_b=block_b, interpret=interpret
        )
    else:
        y = sliding_window_ref(x2, window=window, op=op)
    return y.reshape(lead + (x.shape[-1],))
