"""Public op: sliding-window causal attention with GQA and dispatch.

``local_attention(q, k, v, window, ...)`` takes (B, Hq, T, D) queries and
(B, Hkv, T, D) keys/values with Hq % Hkv == 0, expands KV heads, flattens to
(B·Hq, T, D), and dispatches to the Pallas kernel (interpret on CPU) or the
dense oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.local_attention.kernel import local_attention_pallas
from repro.kernels.local_attention.ref import local_attention_ref


def local_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    window: int,
    *,
    softcap: float = 0.0,
    use_kernel: bool = True,
    interpret: bool | None = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Hq, T, D = q.shape
    Hkv = k.shape[1]
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not divisible by Hkv={Hkv}")
    rep = Hq // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qf = q.reshape(B * Hq, T, D)
    kf = k.reshape(B * Hq, T, D)
    vf = v.reshape(B * Hq, T, D)
    if use_kernel:
        o = local_attention_pallas(
            qf, kf, vf, window=window, softcap=softcap,
            block_q=block_q, block_k=block_k, interpret=interpret,
        )
    else:
        o = local_attention_ref(qf, kf, vf, window=window, softcap=softcap)
    return o.reshape(B, Hq, T, D)
