"""Pure-jnp oracle: dense masked softmax attention with sliding window."""

from __future__ import annotations

import math

import jax.numpy as jnp


def local_attention_ref(q, k, v, *, window: int, softcap: float = 0.0):
    """q, k, v: (BH, T, D); causal window of ``window`` positions incl. self."""
    BH, T, D = q.shape
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(D)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    tpos = jnp.arange(T)[:, None]
    spos = jnp.arange(T)[None, :]
    mask = (spos <= tpos) & (spos > tpos - window)
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bts,bsd->btd", p, v.astype(jnp.float32)).astype(q.dtype)
