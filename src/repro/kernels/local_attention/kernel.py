"""Pallas TPU kernel: sliding-window (local) causal flash attention.

The attention-side consumer of the framework's windowed-aggregation story:
gemma2-27b's local layers and zamba2's shared attention block at long context
attend only to the last ``window`` positions — the KV ring buffer is the
attention analogue of the paper's FIFO window (insert at back, evict at
front), and this kernel computes the windowed softmax over it.

Flash-style online softmax.  Grid ``(B·H, T/bq, nkv)`` with
``nkv = window/bk + 1`` KV blocks per query block (the diagonal plus the
window's reach).  The KV block index is ``qj - (nkv-1) + jk``; negative
indices are clamped for the load and *masked* in-kernel (the unclamped value
is re-derived from program ids, so clamp-duplicated blocks contribute
nothing).  Running (m, l, acc) in f32 VMEM scratch; the output block is
revisited across the innermost grid axis and finalized at ``jk = nkv-1``.

Supports gemma2's logit soft-capping (``cap · tanh(s / cap)``).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1.0e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, window: int, nkv: int, bq: int, bk: int, scale: float, softcap: float,
):
    qj = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, _NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    kvj = qj - (nkv - 1) + jk  # unclamped KV block index (may be < 0)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0].astype(jnp.float32)                  # (bk, D)

    s = q @ k.T                                       # (bq, bk)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    qpos = qj * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kvj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (kpos >= 0) & (kpos <= qpos) & (kpos > qpos - window)
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]                               # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + p @ v
    m_ref[...] = m_new

    @pl.when(jk == nkv - 1)
    def _finalize():
        l = l_ref[...]
        safe_l = jnp.where(l > 0.0, l, 1.0)
        o_ref[0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "block_q", "block_k", "interpret"),
)
def local_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: int,
    softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Windowed causal attention.  q, k, v: (BH, T, D) with equal heads."""
    BH, T, D = q.shape
    bq = min(block_q, T)
    bk = min(block_k, T)
    T_pad = math.ceil(T / max(bq, bk)) * max(bq, bk)
    bq = min(bq, T_pad)
    bk = min(bk, T_pad)

    def pad(x):
        if T_pad == T:
            return x
        return jnp.pad(x, ((0, 0), (0, T_pad - T), (0, 0)))

    q, k, v = pad(q), pad(k), pad(v)
    nkv = min(math.ceil(window / bk) + 1, T_pad // bk)
    n_q = T_pad // bq
    scale = 1.0 / math.sqrt(D)

    def kv_index(bh, qj, jk):
        kvj = qj - (nkv - 1) + jk
        return (bh, jnp.maximum(kvj, 0), 0)

    out = pl.pallas_call(
        functools.partial(
            _attn_kernel,
            window=window, nkv=nkv, bq=bq, bk=bk, scale=scale, softcap=softcap,
        ),
        grid=(BH, n_q, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qj, jk: (bh, qj, 0)),
            pl.BlockSpec((1, bk, D), kv_index),
            pl.BlockSpec((1, bk, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qj, jk: (bh, qj, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T_pad, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :T, :]
