"""Shared elementwise-monoid op registry for all Pallas kernels.

Every kernel (sliding_window, suffix_scan, ...) and the chunked streaming
engine (:mod:`repro.core.chunked`) dispatch through this single table, so a
new elementwise monoid is added in ONE place and becomes available to every
bulk code path at once.

An *op* here is a scalar (elementwise) associative combine with a constant
identity — the subset of :mod:`repro.core.monoids` that maps 1:1 onto VPU
lanes.  Pytree-valued monoids (mean, m4, affine, ...) cannot use the scalar
kernels; they go through the generic ``associative_scan`` path of the
chunked engine instead.  :func:`op_for_monoid` is the structural gate: the
keyed flip sweep routes BOTH halves (``seg_scan``'s segmented suffix and
prefix kernels) through it, falling back to the lax pair-operator scans for
pytree aggregates.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

# Large-magnitude stand-ins for ±inf: Pallas TPU kernels prefer finite
# identities (inf arithmetic is dtype-fragile on VPU), and the values are
# far outside any realistic data range.
_NEG_BIG = {
    jnp.dtype(jnp.float32): -3.0e38,
    jnp.dtype(jnp.bfloat16): -3.0e38,
    jnp.dtype(jnp.float16): -6.0e4,
}


def _lse(a, b):
    m = jnp.maximum(a, b)
    lo = jnp.minimum(a, b)
    # stable: m + log1p(exp(lo - m)); exp(-inf-ish) underflows to 0.
    return m + jnp.log1p(jnp.exp(lo - m))


_COMBINE: dict[str, Callable[[jax.Array, jax.Array], jax.Array]] = {
    "sum": lambda a, b: a + b,
    "prod": lambda a, b: a * b,
    "max": jnp.maximum,
    "min": jnp.minimum,
    "logsumexp": _lse,
}


def available_ops() -> list[str]:
    """Names of the elementwise ops every kernel supports."""
    return sorted(_COMBINE)


def combine_fn(op: str) -> Callable[[jax.Array, jax.Array], jax.Array]:
    """The associative combine for ``op`` (older operand LEFT)."""
    try:
        return _COMBINE[op]
    except KeyError:
        raise ValueError(f"unsupported op {op!r}; have {available_ops()}") from None


def identity_for(op: str, dtype) -> float | int:
    """The identity element of ``op`` as a scalar fill value for ``dtype``."""
    dtype = jnp.dtype(dtype)
    if op == "sum":
        return 0
    if op == "prod":
        return 1
    if op == "max":
        return _NEG_BIG.get(dtype, jnp.iinfo(dtype).min if dtype.kind == "i" else -3.0e38)
    if op == "logsumexp":
        return _NEG_BIG.get(dtype, -3.0e38)
    if op == "min":
        if dtype.kind == "i":
            return jnp.iinfo(dtype).max
        return -_NEG_BIG.get(dtype, -3.0e38)
    raise ValueError(f"unsupported op {op!r}; have {available_ops()}")


# Monoid-registry names (repro.core.monoids) whose combine is bit-identical
# to a kernel op on a plain scalar Agg.  Used to auto-route ChunkedStream.
_MONOID_NAME_TO_OP = {
    "sum": "sum",
    "count": "sum",
    "max": "max",
    "min": "min",
    "logsumexp": "logsumexp",
}



def op_for_monoid(monoid) -> Optional[str]:
    """Kernel op equivalent to ``monoid``, or None if it needs the generic path.

    Matching is by the monoid's registered name prefix (``sum_float32`` →
    ``sum``), gated on the Agg actually being a single scalar leaf — pytree
    aggregates (sketches like KLL/Bloom, mean pairs, m4, affine maps,
    product monoids) always take the generic path even if a caller aliases
    one to a kernel-op name.
    """
    base = monoid.name.split("_")[0].split("#")[0]
    op = _MONOID_NAME_TO_OP.get(base)
    if op is None:
        return None
    leaves = jax.tree.leaves(monoid.identity())
    if len(leaves) != 1 or jnp.ndim(leaves[0]) != 0:
        return None
    return op
