"""Public op: batched suffix scan with kernel/oracle dispatch."""

from __future__ import annotations

import jax

from repro.kernels.suffix_scan.kernel import suffix_scan_pallas
from repro.kernels.suffix_scan.ref import suffix_scan_ref


def suffix_scan(
    x: jax.Array,
    op: str = "sum",
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
    block_b: int = 8,
    block_t: int = 256,
) -> jax.Array:
    """``y[..., t] = x[..., t] ⊗ … ⊗ x[..., T-1]`` along the last axis."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    if use_kernel:
        y = suffix_scan_pallas(
            x2, op=op, block_b=block_b, block_t=block_t, interpret=interpret
        )
    else:
        y = suffix_scan_ref(x2, op=op)
    return y.reshape(lead + (x.shape[-1],))
