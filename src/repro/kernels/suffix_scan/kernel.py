"""Pallas TPU kernel: batched suffix scan — the Two-Stacks *flip* in bulk.

``y[b, t] = x[b, t] ⊗ … ⊗ x[b, T-1]``: exactly the in-place reversal loop of
Two-Stacks Lite's flip (paper §4 lines 11–14) / the front-stack rebuild of
Two-Stacks (§3), vectorized over B rows.  Used for bulk evictions and for
building the "front stack" aggregates of a coarse-grained window in one pass.

Tiling: grid ``(B/Bt, T/Tb)``; the sequence-block axis is innermost and
iterated in REVERSE via the index_map (blocks right→left), with a per-row
carry aggregate in a ``(Bt, 1)`` VMEM scratch:

    carry ← 1                         at j = 0 (rightmost block)
    S     ← in-block suffix scan(X) ⊗ carry
    carry ← S[:, 0]                   (whole block ⊗ old carry)

In-block scan is Hillis–Steele (⌈log₂ Tb⌉ shift-combines on VPU lanes).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops_registry import combine_fn, identity_for
from repro.kernels.sliding_window.kernel import _suffix_scan_block


def _suffix_kernel(x_ref, o_ref, carry_ref, *, op: str):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = jnp.full(
            carry_ref.shape, identity_for(op, x_ref.dtype), x_ref.dtype
        )

    x = x_ref[...]
    s = _suffix_scan_block(x, op)
    s = combine_fn(op)(s, carry_ref[...])  # carry is strictly newer → RIGHT
    o_ref[...] = s
    carry_ref[...] = s[:, 0:1]


@functools.partial(
    jax.jit, static_argnames=("op", "block_b", "block_t", "interpret")
)
def suffix_scan_pallas(
    x: jax.Array,
    *,
    op: str = "sum",
    block_b: int = 8,
    block_t: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Row-wise inclusive suffix scan of (B, T) with monoid ``op``."""
    if x.ndim != 2:
        raise ValueError(f"expected (B, T), got {x.shape}")
    B, T = x.shape
    ident = identity_for(op, x.dtype)

    Bt = min(block_b, B)
    Tb = min(block_t, T)
    B_pad = math.ceil(B / Bt) * Bt
    T_pad = math.ceil(T / Tb) * Tb
    xp = jnp.full((B_pad, T_pad), ident, x.dtype).at[:B, :T].set(x)

    n_tb = T_pad // Tb
    out = pl.pallas_call(
        functools.partial(_suffix_kernel, op=op),
        grid=(B_pad // Bt, n_tb),
        in_specs=[pl.BlockSpec((Bt, Tb), lambda b, j: (b, n_tb - 1 - j))],
        out_specs=pl.BlockSpec((Bt, Tb), lambda b, j: (b, n_tb - 1 - j)),
        out_shape=jax.ShapeDtypeStruct((B_pad, T_pad), x.dtype),
        scratch_shapes=[pltpu.VMEM((Bt, 1), x.dtype)],
        interpret=interpret,
    )(xp)
    return out[:B, :T]
