"""Pure-jnp oracle for the batched suffix scan (flip)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ops_registry import combine_fn


def suffix_scan_ref(x: jax.Array, *, op: str = "sum") -> jax.Array:
    comb = combine_fn(op)
    # associative_scan over the reversed axis; operand order must be
    # older-LEFT after un-reversing, so flip the combine's arguments.
    rev = jnp.flip(x, axis=-1)
    scanned = jax.lax.associative_scan(lambda a, b: comb(b, a), rev, axis=-1)
    return jnp.flip(scanned, axis=-1)
