"""Pure-jnp oracle for the batched suffix scan (flip)."""

from __future__ import annotations

import jax

from repro.core.swag_base import suffix_scan
from repro.kernels.ops_registry import combine_fn


def suffix_scan_ref(x: jax.Array, *, op: str = "sum") -> jax.Array:
    # one shared implementation carries the non-commutative operand-order
    # rule (see swag_base.suffix_scan)
    return suffix_scan(combine_fn(op), x, axis=-1)
