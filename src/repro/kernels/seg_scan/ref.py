"""Pure-jnp oracle for the segmented suffix scan.

Deliberately a DIFFERENT formulation from both the kernel (blocked
Hillis–Steele) and the production lax path (flipped ``associative_scan``
on pair operands in :func:`repro.core.keyed.seg_suffix_scan`): a plain
sequential right-to-left ``lax.scan``, one combine per element — the
directly-readable spelling of the recurrence

    out[t] = x[t]               if flags[t]  (t ends its segment)
           = x[t] ⊗ out[t+1]    otherwise

so kernel/lax/ref agreement cross-checks three independent derivations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ops_registry import combine_fn, identity_for


def seg_suffix_scan_ref(x: jax.Array, flags: jax.Array, *, op: str = "sum"):
    """``out[..., t] = x[..., t] ⊗ … ⊗ x[..., e(t)]`` along the last axis;
    ``flags`` marks segment ends (``e(t)`` = first True at or after t)."""
    comb = combine_fn(op)
    ident = identity_for(op, x.dtype)
    xs = jnp.moveaxis(jnp.asarray(x), -1, 0)
    fs = jnp.moveaxis(jnp.asarray(flags, bool), -1, 0)

    def step(carry, inp):
        xv, fl = inp
        out = jnp.where(fl, xv, comb(xv, carry))
        return out, out

    init = jnp.full(xs.shape[1:], ident, x.dtype)
    _, ys = jax.lax.scan(step, init, (xs, fs), reverse=True)
    return jnp.moveaxis(ys, 0, -1)


def seg_prefix_scan_ref(x: jax.Array, flags: jax.Array, *, op: str = "sum"):
    """``out[..., t] = x[..., s(t)] ⊗ … ⊗ x[..., t]`` along the last axis;
    ``flags`` marks segment starts (``s(t)`` = last True at or before t).
    Forward sequential scan — the carry (older) operand stays LEFT."""
    comb = combine_fn(op)
    ident = identity_for(op, x.dtype)
    xs = jnp.moveaxis(jnp.asarray(x), -1, 0)
    fs = jnp.moveaxis(jnp.asarray(flags, bool), -1, 0)

    def step(carry, inp):
        xv, fl = inp
        out = jnp.where(fl, xv, comb(carry, xv))
        return out, out

    init = jnp.full(xs.shape[1:], ident, x.dtype)
    _, ys = jax.lax.scan(step, init, (xs, fs))
    return jnp.moveaxis(ys, 0, -1)
