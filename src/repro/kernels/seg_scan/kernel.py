"""Pallas TPU kernels: batched SEGMENTED suffix AND prefix scans.

Suffix: ``y[b, t] = x[b, t] ⊗ … ⊗ x[b, e(t)]`` where ``e(t)`` is the first
index ``≥ t`` with ``flags[b, e(t)] = True`` (the end of t's segment), or
``T-1`` when the last segment never closes.  Prefix (the mirror):
``y[b, t] = x[b, s(t)] ⊗ … ⊗ x[b, t]`` with ``flags`` marking segment
STARTS.  Together they are the two halves of the flip sweep in
:meth:`repro.core.keyed.KeyedWindowStore.update_chunk`: one key-sorted chunk
holds many segments (one per key) and every per-row window fold is one
suffix-scan value ⊗ one prefix-scan value — the keyed generalization of the
Two-Stacks flip that ``kernels/suffix_scan`` computes for a single window
(flip invariant: ``repro.core.event_time`` module docstring).

Tiling mirrors ``suffix_scan``: grid ``(B/Bt, T/Tb)``, sequence-block axis
innermost and iterated in REVERSE via the index_map (blocks right→left),
with a per-row carry in a ``(Bt, 1)`` VMEM scratch.  The carry is the
finished scan value at the right block's leftmost column — exactly the fold
any unterminated segment of the current block continues into:

    carry ← 1                                   at j = 0 (rightmost block)
    (V,F) ← in-block segmented suffix scan      (Hillis–Steele on pairs)
    O     ← F ? V : V ⊗ carry
    carry ← O[:, 0]

The in-block scan runs ⌈log₂ Tb⌉ shift-combine steps on the classic
segmented-scan pair operator ``(f_a, v_a) • (f_b, v_b) =
(f_a | f_b, f_a ? v_a : v_a ⊗ v_b)`` (left operand newer), the same
operator :func:`repro.core.keyed.seg_suffix_scan` feeds to
``associative_scan`` — so outputs agree combine-for-combine with the lax
path for every op in the registry.

Padding: values pad with the op identity and flags pad with False, so
padded columns fold identities into the carry chain without perturbing any
real segment.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops_registry import combine_fn, identity_for
from repro.kernels.sliding_window.kernel import _shift_left, _shift_right


def _seg_suffix_scan_block(v: jax.Array, f: jax.Array, op: str):
    """In-block segmented suffix scan on (value, end-flag) pairs:
    ``V[i] = x[i] ⊗ … ⊗ x[min(e(i), Tb-1)]``, ``F[i] = e(i) < Tb``."""
    comb = combine_fn(op)
    ident = identity_for(op, v.dtype)
    w = v.shape[1]
    d = 1
    while d < w:
        vs = _shift_left(v, d, ident)
        fs = _shift_left(f, d, 0)
        v = jnp.where(f != 0, v, comb(v, vs))
        f = f | fs
        d *= 2
    return v, f


def _seg_suffix_kernel(x_ref, f_ref, o_ref, carry_ref, *, op: str):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = jnp.full(
            carry_ref.shape, identity_for(op, x_ref.dtype), x_ref.dtype
        )

    v, f = _seg_suffix_scan_block(x_ref[...], f_ref[...], op)
    # unterminated rows continue into the (strictly newer → RIGHT) carry
    out = jnp.where(f != 0, v, combine_fn(op)(v, carry_ref[...]))
    o_ref[...] = out
    carry_ref[...] = out[:, 0:1]


@functools.partial(
    jax.jit, static_argnames=("op", "block_b", "block_t", "interpret")
)
def seg_suffix_scan_pallas(
    x: jax.Array,
    flags: jax.Array,
    *,
    op: str = "sum",
    block_b: int = 8,
    block_t: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Row-wise segmented inclusive suffix scan of (B, T) with monoid
    ``op``; ``flags`` (B, T) marks segment ENDS."""
    if x.ndim != 2:
        raise ValueError(f"expected (B, T), got {x.shape}")
    if flags.shape != x.shape:
        raise ValueError(f"flags {flags.shape} != values {x.shape}")
    B, T = x.shape
    ident = identity_for(op, x.dtype)

    Bt = min(block_b, B)
    Tb = min(block_t, T)
    B_pad = math.ceil(B / Bt) * Bt
    T_pad = math.ceil(T / Tb) * Tb
    xp = jnp.full((B_pad, T_pad), ident, x.dtype).at[:B, :T].set(x)
    fp = (
        jnp.zeros((B_pad, T_pad), jnp.int32)
        .at[:B, :T]
        .set(flags.astype(jnp.int32))
    )

    n_tb = T_pad // Tb
    out = pl.pallas_call(
        functools.partial(_seg_suffix_kernel, op=op),
        grid=(B_pad // Bt, n_tb),
        in_specs=[
            pl.BlockSpec((Bt, Tb), lambda b, j: (b, n_tb - 1 - j)),
            pl.BlockSpec((Bt, Tb), lambda b, j: (b, n_tb - 1 - j)),
        ],
        out_specs=pl.BlockSpec((Bt, Tb), lambda b, j: (b, n_tb - 1 - j)),
        out_shape=jax.ShapeDtypeStruct((B_pad, T_pad), x.dtype),
        scratch_shapes=[pltpu.VMEM((Bt, 1), x.dtype)],
        interpret=interpret,
    )(xp, fp)
    return out[:B, :T]


def _seg_prefix_scan_block(v: jax.Array, f: jax.Array, op: str):
    """In-block segmented prefix scan on (value, start-flag) pairs:
    ``V[i] = x[max(s(i), 0)] ⊗ … ⊗ x[i]``, ``F[i] = s(i) >= 0`` (the
    segment start is inside this block)."""
    comb = combine_fn(op)
    ident = identity_for(op, v.dtype)
    w = v.shape[1]
    d = 1
    while d < w:
        vs = _shift_right(v, d, ident)
        fs = _shift_right(f, d, 0)
        v = jnp.where(f != 0, v, comb(vs, v))
        f = f | fs
        d *= 2
    return v, f


def _seg_prefix_kernel(x_ref, f_ref, o_ref, carry_ref, *, op: str):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = jnp.full(
            carry_ref.shape, identity_for(op, x_ref.dtype), x_ref.dtype
        )

    v, f = _seg_prefix_scan_block(x_ref[...], f_ref[...], op)
    # rows whose segment started left of this block continue the (strictly
    # older → LEFT) carry
    out = jnp.where(f != 0, v, combine_fn(op)(carry_ref[...], v))
    o_ref[...] = out
    carry_ref[...] = out[:, -1:]


@functools.partial(
    jax.jit, static_argnames=("op", "block_b", "block_t", "interpret")
)
def seg_prefix_scan_pallas(
    x: jax.Array,
    flags: jax.Array,
    *,
    op: str = "sum",
    block_b: int = 8,
    block_t: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Row-wise segmented inclusive prefix scan of (B, T) with monoid
    ``op``; ``flags`` (B, T) marks segment STARTS.  Mirror of
    :func:`seg_suffix_scan_pallas`: forward block order, carry = finished
    scan value at the left block's rightmost column."""
    if x.ndim != 2:
        raise ValueError(f"expected (B, T), got {x.shape}")
    if flags.shape != x.shape:
        raise ValueError(f"flags {flags.shape} != values {x.shape}")
    B, T = x.shape
    ident = identity_for(op, x.dtype)

    Bt = min(block_b, B)
    Tb = min(block_t, T)
    B_pad = math.ceil(B / Bt) * Bt
    T_pad = math.ceil(T / Tb) * Tb
    xp = jnp.full((B_pad, T_pad), ident, x.dtype).at[:B, :T].set(x)
    fp = (
        jnp.zeros((B_pad, T_pad), jnp.int32)
        .at[:B, :T]
        .set(flags.astype(jnp.int32))
    )

    n_tb = T_pad // Tb
    out = pl.pallas_call(
        functools.partial(_seg_prefix_kernel, op=op),
        grid=(B_pad // Bt, n_tb),
        in_specs=[
            pl.BlockSpec((Bt, Tb), lambda b, j: (b, j)),
            pl.BlockSpec((Bt, Tb), lambda b, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((Bt, Tb), lambda b, j: (b, j)),
        out_shape=jax.ShapeDtypeStruct((B_pad, T_pad), x.dtype),
        scratch_shapes=[pltpu.VMEM((Bt, 1), x.dtype)],
        interpret=interpret,
    )(xp, fp)
    return out[:B, :T]
