"""Public ops: batched segmented suffix/prefix scans, kernel/oracle dispatch."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.seg_scan.kernel import (
    seg_prefix_scan_pallas,
    seg_suffix_scan_pallas,
)
from repro.kernels.seg_scan.ref import seg_prefix_scan_ref, seg_suffix_scan_ref


def seg_suffix_scan_op(
    x: jax.Array,
    flags: jax.Array,
    op: str = "sum",
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
    block_b: int = 8,
    block_t: int = 256,
) -> jax.Array:
    """Segmented suffix scan along the last axis: ``y[..., t] = x[..., t] ⊗
    … ⊗ x[..., e(t)]`` with ``flags`` marking segment ends.  This is the
    scalar-monoid fast path of
    :meth:`repro.core.keyed.KeyedWindowStore.update_chunk` (selected there
    through the ``ops_registry.op_for_monoid`` structural gate)."""
    x = jnp.asarray(x)
    flags = jnp.asarray(flags)
    if flags.shape != x.shape:
        flags = jnp.broadcast_to(flags, x.shape)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    f2 = flags.reshape((-1, x.shape[-1]))
    if use_kernel:
        y = seg_suffix_scan_pallas(
            x2, f2, op=op, block_b=block_b, block_t=block_t,
            interpret=interpret,
        )
    else:
        y = seg_suffix_scan_ref(x2, flags=f2, op=op)
    return y.reshape(lead + (x.shape[-1],))


def seg_prefix_scan_op(
    x: jax.Array,
    flags: jax.Array,
    op: str = "sum",
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
    block_b: int = 8,
    block_t: int = 256,
) -> jax.Array:
    """Segmented prefix scan along the last axis: ``y[..., t] = x[..., s(t)]
    ⊗ … ⊗ x[..., t]`` with ``flags`` marking segment STARTS — the second
    half of the keyed flip sweep (same ``op_for_monoid`` gate as
    :func:`seg_suffix_scan_op`)."""
    x = jnp.asarray(x)
    flags = jnp.asarray(flags)
    if flags.shape != x.shape:
        flags = jnp.broadcast_to(flags, x.shape)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    f2 = flags.reshape((-1, x.shape[-1]))
    if use_kernel:
        y = seg_prefix_scan_pallas(
            x2, f2, op=op, block_b=block_b, block_t=block_t,
            interpret=interpret,
        )
    else:
        y = seg_prefix_scan_ref(x2, flags=f2, op=op)
    return y.reshape(lead + (x.shape[-1],))
