"""Sharding rules: parameter / batch / decode-state PartitionSpecs.

Mesh axes (launch/mesh.py): single-pod ``(data=16, model=16)``; multi-pod
``(pod=2, data=16, model=16)``.  Data parallelism runs over ``("pod","data")``
(gradient psum crosses pods — the multi-pod dry-run proves that axis shards),
tensor/expert parallelism over ``"model"``.

Rules are keyed on parameter *path names* (the nested-dict keys), so they
apply uniformly to the layer-stacked (leading L axis) parameters:

  embed / lm_head    (V, d)      → (model, None)        vocab-sharded
  attn  wq/wk/wv     (d, H, hd)  → (None, model, None)  head-sharded TP
  attn  wo           (H, hd, d)  → (model, None, None)
  mlp   gate/up      (d, f)      → (None, model)        f-sharded TP
  mlp   down         (f, d)      → (model, None)
  moe   experts      (E, d, f)   → (model, None, None)  EP
  rwkv/mamba projections          f/head-sharded TP (heads follow d_ff)
  norms / scalars                 replicated

Activations: batch over ("pod","data").  For decode shapes whose batch is
smaller than the DP axis (long_500k: B=1), the KV/state sequence or head axis
is sharded instead (see ``decode_state_pspecs``).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig

PyTree = Any

DP_AXES = ("pod", "data")  # flattened data-parallel axes (when present)
TP = "model"


def _dp(mesh) -> tuple:
    """The data-parallel mesh axes present in this mesh."""
    return tuple(a for a in DP_AXES if a in mesh.axis_names)


def _rule_for(path: tuple[str, ...], leaf, tp: int) -> P:
    """PartitionSpec for one parameter leaf, by path name + rank.

    Every TP assignment is divisibility-checked against the model-axis size
    ``tp``; when a dimension does not divide, the rule falls back (replicate
    attention heads, f-TP instead of EP for few-expert MoE).  The fallbacks
    are recorded as §Perf baseline costs — e.g. arctic's 56 heads replicate
    over a 16-way axis, making its attention core the hillclimb target.
    """
    name = path[-1]
    stacked = "layers" in path  # leading L axis from the layer stack
    pre = (None,) if stacked else ()

    def spec(*axes):
        return P(*(pre + axes))

    def tpif(dim_size):
        return TP if dim_size % tp == 0 else None

    dims = leaf.shape[len(pre):]

    if name in ("embed", "lm_head"):
        return P(tpif(leaf.shape[0]), None)  # vocab-sharded (never stacked)
    if name in ("wq", "wk", "wv"):
        return spec(None, tpif(dims[1]), None)  # (d, H|Hkv, hd)
    if name == "wo":
        return spec(tpif(dims[0]), None, None)  # (H, hd, d)
    if name in ("w_gate", "w_up"):
        if len(dims) == 3:  # MoE experts (E, d, f): EP, else f-TP
            if dims[0] % tp == 0:
                return spec(TP, None, None)
            return spec(None, None, tpif(dims[2]))
        return spec(None, tpif(dims[1]))
    if name == "w_down":
        if len(dims) == 3:  # MoE experts (E, f, d)
            if dims[0] % tp == 0:
                return spec(TP, None, None)
            return spec(None, tpif(dims[1]), None)
        return spec(tpif(dims[0]), None)
    if name == "router":
        return spec(None, None)
    # RWKV-6
    if name in ("w_r", "w_k", "w_v", "w_g"):
        return spec(None, tpif(dims[1]))
    if name == "w_o":
        return spec(tpif(dims[0]), None)
    if name in ("decay_w0", "bonus_u"):
        return spec(tpif(dims[0]), None)  # (H, K): heads sharded
    if name in ("decay_a", "decay_b", "mu", "cm_mu"):
        return spec(*(None,) * len(dims))
    if name == "cm_k":
        return spec(None, tpif(dims[1]))
    if name == "cm_v":
        return spec(tpif(dims[0]), None)
    # Mamba-2
    if name == "w_in":
        return spec(None, tpif(dims[1]))
    if name in ("w_bc", "w_dt"):
        return spec(tpif(dims[0]), None)
    if name == "conv_w":
        return spec(None, tpif(dims[1]))
    if name == "norm" and len(dims) == 1:
        return spec(tpif(dims[0]))  # (f,) rmsnorm over the sharded inner dim
    if name in ("dt_bias", "a_log", "d_skip"):
        return spec(None)
    # norms and anything 1-D / scalar: replicate
    return spec(*(None,) * len(dims))


def param_pspecs(
    cfg: ModelConfig,
    params_shape: PyTree,
    tp: int = 16,
    fsdp_mesh=None,
    fsdp_min_size: int = 1 << 20,
) -> PyTree:
    """PartitionSpec pytree matching ``params_shape`` (from eval_shape).

    With ``fsdp_mesh`` set, every large leaf that has no data-parallel axis
    gets one added on its first divisible unsharded dimension (ZeRO-3-style
    full parameter sharding).  With scanned layer stacks the just-in-time
    all-gather happens inside the scan body, so the working set stays one
    layer.  Required for arctic-480b / grok-1-314b (params+optimizer exceed
    a pod's aggregate HBM 16-way sharded) and used for all serving params.
    """

    dp = _dp(fsdp_mesh) if fsdp_mesh is not None else ()
    dp_size = 1
    for a in dp:
        dp_size *= fsdp_mesh.shape[a]

    def assign(path, leaf):
        names = tuple(
            p.key if isinstance(p, jax.tree_util.DictKey) else str(p)
            for p in path
        )
        spec = _rule_for(names, leaf, tp)
        if not dp or dp_size <= 1 or leaf.size < fsdp_min_size:
            return spec
        axes = list(spec) + [None] * (leaf.ndim - len(spec))
        stacked = "layers" in names
        lo = 1 if stacked else 0
        has_tp = any(a == TP for a in axes)
        is_expert = names[-1] in ("w_gate", "w_up", "w_down") and leaf.ndim - lo == 3
        # FSDP dim preference is measured, not aesthetic (EXPERIMENTS §Perf):
        #  * MoE expert tensors (E, d, f): shard the LAST (output) dim —
        #    d-sharding makes the dispatch einsum replicate the batch 16×
        #    (arctic baseline pathology);
        #  * other weights WITHOUT a TP axis (replicated-attention archs
        #    like arctic): LAST dim, so conflicts resolve via MB-scale
        #    weight gathers instead of GB-scale activation gathers;
        #  * weights WITH a TP axis (head/f-sharded): FIRST dim — last-dim
        #    sharding regressed gemma2 train 0.7× / llama prefill 0.5×
        #    (output-dim conflicts with the existing TP layout).
        order = (
            range(lo, leaf.ndim)
            if (has_tp and not is_expert)
            else range(leaf.ndim - 1, lo - 1, -1)
        )
        for i in order:
            if axes[i] is None and leaf.shape[i] % dp_size == 0:
                axes[i] = dp
                return P(*axes)
        return spec

    return jax.tree_util.tree_map_with_path(assign, params_shape)


def batch_pspecs(cfg: ModelConfig, batch_shape: dict, mesh) -> dict:
    dp = _dp(mesh)
    out = {}
    for k, v in batch_shape.items():
        if k == "positions":  # (3, B, S)
            out[k] = P(None, dp, None)
        elif v.ndim >= 2:
            out[k] = P(dp, *(None,) * (v.ndim - 1))
        else:
            out[k] = P(dp)
    return out


def decode_state_pspecs(cfg: ModelConfig, state_shape: dict, mesh) -> dict:
    """KV caches (L, B, Hkv, S, hd) / SSM states (L, B, H, K, V).

    Batch shards over DP when divisible; otherwise the cache sequence axis
    (full-attention caches) or nothing.  Heads shard over TP when divisible —
    decode TP mirrors the train-time head sharding.
    """
    dp = _dp(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    tp_size = mesh.shape[TP] if TP in mesh.axis_names else 1

    def assign(path, leaf):
        names = tuple(
            p.key if isinstance(p, jax.tree_util.DictKey) else str(p)
            for p in path
        )
        name = names[-1]
        if name == "pos" or leaf.ndim == 0:
            return P()
        if name in ("k", "v", "k_local", "v_local", "k_global", "v_global",
                    "xk", "xv", "shared_k", "shared_v"):
            L, B, Hkv, S, hd = leaf.shape
            b_ax = dp if B % max(dp_size, 1) == 0 and dp_size > 1 else None
            h_ax = TP if Hkv % max(tp_size, 1) == 0 and tp_size > 1 else None
            # Shard the cache sequence over whichever axes remain unused:
            # few-kv-head archs (grok Hkv=8 < 16) S-shard over model; B=1
            # long-context decode S-shards over data.
            s_axes = []
            if b_ax is None and dp_size > 1 and S % dp_size == 0:
                s_axes.extend(dp)
            if h_ax is None and tp_size > 1 and S % (tp_size * max(dp_size if s_axes else 1, 1)) == 0:
                s_axes.append(TP)
            s_ax = tuple(s_axes) if s_axes else None
            return P(None, b_ax, h_ax, s_ax, None)
        if name == "ssm":
            L, B, H = leaf.shape[:3]
            b_ax = dp if B % max(dp_size, 1) == 0 and dp_size > 1 else None
            h_ax = TP if H % max(tp_size, 1) == 0 and tp_size > 1 else None
            return P(None, b_ax, h_ax, *(None,) * (leaf.ndim - 3))
        if name in ("tm_last", "cm_last"):
            L, B, d = leaf.shape
            b_ax = dp if B % max(dp_size, 1) == 0 and dp_size > 1 else None
            return P(None, b_ax, None)
        if name == "conv":
            L, B, _, f = leaf.shape
            b_ax = dp if B % max(dp_size, 1) == 0 and dp_size > 1 else None
            f_ax = TP if f % max(tp_size, 1) == 0 and tp_size > 1 else None
            return P(None, b_ax, None, f_ax)
        return P(*(None,) * leaf.ndim)

    return jax.tree_util.tree_map_with_path(assign, state_shape)


def token_pspec(mesh, batch: int) -> P:
    dp = _dp(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    return P(dp) if dp_size > 1 and batch % dp_size == 0 else P()


def keyed_store_pspecs(state: PyTree, axis: str = "data") -> PyTree:
    """PartitionSpecs for a shard-stacked keyed window store
    (:class:`repro.core.keyed.ShardedKeyedStore`).

    Every leaf of the stacked state — carry lanes, ``last`` aggregates,
    directory tables, counters — carries a leading shard axis (one keyed
    store per shard), sharded over ``axis``; all trailing dims stay local.
    The key space is hash-partitioned onto the same axis, so the steady
    state needs no collectives: each shard's slots, probes, and carries are
    touched only by its own keys.
    """
    return jax.tree.map(
        lambda leaf: P(axis, *(None,) * (jnp.ndim(leaf) - 1)), state
    )


def make_shardings(mesh, pspecs: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
