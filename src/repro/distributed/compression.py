"""Int8 error-feedback gradient compression for data-parallel reductions.

The distributed-optimization trick for the DP axis: gradients are quantized
to int8 (per-tensor scale) before crossing the interconnect, cutting DP
all-reduce bytes 2× vs bf16 / 4× vs f32.  Error feedback (Karimireddy et al.)
accumulates the quantization residual locally and re-injects it next step, so
convergence is preserved (validated in tests on a quadratic problem).

Two layers:
  * ``quantize`` / ``dequantize`` / ``ef_compress``: the math, usable anywhere.
  * ``compressed_psum_mean``: an in-shard_map ring reduce-scatter +
    all-gather over a named axis whose *wire format* is int8 chunks — the
    TPU-real collective; falls back to dense psum for tiny tensors.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(grad: jax.Array, err: jax.Array):
    """Error-feedback compression: returns (q, scale, new_err)."""
    target = grad.astype(jnp.float32) + err
    q, scale = quantize(target)
    new_err = target - dequantize(q, scale)
    return q, scale, new_err


def ef_compress_tree(grads: PyTree, errs: PyTree):
    """Tree version; returns (decompressed_grads, new_errs).

    The decompressed value is exactly what the wire carries — downstream
    reductions of it model the compressed collective's numerics.
    """
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errs)
    outs, new_errs = [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = ef_compress(g, e)
        outs.append(dequantize(q, s))
        new_errs.append(ne)
    return treedef.unflatten(outs), treedef.unflatten(new_errs)


def init_error_state(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# In-shard_map int8 ring reduce-scatter + all-gather
# ---------------------------------------------------------------------------


def compressed_psum_mean(x: jax.Array, axis_name: str, min_size: int = 1024):
    """Mean-reduce ``x`` across ``axis_name`` with an int8 ring.

    Ring reduce-scatter: each of the n-1 steps sends one int8 chunk (plus an
    f32 scale) to the next neighbor, accumulating in f32 and requantizing —
    wire bytes ≈ payload/4 vs f32 psum.  Followed by an int8 all-gather of
    the owned chunk.  Small tensors fall back to a plain psum.
    """
    # jax 0.4.x has no lax.axis_size; psum of a constant folds to the size.
    n = jax.lax.psum(1, axis_name)
    if n == 1:
        return x
    orig_shape, orig_dtype = x.shape, x.dtype
    size = x.size
    if size < min_size or size % n != 0:
        return jax.lax.pmean(x, axis_name)

    idx = jax.lax.axis_index(axis_name)
    chunks = x.astype(jnp.float32).reshape(n, size // n)
    perm = [(i, (i + 1) % n) for i in range(n)]

    # reduce-scatter: after n-1 steps, device i owns the full sum of chunk
    # (i+1) mod n.  Wire format per step: int8 chunk + f32 scale.
    def body(step, carry):
        acc = carry  # (n, chunk) f32: acc[j] = partial sum of chunk j
        send_j = (idx - step) % n  # chunk index this device forwards
        payload = jnp.take(acc, send_j, axis=0)
        q, s = quantize(payload)
        q = jax.lax.ppermute(q, axis_name, perm)
        s = jax.lax.ppermute(s, axis_name, perm)
        recv_j = (idx - step - 1) % n
        upd = jnp.take(acc, recv_j, axis=0) + dequantize(q, s)
        return acc.at[recv_j].set(upd)

    acc = jax.lax.fori_loop(0, n - 1, body, chunks)
    own = (idx + 1) % n
    mine = jnp.take(acc, own, axis=0) / n  # mean

    # all-gather the owned chunks (int8 wire) back to the full tensor.
    qm, sm = quantize(mine)
    qs = jax.lax.all_gather(qm, axis_name, axis=0)  # (n, chunk) int8
    ss = jax.lax.all_gather(sm, axis_name, axis=0)  # (n,)
    full = dequantize(qs, ss[:, None])
    # chunks are owned in ring order: device j owns chunk (j+1)%n
    order = (jnp.arange(n) + 1) % n
    full = jnp.zeros_like(full).at[order].set(full)
    return full.reshape(orig_shape).astype(orig_dtype)
