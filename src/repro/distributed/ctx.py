"""Process-global sharding context for in-model constraints.

Model code (moe_block, attention) sometimes needs explicit
``with_sharding_constraint`` hints whose axis names depend on the active
mesh.  Launchers set the data-parallel axis tuple here before tracing;
when unset (unit tests, single-device runs) all in-model constraints are
no-ops.
"""

from __future__ import annotations

from typing import Optional

_DP_AXES: Optional[tuple] = None
_DP_SIZE: int = 1
_TP_SIZE: int = 1


def set_dp_axes(axes: Optional[tuple], size: int = 16, tp_size: int = 16):
    global _DP_AXES, _DP_SIZE, _TP_SIZE
    _DP_AXES = tuple(axes) if axes else None
    _DP_SIZE = size if axes else 1
    _TP_SIZE = tp_size if axes else 1


def dp_axes() -> Optional[tuple]:
    return _DP_AXES


def dp_size() -> int:
    return _DP_SIZE


def tp_size() -> int:
    return _TP_SIZE


def constrain(x, spec):
    """with_sharding_constraint iff a dp context is active."""
    if _DP_AXES is None:
        return x
    import jax

    return jax.lax.with_sharding_constraint(x, spec)
