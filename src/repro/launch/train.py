import os
import sys

if __name__ == "__main__" and "--devices" in sys.argv:
    _n = sys.argv[sys.argv.index("--devices") + 1]
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_n}"

"""Training launcher.

Runs the fault-tolerant trainer on any assigned architecture.  On this CPU
container the default is the reduced config on 1 device; ``--devices N``
(must be first jax touch) creates N placeholder devices and shards the step
over a (data × model) debug mesh, exercising the real distribution path.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --steps 20
    PYTHONPATH=src python -m repro.launch.train --arch rwkv6-1.6b \
        --devices 4 --mesh 2x2 --steps 10
"""

import argparse
import dataclasses

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 2x2")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import ARCHS
    from repro.data.stream import SyntheticStream
    from repro.distributed.sharding import make_shardings, param_pspecs
    from repro.models.factory import reduced_config
    from repro.optim.adamw import AdamW, warmup_cosine
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = ARCHS[args.arch] if args.full else reduced_config(ARCHS[args.arch])
    d_data, d_model = (int(x) for x in args.mesh.split("x"))
    if d_data * d_model > 1:
        mesh = jax.make_mesh((d_data, d_model), ("data", "model"))
        # reduced configs need kv heads divisible by the model axis
        if cfg.num_kv_heads % d_model and cfg.num_kv_heads < d_model:
            cfg = dataclasses.replace(cfg, num_kv_heads=cfg.num_heads)
    else:
        mesh = None

    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_every=max(args.steps // 2, 1),
        ckpt_dir=args.ckpt_dir,
        metric_window=32,
        log_every=max(args.steps // 10, 1),
        compress_grads=args.compress_grads,
    )
    stream = SyntheticStream(cfg, batch=args.batch, seq=args.seq, seed=0)
    opt = AdamW(learning_rate=warmup_cosine(1e-3, 2, args.steps))
    trainer = Trainer(cfg, tcfg, opt, stream)
    state = trainer.resume_or_init(jax.random.key(0))

    if mesh is not None:
        pspec = param_pspecs(cfg, jax.eval_shape(lambda: state.params), tp=d_model)
        sh = make_shardings(mesh, pspec)
        params = jax.tree.map(jax.device_put, state.params, sh)
        state = dataclasses.replace(state, params=params)
        print(f"mesh {args.mesh}: params sharded over {d_model}-way model axis")
        with mesh:
            state = trainer.run(state)
    else:
        state = trainer.run(state)

    print(f"done at step {int(state.step)}")
    for h in trainer.history[-3:]:
        print(f"  step {h['step']:4d} loss={h['loss']:.4f} "
              f"win_mean={h['win/loss_mean']:.4f}")


if __name__ == "__main__":
    main()
