"""Serving launcher: continuous-batching engine over an assigned arch.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --requests 8 --slots 4 --max-new 12
"""

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCHS
    from repro.models.factory import reduced_config
    from repro.models.transformer import build_model
    from repro.serve.engine import DecodeEngine, Request

    cfg = ARCHS[args.arch] if args.full else reduced_config(ARCHS[args.arch])
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    eng = DecodeEngine(cfg, params, batch_slots=args.slots, cache_len=args.cache_len)

    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, int(rng.integers(4, 24))).astype(np.int32),
            max_new=args.max_new,
        )
        for i in range(args.requests)
    ]
    for r in reqs:
        eng.submit(r)

    t0 = time.perf_counter()
    steps = 0
    lat = []
    while True:
        s0 = time.perf_counter()
        n = eng.step()
        if n:
            lat.append((time.perf_counter() - s0) / 1)
        steps += 1
        if n == 0 and not eng.queue:
            break
        if steps > 10_000:
            break
    wall = time.perf_counter() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    lat = np.array(lat) * 1e3
    print(f"served {done}/{args.requests} requests, {toks} tokens "
          f"in {wall:.2f}s ({toks/wall:.1f} tok/s)")
    if len(lat):
        print(f"decode-step latency ms: p50={np.percentile(lat,50):.1f} "
              f"p99={np.percentile(lat,99):.1f} max={lat.max():.1f}")
    print("sample output:", reqs[0].out)


if __name__ == "__main__":
    main()
