import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay the first statements in this module — jax locks
the device count at first initialization, and the dry-run needs 512 host
placeholder devices to build the production meshes.  Everything else
(smoke tests, benchmarks) sees the default single device.

For each cell this script:
  1. builds the production mesh (16×16 single-pod or 2×16×16 multi-pod),
  2. derives parameter / batch / decode-state PartitionSpecs,
  3. ``jax.jit(step, in_shardings, out_shardings).lower(**specs).compile()``
     with ShapeDtypeStruct stand-ins (zero allocation),
  4. prints ``compiled.memory_analysis()`` (proves the step fits) and
     ``compiled.cost_analysis()`` (FLOPs/bytes for §Roofline),
  5. parses collective ops from the optimized HLO and writes the roofline
     record to experiments/dryrun/<arch>__<shape>__<mesh>.json.

DEPTH EXTRAPOLATION: XLA's cost_analysis counts a ``while``-loop body ONCE
regardless of trip count, so a scanned 46-layer stack reports ~1 layer of
FLOPs.  We therefore also compile two reduced-depth variants (L = p and
L = 2p, p = the architecture's layer period) and linearly extrapolate:
    cost(L) = C_p + (C_{2p} - C_p)/p · (L - p)
which is exact for any cost linear in depth.  The full-depth compile is still
performed (it is the deliverable — sharding coherence + memory analysis);
only FLOP/byte/collective accounting uses the extrapolation.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""

import argparse
import dataclasses
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES
from repro.configs.profiles import get_profile
from repro.configs.shapes import LONG_CONTEXT_ARCHS
from repro.distributed import ctx
from repro.distributed.sharding import (
    batch_pspecs,
    decode_state_pspecs,
    make_shardings,
    param_pspecs,
    token_pspec,
)
from repro.launch.mesh import chips, make_production_mesh
from repro.models import factory
from repro.models.transformer import decode_step, init_params, prefill
from repro.optim.adamw import AdamW, AdamWState
from repro.roofline import analysis
from repro.train.train_step import TrainState, init_train_state, make_train_step

OUT_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"
)


def _replicated_like(tree):
    return jax.tree.map(lambda _: P(), tree)


def _train_state_shapes(cfg, optimizer):
    def thunk():
        params = init_params(cfg, jax.random.key(0))
        return init_train_state(cfg, params, optimizer, metric_window=128)

    return jax.eval_shape(thunk)


def _train_state_pspecs(cfg, state_shape, tp, fsdp_mesh=None):
    p_spec = param_pspecs(cfg, state_shape.params, tp, fsdp_mesh=fsdp_mesh)
    return TrainState(
        params=p_spec,
        opt_state=AdamWState(count=P(), m=p_spec, v=p_spec),
        step=P(),
        metric_windows=_replicated_like(state_shape.metric_windows),
        compress_err=None,
    )


def _build_lowered(cfg, shape, mesh, profile, accum=None):
    """Build the jitted step for (cfg, shape) and lower it on ``mesh``."""
    tp = mesh.shape["model"]
    optimizer = AdamW(learning_rate=3e-4, state_dtype=profile.opt_dtype)
    if shape.kind == "train":
        accum = profile.accum if accum is None else accum
        state_shape = _train_state_shapes(cfg, optimizer)
        state_specs = _train_state_pspecs(
            cfg, state_shape, tp, mesh if profile.fsdp else None
        )
        batch_shape = factory.input_specs(cfg, shape)["batch"]
        bspecs = batch_pspecs(cfg, batch_shape, mesh)
        jitted = jax.jit(
            make_train_step(cfg, optimizer, accum_steps=accum),
            in_shardings=(
                make_shardings(mesh, state_specs),
                make_shardings(mesh, bspecs),
            ),
            out_shardings=(make_shardings(mesh, state_specs), None),
            donate_argnums=(0,),
        )
        return jitted.lower(state_shape, batch_shape)
    if shape.kind == "prefill":
        spec = factory.decode_spec(cfg, shape)
        params_shape = factory.param_specs(cfg)
        p_specs = param_pspecs(
            cfg, params_shape, tp,
            fsdp_mesh=mesh if profile.fsdp_serve else None,
        )
        batch_shape = factory.input_specs(cfg, shape)["batch"]
        bspecs = batch_pspecs(cfg, batch_shape, mesh)
        state_shape = jax.eval_shape(
            lambda: factory.init_decode_state(None, cfg, spec)
        )
        st_specs = decode_state_pspecs(cfg, state_shape, mesh)
        jitted = jax.jit(
            lambda params, batch: prefill(params, cfg, batch, spec),
            in_shardings=(
                make_shardings(mesh, p_specs),
                make_shardings(mesh, bspecs),
            ),
            out_shardings=(
                NamedSharding(mesh, P()),
                make_shardings(mesh, st_specs),
            ),
        )
        return jitted.lower(params_shape, batch_shape)
    # decode
    params_shape = factory.param_specs(cfg)
    p_specs = param_pspecs(
        cfg, params_shape, tp,
        fsdp_mesh=mesh if profile.fsdp_serve else None,
    )
    specs = factory.input_specs(cfg, shape)
    st_specs = decode_state_pspecs(cfg, specs["state"], mesh)
    tok_spec = token_pspec(mesh, shape.global_batch)
    jitted = jax.jit(
        lambda params, state, token: decode_step(params, cfg, state, token),
        in_shardings=(
            make_shardings(mesh, p_specs),
            make_shardings(mesh, st_specs),
            NamedSharding(mesh, tok_spec),
        ),
        out_shardings=(
            NamedSharding(mesh, P()),
            make_shardings(mesh, st_specs),
        ),
        donate_argnums=(1,),
    )
    return jitted.lower(params_shape, specs["state"], specs["token"])


def _compile_and_cost(cfg, shape, mesh, profile, accum=None):
    lowered = _build_lowered(cfg, shape, mesh, profile, accum)
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    colls = analysis.parse_collectives(compiled.as_text())
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "temp_size": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:
        mem_d = {"error": str(e)}
    return {
        "compile_s": compile_s,
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collectives": colls,
        "coll_bytes": analysis.effective_collective_bytes(colls),
        "memory": mem_d,
    }


def _layer_period(cfg) -> int:
    if cfg.shared_attn_every > 0:
        return cfg.shared_attn_every
    if cfg.attn_pattern == "alternating":
        return 2
    return 1


def _depth_variant(cfg, layers: int, seq_len: int):
    """Reduced-depth, cost-exact variant: unrolled scans, single-chunk
    attention (trip count 1 ⇒ counted exactly once = correct)."""
    kw = {
        "num_layers": layers,
        "name": f"{cfg.name}@L{layers}",
        "unroll_layers": True,
        "unroll_attn": True,  # production q_chunk, trip-count-exact bytes
    }
    if cfg.is_encoder_decoder:
        kw["encoder_layers"] = layers
    return dataclasses.replace(cfg, **kw)


def lower_cell(arch: str, shape_name: str, mesh_kind: str, verbose: bool = True,
               overrides: dict | None = None):
    cfg = ARCHS[arch]
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = chips(mesh)
    ctx.set_dp_axes(("pod", "data") if mesh_kind == "multi" else ("data",), size=32 if mesh_kind == "multi" else 16, tp_size=16)

    profile = get_profile(arch)
    with mesh:
        full = _compile_and_cost(cfg, shape, mesh, profile)  # deliverable
        p = _layer_period(cfg)
        # cost variants: accum=1 (same math, trip-count-exact accounting).
        # Anchors at 2p and 3p: depth-1 modules trigger anomalous global
        # layout choices in the SPMD partitioner; costs are exactly linear
        # from 2p upward (verified: arctic diffs agree to 4 digits).
        ca = _compile_and_cost(
            _depth_variant(cfg, 2 * p, shape.seq_len), shape, mesh, profile, accum=1)
        cb = _compile_and_cost(
            _depth_variant(cfg, 3 * p, shape.seq_len), shape, mesh, profile, accum=1)

    L = cfg.num_layers

    def extrap(key):
        per = (cb[key] - ca[key]) / p
        return max(ca[key] + per * (L - 2 * p), 0.0)

    gla_f, gla_b = analysis.gla_correction(cfg, shape)
    roof = analysis.Roofline(
        arch=arch,
        shape=shape_name,
        mesh=mesh_kind,
        chips=n_chips,
        flops_per_device=extrap("flops") + gla_f / n_chips,
        bytes_per_device=extrap("bytes") + gla_b / n_chips,
        collective_bytes=extrap("coll_bytes"),
        collectives=full["collectives"],
        model_flops_total=analysis.model_flops(cfg, shape),
        memory_analysis=full["memory"],
    )
    if verbose:
        print(f"== {arch} × {shape_name} × {mesh_kind} ({n_chips} chips) ==")
        print(f"   compile(full/L{2*p}/L{3*p}): {full['compile_s']:.1f}s/"
              f"{ca['compile_s']:.1f}s/{cb['compile_s']:.1f}s")
        print(f"   memory_analysis: {full['memory']}")
        print(f"   flops/dev={roof.flops_per_device:.3e} "
              f"bytes/dev={roof.bytes_per_device:.3e} "
              f"coll_bytes/dev={roof.collective_bytes:.3e}")
        print(f"   t_comp={roof.t_compute*1e3:.2f}ms t_mem={roof.t_memory*1e3:.2f}ms "
              f"t_coll={roof.t_collective*1e3:.2f}ms → {roof.bottleneck}-bound; "
              f"useful={roof.useful_fraction:.2f} roofline={roof.roofline_fraction:.3f}")
        sys.stdout.flush()
    return roof


def run_cell(arch, shape_name, mesh_kind, out_dir, overrides=None, tag=""):
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        roof = analysis.Roofline(
            arch=arch, shape=shape_name, mesh=mesh_kind, chips=0,
            flops_per_device=0, bytes_per_device=0, collective_bytes=0,
            collectives={}, model_flops_total=0, memory_analysis={},
            skipped=True,
            note="pure full-attention arch: 500k decode needs sub-quadratic "
                 "attention; skipped per assignment (DESIGN.md §5)",
        )
        analysis.save_roofline(roof, path)
        print(f"== {arch} × {shape_name} × {mesh_kind}: SKIP (full attention)")
        return roof
    roof = lower_cell(arch, shape_name, mesh_kind, overrides=overrides)
    if tag:
        roof = dataclasses.replace(roof, note=f"variant: {tag} {overrides}")
    analysis.save_roofline(roof, path)
    return roof


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    ap.add_argument("--tag", default="", help="variant tag for output filename")
    ap.add_argument("--set", nargs="*", default=[],
                    help="ModelConfig overrides, e.g. moe_2d=true gla_chunk=128")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            overrides[k] = v.lower() == "true"
        else:
            try:
                overrides[k] = int(v)
            except ValueError:
                overrides[k] = v

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                path = os.path.join(
                    args.out, f"{arch}__{shape_name}__{mesh_kind}.json"
                )
                if args.skip_existing and os.path.exists(path):
                    continue
                try:
                    run_cell(arch, shape_name, mesh_kind, args.out,
                             overrides=overrides or None, tag=args.tag)
                except Exception:
                    failures.append((arch, shape_name, mesh_kind))
                    traceback.print_exc()
                    sys.stdout.flush()
    if failures:
        print("FAILED CELLS:", failures)
        sys.exit(1)
    print("ALL CELLS OK")


if __name__ == "__main__":
    main()
