"""Production mesh definitions.

TPU v5e pod of 256 chips as a (data=16, model=16) mesh; the multi-pod
configuration stacks 2 pods into (pod=2, data=16, model=16) = 512 chips.
Data parallelism spans ("pod", "data"); tensor/expert parallelism stays
inside a pod on "model" (ICI-local).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
initialization (see launch/dryrun.py lines 1–2).
"""

from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def chips(mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n
