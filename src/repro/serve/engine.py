"""Batched serving engine: continuous batching over a fixed slot grid.

``DecodeEngine`` owns a B-slot batched decode state (KV caches / SSM states).
Requests queue up; free slots are prefilled one at a time (their caches
scattered into the batch at the slot index) and then all active slots decode
in lock-step — the standard continuous-batching pattern.  Finished sequences
(EOS or max-len) retire and their slots are refilled.

The decode step is the latency-critical path: for the windowed-state archs
(rwkv6 / zamba2 long-context) its per-token cost is worst-case O(1) monoid
combines — the paper's guarantee surfacing as serve-tail-latency uniformity.

Windowed serve telemetry rides on the unified telemetry layer: per-slot
occupancy / retire-rate and decode-step latency over the last
``telemetry_window`` engine steps live in ONE product-monoid state (a single
extra jitted dispatch per step), surfaced via :meth:`DecodeEngine.telemetry`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.monoids import max_monoid, mean_monoid
from repro.core.telemetry import WindowedTelemetry
from repro.models.common import ModelConfig
from repro.models.transformer import DecodeSpec, build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 32
    eos: Optional[int] = None
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        batch_slots: int,
        cache_len: int,
        telemetry_window: int = 128,
    ):
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        # per-slot windowed serve stats: one B-lane product-monoid state,
        # one jitted dispatch per engine step
        self._telem = WindowedTelemetry(
            {
                "active": mean_monoid(),       # per-slot occupancy fraction
                "retired": mean_monoid(),      # per-slot retire rate / step
                "decode_ms": mean_monoid(),    # decode-step latency (lock-step)
                "decode_ms_max": max_monoid(),
            },
            telemetry_window,
            batch=batch_slots,
        )
        self.model = build_model(cfg)
        self.spec = DecodeSpec(
            cache_len=cache_len,
            local_cache_len=min(cfg.local_window, cache_len),
            batch=batch_slots,
        )
        self.state = self.model.init_decode_state(params, self.spec)
        self.slot_req: list[Optional[Request]] = [None] * batch_slots
        self.slot_remaining = np.zeros(batch_slots, np.int32)
        self.queue: list[Request] = []
        self.retired: list[Request] = []  # finished since last drain
        self.cur_tok = jnp.zeros((batch_slots,), jnp.int32)
        self._decode = jax.jit(self.model.decode_step)
        # single-slot prefill (B=1 spec) + scatter into the batch state
        self.spec1 = dataclasses.replace(self.spec, batch=1)
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.spec1),
            static_argnames=(),
        )

    # -- request management ---------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def _scatter_slot(self, state1, slot: int):
        """Insert a B=1 prefilled state into batch slot ``slot``."""

        def place(full, one):
            if one.ndim == 1:  # per-row pos: (B,) ← (1,) at slot
                return jax.lax.dynamic_update_slice_in_dim(full, one, slot, axis=0)
            # caches / states are (L, B, ...): batch axis 1
            return jax.lax.dynamic_update_slice_in_dim(full, one, slot, axis=1)

        self.state = jax.tree.map(place, self.state, state1)

    def _fill_free_slots(self):
        for slot in range(self.B):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
                logits, st1 = self._prefill(self.params, batch)
                self._scatter_slot(st1, slot)
                tok = int(jnp.argmax(logits[0]))
                req.out.append(tok)
                self.cur_tok = self.cur_tok.at[slot].set(tok)
                self.slot_req[slot] = req
                self.slot_remaining[slot] = req.max_new - 1

    # -- the decode loop --------------------------------------------------

    def step(self) -> int:
        """One engine step: refill slots, decode once, retire finished.
        Returns the number of active slots.  Retired requests are collected
        in ``self.retired`` (drained by :meth:`run_until_drained`)."""
        self._fill_free_slots()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        t0 = time.perf_counter()
        logits, self.state = self._decode(self.params, self.state, self.cur_tok)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.cur_tok = nxt
        nxt_np = np.asarray(nxt)  # host sync: the decode step is complete
        decode_ms = (time.perf_counter() - t0) * 1e3
        retired_mask = np.zeros(self.B, np.float32)
        for i in active:
            req = self.slot_req[i]
            tok = int(nxt_np[i])
            req.out.append(tok)
            self.slot_remaining[i] -= 1
            if self.slot_remaining[i] <= 0 or (req.eos is not None and tok == req.eos):
                req.done = True
                self.slot_req[i] = None
                self.retired.append(req)
                retired_mask[i] = 1.0
        active_mask = np.zeros(self.B, np.float32)
        active_mask[active] = 1.0
        self._telem.observe(
            {
                "active": jnp.asarray(active_mask),
                "retired": jnp.asarray(retired_mask),
                "decode_ms": jnp.float32(decode_ms),
                "decode_ms_max": jnp.float32(decode_ms),
            }
        )
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        done: list[Request] = []
        for _ in range(max_steps):
            n = self.step()
            done.extend(self.retired)
            self.retired.clear()
            if n == 0 and not self.queue:
                break
        return done

    # -- windowed serve telemetry -----------------------------------------

    def telemetry(self) -> dict:
        """Windowed serve statistics over the last ``telemetry_window``
        engine steps (one host transfer): per-slot occupancy and retire
        rate, decode-step latency mean/max (ms).  All slots decode in
        lock-step, so the latency window is shared across lanes."""
        s = self._telem.snapshot()  # dict of (B,) arrays
        return {
            "slot_occupancy": np.asarray(s["active"]),
            "slot_retire_rate": np.asarray(s["retired"]),
            "decode_ms_mean": float(np.asarray(s["decode_ms"])[0]),
            "decode_ms_max": float(np.asarray(s["decode_ms_max"])[0]),
        }
