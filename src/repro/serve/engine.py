"""Batched serving engine: continuous batching over a fixed slot grid.

``DecodeEngine`` owns a B-slot batched decode state (KV caches / SSM states).
Requests queue up; free slots are prefilled one at a time (their caches
scattered into the batch at the slot index) and then all active slots decode
in lock-step — the standard continuous-batching pattern.  Finished sequences
(EOS or max-len) retire and their slots are refilled.

The decode step is the latency-critical path: for the windowed-state archs
(rwkv6 / zamba2 long-context) its per-token cost is worst-case O(1) monoid
combines — the paper's guarantee surfacing as serve-tail-latency uniformity.

Windowed serve telemetry rides on the unified telemetry layer: per-slot
occupancy / retire-rate, decode-step latency, and a KLL tail-latency sketch
(p50/p95/p99) live in ONE product-monoid state (a single extra jitted
dispatch per step), surfaced via :meth:`DecodeEngine.telemetry`.  The window
is **event-time** by default (``telemetry_horizon`` seconds of wall clock,
each step observed at its completion timestamp): under stragglers a
count-of-steps window silently stretches to cover more wall time exactly
when latency is most interesting, whereas the horizon window keeps
measuring the same span of real time.  Telemetry survives restarts via
:meth:`DecodeEngine.save_telemetry` / :meth:`DecodeEngine.restore_telemetry`
(the checkpoint layer of :mod:`repro.train.checkpoint`).

Per-REQUEST windows ride on the keyed store
(:class:`repro.core.telemetry.KeyedTelemetry` over
:mod:`repro.core.keyed`): each engine step issues one fused mixed-key
dispatch observing every active slot under its request id, so
:meth:`DecodeEngine.request_telemetry` serves per-request decode-latency
and token-throughput windows for an unbounded id space with a bounded
(LRU-evicted) hot set.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.monoids import count_monoid, kll_monoid, max_monoid, mean_monoid
from repro.core.telemetry import WindowedTelemetry
from repro.models.common import ModelConfig
from repro.models.transformer import DecodeSpec, build_model
from repro.train import checkpoint


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 32
    eos: Optional[int] = None
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        batch_slots: int,
        cache_len: int,
        telemetry_window: int = 128,
        telemetry_horizon: Optional[float] = 30.0,
        request_telemetry_slots: Optional[int] = None,
        obs=None,
    ):
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        # obs: repro.obs.registry.ObsConfig — the serve step already pays a
        # host sync per decode step (decode_ms is a host float), so the obs
        # hook is a free host-side histogram append + counters; disabled
        # changes nothing in the traced computation
        self._obs = obs if (obs is not None and obs.enabled) else None
        self._obs_hist = None
        self._obs_steps = 0
        self._obs_tokens = 0
        if self._obs is not None:
            reg = self._obs.resolved_registry()
            self._obs_hist = reg.histogram(
                "repro_serve_decode_ms", "decode-step latency (ms)",
            )
            self.attach_obs(reg)
        # per-slot windowed serve stats: one B-lane product-monoid state,
        # one jitted dispatch per engine step.  Default is an EVENT-TIME
        # window (``telemetry_horizon`` seconds, each step observed at its
        # completion time) so the stats stay correct under stragglers; pass
        # ``telemetry_horizon=None`` for a count window of
        # ``telemetry_window`` steps.  In event-time mode the engine holds
        # at most max(telemetry_window, 512) in-horizon steps — past that
        # the window covers the newest steps only and telemetry() reports
        # the loss under "telemetry_overflow".
        metrics = {
            "active": mean_monoid(),       # per-slot occupancy fraction
            "retired": mean_monoid(),      # per-slot retire rate / step
            "decode_ms": mean_monoid(),    # decode-step latency (lock-step)
            "decode_ms_max": max_monoid(),
            # tail latency: mergeable KLL quantile sketch (p50/p95/p99);
            # representable weight k*(2^levels - 1) = 1984 must cover the
            # engine's max in-horizon step count (512 below) or the top
            # level silently sheds the coarsest summaries
            "decode_ms_q": kll_monoid(k=64, levels=5),
        }
        if telemetry_horizon is None:
            self._telem = WindowedTelemetry(
                metrics, telemetry_window, batch=batch_slots
            )
        else:
            self._telem = WindowedTelemetry(
                metrics,
                horizon=float(telemetry_horizon),
                capacity=max(int(telemetry_window), 512),
                batch=batch_slots,
            )
        self._telem_t0 = time.perf_counter()  # float32-safe ts anchor
        # per-REQUEST-key windows on the keyed store: decode latency and
        # token throughput per request id, over the last telemetry_window
        # steps OF THAT REQUEST.  Slots bound the hot set (finished
        # requests age out via LRU) while request ids grow without bound.
        if request_telemetry_slots is None:
            request_telemetry_slots = max(4 * batch_slots, 64)
        self._keyed_telem = WindowedTelemetry.keyed(
            {
                "decode_ms": mean_monoid(),
                "tokens": count_monoid(),
                "decode_ms_max": max_monoid(),
            },
            window=telemetry_window,
            slots=request_telemetry_slots,
            chunk=batch_slots,
        )
        self.model = build_model(cfg)
        self.spec = DecodeSpec(
            cache_len=cache_len,
            local_cache_len=min(cfg.local_window, cache_len),
            batch=batch_slots,
        )
        self.state = self.model.init_decode_state(params, self.spec)
        self.slot_req: list[Optional[Request]] = [None] * batch_slots
        self.slot_remaining = np.zeros(batch_slots, np.int32)
        self.queue: list[Request] = []
        self.retired: list[Request] = []  # finished since last drain
        self.retired_count = 0  # finished since engine start (monotone)
        self.cur_tok = jnp.zeros((batch_slots,), jnp.int32)
        self._decode = jax.jit(self.model.decode_step)
        # single-slot prefill (B=1 spec) + scatter into the batch state
        self.spec1 = dataclasses.replace(self.spec, batch=1)
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.spec1),
            static_argnames=(),
        )

    # -- request management ---------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def _scatter_slot(self, state1, slot: int):
        """Insert a B=1 prefilled state into batch slot ``slot``."""

        def place(full, one):
            if one.ndim == 1:  # per-row pos: (B,) ← (1,) at slot
                return jax.lax.dynamic_update_slice_in_dim(full, one, slot, axis=0)
            # caches / states are (L, B, ...): batch axis 1
            return jax.lax.dynamic_update_slice_in_dim(full, one, slot, axis=1)

        self.state = jax.tree.map(place, self.state, state1)

    def _fill_free_slots(self):
        for slot in range(self.B):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
                logits, st1 = self._prefill(self.params, batch)
                self._scatter_slot(st1, slot)
                tok = int(jnp.argmax(logits[0]))
                req.out.append(tok)
                self.cur_tok = self.cur_tok.at[slot].set(tok)
                self.slot_req[slot] = req
                self.slot_remaining[slot] = req.max_new - 1

    # -- the decode loop --------------------------------------------------

    def step(self) -> int:
        """One engine step: refill slots, decode once, retire finished.
        Returns the number of active slots.  Retired requests are collected
        in ``self.retired`` (drained by :meth:`run_until_drained`)."""
        self._fill_free_slots()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        t0 = time.perf_counter()
        logits, self.state = self._decode(self.params, self.state, self.cur_tok)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.cur_tok = nxt
        nxt_np = np.asarray(nxt)  # host sync: the decode step is complete
        decode_ms = (time.perf_counter() - t0) * 1e3
        if self._obs is not None:
            self._obs_hist.observe(decode_ms)
            self._obs_steps += 1
            self._obs_tokens += len(active)
            tr = self._obs.trace
            if tr is not None:
                tr.complete("serve.decode_step", tr._now_us() - decode_ms * 1e3,
                            decode_ms * 1e3, tid=2,
                            args={"active_slots": len(active)})
        rid_by_slot = {i: self.slot_req[i].rid for i in active}
        retired_mask = np.zeros(self.B, np.float32)
        for i in active:
            req = self.slot_req[i]
            tok = int(nxt_np[i])
            req.out.append(tok)
            self.slot_remaining[i] -= 1
            if self.slot_remaining[i] <= 0 or (req.eos is not None and tok == req.eos):
                req.done = True
                self.slot_req[i] = None
                self.retired.append(req)
                self.retired_count += 1
                retired_mask[i] = 1.0
        active_mask = np.zeros(self.B, np.float32)
        active_mask[active] = 1.0
        # event time = wall-clock completion of this decode step
        now = time.perf_counter() - self._telem_t0
        self._telem.observe(
            {
                "active": jnp.asarray(active_mask),
                "retired": jnp.asarray(retired_mask),
                "decode_ms": jnp.float32(decode_ms),
                "decode_ms_max": jnp.float32(decode_ms),
                "decode_ms_q": jnp.float32(decode_ms),
            },
            ts=now,
        )
        # per-request keyed windows: one fused mixed-key dispatch (slot i's
        # row is keyed by its request id; free slots are masked out).
        # note `active` still reflects the slots that decoded THIS step —
        # retirement above only cleared slot_req for the next step.
        rids = np.zeros(self.B, np.int32)
        for i in active:
            rids[i] = rid_by_slot[i]
        self._keyed_telem.observe_bulk(
            jnp.asarray(rids),
            {
                "decode_ms": jnp.full((self.B,), decode_ms, jnp.float32),
                "tokens": jnp.zeros((self.B,), jnp.int32),  # count lifts to 1
                "decode_ms_max": jnp.full((self.B,), decode_ms, jnp.float32),
            },
            ts=now,
            mask=jnp.asarray(active_mask > 0),
        )
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        done: list[Request] = []
        for _ in range(max_steps):
            n = self.step()
            done.extend(self.retired)
            self.retired.clear()
            if n == 0 and not self.queue:
                break
        return done

    # -- observability -----------------------------------------------------

    def attach_obs(self, registry, *, prefix: str = "repro_serve"):
        """Register the serve scrape collector: engine step/token counters,
        live slot occupancy, queue depth, retired requests, telemetry
        overflow.  (The decode-ms KLL summary registers separately as
        ``repro_serve_decode_ms`` when the engine is built with ``obs=``.)"""
        registry.describe(f"{prefix}_steps_total", "counter",
                          "decode engine steps")
        registry.describe(f"{prefix}_tokens_total", "counter",
                          "tokens decoded across all slots")
        registry.describe(f"{prefix}_active_slots", "gauge",
                          "slots decoding this step")
        registry.describe(f"{prefix}_queue_depth", "gauge",
                          "requests waiting for a slot")
        registry.describe(f"{prefix}_retired_total", "counter",
                          "requests finished since engine start")
        registry.describe(f"{prefix}_telemetry_overflow_total", "counter",
                          "telemetry steps lost to window capacity")

        def collect():
            return {
                f"{prefix}_steps_total": self._obs_steps,
                f"{prefix}_tokens_total": self._obs_tokens,
                f"{prefix}_active_slots": sum(
                    r is not None for r in self.slot_req
                ),
                f"{prefix}_queue_depth": len(self.queue),
                f"{prefix}_retired_total": self.retired_count,
                f"{prefix}_telemetry_overflow_total":
                    self._telem.overflow_count(),
            }

        registry.register_collector(collect)
        return collect

    # -- windowed serve telemetry -----------------------------------------

    def telemetry(self) -> dict:
        """Windowed serve statistics (one host transfer): per-slot occupancy
        and retire rate, decode-step latency mean/max and KLL tail
        quantiles p50/p95/p99 (ms), over the last ``telemetry_horizon``
        seconds of engine steps (or ``telemetry_window`` steps in count
        mode).  All slots decode in lock-step, so the latency window is
        shared across lanes."""
        s = self._telem.snapshot()  # (B,)-leading; lane axis squeezed at B=1
        q = np.atleast_2d(np.asarray(s["decode_ms_q"]))[0]  # (3,): p50/95/99
        return {
            "slot_occupancy": np.atleast_1d(np.asarray(s["active"])),
            "slot_retire_rate": np.atleast_1d(np.asarray(s["retired"])),
            "decode_ms_mean": float(np.atleast_1d(np.asarray(s["decode_ms"]))[0]),
            "decode_ms_max": float(np.atleast_1d(np.asarray(s["decode_ms_max"]))[0]),
            "decode_ms_p50": float(q[0]),
            "decode_ms_p95": float(q[1]),
            "decode_ms_p99": float(q[2]),
            # steps lost to the event-time engine's capacity (0 = the full
            # horizon is represented; raise telemetry_window to extend)
            "telemetry_overflow": self._telem.overflow_count(),
        }

    def request_telemetry(self, rids=None) -> dict:
        """Per-REQUEST windowed stats from the keyed store: decode-latency
        mean/max and decoded-token count over each request's own last
        ``telemetry_window`` steps.  ``rids`` defaults to every request id
        still holding a store slot (finished requests age out via LRU).
        Returns ``{rid: {"decode_ms_mean", "decode_ms_max", "tokens"}}``
        plus the store's admission counters under ``"_counters"``."""
        if rids is None:
            rids = sorted(int(k) for k in self._keyed_telem.live_keys())
        rids = list(rids)
        out = {"_counters": self._keyed_telem.counters()}
        if not rids:
            return out
        s = self._keyed_telem.snapshot(np.asarray(rids, np.int32))
        for j, rid in enumerate(rids):
            if bool(s["found"][j]):
                out[rid] = {
                    "decode_ms_mean": float(s["decode_ms"][j]),
                    "decode_ms_max": float(s["decode_ms_max"][j]),
                    "tokens": int(s["tokens"][j]),
                }
        return out

    # -- telemetry checkpoint/restore --------------------------------------

    def save_telemetry(self, directory: str, step: int) -> str:
        """Checkpoint the windowed serve telemetry — the global event-time
        window AND the per-request keyed store (atomic, see
        :mod:`repro.train.checkpoint`); returns the checkpoint path."""
        payload = {
            "telem": self._telem.state_dict(),
            "keyed": self._keyed_telem.state_dict(),
        }
        return checkpoint.save(payload, directory, step)

    def restore_telemetry(self, directory: str, step: Optional[int] = None) -> int:
        """Restore telemetry saved by :meth:`save_telemetry` (latest step if
        unspecified) — the global and per-request windows both survive an
        engine restart.  Returns the restored step."""
        if step is None:
            step = checkpoint.latest_step(directory)
            if step is None:
                raise FileNotFoundError(f"no telemetry checkpoint under {directory}")
        like = {
            "telem": self._telem.state_dict(),
            "keyed": self._keyed_telem.state_dict(),
        }
        sd = checkpoint.restore(directory, step, like=like)
        self._telem.load_state_dict(sd["telem"])
        self._keyed_telem.load_state_dict(sd["keyed"])
        # continue the anchored serve clock from the restored watermark so
        # post-restore steps are not "late" against the saved window
        self._telem_t0 = time.perf_counter() - self._telem.last_timestamp()
        return step
