"""Batched serving engine: continuous batching over a fixed slot grid.

``DecodeEngine`` owns a B-slot batched decode state (KV caches / SSM states).
Requests queue up; free slots are prefilled one at a time (their caches
scattered into the batch at the slot index) and then all active slots decode
in lock-step — the standard continuous-batching pattern.  Finished sequences
(EOS or max-len) retire and their slots are refilled.

The decode step is the latency-critical path: for the windowed-state archs
(rwkv6 / zamba2 long-context) its per-token cost is worst-case O(1) monoid
combines — the paper's guarantee surfacing as serve-tail-latency uniformity.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig
from repro.models.transformer import DecodeSpec, build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 32
    eos: Optional[int] = None
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int, cache_len: int):
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.model = build_model(cfg)
        self.spec = DecodeSpec(
            cache_len=cache_len,
            local_cache_len=min(cfg.local_window, cache_len),
            batch=batch_slots,
        )
        self.state = self.model.init_decode_state(params, self.spec)
        self.slot_req: list[Optional[Request]] = [None] * batch_slots
        self.slot_remaining = np.zeros(batch_slots, np.int32)
        self.queue: list[Request] = []
        self.retired: list[Request] = []  # finished since last drain
        self.cur_tok = jnp.zeros((batch_slots,), jnp.int32)
        self._decode = jax.jit(self.model.decode_step)
        # single-slot prefill (B=1 spec) + scatter into the batch state
        self.spec1 = dataclasses.replace(self.spec, batch=1)
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.spec1),
            static_argnames=(),
        )

    # -- request management ---------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def _scatter_slot(self, state1, slot: int):
        """Insert a B=1 prefilled state into batch slot ``slot``."""

        def place(full, one):
            if one.ndim == 1:  # per-row pos: (B,) ← (1,) at slot
                return jax.lax.dynamic_update_slice_in_dim(full, one, slot, axis=0)
            # caches / states are (L, B, ...): batch axis 1
            return jax.lax.dynamic_update_slice_in_dim(full, one, slot, axis=1)

        self.state = jax.tree.map(place, self.state, state1)

    def _fill_free_slots(self):
        for slot in range(self.B):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
                logits, st1 = self._prefill(self.params, batch)
                self._scatter_slot(st1, slot)
                tok = int(jnp.argmax(logits[0]))
                req.out.append(tok)
                self.cur_tok = self.cur_tok.at[slot].set(tok)
                self.slot_req[slot] = req
                self.slot_remaining[slot] = req.max_new - 1

    # -- the decode loop --------------------------------------------------

    def step(self) -> int:
        """One engine step: refill slots, decode once, retire finished.
        Returns the number of active slots.  Retired requests are collected
        in ``self.retired`` (drained by :meth:`run_until_drained`)."""
        self._fill_free_slots()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        logits, self.state = self._decode(self.params, self.state, self.cur_tok)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.cur_tok = nxt
        nxt_np = np.asarray(nxt)
        for i in active:
            req = self.slot_req[i]
            tok = int(nxt_np[i])
            req.out.append(tok)
            self.slot_remaining[i] -= 1
            if self.slot_remaining[i] <= 0 or (req.eos is not None and tok == req.eos):
                req.done = True
                self.slot_req[i] = None
                self.retired.append(req)
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        done: list[Request] = []
        for _ in range(max_steps):
            n = self.step()
            done.extend(self.retired)
            self.retired.clear()
            if n == 0 and not self.queue:
                break
        return done
