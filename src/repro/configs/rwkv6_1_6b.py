"""rwkv6-1.6b [ssm] — Finch, data-dependent decay [arXiv:2404.05892; unverified].

24L d_model=2048 (attention-free) d_ff=7168 vocab=65536.  The per-token state
update is an affine map — the non-invertible, non-commutative monoid that the
paper's DABA Lite maintains for exact windowed decode (long_500k path).
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,  # head size 64
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    rwkv=True,
    tie_embeddings=False,
)
