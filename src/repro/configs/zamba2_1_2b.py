"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf].

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64.  A
single shared attention+MLP block (one parameter set) is applied every 6
Mamba-2 layers; at long context it runs with a sliding window, whose KV ring
is the paper's FIFO eviction.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    mamba=True,
    shared_attn_every=6,
    local_window=4096,
    tie_embeddings=True,
)
