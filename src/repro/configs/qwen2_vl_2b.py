"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.  Backbone only: the
vision frontend is a STUB — input_specs() provides precomputed patch
embeddings plus the 3-stream (t, h, w) M-RoPE position ids.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    mrope=True,
    embed_inputs=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
)
