"""Assigned input shapes (identical across all 10 LM architectures).

  train_4k     seq_len=4096   global_batch=256   → train_step
  prefill_32k  seq_len=32768  global_batch=32    → prefill (inference)
  decode_32k   seq_len=32768  global_batch=128   → serve_step (1 new token,
                                                    KV/state covers seq_len)
  long_500k    seq_len=524288 global_batch=1     → serve_step; sub-quadratic
                                                    archs only (see DESIGN.md)
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Architectures whose decode at 500k context is sub-quadratic / state-bounded.
LONG_CONTEXT_ARCHS = {"gemma2-27b", "rwkv6-1.6b", "zamba2-1.2b"}


def cells(arch_names):
    """All (arch, shape) dry-run cells, with inapplicable ones marked skip."""
    out = []
    for a in arch_names:
        for s in SHAPES.values():
            skip = s.name == "long_500k" and a not in LONG_CONTEXT_ARCHS
            out.append((a, s.name, skip))
    return out
