"""Per-architecture runtime profiles: the distributed-optimization knobs
that make each (arch × shape) cell fit a 16 GB/chip v5e pod.

  accum        gradient-accumulation microbatch count for train_4k
               (activation temp memory ∝ global_batch / accum)
  opt_dtype    AdamW m/v storage dtype (bf16 for the giant MoEs: params +
               optimizer in f32 exceed a pod's aggregate HBM)
  fsdp         shard large parameter leaves over the data axes as well
               (ZeRO-3-style; per-layer JIT all-gather inside the scan)
  fsdp_serve   same for the read-only serving params (prefill/decode)

Derived empirically from the dry-run memory_analysis (EXPERIMENTS.md
§Dry-run records before/after).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RunProfile:
    accum: int = 4
    opt_dtype: object = jnp.float32
    fsdp: bool = False
    fsdp_serve: bool = True  # serving params are read-only: always shard


PROFILES = {
    "llama3.2-1b": RunProfile(accum=2),
    "gemma2-27b": RunProfile(accum=8, fsdp=True),
    "minitron-8b": RunProfile(accum=4, fsdp=True),
    "codeqwen1.5-7b": RunProfile(accum=4, fsdp=True),
    "qwen2-vl-2b": RunProfile(accum=2),
    "arctic-480b": RunProfile(accum=16, opt_dtype=jnp.bfloat16, fsdp=True),
    "grok-1-314b": RunProfile(accum=16, opt_dtype=jnp.bfloat16, fsdp=True),
    "whisper-large-v3": RunProfile(accum=8),
    "rwkv6-1.6b": RunProfile(accum=2),
    "zamba2-1.2b": RunProfile(accum=2),
}


def get_profile(arch: str) -> RunProfile:
    return PROFILES.get(arch, RunProfile())
