"""Architecture registry: the 10 assigned configs, selectable via --arch."""

from repro.configs import shapes
from repro.configs.arctic_480b import CONFIG as arctic_480b
from repro.configs.codeqwen1_5_7b import CONFIG as codeqwen1_5_7b
from repro.configs.gemma2_27b import CONFIG as gemma2_27b
from repro.configs.grok_1_314b import CONFIG as grok_1_314b
from repro.configs.llama3_2_1b import CONFIG as llama3_2_1b
from repro.configs.minitron_8b import CONFIG as minitron_8b
from repro.configs.qwen2_vl_2b import CONFIG as qwen2_vl_2b
from repro.configs.rwkv6_1_6b import CONFIG as rwkv6_1_6b
from repro.configs.whisper_large_v3 import CONFIG as whisper_large_v3
from repro.configs.zamba2_1_2b import CONFIG as zamba2_1_2b

ARCHS = {
    c.name: c
    for c in [
        llama3_2_1b,
        gemma2_27b,
        minitron_8b,
        codeqwen1_5_7b,
        qwen2_vl_2b,
        arctic_480b,
        grok_1_314b,
        whisper_large_v3,
        rwkv6_1_6b,
        zamba2_1_2b,
    ]
}


def get_config(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


SHAPES = shapes.SHAPES
LONG_CONTEXT_ARCHS = shapes.LONG_CONTEXT_ARCHS
