"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1; unverified].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8e top-2.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    experts_per_token=2,
    attn_softcap=30.0,
    logit_softcap=30.0,
    tie_embeddings=False,
)
