"""whisper-large-v3 [audio] — enc-dec, conv frontend stub
[arXiv:2212.04356; unverified].

32L d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.  32 encoder + 32 decoder
layers; the mel+conv frontend is a STUB — input_specs() provides precomputed
frame embeddings (1500 frames = 30 s).  Decoder self-attn is causal; cross
attention reads the encoder output.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    is_encoder_decoder=True,
    encoder_layers=32,
    encoder_seq=1500,
    embed_inputs=False,  # decoder consumes token ids; frames via batch["frames"]
    tie_embeddings=True,
)
