"""gemma2-27b [dense] — local+global alternating attention, logit softcap
[arXiv:2408.00118; hf].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.  Even layers use a
4096-token sliding window (the framework's ring-KV eviction path); odd layers
are global.  Attention softcap 50, final-logit softcap 30.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    d_ff=36864,
    vocab_size=256000,
    head_dim=128,
    attn_pattern="alternating",
    local_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    tie_embeddings=True,
)
