"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2 with a
parallel dense-residual MLP per layer (arctic's dense+MoE hybrid design).
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    num_experts=128,
    experts_per_token=2,
    moe_dense_residual=True,
    tie_embeddings=False,
    # 56 heads don't divide the 16-way model axis: shard the attention
    # section's batch over data×model instead (4.7x roofline win, see
    # EXPERIMENTS.md §Perf).
    pin_attn_batch=True,
)
