"""Model substrate: config schema, initializers, norms, RoPE / M-RoPE.

All models are pure-functional JAX: params are nested dicts of arrays,
layer stacks carry a leading (L,) axis and are driven by ``lax.scan`` so
compile time and HLO size are O(1) in depth (required for 46-layer dry-runs).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config object covers all 10 assigned architectures."""

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # attention pattern
    attn_pattern: str = "full"  # full | local | alternating(local/global)
    local_window: int = 4096
    logit_softcap: float = 0.0  # gemma2 final-logit capping
    attn_softcap: float = 0.0  # gemma2 attention-score capping

    # MoE
    num_experts: int = 0
    experts_per_token: int = 2
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel
    capacity_factor: float = 1.25

    # SSM / RWKV
    ssm_state: int = 0
    rwkv: bool = False
    mamba: bool = False
    shared_attn_every: int = 0  # zamba2: shared attention block period

    # positions
    rope_theta: float = 10000.0
    mrope: bool = False  # qwen2-vl 3-section M-RoPE

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper 30 s @ 50 Hz after conv stub

    # frontend stub: inputs are precomputed embeddings, not token ids
    embed_inputs: bool = False

    dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    remat: bool = True  # activation checkpointing per layer

    # --- cost-accounting knobs (dry-run only; defaults = production) ------
    # XLA cost_analysis counts while-loop bodies once; the dry-run compiles
    # reduced-depth variants with unrolled scans to recover exact per-layer
    # costs (launch/dryrun.py).
    unroll_layers: bool = False  # unroll the layer scan(s)
    unroll_attn: bool = False  # unroll the blocked-attention q-chunk scan
    q_chunk: int = 512  # blocked-attention query chunk (seq_len ⇒ 1 chunk)

    # --- sharding-strategy knobs (§Perf hillclimb levers) ------------------
    # moe_2d: constrain MoE dispatch activations to the expert weights' 2-D
    # (E×d over model×data) layout, so the expert einsums contract the
    # data-sharded dim instead of replicating the batch (arctic) or
    # gathering weights per step (grok decode).
    moe_2d: bool = False
    # gather_attn_weights: for archs whose heads don't divide the model axis
    # (replicated attention weights + FSDP storage), force the JIT weight
    # all-gather instead of letting the partitioner replicate batch compute.
    gather_attn_weights: bool = False
    # pin_attn_batch: constrain q/k/v/o activations to stay batch-sharded
    # through the attention block, so FSDP-stored weights are gathered
    # (MBs) instead of activations (GBs) — the arctic-56-head fix (§Perf).
    pin_attn_batch: bool = False
    # gla_chunk: chunked-GLA block length (SSM archs): state HBM traffic
    # ∝ 1/chunk, intra-chunk compute ∝ chunk.
    gla_chunk: int = 0  # 0 = per-family default (rwkv 64, mamba 16)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 16 so the embedding/logits can
        always shard over the 16-way model axis (whisper: 51866 → 51872).
        Pad logits are masked to -inf in logits_fn."""
        return -(-self.vocab_size // 16) * 16

    @property
    def is_attention_free(self) -> bool:
        return (self.rwkv or self.mamba) and self.shared_attn_every == 0

    def layer_is_local(self, layer_idx: jax.Array) -> jax.Array:
        """gemma2: even layers local, odd layers global (alternating)."""
        if self.attn_pattern == "local":
            return jnp.ones_like(layer_idx, bool)
        if self.attn_pattern == "alternating":
            return (layer_idx % 2) == 0
        return jnp.zeros_like(layer_idx, bool)

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline accounting)."""
        d, f, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd, H, Hkv = self.hd, self.num_heads, self.num_kv_heads
        attn = d * H * hd + 2 * d * Hkv * hd + H * hd * d
        dense_mlp = 3 * d * f
        per_layer = 0
        if self.rwkv:
            # r,k,v,g,o projections + decay lora + channel-mix (≈ swiglu)
            per_layer = 5 * d * d + 2 * d * 64 + 3 * d * f
        elif self.mamba:
            S = self.ssm_state
            per_layer = 2 * d * f + f * (2 * S) + f * d + f  # in/out/BC/dt
        elif self.num_experts > 0:
            per_layer = attn + 3 * d * f * self.num_experts + d * self.num_experts
            if self.moe_dense_residual:
                per_layer += dense_mlp
        else:
            per_layer = attn + dense_mlp
        total = L * per_layer + V * d  # embed (+ tied head)
        if self.shared_attn_every > 0:
            total += attn + dense_mlp  # one shared block
        if self.is_encoder_decoder:
            total += self.encoder_layers * (attn + dense_mlp)
            total += L * attn  # cross-attention
        if not self.tie_embeddings:
            total += V * d
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.num_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        full = self.param_count()
        inactive = self.num_layers * 3 * d * f * (self.num_experts - self.experts_per_token)
        return int(full - inactive)


# ---------------------------------------------------------------------------
# Initializers (used by smoke tests / examples; dry-run uses eval_shape)
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + weight.astype(jnp.float32))
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, T, D); positions: (B, T) int32."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)  # (D/2,)
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,T,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections=(2, 3, 3)) -> jax.Array:
    """Qwen2-VL multimodal RoPE: positions (3, B, T) for (t, h, w) streams.

    The D/2 frequency slots are split into ``sections`` (scaled to D/2) and
    each section uses its own position stream.  With text-only positions
    (all three streams equal) this reduces to standard RoPE.
    """
    D = x.shape[-1]
    half = D // 2
    sec = [s * half // sum(sections) for s in sections]
    sec[-1] = half - sum(sec[:-1])
    freqs = rope_freqs(D, theta)  # (half,)
    # Build a (B, T, half) angle table with per-section position streams.
    parts = []
    off = 0
    for i, s in enumerate(sec):
        pos = positions[i].astype(jnp.float32)  # (B, T)
        parts.append(pos[:, :, None] * freqs[off : off + s])
        off += s
    angles = jnp.concatenate(parts, axis=-1)[:, None, :, :]  # (B,1,T,half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
