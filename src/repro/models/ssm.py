"""SSM / linear-attention blocks: RWKV-6 (Finch) and Mamba-2 (SSD), plus the
chunked gated-linear-attention primitive both reduce to.

Both architectures update a per-head state with an *affine map* per token —
``S_t = diag(a_t) · S_{t-1} + k_tᵀ v_t`` — the very monoid DABA Lite maintains
for windowed decode (repro.core.windowed_state).  Training uses the chunked
form: sequential scan across chunks (carrying only the (B,H,K,V) state) and
matmul-parallel work within chunks, which cuts the state HBM traffic by the
chunk length versus a per-token scan — this trade is one of the §Perf levers.

RWKV-6 specifics: token-shift interpolation, data-dependent per-channel decay
via a low-rank adapter (``w_t = exp(-exp(w0 + tanh(x·A)·B))``), bonus ``u``
term, output gating, channel-mix MLP.
Mamba-2 specifics: input-dependent Δ_t, scalar-per-head decay
``a_t = exp(Δ_t·A)``, B/C projections (state in/out), D skip, gated output.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, rmsnorm


# ---------------------------------------------------------------------------
# Gated linear attention: chunked (train) and sequential (decode / oracle)
# ---------------------------------------------------------------------------


def gla_sequential(r, k, v, a, state, bonus_u=None):
    """Per-token scan oracle.  r,k,a: (B,T,H,K); v: (B,T,H,V);
    state: (B,H,K,V).  Returns (outputs (B,T,H,V), final_state)."""

    def step(s, xs):
        rt, kt, vt, at = xs  # (B,H,K), (B,H,K), (B,H,V), (B,H,K)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        if bonus_u is not None:
            # RWKV-6: o_t = r_t · (S_{t-1} + diag(u) k_tᵀv_t); decay after.
            eff = s + bonus_u[None, :, :, None] * kv
            o = jnp.einsum("bhk,bhkv->bhv", rt, eff)
            s = at[..., None] * s + kv
        else:
            # Mamba-2 / SSD: h_t = a_t h_{t-1} + k_tᵀv_t; o_t = r_t · h_t.
            s = at[..., None] * s + kv
            o = jnp.einsum("bhk,bhkv->bhv", rt, s)
        return s, o

    xs = jax.tree.map(lambda x: jnp.moveaxis(x, 1, 0), (r, k, v, a))
    state, outs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(outs, 0, 1), state


def gla_chunked(r, k, v, a, state, bonus_u=None, chunk: int = 64):
    """Chunked GLA.  Same contract as :func:`gla_sequential`.

    Within a chunk (length L, cumulative decay P_t = ∏_{j≤t} a_j):

        o_t   = (r_t ⊙ P_{t-1}) · S_0  +  Σ_{j<t} [(r_t ⊙ P_{t-1}) · (k_j / P_j)] v_j
                (+ bonus/self term)
        S_L   = P_L ⊙ S_0 + Σ_j ((P_L / P_j) ⊙ k_j) ⊗ v_j

    Numerical note: the ``k_j / P_j`` factorization assumes decays not far
    below 1 within a chunk (true for RWKV-6/Mamba-2 operating ranges); chunk
    length bounds the dynamic range.
    """
    B, T, H, K = r.shape
    V = v.shape[-1]
    L = min(chunk, T)
    if T % L:
        pad = L - T % L
        zeros = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zeros(r), zeros(k), zeros(v)
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        Tp = T + pad
    else:
        Tp = T
    nc = Tp // L

    def reshape_c(x):
        return jnp.moveaxis(
            x.reshape(B, nc, L, H, x.shape[-1]), 1, 0
        )  # (nc, B, L, H, ·)

    rc, kc, vc, ac = map(reshape_c, (r, k, v, a))
    causal_strict = jnp.tril(jnp.ones((L, L), bool), k=-1)
    causal_incl = jnp.tril(jnp.ones((L, L), bool))

    def one_chunk(S0, xs):
        rt, kt, vt, at = xs  # (B,L,H,·) f32
        logp = jnp.cumsum(jnp.log(jnp.maximum(at, 1e-12)), axis=1)  # (B,L,H,K)
        P = jnp.exp(logp)  # inclusive ∏
        k_t = kt / jnp.maximum(P, 1e-24)
        if bonus_u is not None:
            # RWKV-6 reads the PRE-decay state: use P_{t-1}, strict mask,
            # current token enters through diag(u) only.
            P_prev = jnp.exp(logp - jnp.log(jnp.maximum(at, 1e-12)))
            r_t = rt * P_prev
            mask = causal_strict
        else:
            # Mamba-2 reads the POST-update state: inclusive P_t and j ≤ t.
            r_t = rt * P
            mask = causal_incl
        inter = jnp.einsum("blhk,bhkv->blhv", r_t, S0)
        scores = jnp.einsum("blhk,bmhk->bhlm", r_t, k_t)
        scores = jnp.where(mask[None, None], scores, 0.0)
        intra = jnp.einsum("bhlm,bmhv->blhv", scores, vt)
        o = inter + intra
        if bonus_u is not None:  # RWKV: current token through diag(u)
            s_self = jnp.einsum("blhk,hk,blhk->blh", rt, bonus_u, kt)
            o = o + s_self[..., None] * vt
        PL = P[:, -1]  # (B,H,K)
        S = PL[..., None] * S0 + jnp.einsum(
            "blhk,blhv->bhkv", k_t * PL[:, None], vt
        )
        return S, o

    state, outs = jax.lax.scan(one_chunk, state, (rc, kc, vc, ac))
    outs = jnp.moveaxis(outs, 0, 1).reshape(B, Tp, H, V)
    return outs[:, :T], state


# ---------------------------------------------------------------------------
# RWKV-6 layer
# ---------------------------------------------------------------------------


def init_rwkv_params(key, cfg: ModelConfig, dtype=None):
    dtype = dtype or cfg.dtype
    d, f = cfg.d_model, cfg.d_ff
    H = cfg.num_heads
    K = d // H
    ks = jax.random.split(key, 12)
    lora = 64
    return {
        "mu": (jax.random.uniform(ks[0], (5, d)) * 0.5 + 0.25).astype(jnp.float32),
        "w_r": dense_init(ks[1], (d, d), dtype),
        "w_k": dense_init(ks[2], (d, d), dtype),
        "w_v": dense_init(ks[3], (d, d), dtype),
        "w_g": dense_init(ks[4], (d, d), dtype),
        "w_o": dense_init(ks[5], (d, d), dtype),
        # decay = exp(-exp(w0 + lora)): w0 ≈ -5 gives per-step decay ≈ 0.993,
        # the RWKV operating range (and keeps the chunked k/P factorization
        # well-conditioned over a 64-token chunk).
        "decay_w0": jnp.zeros((H, K), jnp.float32) - 5.0,
        "decay_a": dense_init(ks[6], (d, lora), jnp.float32),
        "decay_b": dense_init(ks[7], (lora, d), jnp.float32),
        "bonus_u": (jax.random.normal(ks[8], (H, K)) * 0.1).astype(jnp.float32),
        "ln_x": jnp.zeros((d,), jnp.float32),
        # channel mix
        "cm_mu": (jax.random.uniform(ks[9], (d,)) * 0.5 + 0.25).astype(jnp.float32),
        "cm_k": dense_init(ks[10], (d, f), dtype),
        "cm_v": dense_init(ks[11], (f, d), dtype, scale=1.0 / math.sqrt(f)),
    }


def _token_shift(x, x_last: Optional[jax.Array] = None):
    """x: (B, T, d) → previous-token stream; x_last carries across chunks."""
    prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if x_last is not None:
        prev = prev.at[:, 0].set(x_last)
    return prev


def rwkv6_time_mix(params, x, cfg: ModelConfig, state, x_last=None, chunked=True):
    """x: (B, T, d); state: (B, H, K, K).  Returns (out, new_state, new_x_last).

    Token-shift interpolation runs in the residual dtype (bf16): keeping the
    five mix streams in f32 doubles the tensor-parallel all-reduce bytes of
    the projections' forward+backward (measured §Perf — the f32 ARs were the
    collective bottleneck for rwkv train_4k).  Only the decay adapter and the
    recurrence itself stay f32."""
    B, T, d = x.shape
    H = cfg.num_heads
    K = d // H
    mu = params["mu"].astype(x.dtype)  # (5, d): r, k, v, g, w
    prev = _token_shift(x, x_last)
    mix = lambda i: x + mu[i] * (prev - x)
    xr, xk, xv, xg, xw = (mix(i) for i in range(5))

    r = (xr @ params["w_r"]).reshape(B, T, H, K).astype(jnp.float32)
    k = (xk @ params["w_k"]).reshape(B, T, H, K).astype(jnp.float32)
    v = (xv @ params["w_v"]).reshape(B, T, H, K).astype(jnp.float32)
    g = jax.nn.silu((xg @ params["w_g"]).astype(jnp.float32))

    # data-dependent decay (the Finch contribution): low-rank adapter (f32)
    xw32 = xw.astype(jnp.float32)
    dd = jnp.tanh(xw32 @ params["decay_a"]) @ params["decay_b"]  # (B,T,d)
    w = params["decay_w0"][None, None] + dd.reshape(B, T, H, K)
    a = jnp.exp(-jnp.exp(w))  # decay in (0, 1)

    if chunked:
        o, state = gla_chunked(
            r, k, v, a, state, bonus_u=params["bonus_u"],
            chunk=cfg.gla_chunk or 64,
        )
    else:
        o, state = gla_sequential(r, k, v, a, state, bonus_u=params["bonus_u"])
    o = o.reshape(B, T, d)
    o = rmsnorm(o, params["ln_x"], cfg.norm_eps).astype(jnp.float32) * g
    out = (o.astype(x.dtype) @ params["w_o"])
    return out, state, x[:, -1].astype(jnp.float32)


def rwkv6_channel_mix(params, x, x_last=None):
    prev = _token_shift(x, x_last)
    xk = x + params["cm_mu"].astype(x.dtype) * (prev - x)
    h = jnp.square(jax.nn.relu(xk @ params["cm_k"]))
    return h @ params["cm_v"], x[:, -1].astype(jnp.float32)


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) layer
# ---------------------------------------------------------------------------


def init_mamba_params(key, cfg: ModelConfig, dtype=None):
    dtype = dtype or cfg.dtype
    d, f = cfg.d_model, cfg.d_ff  # f = expanded inner dim
    N = cfg.ssm_state
    H = cfg.num_heads  # SSD heads over the inner dim
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], (d, 2 * f), dtype),  # x and gate z
        "w_bc": dense_init(ks[1], (f, 2 * N), dtype),  # B and C (shared groups)
        "w_dt": dense_init(ks[2], (f, H), jnp.float32),
        # softplus(dt_bias) ≈ 0.01: Mamba-2's Δ init range; a = exp(-Δ·A)
        # then sits in [0.85, 0.99] so chunked cumulative decays stay sane.
        "dt_bias": jnp.full((H,), math.log(math.expm1(0.01)), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "conv_w": (jax.random.normal(ks[3], (4, f)) * 0.2).astype(jnp.float32),
        "w_out": dense_init(ks[4], (f, d), dtype, scale=1.0 / math.sqrt(f)),
        "norm": jnp.zeros((f,), jnp.float32),
    }


def _short_conv(x, w):
    """Depthwise causal conv along T.  x: (B,T,f); w: (k,f)."""
    kk = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (kk - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(kk))
    return out


def mamba2_mix(params, x, cfg: ModelConfig, state, chunked=True):
    """x: (B, T, d); state: (B, H, N, P) with P = f // H head dim.

    Returns (out, new_state, conv_tail) where conv_tail (B, 3, f) is the raw
    pre-conv input history needed to continue decoding after a prefill.
    """
    B, T, d = x.shape
    f = params["w_in"].shape[1] // 2
    H = cfg.num_heads
    P = f // H
    N = cfg.ssm_state

    xz = x @ params["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)  # (B,T,f) each
    xi_raw = xi.astype(jnp.float32)
    conv_tail = jnp.pad(xi_raw, ((0, 0), (3, 0), (0, 0)))[:, -3:]
    xi = _short_conv(xi_raw, params["conv_w"])
    xi = jax.nn.silu(xi)

    bc = xi.astype(x.dtype) @ params["w_bc"]  # (B,T,2N)
    bmat, cmat = jnp.split(bc.astype(jnp.float32), 2, axis=-1)  # (B,T,N)
    dt = jax.nn.softplus(xi @ params["w_dt"] + params["dt_bias"])  # (B,T,H)
    a = jnp.exp(-dt * jnp.exp(params["a_log"]))  # (B,T,H) scalar decay/head

    xh = xi.reshape(B, T, H, P)
    v = xh * dt[..., None]  # Δ-scaled input  (B,T,H,P)
    k = jnp.broadcast_to(bmat[:, :, None, :], (B, T, H, N))
    r = jnp.broadcast_to(cmat[:, :, None, :], (B, T, H, N))
    a_vec = jnp.broadcast_to(a[..., None], (B, T, H, N))

    if chunked:
        # chunk 16 default: Mamba decays reach ~0.85/step, so shorter chunks
        # bound the k/P dynamic range (vs 64 for RWKV's ~0.99 decays).
        o, state = gla_chunked(r, k, v, a_vec, state, chunk=cfg.gla_chunk or 16)
    else:
        o, state = gla_sequential(r, k, v, a_vec, state)  # (B,T,H,P)
    o = o + xh * params["d_skip"][None, None, :, None]
    o = o.reshape(B, T, f)
    o = rmsnorm(o, params["norm"], cfg.norm_eps).astype(jnp.float32)
    o = o * jax.nn.silu(z.astype(jnp.float32))
    return o.astype(x.dtype) @ params["w_out"], state, conv_tail
