"""Feed-forward blocks: SwiGLU MLP and capacity-based top-k MoE.

MoE uses FLOP-free scatter/gather dispatch (tokens → per-expert capacity
buckets) followed by dense per-expert matmuls, so HLO FLOPs reflect the true
active compute (≈ 2 · tokens · k · 3 · d · f · capacity_factor) instead of an
all-experts einsum.  Experts are sharded over the mesh "model" axis (EP);
arctic's parallel dense-residual MLP is supported via ``moe_dense_residual``.

Router telemetry: per-expert windowed load statistics (maxcount monoid over
the hottest expert) feed the training-loop SWAG metrics.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed import ctx
from repro.models.common import ModelConfig, dense_init


def swiglu(params, x):
    """x: (..., d) → (..., d) through gate/up/down."""
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


def init_mlp_params(key, d: int, f: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, f), dtype),
        "w_up": dense_init(k2, (d, f), dtype),
        "w_down": dense_init(k3, (f, d), dtype, scale=1.0 / math.sqrt(f)),
    }


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def _capacity(num_tokens: int, cfg: ModelConfig) -> int:
    c = int(
        math.ceil(
            num_tokens * cfg.experts_per_token * cfg.capacity_factor / cfg.num_experts
        )
    )
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_block(params, x, cfg: ModelConfig):
    """Top-k token-choice MoE with per-expert capacity.  x: (B, T, d).

    Dispatch is computed *per batch row* so the position-assignment cumsum
    runs along the (unsharded) sequence axis — zero collectives are induced
    by dispatch when B is data-sharded and E is expert-sharded.

    Returns (out, aux) where aux = {"lb_loss", "max_load"} for telemetry.
    """
    B, T, d = x.shape
    if T == 1 and B > 1:
        # Decode: dispatch across the BATCH as one row.  Per-row capacity
        # with T=1 pads each expert to the 8-slot floor — 32× wasted expert
        # FLOPs at grok's decode shape (measured, §Perf); batch-wise
        # dispatch sizes capacity to ~B·k/E.
        from jax.sharding import PartitionSpec as P

        dp = ctx.dp_axes()
        if cfg.moe_2d and dp:
            # Decode batch is tiny (≈MBs) while expert weights are GBs/layer:
            # replicate the batch, dispatch locally, and contract on the
            # d-sharded weights directly — zero weight gathers (§Perf).
            x = ctx.constrain(x, P(None, None, None))
        out, aux = moe_block(params, x.reshape(1, B, d), cfg)
        out = out.reshape(B, T, d)
        if cfg.moe_2d and dp and B % ctx.dp_size() == 0:
            out = ctx.constrain(out, P(dp, None, None))
        return out, aux
    E, K = cfg.num_experts, cfg.experts_per_token
    C = _capacity(T, cfg)  # capacity per expert per batch row

    logits = jnp.einsum(
        "btd,de->bte", x.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)  # (B, T, E)
    top_w, top_e = jax.lax.top_k(probs, K)  # (B, T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # --- dispatch: position of each (token, k) assignment within its expert,
    #     computed independently per batch row (cumsum along T*K only).
    flat_e = top_e.reshape(B, T * K)  # expert ids in token order
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (B, T*K, E)
    pos = jnp.cumsum(onehot, axis=1) * onehot
    pos_in_expert = pos.sum(axis=-1) - 1  # (B, T*K)
    keep = pos_in_expert < C
    slot = jnp.where(keep, flat_e * C + pos_in_expert, E * C)  # (B, T*K)

    xr = jnp.repeat(x, K, axis=1)  # (B, T*K, d) token per assignment
    buf = jnp.zeros((B, E * C + 1, d), x.dtype)
    buf = jax.vmap(lambda b, s, v: b.at[s].set(v))(buf, slot, xr)
    xe = buf[:, : E * C].reshape(B, E, C, d)

    # --- per-expert SwiGLU (dense; E is EP-shardable, B data-shardable)
    if cfg.moe_2d and ctx.dp_axes():
        # 2-D expert TP: align the dispatch buffer with the weights' E×d
        # (model × data) grid so the einsums contract the data-sharded dim —
        # no batch replication (arctic) and no per-step weight gather (grok
        # decode).  Output returns to batch sharding for the combine.
        from jax.sharding import PartitionSpec as P

        dp = ctx.dp_axes()
        e_ax = "model" if E % 16 == 0 else None
        xe = ctx.constrain(xe, P(None, e_ax, None, dp))
        g = jnp.einsum("becd,edf->becf", xe, params["w_gate"])
        u = jnp.einsum("becd,edf->becf", xe, params["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        ye = jnp.einsum("becf,efd->becd", h, params["w_down"])
        b_ax = dp if B % ctx.dp_size() == 0 else None
        ye = ctx.constrain(ye, P(b_ax, e_ax, None, None))
    else:
        g = jnp.einsum("becd,edf->becf", xe, params["w_gate"])
        u = jnp.einsum("becd,edf->becf", xe, params["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        ye = jnp.einsum("becf,efd->becd", h, params["w_down"])  # (B, E, C, d)

    # --- combine: gather each assignment's output, weight, and sum over K
    ye_flat = jnp.concatenate(
        [ye.reshape(B, E * C, d), jnp.zeros((B, 1, d), x.dtype)], axis=1
    )
    yr = jax.vmap(lambda y, s: y[s])(ye_flat, slot)  # (B, T*K, d)
    yr = yr * top_w.reshape(B, T * K, 1).astype(x.dtype)
    out = yr.reshape(B, T, K, d).sum(axis=2)

    if cfg.moe_dense_residual:
        out = out + swiglu(params["dense"], x)

    # load-balancing loss (Switch-style) + hottest-expert load for telemetry
    me = probs.mean(axis=(0, 1))  # mean router prob per expert
    ce = onehot.sum(axis=(0, 1)).astype(jnp.float32) / max(B * T * K, 1)
    lb_loss = E * jnp.sum(me * ce)
    max_load = onehot.sum(axis=1).max().astype(jnp.float32) / C
    return out, {"lb_loss": lb_loss, "max_load": max_load}


def init_moe_params(key, cfg: ModelConfig, dtype):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    keys = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    params = {
        "router": dense_init(keys[0], (d, E), jnp.float32),
        "w_gate": (jax.random.normal(keys[1], (E, d, f), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(keys[2], (E, d, f), jnp.float32) * scale).astype(dtype),
        "w_down": (
            jax.random.normal(keys[3], (E, f, d), jnp.float32) / math.sqrt(f)
        ).astype(dtype),
    }
    if cfg.moe_dense_residual:
        params["dense"] = init_mlp_params(keys[4], d, f, dtype)
    return params
