"""Unified LM assembly for all 10 assigned architectures.

One ``build_model(cfg)`` covers:
  * dense GQA decoders (llama3.2-1b, minitron-8b, codeqwen1.5-7b),
  * local/global alternating with softcaps (gemma2-27b),
  * M-RoPE embed-input backbones (qwen2-vl-2b),
  * MoE with optional dense residual (arctic-480b, grok-1-314b),
  * encoder-decoder with frame-embedding frontend stub (whisper-large-v3),
  * RWKV-6 (rwkv6-1.6b) and Mamba-2 + shared-attention hybrid (zamba2-1.2b).

Layer stacks are scanned (``lax.scan`` over stacked params) with optional
per-layer remat, so HLO size and compile time are depth-independent —
required for the 46-layer × 512-device dry-run.

Interface (all functional):
  init_params(key)                     → params pytree
  loss_fn(params, batch)               → (loss, metrics)
  prefill(params, batch)               → (last_logits, decode_state)
  decode_step(params, state, token)    → (logits, decode_state)
"""

from __future__ import annotations

import dataclasses
import functools
import math
from types import SimpleNamespace
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import ssm as ssm_lib
from repro.models.attention import (
    attention_decode,
    attention_train,
    init_attention_params,
)
from repro.models.common import ModelConfig, embed_init, rmsnorm
from repro.models.mlp import init_mlp_params, init_moe_params, moe_block, swiglu

PyTree = Any


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if cfg.rwkv:
        return {
            "tm": ssm_lib.init_rwkv_params(ks[0], cfg),
            "norm1": jnp.zeros((d,), jnp.float32),
            "norm2": jnp.zeros((d,), jnp.float32),
        }
    if cfg.mamba:
        return {
            "mix": ssm_lib.init_mamba_params(ks[0], cfg),
            "norm1": jnp.zeros((d,), jnp.float32),
        }
    layer = {
        "attn": init_attention_params(ks[0], cfg),
        "norm1": jnp.zeros((d,), jnp.float32),
        "norm2": jnp.zeros((d,), jnp.float32),
    }
    if cfg.num_experts > 0:
        layer["moe"] = init_moe_params(ks[1], cfg, cfg.dtype)
    else:
        layer["mlp"] = init_mlp_params(ks[1], d, cfg.d_ff, cfg.dtype)
    if cfg.is_encoder_decoder:
        layer["xattn"] = init_attention_params(ks[2], cfg)
        layer["norm3"] = jnp.zeros((d,), jnp.float32)
    return layer


def _init_encoder_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {
        "attn": init_attention_params(ks[0], cfg),
        "mlp": init_mlp_params(ks[1], d, cfg.d_ff, cfg.dtype),
        "norm1": jnp.zeros((d,), jnp.float32),
        "norm2": jnp.zeros((d,), jnp.float32),
    }


def init_params(cfg: ModelConfig, key) -> PyTree:
    kemb, klayers, kenc, kshared, khead = jax.random.split(key, 5)
    params = {
        "embed": embed_init(kemb, (cfg.padded_vocab, cfg.d_model), cfg.dtype),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "layers": jax.vmap(lambda k: _init_layer(k, cfg))(
            jax.random.split(klayers, cfg.num_layers)
        ),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(khead, (cfg.padded_vocab, cfg.d_model), cfg.dtype)
    if cfg.shared_attn_every > 0:
        d = cfg.d_model
        k1, k2 = jax.random.split(kshared)
        params["shared"] = {
            "attn": init_attention_params(k1, cfg),
            "mlp": init_mlp_params(k2, d, cfg.d_ff, cfg.dtype),
            "norm1": jnp.zeros((d,), jnp.float32),
            "norm2": jnp.zeros((d,), jnp.float32),
        }
    if cfg.is_encoder_decoder:
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _init_encoder_layer(k, cfg))(
                jax.random.split(kenc, cfg.encoder_layers)
            ),
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        }
    return params


# ---------------------------------------------------------------------------
# Train-time forward (scan over layers)
# ---------------------------------------------------------------------------


def _sinusoidal(T: int, d: int) -> jax.Array:
    pos = jnp.arange(T, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _layer_train(layer, x, cfg: ModelConfig, layer_idx, positions, enc_out):
    """One decoder layer, train/prefill.  Returns (x, aux)."""
    aux = {}
    if cfg.rwkv:
        B, T, d = x.shape
        H, K = cfg.num_heads, d // cfg.num_heads
        s0 = jnp.zeros((B, H, K, K), jnp.float32)
        h, _, _ = ssm_lib.rwkv6_time_mix(
            layer["tm"], rmsnorm(x, layer["norm1"], cfg.norm_eps), cfg, s0
        )
        x = x + h
        h, _ = ssm_lib.rwkv6_channel_mix(
            layer["tm"], rmsnorm(x, layer["norm2"], cfg.norm_eps)
        )
        return x + h.astype(x.dtype), aux
    if cfg.mamba:
        B, T, d = x.shape
        f = cfg.d_ff
        H = cfg.num_heads
        P, N = f // H, cfg.ssm_state
        s0 = jnp.zeros((B, H, N, P), jnp.float32)
        h, _, _ = ssm_lib.mamba2_mix(
            layer["mix"], rmsnorm(x, layer["norm1"], cfg.norm_eps), cfg, s0
        )
        return x + h, aux

    is_local = cfg.layer_is_local(layer_idx)
    h = attention_train(
        layer["attn"],
        rmsnorm(x, layer["norm1"], cfg.norm_eps),
        cfg,
        positions=positions,
        is_local=is_local,
    )
    x = x + h
    if cfg.is_encoder_decoder:
        h = attention_train(
            layer["xattn"],
            rmsnorm(x, layer["norm3"], cfg.norm_eps),
            cfg,
            positions=positions,
            is_local=jnp.zeros((), bool),
            kv_override=enc_out,
        )
        x = x + h
    hn = rmsnorm(x, layer["norm2"], cfg.norm_eps)
    if cfg.num_experts > 0:
        h, aux = moe_block(layer["moe"], hn, cfg)
    else:
        h = swiglu(layer["mlp"], hn)
    return x + h, aux


def _shared_block(shared, x, cfg: ModelConfig, positions):
    """zamba2 shared attention+MLP block (single param set, reused)."""
    h = attention_train(
        shared["attn"], rmsnorm(x, shared["norm1"], cfg.norm_eps), cfg,
        positions=positions, is_local=jnp.ones((), bool),
    )
    x = x + h
    h = swiglu(shared["mlp"], rmsnorm(x, shared["norm2"], cfg.norm_eps))
    return x + h


def _encode(params, cfg: ModelConfig, frames: jax.Array):
    """Whisper encoder over precomputed frame embeddings (conv stub)."""
    B, T, d = frames.shape
    x = frames.astype(cfg.dtype) + _sinusoidal(T, d).astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))

    def body(x, layer):
        h = attention_train(
            layer["attn"], rmsnorm(x, layer["norm1"], cfg.norm_eps), cfg,
            positions=positions, is_local=jnp.zeros((), bool), causal=False,
        )
        x = x + h
        h = swiglu(layer["mlp"], rmsnorm(x, layer["norm2"], cfg.norm_eps))
        return x + h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    unroll = cfg.encoder_layers if cfg.unroll_layers else 1
    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"], unroll=unroll)
    return rmsnorm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Hidden states (B, T, d).  batch keys:
    tokens (B,T) int32 | embeds (B,T,d); optional positions ((B,T) or (3,B,T)),
    frames (B,Tenc,d) for enc-dec."""
    if cfg.embed_inputs and "embeds" in batch:
        x = batch["embeds"].astype(cfg.dtype)
        B, T = x.shape[:2]
    else:
        tokens = batch["tokens"]
        B, T = tokens.shape
        x = params["embed"][tokens] * math.sqrt(cfg.d_model)
        x = x.astype(cfg.dtype)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions, (3, B, T))

    enc_out = None
    if cfg.is_encoder_decoder:
        enc_hidden = _encode(params, cfg, batch["frames"])
        # Precompute per-layer cross K/V lazily inside each layer instead:
        # pass raw encoder hidden; layers project with their own wk/wv.
        enc_out = enc_hidden

    def body(carry, scanned):
        x = carry
        layer, idx = scanned
        kv = None
        if enc_out is not None:
            k = jnp.einsum("btd,dhk->bhtk", enc_out, layer["xattn"]["wk"])
            v = jnp.einsum("btd,dhk->bhtk", enc_out, layer["xattn"]["wv"])
            kv = (k, v)

        def run(x):
            y, _aux = _layer_train(layer, x, cfg, idx, positions, kv)
            return y

        if cfg.remat:
            run = jax.checkpoint(run)
        x = run(x)
        if cfg.shared_attn_every > 0:
            apply_shared = (idx % cfg.shared_attn_every) == (cfg.shared_attn_every - 1)
            x = jax.lax.cond(
                apply_shared,
                lambda x: _shared_block(params["shared"], x, cfg, positions),
                lambda x: x,
                x,
            )
        return x, None

    idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)
    unroll = cfg.num_layers if cfg.unroll_layers else 1
    x, _ = jax.lax.scan(body, x, (params["layers"], idxs), unroll=unroll)
    return rmsnorm(x, params["final_norm"], cfg.norm_eps)


def logits_fn(params, cfg: ModelConfig, hidden: jax.Array) -> jax.Array:
    table = params.get("lm_head", params["embed"])
    logits = jnp.einsum("btd,vd->btv", hidden, table).astype(jnp.float32)
    if cfg.logit_softcap > 0.0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:
        # mask the padding rows without breaking the vocab sharding
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad[None, None, :], -1e30, logits)
    return logits


def loss_fn(params, cfg: ModelConfig, batch: dict):
    """Next-token cross-entropy.  Labels = tokens shifted left."""
    hidden = forward(params, cfg, batch)
    logits = logits_fn(params, cfg, hidden)  # (B, T, V)
    targets = batch.get("labels")
    if targets is None:
        tokens = batch["tokens"]
        targets = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)), constant_values=-1)
    mask = (targets >= 0).astype(jnp.float32)
    tsafe = jnp.maximum(targets, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tsafe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    metrics = {
        "loss": loss,
        "tokens": mask.sum(),
    }
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode (serve_step): KV caches / SSM states
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DecodeSpec:
    """Static description of the decode cache layout for a config."""

    cache_len: int  # S for full/global layers
    local_cache_len: int  # ring size for local layers (alternating/local)
    batch: int


def _attn_cache_shape(cfg: ModelConfig, n_layers, B, S):
    return (n_layers, B, cfg.num_kv_heads, S, cfg.hd)


def init_decode_state(params, cfg: ModelConfig, spec: DecodeSpec) -> PyTree:
    B, S = spec.batch, spec.cache_len
    W = min(spec.local_cache_len, S)
    dt = cfg.dtype
    state: dict = {"pos": jnp.zeros((B,), jnp.int32)}
    L = cfg.num_layers
    if cfg.rwkv:
        H, K = cfg.num_heads, cfg.d_model // cfg.num_heads
        state["ssm"] = jnp.zeros((L, B, H, K, K), jnp.float32)
        state["tm_last"] = jnp.zeros((L, B, cfg.d_model), jnp.float32)
        state["cm_last"] = jnp.zeros((L, B, cfg.d_model), jnp.float32)
    elif cfg.mamba:
        f, H, N = cfg.d_ff, cfg.num_heads, cfg.ssm_state
        P = f // H
        state["ssm"] = jnp.zeros((L, B, H, N, P), jnp.float32)
        state["conv"] = jnp.zeros((L, B, 3, f), jnp.float32)
        if cfg.shared_attn_every > 0:
            napp = L // cfg.shared_attn_every
            state["shared_k"] = jnp.zeros(
                _attn_cache_shape(cfg, napp, B, W), dt
            )
            state["shared_v"] = jnp.zeros(
                _attn_cache_shape(cfg, napp, B, W), dt
            )
    elif cfg.attn_pattern == "alternating":
        Lp = L // 2
        state["k_local"] = jnp.zeros(_attn_cache_shape(cfg, Lp, B, W), dt)
        state["v_local"] = jnp.zeros(_attn_cache_shape(cfg, Lp, B, W), dt)
        state["k_global"] = jnp.zeros(_attn_cache_shape(cfg, Lp, B, S), dt)
        state["v_global"] = jnp.zeros(_attn_cache_shape(cfg, Lp, B, S), dt)
    else:
        state["k"] = jnp.zeros(_attn_cache_shape(cfg, L, B, S), dt)
        state["v"] = jnp.zeros(_attn_cache_shape(cfg, L, B, S), dt)
    if cfg.is_encoder_decoder:
        Te = cfg.encoder_seq
        state["xk"] = jnp.zeros(_attn_cache_shape(cfg, L, B, Te), dt)
        state["xv"] = jnp.zeros(_attn_cache_shape(cfg, L, B, Te), dt)
    return state


def decode_step(params, cfg: ModelConfig, state: PyTree, token: jax.Array):
    """One decode step.  token: (B,) int32 → (logits (B, V), new state)."""
    B = token.shape[0]
    x = params["embed"][token][:, None, :] * math.sqrt(cfg.d_model)
    x = x.astype(cfg.dtype)
    pos = state["pos"]

    if cfg.rwkv:
        x, state = _decode_rwkv(params, cfg, state, x)
    elif cfg.mamba:
        x, state = _decode_mamba(params, cfg, state, x)
    elif cfg.attn_pattern == "alternating":
        x, state = _decode_alternating(params, cfg, state, x)
    else:
        x, state = _decode_dense(params, cfg, state, x)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, cfg, x)[:, 0]
    state = dict(state, pos=pos + 1)
    return logits, state


def _decode_dense(params, cfg, state, x):
    pos = state["pos"]

    def body(carry, scanned):
        x = carry
        layer, kc, vc, idx = scanned[0], scanned[1], scanned[2], scanned[3]
        kv = None
        if cfg.is_encoder_decoder:
            kv = None  # handled below via xk/xv
        h, kc, vc = attention_decode(
            layer["attn"], rmsnorm(x, layer["norm1"], cfg.norm_eps), cfg,
            k_cache=kc, v_cache=vc, cache_pos=pos, abs_pos=pos,
            is_local=cfg.layer_is_local(idx),
        )
        x = x + h
        if cfg.is_encoder_decoder:
            xk, xv = scanned[4], scanned[5]
            h, _, _ = attention_decode(
                layer["xattn"], rmsnorm(x, layer["norm3"], cfg.norm_eps), cfg,
                k_cache=xk, v_cache=xv, cache_pos=pos, abs_pos=pos,
                is_local=jnp.zeros((), bool), kv_override=(xk, xv),
            )
            x = x + h
        hn = rmsnorm(x, layer["norm2"], cfg.norm_eps)
        if cfg.num_experts > 0:
            h, _ = moe_block(layer["moe"], hn, cfg)
        else:
            h = swiglu(layer["mlp"], hn)
        return x + h, (kc, vc)

    idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)
    scanned = [params["layers"], state["k"], state["v"], idxs]
    if cfg.is_encoder_decoder:
        scanned += [state["xk"], state["xv"]]
    unroll = cfg.num_layers if cfg.unroll_layers else 1
    x, caches = jax.lax.scan(body, x, tuple(scanned), unroll=unroll)
    state = dict(state, k=caches[0], v=caches[1])
    return x, state


def _decode_alternating(params, cfg, state, x):
    pos = state["pos"]
    Lp = cfg.num_layers // 2
    pair_layers = jax.tree.map(
        lambda a: a.reshape((Lp, 2) + a.shape[1:]), params["layers"]
    )

    def body(carry, scanned):
        x = carry
        pair, kl, vl, kg, vg, pidx = scanned
        l_local = jax.tree.map(lambda a: a[0], pair)
        l_global = jax.tree.map(lambda a: a[1], pair)
        # local sub-layer: ring cache of W slots
        h, kl, vl = attention_decode(
            l_local["attn"], rmsnorm(x, l_local["norm1"], cfg.norm_eps), cfg,
            k_cache=kl, v_cache=vl, cache_pos=pos, abs_pos=pos,
            is_local=jnp.ones((), bool),
        )
        x = x + h
        x = x + swiglu(l_local["mlp"], rmsnorm(x, l_local["norm2"], cfg.norm_eps))
        # global sub-layer: full cache
        h, kg, vg = attention_decode(
            l_global["attn"], rmsnorm(x, l_global["norm1"], cfg.norm_eps), cfg,
            k_cache=kg, v_cache=vg, cache_pos=pos, abs_pos=pos,
            is_local=jnp.zeros((), bool),
        )
        x = x + h
        x = x + swiglu(l_global["mlp"], rmsnorm(x, l_global["norm2"], cfg.norm_eps))
        return x, (kl, vl, kg, vg)

    x, caches = jax.lax.scan(
        body, x,
        (pair_layers, state["k_local"], state["v_local"],
         state["k_global"], state["v_global"], jnp.arange(Lp)),
        unroll=Lp if cfg.unroll_layers else 1,
    )
    state = dict(
        state, k_local=caches[0], v_local=caches[1],
        k_global=caches[2], v_global=caches[3],
    )
    return x, state


def _decode_rwkv(params, cfg, state, x):
    def body(carry, scanned):
        x = carry
        layer, s, tml, cml = scanned
        h, s, tml = ssm_lib.rwkv6_time_mix(
            layer["tm"], rmsnorm(x, layer["norm1"], cfg.norm_eps), cfg,
            s, x_last=tml, chunked=False,
        )
        x = x + h
        h, cml = ssm_lib.rwkv6_channel_mix(
            layer["tm"], rmsnorm(x, layer["norm2"], cfg.norm_eps), x_last=cml
        )
        return x + h.astype(x.dtype), (s, tml, cml)

    x, (ssm, tm_last, cm_last) = jax.lax.scan(
        body, x, (params["layers"], state["ssm"], state["tm_last"], state["cm_last"]),
        unroll=cfg.num_layers if cfg.unroll_layers else 1,
    )
    return x, dict(state, ssm=ssm, tm_last=tm_last, cm_last=cm_last)


def _decode_mamba(params, cfg, state, x):
    """Mamba / zamba2 decode.  The shared attention block's per-application
    KV caches travel in the scan CARRY (a counter selects the active slot),
    so no per-layer cache expansion is needed."""
    pos = state["pos"]
    every = cfg.shared_attn_every

    def body(carry, scanned):
        layer, s, conv, idx = scanned
        if every > 0:
            x, ks, vs, app = carry
        else:
            x = carry
        xin = rmsnorm(x, layer["norm1"], cfg.norm_eps)
        h, s, conv = _mamba_decode_step(layer["mix"], xin, cfg, s, conv)
        x = x + h
        if every > 0:
            apply_shared = (idx % every) == (every - 1)

            def with_shared(args):
                x, ks, vs, app = args
                kc = jax.lax.dynamic_index_in_dim(ks, app, 0, keepdims=False)
                vc = jax.lax.dynamic_index_in_dim(vs, app, 0, keepdims=False)
                h, kc, vc = attention_decode(
                    params["shared"]["attn"],
                    rmsnorm(x, params["shared"]["norm1"], cfg.norm_eps), cfg,
                    k_cache=kc, v_cache=vc, cache_pos=pos, abs_pos=pos,
                    is_local=jnp.ones((), bool),
                )
                x = x + h
                x = x + swiglu(
                    params["shared"]["mlp"],
                    rmsnorm(x, params["shared"]["norm2"], cfg.norm_eps),
                )
                ks = jax.lax.dynamic_update_index_in_dim(ks, kc, app, 0)
                vs = jax.lax.dynamic_update_index_in_dim(vs, vc, app, 0)
                return x, ks, vs, app + 1

            carry = jax.lax.cond(
                apply_shared, with_shared, lambda a: a, (x, ks, vs, app)
            )
            return carry, (s, conv)
        return x, (s, conv)

    L = cfg.num_layers
    idxs = jnp.arange(L, dtype=jnp.int32)
    xs = (params["layers"], state["ssm"], state["conv"], idxs)
    if every > 0:
        init = (x, state["shared_k"], state["shared_v"], jnp.zeros((), jnp.int32))
        (x, ks, vs, _), (ssm, conv) = jax.lax.scan(
            body, init, xs, unroll=cfg.num_layers if cfg.unroll_layers else 1)
        state = dict(state, ssm=ssm, conv=conv, shared_k=ks, shared_v=vs)
    else:
        x, (ssm, conv) = jax.lax.scan(
            body, x, xs, unroll=cfg.num_layers if cfg.unroll_layers else 1)
        state = dict(state, ssm=ssm, conv=conv)
    return x, state


def _mamba_decode_step(p, x, cfg: ModelConfig, s, conv):
    """Single-token Mamba-2 step.  x: (B,1,d); s: (B,H,N,P); conv: (B,3,f)."""
    B = x.shape[0]
    f = p["w_in"].shape[1] // 2
    H, N = cfg.num_heads, cfg.ssm_state
    P = f // H
    xz = x @ p["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)  # (B,1,f)
    xi = xi[:, 0].astype(jnp.float32)
    # causal conv over (conv history, current)
    hist = jnp.concatenate([conv, xi[:, None]], axis=1)  # (B,4,f)
    xc = (hist * p["conv_w"][None]).sum(axis=1)
    xc = jax.nn.silu(xc)
    conv = hist[:, 1:]
    bc = xc.astype(x.dtype) @ p["w_bc"]
    bmat, cmat = jnp.split(bc.astype(jnp.float32), 2, axis=-1)  # (B,N)
    dt = jax.nn.softplus(xc @ p["w_dt"] + p["dt_bias"])  # (B,H)
    a = jnp.exp(-dt * jnp.exp(p["a_log"]))  # (B,H)
    xh = xc.reshape(B, H, P)
    kv = jnp.einsum("bn,bhp->bhnp", bmat, xh * dt[..., None])
    s = a[:, :, None, None] * s + kv
    o = jnp.einsum("bn,bhnp->bhp", cmat, s)
    o = o + xh * p["d_skip"][None, :, None]
    o = o.reshape(B, 1, f)
    o = rmsnorm(o, p["norm"], cfg.norm_eps).astype(jnp.float32)
    o = o * jax.nn.silu(z.astype(jnp.float32))
    return o.astype(x.dtype) @ p["w_out"], s, conv


# ---------------------------------------------------------------------------
# Prefill: run the forward pass while building the decode state
# ---------------------------------------------------------------------------


def _to_ring(k_full: jax.Array, W: int) -> jax.Array:
    """Pack a (B, Hkv, T, hd) full K/V into a W-slot ring (slot = pos % W)."""
    B, Hkv, T, hd = k_full.shape
    if T <= W:
        return jnp.pad(k_full, ((0, 0), (0, 0), (0, W - T), (0, 0)))
    last = k_full[:, :, T - W :, :]
    idx = (T - W + jnp.arange(W)) % W
    ring = jnp.zeros((B, Hkv, W, hd), k_full.dtype)
    return ring.at[:, :, idx, :].set(last)


def prefill(params, cfg: ModelConfig, batch: dict, spec: DecodeSpec):
    """Process the prompt; returns (last-position logits (B, V), decode state).

    The layer scan emits per-layer K/V (attention archs) or final recurrent
    states (SSM archs) as scan outputs, which are then packed into the same
    decode-state layout ``init_decode_state`` defines.
    """
    if cfg.embed_inputs and "embeds" in batch:
        x = batch["embeds"].astype(cfg.dtype)
        B, T = x.shape[:2]
    else:
        tokens = batch["tokens"]
        B, T = tokens.shape
        x = (params["embed"][tokens] * math.sqrt(cfg.d_model)).astype(cfg.dtype)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions, (3, B, T))

    state = init_decode_state(params, cfg, spec)
    S, W = spec.cache_len, min(spec.local_cache_len, spec.cache_len)

    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, cfg, batch["frames"])

    if cfg.rwkv:
        def body(x, scanned):
            layer, = scanned
            B_, H, K = x.shape[0], cfg.num_heads, cfg.d_model // cfg.num_heads
            s0 = jnp.zeros((B_, H, K, K), jnp.float32)
            h, s, tml = ssm_lib.rwkv6_time_mix(
                layer["tm"], rmsnorm(x, layer["norm1"], cfg.norm_eps), cfg, s0
            )
            x = x + h
            h, cml = ssm_lib.rwkv6_channel_mix(
                layer["tm"], rmsnorm(x, layer["norm2"], cfg.norm_eps)
            )
            return x + h.astype(x.dtype), (s, tml, cml)

        x, (ssm, tml, cml) = jax.lax.scan(
            body, x, (params["layers"],),
            unroll=cfg.num_layers if cfg.unroll_layers else 1)
        state = dict(state, ssm=ssm, tm_last=tml, cm_last=cml)

    elif cfg.mamba:
        every = cfg.shared_attn_every

        def body(carry, scanned):
            layer, idx = scanned
            if every > 0:
                x, ks, vs, app = carry
            else:
                x = carry
            B_ = x.shape[0]
            f, H, N = cfg.d_ff, cfg.num_heads, cfg.ssm_state
            P = f // H
            s0 = jnp.zeros((B_, H, N, P), jnp.float32)
            h, s, tail = ssm_lib.mamba2_mix(
                layer["mix"], rmsnorm(x, layer["norm1"], cfg.norm_eps), cfg, s0
            )
            x = x + h
            if every > 0:
                apply_shared = (idx % every) == (every - 1)

                def with_shared(args):
                    x, ks, vs, app = args
                    h, (k, v) = attention_train(
                        params["shared"]["attn"],
                        rmsnorm(x, params["shared"]["norm1"], cfg.norm_eps),
                        cfg, positions=positions,
                        is_local=jnp.ones((), bool), return_kv=True,
                    )
                    x = x + h
                    x = x + swiglu(
                        params["shared"]["mlp"],
                        rmsnorm(x, params["shared"]["norm2"], cfg.norm_eps),
                    )
                    kr = _to_ring(k, ks.shape[3])
                    vr = _to_ring(v, vs.shape[3])
                    ks = jax.lax.dynamic_update_index_in_dim(ks, kr, app, 0)
                    vs = jax.lax.dynamic_update_index_in_dim(vs, vr, app, 0)
                    return x, ks, vs, app + 1

                carry = jax.lax.cond(
                    apply_shared, with_shared, lambda a: a, (x, ks, vs, app)
                )
                return carry, (s, tail)
            return x, (s, tail)

        idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)
        if every > 0:
            init = (x, state["shared_k"], state["shared_v"], jnp.zeros((), jnp.int32))
            (x, ks, vs, _), (ssm, conv) = jax.lax.scan(
                body, init, (params["layers"], idxs),
                unroll=cfg.num_layers if cfg.unroll_layers else 1,
            )
            state = dict(state, ssm=ssm, conv=conv, shared_k=ks, shared_v=vs)
        else:
            x, (ssm, conv) = jax.lax.scan(
                body, x, (params["layers"], idxs),
                unroll=cfg.num_layers if cfg.unroll_layers else 1)
            state = dict(state, ssm=ssm, conv=conv)

    else:
        def body(x, scanned):
            layer, idx = scanned
            is_local = cfg.layer_is_local(idx)
            h, (k, v) = attention_train(
                layer["attn"], rmsnorm(x, layer["norm1"], cfg.norm_eps), cfg,
                positions=positions, is_local=is_local, return_kv=True,
            )
            x = x + h
            xk = xv = jnp.zeros((0,), cfg.dtype)
            if cfg.is_encoder_decoder:
                h, (xk, xv) = attention_train(
                    layer["xattn"], rmsnorm(x, layer["norm3"], cfg.norm_eps),
                    cfg, positions=positions, is_local=jnp.zeros((), bool),
                    kv_override=(
                        jnp.einsum("btd,dhk->bhtk", enc_out, layer["xattn"]["wk"]),
                        jnp.einsum("btd,dhk->bhtk", enc_out, layer["xattn"]["wv"]),
                    ),
                    return_kv=True,
                )
                x = x + h
            hn = rmsnorm(x, layer["norm2"], cfg.norm_eps)
            if cfg.num_experts > 0:
                h, _ = moe_block(layer["moe"], hn, cfg)
            else:
                h = swiglu(layer["mlp"], hn)
            return x + h, (k, v, xk, xv)

        idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)
        x, (ks, vs, xks, xvs) = jax.lax.scan(
            body, x, (params["layers"], idxs),
            unroll=cfg.num_layers if cfg.unroll_layers else 1)
        # ks: (L, B, Hkv, T, hd) → pack into the decode cache layout
        pad_to_s = lambda c: jnp.pad(c, ((0, 0),) * 3 + ((0, S - T), (0, 0)))
        if cfg.attn_pattern == "alternating":
            Lp = cfg.num_layers // 2
            state = dict(
                state,
                k_local=jax.vmap(lambda c: _to_ring(c, W))(ks[0::2]),
                v_local=jax.vmap(lambda c: _to_ring(c, W))(vs[0::2]),
                k_global=pad_to_s(ks[1::2]),
                v_global=pad_to_s(vs[1::2]),
            )
        else:
            state = dict(state, k=pad_to_s(ks), v=pad_to_s(vs))
        if cfg.is_encoder_decoder:
            state = dict(state, xk=xks, xv=xvs)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, cfg, x[:, -1:, :])[:, 0]
    state = dict(state, pos=state["pos"] + T)  # per-row positions advance by T
    return logits, state


# ---------------------------------------------------------------------------
# Builder
# ---------------------------------------------------------------------------


def build_model(cfg: ModelConfig) -> SimpleNamespace:
    return SimpleNamespace(
        cfg=cfg,
        init_params=functools.partial(init_params, cfg),
        forward=functools.partial(forward, cfg=cfg),
        loss_fn=lambda params, batch: loss_fn(params, cfg, batch),
        prefill=lambda params, batch, spec: prefill(params, cfg, batch, spec),
        decode_step=lambda params, state, token: decode_step(params, cfg, state, token),
        init_decode_state=lambda params, spec: init_decode_state(params, cfg, spec),
    )
