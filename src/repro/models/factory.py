"""Model factory: build a model + its input specs for any (arch × shape).

``input_specs(cfg, shape)`` returns ``jax.ShapeDtypeStruct`` stand-ins for
every model input — weak-type-correct, shardable, and allocation-free — used
by the multi-pod dry-run (lower + compile only).  ``reduced_config`` shrinks
any architecture to a CPU-smoke-testable size while preserving its structural
features (alternating windows, MoE routing, shared blocks, enc-dec, M-RoPE).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.models.common import ModelConfig
from repro.models.transformer import DecodeSpec, init_decode_state


def decode_spec(cfg: ModelConfig, shape: ShapeSpec) -> DecodeSpec:
    return DecodeSpec(
        cache_len=shape.seq_len,
        local_cache_len=min(cfg.local_window, shape.seq_len),
        batch=shape.global_batch,
    )


def _token_batch(cfg: ModelConfig, B: int, S: int, with_labels: bool):
    specs = {}
    if cfg.embed_inputs:
        specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype)
        if cfg.mrope:
            specs["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
        if with_labels:
            specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.is_encoder_decoder:
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), cfg.dtype
        )
    return specs


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for the step function's data arguments."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": _token_batch(cfg, B, S, with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": _token_batch(cfg, B, S, with_labels=False)}
    if shape.kind == "decode":
        state = jax.eval_shape(
            lambda: init_decode_state(None, cfg, decode_spec(cfg, shape))
        )
        return {
            "state": state,
            "token": jax.ShapeDtypeStruct((B,), jnp.int32),
        }
    raise ValueError(shape.kind)


def param_specs(cfg: ModelConfig):
    """Allocation-free parameter shapes via eval_shape of the initializer."""
    from repro.models.transformer import init_params

    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Shrink to smoke-test size, preserving every structural feature."""
    L = cfg.num_layers
    if cfg.shared_attn_every > 0:
        layers, every = 6, 3
    elif cfg.attn_pattern == "alternating":
        layers, every = 4, 0
    else:
        layers, every = 2, 0
    heads = 4
    kv = heads if cfg.num_kv_heads == cfg.num_heads else 2
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=layers,
        d_model=128,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 4),
        shared_attn_every=every,
        local_window=16,
        ssm_state=16 if cfg.ssm_state else 0,
        encoder_layers=2 if cfg.is_encoder_decoder else 0,
        encoder_seq=24 if cfg.is_encoder_decoder else cfg.encoder_seq,
        dtype=jnp.float32,
        remat=False,
    )


def make_smoke_batch(cfg: ModelConfig, key, B: int = 2, S: int = 16) -> dict:
    """Concrete random batch matching input_specs(train) for smoke tests."""
    ks = jax.random.split(key, 4)
    batch = {}
    if cfg.embed_inputs:
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model), cfg.dtype)
        if cfg.mrope:
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
            batch["positions"] = jnp.broadcast_to(pos, (3, B, S))
        batch["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.encoder_seq, cfg.d_model), cfg.dtype
        )
    return batch
