"""Attention: GQA with full/local patterns, softcap, RoPE; train + decode.

Training/prefill path is a memory-efficient blocked attention (flash
algorithm in pure jnp, ``lax.scan`` over query chunks) so that 32k-sequence
activations fit device memory at dry-run time and HLO FLOPs reflect the true
2·B·H·T²·D attention cost.  On real TPU the Pallas ``local_attention`` kernel
(repro.kernels.local_attention) is the drop-in fast path via
``use_pallas=True``.

Decode path consumes a KV cache: full-attention layers keep a (S_max) cache;
local layers keep a ring cache of ``window`` slots — the attention analogue
of the paper's FIFO eviction.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, apply_mrope, apply_rope

_NEG_INF = -1.0e30


def _maybe_gather(w, cfg: ModelConfig):
    """Force the JIT all-gather of FSDP-stored replicated-TP weights (archs
    whose heads don't divide the model axis) instead of letting the SPMD
    partitioner replicate the batch compute."""
    if cfg.gather_attn_weights:
        from jax.sharding import PartitionSpec as P

        from repro.distributed import ctx

        return ctx.constrain(w, P(*(None,) * w.ndim))
    return w


def qkv_project(params, x, cfg: ModelConfig):
    """x: (B, T, d) → q: (B, H, T, hd), k/v: (B, Hkv, T, hd)."""
    B, T, _ = x.shape
    q = jnp.einsum("btd,dhk->bhtk", x, _maybe_gather(params["wq"], cfg))
    k = jnp.einsum("btd,dhk->bhtk", x, _maybe_gather(params["wk"], cfg))
    v = jnp.einsum("btd,dhk->bhtk", x, _maybe_gather(params["wv"], cfg))
    return q, k, v


def out_project(params, o, cfg: Optional[ModelConfig] = None):
    w = params["wo"] if cfg is None else _maybe_gather(params["wo"], cfg)
    return jnp.einsum("bhtk,hkd->btd", o, w)


def _expand_kv(k: jax.Array, num_q_heads: int) -> jax.Array:
    rep = num_q_heads // k.shape[1]
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=1)


def blocked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window=0,  # 0 = unbounded (full); may be a traced scalar (alternating)
    softcap: float = 0.0,
    q_chunk: int = 512,
    q_offset: int = 0,
    unroll: bool = False,
) -> jax.Array:
    """Online-softmax blocked attention.  q: (B,H,T,D); k,v: (B,H,S,D).

    ``q_offset`` is the absolute position of q[..., 0, :] relative to k's
    position 0 (for prefill continuation / cross-chunk decode).
    """
    B, H, T, D = q.shape
    S = k.shape[2]
    static_window = isinstance(window, int)
    if not static_window:
        # traced per-layer window: 0 → effectively unbounded
        window = jnp.where(window > 0, window, S + T + 1)
    scale = 1.0 / math.sqrt(D)
    nq = max(1, math.ceil(T / q_chunk))
    Tp = nq * q_chunk
    if Tp != T:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    qs = q.reshape(B, H, nq, q_chunk, D).transpose(2, 0, 1, 3, 4)  # (nq,B,H,c,D)
    kpos = jnp.arange(S)

    def one_chunk(carry, args):
        qc, idx = args  # (B,H,c,D), scalar chunk index
        qpos = q_offset + idx * q_chunk + jnp.arange(q_chunk)
        s = jnp.einsum(
            "bhtd,bhsd->bhts", qc.astype(jnp.float32), k.astype(jnp.float32)
        ) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((q_chunk, S), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if not static_window or window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None], s, _NEG_INF)
        m = s.max(axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        p = jnp.where(mask[None, None], p, 0.0)
        l = p.sum(axis=-1, keepdims=True)
        o = jnp.einsum("bhts,bhsd->bhtd", p, v.astype(jnp.float32))
        o = o / jnp.where(l > 0, l, 1.0)
        return carry, o.astype(q.dtype)

    _, outs = jax.lax.scan(
        one_chunk, None, (qs, jnp.arange(nq)), unroll=nq if unroll else 1
    )  # (nq, B, H, c, D)
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, Tp, D)
    return out[:, :, :T]


def attention_train(
    params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    is_local,  # scalar bool (traced): this layer uses the sliding window
    kv_override: Optional[tuple] = None,  # cross-attention (whisper)
    causal: bool = True,
    return_kv: bool = False,  # prefill: hand back post-RoPE K/V for caching
):
    """Full training/prefill attention for one layer.  x: (B, T, d)."""
    q, k, v = qkv_project(params, x, cfg)
    if kv_override is not None:
        k, v = kv_override
        causal = False
    elif cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    else:
        pos2d = positions if positions.ndim == 2 else positions[0]
        q = apply_rope(q, pos2d, cfg.rope_theta)
        k = apply_rope(k, pos2d, cfg.rope_theta)
    kv_cacheable = (k, v)
    k = _expand_kv(k, cfg.num_heads)
    v = _expand_kv(v, cfg.num_heads)

    if cfg.pin_attn_batch:
        from jax.sharding import PartitionSpec as P

        from repro.distributed import ctx

        dp = ctx.dp_axes()
        if dp:
            # Heads don't divide the model axis (arctic 56H, qwen2-vl 12H):
            # shard the attention section's BATCH over data AND model, so
            # the otherwise-idle model axis shares the quadratic attention
            # compute (16× per-device FLOP reduction measured on arctic).
            full = dp + ("model",)
            if q.shape[0] % (ctx.dp_size() * ctx.tp_size()) == 0:
                axes = full
            elif q.shape[0] % ctx.dp_size() == 0:
                axes = dp
            else:
                axes = None
            if axes:
                pin = lambda t: ctx.constrain(t, P(axes, None, None, None))
                q, k, v = pin(q), pin(k), pin(v)

    if cfg.attn_pattern == "alternating":
        # Both patterns share the same einsum structure; select on mask only
        # (the per-layer window is a traced scalar under the layer scan).
        window = jnp.where(is_local, cfg.local_window, 0)
    elif cfg.attn_pattern == "local":
        window = cfg.local_window
    else:
        window = 0
    out = blocked_attention(
        q, k, v, causal=causal, window=window, softcap=cfg.attn_softcap,
        q_chunk=cfg.q_chunk, unroll=cfg.unroll_attn,
    )
    out = out_project(params, out, cfg)
    if cfg.pin_attn_batch:
        from jax.sharding import PartitionSpec as P

        from repro.distributed import ctx

        dp = ctx.dp_axes()
        if dp and out.shape[0] % ctx.dp_size() == 0:
            out = ctx.constrain(out, P(dp, None, None))
    if return_kv:
        return out, kv_cacheable
    return out


def attention_decode(
    params,
    x: jax.Array,  # (B, 1, d) current token
    cfg: ModelConfig,
    *,
    k_cache: jax.Array,  # (B, Hkv, S, hd)
    v_cache: jax.Array,
    cache_pos: jax.Array,  # (B,) int32: next write slot (ring for local)
    abs_pos: jax.Array,  # (B,) int32: absolute token position per sequence
    is_local,
    kv_override: Optional[tuple] = None,
):
    """One-token decode.  Returns (out (B,1,d), new_k_cache, new_v_cache).

    Positions are per-row so continuous batching can mix sequences at
    different depths in one decode batch.
    """
    B = x.shape[0]
    S = k_cache.shape[2]
    cache_pos = jnp.broadcast_to(cache_pos, (B,))
    abs_pos = jnp.broadcast_to(abs_pos, (B,))
    q, k, v = qkv_project(params, x, cfg)
    if kv_override is None:
        pos = abs_pos[:, None]  # (B, 1)
        if cfg.mrope:
            pos3 = jnp.broadcast_to(abs_pos[None, :, None], (3, B, 1))
            q = apply_mrope(q, pos3, cfg.rope_theta)
            k = apply_mrope(k, pos3, cfg.rope_theta)
        else:
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)
        slot = cache_pos % S  # (B,)
        upd = jax.vmap(
            lambda c, kn, s: jax.lax.dynamic_update_slice_in_dim(c, kn, s, axis=1)
        )
        k_cache = upd(k_cache, k, slot)
        v_cache = upd(v_cache, v, slot)
        kk, vv = k_cache, v_cache
        # Absolute position of each cache slot (ring-aware for local layers).
        slots = jnp.arange(S)[None, :]  # (1, S)
        wraps = ((cache_pos // S) * S)[:, None]  # (B, 1)
        slot_b = slot[:, None]
        slot_pos = jnp.where(slots <= slot_b, wraps + slots, wraps - S + slots)
        valid = (slot_pos >= 0) & (slot_pos <= abs_pos[:, None])
        valid &= jnp.where(
            is_local, slot_pos > abs_pos[:, None] - cfg.local_window, True
        )
    else:
        kk, vv = kv_override
        valid = jnp.ones((B, kk.shape[2]), bool)

    # Grouped-query attention WITHOUT expanding the KV cache: q is reshaped
    # to (B, G, rep, 1, D) and contracted against the (B, G, S, D) cache
    # directly.  This matters enormously when the cache's S axis is sharded
    # (few-kv-head archs): a ``jnp.repeat``-expanded cache defeats sharding
    # propagation and forces a full f32 cache all-gather (measured: 2×17 GB
    # per layer for grok decode_32k).  f32 accumulation happens inside the
    # einsum via preferred_element_type — the cache is read in bf16.
    G = kk.shape[1]
    rep = cfg.num_heads // G
    qg = q.reshape(B, G, rep, 1, cfg.hd)
    scale = 1.0 / math.sqrt(cfg.hd)
    s = jnp.einsum(
        "bgrtd,bgsd->bgrts", qg, kk, preferred_element_type=jnp.float32
    ) * scale
    if cfg.attn_softcap > 0.0:
        s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
    s = jnp.where(valid[:, None, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bgrts,bgsd->bgrtd", p.astype(x.dtype), vv,
        preferred_element_type=jnp.float32,
    )
    o = o.reshape(B, cfg.num_heads, 1, cfg.hd).astype(x.dtype)
    return out_project(params, o, cfg), k_cache, v_cache


def init_attention_params(key, cfg: ModelConfig, dtype=None):
    from repro.models.common import dense_init

    dtype = dtype or cfg.dtype
    d, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d, H, hd), dtype),
        "wk": dense_init(k2, (d, Hkv, hd), dtype),
        "wv": dense_init(k3, (d, Hkv, hd), dtype),
        "wo": dense_init(k4, (H, hd, d), dtype, scale=1.0 / math.sqrt(H * hd)),
    }
