"""Deterministic synthetic token stream + windowed stream statistics.

Every batch is a pure function of (seed, step), so a restarted run replays
exactly the batches it would have seen — the data-side half of
checkpoint-restart fault tolerance (no shuffle-buffer state to persist).

``WindowedStreamStats`` runs the paper's aggregators over the live stream:
Bloom-filter windowed dedup (non-invertible OR monoid ⇒ DABA required) and
min/max/mean token statistics for normalization — the data-pipeline
integration of the sliding-window technique.
"""

from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import daba_lite
from repro.core.monoids import bloom_monoid, bloom_contains, mean_monoid, min_monoid, max_monoid
from repro.models.common import ModelConfig


class SyntheticStream:
    """Zipf-ish token batches, deterministic per (seed, step), shardable."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        V = self.cfg.vocab_size
        # Zipf-like marginal over a shuffled vocab for realistic token stats
        z = rng.zipf(1.3, size=(self.batch, self.seq)).astype(np.int64)
        tokens = (z % V).astype(np.int32)
        out = {}
        if self.cfg.embed_inputs:
            d = self.cfg.d_model
            emb = rng.standard_normal((self.batch, self.seq, d)).astype(np.float32)
            out["embeds"] = jnp.asarray(emb, self.cfg.dtype)
            if self.cfg.mrope:
                pos = np.broadcast_to(
                    np.arange(self.seq, dtype=np.int32), (self.batch, self.seq)
                )
                out["positions"] = jnp.asarray(np.broadcast_to(pos, (3,) + pos.shape))
            out["labels"] = jnp.asarray(tokens)
        else:
            out["tokens"] = jnp.asarray(tokens)
        if self.cfg.is_encoder_decoder:
            frames = rng.standard_normal(
                (self.batch, self.cfg.encoder_seq, self.cfg.d_model)
            ).astype(np.float32)
            out["frames"] = jnp.asarray(frames, self.cfg.dtype)
        return out

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class WindowedStreamStats:
    """Sliding-window stream statistics maintained by DABA Lite.

    * ``doc_bloom``: Bloom filter over the last ``window`` document hashes —
      windowed dedup (was this document seen in the recent stream?).
    * ``tok_mean`` / ``tok_min`` / ``tok_max``: windowed per-batch token
      statistics for normalization / drift monitoring.
    """

    def __init__(self, window: int = 256, bloom_words: int = 64):
        self.window = window
        self.m_bloom = bloom_monoid(bloom_words)
        self.m_mean = mean_monoid()
        self.m_min = min_monoid()
        self.m_max = max_monoid()
        cap = window + 1
        self.bloom = daba_lite.init(self.m_bloom, cap)
        self.mean = daba_lite.init(self.m_mean, cap)
        self.min = daba_lite.init(self.m_min, cap)
        self.max = daba_lite.init(self.m_max, cap)

    def _slide(self, m, st, v):
        st = daba_lite.insert(m, st, v)
        if int(daba_lite.size(st)) > self.window:
            st = daba_lite.evict(m, st)
        return st

    def observe_batch(self, tokens: jax.Array, doc_id: int) -> dict:
        tf = tokens.astype(jnp.float32)
        self.bloom = self._slide(self.m_bloom, self.bloom, jnp.asarray(doc_id))
        self.mean = self._slide(self.m_mean, self.mean, tf.mean())
        self.min = self._slide(self.m_min, self.min, tf.min())
        self.max = self._slide(self.m_max, self.max, tf.max())
        return self.snapshot()

    def seen_recently(self, doc_id: int) -> bool:
        filt = daba_lite.query(self.m_bloom, self.bloom)
        return bool(bloom_contains(filt, jnp.asarray(doc_id)))

    def snapshot(self) -> dict:
        return {
            "win_tok_mean": float(
                self.m_mean.lower(daba_lite.query(self.m_mean, self.mean))
            ),
            "win_tok_min": float(daba_lite.query(self.m_min, self.min)),
            "win_tok_max": float(daba_lite.query(self.m_max, self.max)),
        }
