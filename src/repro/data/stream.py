"""Deterministic synthetic token stream + windowed stream statistics.

Every batch is a pure function of (seed, step), so a restarted run replays
exactly the batches it would have seen — the data-side half of
checkpoint-restart fault tolerance (no shuffle-buffer state to persist).

``DisorderedEventStream`` emits timestamped values in a configurably
out-of-order arrival sequence with bounded lateness — the feed for the
event-time windowing engine (:mod:`repro.core.event_time`) and its
equivalence tests/benchmarks.  ``KeyedEventStream`` adds the key dimension:
Zipf-distributed tenant ids over a configurable universe with the same
bounded-disorder arrival model — the feed for the keyed window store
(:mod:`repro.core.keyed`).  ``MultiTenantEventStream`` adds the tenant
dimension on top: independent per-tenant Zipf-keyed substreams with their
own event clocks and rate scales — the load-generator feed for the
streaming analytics service (:mod:`repro.service`).

``WindowedStreamStats`` runs the paper's aggregators over the live stream:
Bloom-filter windowed dedup (non-invertible OR monoid) and min/max/mean
token statistics for normalization.  All four metrics live in ONE
:class:`repro.core.telemetry.WindowedTelemetry` product-monoid state, so an
``observe_batch`` is a single jitted dispatch (the per-batch token
reductions are fused into it) and a snapshot is one host transfer — the old
implementation ran four separate DABA loops and ``float()``-synced each
metric individually.
"""

from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.monoids import bloom_monoid, bloom_contains, mean_monoid, min_monoid, max_monoid
from repro.core.telemetry import WindowedTelemetry
from repro.models.common import ModelConfig


class SyntheticStream:
    """Zipf-ish token batches, deterministic per (seed, step), shardable."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        V = self.cfg.vocab_size
        # Zipf-like marginal over a shuffled vocab for realistic token stats
        z = rng.zipf(1.3, size=(self.batch, self.seq)).astype(np.int64)
        tokens = (z % V).astype(np.int32)
        out = {}
        if self.cfg.embed_inputs:
            d = self.cfg.d_model
            emb = rng.standard_normal((self.batch, self.seq, d)).astype(np.float32)
            out["embeds"] = jnp.asarray(emb, self.cfg.dtype)
            if self.cfg.mrope:
                pos = np.broadcast_to(
                    np.arange(self.seq, dtype=np.int32), (self.batch, self.seq)
                )
                out["positions"] = jnp.asarray(np.broadcast_to(pos, (3,) + pos.shape))
            out["labels"] = jnp.asarray(tokens)
        else:
            out["tokens"] = jnp.asarray(tokens)
        if self.cfg.is_encoder_decoder:
            frames = rng.standard_normal(
                (self.batch, self.cfg.encoder_seq, self.cfg.d_model)
            ).astype(np.float32)
            out["frames"] = jnp.asarray(frames, self.cfg.dtype)
        return out

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class DisorderedEventStream:
    """Deterministic timestamped stream with configurable bounded disorder.

    Event times are a Poisson-ish arrival process (exponential gaps of mean
    ``mean_gap``); the *arrival* order perturbs the event order by delaying a
    ``disorder`` fraction of elements by up to ``slack`` time units (sort by
    ``ts + U(0, slack) * Bernoulli(disorder)``).  By construction every
    element's lateness relative to the running max is ≤ ``slack``, so an
    :class:`repro.core.event_time.EventTimeChunkedStream` with that slack
    reproduces the in-order reference exactly — the generator for the
    equivalence tests and the out-of-order benchmark rows.

    Pure function of the seed: a restarted consumer replays the identical
    arrival sequence (same fault-tolerance story as :class:`SyntheticStream`).
    """

    def __init__(
        self,
        n: int,
        batch: int = 1,
        *,
        mean_gap: float = 1.0,
        disorder: float = 0.1,
        slack: float = 8.0,
        integer_values: bool = False,
        seed: int = 0,
    ):
        self.n = int(n)
        self.batch = int(batch)
        self.mean_gap = float(mean_gap)
        self.disorder = float(disorder)
        self.slack = float(slack)
        self.integer_values = integer_values
        self.seed = seed

    def _event_order(self):
        rng = np.random.default_rng(self.seed)
        ts = np.cumsum(rng.exponential(self.mean_gap, self.n)).astype(np.float32)
        if self.integer_values:
            xs = rng.integers(-9, 9, (self.n, self.batch)).astype(np.int32)
        else:
            xs = rng.standard_normal((self.n, self.batch)).astype(np.float32)
        delay = (rng.random(self.n) < self.disorder) * rng.uniform(
            0.0, self.slack, self.n
        )
        return ts, xs, np.argsort(ts + delay, kind="stable")

    def arrival(self):
        """(ts, xs) in ARRIVAL order — (n,) timestamps, (n, batch) values."""
        ts, xs, order = self._event_order()
        return jnp.asarray(ts[order]), jnp.asarray(xs[order])

    def in_order(self):
        """(ts, xs) sorted by event time (the reference stream)."""
        ts, xs, _ = self._event_order()
        return jnp.asarray(ts), jnp.asarray(xs)

    def max_lateness(self) -> float:
        """Largest observed lateness vs the running max (≤ ``slack``)."""
        ts, _, order = self._event_order()
        arr = ts[order]
        return float(np.max(np.maximum.accumulate(arr) - arr))


class KeyedEventStream:
    """Deterministic multi-tenant event stream: Zipf keys, bounded disorder.

    Every event is ``(key, ts, x)``: keys are Zipf-distributed over a
    ``universe`` of int32 ids (a few hot tenants, a long cold tail — the
    realistic per-user skew for the keyed window store), event times are a
    Poisson-ish arrival process, and the arrival order perturbs event order
    with the same bounded-lateness construction as
    :class:`DisorderedEventStream` (every element ≤ ``slack`` late).  Pure
    function of the seed: a restarted consumer replays the identical
    sequence.

    The feed for :class:`repro.core.keyed.KeyedChunkedStream` equivalence
    tests and ``benchmarks/bench_keyed.py``.
    """

    def __init__(
        self,
        n: int,
        universe: int,
        *,
        zipf_a: float = 1.2,
        mean_gap: float = 1.0,
        disorder: float = 0.0,
        slack: float = 8.0,
        integer_values: bool = True,
        seed: int = 0,
    ):
        self.n = int(n)
        self.universe = int(universe)
        self.zipf_a = float(zipf_a)
        self.mean_gap = float(mean_gap)
        self.disorder = float(disorder)
        self.slack = float(slack)
        self.integer_values = integer_values
        self.seed = seed

    def _event_order(self):
        rng = np.random.default_rng((self.seed, 77))
        z = rng.zipf(self.zipf_a, self.n).astype(np.int64)
        # shuffle the Zipf ranks over the id space so hot keys are spread out
        perm = np.random.default_rng((self.seed, 78)).permutation(self.universe)
        keys = perm[(z % self.universe)].astype(np.int32)
        ts = np.cumsum(rng.exponential(self.mean_gap, self.n)).astype(np.float32)
        if self.integer_values:
            xs = rng.integers(-9, 9, self.n).astype(np.int32)
        else:
            xs = rng.standard_normal(self.n).astype(np.float32)
        delay = (rng.random(self.n) < self.disorder) * rng.uniform(
            0.0, self.slack, self.n
        )
        return keys, ts, xs, np.argsort(ts + delay, kind="stable")

    def arrival(self):
        """``(keys, ts, xs)`` in ARRIVAL order — (n,) each."""
        keys, ts, xs, order = self._event_order()
        return (
            jnp.asarray(keys[order]),
            jnp.asarray(ts[order]),
            jnp.asarray(xs[order]),
        )

    def in_order(self):
        """``(keys, ts, xs)`` sorted by event time."""
        keys, ts, xs, _ = self._event_order()
        return jnp.asarray(keys), jnp.asarray(ts), jnp.asarray(xs)

    def hot_keys(self, top: int = 10) -> np.ndarray:
        """The ``top`` most frequent keys (host-side; for report/queries)."""
        keys, _, _, _ = self._event_order()
        uniq, counts = np.unique(keys, return_counts=True)
        return uniq[np.argsort(-counts)][:top]


class MultiTenantEventStream:
    """The tenant dimension over :class:`KeyedEventStream`: ``tenants``
    independent Zipf-keyed substreams, one per tenant, each a pure function
    of ``(seed, tenant)`` — the load-generator feed for the streaming
    analytics service (:mod:`repro.service`) and its benchmark.

    Every tenant gets its own Poisson event clock (timestamps non-decreasing
    per tenant — the keyed store's event-time precondition when
    ``disorder=0``), its own Zipf key marginal over ``universe`` ids, and a
    per-tenant ``rate_scale`` so quota scenarios can drive one tenant hotter
    than the rest.  :meth:`batches` yields host-side numpy batches (the HTTP
    client serializes them as JSON rows), so no device work happens in the
    generator.
    """

    def __init__(
        self,
        tenants: int,
        n_per_tenant: int,
        universe: int,
        *,
        zipf_a: float = 1.2,
        mean_gap: float = 1.0,
        disorder: float = 0.0,
        slack: float = 8.0,
        rate_scales: Optional[list] = None,
        integer_values: bool = True,
        seed: int = 0,
    ):
        self.tenants = int(tenants)
        self.n_per_tenant = int(n_per_tenant)
        if rate_scales is None:
            rate_scales = [1.0] * self.tenants
        if len(rate_scales) != self.tenants:
            raise ValueError("rate_scales must have one entry per tenant")
        self._streams = [
            KeyedEventStream(
                n_per_tenant,
                universe,
                zipf_a=zipf_a,
                # a hotter tenant = denser event clock
                mean_gap=mean_gap / float(rate_scales[i]),
                disorder=disorder,
                slack=slack,
                integer_values=integer_values,
                seed=seed + 9973 * i,
            )
            for i in range(self.tenants)
        ]

    def tenant(self, i: int) -> KeyedEventStream:
        return self._streams[i]

    def arrival_host(self, i: int):
        """Tenant ``i``'s full ``(keys, ts, xs)`` in arrival order as numpy
        arrays (host-side; the generator feeds an HTTP client)."""
        keys, ts, xs, order = self._streams[i]._event_order()
        return keys[order], ts[order], xs[order]

    def batches(self, i: int, batch: int) -> Iterator[tuple]:
        """Tenant ``i``'s stream as ``(keys, ts, xs)`` numpy batches of
        ``batch`` rows (last one ragged)."""
        keys, ts, xs = self.arrival_host(i)
        for lo in range(0, len(keys), batch):
            yield keys[lo:lo + batch], ts[lo:lo + batch], xs[lo:lo + batch]


class WindowedStreamStats:
    """Sliding-window stream statistics on the unified telemetry layer.

    * ``doc_bloom``: Bloom filter over the last ``window`` document hashes —
      windowed dedup (was this document seen in the recent stream?).
    * ``tok_mean`` / ``tok_min`` / ``tok_max``: windowed per-batch token
      statistics for normalization / drift monitoring.

    One :class:`WindowedTelemetry` product-monoid state holds all four
    windows; ``observe_batch`` — token reductions included — is exactly one
    jitted device dispatch, and ``snapshot`` one host transfer.
    """

    def __init__(self, window: int = 256, bloom_words: int = 64):
        self.window = window

        def prepare(raw):
            tf = raw["tokens"].astype(jnp.float32)
            return {
                "doc_bloom": raw["doc_id"],
                "tok_mean": tf.mean(),
                "tok_min": tf.min(),
                "tok_max": tf.max(),
            }

        self.telem = WindowedTelemetry(
            {
                "doc_bloom": bloom_monoid(bloom_words),
                "tok_mean": mean_monoid(),
                "tok_min": min_monoid(),
                "tok_max": max_monoid(),
            },
            window,
            prepare=prepare,
        )

    def observe_batch(self, tokens: jax.Array, doc_id: int) -> dict:
        self.telem.observe(
            {"tokens": tokens, "doc_id": jnp.asarray(doc_id, jnp.int32)}
        )
        return self.snapshot()

    def seen_recently(self, doc_id: int) -> bool:
        filt = self.telem.aggregate("doc_bloom")  # live windowed Bloom filter
        return bool(bloom_contains(filt, jnp.asarray(doc_id, jnp.int32)))

    def snapshot(self) -> dict:
        s = self.telem.snapshot()
        return {
            "win_tok_mean": float(s["tok_mean"]),
            "win_tok_min": float(s["tok_min"]),
            "win_tok_max": float(s["tok_max"]),
        }
