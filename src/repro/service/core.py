"""Multi-tenant streaming analytics service over the keyed window engine.

The engines in :mod:`repro.core` are libraries; this module is the front
door that turns them into a service.  Design rules, in order:

* **Never per-event device work.**  HTTP handler threads do numpy
  validation, a token-bucket debit, and a deque append — nothing else.  A
  single consumer thread drains the per-tenant queues in batched
  round-robin: one drained chunk = whole batches of ONE tenant, padded to
  the engine chunk size, fused into ONE
  :meth:`repro.core.keyed.KeyedChunkedStream.process_chunk` dispatch (plus
  one chunk-summary fold and one C=1 rollup observation when rollups are
  on).  I/O is amortized exactly the way the keyed hot path wants.

* **Robustness is load-shedding, not memory.**  Per-tenant token buckets
  throttle over-quota tenants (429 + ``Retry-After``) without touching
  anyone else's tokens; bounded per-tenant queues and a global pending-row
  high-watermark shed bursts (503 + shed accounting) instead of growing
  without bound; and over-capacity chunks degrade gracefully through the
  KeyDirectory's fail-safe drop path, surfaced per tenant (a drained chunk
  is single-tenant, so the store's drop-counter delta attributes cleanly).

* **One engine, namespaced keys.**  Tenant ``idx`` and raw key ``k`` map
  to ``(idx << key_bits) | k`` inside one shared
  :class:`~repro.core.keyed.KeyedChunkedStream` with event-time
  ``horizon=`` windows — per-tenant key spaces are disjoint, so tenant
  isolation is arithmetic, not data structures.  The engine runs
  ``donate=False``: queries read the live state concurrently with drains
  (a pure update returns a fresh state; the swap is one reference
  assignment).

* **Ingest→queryable is measured, not modeled.**  Each accepted batch
  stamps ``perf_counter`` at enqueue; the drain that folds it ends with
  one small host transfer of the store's health counters — a sync point,
  after which the rows are queryable — and records the elapsed time per
  batch (bounded exact ring + optional obs KLL histogram).

Per-tenant rollups ride along as mergeable sketches: each drained chunk is
reduced to ONE product-sketch summary (value-quantile KLL + distinct-key
HLL + heavy-hitter top-k, a log-depth masked fold), and that summary is a
single window element of a :class:`repro.core.telemetry.KeyedTelemetry`
keyed by tenant — ``GET /query`` serves p50/p95/p99, a distinct-key
estimate, and the hottest keys from the last ``rollup_window`` chunks.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.event_time import fold_axis0
from repro.core.keyed import KeyedChunkedStream
from repro.core.monoids import (
    get_monoid,
    hll_estimate,
    hll_monoid,
    kll_monoid,
    topk_items,
    topk_monoid,
)
from repro.service.config import ServiceConfig
from repro.service.tenancy import Batch, TenantState, TokenBucket, validate_batch


class AnalyticsService:
    """The multi-tenant streaming analytics service (HTTP layer lives in
    :mod:`repro.service.http`; this class is directly drivable in tests).

    Lifecycle::

        svc = AnalyticsService(ServiceConfig()).start()
        status, payload, headers = svc.ingest("tenant-a", keys, ts, values)
        svc.flush()                      # tests/benchmarks: drain the queues
        snap = svc.query("tenant-a", keys=[1, 2, 3])
        svc.stop()
    """

    def __init__(self, cfg: Optional[ServiceConfig] = None):
        self.cfg = cfg = cfg or ServiceConfig()
        self.monoid = get_monoid(cfg.monoid)
        # donate=False: /query reads the live state while the consumer
        # dispatches the next chunk — donation would delete those buffers
        # out from under a concurrent reader (the KeyedTelemetry rule)
        self._engine = KeyedChunkedStream(
            self.monoid, cfg.window, cfg.slots, cfg.chunk,
            horizon=cfg.horizon, donate=False,
        )
        self._state = self._engine.init_state()
        self._query_jit = jax.jit(self._engine.store.query)
        self._prev_health = {k: 0 for k in
                             ("n_evicted", "n_failed", "n_dropped")}

        # per-tenant rollup sketches: the store folds pre-combined CHUNK
        # summaries, so the member monoids carry an identity lift — the
        # heavy per-row lifting happens once per chunk in _summary_jit
        self._rollup = None
        if cfg.rollup:
            from repro.core.telemetry import KeyedTelemetry

            # size the KLL so its weighted capacity k*(2^levels - 1) covers
            # every row the rollup window can hold (rollup_window chunks of
            # cfg.chunk rows): a top-level compaction DROPS its promoted
            # survivors, so an undersized sketch silently sheds mass —
            # cfg.kll_levels is a floor, not the operative value
            need = cfg.rollup_window * cfg.chunk
            levels = cfg.kll_levels
            while cfg.kll_k * ((1 << levels) - 1) < need:
                levels += 1
            self._sketches = {
                "values": kll_monoid(k=cfg.kll_k, levels=levels),
                "distinct": hll_monoid(cfg.hll_registers),
                "hot": topk_monoid(cfg.topk_k),
            }
            self._rollup = KeyedTelemetry(
                {name: dataclasses.replace(m, lift=lambda a: a)
                 for name, m in self._sketches.items()},
                cfg.rollup_window,
                slots=cfg.max_tenants,
                chunk=cfg.chunk,
            )
            self._summary_jit = jax.jit(self._chunk_summary)

        # tenancy + accounting (ONE lock; device work never runs under it)
        self._lock = threading.RLock()
        self._tenants: Dict[str, TenantState] = {}
        self._order: List[str] = []     # registration order, for round-robin
        self._rr = 0
        self._pending_rows = 0
        self._chunks = 0
        self._drained_rows = 0
        self._latencies = deque(maxlen=cfg.latency_ring)
        self._t_start = time.monotonic()

        # consumer thread
        self._stop_evt = threading.Event()
        self._wake = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._consumer_error: Optional[str] = None

        # obs (attach_obs fills these in)
        self._obs_registry = None
        self._lat_hist = None

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "AnalyticsService":
        if self._thread is not None:
            raise RuntimeError("service already started")
        self._stop_evt.clear()
        self._thread = threading.Thread(
            target=self._consume, name="service-consumer", daemon=True
        )
        self._thread.start()
        return self

    def stop(self, drain: bool = True, timeout: float = 300.0) -> None:
        if self._thread is None:
            return
        if drain:
            self.flush(timeout=timeout)
        self._stop_evt.set()
        self._wake.set()
        self._thread.join(timeout=timeout)
        self._thread = None

    def flush(self, timeout: float = 300.0) -> bool:
        """Block until every accepted row is queryable (tests/benchmarks).
        The generous default absorbs first-chunk jit compiles on slow
        hosts; returns False (state possibly still draining) on timeout."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self._consumer_error is not None:
                raise RuntimeError(
                    f"service consumer died:\n{self._consumer_error}"
                )
            with self._lock:
                if self._pending_rows == 0:
                    return True
            self._wake.set()
            time.sleep(0.001)
        return False

    def __enter__(self) -> "AnalyticsService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- ingest path (handler threads; host-side only) ---------------------

    def _tenant(self, name: str) -> tuple:
        """Find-or-register under the lock → ``(tenant, error_payload)``."""
        t = self._tenants.get(name)
        if t is not None:
            return t, None
        if len(self._tenants) >= self.cfg.max_tenants:
            return None, {"error": "tenant capacity exhausted",
                          "max_tenants": self.cfg.max_tenants}
        t = TenantState(
            name, len(self._tenants),
            TokenBucket(self.cfg.quota_rows_per_s, self.cfg.quota_burst),
            self.cfg.tenant_queue_batches,
        )
        self._tenants[name] = t
        self._order.append(name)
        return t, None

    def ingest(self, tenant: str, keys, ts, xs) -> tuple:
        """One batch → ``(http_status, payload, headers)``.

        200 accepted · 400 malformed · 413 oversized · 429 over quota
        (``Retry-After`` header) · 503 backpressure or tenant capacity.
        Accounting is all-or-nothing per batch: an accepted batch is
        enqueued whole and will be drained whole.
        """
        cfg = self.cfg
        with self._lock:
            t, err = self._tenant(str(tenant))
            if t is None:
                return 503, err, {}
            last_ts = t.last_ts
        error, payload = validate_batch(
            keys, ts, xs, max_batch=cfg.max_batch, key_limit=cfg.key_limit,
            last_ts=last_ts, value_dtype=cfg.value_dtype,
        )
        if error is not None:
            with self._lock:
                t.rejected_batches += 1
            return error, payload, {}
        k, tsa, x = payload
        n = int(k.shape[0])
        ok, retry_after = t.bucket.try_take(n)
        if not ok:
            with self._lock:
                t.throttled_batches += 1
                t.throttled += n
            return 429, {"error": "quota exhausted",
                         "retry_after": round(retry_after, 3)}, {
                "Retry-After": str(max(1, int(np.ceil(retry_after))))}
        batch = Batch(k, tsa, x, time.perf_counter())
        with self._lock:
            if self._pending_rows + n > cfg.global_rows_hw:
                t.shed += n
                return 503, {"error": "backpressure: global queue "
                                      "high-watermark", "shed": n}, {}
            if len(t.queue) >= t.queue_limit:
                t.shed += n
                return 503, {"error": "backpressure: tenant queue full",
                             "shed": n}, {}
            t.queue.append(batch)
            t.last_ts = float(tsa[-1])
            t.ingested += n
            self._pending_rows += n
            seq = t.ingested
        self._wake.set()
        return 200, {"accepted": n, "seq": seq}, {}

    # -- consumer (the single drain thread) --------------------------------

    def _consume(self) -> None:
        import sys
        import traceback

        while not self._stop_evt.is_set():
            try:
                busy = self._drain_once()
            except Exception:
                # a dead consumer must be LOUD: record the traceback so
                # flush()/ingest() fail fast instead of hanging on queues
                # nobody will ever drain
                self._consumer_error = traceback.format_exc()
                print(f"service consumer died:\n{self._consumer_error}",
                      file=sys.stderr)
                return
            if not busy:
                self._wake.wait(self.cfg.idle_sleep_s)
                self._wake.clear()

    def _pick(self) -> Optional[TenantState]:
        """Round-robin over tenants with pending batches (under the lock)."""
        if not self._order:
            return None
        n = len(self._order)
        for i in range(n):
            t = self._tenants[self._order[(self._rr + i) % n]]
            if t.queue:
                self._rr = (self._rr + i + 1) % n
                return t
        return None

    def _drain_once(self) -> bool:
        cfg = self.cfg
        with self._lock:
            t = self._pick()
            if t is None:
                return False
            # whole batches of ONE tenant, up to the engine chunk
            batches, rows = [], 0
            while t.queue and rows + t.queue[0].n <= cfg.chunk:
                b = t.queue.popleft()
                batches.append(b)
                rows += b.n
        keys = np.concatenate([b.keys for b in batches])
        ts = np.concatenate([b.ts for b in batches])
        xs = np.concatenate([b.xs for b in batches])
        namespaced = (t.idx << cfg.key_bits) | keys.astype(np.int64)
        pk = np.empty(cfg.chunk, np.int32)
        pk[:rows] = namespaced
        pk[rows:] = pk[rows - 1]
        px = np.empty(cfg.chunk, xs.dtype)
        px[:rows] = xs
        px[rows:] = xs[-1]
        mask = np.arange(cfg.chunk) < rows
        pt = None
        if cfg.horizon is not None:
            pt = np.empty(cfg.chunk, np.float32)
            pt[:rows] = ts
            pt[rows:] = ts[-1]
            pt = jnp.asarray(pt)
        # ONE fused engine dispatch for the whole drained chunk
        state, _, _ = self._engine.process_chunk(
            self._state, jnp.asarray(pk), jnp.asarray(px), pt,
            jnp.asarray(mask),
        )
        if self._rollup is not None:
            raw_keys = pk & (cfg.key_limit - 1)  # un-namespace (padded shape)
            summary = self._summary_jit(
                jnp.asarray(raw_keys), jnp.asarray(px), jnp.asarray(mask)
            )
            self._rollup.observe(t.idx, summary)
        # the sync point: one small host transfer of the store's health
        # counters — after this the rows are queryable, and the counter
        # deltas attribute to THIS tenant (single-tenant chunk)
        health = jax.device_get(self._engine.store.counters(state))
        now = time.perf_counter()
        lats = [now - b.t_enqueue for b in batches]
        with self._lock:
            self._state = state
            dropped = int(health["n_dropped"]) - self._prev_health["n_dropped"]
            self._prev_health = {k: int(health[k]) for k in self._prev_health}
            t.dropped += dropped
            t.queryable += rows
            self._pending_rows -= rows
            self._chunks += 1
            self._drained_rows += rows
            self._latencies.extend(lats)
        if self._lat_hist is not None:
            self._lat_hist.observe_many(lats)
        return True

    def _chunk_summary(self, keys, xs, mask):
        """Reduce one drained chunk to a single product-sketch element:
        a masked log-depth fold per sketch (C combines total) — the rollup
        store then folds ONE element per chunk instead of C."""
        out = {}
        inputs = {
            "values": xs.astype(jnp.float32),
            "distinct": keys,
            "hot": keys,
        }
        for name, m in self._sketches.items():
            lifted = jax.vmap(m.lift)(inputs[name])
            ident = m.identity()
            lifted = jax.tree.map(
                lambda a, i: jnp.where(
                    mask.reshape((-1,) + (1,) * (a.ndim - 1)),
                    a, jnp.asarray(i, a.dtype),
                ),
                lifted, ident,
            )
            out[name] = fold_axis0(m, lifted)
        return out

    # -- query path --------------------------------------------------------

    def _namespace(self, idx: int, keys: np.ndarray) -> np.ndarray:
        return ((idx << self.cfg.key_bits) | keys.astype(np.int64)).astype(
            np.int32
        )

    def query(self, tenant: str, keys=None, top: int = 10) -> tuple:
        """Tenant snapshot → ``(http_status, payload)``.

        ``keys`` (optional) are raw per-tenant keys to read window folds
        for; defaults to the tenant's hottest keys from the rollup.  The
        payload carries live-key count, rollup sketches (value p50/p95/p99,
        distinct-key estimate, hottest keys), admission counters, and the
        ingest→queryable row lag.
        """
        with self._lock:
            t = self._tenants.get(str(tenant))
            if t is None:
                return 404, {"error": f"unknown tenant {tenant!r}"}
            counters = t.counters()
            idx = t.idx
        state = self._state  # one consistent reference (donate=False)

        rollup = {}
        hot = []
        if self._rollup is not None:
            snap = self._rollup.snapshot(np.asarray([idx], np.int32))
            if bool(snap["found"][0]):
                q50, q95, q99 = np.asarray(snap["values"][0]).tolist()
                rollup["value_quantiles"] = {"p50": q50, "p95": q95, "p99": q99}
                rollup["distinct_keys_est"] = float(
                    hll_estimate(snap["distinct"][0])
                )
                hot = topk_items(
                    jax.tree.map(lambda a: a[0], snap["hot"])
                )[: int(top)]
                rollup["hot_keys"] = [[int(k), int(c)] for k, c in hot]

        if keys is None:
            keys = np.asarray([k for k, _ in hot], np.int64)
        else:
            keys = np.asarray(list(keys), np.int64)
        folds = {}
        if keys.size:
            if keys.min() < 0 or keys.max() >= self.cfg.key_limit:
                return 400, {"error": f"keys must be in [0, {self.cfg.key_limit})"}
            # pow2-pad with the -1 sentinel (never found) so drifting query
            # sizes reuse O(log) compilations — the KeyedTelemetry pattern
            n = int(keys.size)
            cap = 1
            while cap < n:
                cap *= 2
            padded = np.full(cap, -1, np.int32)
            padded[:n] = self._namespace(idx, keys)
            aggs, found = self._query_jit(state, jnp.asarray(padded))
            lowered = jax.device_get(
                {"vals": self.monoid.lower(aggs), "found": found}
            )
            for i, k in enumerate(keys.tolist()):
                folds[str(k)] = {
                    "found": bool(lowered["found"][i]),
                    "fold": np.asarray(lowered["vals"])[i].tolist(),
                }
        # live keys: host scan of the directory for this tenant's namespace
        sk = np.asarray(state["dir"]["slot_key"])
        live = int(np.sum((sk >= 0) & ((sk >> self.cfg.key_bits) == idx)))
        return 200, {
            "tenant": str(tenant),
            "keys": folds,
            "live_keys": live,
            **rollup,
            "counters": counters,
            "lag_rows": counters["pending_rows"],
        }

    def stats(self) -> dict:
        """Service-level snapshot: totals, queue depth, and EXACT
        ingest→queryable latency percentiles over the bounded ring."""
        with self._lock:
            lats = np.asarray(self._latencies, np.float64)
            tenants = {n: t.counters() for n, t in self._tenants.items()}
            out = {
                "tenants": len(tenants),
                "pending_rows": self._pending_rows,
                "chunks": self._chunks,
                "drained_rows": self._drained_rows,
                "uptime_s": round(time.monotonic() - self._t_start, 3),
            }
        lat = {"count": int(lats.size)}
        if lats.size:
            p50, p95, p99 = np.percentile(lats, [50, 95, 99]) * 1e3
            lat.update(p50_ms=round(float(p50), 3),
                       p95_ms=round(float(p95), 3),
                       p99_ms=round(float(p99), 3),
                       max_ms=round(float(lats.max() * 1e3), 3))
        out["ingest_to_queryable"] = lat
        out["per_tenant"] = tenants
        return out

    # -- observability -----------------------------------------------------

    def attach_obs(self, registry=None, *, prefix: str = "repro_service"):
        """Wire the service into a :class:`repro.obs.registry
        .MetricsRegistry`: per-tenant labeled ingested/throttled/shed/
        dropped/lag series, global queue depth and chunk counters, an
        ingest→queryable KLL summary, plus the keyed engine's own store
        health series (``repro_keyed_*``).  Returns the registry (the HTTP
        layer serves ``GET /metrics`` from it)."""
        if registry is None:
            from repro.obs.registry import default_registry

            registry = default_registry()
        self._obs_registry = registry
        self._lat_hist = registry.histogram(
            f"{prefix}_ingest_to_queryable_seconds",
            "ingest accept → rows queryable (per accepted batch)",
        )
        registry.describe(f"{prefix}_pending_rows", "gauge",
                          "rows accepted but not yet queryable (all tenants)")
        registry.describe(f"{prefix}_tenants", "gauge", "registered tenants")
        registry.describe(f"{prefix}_chunks_total", "counter",
                          "fused drain dispatches")
        registry.describe(f"{prefix}_drained_rows_total", "counter",
                          "rows drained into the keyed store")
        per_tenant = {
            "ingested_rows": ("ingested_rows_total", "counter",
                              "rows accepted into the tenant queue"),
            "queryable_rows": ("queryable_rows_total", "counter",
                               "rows drained + synced into the store"),
            "throttled_rows": ("throttled_rows_total", "counter",
                               "rows refused by the tenant quota (429)"),
            "shed_rows": ("shed_rows_total", "counter",
                          "rows refused by backpressure (503)"),
            "dropped_rows": ("dropped_rows_total", "counter",
                             "rows dropped by failed slot admission"),
            "pending_rows": ("lag_rows", "gauge",
                             "ingest→queryable row lag"),
        }
        for _, (suffix, typ, help) in per_tenant.items():
            registry.describe(f"{prefix}_{suffix}", typ, help)

        def collect():
            with self._lock:
                out = {
                    f"{prefix}_pending_rows": self._pending_rows,
                    f"{prefix}_tenants": len(self._tenants),
                    f"{prefix}_chunks_total": self._chunks,
                    f"{prefix}_drained_rows_total": self._drained_rows,
                }
                for name, t in self._tenants.items():
                    c = t.counters()
                    for key, (suffix, _, _) in per_tenant.items():
                        out[f'{prefix}_{suffix}{{tenant="{name}"}}'] = c[key]
            return out

        registry.register_collector(collect)

        # shared-store health straight off the live state (donate=False:
        # the reference a scrape reads stays valid across drains) — the
        # engine's own attach_obs only reports when built with an ObsConfig
        store_series = {
            "n_live": (f"{prefix}_store_live_keys", "gauge",
                       "keys resident in the shared slot pool"),
            "n_evicted": (f"{prefix}_store_evictions_total", "counter",
                          "LRU evictions since init"),
            "n_failed": (f"{prefix}_store_admission_failed_total", "counter",
                         "abandoned slot admissions"),
            "n_dropped": (f"{prefix}_store_dropped_rows_total", "counter",
                          "rows dropped by failed admission"),
        }
        for key, (name, typ, help) in store_series.items():
            registry.describe(name, typ, help)

        def collect_store():
            c = self._engine.store.counters(self._state)
            return {name: c[key] for key, (name, _, _) in store_series.items()}

        registry.register_collector(collect_store)
        return registry
