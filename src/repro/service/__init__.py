"""Multi-tenant streaming analytics service over the keyed window engines.

The front door that turns :mod:`repro.core` from a library into a service:
HTTP ingestion with per-tenant quotas and backpressure, a single batched
consumer draining into ONE shared :class:`repro.core.keyed
.KeyedChunkedStream` (tenant-namespaced keys, event-time windows), per-
tenant rollup sketches (quantiles / distinct keys / heavy hitters), and a
query + metrics surface.  See :mod:`repro.service.core` for the design
rules.
"""

from repro.service.config import ServiceConfig
from repro.service.core import AnalyticsService
from repro.service.http import ServiceHTTPServer
from repro.service.tenancy import Batch, TenantState, TokenBucket, validate_batch

__all__ = [
    "AnalyticsService",
    "Batch",
    "ServiceConfig",
    "ServiceHTTPServer",
    "TenantState",
    "TokenBucket",
    "validate_batch",
]
