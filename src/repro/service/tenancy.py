"""Per-tenant admission state: token-bucket quotas and bounded queues.

A tenant's ingest path is host-side bookkeeping only — numpy validation,
a token-bucket check, a deque append — so HTTP handler threads never touch
the device.  All device work happens on the single consumer thread
(:mod:`repro.service.core`), which drains these queues in batched
round-robin.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Deque, Optional

import numpy as np


class TokenBucket:
    """Thread-safe token bucket: ``rate`` tokens/s refill, ``burst`` cap.

    ``try_take(n)`` either debits ``n`` tokens and returns ``(True, 0.0)``
    or leaves the bucket untouched and returns ``(False, retry_after)`` —
    the seconds until ``n`` tokens will have accrued (the 429
    ``Retry-After`` hint).  ``clock`` is injectable for deterministic
    tests.
    """

    def __init__(self, rate: float, burst: float,
                 clock: Callable[[], float] = time.monotonic):
        self.rate = float(rate)
        self.burst = float(burst)
        self._clock = clock
        self._tokens = float(burst)
        self._t_last = clock()
        self._lock = threading.Lock()

    def _refill(self, now: float) -> None:
        self._tokens = min(
            self.burst, self._tokens + (now - self._t_last) * self.rate
        )
        self._t_last = now

    def try_take(self, n: float) -> tuple:
        with self._lock:
            now = self._clock()
            self._refill(now)
            if self._tokens >= n:
                self._tokens -= n
                return True, 0.0
            deficit = n - self._tokens
            return False, deficit / self.rate if self.rate > 0 else 60.0

    @property
    def tokens(self) -> float:
        with self._lock:
            self._refill(self._clock())
            return self._tokens


@dataclasses.dataclass
class Batch:
    """One accepted ingest batch, queued host-side until the consumer
    drains it (kept as numpy — no device work on the ingest path)."""

    keys: np.ndarray        # (n,) int32, raw per-tenant keys
    ts: np.ndarray          # (n,) float32, non-decreasing
    xs: np.ndarray          # (n,) value dtype
    t_enqueue: float        # perf_counter at accept (latency measurement)

    @property
    def n(self) -> int:
        return int(self.keys.shape[0])


class TenantState:
    """Everything the service tracks per tenant.

    Counter discipline: mutated only under the service's accounting lock
    (handler threads and the consumer both take it); the queue is a deque
    of whole :class:`Batch` objects — batches are atomic, a drained chunk
    contains only whole batches of ONE tenant, so failed-admission drops
    reported by the keyed store attribute cleanly.
    """

    def __init__(self, name: str, idx: int, bucket: TokenBucket,
                 queue_batches: int):
        self.name = name
        self.idx = int(idx)
        self.bucket = bucket
        self.queue: Deque[Batch] = deque()
        self.queue_limit = int(queue_batches)
        self.last_ts: float = -np.inf   # monotone event-time enforcement
        # counters (rows unless noted)
        self.ingested = 0               # accepted into the queue
        self.queryable = 0              # drained + synced into the store
        self.throttled_batches = 0      # 429s
        self.throttled = 0              # rows refused by quota
        self.shed = 0                   # rows refused by backpressure
        self.rejected_batches = 0       # 400/413s
        self.dropped = 0                # rows dropped by failed admission

    @property
    def pending(self) -> int:
        return self.ingested - self.queryable

    def counters(self) -> dict:
        return {
            "ingested_rows": self.ingested,
            "queryable_rows": self.queryable,
            "pending_rows": self.pending,
            "throttled_batches": self.throttled_batches,
            "throttled_rows": self.throttled,
            "shed_rows": self.shed,
            "rejected_batches": self.rejected_batches,
            "dropped_rows": self.dropped,
        }


def validate_batch(
    keys, ts, xs, *, max_batch: int, key_limit: int, last_ts: float,
    value_dtype: str,
) -> tuple:
    """Validate one ingest batch → ``(error, payload_or_arrays)``.

    ``error`` is None on success (payload = ``(keys, ts, xs)`` as typed
    numpy arrays) or an HTTP status code with a reason dict.  Enforced:
    equal lengths, ``0 < n <= max_batch`` (413 beyond), keys in
    ``[0, key_limit)``, finite non-decreasing timestamps that do not
    precede the tenant's last accepted timestamp (the keyed store's
    event-time precondition — disorder must be resolved upstream).
    """
    try:
        k = np.asarray(keys, np.int64)
        t = np.asarray(ts, np.float32)
        x = np.asarray(
            xs, np.int32 if value_dtype == "i32" else np.float32
        )
    except (TypeError, ValueError, OverflowError):
        return 400, {"error": "malformed rows"}
    if k.ndim != 1 or k.shape != t.shape or k.shape != x.shape:
        return 400, {"error": "keys/ts/values must be equal-length 1-D"}
    n = int(k.shape[0])
    if n == 0:
        return 400, {"error": "empty batch"}
    if n > max_batch:
        return 413, {"error": "batch too large", "max_batch": max_batch}
    if k.min() < 0 or k.max() >= key_limit:
        return 400, {"error": f"keys must be in [0, {key_limit})"}
    if not np.all(np.isfinite(t)):
        return 400, {"error": "timestamps must be finite"}
    if n > 1 and np.any(np.diff(t) < 0):
        return 400, {"error": "timestamps must be non-decreasing"}
    if float(t[0]) < last_ts:
        return 400, {
            "error": "timestamps precede the tenant's last accepted batch"
        }
    return None, (k.astype(np.int32), t, x)
