"""HTTP front-end for the multi-tenant streaming analytics service.

Stdlib ``http.server`` only (the :class:`repro.obs.exporter.MetricsExporter`
pattern — no new dependencies)::

    POST /ingest                JSON {"tenant", "keys", "ts", "values"}
                                → 200 {"accepted", "seq"}
                                · 400/413 malformed/oversized
                                · 429 over quota (Retry-After header)
                                · 503 backpressure / tenant capacity
    GET  /query?tenant=a[&keys=1,2&top=5]
                                → tenant snapshot (window folds, rollup
                                  quantiles/distinct/hot keys, counters)
    GET  /stats                 → service totals + exact ingest→queryable
                                  latency percentiles
    GET  /metrics               → Prometheus exposition (requires
                                  ``attach_obs``; 503 without)
    GET  /healthz               → "ok"

Handler threads only validate + enqueue (the service's ingest path is
host-side numpy); every device dispatch stays on the service's single
consumer thread, so concurrency here never races the engine.
"""

from __future__ import annotations

import json
import threading
import traceback
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional
from urllib.parse import parse_qs, urlparse

from repro.service.core import AnalyticsService

PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"
MAX_BODY_BYTES = 4 << 20  # absolute request cap; cfg.max_batch rules rows


class ServiceHTTPServer:
    """Background HTTP server over an :class:`AnalyticsService`.

    Does NOT own the service lifecycle by default: ``start()`` starts the
    HTTP thread and — when the service's consumer is not yet running — the
    service too (and ``stop()`` mirrors that).  ``port=0`` binds an
    ephemeral port; read ``.port`` / ``.url`` after ``start()``.
    """

    def __init__(self, service: AnalyticsService, *, host: str = "127.0.0.1",
                 port: int = 0):
        self.service = service
        self.host = host
        self._requested_port = port
        self._server: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None
        self._owns_service = False

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "ServiceHTTPServer":
        service = self.service
        if service._thread is None:
            service.start()
            self._owns_service = True

        class Handler(BaseHTTPRequestHandler):
            def _reply(self, code: int, payload, headers=None,
                       ctype: str = "application/json"):
                body = (payload if isinstance(payload, bytes)
                        else (json.dumps(payload) + "\n").encode("utf-8"))
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                for k, v in (headers or {}).items():
                    self.send_header(k, v)
                self.end_headers()
                self.wfile.write(body)

            def do_POST(self):  # noqa: N802 (http.server API)
                if urlparse(self.path).path != "/ingest":
                    self._reply(404, {"error": "POST /ingest only"})
                    return
                try:
                    n = int(self.headers.get("Content-Length", 0))
                    if n > MAX_BODY_BYTES:
                        self._reply(413, {"error": "body too large"})
                        return
                    doc = json.loads(self.rfile.read(n))
                    tenant = doc["tenant"]
                    keys, ts, values = doc["keys"], doc["ts"], doc["values"]
                except Exception:
                    self._reply(400, {"error": "body must be JSON with "
                                               "tenant/keys/ts/values"})
                    return
                try:
                    code, payload, hdrs = service.ingest(
                        tenant, keys, ts, values
                    )
                    self._reply(code, payload, hdrs)
                except Exception:
                    self._reply(500, {"error": traceback.format_exc()})

            def do_GET(self):  # noqa: N802 (http.server API)
                url = urlparse(self.path)
                try:
                    if url.path == "/healthz":
                        self._reply(200, b"ok\n", ctype="text/plain")
                    elif url.path == "/stats":
                        self._reply(200, service.stats())
                    elif url.path == "/metrics":
                        reg = service._obs_registry
                        if reg is None:
                            self._reply(503, {"error": "no registry "
                                              "attached (call attach_obs)"})
                        else:
                            self._reply(200, reg.render().encode("utf-8"),
                                        ctype=PROM_CONTENT_TYPE)
                    elif url.path == "/query":
                        q = parse_qs(url.query)
                        if "tenant" not in q:
                            self._reply(400, {"error": "tenant= required"})
                            return
                        keys = None
                        if "keys" in q:
                            keys = [int(k) for part in q["keys"]
                                    for k in part.split(",") if k]
                        top = int(q.get("top", ["10"])[0])
                        code, payload = service.query(
                            q["tenant"][0], keys=keys, top=top
                        )
                        self._reply(code, payload)
                    else:
                        self._reply(404, {"error": f"no route {url.path}"})
                except BrokenPipeError:
                    pass
                except Exception:
                    self._reply(500, {"error": traceback.format_exc()})

            def log_message(self, *a):  # quiet: no per-request stderr spam
                pass

        self._server = ThreadingHTTPServer(
            (self.host, self._requested_port), Handler
        )
        self._server.daemon_threads = True
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="service-http",
            daemon=True,
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
            self._server = None
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        if self._owns_service:
            self.service.stop()
            self._owns_service = False

    # -- address -----------------------------------------------------------

    @property
    def port(self) -> int:
        if self._server is None:
            raise RuntimeError("server not started")
        return self._server.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def __enter__(self) -> "ServiceHTTPServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
