"""Service configuration — the one knob surface for :mod:`repro.service`.

Everything the multi-tenant streaming analytics service does is gated
here: the windowed-aggregation engine shape (monoid, per-key window
capacity, event-time horizon, slot pool, chunk size), the tenant key
namespace split, admission quotas (token buckets), queue bounds and the
global backpressure high-watermark, and the per-tenant rollup sketches
(value quantiles / distinct keys / heavy hitters).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Knobs for :class:`repro.service.core.AnalyticsService`.

    Key namespacing: tenant ``idx`` and raw key ``k`` map to the engine key
    ``(idx << key_bits) | k``, so per-tenant key spaces are disjoint inside
    ONE shared :class:`repro.core.keyed.KeyedChunkedStream`.  Raw keys must
    satisfy ``0 <= k < 2**key_bits`` (enforced at ingest with a 400) and
    the namespaced key must stay below ``2**31`` (int32, non-negative), so
    ``max_tenants <= 2**(31 - key_bits)``.
    """

    # -- engine ------------------------------------------------------------
    monoid: str = "sum_i32"            # repro.core.monoids registry name
    window: int = 256                  # per-key window capacity (elements)
    horizon: Optional[float] = 64.0    # event-time span (ts units); None =
                                       # count windows
    slots: int = 8192                  # shared hot-key pool (LRU beyond)
    chunk: int = 1024                  # fused dispatch size (rows)
    value_dtype: str = "i32"           # "i32" (bit-exact) or "f32"

    # -- tenancy / namespacing --------------------------------------------
    key_bits: int = 20                 # per-tenant key space = 2**key_bits
    max_tenants: int = 64              # auto-registered on first ingest

    # -- admission quotas (token bucket per tenant) -----------------------
    quota_rows_per_s: float = 100_000.0
    quota_burst: float = 20_000.0      # bucket capacity (rows)

    # -- queueing / backpressure ------------------------------------------
    max_batch: int = 512               # rows per POST (413 beyond)
    tenant_queue_batches: int = 256    # bounded per-tenant queue (503 full)
    global_rows_hw: int = 65_536       # pending-row high-watermark (503)

    # -- per-tenant rollup sketches ---------------------------------------
    rollup: bool = True
    rollup_window: int = 32            # window of drained-CHUNK summaries
    kll_k: int = 32
    kll_levels: int = 6                # floor; auto-raised so the sketch
                                       # capacity covers rollup_window*chunk
    hll_registers: int = 64
    topk_k: int = 8

    # -- consumer ----------------------------------------------------------
    idle_sleep_s: float = 0.002        # drain-thread wait when queues empty
    latency_ring: int = 65_536         # exact ingest→queryable samples kept

    def __post_init__(self):
        if self.key_bits < 1 or self.key_bits > 30:
            raise ValueError(f"key_bits must be in [1, 30], got {self.key_bits}")
        if self.max_tenants > 2 ** (31 - self.key_bits):
            raise ValueError(
                f"max_tenants={self.max_tenants} overflows int32 keys with "
                f"key_bits={self.key_bits} (max {2 ** (31 - self.key_bits)})"
            )
        if self.max_batch > self.chunk:
            raise ValueError(
                f"max_batch={self.max_batch} must be <= chunk={self.chunk} "
                "(batches are drained whole into one fused dispatch)"
            )
        if self.value_dtype not in ("i32", "f32"):
            raise ValueError(f"value_dtype must be i32|f32, got {self.value_dtype}")

    @property
    def key_limit(self) -> int:
        return 1 << self.key_bits
