"""Run the analytics service from the command line::

    PYTHONPATH=src python -m repro.service --port 8080 --window 256 \
        --horizon 64 --quota-rows-per-s 100000

Serves until interrupted; ``--obs`` attaches the metrics registry so
``GET /metrics`` exposes per-tenant series.
"""

from __future__ import annotations

import argparse
import time

from repro.service.config import ServiceConfig
from repro.service.core import AnalyticsService
from repro.service.http import ServiceHTTPServer


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="repro.service",
                                description=__doc__.splitlines()[0])
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0,
                   help="0 = ephemeral (printed at startup)")
    p.add_argument("--monoid", default="sum_i32")
    p.add_argument("--window", type=int, default=256)
    p.add_argument("--horizon", type=float, default=64.0,
                   help="event-time span; <= 0 for count windows")
    p.add_argument("--slots", type=int, default=8192)
    p.add_argument("--chunk", type=int, default=1024)
    p.add_argument("--max-batch", type=int, default=512)
    p.add_argument("--quota-rows-per-s", type=float, default=100_000.0)
    p.add_argument("--quota-burst", type=float, default=20_000.0)
    p.add_argument("--no-rollup", action="store_true")
    p.add_argument("--obs", action="store_true",
                   help="attach the metrics registry (GET /metrics)")
    args = p.parse_args(argv)

    cfg = ServiceConfig(
        monoid=args.monoid,
        window=args.window,
        horizon=args.horizon if args.horizon > 0 else None,
        slots=args.slots,
        chunk=args.chunk,
        max_batch=args.max_batch,
        quota_rows_per_s=args.quota_rows_per_s,
        quota_burst=args.quota_burst,
        rollup=not args.no_rollup,
    )
    svc = AnalyticsService(cfg)
    if args.obs:
        svc.attach_obs()
    with ServiceHTTPServer(svc, host=args.host, port=args.port) as srv:
        print(f"serving on {srv.url}  (POST /ingest, GET /query,"
              f" /stats, /healthz{', /metrics' if args.obs else ''})",
              flush=True)
        try:
            while True:
                time.sleep(3600)
        except KeyboardInterrupt:
            pass
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
