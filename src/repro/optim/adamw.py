"""AdamW + schedules + global-norm clipping, from scratch (no optax).

Optimizer state mirrors the parameter pytree (m, v), so parameter sharding
rules apply verbatim; ``zero1=True`` additionally shards m/v over the
data-parallel axes (ZeRO-1) — one of the §Perf memory levers.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    count: jax.Array
    m: PyTree
    v: PyTree


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: Optional[float] = 1.0
    # storage dtype for m/v (compute stays f32).  bf16 halves optimizer HBM —
    # required to fit arctic-480b / grok-1-314b on a 256-chip pod.
    state_dtype: Any = jnp.float32

    def init(self, params: PyTree) -> AdamWState:
        zeros = lambda p: jax.tree.map(
            lambda x: jnp.zeros_like(x, self.state_dtype), p
        )
        return AdamWState(count=jnp.zeros((), jnp.int32), m=zeros(params), v=zeros(params))

    def lr(self, count) -> jax.Array:
        if callable(self.learning_rate):
            return self.learning_rate(count)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads: PyTree, state: AdamWState, params: PyTree):
        """Returns (new_params, new_state, stats)."""
        gnorm = global_norm(grads)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        count = state.count + 1
        b1c = 1.0 - self.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** count.astype(jnp.float32)
        lr = self.lr(count)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            mf = self.b1 * m.astype(jnp.float32) + (1.0 - self.b1) * gf
            vf = self.b2 * v.astype(jnp.float32) + (1.0 - self.b2) * gf * gf
            mh = mf / b1c
            vh = vf / b2c
            step = mh / (jnp.sqrt(vh) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                step = step + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
            return new_p, mf.astype(self.state_dtype), vf.astype(self.state_dtype)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, AdamWState(count, new_m, new_v), {"grad_norm": gnorm, "lr": lr}


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.1):
    def lr(count):
        c = count.astype(jnp.float32)
        warm = peak * c / max(warmup, 1)
        prog = jnp.clip((c - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(math.pi * prog)))
        return jnp.where(c < warmup, warm, cos)

    return lr
