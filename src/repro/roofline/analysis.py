"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs_per_device   / PEAK_FLOPS_BF16
    memory     = HLO_bytes_per_device   / HBM_BW
    collective = effective_collective_bytes_per_device / ICI_BW

``cost_analysis()`` on the SPMD-partitioned executable reports *per-device*
FLOPs and bytes, so the prompt's ``/ chips`` is already applied.  Collective
bytes are NOT in cost_analysis: we parse the final optimized HLO
(``compiled.as_text()``) and sum result-shape bytes of every collective op,
weighting all-reduce 2× (ring reduce+broadcast phases).  MODEL_FLOPS uses
6·N·D (train) / 2·N·D (inference) with N = (active) parameter count.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

# Effective streaming bandwidth per backend for the memory-bound cost
# models below.  The CPU figure is the measured single-core effective
# bandwidth of this container on large gather/scatter+scan patterns (NOT
# peak DRAM bandwidth — XLA:CPU runs these single-threaded); TPU/GPU use
# the device HBM figure.
BACKEND_EFF_BW = {
    "cpu": 2.0e9,
    "tpu": HBM_BW,
    "gpu": 600e9,
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]))\S*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-op-type result bytes from the optimized (post-SPMD) HLO text."""
    by_type: dict[str, dict] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        d = by_type.setdefault(op, {"count": 0, "bytes": 0})
        # `-start/-done` pairs would double count; regex folds them to the
        # same op name, so skip `-done` results (they repeat the shape).
        d["count"] += 1
        d["bytes"] += b
    return by_type


def effective_collective_bytes(by_type: dict) -> float:
    """Ring-model effective wire bytes per device."""
    total = 0.0
    for op, d in by_type.items():
        w = 2.0 if op == "all-reduce" else 1.0
        total += w * d["bytes"]
    return total


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    collectives: dict
    model_flops_total: float  # analytic useful FLOPs for the whole step
    memory_analysis: dict
    skipped: bool = False
    note: str = ""

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def model_flops_per_device(self) -> float:
        return self.model_flops_total / max(self.chips, 1)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (per device) — remat/redundancy waste."""
        if self.flops_per_device <= 0:
            return 0.0
        return self.model_flops_per_device / self.flops_per_device

    @property
    def roofline_time(self) -> float:
        """Lower-bound step time = max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound time: how close the step is to the
        hardware roofline if perfectly overlapped."""
        t = self.roofline_time
        if t <= 0:
            return 0.0
        return (self.model_flops_per_device / PEAK_FLOPS_BF16) / t

    def to_json(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes": self.collective_bytes,
            "collectives": self.collectives,
            "model_flops_total": self.model_flops_total,
            "memory_analysis": self.memory_analysis,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_fraction": self.useful_fraction,
            "roofline_fraction": self.roofline_fraction,
            "skipped": self.skipped,
            "note": self.note,
        }


def gla_correction(cfg, shape) -> tuple[float, float]:
    """(flops, bytes) missed by cost_analysis for the GLA chunk scan.

    The gated-linear-attention recurrence scans over sequence chunks; XLA
    counts its body once per layer, so (nc - 1) iterations are missing.
    Per-chunk costs (fwd):
        flops ≈ 2·B·H·(L²·(K+V) + 2·L·K·V)
        bytes ≈ 4·B·L·H·(3K + 2V) + 8·B·H·K·V       (f32 activations+state)
    Train steps include remat-recompute + backward ≈ 4× fwd flops / 3× bytes.
    Decode shapes use the per-token sequential step (no scan) — zero
    correction.  These terms are small for both SSM archs (< a few % of the
    projection matmuls) but are included for honesty.
    """
    if not (getattr(cfg, "rwkv", False) or getattr(cfg, "mamba", False)):
        return 0.0, 0.0
    if shape.kind == "decode":
        return 0.0, 0.0
    B, T = shape.global_batch, shape.seq_len
    H = cfg.num_heads
    if cfg.rwkv:
        K = V = cfg.d_model // H
        Lc = cfg.gla_chunk or 64
    else:
        K = cfg.ssm_state
        V = cfg.d_ff // H
        Lc = cfg.gla_chunk or 16
    nc = max(T // Lc, 1)
    missing = max(nc - 1, 0) * cfg.num_layers
    flops_chunk = 2.0 * B * H * (Lc * Lc * (K + V) + 2 * Lc * K * V)
    bytes_chunk = 4.0 * B * Lc * H * (3 * K + 2 * V) + 8.0 * B * H * K * V
    if shape.kind == "train":
        flops_chunk *= 4.0
        bytes_chunk *= 3.0
    return missing * flops_chunk, missing * bytes_chunk


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs for one step of (cfg, shape).

    train: 6·N_active·D;  prefill: 2·N_active·D;  decode: 2·N_active·B
    plus attention-context FLOPs for decode (KV reads are memory-side).
    """
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def keyed_update_cost(
    chunk: int,
    window: int,
    *,
    value_bytes: int = 4,
    probes: int = 32,
    backend: Optional[str] = None,
) -> dict:
    """Memory-bound roofline for one keyed ``update_chunk`` dispatch.

    Models the MANDATORY steady-state traffic of
    :meth:`repro.core.keyed.KeyedWindowStore.update_chunk` — every term is
    per-chunk, none scales with the slot pool (the donated carry scatter is
    in-place, so the resident (slots, h) state contributes only the touched
    rows):

      * sort + segment bookkeeping: ``~log2(C)`` comparison passes over the
        (C,) key lane plus a handful of (C,) index/mask lanes;
      * directory probing: one ``(C, probes)`` int32 gather;
      * carry traffic: ONE (C, h) row gather + ONE (C, h) batched scatter;
      * segmented two-stacks flip sweep: TWO segmented pair-operator scans
        (block prefix + block suffix, ``log2(C)`` passes each) — the
        constant-combine replacement for the retired ``log2(W)`` doubling
        range-fold table, so per-chunk cost no longer carries a term that
        grows with the window;
      * refresh suffix scan: one more ``log2(C)`` pair-operator pass.

    Returns ``{"bytes_per_chunk", "t_memory", "items_per_s_bound", "bw",
    "backend", "stages"}``.  The bound is what a perfectly-fused
    implementation hitting effective bandwidth would sustain; ``measured /
    items_per_s_bound`` is the roofline-relative fraction benchmark rows
    report.  ``stages`` maps pipeline stage → modeled bytes (sort / probe /
    admit / sweep / scatter) — :meth:`repro.obs.trace.TraceRecorder
    .add_stage_spans` uses it to apportion a measured chunk span into
    per-stage sub-spans.
    """
    import math

    if backend is None:
        import jax

        backend = jax.default_backend()
    bw = BACKEND_EFF_BW.get(backend, BACKEND_EFF_BW["cpu"])
    C = int(chunk)
    h = max(int(window) - 1, 0)
    lg_c = max(math.ceil(math.log2(max(C, 2))), 1)

    b_sort = 2.0 * C * 4 * lg_c                 # argsort passes (int32 keys)
    b_lanes = 10.0 * C * 4                      # segment/index/mask lanes
    b_probe = C * probes * 4.0                  # directory gather
    b_carry = 2.0 * C * h * value_bytes         # row gather + batched scatter
    # flip sweep (block prefix + block suffix) + refresh suffix scan: three
    # segmented pair-op scans, constant in W (the log2(W) doubling-table
    # term is retired)
    b_sscan = 3.0 * 3.0 * C * lg_c * (value_bytes + 4)
    total = b_sort + b_lanes + b_probe + b_carry + b_sscan
    t_mem = total / bw
    return {
        "bytes_per_chunk": total,
        "t_memory": t_mem,
        "items_per_s_bound": C / t_mem if t_mem > 0 else 0.0,
        "bw": bw,
        "backend": backend,
        # hot-path stage names (update_chunk order); carry traffic split
        # between its gather (admit) and scatter halves
        "stages": {
            "sort": b_sort + b_lanes,
            "probe": b_probe,
            "admit": b_carry / 2.0,
            "sweep": b_sscan,
            "scatter": b_carry / 2.0,
        },
    }


def eventtime_release_cost(
    chunk: int,
    capacity: int,
    *,
    distance: int = 0,
    value_bytes: int = 4,
    batch: int = 1,
    backend: Optional[str] = None,
) -> dict:
    """Memory-bound roofline for one event-time ``process_chunk`` dispatch.

    Models the steady-state traffic of
    :class:`repro.core.event_time.EventTimeChunkedStream` per chunk of P
    released rows merged into a W-row window (``M = W + P`` merged
    positions, ``batch`` value lanes per position).  The release stage is
    DISTANCE-AWARE (the disorder-adaptive path of
    :mod:`repro.core.ooo_index`): ``distance`` is the maximum out-of-order
    displacement ``d`` of the chunk's rows —

      * ``d = 0`` (the ``lax.cond`` fast branch): no sort at all, just the
        comparison-free ``compact_perm`` index build plus its gather —
        2 passes over the (P,) pending lanes;
      * ``d > 0``: a stable sort whose comparison depth scales with the
        disordered region ``min(P, 2d)`` — ``log2`` passes over (P,)
        lanes plus the sorted gather (cf. the d-bounded costs of
        arXiv 1810.11308 / 2307.11210);
      * merge gather dual: merged timestamps + aggregates assembled by two
        position gathers (no scatters — see the module docstring);
      * flip boundary orbit: gather-only binary lifting, ``log2(M)``
        levels of (M,) int32 hops;
      * flip sweep: segmented suffix + running prefix ``associative_scan``
        over (M,) pair lanes — constant combines per element, NO term
        grows with the horizon (the retired table paid ``log2(W + C)``
        per element);
      * eviction re-gather of the W-row window.

    Same return shape as :func:`keyed_update_cost`; ``items_per_s_bound``
    counts P·batch items per dispatch.  ``stages["release"]`` holds
    whichever release term applies (compact or sort).
    """
    import math

    if backend is None:
        import jax

        backend = jax.default_backend()
    bw = BACKEND_EFF_BW.get(backend, BACKEND_EFF_BW["cpu"])
    P = int(chunk)
    W = int(capacity)
    M = W + P
    d = max(int(distance), 0)
    vb = value_bytes * max(int(batch), 1)
    lg_m = max(math.ceil(math.log2(max(M, 2))), 1)

    if d == 0:
        # compact_perm: index arithmetic + one permutation gather
        b_release = 2.0 * P * (vb + 4)
    else:
        region = min(P, max(2 * d, 2))
        lg_d = max(math.ceil(math.log2(region)), 1)
        b_release = 2.0 * P * 4 * lg_d + P * (vb + 4)
    b_merge = 3.0 * M * (vb + 4)               # gather-dual ts+agg assembly
    b_orbit = 2.0 * M * 4 * lg_m               # binary-lifting hop levels
    b_sweep = 4.0 * M * (vb + 4)               # seg suffix + prefix scans
    b_evict = 2.0 * W * (vb + 4)               # window re-gather
    total = b_release + b_merge + b_orbit + b_sweep + b_evict
    t_mem = total / bw
    items = P * max(int(batch), 1)
    return {
        "bytes_per_chunk": total,
        "t_memory": t_mem,
        "items_per_s_bound": items / t_mem if t_mem > 0 else 0.0,
        "bw": bw,
        "backend": backend,
        "stages": {
            "release": b_release,
            "merge": b_merge,
            "orbit": b_orbit,
            "sweep": b_sweep,
            "evict": b_evict,
        },
    }


def keyed_horizon_cost(
    chunk: int,
    window: int,
    *,
    value_bytes: int = 4,
    probes: int = 32,
    backend: Optional[str] = None,
) -> dict:
    """Memory-bound roofline for one keyed ``update_chunk`` dispatch in
    event-time ``horizon=`` mode — :func:`keyed_update_cost` plus the two
    extra traffic terms the mode adds:

      * lane timestamps: ONE (C, h) f32 ``carry_ts`` row gather + ONE
        batched scatter (the ts mirror of the carry traffic);
      * span-start finger search: ``bit_length(C)`` rounds of one (C,)
        timestamp gather each (:func:`repro.core.ooo_index
        .seg_bounded_search`).

    Same return shape; ``stages`` gains ``lane_ts`` / ``search``.
    """
    import math

    base = keyed_update_cost(
        chunk, window, value_bytes=value_bytes, probes=probes,
        backend=backend,
    )
    C = int(chunk)
    h = max(int(window) - 1, 0)
    lg_c = max(math.ceil(math.log2(max(C, 2))), 1)
    b_ts = 2.0 * C * h * 4                     # carry_ts gather + scatter
    b_search = C * 4.0 * (lg_c + 1)            # finger-search gather rounds
    total = base["bytes_per_chunk"] + b_ts + b_search
    t_mem = total / base["bw"]
    return {
        "bytes_per_chunk": total,
        "t_memory": t_mem,
        "items_per_s_bound": C / t_mem if t_mem > 0 else 0.0,
        "bw": base["bw"],
        "backend": base["backend"],
        "stages": dict(base["stages"], lane_ts=b_ts, search=b_search),
    }


def save_roofline(r: Roofline, path: str):
    with open(path, "w") as f:
        json.dump(r.to_json(), f, indent=2)


def load_roofline(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
