import os
if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""HLO collective inspector: compile one (arch × shape) cost variant and list
every collective op with its result shape, sorted by bytes — the profiling
loupe for §Perf iterations (we reason from lowered IR, not wall traces).

    PYTHONPATH=src python -m repro.roofline.inspect --arch grok-1-314b \
        --shape decode_32k [--set moe_2d=true] [--top 20]
"""

import argparse
import dataclasses
import re


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--layers", type=int, default=0, help="0 = 2×period")
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--set", nargs="*", default=[])
    args = ap.parse_args()

    from repro.configs import ARCHS, SHAPES
    from repro.configs.profiles import get_profile
    from repro.distributed import ctx
    from repro.launch import dryrun
    from repro.launch.mesh import make_production_mesh
    from repro.roofline.analysis import _COLLECTIVE_RE, _shape_bytes

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = (v.lower() == "true") if v.lower() in ("true", "false") else int(v)

    cfg = ARCHS[args.arch]
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    ctx.set_dp_axes(("pod", "data") if args.mesh == "multi" else ("data",))
    profile = get_profile(args.arch)
    p = dryrun._layer_period(cfg)
    L = args.layers or 2 * p
    var = dryrun._depth_variant(cfg, L, shape.seq_len)

    with mesh:
        lowered = dryrun._build_lowered(var, shape, mesh, profile, accum=1)
        compiled = lowered.compile()

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    print(f"L={L} flops={cost.get('flops', 0):.3e} bytes={cost.get('bytes accessed', 0):.3e}")

    hlo = compiled.as_text()
    ops = []
    for m in _COLLECTIVE_RE.finditer(hlo):
        ops.append((_shape_bytes(m.group(1)), m.group(2), m.group(1)))
    ops.sort(reverse=True)
    total = sum(b for b, _, _ in ops)
    print(f"{len(ops)} collectives, {total/1e9:.3f} GB result bytes (counted once/loop)")
    for b, op, shp in ops[: args.top]:
        print(f"  {b/1e6:12.2f} MB  {op:20s} {shp[:90]}")

    # largest dot ops by (result elements × contraction size) ≈ flops/2
    dot_re = re.compile(
        r"= ([a-z0-9]+)\[([0-9,]+)\][^\n]*? dot\([^\n]*?"
        r"lhs_contracting_dims=\{([0-9,]+)\}[^\n]*?\n?[^\n]*?%(\S+)? ?", re.M)
    shape_re = re.compile(r"%\S+ = [a-z0-9]+\[([0-9,]+)\]")
    dots = []
    for line in hlo.splitlines():
        if " dot(" not in line:
            continue
        mres = re.search(r"= [a-z0-9]+\[([0-9,]+)\]", line)
        mlhs = re.search(r"dot\(\s*%?\S+?\s", line)
        mc = re.search(r"lhs_contracting_dims=\{([0-9,]+)\}", line)
        ml = re.search(r"dot\(([^,]+),", line)
        if not (mres and mc and ml):
            continue
        res_elems = 1
        for d in mres.group(1).split(","):
            res_elems *= int(d)
        # find lhs shape in the same line (operand referenced by name only);
        # approximate contraction size from flops ∝ res × K unknown — just
        # report result elems; K visible when operand shapes inline
        dots.append((res_elems, line.strip()[:140]))
    dots.sort(reverse=True)
    print(f"\ntop dot ops by result elements:")
    for n, line in dots[: args.top]:
        print(f"  {n/1e6:10.1f}M  {line}")


if __name__ == "__main__":
    main()
