"""Keyed window store — per-key sliding windows at million-key scale.

The batched/chunked engines maintain B windows in lock-step: every lane sees
every element.  A multi-tenant system is the transpose — each event belongs
to ONE key (user, request, partition) out of an unbounded universe, and only
that key's window moves.  This module provides that layer over the existing
SWAG machinery:

  * :class:`KeyDirectory` — a JAX-native open-addressing hash directory
    (key → dense slot): vectorized lookup, sequential-per-new-key admission
    fused into the chunk dispatch, LRU eviction when the slot pool is full
    and TTL expiry for idle keys — the hot set stays bounded (``slots``)
    while the key universe is unbounded.
  * :class:`KeyedWindowStore` — ``slots`` independent count-based windows
    stored as stacked SoA lanes of the warm-carry representation
    (:mod:`repro.core.swag_base`): lane t of a slot's carry is the suffix
    fold of its last ``window - 1 - t`` elements.  Any bulk-protocol SWAG
    algorithm interoperates: ``export_states`` / ``adopt_states`` convert
    lanes to/from live per-element states via ``carry_to_state`` /
    ``state_to_carry``.
  * :meth:`KeyedWindowStore.update_chunk` — the bulk path: a mixed-key
    ``(key, x)`` chunk becomes ONE fused segment-wise dispatch: stable sort
    by key (arrival order preserved within key — non-commutative monoids
    stay bit-exact vs the per-key per-element reference), segment
    boundaries, directory admission, per-row window outputs via a
    constant-combine segmented two-stacks flip sweep (the flip invariant —
    see the :mod:`repro.core.event_time` module docstring, the ONE place
    stating it and the suffix-scan operand-order rule), and one scatter of
    refreshed carries — instead of K tiny per-key updates (cf. the
    bulk-eviction direction of arXiv 2307.11210, extended across the key
    dimension).

The hot-path anatomy keeps every per-dispatch cost proportional to the
CHUNK, never to the slot pool:

  1. stable sort by key → segments (O(C log C));
  2. admission (:meth:`KeyDirectory.admit_heads`): ONE vectorized lookup of
     the segment-head keys; a ``lax.cond`` takes the all-hit branch (just a
     recency-bump scatter) when the chunk introduces no new keys, else a
     round-based *batched* admission that inserts every genuinely-new head
     per round with scatter-min conflict resolution — sequential only in
     the (few) probe-conflict rounds, not per key;
  3. per-row outputs from the intra-chunk flip sweep — one segmented
     prefix scan + one segmented suffix scan at W-aligned block boundaries
     (O(1) ⊗ per row, flat in W; invertible commutative monoids keep the
     one-prefix-scan ``range_fold_invertible`` fast path) — plus a
     warm-prefix gather of (C, h) carry lanes; reclaimed slots are masked
     at the GATHER (never a full-(slots, h) reset pass);
  4. refreshed carries from one more segmented suffix scan
     (:func:`seg_suffix_scan` / :func:`seg_prefix_scan`, or the fused
     ``kernels/seg_scan`` Pallas kernels for scalar monoids on TPU) fused
     into two masked gathers and ONE batched (C, h) scatter.

:class:`KeyedChunkedStream` donates the state buffers into the jitted
update, so that scatter is in-place — per-chunk work stays O(C·h) while
the resident state is O(slots·h).
  * :class:`KeyedChunkedStream` — the chunk-at-a-time driver (jit cache,
    ragged final chunk padding) mirroring
    :class:`repro.core.chunked.ChunkedStream`.
  * :class:`ShardedKeyedStore` — device sharding of the key space:
    ``shard_map`` over a mesh axis, key → shard by hash, per-shard stores
    and directories, ZERO collectives in steady state (each shard masks the
    chunk down to its own rows; outputs stay shard-local).

Keys are non-negative int32 (hash-partition larger key spaces before
ingest); ``-1``/``-2`` are directory sentinels.  Within one chunk at most
``slots`` distinct keys can be admitted (later ones are counted in
``n_dropped`` and emit identity outputs) and an LRU victim is never a slot
already touched by the same chunk, so slot assignment is deterministic.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import ooo_index, swag_base
from repro.core.event_time import (
    COMBINE_COUNTS,
    counting_combines,
    flip_range_fold,
    range_fold_invertible,
    reset_combine_counts,
    seg_prefix_scan,
    seg_suffix_scan,
)
from repro.core.monoids import Monoid, _hash_u32
from repro.core.swag_base import chunk_length
from repro.obs import counters as obs_counters

__all__ = [
    "KeyDirectory",
    "KeyedWindowStore",
    "KeyedChunkedStream",
    "ShardedKeyedStore",
    "COMBINE_COUNTS",
    "counting_combines",
    "reset_combine_counts",
    "seg_prefix_scan",
    "seg_suffix_scan",
]

PyTree = Any

EMPTY = jnp.int32(-1)  # free table entry / free slot
DELETED = jnp.int32(-2)  # tombstone: probes continue through it
_KEY_SENTINEL = jnp.int32(2**31 - 1)  # masked rows sort last


# Host-side admission-branch counters (filled only by stores built with
# ``instrument_admission=True`` — a jax.debug.callback in each branch of the
# admission cond, so tests can assert the hit branch was actually taken at
# runtime).  The counters live in :mod:`repro.obs.counters` (one home for
# the effects-barrier-before-read rule); ``ADMISSION_COUNTS`` is a thin
# deprecated alias — barriered reads should go through
# ``obs_counters.admission.read()``.
ADMISSION_COUNTS = obs_counters.admission


def reset_admission_counts() -> None:
    obs_counters.admission.reset()


def _count_admission(branch: str) -> None:
    obs_counters.admission.bump(branch)


def _bc(mask, leaf):
    return mask.reshape(mask.shape + (1,) * (leaf.ndim - mask.ndim))


def _where_rows(mask, a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(lambda x, y: jnp.where(_bc(mask, x), x, y), a, b)


def _mask_tree(tree: PyTree, mask, ident: PyTree) -> PyTree:
    return jax.tree.map(
        lambda a, i: jnp.where(_bc(mask, a), a, jnp.asarray(i, a.dtype)),
        tree,
        ident,
    )


def _take0(tree: PyTree, idx) -> PyTree:
    return jax.tree.map(lambda a: a[idx], tree)


# The segmented scans (seg_suffix_scan / seg_prefix_scan) live in
# :mod:`repro.core.event_time` next to the flip-invariant statement they
# implement; they are re-exported above for back-compat.


# ---------------------------------------------------------------------------
# Key directory
# ---------------------------------------------------------------------------


class KeyDirectory:
    """Open-addressing key → slot directory as plain JAX arrays.

    ``slots`` dense window slots are addressed through a power-of-two probe
    table of ``dir_factor * slots`` entries (linear probing, ≤ ``probes``
    steps, tombstoned deletes that inserts reuse).  All operations are pure
    functions of the state dict, usable inside jit:

      * :meth:`lookup` — fully vectorized (C, probes) gather for a whole
        chunk of keys;
      * :meth:`admit_row` — one key: find-or-allocate.  Allocation takes a
        free slot while any exists, else evicts the least-recently-used
        slot NOT touched by the current chunk (``touched``) and tombstones
        its table entry.  Taken-branch ``lax.cond`` keeps the hit path at
        O(probes);
      * :meth:`expire` — vectorized TTL sweep freeing every slot idle
        longer than ``ttl``.
    """

    def __init__(self, slots: int, *, dir_factor: int = 2, probes: int = 32):
        self.slots = int(slots)
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        size = 8
        while size < dir_factor * self.slots:
            size *= 2
        self.size = size
        self.probes = min(int(probes), size)

    def init(self) -> PyTree:
        return {
            "table_key": jnp.full((self.size,), EMPTY, jnp.int32),
            "table_slot": jnp.zeros((self.size,), jnp.int32),
            "slot_key": jnp.full((self.slots,), EMPTY, jnp.int32),
            "last_used": jnp.full((self.slots,), -jnp.inf, jnp.float32),
            "n_live": jnp.zeros((), jnp.int32),
            "n_evicted": jnp.zeros((), jnp.int32),
            "n_failed": jnp.zeros((), jnp.int32),
        }

    def _probe_pos(self, key):
        h = _hash_u32(jnp.asarray(key, jnp.int32), 0).astype(jnp.int32)
        offs = jnp.arange(self.probes, dtype=jnp.int32)
        return (h + offs) & jnp.int32(self.size - 1)

    def lookup(self, state: PyTree, keys) -> tuple:
        """Vectorized chunk lookup: ``(slots, found)`` — slot -1 if absent.
        Negative keys (the sentinel range) are never found, so callers may
        pad query batches with -1."""
        keys = jnp.asarray(keys, jnp.int32)
        pos = jax.vmap(self._probe_pos)(keys)  # (C, P)
        tk = state["table_key"][pos]
        eq = tk == keys[:, None]
        empty = tk == EMPTY
        before = jnp.cumsum(empty.astype(jnp.int32), axis=1) - empty
        hit = eq & (before == 0)
        found = hit.any(axis=1) & (keys >= 0)
        j = jnp.argmax(hit, axis=1)
        slot = state["table_slot"][
            jnp.take_along_axis(pos, j[:, None], axis=1)[:, 0]
        ]
        return jnp.where(found, slot, -1), found

    def admit_row(self, state: PyTree, touched, key, ts):
        """Find-or-allocate one key; returns ``(state, touched, slot, new)``.

        ``touched`` is the (slots,) mask of slots used by the current chunk
        — LRU eviction never reclaims one, so a chunk with more distinct
        keys than free+evictable slots fails the excess admissions
        (slot -1, ``n_failed``) instead of corrupting earlier segments.
        """
        key = jnp.asarray(key, jnp.int32)
        ts = jnp.asarray(ts, jnp.float32)
        pos = self._probe_pos(key)
        tk = state["table_key"][pos]
        eq = tk == key
        empty = tk == EMPTY
        free = empty | (tk == DELETED)
        before = jnp.cumsum(empty.astype(jnp.int32)) - empty
        hit = eq & (before == 0)
        found = hit.any()

        def on_found(st, tch):
            slot = st["table_slot"][pos[jnp.argmax(hit)]]
            st = dict(st, last_used=st["last_used"].at[slot].set(ts))
            return st, tch.at[slot].set(True), slot, jnp.asarray(False)

        def on_miss(st, tch):
            ins_ok = free.any()
            ins_pos = pos[jnp.argmax(free)]
            use_free = st["n_live"] < self.slots
            free_slot = jnp.argmax(st["slot_key"] == EMPTY).astype(jnp.int32)
            cost = jnp.where(tch, jnp.inf, st["last_used"])
            victim = jnp.argmin(cost).astype(jnp.int32)
            evict_ok = jnp.isfinite(cost[victim])
            slot = jnp.where(use_free, free_slot, victim)
            ok = ins_ok & (use_free | evict_ok)
            evicting = ok & ~use_free
            # tombstone the victim's table entry (guarded drop-scatter)
            old_key = st["slot_key"][victim]
            vpos = self._probe_pos(old_key)
            vtk = st["table_key"][vpos]
            vempty = vtk == EMPTY
            vbefore = jnp.cumsum(vempty.astype(jnp.int32)) - vempty
            vhit = (vtk == old_key) & (vbefore == 0)
            vslot = jnp.where(
                evicting & vhit.any(), vpos[jnp.argmax(vhit)], self.size
            )
            table_key = st["table_key"].at[vslot].set(DELETED, mode="drop")
            wr = jnp.where(ok, ins_pos, self.size)
            sl = jnp.where(ok, slot, self.slots)
            st = dict(
                st,
                table_key=table_key.at[wr].set(key, mode="drop"),
                table_slot=st["table_slot"].at[wr].set(slot, mode="drop"),
                slot_key=st["slot_key"].at[sl].set(key, mode="drop"),
                last_used=st["last_used"].at[sl].set(ts, mode="drop"),
                n_live=st["n_live"] + (ok & use_free),
                n_evicted=st["n_evicted"] + evicting,
                n_failed=st["n_failed"] + ~ok,
            )
            tch = tch.at[sl].set(True, mode="drop")
            return st, tch, jnp.where(ok, slot, -1), ok

        return jax.lax.cond(found, on_found, on_miss, state, touched)

    def admit_heads(self, state: PyTree, keys, tss, head_mask, *,
                    instrument: bool = False):
        """Chunk-wide find-or-allocate for the segment-head keys (the bulk
        counterpart of :meth:`admit_row`); returns ``(state, slots, new)``
        with (C,) per-row slots (-1 off-head / failed) and new-key flags.

        ONE vectorized lookup resolves every already-admitted head; a
        ``lax.cond`` then skips allocation entirely when the chunk has no
        new keys (the steady-state fast path is a single recency-bump
        scatter).  Otherwise the genuinely-new heads are admitted in
        *batched rounds*: each round probes all pending keys at once,
        resolves probe-cell conflicts by scatter-min (lowest head index
        wins a cell), assigns winners consecutive slots from a candidate
        list precomputed ONCE (free slots in index order, then evictable
        slots in LRU order — never a slot held by a key of this chunk), and
        tombstones + inserts them in bulk.  Every round admits at least one
        pending head, so the while_loop runs O(probe-conflict chain) rounds
        of O(C · probes) vector work — not one sequential step per key.

        Heads whose probe window is full, or that arrive after the
        free+evictable budget is spent, fail safely (slot -1,
        ``n_failed``); which head pays for capacity exhaustion can differ
        from :meth:`admit_row`'s strict one-at-a-time order under probe
        conflicts, but the outcome is deterministic.
        """
        S, size, P = self.slots, self.size, self.probes
        keys = jnp.asarray(keys, jnp.int32)
        tss = jnp.asarray(tss, jnp.float32)
        head_mask = jnp.asarray(head_mask, bool)
        C = int(keys.shape[0])
        idx_c = jnp.arange(C, dtype=jnp.int32)

        slot0, found = self.lookup(state, jnp.where(head_mask, keys, EMPTY))
        found_scat = jnp.where(found, slot0, S)
        # recency bump for every already-admitted head (one scatter)
        state = dict(
            state,
            last_used=state["last_used"].at[found_scat].set(tss, mode="drop"),
        )
        pending0 = head_mask & ~found

        def hits_only(st):
            if instrument:
                jax.debug.callback(_count_admission, "fast")
            return st, slot0, jnp.zeros((C,), bool)

        def with_admission(st):
            if instrument:
                jax.debug.callback(_count_admission, "slow")
            touched = jnp.zeros((S,), bool).at[found_scat].set(
                True, mode="drop"
            )
            live = st["slot_key"] != EMPTY
            free_slots = ~live
            evictable = live & ~touched & jnp.isfinite(st["last_used"])
            klass = jnp.where(
                free_slots, 0, jnp.where(evictable, 1, 2)
            ).astype(jnp.int32)
            order_key = jnp.where(
                free_slots,
                jnp.arange(S, dtype=jnp.float32),
                st["last_used"],
            )
            cand = jnp.lexsort((order_key, klass)).astype(jnp.int32)
            n_avail = (klass < 2).sum(dtype=jnp.int32)
            n_free0 = free_slots.sum(dtype=jnp.int32)
            pos_all = jax.vmap(self._probe_pos)(keys)  # (C, P)

            def round_body(carry):
                st, pending, slots, new, consumed = carry
                tk = st["table_key"][pos_all]
                empty = tk == EMPTY
                free = empty | (tk == DELETED)
                has_cell = free.any(axis=1)
                ins_j = jnp.argmax(free, axis=1)
                ins_pos = jnp.take_along_axis(
                    pos_all, ins_j[:, None], axis=1
                )[:, 0]
                ins_ok = pending & has_cell
                fail_now = pending & ~has_cell
                # conflict resolution: lowest head index wins each cell
                claims = jnp.full((size,), C, jnp.int32).at[
                    jnp.where(ins_ok, ins_pos, size)
                ].min(idx_c, mode="drop")
                win = ins_ok & (claims[ins_pos] == idx_c)
                rank = jnp.cumsum(win.astype(jnp.int32)) - 1
                cand_idx = consumed + jnp.where(win, rank, 0)
                alloc_ok = win & (cand_idx < n_avail)
                cap_fail = win & ~(cand_idx < n_avail)
                slot = cand[jnp.clip(cand_idx, 0, S - 1)]
                evicting = alloc_ok & (cand_idx >= n_free0)
                # tombstone the evicted tenants' table entries (each live
                # key holds exactly one entry, so victim writes never clash)
                old_key = st["slot_key"][slot]
                vpos = jax.vmap(self._probe_pos)(old_key)
                vtk = st["table_key"][vpos]
                vempty = vtk == EMPTY
                vbefore = jnp.cumsum(vempty.astype(jnp.int32), axis=1) - vempty
                vhit = (vtk == old_key[:, None]) & (vbefore == 0)
                vdst = jnp.where(
                    evicting & vhit.any(axis=1),
                    jnp.take_along_axis(
                        vpos, jnp.argmax(vhit, axis=1)[:, None], axis=1
                    )[:, 0],
                    size,
                )
                table_key = st["table_key"].at[vdst].set(DELETED, mode="drop")
                wdst = jnp.where(alloc_ok, ins_pos, size)
                sdst = jnp.where(alloc_ok, slot, S)
                st = dict(
                    st,
                    table_key=table_key.at[wdst].set(keys, mode="drop"),
                    table_slot=st["table_slot"].at[wdst].set(slot, mode="drop"),
                    slot_key=st["slot_key"].at[sdst].set(keys, mode="drop"),
                    last_used=st["last_used"].at[sdst].set(tss, mode="drop"),
                    n_live=st["n_live"]
                    + (alloc_ok & ~evicting).sum(dtype=jnp.int32),
                    n_evicted=st["n_evicted"] + evicting.sum(dtype=jnp.int32),
                    n_failed=st["n_failed"]
                    + (fail_now | cap_fail).sum(dtype=jnp.int32),
                )
                return (
                    st,
                    pending & ~(alloc_ok | fail_now | cap_fail),
                    jnp.where(alloc_ok, slot, slots),
                    new | alloc_ok,
                    consumed + alloc_ok.sum(dtype=jnp.int32),
                )

            st, _, slots, new, _ = jax.lax.while_loop(
                lambda c: c[1].any(),
                round_body,
                (st, pending0, slot0, jnp.zeros((C,), bool), jnp.int32(0)),
            )
            return st, slots, new

        return jax.lax.cond(pending0.any(), with_admission, hits_only, state)

    def expire(self, state: PyTree, now, ttl) -> tuple:
        """Free every slot idle longer than ``ttl``; returns
        ``(state, expired)`` with the (slots,) expiry mask (vectorized)."""
        now = jnp.asarray(now, jnp.float32)
        live = state["slot_key"] != EMPTY
        expired = live & (now - state["last_used"] > jnp.asarray(ttl, jnp.float32))
        te_slot = jnp.clip(state["table_slot"], 0, self.slots - 1)
        kill = (state["table_key"] >= 0) & expired[te_slot]
        state = dict(
            state,
            table_key=jnp.where(kill, DELETED, state["table_key"]),
            slot_key=jnp.where(expired, EMPTY, state["slot_key"]),
            last_used=jnp.where(expired, -jnp.inf, state["last_used"]),
            n_live=state["n_live"] - expired.sum(dtype=jnp.int32),
            n_evicted=state["n_evicted"] + expired.sum(dtype=jnp.int32),
        )
        return state, expired


# ---------------------------------------------------------------------------
# The keyed store
# ---------------------------------------------------------------------------


class KeyedWindowStore:
    """``slots`` independent per-key count windows as stacked carry lanes.

    State layout (SoA, one leading slot axis everywhere):

      * ``carry``  (slots, window-1, ...) — per-slot warm-carry tails
        (entry t = suffix fold of the slot's last ``window-1-t`` elements,
        front-truncated; the exact representation of
        :mod:`repro.core.swag_base`'s carry protocol);
      * ``last``   (slots, ...)           — the slot's latest window
        aggregate (what :meth:`query` serves);
      * ``n_seen`` (slots,)               — elements ever folded per slot;
      * ``dir``                           — the :class:`KeyDirectory` state;
      * ``tick``   ()                     — default recency clock;
      * ``carry_ts`` (slots, window-1)    — HORIZON MODE ONLY: lane t holds
        the timestamp of the slot's ``window-1-t``-th-from-last element
        (``-inf`` where that element does not exist yet).

    ``horizon=`` switches the store from count windows to true EVENT-TIME
    windows: row j's output folds its key's elements with timestamp
    ``> ts_j - horizon`` (still capped at the last ``window`` elements —
    ``window`` becomes the static per-key capacity).  Expiry is watermark-
    driven and READ-side: the warm-prefix gather selects carry lane
    ``max(p, t*)`` where ``t* = #{lane_ts <= ts_j - horizon}`` counts the
    expired history lanes, and the in-chunk span start comes from the
    per-segment finger search :func:`repro.core.ooo_index
    .seg_bounded_search` — no per-slot sweep ever runs, the one-gather/
    one-scatter carry refresh (and its donation) is preserved, with
    ``carry_ts`` refreshed by the same shifted-lane/from-chunk ladder as
    ``carry``.  Precondition: each key's timestamps must be non-decreasing
    in arrival order (chain the store behind :class:`repro.core.event_time
    .EventTimeChunkedStream`, whose released rows are globally sorted).
    ``horizon=None`` keeps the count path byte-identical.

    :meth:`update_chunk` is pure (jit it, or use :class:`KeyedChunkedStream`
    which caches the jit per chunk length).
    """

    def __init__(
        self,
        monoid: Monoid,
        window: int,
        slots: int,
        *,
        dir_factor: int = 2,
        probes: int = 32,
        ttl: Optional[float] = None,
        horizon: Optional[float] = None,
        use_inverse: Optional[bool] = None,
        use_seg_kernel: Optional[bool] = None,
        instrument_admission: bool = False,
        instrument_combines: bool = False,
        obs: Optional[Any] = None,
    ):
        # obs: a repro.obs.registry.ObsConfig — the one observability gate.
        # Disabled (or None) contributes NOTHING to the traced computation
        # (tests assert jaxpr equality); enabled folds its instrument flags
        # into the jit-visible hooks below.
        if obs is not None and obs.enabled:
            instrument_admission = instrument_admission or obs.instrument_admission
            instrument_combines = instrument_combines or obs.instrument_combines
        self.obs = obs if (obs is not None and obs.enabled) else None
        self.monoid = monoid
        self.window = int(window)
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.h = self.window - 1
        self.slots = int(slots)
        self.directory = KeyDirectory(slots, dir_factor=dir_factor, probes=probes)
        self.ttl = ttl
        if horizon is not None and float(horizon) <= 0:
            raise ValueError(f"horizon must be > 0, got {horizon}")
        self.horizon = None if horizon is None else float(horizon)
        if use_inverse is None:
            use_inverse = monoid.invertible and monoid.commutative
        self.use_inverse = bool(use_inverse)
        # seg_scan Pallas kernels: None = auto (scalar-monoid gate AND TPU
        # backend), True = force (raises for unsupported monoids), False =
        # always the lax associative_scan path.
        self.use_seg_kernel = use_seg_kernel
        self.instrument_admission = bool(instrument_admission)
        # instrument_combines routes every sweep ⊗ through
        # ``COMBINE_COUNTS["keyed"]`` — forces the lax scan path (the Pallas
        # kernel cannot host the debug callback).
        self.instrument_combines = bool(instrument_combines)

    def _kernel_op(self) -> Optional[str]:
        """The seg_scan kernel op for this store, or None for the lax path."""
        use = self.use_seg_kernel
        if self.instrument_combines or not (use is None or use):
            return None
        from repro.kernels.ops_registry import op_for_monoid

        op = op_for_monoid(self.monoid)
        if use is None:
            return op if (op is not None
                          and jax.default_backend() == "tpu") else None
        if op is None:
            raise ValueError(
                "use_seg_kernel=True needs a scalar-op monoid "
                f"(got {getattr(self.monoid, 'name', self.monoid)!r})"
            )
        return op

    def _sweep_monoid(self) -> Monoid:
        return (counting_combines(self.monoid, "keyed")
                if self.instrument_combines else self.monoid)

    def _seg_scan(self, end_flags, lifted: PyTree) -> PyTree:
        """Segmented suffix scan over the sorted chunk — the fused
        ``kernels/seg_scan`` Pallas kernel when the monoid passes the
        scalar-monoid structural gate (auto: only on TPU; ``interpret``
        under the kernel keeps CPU tests exact), else the generic
        :func:`seg_suffix_scan` lax fallback."""
        op = self._kernel_op()
        if op is not None:
            from repro.kernels.seg_scan.ops import seg_suffix_scan_op

            leaves, treedef = jax.tree.flatten(lifted)
            out = seg_suffix_scan_op(leaves[0], end_flags, op)
            return jax.tree.unflatten(treedef, [out])
        return seg_suffix_scan(self._sweep_monoid(), end_flags, lifted)

    def _seg_pscan(self, start_flags, lifted: PyTree) -> PyTree:
        """Segmented PREFIX scan — the mirror of :meth:`_seg_scan`, behind
        the same kernel gate (``kernels/seg_scan``'s prefix variant on TPU,
        :func:`seg_prefix_scan` lax fallback)."""
        op = self._kernel_op()
        if op is not None:
            from repro.kernels.seg_scan.ops import seg_prefix_scan_op

            leaves, treedef = jax.tree.flatten(lifted)
            out = seg_prefix_scan_op(leaves[0], start_flags, op)
            return jax.tree.unflatten(treedef, [out])
        return seg_prefix_scan(self._sweep_monoid(), start_flags, lifted)

    # -- state -------------------------------------------------------------

    def init_state(self) -> PyTree:
        ident = self.monoid.identity()

        def fill(lead: tuple):
            return jax.tree.map(
                lambda i: jnp.broadcast_to(
                    jnp.asarray(i), lead + jnp.asarray(i).shape
                ).copy(),
                ident,
            )

        state = {
            "dir": self.directory.init(),
            "carry": fill((self.slots, self.h)),
            "last": fill((self.slots,)),
            "n_seen": jnp.zeros((self.slots,), jnp.int32),
            "tick": jnp.zeros((), jnp.float32),
            "n_dropped": jnp.zeros((), jnp.int32),
        }
        if self.horizon is not None:
            # -inf = "no such element yet": those lanes always count as
            # expired in the read-side lane selection, so a cold key's
            # front-truncated lanes are skipped without any extra mask
            state["carry_ts"] = jnp.full(
                (self.slots, self.h), -jnp.inf, jnp.float32
            )
        return state

    def query(self, state: PyTree, keys) -> tuple:
        """Latest window aggregate per key: ``(aggs, found)`` — identity for
        unknown keys.  Pure; vectorized over (C,) keys."""
        keys = jnp.asarray(keys, jnp.int32)
        slot, found = self.directory.lookup(state["dir"], keys)
        aggs = _take0(state["last"], jnp.clip(slot, 0, self.slots - 1))
        return _mask_tree(aggs, found, self.monoid.identity()), found

    def counters(self, state: PyTree) -> dict:
        """Store health counters as DEVICE scalars (no host sync — the obs
        registry batches the transfer at scrape; callers reading directly
        should ``jax.device_get`` the dict)."""
        d = state["dir"]
        return {
            "n_live": d["n_live"],
            "n_evicted": d["n_evicted"],
            "n_failed": d["n_failed"],
            "n_dropped": state["n_dropped"],
        }

    def expire(self, state: PyTree, now=None) -> PyTree:
        """TTL sweep: evict every key idle longer than ``ttl`` and reset its
        lanes (pure; no-op when ``ttl`` is None)."""
        if self.ttl is None:
            return state
        now = state["tick"] if now is None else jnp.asarray(now, jnp.float32)
        dir_state, expired = self.directory.expire(state["dir"], now, self.ttl)
        state = dict(
            state,
            dir=dir_state,
            carry=self._reset_lanes(state["carry"], expired),
            last=self._reset_lanes(state["last"], expired),
            n_seen=jnp.where(expired, 0, state["n_seen"]),
        )
        if self.horizon is not None:
            state["carry_ts"] = jnp.where(
                expired[:, None], -jnp.inf, state["carry_ts"]
            )
        return state

    def _reset_lanes(self, lanes: PyTree, mask) -> PyTree:
        ident = self.monoid.identity()
        return jax.tree.map(
            lambda a, i: jnp.where(
                mask.reshape((self.slots,) + (1,) * (a.ndim - 1)),
                jnp.asarray(i, a.dtype),
                a,
            ),
            lanes,
            ident,
        )

    # -- the fused chunk update --------------------------------------------

    def update_chunk(self, state: PyTree, keys, xs, ts=None, mask=None):
        """One mixed-key chunk: ``keys`` (C,), ``xs`` (C, ...) raw inputs.

        Returns ``(state, ys, info)``: ``ys`` (C, ...) per-row window
        aggregates (pre-``lower``) aligned with the inputs — row j is the
        fold of the last ``min(window, seen)`` elements OF ROW j'S KEY —
        and ``info`` with per-row ``slots`` / ``dropped`` and the admission
        counters.  ``ts`` (scalar or (C,)) feeds directory recency (and the
        TTL clock); defaults to an internal tick.  ``mask`` (C,) pads a
        ragged final chunk (False rows are ignored and emit identities).

        In ``horizon=`` mode row j instead folds its key's elements with
        timestamp ``> ts_j - horizon`` (capped at the last ``window``),
        where ``ts`` doubles as the event time.  PRECONDITION: each key's
        timestamps must be non-decreasing in arrival order (feed released
        rows of an :class:`repro.core.event_time.EventTimeChunkedStream`);
        violating it silently returns wrong folds, exactly like violating
        the flip invariant.
        """
        m = self.monoid
        ident = m.identity()
        S, W, h = self.slots, self.window, self.h
        keys = jnp.asarray(keys, jnp.int32)
        C = int(keys.shape[0])
        valid = jnp.ones((C,), bool) if mask is None else jnp.asarray(mask, bool)
        tick = state["tick"] + 1.0
        if ts is None:
            ts_row = jnp.broadcast_to(tick, (C,))
        else:
            ts_row = jnp.broadcast_to(jnp.asarray(ts, jnp.float32), (C,))

        # -- stable sort by key: segments, arrival order kept within key --
        order = jnp.argsort(jnp.where(valid, keys, _KEY_SENTINEL), stable=True)
        inv = jnp.argsort(order)
        ks = keys[order]
        vs = valid[order]
        tss = ts_row[order]
        xss = _take0(xs, order)
        idx = jnp.arange(C, dtype=jnp.int32)
        prev = jnp.concatenate([ks[:1] - 1, ks[:-1]])
        seg_head = vs & ((idx == 0) | (ks != prev))
        nxt_head = jnp.concatenate([seg_head[1:], jnp.ones((1,), bool)])
        nxt_invalid = jnp.concatenate([~vs[1:], jnp.ones((1,), bool)])
        seg_end = vs & (nxt_head | nxt_invalid)
        sid = jnp.clip(jnp.cumsum(seg_head.astype(jnp.int32)) - 1, 0, C - 1)

        # -- directory admission: one vectorized pass over segment HEADS --
        dir_state, head_slots, new_heads = self.directory.admit_heads(
            state["dir"],
            ks,
            tss,
            seg_head,
            instrument=self.instrument_admission,
        )

        # -- per-segment fields broadcast to rows --------------------------
        scat = jnp.where(seg_head, sid, C)
        head_pos = jnp.zeros((C,), jnp.int32).at[scat].set(idx, mode="drop")
        slot_by_seg = jnp.full((C,), -1, jnp.int32).at[scat].set(
            head_slots, mode="drop"
        )
        new_by_seg = jnp.zeros((C,), bool).at[scat].set(new_heads, mode="drop")
        end_pos = jnp.zeros((C,), jnp.int32).at[
            jnp.where(seg_end, sid, C)
        ].set(idx, mode="drop")
        a = head_pos[sid]
        b = end_pos[sid]
        slot = slot_by_seg[sid]
        row_new = new_by_seg[sid]
        row_ok = vs & (slot >= 0)
        cslot = jnp.clip(slot, 0, S - 1)
        p = idx - a  # position within the segment
        n_seg = b - a + 1

        # Reclaimed slots are handled GATHER-side: every read of a
        # newly-admitted key's old lanes is masked/ignored at the read
        # instead of a full-(slots, h) reset pass — the previous tenant's
        # values never leak, and per-chunk work stays O(C·h).  (Every
        # admitted head also lands a scatter below, so no reclaimed slot
        # keeps stale ``last``/``n_seen``.)
        #
        # All carry history comes through ONE (C, h) row gather (``crows``)
        # so the donated (slots, h) buffer has exactly two uses — that
        # gather (which feeds the scattered values) and the batched scatter
        # itself.  A second independent read (e.g. a direct warm-prefix
        # gather for ``ys``) leaves XLA unable to order the reads before
        # the in-place scatter, and copy-insertion materializes full
        # (slots, h) copies that put the K-cliff right back.

        # -- lift + intra-chunk window folds: the flip sweep ---------------
        # Per-row spans [max(a, j-W+1), j] have monotone starts AND ends
        # within each segment — the flip invariant
        # (:mod:`repro.core.event_time` module docstring).  Cutting each
        # segment into W-aligned blocks (boundary at p % W == 0) makes every
        # span exact as suffix-scan-left-of-boundary ⊗
        # prefix-scan-right-of-boundary: with p = qW + r, the span start
        # max(a, j-W+1) lands at the block start a+qW when r = W-1 or p < W
        # (prefix alone suffices) and strictly inside block q-1 otherwise
        # (its block-suffix ends exactly at the boundary).  O(1) ⊗/row —
        # replaces the old O(log W) per-row doubling range fold.
        lifted = _mask_tree(jax.vmap(m.lift)(xss), row_ok, ident)
        m_sweep = self._sweep_monoid()
        if self.horizon is not None:
            # Event-time span starts: within a segment the in-horizon rows
            # form a suffix (per-key ts non-decreasing), found by the
            # bounded finger search — row j's chunk span is
            # [max(count start, s0_j), j].  Starts stay non-decreasing
            # globally (s0 is monotone within a segment, segments are
            # disjoint and invalid rows sort last with starts = idx + 1)
            # and ends = idx is strictly increasing, so the generic flip
            # sweep applies; the W-aligned block trick below does not (its
            # exactness needs starts == max(a, j - W + 1) precisely).
            thr = tss - jnp.asarray(self.horizon, tss.dtype)
            s0 = ooo_index.seg_bounded_search(tss, a, idx, thr)
            starts = jnp.where(
                row_ok,
                jnp.maximum(jnp.maximum(a, idx - (W - 1)), s0),
                idx + 1,
            )
            if self.use_inverse:
                intra = range_fold_invertible(m_sweep, lifted, starts, idx)
            else:
                intra = flip_range_fold(m_sweep, lifted, starts, idx)
        elif self.use_inverse:
            starts = jnp.where(row_ok, jnp.maximum(a, idx - (W - 1)), idx + 1)
            intra = range_fold_invertible(m_sweep, lifted, starts, idx)
        else:
            starts = jnp.where(row_ok, jnp.maximum(a, idx - (W - 1)), idx + 1)
            # invalid rows are their own single-row segments (their lifted
            # rows are already identity), so garbage never crosses them
            bstart = seg_head | ~vs | (row_ok & (p % W == 0))
            bpref = self._seg_pscan(bstart, lifted)
            if W > C:
                # a chunk can't wrap a block: every span starts at its
                # segment head, the prefix scan alone is exact
                intra = bpref
            else:
                bend = seg_end | ~vs | (row_ok & (p % W == W - 1))
                bsuf = self._seg_scan(bend, lifted)
                cellstart = jax.lax.associative_scan(
                    jnp.maximum, jnp.where(bstart, idx, 0)
                )
                left = _take0(bsuf, jnp.clip(starts, 0, C - 1))
                both = m_sweep.combine(left, bpref)  # older operand LEFT
                intra = _where_rows(starts >= cellstart, bpref, both)

        if h > 0:
            # the ONE donated-buffer read: a contiguous (C, h) row gather;
            # the refresh's shifted lanes t + n_seg and the warm-prefix lane
            # min(p, h-1) are take_along_axis views of the gathered copy.
            # (A single fused (C, h+1) 2-D lane gather straight off the
            # donated buffer benchmarked ~15% slower — random (row, lane)
            # addressing loses to contiguous row copies; two independent
            # reads of the donated buffer break in-place donation outright.)
            # row_new rows' garbage is masked at every consumer (the
            # need_carry select below / the refresh's ``old_m`` mask).
            t_ax = jnp.arange(h, dtype=jnp.int32)
            old_t = jnp.clip(t_ax[None, :] + n_seg[:, None], 0, h - 1)
            crows = jax.tree.map(lambda cl: cl[cslot], state["carry"])
            old = jax.tree.map(
                lambda cr: jnp.take_along_axis(
                    cr, old_t.reshape((C, h) + (1,) * (cr.ndim - 2)), axis=1
                ),
                crows,
            )
            pidx = jnp.clip(p, 0, h - 1)[:, None]
            if self.horizon is not None:
                # the ONE carry_ts read (the ts mirror of ``crows``): t* =
                # #{lane_ts <= thr} is the first lane whose whole suffix is
                # in-horizon (-inf "absent" lanes always count as expired),
                # and the count cap composes with the horizon cap as
                # lane max(p, t*) — expiry is purely read-side
                ts_rows = state["carry_ts"][cslot]
                thr_col = thr[:, None]
                tstar = jnp.sum(
                    (ts_rows <= thr_col).astype(jnp.int32), axis=1
                )
                lane = jnp.maximum(pidx, jnp.clip(tstar, 0, h - 1)[:, None])
            else:
                lane = pidx
            cvals = jax.tree.map(
                lambda cr: jnp.take_along_axis(
                    cr, lane.reshape((C, 1) + (1,) * (cr.ndim - 2)), axis=1
                )[:, 0],
                crows,
            )

        # -- warm prefix: windows reaching into the key's history ----------
        if h > 0:
            need_carry = row_ok & (p < h) & ~row_new
            if self.horizon is not None:
                # history contributes only when the whole chunk span so far
                # is itself in-horizon (s0 == a; history is older than any
                # chunk row) and at least one history lane survives
                need_carry &= (s0 == a) & (tstar < h)
            warmed = m.combine(cvals, intra)
            ys = _where_rows(need_carry, warmed, intra)
        else:
            ys = intra
        ys = _mask_tree(ys, row_ok, ident)

        # -- refreshed carries: ONE batched (C, h) scatter -----------------
        # Entry t of a head's refreshed carry folds the slot's trailing
        # h - t elements: a pure segment suffix when that fits in the chunk
        # (``from_chunk``), else surviving old-carry lane t + n_seg extended
        # by the whole-segment fold.  (A "fused" two-gather variant with the
        # whole-segment fold folded into the from_chunk gather via index
        # clamping benchmarked ~2.3× SLOWER here: the broadcast ``whole``
        # fuses into the select for free, a second data-dependent (C, h)
        # gather does not.)
        if h > 0:
            ss = self._seg_scan(seg_end, lifted)
            old_m = _mask_tree(old, ~row_new, ident)
            whole = jax.tree.map(
                lambda s_: jnp.broadcast_to(
                    s_[jnp.clip(a, 0, C - 1)][:, None],
                    (C, h) + s_.shape[1:],
                ),
                ss,
            )
            carried = m.combine(old_m, whole)
            # Static lane split: entry t folds need = h - t trailing
            # elements and a C-row chunk holds n_seg <= C of them, so only
            # the last min(h, C) lanes can ever take the ``from_chunk``
            # branch — the data-dependent gather + select is skipped
            # entirely on the h - min(h, C) leading lanes (3/4 of the
            # refresh at W=4096, C=1024).
            hc = min(h, C)
            h0 = h - hc
            need = h - t_ax[h0:]  # (hc,) trailing elements entry t folds
            in_chunk = need[None, :] <= n_seg[:, None]  # (C, hc)
            src = jnp.clip(b[:, None] - need[None, :] + 1, 0, C - 1)
            from_chunk = jax.tree.map(lambda s_: s_[src], ss)
            new_tail = jax.tree.map(
                lambda fc, cd: jnp.where(_bc(in_chunk, fc), fc, cd[:, h0:]),
                from_chunk,
                carried,
            )
            head_scat = jnp.where(seg_head & row_ok, slot, S)
            if h0:
                # two scatters into disjoint lane ranges instead of a
                # concatenated (C, h) update: the leading-lane write streams
                # ``carried`` directly, no 16MB concat materialization
                carry1 = jax.tree.map(
                    lambda cl, cd: cl.at[head_scat, :h0].set(
                        cd[:, :h0], mode="drop"
                    ),
                    state["carry"],
                    carried,
                )
                carry1 = jax.tree.map(
                    lambda cl, nt: cl.at[head_scat, h0:].set(
                        nt, mode="drop"
                    ),
                    carry1,
                    new_tail,
                )
            else:
                carry1 = jax.tree.map(
                    lambda cl, nt: cl.at[head_scat].set(nt, mode="drop"),
                    state["carry"],
                    new_tail,
                )
            if self.horizon is not None:
                # carry_ts rides the SAME ladder as carry: shifted old lane
                # t + n_seg (-inf for new heads) on lanes the chunk can't
                # fill, ``tss[src]`` where the trailing suffix fits — one
                # extra lane view of the already-gathered ts_rows and one
                # scatter, so the donation discipline holds for carry_ts too
                ts_old = jnp.take_along_axis(ts_rows, old_t, axis=1)
                ts_old = jnp.where(row_new[:, None], -jnp.inf, ts_old)
                ts_tail = jnp.where(in_chunk, tss[src], ts_old[:, h0:])
                if h0:
                    cts1 = state["carry_ts"].at[head_scat, :h0].set(
                        ts_old[:, :h0], mode="drop"
                    )
                    cts1 = cts1.at[head_scat, h0:].set(ts_tail, mode="drop")
                else:
                    cts1 = state["carry_ts"].at[head_scat].set(
                        ts_tail, mode="drop"
                    )
        else:
            head_scat = jnp.where(seg_head & row_ok, slot, S)
            carry1 = state["carry"]
            if self.horizon is not None:
                cts1 = state["carry_ts"]

        # -- per-slot latest aggregate + seen counts -----------------------
        y_end = _take0(ys, jnp.clip(b, 0, C - 1))
        last1 = jax.tree.map(
            lambda ll, v: ll.at[head_scat].set(v, mode="drop"),
            state["last"],
            y_end,
        )
        n_seen1 = state["n_seen"].at[head_scat].set(
            jnp.where(row_new, 0, state["n_seen"][cslot]) + n_seg,
            mode="drop",
        )

        dropped_sorted = vs & ~row_ok
        state = dict(
            state,
            dir=dir_state,
            carry=carry1,
            last=last1,
            n_seen=n_seen1,
            tick=jnp.maximum(tick, jnp.max(jnp.where(vs, tss, -jnp.inf))),
            n_dropped=state["n_dropped"] + dropped_sorted.sum(dtype=jnp.int32),
        )
        if self.horizon is not None:
            state["carry_ts"] = cts1
        if self.ttl is not None:
            state = self.expire(state)
        info = {
            "slots": slot[inv],
            "dropped": dropped_sorted[inv],
            "n_live": dir_state["n_live"],
            "n_evicted": dir_state["n_evicted"],
        }
        return state, _take0(ys, inv), info

    # -- SWAG interop (the carry protocol across the key dimension) --------

    def export_states(self, state: PyTree, keys, algo, capacity: Optional[int] = None):
        """Per-key live SWAG states built from the stored carries via
        ``carry_to_state`` — hand a key's window to any per-element
        algorithm.  Returns ``(states, found)`` with a leading key axis."""
        capacity = capacity or self.window + 1
        keys = jnp.asarray(keys, jnp.int32)
        slot, found = self.directory.lookup(state["dir"], keys)
        carries = jax.tree.map(
            lambda cl: cl[jnp.clip(slot, 0, self.slots - 1)], state["carry"]
        )
        states = jax.vmap(
            lambda c: swag_base.carry_to_state(algo, self.monoid, c, capacity)
        )(carries)
        return states, found

    def adopt_states(self, state: PyTree, keys, swag_states, algo) -> PyTree:
        """Admit ``keys`` and seed their lanes from live per-element SWAG
        states (``state_to_carry``) — warm-start the store from existing
        windows.  Keys beyond the slot budget are dropped (directory
        ``n_failed``)."""
        keys = jnp.asarray(keys, jnp.int32)
        carries = jax.vmap(
            lambda s: swag_base.state_to_carry(algo, self.monoid, s, self.window)
        )(swag_states)
        lasts = jax.vmap(lambda s: algo.query(self.monoid, s))(swag_states)
        counts = jax.vmap(algo.size)(swag_states).astype(jnp.int32)
        tick = state["tick"] + 1.0

        def body(i, acc):
            dir_state, touched, slots = acc
            dir_state, touched, slot, _ = self.directory.admit_row(
                dir_state, touched, keys[i], tick
            )
            return dir_state, touched, slots.at[i].set(slot)

        n = int(keys.shape[0])
        dir_state, _, slots = jax.lax.fori_loop(
            0,
            n,
            body,
            (
                state["dir"],
                jnp.zeros((self.slots,), bool),
                jnp.full((n,), -1, jnp.int32),
            ),
        )
        scat = jnp.where(slots >= 0, slots, self.slots)
        state = dict(
            state,
            dir=dir_state,
            carry=jax.tree.map(
                lambda cl, cv: cl.at[scat].set(cv, mode="drop"),
                state["carry"],
                carries,
            ),
            last=jax.tree.map(
                lambda ll, lv: ll.at[scat].set(lv, mode="drop"),
                state["last"],
                lasts,
            ),
            n_seen=state["n_seen"].at[scat].set(counts, mode="drop"),
            tick=tick,
        )
        if self.horizon is not None:
            # per-element timestamps don't survive the carry protocol:
            # adopted history is stamped "arrived now", so it expires
            # all-or-nothing once ``tick`` leaves the horizon
            state["carry_ts"] = state["carry_ts"].at[scat].set(
                tick, mode="drop"
            )
        return state


# ---------------------------------------------------------------------------
# Chunk-at-a-time driver
# ---------------------------------------------------------------------------


class KeyedChunkedStream:
    """Chunked driver over a :class:`KeyedWindowStore` (jit per chunk shape,
    ragged-final-chunk padding) — the keyed counterpart of
    :class:`repro.core.chunked.ChunkedStream`.

    Usage::

        eng = KeyedChunkedStream(monoid, window=256, slots=4096, chunk=4096)
        state = eng.init_state()
        state, ys, info = eng.process_chunk(state, keys, xs)   # (C,) rows
        state, ys = eng.stream(keys, xs)                       # whole stream

    ``donate=True`` (the default) donates the state buffers into the jitted
    update, making the (slots, h) carry scatter in-place — per-chunk cost
    stays O(chunk·h) even when the resident state is huge.  The flip side:
    a state passed to :meth:`process_chunk` is CONSUMED (its buffers are
    deleted); always continue from the returned state, and pass
    ``donate=False`` when external references to the state must stay live
    (e.g. a checkpoint payload holding the same arrays).
    """

    def __init__(
        self,
        monoid: Monoid,
        window: int,
        slots: int,
        chunk: Optional[int] = None,
        *,
        donate: bool = True,
        **store_kwargs,
    ):
        self.store = KeyedWindowStore(monoid, window, slots, **store_kwargs)
        self.monoid = monoid
        self.window = self.store.window
        self.chunk = int(chunk) if chunk is not None else 1024
        self.donate = bool(donate)
        self._jitted: dict = {}
        self._full_masks: dict = {}
        # obs plumbing (all None/zero when the store's ObsConfig is off —
        # process_chunk then takes the exact pre-obs code path)
        self._obs = self.store.obs
        self._obs_snap: Optional[dict] = None
        self._obs_chunks = 0
        self._obs_rows = 0
        self._trace_stages: dict = {}
        # ONE async dispatch for the per-chunk scalar snapshot (4 separate
        # jnp.copy calls measured ~10% off keyed throughput; fused they
        # disappear into dispatch noise)
        self._snap_jit = jax.jit(lambda t: jax.tree.map(jnp.copy, t))

    def init_state(self) -> PyTree:
        return self.store.init_state()

    def _full_mask(self, C: int):
        m = self._full_masks.get(C)
        if m is None:
            m = self._full_masks[C] = jnp.ones((C,), bool)
        return m

    def process_chunk(self, state, keys, xs, ts=None, mask=None):
        """Jitted :meth:`KeyedWindowStore.update_chunk` (cached per chunk
        length and ts presence)."""
        C = int(jnp.shape(jnp.asarray(keys))[0])
        if mask is None:
            mask = self._full_mask(C)
        key = (C, ts is not None)
        fn = self._jitted.get(key)
        if fn is None:
            donate = dict(donate_argnums=(0,)) if self.donate else {}
            if ts is None:
                fn = jax.jit(
                    lambda st, k, x, mk: self.store.update_chunk(
                        st, k, x, None, mk
                    ),
                    **donate,
                )
            else:
                fn = jax.jit(self.store.update_chunk, **donate)
            self._jitted[key] = fn
        if self._obs is None:
            if ts is None:
                return fn(state, keys, xs, mask)
            return fn(state, keys, xs, ts, mask)
        return self._process_chunk_obs(fn, state, keys, xs, ts, mask, C)

    def _process_chunk_obs(self, fn, state, keys, xs, ts, mask, C):
        """The instrumented dispatch: optional trace span around the call
        (synced so the duration is real, with roofline-apportioned stage
        sub-spans), then tiny-scalar snapshot copies for scrape collectors.
        The copies matter: with donation on, the returned state's buffers
        die inside the NEXT process_chunk — a collector reading them later
        would hit deleted buffers."""
        tr = self._obs.trace
        if tr is not None:
            with tr.span("keyed.update_chunk", args={"chunk": C}) as sa:
                t0 = tr._now_us()
                out = (fn(state, keys, xs, mask) if ts is None
                       else fn(state, keys, xs, ts, mask))
                jax.block_until_ready(out[1])
                dur = tr._now_us() - t0
            stages = self._trace_stages.get(C)
            if stages is None:
                from repro.roofline.analysis import keyed_update_cost

                stages = self._trace_stages[C] = keyed_update_cost(
                    C, self.window
                )["stages"]
            tr.add_stage_spans("keyed.update_chunk", t0, dur, stages, tid=1)
        else:
            out = (fn(state, keys, xs, mask) if ts is None
                   else fn(state, keys, xs, ts, mask))
        st, _, info = out
        self._obs_chunks += 1
        self._obs_rows += C
        self._obs_snap = self._snap_jit({
            "n_live": info["n_live"],
            "n_evicted": info["n_evicted"],
            "n_failed": st["dir"]["n_failed"],
            "n_dropped": st["n_dropped"],
        })
        return out

    def attach_obs(self, registry, *, prefix: str = "repro_keyed"):
        """Register this stream's scrape collector: live/evicted/failed/
        dropped from the latest chunk's snapshot plus host-side chunk/row
        throughput counters.  Admission-branch counters ride along globally
        via ``obs.counters.admission`` (adopted by the default registry)."""
        series = {
            "n_live": (f"{prefix}_live_keys", "gauge",
                       "keys currently resident in the slot pool"),
            "n_evicted": (f"{prefix}_evictions_total", "counter",
                          "LRU + TTL evictions since init"),
            "n_failed": (f"{prefix}_admission_failed_total", "counter",
                         "admissions abandoned after probe/victim rounds"),
            "n_dropped": (f"{prefix}_dropped_rows_total", "counter",
                          "chunk rows dropped by failed admission"),
        }
        for key, (name, typ, help) in series.items():
            registry.describe(name, typ, help)
        registry.describe(f"{prefix}_chunks_total", "counter",
                          "update_chunk dispatches")
        registry.describe(f"{prefix}_rows_total", "counter",
                          "chunk rows ingested (incl. padding)")

        def collect():
            out = {
                f"{prefix}_chunks_total": self._obs_chunks,
                f"{prefix}_rows_total": self._obs_rows,
            }
            if self._obs_snap is not None:
                for key, (name, _, _) in series.items():
                    out[name] = self._obs_snap[key]
            return out

        registry.register_collector(collect)
        return collect

    def query(self, state, keys):
        return self.store.query(state, keys)

    def stream(self, keys, xs, *, ts=None, state: Optional[PyTree] = None):
        """Whole-stream ingest: (T,) keys / (T, ...) values chunk-by-chunk;
        returns ``(state, (T, ...) per-row window aggregates)``.  The ragged
        last chunk is padded under a mask so every chunk shares one
        compilation."""
        keys = jnp.asarray(keys, jnp.int32)
        T = int(keys.shape[0])
        if state is None:
            state = self.init_state()
        if T == 0:
            return state, jax.vmap(self.monoid.lift)(xs)
        ys = []
        for lo in range(0, T, self.chunk):
            hi = min(lo + self.chunk, T)
            pk = keys[lo:hi]
            px = jax.tree.map(lambda a_: a_[lo:hi], xs)
            pt = None if ts is None else jnp.asarray(ts)[lo:hi]
            if hi - lo < self.chunk:
                pad = self.chunk - (hi - lo)
                pk = jnp.concatenate([pk, jnp.broadcast_to(pk[-1:], (pad,))])
                px = jax.tree.map(
                    lambda a_: jnp.concatenate(
                        [a_, jnp.broadcast_to(a_[-1:], (pad,) + a_.shape[1:])], 0
                    ),
                    px,
                )
                if pt is not None:
                    pt = jnp.concatenate(
                        [pt, jnp.broadcast_to(pt[-1:], (pad,))]
                    )
                mask = jnp.arange(self.chunk) < (hi - lo)
                state, y, _ = self.process_chunk(state, pk, px, pt, mask)
                y = jax.tree.map(lambda a_: a_[: hi - lo], y)
            else:
                state, y, _ = self.process_chunk(state, pk, px, pt)
            ys.append(y)
        return state, jax.tree.map(
            lambda *parts: jnp.concatenate(parts, axis=0), *ys
        )


# ---------------------------------------------------------------------------
# Device sharding of the key space
# ---------------------------------------------------------------------------


def shard_of_key(keys, n_shards: int):
    """Key → shard assignment by hash (a different hash stream than the
    directory's probe hash, so shard skew does not correlate with probe
    clustering)."""
    return (_hash_u32(jnp.asarray(keys, jnp.int32), 3) % jnp.uint32(n_shards)).astype(
        jnp.int32
    )


class ShardedKeyedStore:
    """Key-space sharding of a :class:`KeyedWindowStore` over a mesh axis.

    Every shard owns ``slots`` slots and a private directory; a chunk is
    broadcast to all shards and each masks it down to its own rows
    (``hash(key) % shards == shard_index``) — the steady state runs ZERO
    collectives (no gathers, no psums: outputs and state stay shard-local,
    stacked on the leading axis).  Partition specs come from
    :func:`repro.distributed.sharding.keyed_store_pspecs`.

    Usage::

        mesh = jax.make_mesh((R,), ("data",))
        sh = ShardedKeyedStore(monoid, window, slots_per_shard, mesh, "data")
        state = sh.init_state()                       # (R, ...)-stacked
        state, ys, owner = sh.update_chunk(state, keys, xs)
        y = ShardedKeyedStore.collect(ys, owner)      # host-side select
    """

    def __init__(
        self,
        monoid: Monoid,
        window: int,
        slots_per_shard: int,
        mesh,
        axis: str = "data",
        *,
        donate: bool = True,
        **store_kwargs,
    ):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import keyed_store_pspecs

        self.store = KeyedWindowStore(monoid, window, slots_per_shard, **store_kwargs)
        self.mesh = mesh
        self.axis = axis
        self.n_shards = mesh.shape[axis]
        self._pspecs = keyed_store_pspecs

        # two entry points: with an explicit ts chunk, and without one — the
        # latter must pass ts=None THROUGH to the store so each shard's internal
        # tick drives recency (a constant ts would freeze last_used, making
        # LRU degenerate and TTL evict actively-used keys)
        def build(has_ts):
            def local_update(st, keys, xs, *rest):
                ts_row = rest[0] if has_ts else None
                mask = rest[-1]
                idx = jax.lax.axis_index(axis)
                mine = mask & (shard_of_key(keys, self.n_shards) == idx)
                st1 = jax.tree.map(lambda a_: a_[0], st)  # drop the shard axis
                st2, ys, _info = self.store.update_chunk(
                    st1, keys, xs, ts_row, mine
                )
                return (
                    jax.tree.map(lambda a_: a_[None], st2),
                    jax.tree.map(lambda a_: a_[None], ys),
                )

            def wrapped(st, keys, xs, *rest):
                specs = jax.tree.map(lambda _: P(axis), st)
                y_spec = jax.tree.map(
                    lambda _: P(axis),
                    jax.eval_shape(lambda x: jax.vmap(monoid.lift)(x), xs),
                )
                return shard_map(
                    local_update,
                    mesh=mesh,
                    in_specs=(specs, P(), P()) + (P(),) * len(rest),
                    out_specs=(specs, y_spec),
                    # the batched-admission while_loop has no replication
                    # rule; every output is explicitly sharded anyway
                    check_rep=False,
                )(st, keys, xs, *rest)

            if donate:
                # state-in is consumed: the per-shard carry scatter runs
                # in-place (continue from the returned state only)
                return jax.jit(wrapped, donate_argnums=(0,))
            return jax.jit(wrapped)

        self._update_with_ts = build(True)
        self._update_no_ts = build(False)

    def init_state(self) -> PyTree:
        from jax.sharding import NamedSharding

        one = self.store.init_state()
        stacked = jax.tree.map(
            lambda a_: jnp.broadcast_to(a_, (self.n_shards,) + a_.shape).copy(),
            one,
        )
        specs = self._pspecs(stacked, self.axis)
        return jax.tree.map(
            lambda a_, s: jax.device_put(a_, NamedSharding(self.mesh, s)),
            stacked,
            specs,
        )

    def update_chunk(self, state, keys, xs, ts=None, mask=None):
        """Returns ``(state, ys, owner)``: ``ys`` is (shards, C, ...) with
        row j meaningful only at ``ys[owner[j], j]``; everything else is the
        identity.  ``owner`` is the (C,) shard assignment."""
        keys = jnp.asarray(keys, jnp.int32)
        C = int(keys.shape[0])
        if mask is None:
            mask = jnp.ones((C,), bool)
        if ts is None:
            state, ys = self._update_no_ts(state, keys, xs, mask)
        else:
            ts_row = jnp.broadcast_to(jnp.asarray(ts, jnp.float32), (C,))
            state, ys = self._update_with_ts(state, keys, xs, ts_row, mask)
        return state, ys, shard_of_key(keys, self.n_shards)

    @staticmethod
    def collect(ys: PyTree, owner) -> PyTree:
        """Host-side compaction of sharded outputs: pick each row from its
        owning shard (the one cross-shard data movement, OUTSIDE the steady
        state)."""
        owner = jnp.asarray(owner)
        idx = jnp.arange(owner.shape[0])
        return jax.tree.map(lambda a_: a_[owner, idx], ys)

    def counters(self, state, *, per_shard: bool = False) -> dict:
        """MESH-WIDE store counters: ``n_live`` / ``n_evicted`` /
        ``n_failed`` / ``n_dropped`` summed over every shard (each shard
        tracks only its own rows; before this rollup the per-shard scalars
        were the only view — the telemetry blind spot).  Device values; the
        reduce runs at read time, outside the steady state.  With
        ``per_shard=True`` the un-summed (shards,) arrays ride along under
        ``"per_shard"``."""
        d = state["dir"]
        shard_vals = {
            "n_live": d["n_live"],
            "n_evicted": d["n_evicted"],
            "n_failed": d["n_failed"],
            "n_dropped": state["n_dropped"],
        }
        out = {k: v.sum() for k, v in shard_vals.items()}
        if per_shard:
            out["per_shard"] = shard_vals
        return out

    def attach_obs(self, registry, get_state, *,
                   prefix: str = "repro_sharded"):
        """Register a scrape collector over ``get_state()`` (the caller's
        current state variable): mesh-wide totals plus per-shard
        ``{shard="i"}``-labelled series."""
        series = {
            "n_live": (f"{prefix}_live_keys", "gauge",
                       "keys resident across all shards"),
            "n_evicted": (f"{prefix}_evictions_total", "counter",
                          "LRU + TTL evictions, all shards"),
            "n_failed": (f"{prefix}_admission_failed_total", "counter",
                         "abandoned admissions, all shards"),
            "n_dropped": (f"{prefix}_dropped_rows_total", "counter",
                          "rows dropped by failed admission, all shards"),
        }
        for key, (name, typ, help) in series.items():
            registry.describe(name, typ, help)

        def collect():
            c = self.counters(get_state(), per_shard=True)
            out = {}
            for key, (name, _, _) in series.items():
                out[name] = c[key]
                for i in range(self.n_shards):
                    out[f'{name}{{shard="{i}"}}'] = c["per_shard"][key][i]
            return out

        registry.register_collector(collect)
        return collect
