"""Two-Stacks (paper §3): amortized O(1), worst-case O(n) SWAG.

A FIFO queue as two stacks, augmented with aggregation.  The front stack F
aggregates toward its top (= oldest element, easy eviction); the back stack B
aggregates toward its top (= newest element, easy insertion).  When F runs
empty, ``evict`` first performs a *flip*: pop everything from B, pushing onto
F while reversing the aggregation direction — the O(n) latency spike DABA
exists to remove.

Each stack element is a (val, agg) struct (paper Fig. 1): total space 2n
partial aggregates.  Stacks are fixed-capacity arrays with a size scalar
(stack tops never wrap, no ring arithmetic needed).

Under ``vmap``, the flip's data-dependent loop becomes a ``while_loop`` whose
trip count is the max over lanes: one lane's flip stalls the whole batch.
This is measurable in benchmarks/bench_batched.py and is the SIMD-level
restatement of the paper's latency argument (DESIGN.md §2.1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.monoids import Monoid
from repro.core.swag_base import (
    alloc_ring,
    chunk_length,
    i32,
    lazy_cond,
    lazy_fori,
    suffix_carry_from_regions,
    swag_state,
)

PyTree = object


def _get(buf, idx):
    idx = jnp.asarray(idx, jnp.int32)
    return jax.tree.map(lambda a: a[idx], buf)


def _set(buf, idx, elem):
    idx = jnp.asarray(idx, jnp.int32)
    return jax.tree.map(lambda a, e: a.at[idx].set(e), buf, elem)


@swag_state
class TwoStacksState:
    f_vals: PyTree
    f_aggs: PyTree
    f_size: jax.Array
    b_vals: PyTree
    b_aggs: PyTree
    b_size: jax.Array
    capacity: int


def init(monoid: Monoid, capacity: int) -> TwoStacksState:
    return TwoStacksState(
        f_vals=alloc_ring(monoid, capacity),
        f_aggs=alloc_ring(monoid, capacity),
        f_size=i32(0),
        b_vals=alloc_ring(monoid, capacity),
        b_aggs=alloc_ring(monoid, capacity),
        b_size=i32(0),
        capacity=capacity,
    )


def size(state: TwoStacksState):
    return state.f_size + state.b_size


def _pi_f(monoid: Monoid, state: TwoStacksState):
    """Aggregate of the whole front stack: its top's agg (or 1)."""
    return lazy_cond(
        state.f_size == 0,
        lambda: monoid.identity(),
        lambda: _get(state.f_aggs, state.f_size - 1),
    )


def _pi_b(monoid: Monoid, state: TwoStacksState):
    return lazy_cond(
        state.b_size == 0,
        lambda: monoid.identity(),
        lambda: _get(state.b_aggs, state.b_size - 1),
    )


def query(monoid: Monoid, state: TwoStacksState):
    return monoid.combine(_pi_f(monoid, state), _pi_b(monoid, state))


def insert(monoid: Monoid, state: TwoStacksState, value) -> TwoStacksState:
    v = monoid.lift(value)
    agg = monoid.combine(_pi_b(monoid, state), v)  # 1 ⊗-invocation
    return TwoStacksState(
        f_vals=state.f_vals,
        f_aggs=state.f_aggs,
        f_size=state.f_size,
        b_vals=_set(state.b_vals, state.b_size, v),
        b_aggs=_set(state.b_aggs, state.b_size, agg),
        b_size=state.b_size + 1,
        capacity=state.capacity,
    )


def _flip(monoid: Monoid, state: TwoStacksState) -> TwoStacksState:
    """Pop all of B, pushing onto F with reversed aggregation direction.

    After the flip, F.top() (at index b_size-1) is the oldest element with
    agg = v_oldest ⊗ … ⊗ v_newest.  Costs exactly |B| ⊗-invocations, paid for
    by the banker's-method coins deposited by the preceding insertions.
    """

    nb = state.b_size

    def body(i, carry):
        f_vals, f_aggs = carry
        # Pop order: B's top first (newest), so F is built newest→oldest and
        # F's final top is the oldest element.
        src = nb - 1 - i
        v = _get(state.b_vals, src)
        prev = lazy_cond(
            i == 0, lambda: monoid.identity(), lambda: _get(f_aggs, i - 1)
        )
        agg = monoid.combine(v, prev)  # older operand LEFT: v is older than prev
        return _set(f_vals, i, v), _set(f_aggs, i, agg)

    f_vals, f_aggs = lazy_fori(0, nb, body, (state.f_vals, state.f_aggs))
    return TwoStacksState(
        f_vals=f_vals,
        f_aggs=f_aggs,
        f_size=nb,
        b_vals=state.b_vals,
        b_aggs=state.b_aggs,
        b_size=i32(0),
        capacity=state.capacity,
    )


def evict(monoid: Monoid, state: TwoStacksState) -> TwoStacksState:
    state = lazy_cond(
        state.f_size == 0,
        lambda s: _flip(monoid, s),
        lambda s: s,
        state,
    )
    return TwoStacksState(
        f_vals=state.f_vals,
        f_aggs=state.f_aggs,
        f_size=state.f_size - 1,
        b_vals=state.b_vals,
        b_aggs=state.b_aggs,
        b_size=state.b_size,
        capacity=state.capacity,
    )


# --- warm-carry protocol ----------------------------------------------------


def state_to_carry(monoid: Monoid, state: TwoStacksState, window: int):
    """Warm-carry extraction.  In age order the window is the front stack
    read top-down (``f_vals[f_size-1-j]``) followed by the back stack
    bottom-up; front aggs fold from each element to the front/back boundary
    (= "to B"), the back supplies raw values — the two_stacks_lite region
    shape with L = R = A = B = f_size."""
    cap = state.capacity
    length = cap + 1
    j = jnp.arange(length, dtype=jnp.int32)
    fi = jnp.clip(state.f_size - 1 - j, 0, cap - 1)
    bi = jnp.clip(j - state.f_size, 0, cap - 1)
    agg_log = jax.tree.map(lambda a: a[fi], state.f_aggs)
    raw_log = jax.tree.map(lambda a: a[bi], state.b_vals)
    d = state.f_size
    return suffix_carry_from_regions(
        monoid, raw_log, agg_log, state.f_size + state.b_size,
        d, d, d, d, window,
    )


def carry_to_state(monoid: Monoid, carry, capacity: int) -> TwoStacksState:
    """Exact carry import: the carry entries are suffix folds, i.e. a fully
    flipped front stack (top = oldest) with an empty back.  The front vals
    are pseudo (a flip never touches them; only ``b_vals`` is read)."""
    h = chunk_length(carry)
    if h > capacity:
        raise ValueError(f"carry of {h} elements exceeds capacity {capacity}")
    state = init(monoid, capacity)
    if h == 0:
        return state
    idx = jnp.arange(h, dtype=jnp.int32)
    flipped = jax.tree.map(lambda c: jnp.flip(c, 0), carry)
    f_aggs = jax.tree.map(lambda a, c: a.at[idx].set(c), state.f_aggs, flipped)
    f_vals = jax.tree.map(lambda a, c: a.at[idx].set(c), state.f_vals, flipped)
    return TwoStacksState(
        f_vals=f_vals,
        f_aggs=f_aggs,
        f_size=i32(h),
        b_vals=state.b_vals,
        b_aggs=state.b_aggs,
        b_size=i32(0),
        capacity=state.capacity,
    )
