"""Vectorized finger-style tail index: the disorder-adaptive release path.

The FiBA line of work ("Sub-O(log n) Out-of-Order Sliding-Window
Aggregation", arXiv 1810.11308; "Out-of-Order SWAG with Efficient Bulk
Evictions and Insertions", arXiv 2307.11210) keeps a *finger* at the newest
end of the window so an insert costs O(log d) in the out-of-order distance
``d`` — not O(log n) in the window — and bulk evictions/insertions amortize
over whole batches.  This module is the JAX-native transliteration of that
idea for :class:`repro.core.event_time.EventTimeChunkedStream`'s chunk
shape, where every array is static-shaped and the adaptivity lives in a
``lax.cond`` between two code paths instead of a tree descent:

  * **frontier tracking** — the engine's append frontier is ``max_ts`` (the
    largest event time ever seen; every window and buffer entry is at or
    below it).  :func:`chunk_in_order` tests, fully vectorized, whether a
    masked chunk lies entirely at-or-above the frontier in non-decreasing
    order — the ``d = 0`` case;
  * **bounded d = 0 merge** — :func:`compact_sorted` turns (sorted reorder
    buffer ++ in-order chunk) into one sorted pending run with ONE gather
    (no sort, no searchsorted): the finger insert at distance zero.
    :func:`append_merge` then places released rows after the window with a
    static concatenation — merged positions are known without any rank
    computation;
  * **bounded general merge** — :func:`sort_pending` (stable argsort of the
    trailing ``buffer + chunk`` region only — the window proper is never
    re-sorted) plus :func:`rank_merge`, the searchsorted rank-dual stable
    merge of two sorted runs.  Work is confined to the trailing
    ``max(d, slack)``-distance region the reorder buffer bounds: an element
    later than ``slack`` is handled by the late policy, never by a deeper
    merge;
  * **bulk evict/insert** — :func:`release_split` peels the released prefix
    off the sorted pending run and shifts the remainder into the new
    reorder buffer in one gather each (the bulk-insert half); the engine's
    watermark eviction re-gathers a contiguous slice (the bulk-evict half).
  * **finger search** — :func:`seg_bounded_search`, a vectorized per-row
    binary search *bounded below by each row's segment head*: the keyed
    store's event-time (``horizon=``) windows use it to find every row's
    in-horizon span start inside its key's segment in O(log C) gathers.

:func:`displacement` measures the classic per-chunk out-of-order distance
``max_i |{j < i : ts_j > ts_i}|`` exactly from the stable sort permutation
(two argsorts, no scatters) — the ``ooo_distance`` gauge the obs layer
scrapes.

Everything here is pure and jit-safe; the merge-order invariant (window
entries precede same-timestamp released entries; buffer entries precede
same-timestamp chunk entries; chunk entries keep arrival order) is stated
once in the :mod:`repro.core.event_time` module docstring and implemented
here.  NOTE the end-of-stream gotcha cross-referenced from there: draining
via ``EventTimeChunkedStream.stream(..., flush=True)`` (or ``.flush()``)
releases every pending element AND fully evicts the window — the fast path
handles the drain chunk (an all-masked chunk is trivially in-order), so a
flushed engine takes the d = 0 branch even on a previously disordered
stream.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def _bc(mask, leaf):
    return mask.reshape(mask.shape + (1,) * (leaf.ndim - 1))


def _mask_tree(tree: PyTree, mask, ident: PyTree) -> PyTree:
    return jax.tree.map(
        lambda a, i: jnp.where(_bc(mask, a), a, jnp.asarray(i, a.dtype)),
        tree,
        ident,
    )


def _take0(tree: PyTree, idx) -> PyTree:
    return jax.tree.map(lambda a: a[idx], tree)


def _where_rows(mask, a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(lambda x, y: jnp.where(_bc(mask, x), x, y), a, b)


def _concat0(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(lambda x, y: jnp.concatenate([x, y], axis=0), a, b)


# ---------------------------------------------------------------------------
# Frontier tracking
# ---------------------------------------------------------------------------


def chunk_in_order(ts_in, frontier):
    """True iff the masked chunk appends at the frontier: ``ts_in`` (C,)
    non-decreasing with every entry ``>= frontier``.

    ``ts_in`` is the engine's masked timestamp row — excluded rows (ragged
    padding, dropped late rows) hold the TS_MAX sentinel, which passes both
    tests at the chunk tail and fails the monotonicity test in the interior
    (an interior hole means the kept rows are not a sorted suffix run, so
    the general path must sort).  ``frontier`` is the pre-chunk ``max_ts``:
    at or above it, a row can interleave with NOTHING already held (window,
    buffer, and all prior releases sit at or below), so the whole chunk is
    one in-order append — the out-of-order distance of every row is zero.
    """
    nondecreasing = jnp.all(ts_in[1:] >= ts_in[:-1])
    at_frontier = jnp.all(ts_in >= frontier)
    return nondecreasing & at_frontier


def displacement(pend_ts, order, tmax):
    """Exact max out-of-order distance of a pending run (device scalar).

    ``order`` is the stable sort permutation of ``pend_ts`` (P,);
    ``tmax``-sentinel rows are padding.  For live row i, with r_i its
    arrival rank among live rows and s_i its sorted rank,

        r_i - s_i = |{j <= i}| - 1 - |{ts_j < ts_i}| - |{j < i, ts_j = ts_i}|
                  = |{j < i : ts_j > ts_i}|  =  d_i,

    the classic per-element out-of-order distance (stable ties: an equal-ts
    earlier arrival sorts first and is not counted).  Sorted ranks come from
    ``argsort(order)`` — the inverse of a permutation, gather-only (a
    scatter would serialize on CPU) — and live rows all sort before the
    sentinel padding, so ranks among all rows equal ranks among live rows.
    """
    P = pend_ts.shape[0]
    live = pend_ts < tmax
    inv = jnp.argsort(order).astype(jnp.int32)
    r = jnp.cumsum(live.astype(jnp.int32)) - 1
    d = jnp.where(live, r - inv, 0)
    return jnp.maximum(jnp.max(d), 0) if P else jnp.int32(0)


# ---------------------------------------------------------------------------
# Pending-run assembly (buffer ++ chunk, time-sorted)
# ---------------------------------------------------------------------------


def compact_perm(buf_ts, chunk_len: int, *, tmax):
    """The d = 0 sort permutation, computed WITHOUT sorting: indices into
    (buffer ++ chunk) that compact the buffer's live prefix ahead of the
    chunk rows, plus the live-region mask.

    Preconditions (the :func:`chunk_in_order` branch guard): the buffer's
    live prefix is sorted and every live entry is at or below the frontier;
    every kept chunk row is at or above it, in non-decreasing order with
    sentinel padding only at the tail.  The stable sorted order is then
    ``[buffer live, chunk rows, padding]`` — buffer entries precede
    same-timestamp chunk rows (they arrived earlier: the merge-order
    invariant's tie rule, for free) — so the permutation is pure index
    arithmetic over the live count ``nb``.  This is what the engine's fast
    ``lax.cond`` branch returns in place of ``argsort``: a (P,) int32
    gather map and a (P,) bool mask, O(P) integer work, zero comparisons
    of timestamps.  Rows with ``in_range`` False must be forced to the
    ``tmax`` sentinel / identity by the caller's gather (they alias
    arbitrary source rows).
    """
    K = buf_ts.shape[0]
    P = K + int(chunk_len)
    nb = (buf_ts < tmax).sum(dtype=jnp.int32)
    jj = jnp.arange(P, dtype=jnp.int32)
    src = jnp.where(jj < nb, jj, jnp.minimum(K + jj - nb, P - 1))
    in_range = jj < nb + chunk_len
    return src, in_range


def compact_sorted(buf_ts, buf_agg, ts_in, chunk_agg, *, tmax, ident):
    """The d = 0 merge: one gather (per leaf) over the :func:`compact_perm`
    permutation turns (sorted buffer ++ in-order chunk) into a sorted
    pending run — no sort, no searchsorted."""
    src, in_range = compact_perm(buf_ts, ts_in.shape[0], tmax=tmax)
    pend_ts0 = jnp.concatenate([buf_ts, ts_in])
    pend_agg0 = _concat0(buf_agg, chunk_agg)
    pend_ts = jnp.where(in_range, pend_ts0[src], tmax)
    pend_agg = _mask_tree(_take0(pend_agg0, src), in_range, ident)
    return pend_ts, pend_agg


def sort_pending(buf_ts, buf_agg, ts_in, chunk_agg):
    """The general merge: stable time-sort of (buffer ++ chunk).

    Buffer entries arrived earlier, so concatenating them first makes the
    stable sort keep them ahead of same-timestamp chunk rows, and chunk
    rows keep arrival order on ties (the merge-order invariant).  This is
    the trailing-region sort of the bounded merge — P = buffer + chunk
    rows, never the window — and the ONLY sort on the release path.
    Returns ``(pend_ts, pend_agg, order)``; ``order`` feeds
    :func:`displacement`.
    """
    pend_ts = jnp.concatenate([buf_ts, ts_in])
    pend_agg = _concat0(buf_agg, chunk_agg)
    order = jnp.argsort(pend_ts, stable=True)
    return pend_ts[order], _take0(pend_agg, order), order


# ---------------------------------------------------------------------------
# Bulk release (the insert half of bulk evict/insert)
# ---------------------------------------------------------------------------


def release_split(pend_ts, pend_agg, wm, *, buffer: int, tmax, ident):
    """Split a sorted pending run at the watermark: the released prefix and
    the shifted new reorder buffer, one gather each.

    Returns ``(rel_ts, rel_agg, rel_mask, buf_ts, buf_agg, overflow)``:
    released rows (ts <= wm) masked to sentinels/identity past the release
    count, the unreleased remainder left-shifted into the (buffer,)-slot
    reorder buffer, and the count of live rows that fell off its end
    (overflow loses the NEWEST pending arrivals — the prefix closest to
    release is kept).
    """
    P = pend_ts.shape[0]
    K = int(buffer)
    jj = jnp.arange(P, dtype=jnp.int32)
    n_rel = ((pend_ts <= wm) & (pend_ts < tmax)).sum(dtype=jnp.int32)
    rel = jj < n_rel
    rel_ts = jnp.where(rel, pend_ts, tmax)
    rel_agg = _mask_tree(pend_agg, rel, ident)
    src = jnp.clip(jj + n_rel, 0, P - 1)
    in_range = (jj + n_rel) < P
    nb_ts = jnp.where(in_range, pend_ts[src], tmax)
    nb_agg = _mask_tree(_take0(pend_agg, src), in_range, ident)
    overflow = (nb_ts[K:] < tmax).sum(dtype=jnp.int32)
    return (
        rel_ts,
        rel_agg,
        rel,
        nb_ts[:K],
        jax.tree.map(lambda a: a[:K], nb_agg),
        overflow,
    )


# ---------------------------------------------------------------------------
# Window merge (append at the frontier / rank-dual stable interleave)
# ---------------------------------------------------------------------------


def append_merge(win_ts, win_agg, rel_ts, rel_agg):
    """Merge released rows that all sit at or above the window's newest
    entry: a static concatenation.

    Valid whenever every released timestamp is >= every window timestamp
    (the d = 0 branch guarantees it: window entries are at or below the old
    frontier, released rows at or above).  Tie discipline holds for free —
    window entries physically precede same-timestamp released entries.
    Window TS_MIN padding leads, released TS_MAX padding trails, so the
    result is sorted for the downstream searchsorteds.  Returns
    ``(mts, magg, pos_rel)`` with ``pos_rel[j] = W + j`` known statically.
    """
    W = win_ts.shape[0]
    P = rel_ts.shape[0]
    mts = jnp.concatenate([win_ts, rel_ts])
    magg = _concat0(win_agg, rel_agg)
    pos_rel = W + jnp.arange(P, dtype=jnp.int32)
    return mts, magg, pos_rel


def rank_merge(win_ts, win_agg, rel_ts, rel_agg):
    """Stable rank-dual merge of the sorted window and released runs.

    Both runs are time-sorted (window ascending with TS_MIN padding in
    front, released ascending with TS_MAX padding behind), so every row's
    merged position is its own index plus its RANK in the other run —
    searchsorteds and gathers replace a stable argsort over W + P rows and
    its inverse permutation (and the scatter dual: scatters lower to
    sequential loops on CPU).  Tie discipline (the merge-order invariant):
    window entries precede same-timestamp released entries (window
    ``side="left"``, released ``side="right"``).  Returns
    ``(mts, magg, pos_rel)``.
    """
    W = win_ts.shape[0]
    P = rel_ts.shape[0]
    Mtot = W + P
    jj = jnp.arange(P, dtype=jnp.int32)
    pos_win = jnp.arange(W, dtype=jnp.int32) + jnp.searchsorted(
        rel_ts, win_ts, side="left"
    ).astype(jnp.int32)
    pos_rel = jj + jnp.searchsorted(
        win_ts, rel_ts, side="right"
    ).astype(jnp.int32)
    # gather dual: pos_win is strictly increasing, so the last window
    # position <= i tells merged row i which run it came from and its rank
    # there (#released rows <= i is then i - wsel - 1).
    mi = jnp.arange(Mtot, dtype=jnp.int32)
    wsel = jnp.searchsorted(pos_win, mi, side="right").astype(jnp.int32) - 1
    wsel_c = jnp.clip(wsel, 0, W - 1)
    from_win = (wsel >= 0) & (pos_win[wsel_c] == mi)
    rsel = jnp.clip(mi - wsel - 1, 0, P - 1)
    mts = jnp.where(from_win, win_ts[wsel_c], rel_ts[rsel])
    magg = _where_rows(
        from_win, _take0(win_agg, wsel_c), _take0(rel_agg, rsel)
    )
    return mts, magg, pos_rel


# ---------------------------------------------------------------------------
# Finger search (per-row, bounded below by a per-row floor)
# ---------------------------------------------------------------------------


def seg_bounded_search(ts, lo, hi, thr):
    """Per-row finger search: the first index in ``[lo_j, hi_j]`` whose
    timestamp exceeds ``thr_j`` (``hi_j + 1`` when none does).

    ``ts`` (C,) must be non-decreasing WITHIN each ``[lo_j, hi_j]`` range
    (the keyed store's per-segment event-time order); across ranges it can
    be anything — each row's search never reads outside its own range, which
    is what a global ``searchsorted`` cannot do.  A branchless vectorized
    binary search: ceil(log2(C)) rounds of one (C,) gather each, no
    scatters.  This is the keyed ``horizon=`` mode's span-start primitive:
    row j's in-horizon window is ``[search(lo_j, j, ts_j - horizon), j]``.
    """
    C = ts.shape[0]
    lo = jnp.asarray(lo, jnp.int32)
    hi = jnp.asarray(hi, jnp.int32)
    left, right = lo, hi + 1  # invariant: first-exceeding in [left, right]
    # a width-C range needs bit_length(C) floor-halvings to reach width 0
    rounds = max(int(C).bit_length(), 1)
    for _ in range(rounds):
        mid = (left + right) // 2
        go_left = ts[jnp.clip(mid, 0, C - 1)] > thr
        narrow = left < right
        left = jnp.where(narrow & ~go_left, mid + 1, left)
        right = jnp.where(narrow & go_left, mid, right)
    return left
