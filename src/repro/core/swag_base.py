"""Shared machinery for the SWAG (sliding-window aggregation) algorithms.

Every algorithm in :mod:`repro.core` is a *functional* state machine:

    state = algo.init(monoid, capacity)
    state = algo.insert(monoid, state, element)     # element: In type
    state = algo.evict(monoid, state)
    agg   = algo.query(monoid, state)               # Agg type (pre-lower)

States are registered pytrees (ring buffers + int32 pointers), so they can be
``jit``-ted, ``vmap``-ped across independent windows, ``scan``-ned over
streams, sharded with ``pjit``, and checkpointed like any other model state.

Control flow uses :func:`lazy_cond`, which executes only the taken branch in
eager mode (matching the paper's pseudocode exactly — this is what makes the
combine-count theorems directly testable) and lowers to ``lax.cond`` under
tracing (where vmap turns it into ``select``; see DESIGN.md §2.1).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.monoids import Monoid

PyTree = Any


# ---------------------------------------------------------------------------
# Control flow
# ---------------------------------------------------------------------------


def lazy_cond(pred, true_fn: Callable, false_fn: Callable, *operands):
    """``lax.cond`` that short-circuits when ``pred`` is concrete.

    In eager execution the paper's sequential semantics (only the taken branch
    runs, so ⊗-counts match the theorems).  Under ``jit``/``vmap`` this is a
    regular ``lax.cond`` (both branches traced; vmap executes both and
    selects — constant, uniform work per lane: the SIMD story of DESIGN.md).
    """
    try:
        concrete = bool(pred)
    except (jax.errors.TracerBoolConversionError, jax.errors.ConcretizationTypeError):
        return jax.lax.cond(pred, true_fn, false_fn, *operands)
    return true_fn(*operands) if concrete else false_fn(*operands)


def lazy_fori(lo, hi, body: Callable, init):
    """``lax.fori_loop`` that runs a Python loop when everything is concrete.

    The Python loop gives the paper's eager sequential semantics (exact
    ⊗-counts).  When the CARRY is traced (under jit/vmap) a Python loop would
    unroll ``hi - lo`` copies of the body into the trace — an enormous graph
    and, under eager vmap, per-op dispatch — so tracers anywhere route to
    ``lax.fori_loop`` even with concrete bounds.
    """
    traced_carry = any(
        isinstance(leaf, jax.core.Tracer) for leaf in jax.tree.leaves(init)
    )
    try:
        lo_c, hi_c = int(lo), int(hi)
    except (jax.errors.TracerBoolConversionError, jax.errors.ConcretizationTypeError, TypeError):
        return jax.lax.fori_loop(lo, hi, body, init)
    if traced_carry:
        return jax.lax.fori_loop(lo_c, hi_c, body, init)
    carry = init
    for i in range(lo_c, hi_c):
        carry = body(i, carry)
    return carry


# ---------------------------------------------------------------------------
# Ring buffers of monoid elements
# ---------------------------------------------------------------------------


def alloc_ring(monoid: Monoid, capacity: int) -> PyTree:
    """Allocate a ring buffer of ``capacity`` Agg elements, filled with 1."""
    ident = monoid.identity()
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (capacity,) + x.shape).copy(), ident
    )


def ring_get(buf: PyTree, ptr, capacity: int) -> PyTree:
    """Read the element at logical pointer ``ptr`` (physical ``ptr % cap``)."""
    idx = jnp.asarray(ptr, jnp.int32) % capacity
    return jax.tree.map(lambda a: a[idx], buf)


def ring_set(buf: PyTree, ptr, elem: PyTree, capacity: int) -> PyTree:
    idx = jnp.asarray(ptr, jnp.int32) % capacity
    return jax.tree.map(lambda a, e: a.at[idx].set(e), buf, elem)


def i32(x) -> jax.Array:
    return jnp.asarray(x, jnp.int32)


# ---------------------------------------------------------------------------
# Bulk-op protocol (chunked streaming; cf. Tangwongsan et al., arXiv
# 2307.11210 on efficient bulk insertions/evictions)
# ---------------------------------------------------------------------------
#
# Every algorithm supports
#
#     state = insert_bulk(algo, monoid, state, values)   # values: (k, ...) In
#     state = evict_bulk(algo, monoid, state, k)
#
# semantically equal to k sequential ``insert``/``evict`` calls (floats may
# differ by combine reassociation; exact for integer monoids).  Algorithms may
# export their own ``insert_bulk(monoid, state, values)`` /
# ``evict_bulk(monoid, state, k)`` with amortized shortcuts (two_stacks_lite,
# daba_lite); everything else conforms through the ``lazy_fori`` fallbacks
# below.  The chunk length k must be static, and — as with per-element
# inserts — ``size + k`` must not exceed the ring capacity.


def chunk_length(values: PyTree) -> int:
    """Static leading length of a stacked chunk of inputs."""
    return jax.tree.leaves(values)[0].shape[0]


def tree_index(tree: PyTree, i) -> PyTree:
    return jax.tree.map(lambda a: a[i], tree)


def lift_chunk(monoid: Monoid, values: PyTree) -> PyTree:
    """Vectorized ``lift`` over the leading (chunk) axis."""
    return jax.vmap(monoid.lift)(values)


def chunk_prefix_scan(monoid: Monoid, lifted: PyTree) -> PyTree:
    """Inclusive prefix scan along axis 0: out[i] = v_0 ⊗ … ⊗ v_i.

    Uses ``lax.associative_scan`` (log-depth), so float results may be a
    reassociation of the sequential left fold; integer monoids are exact.
    """
    return jax.lax.associative_scan(monoid.combine, lifted, axis=0)


def chunk_suffix_scan(monoid: Monoid, lifted: PyTree) -> PyTree:
    """Inclusive suffix scan along axis 0: out[i] = v_i ⊗ … ⊗ v_{k-1}.

    NOT ``associative_scan(..., reverse=True)``: that computes the
    reversed-operand product, which is wrong for non-commutative monoids.
    Flip the axis and scan with the operands swapped instead.
    """
    flipped = jax.tree.map(lambda a: jnp.flip(a, 0), lifted)
    out = jax.lax.associative_scan(
        lambda a, b: monoid.combine(b, a), flipped, axis=0
    )
    return jax.tree.map(lambda a: jnp.flip(a, 0), out)


def chunk_fold(monoid: Monoid, lifted: PyTree) -> PyTree:
    """Total aggregate of a lifted chunk (one log-depth reduction)."""
    return tree_index(chunk_suffix_scan(monoid, lifted), 0)


def generic_insert_bulk(algo, monoid: Monoid, state: PyTree, values: PyTree) -> PyTree:
    """Fallback: k sequential inserts fused into one ``lazy_fori`` loop."""
    k = chunk_length(values)
    return lazy_fori(
        0, k, lambda i, s: algo.insert(monoid, s, tree_index(values, i)), state
    )


def generic_evict_bulk(algo, monoid: Monoid, state: PyTree, k) -> PyTree:
    """Fallback: k sequential evicts fused into one ``lazy_fori`` loop."""
    return lazy_fori(0, k, lambda i, s: algo.evict(monoid, s), state)


def insert_bulk(algo, monoid: Monoid, state: PyTree, values: PyTree) -> PyTree:
    """Insert a stacked chunk of raw inputs; dispatches to the algorithm's
    specialized bulk op when it has one."""
    fn = getattr(algo, "insert_bulk", None)
    if fn is not None:
        return fn(monoid, state, values)
    return generic_insert_bulk(algo, monoid, state, values)


def evict_bulk(algo, monoid: Monoid, state: PyTree, k) -> PyTree:
    """Evict the k oldest elements; dispatches like :func:`insert_bulk`."""
    fn = getattr(algo, "evict_bulk", None)
    if fn is not None:
        return fn(monoid, state, k)
    return generic_evict_bulk(algo, monoid, state, k)


# ---------------------------------------------------------------------------
# State dataclass registration helper
# ---------------------------------------------------------------------------


def swag_state(cls):
    """Decorator: freeze + register a SWAG state dataclass as a JAX pytree.

    All fields are dynamic (pytree children) except fields whose name is
    ``capacity`` (static metadata).
    """
    cls = dataclasses.dataclass(frozen=True)(cls)
    fields = [f.name for f in dataclasses.fields(cls)]
    data_fields = [f for f in fields if f != "capacity"]
    meta_fields = [f for f in fields if f == "capacity"]
    return jax.tree_util.register_dataclass(
        cls, data_fields=data_fields, meta_fields=meta_fields
    )


# ---------------------------------------------------------------------------
# Facade
# ---------------------------------------------------------------------------


class SWAG:
    """Object-style facade binding (algorithm module, monoid, capacity).

    ``algo`` is any module exposing ``init/insert/evict/query/size`` with the
    functional signatures documented above.  With ``use_jit=True`` the three
    operations are jitted (donating the state argument); eager otherwise.
    """

    def __init__(self, algo, monoid: Monoid, capacity: int, use_jit: bool = False):
        self.algo = algo
        self.monoid = monoid
        self.capacity = capacity
        self._state = algo.init(monoid, capacity)
        if use_jit:
            self._insert = jax.jit(
                lambda s, v: algo.insert(monoid, s, v), donate_argnums=(0,)
            )
            self._evict = jax.jit(lambda s: algo.evict(monoid, s), donate_argnums=(0,))
            self._query = jax.jit(lambda s: algo.query(monoid, s))
        else:
            self._insert = lambda s, v: algo.insert(monoid, s, v)
            self._evict = lambda s: algo.evict(monoid, s)
            self._query = lambda s: algo.query(monoid, s)

    @property
    def state(self):
        return self._state

    def insert(self, v) -> None:
        self._state = self._insert(self._state, v)

    def evict(self) -> None:
        self._state = self._evict(self._state)

    def query(self):
        return self._query(self._state)

    def lowered_query(self):
        return self.monoid.lower(self.query())

    def size(self) -> int:
        return int(self.algo.size(self._state))

    def __len__(self) -> int:
        return self.size()
