"""Shared machinery for the SWAG (sliding-window aggregation) algorithms.

Every algorithm in :mod:`repro.core` is a *functional* state machine:

    state = algo.init(monoid, capacity)
    state = algo.insert(monoid, state, element)     # element: In type
    state = algo.evict(monoid, state)
    agg   = algo.query(monoid, state)               # Agg type (pre-lower)

States are registered pytrees (ring buffers + int32 pointers), so they can be
``jit``-ted, ``vmap``-ped across independent windows, ``scan``-ned over
streams, sharded with ``pjit``, and checkpointed like any other model state.

Control flow uses :func:`lazy_cond`, which executes only the taken branch in
eager mode (matching the paper's pseudocode exactly — this is what makes the
combine-count theorems directly testable) and lowers to ``lax.cond`` under
tracing (where vmap turns it into ``select``; see DESIGN.md §2.1).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.monoids import Monoid

PyTree = Any


# ---------------------------------------------------------------------------
# Control flow
# ---------------------------------------------------------------------------


def lazy_cond(pred, true_fn: Callable, false_fn: Callable, *operands):
    """``lax.cond`` that short-circuits when ``pred`` is concrete.

    In eager execution the paper's sequential semantics (only the taken branch
    runs, so ⊗-counts match the theorems).  Under ``jit``/``vmap`` this is a
    regular ``lax.cond`` (both branches traced; vmap executes both and
    selects — constant, uniform work per lane: the SIMD story of DESIGN.md).
    """
    try:
        concrete = bool(pred)
    except (jax.errors.TracerBoolConversionError, jax.errors.ConcretizationTypeError):
        return jax.lax.cond(pred, true_fn, false_fn, *operands)
    return true_fn(*operands) if concrete else false_fn(*operands)


def lazy_fori(lo, hi, body: Callable, init):
    """``lax.fori_loop`` that runs a Python loop when everything is concrete.

    The Python loop gives the paper's eager sequential semantics (exact
    ⊗-counts).  When the CARRY is traced (under jit/vmap) a Python loop would
    unroll ``hi - lo`` copies of the body into the trace — an enormous graph
    and, under eager vmap, per-op dispatch — so tracers anywhere route to
    ``lax.fori_loop`` even with concrete bounds.
    """
    traced_carry = any(
        isinstance(leaf, jax.core.Tracer) for leaf in jax.tree.leaves(init)
    )
    try:
        lo_c, hi_c = int(lo), int(hi)
    except (jax.errors.TracerBoolConversionError, jax.errors.ConcretizationTypeError, TypeError):
        return jax.lax.fori_loop(lo, hi, body, init)
    if traced_carry:
        return jax.lax.fori_loop(lo_c, hi_c, body, init)
    carry = init
    for i in range(lo_c, hi_c):
        carry = body(i, carry)
    return carry


# ---------------------------------------------------------------------------
# Ring buffers of monoid elements
# ---------------------------------------------------------------------------


def alloc_ring(monoid: Monoid, capacity: int) -> PyTree:
    """Allocate a ring buffer of ``capacity`` Agg elements, filled with 1."""
    ident = monoid.identity()
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (capacity,) + x.shape).copy(), ident
    )


def ring_get(buf: PyTree, ptr, capacity: int) -> PyTree:
    """Read the element at logical pointer ``ptr`` (physical ``ptr % cap``)."""
    idx = jnp.asarray(ptr, jnp.int32) % capacity
    return jax.tree.map(lambda a: a[idx], buf)


def ring_set(buf: PyTree, ptr, elem: PyTree, capacity: int) -> PyTree:
    idx = jnp.asarray(ptr, jnp.int32) % capacity
    return jax.tree.map(lambda a, e: a.at[idx].set(e), buf, elem)


def i32(x) -> jax.Array:
    return jnp.asarray(x, jnp.int32)


# ---------------------------------------------------------------------------
# Bulk-op protocol (chunked streaming; cf. Tangwongsan et al., arXiv
# 2307.11210 on efficient bulk insertions/evictions)
# ---------------------------------------------------------------------------
#
# Every algorithm supports
#
#     state = insert_bulk(algo, monoid, state, values)   # values: (k, ...) In
#     state = evict_bulk(algo, monoid, state, k)
#
# semantically equal to k sequential ``insert``/``evict`` calls (floats may
# differ by combine reassociation; exact for integer monoids).  Algorithms may
# export their own ``insert_bulk(monoid, state, values)`` /
# ``evict_bulk(monoid, state, k)`` with amortized shortcuts (two_stacks_lite,
# daba_lite); everything else conforms through the ``lazy_fori`` fallbacks
# below.  The chunk length k must be static, and — as with per-element
# inserts — ``size + k`` must not exceed the ring capacity.


def chunk_length(values: PyTree) -> int:
    """Static leading length of a stacked chunk of inputs."""
    return jax.tree.leaves(values)[0].shape[0]


def tree_index(tree: PyTree, i) -> PyTree:
    return jax.tree.map(lambda a: a[i], tree)


def lift_chunk(monoid: Monoid, values: PyTree) -> PyTree:
    """Vectorized ``lift`` over the leading (chunk) axis."""
    return jax.vmap(monoid.lift)(values)


def chunk_prefix_scan(monoid: Monoid, lifted: PyTree) -> PyTree:
    """Inclusive prefix scan along axis 0: out[i] = v_0 ⊗ … ⊗ v_i.

    Uses ``lax.associative_scan`` (log-depth), so float results may be a
    reassociation of the sequential left fold; integer monoids are exact.
    """
    return jax.lax.associative_scan(monoid.combine, lifted, axis=0)


def suffix_scan(combine: Callable, tree: PyTree, axis: int = 0) -> PyTree:
    """Inclusive suffix scan: ``out[i] = x_i ⊗ x_{i+1} ⊗ … ⊗ x_{n-1}``.

    THE one place the non-commutative operand-order gotcha lives — every
    suffix scan in the repo (:func:`chunk_suffix_scan`, the chunked engine's
    block scans, the suffix_scan kernel oracle) goes through here.  This must
    NOT be ``associative_scan(combine, x, reverse=True)``: that computes the
    *reversed-operand* product ``x_{n-1} ⊗ … ⊗ x_i``, which silently corrupts
    non-commutative monoids (argmax tie-breaks, m4 first/last, affine
    composition).  Instead: flip the axis, scan with the combine's operands
    swapped so the older element stays on the LEFT, and flip back.
    """
    flipped = jax.tree.map(lambda a: jnp.flip(a, axis), tree)
    out = jax.lax.associative_scan(
        lambda a, b: combine(b, a), flipped, axis=axis
    )
    return jax.tree.map(lambda a: jnp.flip(a, axis), out)


def chunk_suffix_scan(monoid: Monoid, lifted: PyTree) -> PyTree:
    """Inclusive suffix scan along axis 0: out[i] = v_i ⊗ … ⊗ v_{k-1}.

    See :func:`suffix_scan` for the non-commutative operand-order rule.
    """
    return suffix_scan(monoid.combine, lifted, axis=0)


def chunk_fold(monoid: Monoid, lifted: PyTree) -> PyTree:
    """Total aggregate of a lifted chunk (one log-depth reduction)."""
    return tree_index(chunk_suffix_scan(monoid, lifted), 0)


def generic_insert_bulk(algo, monoid: Monoid, state: PyTree, values: PyTree) -> PyTree:
    """Fallback: k sequential inserts fused into one ``lazy_fori`` loop."""
    k = chunk_length(values)
    return lazy_fori(
        0, k, lambda i, s: algo.insert(monoid, s, tree_index(values, i)), state
    )


def generic_evict_bulk(algo, monoid: Monoid, state: PyTree, k) -> PyTree:
    """Fallback: k sequential evicts fused into one ``lazy_fori`` loop."""
    return lazy_fori(0, k, lambda i, s: algo.evict(monoid, s), state)


def insert_bulk(algo, monoid: Monoid, state: PyTree, values: PyTree) -> PyTree:
    """Insert a stacked chunk of raw inputs; dispatches to the algorithm's
    specialized bulk op when it has one."""
    fn = getattr(algo, "insert_bulk", None)
    if fn is not None:
        return fn(monoid, state, values)
    return generic_insert_bulk(algo, monoid, state, values)


def evict_bulk(algo, monoid: Monoid, state: PyTree, k) -> PyTree:
    """Evict the k oldest elements; dispatches like :func:`insert_bulk`."""
    fn = getattr(algo, "evict_bulk", None)
    if fn is not None:
        return fn(monoid, state, k)
    return generic_evict_bulk(algo, monoid, state, k)


# ---------------------------------------------------------------------------
# Warm-state carry protocol (chunked streaming from live windows)
# ---------------------------------------------------------------------------
#
# A :class:`repro.core.chunked.ChunkedStream` carry is the *tail* of suffix
# aggregates of the window's last ``h = window - 1`` elements:
#
#     carry[t] = v_{n-(h-t)} ⊗ … ⊗ v_{n-1}       for t = 0 … h-1
#
# front-truncated: with fewer than ``h - t`` live elements it is the fold of
# ALL of them (the monoid identity for an empty window).  Conversions
#
#     carry = state_to_carry(algo, monoid, state, window)   # (h,)-leading
#     state = carry_to_state(algo, monoid, carry, capacity)
#
# let the chunked engine start from ANY live SWAG state (and a per-element
# algorithm resume from a chunked carry).  Every algorithm in repro.core
# exports specialized ``state_to_carry`` — one ring gather + one log-depth
# suffix scan over :func:`suffix_carry_from_regions` — and, where its layout
# permits, ``carry_to_state``; anything else conforms through the generic
# fallbacks below (masked evict/query window-content extraction, and
# pseudo-element insertion which needs an invertible commutative monoid).


def ring_gather(buf: PyTree, front, capacity: int, length: int) -> PyTree:
    """Read ``length`` consecutive ring elements starting at logical ``front``
    into age order (index 0 = oldest).  Entries past the live region wrap and
    must be masked by the caller."""
    j = jnp.arange(length, dtype=jnp.int32)
    idx = (jnp.asarray(front, jnp.int32) + j) % capacity
    return jax.tree.map(lambda a: a[idx], buf)


def suffix_carry_from_regions(
    monoid: Monoid,
    raw_log: PyTree,
    agg_log: PyTree,
    n,
    off_l,
    off_r,
    off_a,
    off_b,
    window: int,
) -> PyTree:
    """Carry from the DABA-family sublist layout, in one log-depth scan.

    ``raw_log``/``agg_log`` are the state's rings in age order (index 0 =
    oldest live element; entries at ``j >= n`` are ignored).  The logical
    offsets mirror the F ≤ L ≤ R ≤ A ≤ B ≤ E pointer chain relative to F:

      * ``[off_r, off_a)`` and ``[off_b, n)`` hold RAW lifted values,
      * slot ``off_a`` (when ``off_a < off_b``) holds Π_A = fold to B,
      * ``[off_l, off_r)`` holds fold-to-R aggregates,
      * everything else live holds fold-to-B aggregates.

    Degenerate layouts reuse this directly: two_stacks_lite passes
    ``off_l = off_r = off_a = off_b`` (front aggregates + raw back) and
    recalc/soe pass all offsets 0 (everything raw).  The suffix-to-end of
    element j is assembled as raw-scan value, ``agg[j] ⊗ suffix(R)``, or
    ``agg[j] ⊗ Π_B`` depending on region; the carry gathers the suffixes of
    the last ``window - 1`` elements, front-truncated.
    """
    h = int(window) - 1
    ident = monoid.identity()
    L = chunk_length(raw_log)
    j = jnp.arange(L, dtype=jnp.int32)
    n = i32(n)
    off_l, off_r, off_a, off_b = i32(off_l), i32(off_r), i32(off_a), i32(off_b)

    def bc(mask, a):
        return mask.reshape(mask.shape + (1,) * (a.ndim - 1))

    live = j < n
    use_raw = live & (((j >= off_r) & (j < off_a)) | (j >= off_b))
    use_agg = live & (j == off_a) & (off_a < off_b)
    scan_vals = jax.tree.map(
        lambda raw, agg, i: jnp.where(
            bc(use_raw, raw),
            raw,
            jnp.where(bc(use_agg, raw), agg, jnp.asarray(i, raw.dtype)),
        ),
        raw_log,
        agg_log,
        ident,
    )
    sb = suffix_scan(monoid.combine, scan_vals, axis=0)
    s_r = tree_index(sb, off_r)  # suffix fold from R to the end
    s_b = tree_index(sb, off_b)  # fold of l_B (the raw back values)
    with_b = jax.vmap(monoid.combine, in_axes=(0, None))(agg_log, s_b)
    with_r = jax.vmap(monoid.combine, in_axes=(0, None))(agg_log, s_r)

    in_scan = use_raw | use_agg
    mid = live & (j >= off_l) & (j < off_r)
    suffix = jax.tree.map(
        lambda sc, wr, wb, i: jnp.where(
            bc(in_scan, sc),
            sc,
            jnp.where(
                bc(mid, sc),
                wr,
                jnp.where(bc(live, sc), wb, jnp.asarray(i, sc.dtype)),
            ),
        ),
        sb,
        with_r,
        with_b,
        ident,
    )
    t = jnp.arange(h, dtype=jnp.int32)
    return jax.tree.map(lambda a: a[jnp.maximum(n - h + t, 0)], suffix)


def generic_state_to_carry(algo, monoid: Monoid, state: PyTree, window: int) -> PyTree:
    """Fallback carry extraction: masked evict+query sweeps.

    Works for ANY algorithm exposing the functional protocol, at
    O(capacity + window) sequential evicts (each worst-case O(1) for the
    paper's algorithms) — the per-algorithm specializations do the same in
    one gather + one log-depth scan.  Also serves as the oracle for them.
    """
    h = int(window) - 1
    ident = monoid.identity()
    buf = jax.tree.map(lambda i: jnp.broadcast_to(i, (h,) + i.shape), ident)
    if h == 0:
        return buf
    cap = state.capacity

    def trim(_, s):
        return lazy_cond(
            algo.size(s) > h, lambda x: algo.evict(monoid, x), lambda x: x, s
        )

    s = lazy_fori(0, max(cap - h, 0), trim, state)

    def body(t, carry):
        s, buf = carry
        q = algo.query(monoid, s)
        buf = jax.tree.map(lambda a, v: a.at[t].set(v), buf, q)
        s = lazy_cond(
            algo.size(s) > h - t - 1,
            lambda x: algo.evict(monoid, x),
            lambda x: x,
            s,
        )
        return s, buf

    _, buf = lazy_fori(0, h, body, (s, buf))
    return buf


def carry_pseudo_elements(monoid: Monoid, carry: PyTree) -> PyTree:
    """Per-element contributions g_t with ``carry[t] = g_t ⊗ carry[t+1]``.

    Recoverable only with an inverse AND commutativity: ``inverse_front``
    removes the *front* element, but here it must strip the *suffix*
    ``carry[t+1]`` — order-safe only when ⊗ commutes.  Raises for anything
    else (a silently wrong window would be worse)."""
    if not (monoid.invertible and monoid.commutative):
        raise NotImplementedError(
            f"carry pseudo-elements need an invertible commutative monoid "
            f"(got {monoid.name}); use an algorithm with a specialized "
            f"carry_to_state (two_stacks/two_stacks_lite/daba/daba_lite)"
        )
    ident = monoid.identity()
    nxt = jax.tree.map(
        lambda a, i: jnp.concatenate(
            [a[1:], jnp.asarray(i, a.dtype)[None]], axis=0
        ),
        carry,
        ident,
    )
    return jax.vmap(monoid.inverse_front)(carry, nxt)


def generic_carry_to_state(algo, monoid: Monoid, carry: PyTree, capacity: int) -> PyTree:
    """Fallback state construction: pseudo-element insertion.

    The :func:`carry_pseudo_elements` g_t are inserted as pre-lifted values.
    Algorithms whose layout stores suffix aggregates directly (two_stacks,
    two_stacks_lite, daba, daba_lite) export exact specializations instead
    and never hit the invertible+commutative restriction.
    """
    state = algo.init(monoid, capacity)
    h = chunk_length(carry)
    if h == 0:
        return state
    g = carry_pseudo_elements(monoid, carry)
    prelifted = dataclasses.replace(
        monoid, name=monoid.name + "#prelifted", lift=lambda v: v
    )
    return insert_bulk(algo, prelifted, state, g)


def state_to_carry(algo, monoid: Monoid, state: PyTree, window: int) -> PyTree:
    """Convert a live SWAG state into a chunked-stream carry; dispatches to
    the algorithm's specialized conversion when it has one."""
    fn = getattr(algo, "state_to_carry", None)
    if fn is not None:
        return fn(monoid, state, window)
    return generic_state_to_carry(algo, monoid, state, window)


def carry_to_state(algo, monoid: Monoid, carry: PyTree, capacity: int) -> PyTree:
    """Build a live SWAG state whose window suffixes equal ``carry``.

    The reconstructed state represents the window *as the carry sees it*:
    ``len(carry)`` elements whose suffix folds are the carry entries — exact
    when the source window held ≥ window-1 elements (shorter histories are
    carried as duplicated front-truncated folds)."""
    fn = getattr(algo, "carry_to_state", None)
    if fn is not None:
        return fn(monoid, carry, capacity)
    return generic_carry_to_state(algo, monoid, carry, capacity)


def state_from_chunk(algo, monoid: Monoid, values: PyTree, capacity: int) -> PyTree:
    """Fresh state holding exactly the chunk contents — fully vectorized.

    The chunked stream's final-state rebuild: one log-depth suffix scan of
    the lifted chunk IS a valid carry of length k, and ``carry_to_state``
    lays it out with no per-element loop (recalc/soe skip even the scan and
    store the raw values directly).  Algorithms without either specialization
    fall back to ``insert_bulk`` into an empty state.  Equivalent to k
    inserts into a fresh state (exact for integer monoids, reassociated for
    floats); requires k ≤ capacity.
    """
    fn = getattr(algo, "state_from_chunk", None)
    if fn is not None:
        return fn(monoid, values, capacity)
    fn = getattr(algo, "carry_to_state", None)
    if fn is not None:
        return fn(
            monoid, chunk_suffix_scan(monoid, lift_chunk(monoid, values)), capacity
        )
    return insert_bulk(algo, monoid, algo.init(monoid, capacity), values)


# ---------------------------------------------------------------------------
# State dataclass registration helper
# ---------------------------------------------------------------------------


def swag_state(cls):
    """Decorator: freeze + register a SWAG state dataclass as a JAX pytree.

    All fields are dynamic (pytree children) except fields whose name is
    ``capacity`` (static metadata).
    """
    cls = dataclasses.dataclass(frozen=True)(cls)
    fields = [f.name for f in dataclasses.fields(cls)]
    data_fields = [f for f in fields if f != "capacity"]
    meta_fields = [f for f in fields if f == "capacity"]
    return jax.tree_util.register_dataclass(
        cls, data_fields=data_fields, meta_fields=meta_fields
    )


# ---------------------------------------------------------------------------
# Facade
# ---------------------------------------------------------------------------


class SWAG:
    """Object-style facade binding (algorithm module, monoid, capacity).

    ``algo`` is any module exposing ``init/insert/evict/query/size`` with the
    functional signatures documented above.  With ``use_jit=True`` the three
    operations are jitted (donating the state argument); eager otherwise.
    """

    def __init__(self, algo, monoid: Monoid, capacity: int, use_jit: bool = False):
        self.algo = algo
        self.monoid = monoid
        self.capacity = capacity
        self._state = algo.init(monoid, capacity)
        if use_jit:
            self._insert = jax.jit(
                lambda s, v: algo.insert(monoid, s, v), donate_argnums=(0,)
            )
            self._evict = jax.jit(lambda s: algo.evict(monoid, s), donate_argnums=(0,))
            self._query = jax.jit(lambda s: algo.query(monoid, s))
        else:
            self._insert = lambda s, v: algo.insert(monoid, s, v)
            self._evict = lambda s: algo.evict(monoid, s)
            self._query = lambda s: algo.query(monoid, s)

    @property
    def state(self):
        return self._state

    def insert(self, v) -> None:
        self._state = self._insert(self._state, v)

    def evict(self) -> None:
        self._state = self._evict(self._state)

    def query(self):
        return self._query(self._state)

    def lowered_query(self):
        return self.monoid.lower(self.query())

    def size(self) -> int:
        return int(self.algo.size(self._state))

    def __len__(self) -> int:
        return self.size()
