"""Shared machinery for the SWAG (sliding-window aggregation) algorithms.

Every algorithm in :mod:`repro.core` is a *functional* state machine:

    state = algo.init(monoid, capacity)
    state = algo.insert(monoid, state, element)     # element: In type
    state = algo.evict(monoid, state)
    agg   = algo.query(monoid, state)               # Agg type (pre-lower)

States are registered pytrees (ring buffers + int32 pointers), so they can be
``jit``-ted, ``vmap``-ped across independent windows, ``scan``-ned over
streams, sharded with ``pjit``, and checkpointed like any other model state.

Control flow uses :func:`lazy_cond`, which executes only the taken branch in
eager mode (matching the paper's pseudocode exactly — this is what makes the
combine-count theorems directly testable) and lowers to ``lax.cond`` under
tracing (where vmap turns it into ``select``; see DESIGN.md §2.1).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.monoids import Monoid

PyTree = Any


# ---------------------------------------------------------------------------
# Control flow
# ---------------------------------------------------------------------------


def lazy_cond(pred, true_fn: Callable, false_fn: Callable, *operands):
    """``lax.cond`` that short-circuits when ``pred`` is concrete.

    In eager execution the paper's sequential semantics (only the taken branch
    runs, so ⊗-counts match the theorems).  Under ``jit``/``vmap`` this is a
    regular ``lax.cond`` (both branches traced; vmap executes both and
    selects — constant, uniform work per lane: the SIMD story of DESIGN.md).
    """
    try:
        concrete = bool(pred)
    except (jax.errors.TracerBoolConversionError, jax.errors.ConcretizationTypeError):
        return jax.lax.cond(pred, true_fn, false_fn, *operands)
    return true_fn(*operands) if concrete else false_fn(*operands)


def lazy_fori(lo, hi, body: Callable, init):
    """``lax.fori_loop`` that runs a Python loop when bounds are concrete."""
    try:
        lo_c, hi_c = int(lo), int(hi)
    except (jax.errors.TracerBoolConversionError, jax.errors.ConcretizationTypeError, TypeError):
        return jax.lax.fori_loop(lo, hi, body, init)
    carry = init
    for i in range(lo_c, hi_c):
        carry = body(i, carry)
    return carry


# ---------------------------------------------------------------------------
# Ring buffers of monoid elements
# ---------------------------------------------------------------------------


def alloc_ring(monoid: Monoid, capacity: int) -> PyTree:
    """Allocate a ring buffer of ``capacity`` Agg elements, filled with 1."""
    ident = monoid.identity()
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (capacity,) + x.shape).copy(), ident
    )


def ring_get(buf: PyTree, ptr, capacity: int) -> PyTree:
    """Read the element at logical pointer ``ptr`` (physical ``ptr % cap``)."""
    idx = jnp.asarray(ptr, jnp.int32) % capacity
    return jax.tree.map(lambda a: a[idx], buf)


def ring_set(buf: PyTree, ptr, elem: PyTree, capacity: int) -> PyTree:
    idx = jnp.asarray(ptr, jnp.int32) % capacity
    return jax.tree.map(lambda a, e: a.at[idx].set(e), buf, elem)


def i32(x) -> jax.Array:
    return jnp.asarray(x, jnp.int32)


# ---------------------------------------------------------------------------
# State dataclass registration helper
# ---------------------------------------------------------------------------


def swag_state(cls):
    """Decorator: freeze + register a SWAG state dataclass as a JAX pytree.

    All fields are dynamic (pytree children) except fields whose name is
    ``capacity`` (static metadata).
    """
    cls = dataclasses.dataclass(frozen=True)(cls)
    fields = [f.name for f in dataclasses.fields(cls)]
    data_fields = [f for f in fields if f != "capacity"]
    meta_fields = [f for f in fields if f == "capacity"]
    return jax.tree_util.register_dataclass(
        cls, data_fields=data_fields, meta_fields=meta_fields
    )


# ---------------------------------------------------------------------------
# Facade
# ---------------------------------------------------------------------------


class SWAG:
    """Object-style facade binding (algorithm module, monoid, capacity).

    ``algo`` is any module exposing ``init/insert/evict/query/size`` with the
    functional signatures documented above.  With ``use_jit=True`` the three
    operations are jitted (donating the state argument); eager otherwise.
    """

    def __init__(self, algo, monoid: Monoid, capacity: int, use_jit: bool = False):
        self.algo = algo
        self.monoid = monoid
        self.capacity = capacity
        self._state = algo.init(monoid, capacity)
        if use_jit:
            self._insert = jax.jit(
                lambda s, v: algo.insert(monoid, s, v), donate_argnums=(0,)
            )
            self._evict = jax.jit(lambda s: algo.evict(monoid, s), donate_argnums=(0,))
            self._query = jax.jit(lambda s: algo.query(monoid, s))
        else:
            self._insert = lambda s, v: algo.insert(monoid, s, v)
            self._evict = lambda s: algo.evict(monoid, s)
            self._query = lambda s: algo.query(monoid, s)

    @property
    def state(self):
        return self._state

    def insert(self, v) -> None:
        self._state = self._insert(self._state, v)

    def evict(self) -> None:
        self._state = self._evict(self._state)

    def query(self):
        return self._query(self._state)

    def lowered_query(self):
        return self.monoid.lower(self.query())

    def size(self) -> int:
        return int(self.algo.size(self._state))

    def __len__(self) -> int:
        return self.size()
