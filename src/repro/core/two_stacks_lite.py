"""Two-Stacks Lite (paper §4): amortized O(1), worst-case O(n), n+1 space.

Improvements over Two-Stacks (following Hammer Slide [35]):
  * none of the front stack's val fields are ever read → store only aggs;
  * only the back stack's LAST agg is read → keep it in a scalar ``aggB``;
  * one physical deque (ring buffer) with a virtual boundary pointer B.

Ring layout: logical pointers F ≤ B ≤ E.  ``deque[F..B)`` is the front
sublist l_F (element i holds v_i ⊗ … ⊗ v_{B-F-1}); ``deque[B..E)`` is the
back sublist l_B (raw lifted values); ``aggB`` holds the product of l_B.
"""

from __future__ import annotations

import dataclasses

import jax

import jax.numpy as jnp

from repro.core.monoids import Monoid
from repro.core.swag_base import (
    alloc_ring,
    chunk_fold,
    chunk_length,
    i32,
    lazy_cond,
    lazy_fori,
    lift_chunk,
    ring_gather,
    ring_get,
    ring_set,
    suffix_carry_from_regions,
    swag_state,
)

PyTree = object


@swag_state
class TwoStacksLiteState:
    deque: PyTree  # ring of partial aggregates
    agg_b: PyTree  # aggregate of the back sublist
    f: jax.Array  # logical pointers (monotone int32)
    b: jax.Array
    e: jax.Array
    capacity: int


def init(monoid: Monoid, capacity: int) -> TwoStacksLiteState:
    return TwoStacksLiteState(
        deque=alloc_ring(monoid, capacity),
        agg_b=monoid.identity(),
        f=i32(0),
        b=i32(0),
        e=i32(0),
        capacity=capacity,
    )


def size(state: TwoStacksLiteState):
    return state.e - state.f


def _pi_f(monoid: Monoid, state: TwoStacksLiteState):
    return lazy_cond(
        state.f == state.b,
        lambda: monoid.identity(),
        lambda: ring_get(state.deque, state.f, state.capacity),
    )


def query(monoid: Monoid, state: TwoStacksLiteState):
    return monoid.combine(_pi_f(monoid, state), state.agg_b)


def insert(monoid: Monoid, state: TwoStacksLiteState, value) -> TwoStacksLiteState:
    v = monoid.lift(value)
    return TwoStacksLiteState(
        deque=ring_set(state.deque, state.e, v, state.capacity),
        agg_b=monoid.combine(state.agg_b, v),  # 1 ⊗-invocation
        f=state.f,
        b=state.b,
        e=state.e + 1,
        capacity=state.capacity,
    )


def _flip(monoid: Monoid, state: TwoStacksLiteState) -> TwoStacksLiteState:
    """In-place suffix combine (paper lines 11–16): deque[i] ← deque[i] ⊗
    deque[i+1] from right to left, then l_F spans everything and l_B empties.
    """

    n = state.e - state.f

    def body(k, deque):
        # k = 0 … n-2 walks I from E-2 down to F.
        i = state.e - 2 - k
        cur = ring_get(deque, i, state.capacity)
        nxt = ring_get(deque, i + 1, state.capacity)
        return ring_set(deque, i, monoid.combine(cur, nxt), state.capacity)

    deque = lazy_fori(0, n - 1, body, state.deque)
    return TwoStacksLiteState(
        deque=deque,
        agg_b=monoid.identity(),
        f=state.f,
        b=state.e,  # front sublist now spans the whole deque
        e=state.e,
        capacity=state.capacity,
    )


def evict(monoid: Monoid, state: TwoStacksLiteState) -> TwoStacksLiteState:
    needs_flip = (state.f == state.b) & (state.b != state.e)
    state = lazy_cond(
        needs_flip, lambda s: _flip(monoid, s), lambda s: s, state
    )
    return TwoStacksLiteState(
        deque=state.deque,
        agg_b=state.agg_b,
        f=state.f + 1,
        b=state.b,
        e=state.e,
        capacity=state.capacity,
    )


# --- bulk ops (chunked streaming protocol) ---------------------------------


_replace = dataclasses.replace  # @swag_state states are frozen dataclasses


def insert_bulk(monoid: Monoid, state: TwoStacksLiteState, values) -> TwoStacksLiteState:
    """k inserts as one vectorized ring write + one log-depth chunk fold.

    The back sublist stores raw lifted values, so a chunk appends wholesale;
    ``aggB`` picks up the chunk's total in a single reduction instead of a
    k-long sequential ⊗-chain.  Requires size + k ≤ capacity (same ring
    constraint as per-element inserts).
    """
    vs = lift_chunk(monoid, values)
    k = chunk_length(vs)
    idx = (state.e + jnp.arange(k, dtype=jnp.int32)) % state.capacity
    deque = jax.tree.map(lambda a, v: a.at[idx].set(v), state.deque, vs)
    return _replace(
        state,
        deque=deque,
        agg_b=monoid.combine(state.agg_b, chunk_fold(monoid, vs)),
        e=state.e + k,
    )


def state_to_carry(monoid: Monoid, state: TwoStacksLiteState, window: int):
    """Warm-carry extraction: the front sublist already stores fold-to-B
    suffix aggregates, the back stores raw values — one degenerate-pointer
    call into the shared region helper (L = R = A = B)."""
    length = state.capacity + 1
    log = ring_gather(state.deque, state.f, state.capacity, length)
    d = state.b - state.f
    return suffix_carry_from_regions(
        monoid, log, log, state.e - state.f, d, d, d, d, window
    )


def carry_to_state(monoid: Monoid, carry, capacity: int) -> TwoStacksLiteState:
    """Exact carry import: the carry IS a front sublist (suffix aggregates
    fold-to-B), so it lands in the deque verbatim with an empty back."""
    h = chunk_length(carry)
    if h > capacity:
        raise ValueError(f"carry of {h} elements exceeds capacity {capacity}")
    state = init(monoid, capacity)
    if h == 0:
        return state
    idx = jnp.arange(h, dtype=jnp.int32)
    deque = jax.tree.map(lambda a, c: a.at[idx].set(c), state.deque, carry)
    return _replace(state, deque=deque, b=i32(h), e=i32(h))


def evict_bulk(monoid: Monoid, state: TwoStacksLiteState, k) -> TwoStacksLiteState:
    """k evicts with at most ONE flip instead of a flip check per element.

    Pointer-advance to the F/B boundary first, then — only if evictions
    remain — run the single suffix-combine flip and advance the rest.
    Equivalent to k sequential evicts: the flip fires exactly when the k-th
    eviction would strictly cross the boundary.
    """
    k = i32(k)
    kb = jnp.minimum(k, state.b - state.f)  # evictions before the boundary
    state = _replace(state, f=state.f + kb)

    def flip_then_advance(s: TwoStacksLiteState) -> TwoStacksLiteState:
        s = _flip(monoid, s)
        return _replace(s, f=s.f + (k - kb))

    return lazy_cond(k > kb, flip_then_advance, lambda s: s, state)
