"""repro.core — the paper's contribution: worst-case O(1) sliding-window
aggregation (DABA / DABA Lite) and the algorithm family it belongs to.

Modules
-------
monoids          lift/combine/lower aggregation framework (paper §2.2), incl.
                 product_monoid (N named metrics as one element)
swag_base        functional-state machinery shared by all algorithms, the
                 bulk-op protocol (insert_bulk/evict_bulk: every algorithm
                 accepts whole chunks; two_stacks_lite and daba_lite have
                 specialized amortized implementations), and the warm-state
                 carry protocol (state_to_carry/carry_to_state: any live
                 window converts to/from a chunked-stream carry; every
                 algorithm has a one-scan specialization)
recalc           recalculate-from-scratch baseline (O(n) query)
soe              subtract-on-evict baseline (invertible monoids only)
two_stacks       amortized O(1) / worst-case O(n), 2n space (paper §3)
two_stacks_lite  amortized O(1) / worst-case O(n), n+1 space (paper §4)
flatfit          amortized O(1) index traverser (paper §7 baseline; eager)
daba             worst-case O(1), 2n space (paper §5)
daba_lite        worst-case O(1), n+2 space (paper §6) — headline algorithm
batched          vmapped multi-window SWAG, shardable over meshes; stream()
                 auto-routes large streams (cold OR warm) through the
                 chunked engine
chunked          ChunkedStream: chunk-at-a-time bulk streaming engine
                 (paper §8.2 coarse-grained direction) — intra-chunk outputs
                 from the sliding_window/suffix_scan Pallas kernels (scalar
                 monoids from kernels/ops_registry) or generic associative
                 scans (any pytree monoid), cross-chunk via a suffix-tail
                 carry (warm-initializable from any live state); ~3
                 combines/element independent of window
telemetry        WindowedTelemetry: N named windowed metrics as ONE jitted
                 product-monoid state (single dispatch per observation,
                 batched snapshot, chunked observe_bulk) — the system's
                 windowed-stats layer (data/train/serve all sit on it)
windowed_state   sliding-window SSM/linear-attention state via DABA Lite;
                 ChunkedWindowedStateCell.prefill consumes whole chunks
event_time       event-time windows: TimestampedWindow (per-element horizon
                 windows with watermark-driven bulk evictions over any SWAG
                 algorithm) and EventTimeChunkedStream (bulk out-of-order
                 engine: (ts, x) chunks, bounded reorder buffer, late-data
                 policies, exact non-commutative merge order)
keyed            per-key sliding windows at scale: KeyDirectory (JAX-native
                 open-addressing key → slot map with LRU/TTL eviction),
                 KeyedWindowStore (slots × carry-lane windows, one fused
                 segment-wise bulk update per mixed-key chunk),
                 KeyedChunkedStream (chunked driver) and ShardedKeyedStore
                 (hash-sharded key space over a mesh axis, collective-free)
"""

from repro.core import (
    chunked,
    daba,
    daba_lite,
    event_time,
    flatfit,
    keyed,
    monoids,
    recalc,
    soe,
    swag_base,
    telemetry,
    two_stacks,
    two_stacks_lite,
)
from repro.core.event_time import EventTimeChunkedStream, TimestampedWindow
from repro.core.keyed import (
    KeyDirectory,
    KeyedChunkedStream,
    KeyedWindowStore,
    ShardedKeyedStore,
)
from repro.core.monoids import (
    Monoid,
    counting,
    get_monoid,
    available_monoids,
    product_monoid,
)
from repro.core.swag_base import (
    SWAG,
    carry_to_state,
    evict_bulk,
    insert_bulk,
    state_to_carry,
)
from repro.core.telemetry import KeyedTelemetry, WindowedTelemetry

ALGORITHMS = {
    "recalc": recalc,
    "soe": soe,
    "two_stacks": two_stacks,
    "two_stacks_lite": two_stacks_lite,
    "daba": daba,
    "daba_lite": daba_lite,
}

# Algorithms that work for ANY associative monoid (soe needs invertibility).
GENERAL_ALGORITHMS = {
    k: v for k, v in ALGORITHMS.items() if k != "soe"
}

# The paper's worst-case O(1) contributions.
CONSTANT_TIME_ALGORITHMS = {"daba": daba, "daba_lite": daba_lite}

# FlatFIT (paper §7 comparison set) is eager-only (mutable pointer chasing,
# queries compress) — kept out of ALGORITHMS, which assumes pytree states.
EAGER_ALGORITHMS = {"flatfit": flatfit}

__all__ = [
    "Monoid",
    "SWAG",
    "WindowedTelemetry",
    "KeyedTelemetry",
    "EventTimeChunkedStream",
    "TimestampedWindow",
    "KeyDirectory",
    "KeyedWindowStore",
    "KeyedChunkedStream",
    "ShardedKeyedStore",
    "counting",
    "get_monoid",
    "available_monoids",
    "product_monoid",
    "insert_bulk",
    "evict_bulk",
    "state_to_carry",
    "carry_to_state",
    "ALGORITHMS",
    "GENERAL_ALGORITHMS",
    "CONSTANT_TIME_ALGORITHMS",
]
