"""Windowed SSM / linear-attention state via DABA Lite (beyond-paper feature).

Gated-linear recurrences (RWKV-6, Mamba-2/SSD, GLA, ...) update a state
``s_t = d_t ⊙ s_{t-1} + u_t`` where ``d_t`` is a data-dependent decay and
``u_t`` an outer-product update (kᵀv).  Each token therefore contributes an
*affine map*; affine maps compose associatively, non-commutatively, and are
non-invertible when any decay channel underflows to 0 — precisely the monoid
class the paper targets.

A **sliding window of W tokens** of such a recurrence is the composition of
the last W affine maps applied to s₀ = 0.  Naively recomputing it costs
O(W) per token; inverting the decay is numerically catastrophic (divide by
d ≈ 0).  DABA Lite maintains it *exactly* in worst-case O(1) combines per
token — an evicting, bounded-context decode state with uniform per-token
latency.  This powers the ``long_500k`` decode path for rwkv6-1.6b and
zamba2-1.2b (DESIGN.md §3, §5).

Shapes: the affine element is ``{"d": (H, K, 1), "u": (H, K, V)}`` broadcast
so that composition is elementwise on decay and a decay-scaled add on state
(K = key/state dim, V = value dim, H = heads).  For Mamba-2, d is scalar per
head: shape (H, 1, 1).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import daba_lite, swag_base
from repro.core.monoids import Monoid, affine_monoid

PyTree = Any


@dataclasses.dataclass(frozen=True)
class WindowedStateCell:
    """Sliding-window recurrence cell: y_t reads the state of the last W tokens.

    Usage (decode loop, one token per call):

        cell  = WindowedStateCell(heads=H, key_dim=K, value_dim=V, window=W)
        state = cell.init()
        state, s_win = cell.update(state, decay, update)   # s_win: (H, K, V)

    ``decay``: (H, K, 1) or broadcastable — per-channel decay d_t in [0, 1].
    ``update``: (H, K, V) — the additive update u_t (e.g. k_tᵀ v_t).
    ``s_win`` is EXACTLY sum_{i=t-W+1..t} (prod_{j>i} d_j) u_i — the state a
    fresh recurrence started W tokens ago would have.  Worst-case 3 combines
    per token (Theorem 13), independent of W.
    """

    heads: int
    key_dim: int
    value_dim: int
    window: int

    @property
    def monoid(self) -> Monoid:
        base = affine_monoid((self.heads, self.key_dim, self.value_dim))

        # Decay is stored broadcast-shaped (H, K, 1) to avoid materializing a
        # (H, K, V) decay; combine broadcasts it over the value dim.
        def identity():
            return {
                "d": jnp.ones((self.heads, self.key_dim, 1), jnp.float32),
                "u": jnp.zeros((self.heads, self.key_dim, self.value_dim), jnp.float32),
            }

        def combine(a, b):
            return {"d": a["d"] * b["d"], "u": b["d"] * a["u"] + b["u"]}

        def lift(e):
            return {"d": e["d"], "u": e["u"]}

        return dataclasses.replace(
            base, identity=identity, combine=combine, lift=lift,
            name=f"affine_h{self.heads}k{self.key_dim}v{self.value_dim}",
        )

    def init(self) -> PyTree:
        # capacity = window + 1: ring slack for the insert-then-evict step.
        return daba_lite.init(self.monoid, self.window + 1)

    def update(self, state: PyTree, decay: jax.Array, update: jax.Array):
        m = self.monoid
        state = daba_lite.insert(m, state, {"d": decay, "u": update})
        state = jax.lax.cond(
            daba_lite.size(state) > self.window,
            lambda s: daba_lite.evict(m, s),
            lambda s: s,
            state,
        )
        agg = daba_lite.query(m, state)
        return state, agg["u"]  # window map applied to s0 = 0

    def prefill(self, state: PyTree, decays: jax.Array, updates: jax.Array):
        """Scan a (T, …) chunk through the cell; returns (state, (T,H,K,V))."""

        def step(st, du):
            d, u = du
            return self.update(st, d, u)

        return jax.lax.scan(step, state, (decays, updates))


def reference_windowed_state(decays: jax.Array, updates: jax.Array, window: int):
    """O(T·W) oracle: for each t, run the recurrence fresh over the last W
    tokens.  decays: (T, H, K, 1); updates: (T, H, K, V) → (T, H, K, V)."""
    T = updates.shape[0]
    outs = []
    for t in range(T):
        lo = max(0, t - window + 1)
        s = jnp.zeros_like(updates[0])
        for j in range(lo, t + 1):
            s = decays[j] * s + updates[j]
        outs.append(s)
    return jnp.stack(outs)


@dataclasses.dataclass(frozen=True)
class ChunkedWindowedStateCell:
    """Coarse-grained windowed recurrence: DABA Lite over CHUNK aggregates.

    For very long windows (long_500k decode), storing one affine map per
    token would need W·(H·K·V) floats — the paper's n+2 space bound with a
    huge element type.  The paper's §8.2 coarse-grained sliding (Scotty-
    style pre-aggregation) composes: tokens accumulate into a *running
    chunk map*; every ``chunk`` tokens the completed chunk's map is inserted
    into a DABA Lite window of ``window_chunks`` elements and the oldest
    chunk is evicted.  The queryable state covers the last
    ``window_chunks·chunk ± chunk`` tokens — exact at chunk granularity,
    worst-case O(1) combines per token (DABA ops only fire at boundaries,
    and each is itself O(1) — no latency spike at chunk turnover, unlike a
    Two-Stacks flip which would recompute the whole window).
    """

    heads: int
    key_dim: int
    value_dim: int
    chunk: int
    window_chunks: int

    @property
    def monoid(self) -> Monoid:
        return WindowedStateCell(
            self.heads, self.key_dim, self.value_dim, 1
        ).monoid

    def init(self) -> PyTree:
        m = self.monoid
        return {
            "daba": daba_lite.init(m, self.window_chunks + 1),
            "partial": m.identity(),  # running (incomplete) chunk map
            "count": jnp.zeros((), jnp.int32),  # tokens in partial chunk
        }

    def update(self, state: PyTree, decay: jax.Array, update: jax.Array):
        m = self.monoid
        partial = m.combine(state["partial"], {"d": decay, "u": update})
        count = state["count"] + 1

        def rollover(args):
            daba, partial = args
            daba = daba_lite.insert(m, daba, partial)
            daba = jax.lax.cond(
                daba_lite.size(daba) > self.window_chunks,
                lambda s: daba_lite.evict(m, s),
                lambda s: s,
                daba,
            )
            return daba, m.identity()

        daba, partial = jax.lax.cond(
            count >= self.chunk,
            rollover,
            lambda args: args,
            (state["daba"], partial),
        )
        count = jnp.where(state["count"] + 1 >= self.chunk, 0, count)
        win = daba_lite.query(m, daba)
        eff = m.combine(win, partial)  # window ∘ current partial chunk
        new_state = {"daba": daba, "partial": partial, "count": count}
        return new_state, eff["u"]

    def prefill(self, state: PyTree, decays: jax.Array, updates: jax.Array):
        """Consume a (T, …) chunk of tokens in bulk; returns (state, (T,H,K,V)).

        The vectorized long-context prefill path (rwkv6 / zamba2): instead of
        a per-token scan, whole chunks are composed with log-depth prefix
        scans, the chunk-granular window comes from one generic VHGW sliding
        window over the chunk maps, and the final DABA Lite state is rebuilt
        through the bulk-op protocol (``insert_bulk``).  Output matches the
        sequential ``update`` loop up to float reassociation.

        Requires a fresh state (``init()``); falls back to the per-token scan
        when the state is warm or traced.
        """
        try:
            fresh = int(state["count"]) == 0 and int(
                daba_lite.size(state["daba"])
            ) == 0
        except (jax.errors.TracerArrayConversionError, jax.errors.ConcretizationTypeError):
            fresh = False
        if not fresh:
            def step(st, du):
                d, u = du
                return self.update(st, d, u)

            return jax.lax.scan(step, state, (decays, updates))

        from repro.core.chunked import tree_sliding_window  # local: avoid cycle

        m = self.monoid
        T, C, Wc = decays.shape[0], self.chunk, self.window_chunks
        n_full, rem = divmod(T, C)
        lifted = {"d": decays, "u": updates}
        ident = m.identity()

        outs = []
        win = None  # truncated chunk-window aggregates, (n_full, ...)
        if n_full:
            blocks = jax.tree.map(
                lambda a: a[: n_full * C].reshape((n_full, C) + a.shape[1:]),
                lifted,
            )
            intra = jax.lax.associative_scan(m.combine, blocks, axis=1)
            maps = jax.tree.map(lambda a: a[:, -1], intra)  # per-chunk totals
            win = tree_sliding_window(m, maps, Wc)
            win_shift = jax.tree.map(
                lambda w_, i: jnp.concatenate([i[None], w_[:-1]], axis=0),
                win,
                jax.tree.map(jnp.asarray, ident),
            )
            # token t in chunk c sees: window over chunks < c, then its own
            # running partial — except the chunk's last token, which sees the
            # just-rolled-over window (partial resets to identity there).
            full = jax.vmap(m.combine)(win_shift, intra)
            full = jax.tree.map(lambda a, w_: a.at[:, -1].set(w_), full, win)
            outs.append(
                jax.tree.map(lambda a: a.reshape((n_full * C,) + a.shape[2:]), full)
            )
        partial, count = ident, jnp.zeros((), jnp.int32)
        if rem:
            tail = jax.tree.map(lambda a: a[n_full * C:], lifted)
            p_rem = swag_base.chunk_prefix_scan(m, tail)
            w_last = (
                swag_base.tree_index(win, n_full - 1) if n_full else ident
            )
            outs.append(jax.vmap(m.combine, in_axes=(None, 0))(w_last, p_rem))
            partial, count = swag_base.tree_index(p_rem, rem - 1), jnp.asarray(rem, jnp.int32)
        out = jax.tree.map(lambda *ps: jnp.concatenate(ps, axis=0), *outs)

        daba = daba_lite.init(m, Wc + 1)
        k = min(Wc, n_full)
        if k:
            daba = daba_lite.insert_bulk(
                m, daba, jax.tree.map(lambda a: a[n_full - k:], maps)
            )
        state = {"daba": daba, "partial": partial, "count": count}
        return state, out["u"]
