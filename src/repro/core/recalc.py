"""Recalculate-from-scratch baseline (paper §8.1).

Maintains a FIFO ring of lifted values; ``query`` folds the whole window:
O(n) ⊗-invocations per query, O(1) per insert/evict.  Space: n partial
aggregates.  This is also the *oracle* used by the property tests — its
correctness is immediate from the ADT definition.
"""

from __future__ import annotations

import jax

from repro.core.monoids import Monoid
from repro.core.swag_base import (
    alloc_ring,
    i32,
    lazy_fori,
    ring_get,
    ring_set,
    swag_state,
)


@swag_state
class RecalcState:
    buf: object  # ring of lifted values
    front: jax.Array  # logical pointer, int32
    end: jax.Array
    capacity: int


def init(monoid: Monoid, capacity: int) -> RecalcState:
    return RecalcState(
        buf=alloc_ring(monoid, capacity), front=i32(0), end=i32(0), capacity=capacity
    )


def size(state: RecalcState):
    return state.end - state.front


def insert(monoid: Monoid, state: RecalcState, value) -> RecalcState:
    v = monoid.lift(value)
    buf = ring_set(state.buf, state.end, v, state.capacity)
    return RecalcState(
        buf=buf, front=state.front, end=state.end + 1, capacity=state.capacity
    )


def evict(monoid: Monoid, state: RecalcState) -> RecalcState:
    return RecalcState(
        buf=state.buf,
        front=state.front + 1,
        end=state.end,
        capacity=state.capacity,
    )


def query(monoid: Monoid, state: RecalcState):
    def body(i, acc):
        return monoid.combine(acc, ring_get(state.buf, state.front + i, state.capacity))

    return lazy_fori(0, state.end - state.front, body, monoid.identity())
