"""Recalculate-from-scratch baseline (paper §8.1).

Maintains a FIFO ring of lifted values; ``query`` folds the whole window:
O(n) ⊗-invocations per query, O(1) per insert/evict.  Space: n partial
aggregates.  This is also the *oracle* used by the property tests — its
correctness is immediate from the ADT definition.
"""

from __future__ import annotations

import jax

from repro.core.monoids import Monoid
from repro.core.swag_base import (
    alloc_ring,
    chunk_length,
    i32,
    lazy_fori,
    lift_chunk,
    ring_gather,
    ring_get,
    ring_set,
    suffix_carry_from_regions,
    swag_state,
)

import jax.numpy as jnp


@swag_state
class RecalcState:
    buf: object  # ring of lifted values
    front: jax.Array  # logical pointer, int32
    end: jax.Array
    capacity: int


def init(monoid: Monoid, capacity: int) -> RecalcState:
    return RecalcState(
        buf=alloc_ring(monoid, capacity), front=i32(0), end=i32(0), capacity=capacity
    )


def size(state: RecalcState):
    return state.end - state.front


def insert(monoid: Monoid, state: RecalcState, value) -> RecalcState:
    v = monoid.lift(value)
    buf = ring_set(state.buf, state.end, v, state.capacity)
    return RecalcState(
        buf=buf, front=state.front, end=state.end + 1, capacity=state.capacity
    )


def evict(monoid: Monoid, state: RecalcState) -> RecalcState:
    return RecalcState(
        buf=state.buf,
        front=state.front + 1,
        end=state.end,
        capacity=state.capacity,
    )


def query(monoid: Monoid, state: RecalcState):
    def body(i, acc):
        return monoid.combine(acc, ring_get(state.buf, state.front + i, state.capacity))

    return lazy_fori(0, state.end - state.front, body, monoid.identity())


def state_to_carry(monoid: Monoid, state: RecalcState, window: int):
    """Warm-carry extraction: the whole ring is raw lifted values — one
    suffix scan (all region offsets 0)."""
    length = state.capacity + 1
    log = ring_gather(state.buf, state.front, state.capacity, length)
    return suffix_carry_from_regions(
        monoid, log, log, state.end - state.front, 0, 0, 0, 0, window
    )


def state_from_chunk(monoid: Monoid, values, capacity: int) -> RecalcState:
    """Fresh state from a chunk: the ring stores raw lifted values, so the
    chunk lands verbatim (no scan needed)."""
    vs = lift_chunk(monoid, values)
    k = chunk_length(vs)
    if k > capacity:
        raise ValueError(f"chunk of {k} elements exceeds capacity {capacity}")
    state = init(monoid, capacity)
    idx = jnp.arange(k, dtype=jnp.int32)
    buf = jax.tree.map(lambda a, v: a.at[idx].set(v), state.buf, vs)
    return RecalcState(buf=buf, front=i32(0), end=i32(k), capacity=capacity)
