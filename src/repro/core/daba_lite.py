"""DABA Lite (paper §6) — worst-case O(1) SWAG in n+2 partial aggregates.

This is the paper's headline new algorithm.  Relative to DABA it drops the
val fields entirely: left-aggregated sublists never have their vals read, and
right-aggregated sublists only need their *total* aggregate — kept in the two
scalars ``aggRA`` (product of l_R ∪ l_A, valid whenever L ≠ R) and ``aggB``
(product of l_B).  Deque slots hold a single partial aggregate:

    [F,L): aggregate from element to right end of l_F (i.e., to B)
    [L,R): aggregate from element to right end of l_L (i.e., to R)
    [R,A): RAW lifted window value v_i
    [A,B): aggregate from element to right end of l_A (i.e., to B)
    [B,E): RAW lifted window value v_i

Worst case ⊗-invocations: ≤3 per insert, ≤2 per evict, ≤1 per query
(Theorem 13); size invariants identical to DABA.
"""

from __future__ import annotations

import jax

from repro.core.monoids import Monoid
from repro.core.swag_base import (
    alloc_ring,
    i32,
    lazy_cond,
    ring_get,
    ring_set,
    swag_state,
)

PyTree = object


@swag_state
class DabaLiteState:
    deque: PyTree  # ring of single partial aggregates
    agg_ra: PyTree  # product of l_R ∪ l_A (valid when L ≠ R)
    agg_b: PyTree  # product of l_B
    f: jax.Array
    l: jax.Array
    r: jax.Array
    a: jax.Array
    b: jax.Array
    e: jax.Array
    capacity: int


def _replace(state: DabaLiteState, **kw) -> DabaLiteState:
    fields = dict(
        deque=state.deque, agg_ra=state.agg_ra, agg_b=state.agg_b,
        f=state.f, l=state.l, r=state.r, a=state.a, b=state.b, e=state.e,
        capacity=state.capacity,
    )
    fields.update(kw)
    return DabaLiteState(**fields)


def init(monoid: Monoid, capacity: int) -> DabaLiteState:
    return DabaLiteState(
        deque=alloc_ring(monoid, capacity),
        agg_ra=monoid.identity(),
        agg_b=monoid.identity(),
        f=i32(0), l=i32(0), r=i32(0), a=i32(0), b=i32(0), e=i32(0),
        capacity=capacity,
    )


def size(state: DabaLiteState):
    return state.e - state.f


# --- Π helpers (paper lines 1–6): O(1), no ⊗-invocations -------------------


def _pi_f(m: Monoid, s: DabaLiteState):
    return lazy_cond(
        s.f == s.b, lambda: m.identity(),
        lambda: ring_get(s.deque, s.f, s.capacity),
    )


def _pi_l(m: Monoid, s: DabaLiteState):
    return lazy_cond(
        s.l == s.r, lambda: m.identity(),
        lambda: ring_get(s.deque, s.l, s.capacity),
    )


def _pi_a(m: Monoid, s: DabaLiteState):
    return lazy_cond(
        s.a == s.b, lambda: m.identity(),
        lambda: ring_get(s.deque, s.a, s.capacity),
    )


def query(monoid: Monoid, state: DabaLiteState):
    return monoid.combine(_pi_f(monoid, state), state.agg_b)


# --- fixup (paper lines 18–34) ---------------------------------------------


def _fixup(m: Monoid, s: DabaLiteState) -> DabaLiteState:
    def singleton(s: DabaLiteState) -> DabaLiteState:
        # |l_F| = 0 ∧ |l_B| = 1: relabel the lone raw value as the new l_F
        # (a singleton's raw value IS its aggregate); reset scalars.
        return _replace(
            s, b=s.e, a=s.e, r=s.e, l=s.e,
            agg_ra=m.identity(), agg_b=m.identity(),
        )

    def non_singleton(s: DabaLiteState) -> DabaLiteState:
        def flip(s: DabaLiteState) -> DabaLiteState:
            # l_F → l_L (already right-aggregated to B = new R's right end),
            # l_B → l_R (raw values, as l_R requires).  aggRA inherits aggB.
            return _replace(
                s, l=s.f, a=s.e, b=s.e,
                agg_ra=s.agg_b, agg_b=m.identity(),
            )

        s = lazy_cond(s.l == s.b, flip, lambda s: s, s)

        def shift(s: DabaLiteState) -> DabaLiteState:
            # L = R = A: slide the (empty) inner sublists right by one.
            # aggRA needs no update: it is only read when L ≠ R.
            return _replace(s, a=s.a + 1, r=s.r + 1, l=s.l + 1)

        def shrink(s: DabaLiteState) -> DabaLiteState:
            # *L ← Π_L ⊗ aggRA  — top of l_L joins the front portion;
            # aggRA = product of l_R ∪ l_A = v_R ⊗ … ⊗ v_{B-1}.
            new_l = m.combine(_pi_l(m, s), s.agg_ra)  # 1 ⊗
            deque = ring_set(s.deque, s.l, new_l, s.capacity)
            s = _replace(s, deque=deque, l=s.l + 1)
            # *(A-1) ← *(A-1) ⊗ Π_A — the raw value v_{A-1} (top of l_R)
            # becomes the new head of the accumulator l_A.
            raw = ring_get(s.deque, s.a - 1, s.capacity)
            new_a = m.combine(raw, _pi_a(m, s))  # 1 ⊗
            deque = ring_set(s.deque, s.a - 1, new_a, s.capacity)
            # l_R ∪ l_A occupies the same elements, so aggRA is unchanged.
            return _replace(s, deque=deque, a=s.a - 1)

        return lazy_cond(s.l == s.r, shift, shrink, s)

    return lazy_cond(s.f == s.b, singleton, non_singleton, s)


def insert(monoid: Monoid, state: DabaLiteState, value) -> DabaLiteState:
    v = monoid.lift(value)
    s = _replace(
        state,
        deque=ring_set(state.deque, state.e, v, state.capacity),
        agg_b=monoid.combine(state.agg_b, v),  # 1 ⊗-invocation
        e=state.e + 1,
    )
    return _fixup(monoid, s)


def evict(monoid: Monoid, state: DabaLiteState) -> DabaLiteState:
    s = _replace(state, f=state.f + 1)
    return _fixup(monoid, s)
