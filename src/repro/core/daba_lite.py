"""DABA Lite (paper §6) — worst-case O(1) SWAG in n+2 partial aggregates.

This is the paper's headline new algorithm.  Relative to DABA it drops the
val fields entirely: left-aggregated sublists never have their vals read, and
right-aggregated sublists only need their *total* aggregate — kept in the two
scalars ``aggRA`` (product of l_R ∪ l_A, valid whenever L ≠ R) and ``aggB``
(product of l_B).  Deque slots hold a single partial aggregate:

    [F,L): aggregate from element to right end of l_F (i.e., to B)
    [L,R): aggregate from element to right end of l_L (i.e., to R)
    [R,A): RAW lifted window value v_i
    [A,B): aggregate from element to right end of l_A (i.e., to B)
    [B,E): RAW lifted window value v_i

Worst case ⊗-invocations: ≤3 per insert, ≤2 per evict, ≤1 per query
(Theorem 13); size invariants identical to DABA.
"""

from __future__ import annotations

import dataclasses

import jax

import jax.numpy as jnp

from repro.core.monoids import Monoid
from repro.core.swag_base import (
    alloc_ring,
    chunk_length,
    i32,
    lazy_cond,
    lazy_fori,
    lift_chunk,
    ring_gather,
    ring_get,
    ring_set,
    suffix_carry_from_regions,
    swag_state,
    tree_index,
)

PyTree = object


@swag_state
class DabaLiteState:
    deque: PyTree  # ring of single partial aggregates
    agg_ra: PyTree  # product of l_R ∪ l_A (valid when L ≠ R)
    agg_b: PyTree  # product of l_B
    f: jax.Array
    l: jax.Array
    r: jax.Array
    a: jax.Array
    b: jax.Array
    e: jax.Array
    capacity: int


_replace = dataclasses.replace  # @swag_state states are frozen dataclasses


def init(monoid: Monoid, capacity: int) -> DabaLiteState:
    return DabaLiteState(
        deque=alloc_ring(monoid, capacity),
        agg_ra=monoid.identity(),
        agg_b=monoid.identity(),
        f=i32(0), l=i32(0), r=i32(0), a=i32(0), b=i32(0), e=i32(0),
        capacity=capacity,
    )


def size(state: DabaLiteState):
    return state.e - state.f


# --- Π helpers (paper lines 1–6): O(1), no ⊗-invocations -------------------


def _pi_f(m: Monoid, s: DabaLiteState):
    return lazy_cond(
        s.f == s.b, lambda: m.identity(),
        lambda: ring_get(s.deque, s.f, s.capacity),
    )


def _pi_l(m: Monoid, s: DabaLiteState):
    return lazy_cond(
        s.l == s.r, lambda: m.identity(),
        lambda: ring_get(s.deque, s.l, s.capacity),
    )


def _pi_a(m: Monoid, s: DabaLiteState):
    return lazy_cond(
        s.a == s.b, lambda: m.identity(),
        lambda: ring_get(s.deque, s.a, s.capacity),
    )


def query(monoid: Monoid, state: DabaLiteState):
    return monoid.combine(_pi_f(monoid, state), state.agg_b)


# --- fixup (paper lines 18–34) ---------------------------------------------


def _fixup(m: Monoid, s: DabaLiteState) -> DabaLiteState:
    def singleton(s: DabaLiteState) -> DabaLiteState:
        # |l_F| = 0 ∧ |l_B| = 1: relabel the lone raw value as the new l_F
        # (a singleton's raw value IS its aggregate); reset scalars.
        return _replace(
            s, b=s.e, a=s.e, r=s.e, l=s.e,
            agg_ra=m.identity(), agg_b=m.identity(),
        )

    def non_singleton(s: DabaLiteState) -> DabaLiteState:
        def flip(s: DabaLiteState) -> DabaLiteState:
            # l_F → l_L (already right-aggregated to B = new R's right end),
            # l_B → l_R (raw values, as l_R requires).  aggRA inherits aggB.
            return _replace(
                s, l=s.f, a=s.e, b=s.e,
                agg_ra=s.agg_b, agg_b=m.identity(),
            )

        s = lazy_cond(s.l == s.b, flip, lambda s: s, s)

        def shift(s: DabaLiteState) -> DabaLiteState:
            # L = R = A: slide the (empty) inner sublists right by one.
            # aggRA needs no update: it is only read when L ≠ R.
            return _replace(s, a=s.a + 1, r=s.r + 1, l=s.l + 1)

        def shrink(s: DabaLiteState) -> DabaLiteState:
            # *L ← Π_L ⊗ aggRA  — top of l_L joins the front portion;
            # aggRA = product of l_R ∪ l_A = v_R ⊗ … ⊗ v_{B-1}.
            new_l = m.combine(_pi_l(m, s), s.agg_ra)  # 1 ⊗
            deque = ring_set(s.deque, s.l, new_l, s.capacity)
            s = _replace(s, deque=deque, l=s.l + 1)
            # *(A-1) ← *(A-1) ⊗ Π_A — the raw value v_{A-1} (top of l_R)
            # becomes the new head of the accumulator l_A.
            raw = ring_get(s.deque, s.a - 1, s.capacity)
            new_a = m.combine(raw, _pi_a(m, s))  # 1 ⊗
            deque = ring_set(s.deque, s.a - 1, new_a, s.capacity)
            # l_R ∪ l_A occupies the same elements, so aggRA is unchanged.
            return _replace(s, deque=deque, a=s.a - 1)

        return lazy_cond(s.l == s.r, shift, shrink, s)

    return lazy_cond(s.f == s.b, singleton, non_singleton, s)


def insert(monoid: Monoid, state: DabaLiteState, value) -> DabaLiteState:
    v = monoid.lift(value)
    s = _replace(
        state,
        deque=ring_set(state.deque, state.e, v, state.capacity),
        agg_b=monoid.combine(state.agg_b, v),  # 1 ⊗-invocation
        e=state.e + 1,
    )
    return _fixup(monoid, s)


def evict(monoid: Monoid, state: DabaLiteState) -> DabaLiteState:
    s = _replace(state, f=state.f + 1)
    return _fixup(monoid, s)


# --- warm-carry protocol ----------------------------------------------------


def state_to_carry(monoid: Monoid, state: DabaLiteState, window: int):
    """Warm-carry extraction straight from the sublist invariants: [F,L) and
    [A,B) hold fold-to-B aggregates, [L,R) fold-to-R, [R,A) and [B,E) raw
    values — exactly the region layout of the shared helper, with the deque
    serving as both the raw and the aggregate ring."""
    length = state.capacity + 1
    log = ring_gather(state.deque, state.f, state.capacity, length)
    f = state.f
    return suffix_carry_from_regions(
        monoid, log, log, state.e - f,
        state.l - f, state.r - f, state.a - f, state.b - f, window,
    )


def carry_to_state(monoid: Monoid, carry, capacity: int) -> DabaLiteState:
    """Exact carry import: the carry entries are fold-to-B suffix aggregates,
    which is precisely what l_F and l_A slots hold.  Lay the carry out as
    F = 0, L = R = A = 1, B = E = h: |l_L| = |l_R| = 0 and
    |l_L| + |l_R| + |l_A| + 1 = h = |l_F| − |l_B| satisfy the DABA size
    invariants, so insert/evict/query continue unperturbed."""
    h = chunk_length(carry)
    if h > capacity:
        raise ValueError(f"carry of {h} elements exceeds capacity {capacity}")
    state = init(monoid, capacity)
    if h == 0:
        return state
    idx = jnp.arange(h, dtype=jnp.int32)
    deque = jax.tree.map(lambda a, c: a.at[idx].set(c), state.deque, carry)
    inner = i32(min(1, h))
    return _replace(
        state, deque=deque,
        l=inner, r=inner, a=inner, b=i32(h), e=i32(h),
    )


# --- bulk ops (chunked streaming protocol) ---------------------------------


def insert_bulk(monoid: Monoid, state: DabaLiteState, values) -> DabaLiteState:
    """k inserts with one vectorized lift + ring write and fused fixups.

    Per-element insert does (lift, write raw value, extend the aggB chain,
    fixup).  In bulk the whole chunk is lifted with one vmap and lands in the
    deque with one vectorized ring write — safe because fixup only ever
    writes to slots strictly below the current end E.  The aggB ⊗-chain must
    stay sequential: flips/singletons inside ``_fixup`` reset aggB at
    data-dependent points, so it cannot be precomposed by a scan for a
    non-invertible monoid.  What remains in the loop is exactly the paper's
    O(1) work per element (1 aggB ⊗ + fixup), with no per-element
    lift/dispatch overhead.

    Requires size + k ≤ capacity, like per-element inserts.
    """
    vs = lift_chunk(monoid, values)
    k = chunk_length(vs)
    idx = (state.e + jnp.arange(k, dtype=jnp.int32)) % state.capacity
    deque = jax.tree.map(lambda a, v: a.at[idx].set(v), state.deque, vs)

    def body(i, s: DabaLiteState) -> DabaLiteState:
        s = _replace(
            s,
            agg_b=monoid.combine(s.agg_b, tree_index(vs, i)),
            e=s.e + 1,
        )
        return _fixup(monoid, s)

    return lazy_fori(0, k, body, _replace(state, deque=deque))


def evict_bulk(monoid: Monoid, state: DabaLiteState, k) -> DabaLiteState:
    """k evicts fused into one loop.

    DABA Lite's evict is already worst-case O(1) with no flip spike, and each
    fixup is required to keep the incremental-reversal invariants — so the
    bulk win is only the fused loop (no per-element cond dispatch), not a
    shortcut.
    """
    return lazy_fori(
        0, k, lambda i, s: _fixup(monoid, _replace(s, f=s.f + 1)), state
    )
