"""Monoid framework for sliding-window aggregation (paper §2.2).

A monoid is ``(S, combine, identity)`` with ``combine`` associative and
``identity`` a two-sided unit.  Following the paper's lift/combine/lower
framework [Tangwongsan et al. 2015], an aggregation is specified by three
functions over three types ``In -> Agg -> Out``:

  * ``lift(e: In) -> Agg``       — applied once on arrival,
  * ``combine(a: Agg, b: Agg)``  — the monoid operator (infix ``⊗``),
  * ``lower(v: Agg) -> Out``     — applied to query results.

``Agg`` elements are arbitrary JAX pytrees with static structure and shapes,
so they can live inside ring buffers, be vmapped, sharded, and carried through
``lax`` control flow.  ``combine`` must NOT assume commutativity: the SWAG
algorithms always pass the *older* operand on the left.

Monoids are plain (static) Python objects, not pytrees — they hold functions.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Monoid:
    """An aggregation monoid with the paper's lift/combine/lower framework.

    Attributes:
      name: identifier used in registries / benchmarks.
      identity: () -> Agg, the unit element ``1``.
      combine: (Agg, Agg) -> Agg, associative; older operand first.
      lift: (In) -> Agg.
      lower: (Agg) -> Out.
      commutative: algebraic property (Table 1 of the paper).
      invertible: True iff ``inverse_front`` is available.
      inverse_front: (Agg, Agg) -> Agg.  ``inverse_front(agg, oldest)``
        removes the *front* element from a window aggregate:
        ``inverse_front(lift(e0) ⊗ r, lift(e0)) == r``.  Only defined for
        invertible monoids (used by the subtract-on-evict baseline).
    """

    name: str
    identity: Callable[[], PyTree]
    combine: Callable[[PyTree, PyTree], PyTree]
    lift: Callable[[Any], PyTree]
    lower: Callable[[PyTree], Any]
    commutative: bool = False
    invertible: bool = False
    inverse_front: Optional[Callable[[PyTree, PyTree], PyTree]] = None

    def __repr__(self) -> str:  # keep pytest parametrize ids short
        return f"Monoid({self.name})"


def counting(monoid: Monoid):
    """Wrap ``monoid`` so every ``combine`` invocation bumps a Python counter.

    Only meaningful in eager (non-traced) execution, where our SWAG
    implementations execute exactly the branch the paper's pseudocode would.
    Returns ``(wrapped_monoid, counter)`` where ``counter`` is a
    :class:`repro.obs.counters.Counter` — ``counter.count`` is the number of
    ⊗-invocations so far and ``counter.reset()`` zeroes it.
    """
    # lazy import: obs.registry imports this module for the KLL sketch, so
    # the reverse edge must not exist at module load
    from repro.obs.counters import Counter

    counter = Counter()

    def combine(a, b):
        counter.inc()
        return monoid.combine(a, b)

    def inverse_front(agg, oldest):
        counter.inc()
        return monoid.inverse_front(agg, oldest)

    wrapped = dataclasses.replace(
        monoid,
        name=monoid.name + "#counted",
        combine=combine,
        inverse_front=inverse_front if monoid.invertible else None,
    )
    return wrapped, counter


# ---------------------------------------------------------------------------
# Sum-like monoids (invertible, commutative — Table 1 row 1)
# ---------------------------------------------------------------------------


def sum_monoid(dtype=jnp.float32) -> Monoid:
    zero = functools.partial(jnp.zeros, (), dtype)
    return Monoid(
        name=f"sum_{jnp.dtype(dtype).name}",
        identity=zero,
        combine=lambda a, b: a + b,
        lift=lambda e: jnp.asarray(e, dtype),
        lower=lambda v: v,
        commutative=True,
        invertible=True,
        inverse_front=lambda agg, oldest: agg - oldest,
    )


def count_monoid(dtype=jnp.int32) -> Monoid:
    return Monoid(
        name="count",
        identity=functools.partial(jnp.zeros, (), dtype),
        combine=lambda a, b: a + b,
        lift=lambda e: jnp.ones((), dtype),
        lower=lambda v: v,
        commutative=True,
        invertible=True,
        inverse_front=lambda agg, oldest: agg - oldest,
    )


def mean_monoid(dtype=jnp.float32) -> Monoid:
    """Arithmetic mean as a (sum, count) pair monoid."""

    def identity():
        return {"s": jnp.zeros((), dtype), "n": jnp.zeros((), jnp.int32)}

    return Monoid(
        name="mean",
        identity=identity,
        combine=lambda a, b: {"s": a["s"] + b["s"], "n": a["n"] + b["n"]},
        lift=lambda e: {"s": jnp.asarray(e, dtype), "n": jnp.ones((), jnp.int32)},
        lower=lambda v: v["s"] / jnp.maximum(v["n"], 1).astype(dtype),
        commutative=True,
        invertible=True,
        inverse_front=lambda agg, old: {"s": agg["s"] - old["s"], "n": agg["n"] - old["n"]},
    )


def geomean_monoid(dtype=jnp.float32) -> Monoid:
    """Geometric mean — the paper's medium-cost operator (§7): log-sum + count."""

    def identity():
        return {"ls": jnp.zeros((), dtype), "n": jnp.zeros((), jnp.int32)}

    return Monoid(
        name="geomean",
        identity=identity,
        combine=lambda a, b: {"ls": a["ls"] + b["ls"], "n": a["n"] + b["n"]},
        lift=lambda e: {"ls": jnp.log(jnp.asarray(e, dtype)), "n": jnp.ones((), jnp.int32)},
        lower=lambda v: jnp.exp(v["ls"] / jnp.maximum(v["n"], 1).astype(dtype)),
        commutative=True,
        invertible=True,
        inverse_front=lambda agg, old: {"ls": agg["ls"] - old["ls"], "n": agg["n"] - old["n"]},
    )


def variance_monoid(dtype=jnp.float32) -> Monoid:
    """Welford/Chan parallel-merge variance: (n, mean, M2) — associative."""

    def identity():
        return {
            "n": jnp.zeros((), dtype),
            "mu": jnp.zeros((), dtype),
            "m2": jnp.zeros((), dtype),
        }

    def combine(a, b):
        n = a["n"] + b["n"]
        safe_n = jnp.maximum(n, 1.0)
        delta = b["mu"] - a["mu"]
        mu = a["mu"] + delta * b["n"] / safe_n
        m2 = a["m2"] + b["m2"] + delta * delta * a["n"] * b["n"] / safe_n
        # Merging with the identity (n == 0) must be exact:
        mu = jnp.where(a["n"] == 0, b["mu"], jnp.where(b["n"] == 0, a["mu"], mu))
        return {"n": n, "mu": mu, "m2": m2}

    return Monoid(
        name="variance",
        identity=identity,
        combine=combine,
        lift=lambda e: {
            "n": jnp.ones((), dtype),
            "mu": jnp.asarray(e, dtype),
            "m2": jnp.zeros((), dtype),
        },
        lower=lambda v: v["m2"] / jnp.maximum(v["n"], 1.0),
        commutative=True,
        invertible=False,
    )


# ---------------------------------------------------------------------------
# Max-like monoids (non-invertible — Table 1 row 2)
# ---------------------------------------------------------------------------


def max_monoid(dtype=jnp.float32) -> Monoid:
    neg_inf = jnp.finfo(dtype).min if jnp.issubdtype(dtype, jnp.floating) else jnp.iinfo(dtype).min
    return Monoid(
        name=f"max_{jnp.dtype(dtype).name}",
        identity=lambda: jnp.full((), neg_inf, dtype),
        combine=jnp.maximum,
        lift=lambda e: jnp.asarray(e, dtype),
        lower=lambda v: v,
        commutative=True,
        invertible=False,
    )


def min_monoid(dtype=jnp.float32) -> Monoid:
    pos_inf = jnp.finfo(dtype).max if jnp.issubdtype(dtype, jnp.floating) else jnp.iinfo(dtype).max
    return Monoid(
        name=f"min_{jnp.dtype(dtype).name}",
        identity=lambda: jnp.full((), pos_inf, dtype),
        combine=jnp.minimum,
        lift=lambda e: jnp.asarray(e, dtype),
        lower=lambda v: v,
        commutative=True,
        invertible=False,
    )


def maxcount_monoid(dtype=jnp.float32) -> Monoid:
    """The paper's running example (§2.2): count of occurrences of the max."""

    def identity():
        neg_inf = jnp.finfo(dtype).min
        return {"m": jnp.full((), neg_inf, dtype), "c": jnp.zeros((), jnp.int32)}

    def combine(a, b):
        gt = a["m"] > b["m"]
        lt = a["m"] < b["m"]
        m = jnp.maximum(a["m"], b["m"])
        c = jnp.where(gt, a["c"], jnp.where(lt, b["c"], a["c"] + b["c"]))
        return {"m": m, "c": c}

    return Monoid(
        name="maxcount",
        identity=identity,
        combine=combine,
        lift=lambda e: {"m": jnp.asarray(e, dtype), "c": jnp.ones((), jnp.int32)},
        lower=lambda v: v["c"],
        commutative=True,
        invertible=False,
    )


def argmax_monoid(dtype=jnp.float32) -> Monoid:
    """argMax with earliest-position tie-break — NON-commutative.

    ``lift`` takes ``(value, position)``.  Ties keep the *left* (older)
    operand, so operand order matters: a genuine non-commutative monoid for
    exercising the SWAG algorithms' ordering discipline.
    """

    def identity():
        neg_inf = jnp.finfo(dtype).min
        return {"m": jnp.full((), neg_inf, dtype), "i": jnp.full((), -1, jnp.int32)}

    def combine(a, b):
        keep_a = a["m"] >= b["m"]  # tie -> older (left) wins
        return {
            "m": jnp.where(keep_a, a["m"], b["m"]),
            "i": jnp.where(keep_a, a["i"], b["i"]),
        }

    def lift(e):
        v, pos = e
        return {"m": jnp.asarray(v, dtype), "i": jnp.asarray(pos, jnp.int32)}

    return Monoid(
        name="argmax",
        identity=identity,
        combine=combine,
        lift=lift,
        lower=lambda v: v["i"],
        commutative=False,
        invertible=False,
    )


def m4_monoid(dtype=jnp.float32) -> Monoid:
    """M4 aggregation [Jugel et al.]: (min, max, first, last) — NON-commutative.

    ``first``/``last`` depend on operand order.  ``n`` tracks emptiness so the
    identity behaves as a true unit.
    """

    def identity():
        return {
            "min": jnp.full((), jnp.finfo(dtype).max, dtype),
            "max": jnp.full((), jnp.finfo(dtype).min, dtype),
            "first": jnp.zeros((), dtype),
            "last": jnp.zeros((), dtype),
            "n": jnp.zeros((), jnp.int32),
        }

    def combine(a, b):
        a_empty = a["n"] == 0
        b_empty = b["n"] == 0
        return {
            "min": jnp.minimum(a["min"], b["min"]),
            "max": jnp.maximum(a["max"], b["max"]),
            "first": jnp.where(a_empty, b["first"], a["first"]),
            "last": jnp.where(b_empty, a["last"], b["last"]),
            "n": a["n"] + b["n"],
        }

    def lift(e):
        v = jnp.asarray(e, dtype)
        return {"min": v, "max": v, "first": v, "last": v, "n": jnp.ones((), jnp.int32)}

    return Monoid(
        name="m4",
        identity=identity,
        combine=combine,
        lift=lift,
        lower=lambda v: jnp.stack([v["min"], v["max"], v["first"], v["last"]]),
        commutative=False,
        invertible=False,
    )


def logsumexp_monoid(dtype=jnp.float32) -> Monoid:
    """Numerically-stable streaming logsumexp (softmax denominators)."""

    neg_inf = jnp.finfo(dtype).min

    def combine(a, b):
        m = jnp.maximum(a, b)
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        out = m_safe + jnp.log(
            jnp.exp(a - m_safe) + jnp.exp(b - m_safe)
        )
        return jnp.where(m <= neg_inf / 2, m, out).astype(dtype)

    return Monoid(
        name="logsumexp",
        identity=lambda: jnp.full((), neg_inf, dtype),
        combine=combine,
        lift=lambda e: jnp.asarray(e, dtype),
        lower=lambda v: v,
        commutative=True,
        invertible=False,
    )


# ---------------------------------------------------------------------------
# Mergeable sketches (non-invertible, commutative — Table 1 row 3)
# ---------------------------------------------------------------------------

_HASH_PRIMES = np.array(
    [0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F, 0x165667B1, 0xFD7046C5],
    dtype=np.uint32,
)


def _hash_u32(x: jax.Array, seed: int) -> jax.Array:
    """Cheap xorshift-multiply hash on uint32 lanes (vectorized)."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_HASH_PRIMES[seed % len(_HASH_PRIMES)])
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def bloom_monoid(num_words: int = 64, num_hashes: int = 4) -> Monoid:
    """Bloom filter — the paper's expensive operator (§7).

    Agg = uint32[num_words] bit array; combine = bitwise OR (non-invertible).
    ``num_words * 32`` bits total.  Use :func:`bloom_contains` on a query
    result for membership tests.
    """

    nbits = num_words * 32

    def lift(e):
        e = jnp.asarray(e)
        filt = jnp.zeros((num_words,), jnp.uint32)
        for k in range(num_hashes):
            h = _hash_u32(e, k) % nbits
            word, bit = h // 32, h % 32
            filt = filt.at[word].set(filt[word] | (jnp.uint32(1) << bit))
        return filt

    return Monoid(
        name=f"bloom{nbits}",
        identity=lambda: jnp.zeros((num_words,), jnp.uint32),
        combine=jnp.bitwise_or,
        lift=lift,
        lower=lambda v: v,
        commutative=True,
        invertible=False,
    )


def bloom_contains(filt: jax.Array, e, num_hashes: int = 4) -> jax.Array:
    nbits = filt.shape[-1] * 32
    hit = jnp.array(True)
    for k in range(num_hashes):
        h = _hash_u32(jnp.asarray(e), k) % nbits
        word, bit = h // 32, h % 32
        hit = hit & ((filt[..., word] >> bit) & 1).astype(bool)
    return hit


def countmin_monoid(rows: int = 4, width: int = 64) -> Monoid:
    """Count-min sketch; merge = elementwise add.  Estimate via
    :func:`countmin_estimate`.  (Merge is formally invertible but the
    estimate is not — we expose it as invertible for subtract-on-evict.)"""

    def lift(e):
        e = jnp.asarray(e)
        sk = jnp.zeros((rows, width), jnp.int32)
        for r in range(rows):
            col = _hash_u32(e, r) % width
            sk = sk.at[r, col].add(1)
        return sk

    return Monoid(
        name=f"countmin{rows}x{width}",
        identity=lambda: jnp.zeros((rows, width), jnp.int32),
        combine=lambda a, b: a + b,
        lift=lift,
        lower=lambda v: v,
        commutative=True,
        invertible=True,
        inverse_front=lambda agg, old: agg - old,
    )


def countmin_estimate(sketch: jax.Array, e) -> jax.Array:
    rows, width = sketch.shape[-2:]
    vals = []
    for r in range(rows):
        col = _hash_u32(jnp.asarray(e), r) % width
        vals.append(sketch[..., r, col])
    return jnp.min(jnp.stack(vals, -1), -1)


def kll_monoid(
    k: int = 64,
    levels: int = 8,
    quantiles: tuple = (0.5, 0.95, 0.99),
    dtype=jnp.float32,
) -> Monoid:
    """Mergeable quantile sketch (KLL-style), fixed-size JAX arrays.

    Agg = ``{"items": (levels, k) sorted values (+inf pads), "n": (levels,)
    counts}``; an item at level l carries weight ``2**l``.  ``combine``
    merge-sorts each level and, when a level exceeds k, deterministically
    *compacts*: adjacent sorted pairs collapse to one survivor promoted to
    the next level (the survivor parity alternates with the level count, so
    compaction does not systematically bias a tail).  Everything is
    fixed-shape ``sort``/``where`` — jit/vmap/scan-safe, usable as a
    telemetry product-monoid member.

    Like every sketch, the result is *order-insensitive in distribution but
    not bitwise*: combine is commutative (a sort of the same multiset) and
    associative up to sketch error — rank error ~ O(1/k) of the window
    count, the usual KLL guarantee shape.  Capacity ~ ``k * 2**levels``
    items; beyond that the oldest coarse summaries fall off the top level.

    ``lower`` returns the ``quantiles`` estimates stacked on the last axis
    (leading/batch axes broadcast); :func:`kll_quantiles` evaluates
    arbitrary quantiles on a raw Agg.
    """

    kk = int(k)
    L = int(levels)
    qs = tuple(float(q) for q in quantiles)
    inf = jnp.asarray(jnp.inf, dtype)  # pad sentinel: non-finite by design

    def identity():
        return {
            "items": jnp.full((L, kk), inf, dtype),
            "n": jnp.zeros((L,), jnp.int32),
        }

    def lift(e):
        items = jnp.full((L, kk), inf, dtype).at[0, 0].set(jnp.asarray(e, dtype))
        return {"items": items, "n": jnp.zeros((L,), jnp.int32).at[0].set(1)}

    def combine(a, b):
        # carry = items promoted from the level below (weight already 2**l)
        carry = jnp.full(a["items"].shape[:-2] + (2 * kk,), inf, dtype)
        carry_n = jnp.zeros(a["n"].shape[:-1], jnp.int32)
        # survivor parity alternates with the global count (and level), so
        # repeated compactions do not systematically keep the larger (or
        # smaller) of each pair — the classic KLL de-biasing coin, made
        # deterministic
        tot = a["n"].sum(axis=-1) + b["n"].sum(axis=-1)
        out_items, out_n = [], []
        idx2k = jnp.arange(2 * kk)
        idxk = jnp.arange(kk)
        for l in range(L):
            merged = jnp.sort(
                jnp.concatenate(
                    [a["items"][..., l, :], b["items"][..., l, :], carry], axis=-1
                ),
                axis=-1,
            )  # (..., 4k) ascending, +inf pads last
            n = a["n"][..., l] + b["n"][..., l] + carry_n
            # overflow compacts the WHOLE level: every sorted adjacent pair
            # collapses to one survivor promoted at double weight
            pairs = jnp.where(n > kk, n // 2, 0)
            off = (tot + l) & 1
            psrc = jnp.clip(2 * idx2k + off[..., None], 0, 4 * kk - 1)
            promoted = jnp.where(
                idx2k < pairs[..., None],
                jnp.take_along_axis(merged, psrc, axis=-1),
                inf,
            )
            ksrc = jnp.clip(2 * pairs[..., None] + idxk, 0, 4 * kk - 1)
            kept_n = n - 2 * pairs
            kept = jnp.where(
                idxk < kept_n[..., None],
                jnp.take_along_axis(merged, ksrc, axis=-1),
                inf,
            )
            out_items.append(kept)
            out_n.append(kept_n)
            carry, carry_n = promoted, pairs
        # promotions past the top level fall off (capacity ~ k * 2**levels)
        return {
            "items": jnp.stack(out_items, axis=-2),
            "n": jnp.stack(out_n, axis=-1),
        }

    def lower(v):
        return kll_quantiles(v, qs)

    return Monoid(
        name=f"kll{kk}x{L}",
        identity=identity,
        combine=combine,
        lift=lift,
        lower=lower,
        commutative=True,
        invertible=False,
    )


def kll_quantiles(agg: PyTree, qs) -> jax.Array:
    """Quantile estimates from a :func:`kll_monoid` Agg (batch axes
    broadcast; returns ``(..., len(qs))``).  Empty sketches yield 0."""
    items = agg["items"]  # (..., L, k)
    L, k = items.shape[-2:]
    flat = items.reshape(items.shape[:-2] + (L * k,))
    level_w = jnp.broadcast_to(
        jnp.repeat(2 ** jnp.arange(L, dtype=jnp.float32), k), flat.shape
    )
    weights = jnp.where(jnp.isfinite(flat), level_w, 0.0)
    order = jnp.argsort(flat, axis=-1)
    svals = jnp.take_along_axis(flat, order, axis=-1)
    swts = jnp.take_along_axis(weights, order, axis=-1)
    cum = jnp.cumsum(swts, axis=-1)
    total = cum[..., -1:]
    outs = []
    for q in qs:
        target = q * total
        idx = jnp.argmax(cum >= target, axis=-1)[..., None]
        val = jnp.take_along_axis(svals, idx, axis=-1)
        outs.append(jnp.where(total > 0, val, 0.0)[..., 0])
    return jnp.stack(outs, axis=-1)


def topk_monoid(k: int = 8, count_dtype=jnp.int32) -> Monoid:
    """SpaceSaving-style fixed-shape heavy hitters over int32 keys.

    Agg = ``{"keys": (k,) int32 (-1 = empty), "counts": (k,)}`` held in
    canonical order (count descending, key ascending on ties — every
    ``combine`` re-canonicalizes, so equal multisets have equal
    representations).  ``combine`` merges the two summaries exactly —
    matching keys sum their counts (a fixed-shape k×k equality match, k is
    small) — then keeps the ``k`` heaviest survivors; the tail truncation
    is the SpaceSaving-style approximation.  Like :func:`kll_monoid`,
    everything is fixed-shape ``where``/``sort`` — jit/vmap/scan-safe, a
    valid telemetry product-monoid member, and usable as a per-key window
    lane in the keyed store.

    Guarantees: exact (and bit-exactly associative/commutative) while the
    union holds ≤ k distinct keys; beyond that, kept counts are lower
    bounds and a key with true frequency above the dropped tail's max
    stays resident — the usual heavy-hitter contract.  ``lift`` takes a
    non-negative int32 key; use :func:`topk_items` to read an Agg.
    """

    kk = int(k)

    def identity():
        return {
            "keys": jnp.full((kk,), -1, jnp.int32),
            "counts": jnp.zeros((kk,), count_dtype),
        }

    def lift(e):
        return {
            "keys": jnp.full((kk,), -1, jnp.int32).at[0].set(
                jnp.asarray(e, jnp.int32)
            ),
            "counts": jnp.zeros((kk,), count_dtype).at[0].set(1),
        }

    def combine(a, b):
        ak, bk = a["keys"], b["keys"]
        # k×k key match: b's count folds into a's matching entry, matched
        # b entries are zeroed (canonical inputs hold each key at most once)
        eq = (ak[..., :, None] == bk[..., None, :]) & (ak[..., :, None] >= 0)
        a_cnt = a["counts"] + jnp.sum(
            jnp.where(eq, b["counts"][..., None, :], 0), axis=-1
        )
        b_cnt = jnp.where(jnp.any(eq, axis=-2), 0, b["counts"])
        keys = jnp.concatenate([ak, bk], axis=-1)
        cnts = jnp.concatenate([a_cnt, b_cnt], axis=-1)
        keys = jnp.where(cnts > 0, keys, -1)
        cnts = jnp.where(keys >= 0, cnts, 0)
        # canonical order: count desc, key asc on ties (empties sort last);
        # keep the k heaviest
        order = jnp.lexsort((keys, -cnts), axis=-1)
        keys = jnp.take_along_axis(keys, order, axis=-1)[..., :kk]
        cnts = jnp.take_along_axis(cnts, order, axis=-1)[..., :kk]
        return {"keys": keys, "counts": cnts}

    return Monoid(
        name=f"topk{kk}",
        identity=identity,
        combine=combine,
        lift=lift,
        lower=lambda v: v,
        commutative=True,
        invertible=False,
    )


def topk_items(agg: PyTree) -> list:
    """``[(key, count), ...]`` of a :func:`topk_monoid` Agg, heaviest first
    (host-side; empty slots elided)."""
    keys = np.asarray(agg["keys"]).ravel()
    counts = np.asarray(agg["counts"]).ravel()
    live = keys >= 0
    return list(zip(keys[live].tolist(), counts[live].tolist()))


def hll_monoid(num_registers: int = 64) -> Monoid:
    """HyperLogLog-style register-max sketch; combine = elementwise max."""

    def lift(e):
        h = _hash_u32(jnp.asarray(e), 0)
        reg = (h % num_registers).astype(jnp.int32)
        # rank = leading-zero count of the remaining bits, +1: rank r with
        # probability 2^-r, the distribution hll_estimate's harmonic-mean
        # estimator assumes (the old +2 shift biased estimates ~2x high)
        rest = _hash_u32(jnp.asarray(e), 1)
        rank = 32 - jnp.floor(jnp.log2(rest.astype(jnp.float32) + 1.0)).astype(jnp.int32)
        regs = jnp.zeros((num_registers,), jnp.int32)
        return regs.at[reg].set(rank)

    return Monoid(
        name=f"hll{num_registers}",
        identity=lambda: jnp.zeros((num_registers,), jnp.int32),
        combine=jnp.maximum,
        lift=lift,
        lower=lambda v: v,
        commutative=True,
        invertible=False,
    )


def hll_estimate(regs) -> jax.Array:
    """Distinct-count estimate from a :func:`hll_monoid` Agg (register
    array, batch axes broadcast) — the standard harmonic-mean estimator
    with the small-range linear-counting correction."""
    regs = jnp.asarray(regs)
    m = regs.shape[-1]
    alpha = 0.7213 / (1.0 + 1.079 / m)
    raw = alpha * m * m / jnp.sum(2.0 ** (-regs.astype(jnp.float32)), axis=-1)
    zeros = jnp.sum(regs == 0, axis=-1)
    linear = m * jnp.log(m / jnp.maximum(zeros, 1).astype(jnp.float32))
    return jnp.where((raw <= 2.5 * m) & (zeros > 0), linear, raw)


# ---------------------------------------------------------------------------
# Non-commutative, non-invertible monoids for systems integration & testing
# ---------------------------------------------------------------------------


def affine_monoid(state_shape: tuple, dtype=jnp.float32) -> Monoid:
    """Composition of affine state maps ``s ↦ d ⊙ s + u`` (SSM/RWKV windows).

    An element represents the map ``s ↦ d*s + u`` with per-channel decay ``d``
    (shape ``state_shape``) and update ``u`` (same shape).  Composition with
    the OLDER map applied first:

        (d_a, u_a) ⊗ (d_b, u_b)  =  (d_a*d_b, d_b*u_a + u_b)

    Associative ✓, non-commutative ✓, non-invertible when any decay is 0 —
    exactly the monoid class DABA exists for.  ``query`` of a window of maps
    applied to a zero initial state yields the *windowed* SSM state: an
    evicting, exact sliding-window recurrence in O(1) worst-case combines per
    token (see core/windowed_state.py).
    """

    def identity():
        return {"d": jnp.ones(state_shape, dtype), "u": jnp.zeros(state_shape, dtype)}

    def combine(a, b):
        return {"d": a["d"] * b["d"], "u": b["d"] * a["u"] + b["u"]}

    def lift(e):
        return {"d": jnp.asarray(e["d"], dtype), "u": jnp.asarray(e["u"], dtype)}

    return Monoid(
        name=f"affine{state_shape}",
        identity=identity,
        combine=combine,
        lift=lift,
        lower=lambda v: v["u"],  # map applied to s0 = 0
        commutative=False,
        invertible=False,
    )


def affine_int_monoid() -> Monoid:
    """Exact-arithmetic affine monoid over Z/2^32 (wraparound int32).

    Exactly associative (no floating-point error), non-commutative and
    non-invertible (a = 0 kills information) — the reference monoid for
    hypothesis property tests where bit-exact oracle equality is asserted.
    lift takes a pair ``(a, b)`` of ints.
    """

    def identity():
        return {"a": jnp.ones((), jnp.int32), "b": jnp.zeros((), jnp.int32)}

    def combine(x, y):
        return {"a": x["a"] * y["a"], "b": y["a"] * x["b"] + y["b"]}

    def lift(e):
        a, b = e
        return {"a": jnp.asarray(a, jnp.int32), "b": jnp.asarray(b, jnp.int32)}

    return Monoid(
        name="affine_i32",
        identity=identity,
        combine=combine,
        lift=lift,
        lower=lambda v: v["b"],
        commutative=False,
        invertible=False,
    )


def matrix_monoid(k: int = 2, dtype=jnp.float32) -> Monoid:
    """k×k matrix product monoid — non-commutative, generally non-invertible."""

    return Monoid(
        name=f"mat{k}x{k}",
        identity=lambda: jnp.eye(k, dtype=dtype),
        combine=lambda a, b: a @ b,
        lift=lambda e: jnp.asarray(e, dtype).reshape(k, k),
        lower=lambda v: v,
        commutative=False,
        invertible=False,
    )


# ---------------------------------------------------------------------------
# Combinators
# ---------------------------------------------------------------------------


def product_monoid(members: dict[str, Monoid]) -> Monoid:
    """Pointwise product of named monoids: Agg = {name: member Agg}.

    One combined element carries N metrics, so a windowed-telemetry update is
    a single monoid operation on a single state (one jitted dispatch) instead
    of N separate windows.  ``lift``/``lower`` map dicts keyed like
    ``members``; algebraic properties are the conjunction of the members'
    (``inverse_front`` exists iff every member is invertible).
    """
    members = dict(members)

    def identity():
        return {k: m.identity() for k, m in members.items()}

    def combine(a, b):
        return {k: m.combine(a[k], b[k]) for k, m in members.items()}

    def lift(e):
        return {k: m.lift(e[k]) for k, m in members.items()}

    def lower(v):
        return {k: m.lower(v[k]) for k, m in members.items()}

    invertible = all(m.invertible for m in members.values())

    def inverse_front(agg, old):
        return {k: m.inverse_front(agg[k], old[k]) for k, m in members.items()}

    return Monoid(
        name="prod[" + ",".join(f"{k}={m.name}" for k, m in members.items()) + "]",
        identity=identity,
        combine=combine,
        lift=lift,
        lower=lower,
        commutative=all(m.commutative for m in members.values()),
        invertible=invertible,
        inverse_front=inverse_front if invertible else None,
    )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], Monoid]] = {
    "sum": sum_monoid,
    "sum_i32": functools.partial(sum_monoid, jnp.int32),
    "sum_i64": functools.partial(sum_monoid, jnp.int64),
    "count": count_monoid,
    "mean": mean_monoid,
    "geomean": geomean_monoid,
    "variance": variance_monoid,
    "max": max_monoid,
    "max_i32": functools.partial(max_monoid, jnp.int32),
    "min": min_monoid,
    "maxcount": maxcount_monoid,
    "argmax": argmax_monoid,
    "m4": m4_monoid,
    "logsumexp": logsumexp_monoid,
    "bloom": bloom_monoid,
    "countmin": countmin_monoid,
    "hll": hll_monoid,
    "kll": kll_monoid,
    "topk": topk_monoid,
    "affine_i32": affine_int_monoid,
    "mat2x2": matrix_monoid,
}


def get_monoid(name: str, **kwargs) -> Monoid:
    if name not in _REGISTRY:
        raise KeyError(f"unknown monoid {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)


def available_monoids() -> list[str]:
    return sorted(_REGISTRY)
