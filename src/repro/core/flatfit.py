"""FlatFIT [Shein et al., SSDBM'17] — the paper's §7 comparison algorithm.

A flat circular buffer of n partial aggregates plus an index array ``nxt``:
slot i stores an aggregate covering window positions [i, nxt[i]).  A query
walks the index chain from the front to the tail, combining the per-range
aggregates, then *path-compresses*: every visited slot is rewritten to hold
the aggregate from itself to the tail (and its index points to the tail), so
repeated queries are cheap.  Amortized O(1) ⊗-invocations per operation,
worst-case O(n) — like Two-Stacks, it trades worst-case latency for
simplicity; the paper (and our benchmarks) use it as an amortized baseline.

Notes on this implementation:
  * the traversal is data-dependent pointer chasing, so (exactly as DESIGN.md
    §2.1 argues) it does not vectorize: this module is EAGER-only, used by
    the correctness tests and the latency benchmark, not by jitted paths.
  * queries mutate the structure (compression).  The module therefore offers
    ``query_mut(monoid, state) -> (agg, state)`` alongside the protocol's
    pure ``query`` (which traverses without compressing — same result, no
    amortization credit).
  * following the paper's §7 adaptation, the buffer is treated as resizable
    via the standard doubling technique at the host layer; within one
    capacity the pointer structure is undisturbed.
"""

from __future__ import annotations

import dataclasses
import sys

import jax
import jax.numpy as jnp

from repro.core import swag_base
from repro.core.monoids import Monoid
from repro.core.swag_base import alloc_ring, i32

PyTree = object


@dataclasses.dataclass
class FlatFitState:
    """Eager mutable state (not a pytree — FlatFIT is host-side by design)."""

    aggs: list  # per-slot partial aggregate (python list of pytrees)
    nxt: list  # per-slot index chain
    head: int
    tail: int  # next write position
    size: int
    capacity: int


def init(monoid: Monoid, capacity: int) -> FlatFitState:
    ident = monoid.identity()
    return FlatFitState(
        aggs=[ident for _ in range(capacity)],
        nxt=[(i + 1) % capacity for i in range(capacity)],
        head=0,
        tail=0,
        size=0,
        capacity=capacity,
    )


def size(state: FlatFitState) -> int:
    return state.size


def insert(monoid: Monoid, state: FlatFitState, value) -> FlatFitState:
    if state.size >= state.capacity - 1:
        raise ValueError("FlatFIT buffer full (host layer should resize)")
    t = state.tail
    state.aggs[t] = monoid.lift(value)
    state.nxt[t] = (t + 1) % state.capacity
    state.tail = (t + 1) % state.capacity
    state.size += 1
    return state


def evict(monoid: Monoid, state: FlatFitState) -> FlatFitState:
    if state.size == 0:
        return state
    state.head = (state.head + 1) % state.capacity
    state.size -= 1
    return state


def _traverse(monoid: Monoid, state: FlatFitState):
    """Walk head → tail; returns (agg, visited indices in walk order)."""
    acc = monoid.identity()
    visited = []
    i = state.head
    while i != state.tail:
        visited.append(i)
        acc = monoid.combine(acc, state.aggs[i])
        i = state.nxt[i]
    return acc, visited


def query(monoid: Monoid, state: FlatFitState):
    """Protocol-pure query (no compression)."""
    acc, _ = _traverse(monoid, state)
    return acc


def query_mut(monoid: Monoid, state: FlatFitState):
    """The real FlatFIT query: combine along the chain, then rewrite every
    visited slot to hold its suffix-to-tail aggregate (path compression)."""
    if state.size == 0:
        return monoid.identity(), state
    # walk and stack the visited prefix aggregates
    stack = []
    i = state.head
    while i != state.tail:
        stack.append(i)
        i = state.nxt[i]
    # suffix-combine in reverse, rewriting slots (the paper's index stack)
    suffix = monoid.identity()
    for j in reversed(stack):
        suffix = monoid.combine(state.aggs[j], suffix)
        state.aggs[j] = suffix
        state.nxt[j] = state.tail
    return suffix, state


# ---------------------------------------------------------------------------
# Bulk-op + warm-carry protocol wiring (eager module: host loops, but the
# same *semantics* as the repro.core.swag_base dispatchers, so FlatFIT states
# interoperate with the chunked engine's carries like every other algorithm)
# ---------------------------------------------------------------------------


def _copy_state(state: FlatFitState) -> FlatFitState:
    """Shallow structural copy — FlatFIT ops mutate ``aggs``/``nxt`` in
    place, so protocol conversions work on a copy to keep the caller's
    state intact (the per-slot aggregates themselves are immutable pytrees)."""
    return FlatFitState(
        aggs=list(state.aggs),
        nxt=list(state.nxt),
        head=state.head,
        tail=state.tail,
        size=state.size,
        capacity=state.capacity,
    )


def insert_bulk(monoid: Monoid, state: FlatFitState, values) -> FlatFitState:
    """k sequential inserts (semantics of the generic bulk fallback)."""
    k = swag_base.chunk_length(values)
    for i in range(k):
        state = insert(monoid, state, swag_base.tree_index(values, i))
    return state


def evict_bulk(monoid: Monoid, state: FlatFitState, k) -> FlatFitState:
    """Evict the k oldest elements (no-op past empty, like ``evict``)."""
    for _ in range(int(k)):
        state = evict(monoid, state)
    return state


def state_to_carry(monoid: Monoid, state: FlatFitState, window: int) -> PyTree:
    """Chunked-stream carry (suffix folds of the last ``window - 1``
    elements) via the generic evict+query sweep — run on a COPY, since
    FlatFIT evictions mutate.  Queries traverse without compressing, so the
    sweep is exact on compressed and uncompressed layouts alike."""
    return swag_base.generic_state_to_carry(
        sys.modules[__name__], monoid, _copy_state(state), window
    )


def carry_to_state(monoid: Monoid, carry: PyTree, capacity: int) -> FlatFitState:
    """EXACT specialization, any monoid: a fully path-compressed FlatFIT
    buffer IS the carry layout.

    After a compressing query, slot i holds the suffix aggregate
    ``fold(i .. tail)`` and its index points at the tail — which is
    precisely ``carry[t] = v_t ⊗ … ⊗ v_{h-1}``.  So the carry is laid out
    directly: slot t ← carry[t], nxt[t] ← h.  Queries, evictions, and
    subsequent inserts behave exactly as if the h underlying elements had
    been inserted individually (no invertibility or commutativity needed,
    unlike the pseudo-element fallback)."""
    h = swag_base.chunk_length(carry)
    if h > capacity - 1:
        raise ValueError(
            f"carry of length {h} needs FlatFIT capacity > {h} (got {capacity})"
        )
    state = init(monoid, capacity)
    for t in range(h):
        state.aggs[t] = swag_base.tree_index(carry, t)
        state.nxt[t] = h
    state.head, state.tail, state.size = 0, h % capacity, h
    return state
