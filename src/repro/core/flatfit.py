"""FlatFIT [Shein et al., SSDBM'17] — the paper's §7 comparison algorithm.

A flat circular buffer of n partial aggregates plus an index array ``nxt``:
slot i stores an aggregate covering window positions [i, nxt[i]).  A query
walks the index chain from the front to the tail, combining the per-range
aggregates, then *path-compresses*: every visited slot is rewritten to hold
the aggregate from itself to the tail (and its index points to the tail), so
repeated queries are cheap.  Amortized O(1) ⊗-invocations per operation,
worst-case O(n) — like Two-Stacks, it trades worst-case latency for
simplicity; the paper (and our benchmarks) use it as an amortized baseline.

Notes on this implementation:
  * the traversal is data-dependent pointer chasing, so (exactly as DESIGN.md
    §2.1 argues) it does not vectorize: this module is EAGER-only, used by
    the correctness tests and the latency benchmark, not by jitted paths.
  * queries mutate the structure (compression).  The module therefore offers
    ``query_mut(monoid, state) -> (agg, state)`` alongside the protocol's
    pure ``query`` (which traverses without compressing — same result, no
    amortization credit).
  * following the paper's §7 adaptation, the buffer is treated as resizable
    via the standard doubling technique at the host layer; within one
    capacity the pointer structure is undisturbed.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.monoids import Monoid
from repro.core.swag_base import alloc_ring, i32

PyTree = object


@dataclasses.dataclass
class FlatFitState:
    """Eager mutable state (not a pytree — FlatFIT is host-side by design)."""

    aggs: list  # per-slot partial aggregate (python list of pytrees)
    nxt: list  # per-slot index chain
    head: int
    tail: int  # next write position
    size: int
    capacity: int


def init(monoid: Monoid, capacity: int) -> FlatFitState:
    ident = monoid.identity()
    return FlatFitState(
        aggs=[ident for _ in range(capacity)],
        nxt=[(i + 1) % capacity for i in range(capacity)],
        head=0,
        tail=0,
        size=0,
        capacity=capacity,
    )


def size(state: FlatFitState) -> int:
    return state.size


def insert(monoid: Monoid, state: FlatFitState, value) -> FlatFitState:
    if state.size >= state.capacity - 1:
        raise ValueError("FlatFIT buffer full (host layer should resize)")
    t = state.tail
    state.aggs[t] = monoid.lift(value)
    state.nxt[t] = (t + 1) % state.capacity
    state.tail = (t + 1) % state.capacity
    state.size += 1
    return state


def evict(monoid: Monoid, state: FlatFitState) -> FlatFitState:
    if state.size == 0:
        return state
    state.head = (state.head + 1) % state.capacity
    state.size -= 1
    return state


def _traverse(monoid: Monoid, state: FlatFitState):
    """Walk head → tail; returns (agg, visited indices in walk order)."""
    acc = monoid.identity()
    visited = []
    i = state.head
    while i != state.tail:
        visited.append(i)
        acc = monoid.combine(acc, state.aggs[i])
        i = state.nxt[i]
    return acc, visited


def query(monoid: Monoid, state: FlatFitState):
    """Protocol-pure query (no compression)."""
    acc, _ = _traverse(monoid, state)
    return acc


def query_mut(monoid: Monoid, state: FlatFitState):
    """The real FlatFIT query: combine along the chain, then rewrite every
    visited slot to hold its suffix-to-tail aggregate (path compression)."""
    if state.size == 0:
        return monoid.identity(), state
    # walk and stack the visited prefix aggregates
    stack = []
    i = state.head
    while i != state.tail:
        stack.append(i)
        i = state.nxt[i]
    # suffix-combine in reverse, rewriting slots (the paper's index stack)
    suffix = monoid.identity()
    for j in reversed(stack):
        suffix = monoid.combine(state.aggs[j], suffix)
        state.aggs[j] = suffix
        state.nxt[j] = state.tail
    return suffix, state
