"""Batched SWAG — partition parallelism (paper §8.2) on SIMD/SPMD hardware.

Maintains B independent sliding windows (one per key/stream partition) as a
single vmapped state, shardable over any mesh axes with **zero cross-window
collectives**.  This is where DABA's worst-case O(1) bound becomes a
throughput property rather than just a latency property (DESIGN.md §2.1):

  * DABA/DABA Lite: ``lax.cond`` → ``select`` under vmap — every lane does
    identical constant work; per-step cost is uniform and independent of the
    per-lane flip phase.
  * Two-Stacks: the flip's data-dependent loop becomes a ``while_loop`` whose
    trip count is the max over all lanes — one lane's O(n) flip stalls the
    whole batch, so batched amortized-O(1) degrades toward O(n / gcd of
    phases).  Measured in benchmarks/bench_batched.py.

Per-lane ``insert``/``evict`` masking supports ragged streams: each step takes
(values, do_insert, do_evict) so different lanes may be at different phases
of fill/slide (dynamic windows per lane).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import swag_base
from repro.core.monoids import Monoid

PyTree = Any

# stream() auto-routes through the chunked bulk engine at or above this many
# steps (for any concrete — cold or warm — initial state); below it the
# per-element scan's lower constant cost wins.
CHUNKED_AUTO_MIN_T = 2048


class BatchedSWAG:
    """Vmapped multi-window SWAG bound to (algo, monoid, capacity).

    All methods are functional: they take and return the batched state.
    ``init(batch)`` allocates ``batch`` lanes.  States are ordinary pytrees —
    shard them with ``jax.device_put(state, NamedSharding(mesh, spec))`` and
    every op stays collective-free.
    """

    def __init__(self, algo, monoid: Monoid, capacity: int):
        self.algo = algo
        self.monoid = monoid
        self.capacity = capacity
        self._chunked_engines = {}  # (window, chunk) -> ChunkedStream
        # jitted final-state rebuild for the chunked path (jit caches per
        # input shape; values have (k, batch) leading -> vmap over axis 1)
        self._bulk_insert = jax.jit(
            jax.vmap(
                functools.partial(swag_base.insert_bulk, algo, monoid),
                in_axes=(0, 1),
            )
        )
        # per-lane bulk evict (k is a (batch,) array: warm lanes may be ragged)
        self._bulk_evict = jax.jit(
            jax.vmap(functools.partial(swag_base.evict_bulk, algo, monoid))
        )
        # fully-vectorized fresh-state rebuild from the last `window` inputs
        # (one log-depth suffix scan, no sequential fixups) — used whenever
        # the stream is long enough to replace the whole window
        self._state_from_chunk = jax.jit(
            jax.vmap(
                lambda vs: swag_base.state_from_chunk(
                    algo, monoid, vs, capacity
                ),
                in_axes=1,
            )
        )

        def _step(state, value, do_insert, do_evict):
            """Masked per-lane step: optionally insert, then optionally evict."""
            state = jax.lax.cond(
                do_insert,
                lambda s: algo.insert(monoid, s, value),
                lambda s: s,
                state,
            )
            state = jax.lax.cond(
                do_evict,
                lambda s: algo.evict(monoid, s),
                lambda s: s,
                state,
            )
            return state

        self._insert = jax.vmap(lambda s, v: algo.insert(monoid, s, v))
        self._evict = jax.vmap(lambda s: algo.evict(monoid, s))
        self._query = jax.vmap(lambda s: algo.query(monoid, s))
        self._step = jax.vmap(_step)
        self._size = jax.vmap(algo.size)

    def init(self, batch: int) -> PyTree:
        return jax.vmap(lambda _: self.algo.init(self.monoid, self.capacity))(
            jnp.arange(batch)
        )

    def insert(self, state: PyTree, values: PyTree) -> PyTree:
        """Insert one value into every lane (values has leading batch dim)."""
        return self._insert(state, values)

    def evict(self, state: PyTree) -> PyTree:
        return self._evict(state)

    def query(self, state: PyTree) -> PyTree:
        return self._query(state)

    def step(self, state: PyTree, values: PyTree, do_insert, do_evict) -> PyTree:
        """Masked step for ragged / dynamically-sized per-lane windows."""
        return self._step(state, values, do_insert, do_evict)

    def size(self, state: PyTree) -> jax.Array:
        return self._size(state)

    def stream(
        self,
        state: PyTree,
        xs: PyTree,
        window: int,
        *,
        chunked: Optional[bool] = None,
        chunk: Optional[int] = None,
    ):
        """Scan a (T, batch, …) stream through fixed-size-``window`` sliding
        aggregation; returns (final_state, (T, batch) queries).  The standard
        count-based window: insert, evict once size exceeds ``window``.

        Routing: by default (``chunked=None``) streams with T ≥
        ``CHUNKED_AUTO_MIN_T`` whose state is concrete (not traced) with
        every lane size ≤ ``window`` go through the
        :class:`~repro.core.chunked.ChunkedStream` bulk engine (Pallas
        kernels / associative scans, ~3 combines per element).  Warm
        (non-empty) states are included: the engine's carry is initialized
        from the live window via the warm-carry protocol
        (``swag_base.state_to_carry``).  Everything else — small T, traced
        state under jit, overfull lanes — takes the per-element ``lax.scan``.
        ``chunked=True`` forces the bulk path (the caller asserts every lane
        holds ≤ ``window`` elements); ``chunked=False`` forces per-element.
        Outputs agree exactly for integer monoids and up to combine
        reassociation for floats; the bulk path's final state is rebuilt by
        bulk-evicting what would overflow and bulk-inserting the last
        min(T, window) inputs — a valid state with identical window contents
        (and therefore identical query results and future behaviour), not a
        bit-identical internal layout.
        """
        T = jax.tree.leaves(xs)[0].shape[0]
        if chunked is None:
            chunked = False
            if T >= CHUNKED_AUTO_MIN_T:
                sizes = self._concrete_sizes(state)
                chunked = sizes is not None and bool((sizes <= window).all())
        if chunked:
            return self._stream_chunked(state, xs, window, chunk)

        def scan_step(st, x):
            st = self._insert(st, x)
            st = self._step(
                st,
                x,
                jnp.zeros(self._size(st).shape, bool),
                self._size(st) > window,
            )
            return st, self._query(st)

        return jax.lax.scan(scan_step, state, xs)

    def _concrete_sizes(self, state: PyTree):
        try:
            return np.asarray(self.size(state))
        except (jax.errors.TracerArrayConversionError, jax.errors.ConcretizationTypeError):
            return None  # traced under jit: stay on the per-element path

    def _stream_chunked(self, state: PyTree, xs: PyTree, window: int, chunk):
        from repro.core.chunked import ChunkedStream  # local: avoid cycle

        key = (window, chunk)
        cached = self._chunked_engines.get(key)
        if cached is None:  # cache: the engine + jitted carry extraction
            engine = ChunkedStream(self.monoid, window, chunk)
            carry_fn = jax.jit(
                lambda st: engine.init_carry(from_state=st, algo=self.algo)
            )
            cached = self._chunked_engines[key] = (engine, carry_fn)
        engine, carry_fn = cached
        ys = engine.stream(xs, carry=carry_fn(state))
        # Final state: same window contents as the per-element scan.
        T = jax.tree.leaves(xs)[0].shape[0]
        if T >= window:
            # the stream replaces the whole window — build a fresh state from
            # the last `window` inputs, fully vectorized (no sequential loop)
            last = jax.tree.map(lambda a: a[T - window:], xs)
            state = self._state_from_chunk(last)
        else:
            # partial refresh (window > T ≥ CHUNKED_AUTO_MIN_T): evict
            # per-lane what the inserts would overflow, then bulk-insert —
            # evict-first also keeps every lane within the ring capacity
            k = jnp.maximum(self.size(state) + T - window, 0)
            state = self._bulk_evict(state, k)
            state = self._bulk_insert(state, xs)
        return state, ys
