"""Batched SWAG — partition parallelism (paper §8.2) on SIMD/SPMD hardware.

Maintains B independent sliding windows (one per key/stream partition) as a
single vmapped state, shardable over any mesh axes with **zero cross-window
collectives**.  This is where DABA's worst-case O(1) bound becomes a
throughput property rather than just a latency property (DESIGN.md §2.1):

  * DABA/DABA Lite: ``lax.cond`` → ``select`` under vmap — every lane does
    identical constant work; per-step cost is uniform and independent of the
    per-lane flip phase.
  * Two-Stacks: the flip's data-dependent loop becomes a ``while_loop`` whose
    trip count is the max over all lanes — one lane's O(n) flip stalls the
    whole batch, so batched amortized-O(1) degrades toward O(n / gcd of
    phases).  Measured in benchmarks/bench_batched.py.

Per-lane ``insert``/``evict`` masking supports ragged streams: each step takes
(values, do_insert, do_evict) so different lanes may be at different phases
of fill/slide (dynamic windows per lane).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.monoids import Monoid

PyTree = Any


class BatchedSWAG:
    """Vmapped multi-window SWAG bound to (algo, monoid, capacity).

    All methods are functional: they take and return the batched state.
    ``init(batch)`` allocates ``batch`` lanes.  States are ordinary pytrees —
    shard them with ``jax.device_put(state, NamedSharding(mesh, spec))`` and
    every op stays collective-free.
    """

    def __init__(self, algo, monoid: Monoid, capacity: int):
        self.algo = algo
        self.monoid = monoid
        self.capacity = capacity

        def _step(state, value, do_insert, do_evict):
            """Masked per-lane step: optionally insert, then optionally evict."""
            state = jax.lax.cond(
                do_insert,
                lambda s: algo.insert(monoid, s, value),
                lambda s: s,
                state,
            )
            state = jax.lax.cond(
                do_evict,
                lambda s: algo.evict(monoid, s),
                lambda s: s,
                state,
            )
            return state

        self._insert = jax.vmap(lambda s, v: algo.insert(monoid, s, v))
        self._evict = jax.vmap(lambda s: algo.evict(monoid, s))
        self._query = jax.vmap(lambda s: algo.query(monoid, s))
        self._step = jax.vmap(_step)
        self._size = jax.vmap(algo.size)

    def init(self, batch: int) -> PyTree:
        return jax.vmap(lambda _: self.algo.init(self.monoid, self.capacity))(
            jnp.arange(batch)
        )

    def insert(self, state: PyTree, values: PyTree) -> PyTree:
        """Insert one value into every lane (values has leading batch dim)."""
        return self._insert(state, values)

    def evict(self, state: PyTree) -> PyTree:
        return self._evict(state)

    def query(self, state: PyTree) -> PyTree:
        return self._query(state)

    def step(self, state: PyTree, values: PyTree, do_insert, do_evict) -> PyTree:
        """Masked step for ragged / dynamically-sized per-lane windows."""
        return self._step(state, values, do_insert, do_evict)

    def size(self, state: PyTree) -> jax.Array:
        return self._size(state)

    def stream(self, state: PyTree, xs: PyTree, window: int):
        """Scan a (T, batch, …) stream through fixed-size-``window`` sliding
        aggregation; returns (final_state, (T, batch) queries).  The standard
        count-based window: insert, evict once size exceeds ``window``.
        """

        def scan_step(st, x):
            st = self._insert(st, x)
            st = self._step(
                st,
                x,
                jnp.zeros(self._size(st).shape, bool),
                self._size(st) > window,
            )
            return st, self._query(st)

        return jax.lax.scan(scan_step, state, xs)
