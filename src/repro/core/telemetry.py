"""Unified windowed telemetry — N named metrics, ONE monoid state.

Every consumer of windowed statistics in the system (data-pipeline stream
stats, trainer metric windows, the serve engine's per-slot stats) used to
hand-roll its own per-element DABA Lite loop with one device round-trip per
metric.  ``WindowedTelemetry`` replaces all of them with a single
product-monoid state driven by the chunked streaming engines:

  * **one state**: the N metrics live in one
    :func:`repro.core.monoids.product_monoid` element, so an observation is
    one monoid operation, not N;
  * **one dispatch**: :meth:`observe` runs (prepare → lift → window update →
    lower) as a single jitted call; :meth:`snapshot` is a single host
    transfer of every lowered metric — no per-metric ``float()`` syncs;
  * **chunked bulk**: :meth:`observe_bulk` feeds whole (C,) / (C, B) chunks
    through the engine's pure ``chunk_fn`` (~3 combines per element, log
    depth) and returns the per-step windowed outputs;
  * **pure functional core**: :meth:`init_state` / :meth:`update` /
    :meth:`read` are pure, so the same telemetry can live *inside* an outer
    ``jit`` (the trainer embeds it in the fused train step);
  * **checkpointable**: :meth:`state_dict` / :meth:`load_state_dict` expose
    the window state as a plain pytree for
    :mod:`repro.train.checkpoint` — serve/train telemetry survives restarts.

Window semantics — exactly one of:

  * ``window=N`` — **count-based**: fold of the last N observations
    (front-truncated during fill), driven by
    :class:`repro.core.chunked.ChunkedStream`;
  * ``horizon=H`` — **event-time**: fold of every observation whose
    timestamp lies in ``(now - H, now]`` where ``now`` is the watermark of
    the newest observation, driven by
    :class:`repro.core.event_time.EventTimeChunkedStream`.  Each
    observation carries a timestamp (``ts=`` on observe/update; defaults to
    ``time.monotonic()`` on the stateful wrappers), shared across lanes.
    Mildly out-of-order timestamps are stable-merged into the window (the
    engine's ``"merge"`` late policy), so wall-clock jitter between
    producers cannot corrupt non-commutative metrics.  Under stragglers a
    count window silently stretches its wall-clock coverage; a horizon
    window keeps measuring the same span of real time.

Lanes: ``batch > 1`` maintains per-lane windows (e.g. one per serve slot);
per-observation values may be scalars (broadcast to every lane) or
``(batch,)`` arrays.

Cost model: a single :meth:`observe` does O(window) *vectorized* combines at
O(log window) depth (the chunked engines' C=1 case) — uniform and
data-independent, but not the per-element algorithms' O(1) combine count.
The dispatch, not the combine count, dominates telemetry-rate updates; bulk
ingest amortizes to ~3 combines per element (count mode) / O(log) per
element (event-time mode).
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chunked import ChunkedStream
from repro.core.event_time import EventTimeChunkedStream
from repro.core.monoids import Monoid, product_monoid

PyTree = Any


def _adopt_state_dict(restored: PyTree, current: PyTree, hint: str) -> PyTree:
    """Validate a restored telemetry state against the live one (tree
    structure, then per-leaf shapes) and return it cast to the live dtypes.
    ``hint`` names the configuration knobs to check on mismatch."""
    if jax.tree.structure(restored) != jax.tree.structure(current):
        raise ValueError(
            f"telemetry state_dict structure mismatch — configure the "
            f"instance ({hint}) like the saved one"
        )
    for new, old in zip(jax.tree.leaves(restored), jax.tree.leaves(current)):
        if jnp.shape(new) != jnp.shape(old):
            raise ValueError(
                f"telemetry state_dict shape mismatch ({jnp.shape(new)} vs "
                f"{jnp.shape(old)}) — the saved {hint} differs from this "
                f"instance's configuration"
            )
    return jax.tree.map(
        lambda new, old: jnp.asarray(new, jnp.asarray(old).dtype),
        restored,
        current,
    )


class WindowedTelemetry:
    """N named sliding-window metrics as one jitted product-monoid state.

    Args:
      metrics: name → :class:`Monoid`; the window semantics apply to every
        metric uniformly.
      window: count-based window length (exclusive with ``horizon``).
      horizon: event-time window span (exclusive with ``window``).
      slack: event-time reorder slack (see
        :class:`~repro.core.event_time.EventTimeChunkedStream`); 0 releases
        every observation immediately.
      capacity / buffer: event-time engine capacities (max live in-horizon
        observations / reorder slots).
      batch: number of independent lanes (per-slot / per-key windows).
      prepare: optional traced function mapping raw observe() input to the
        per-metric value dict — reductions fused into the same dispatch.
      chunk: chunk length hint for bulk ingest.
    """

    def __init__(
        self,
        metrics: Dict[str, Monoid],
        window: Optional[int] = None,
        *,
        horizon=None,
        slack=0.0,
        capacity: int = 256,
        buffer: int = 8,
        batch: int = 1,
        prepare: Optional[Callable] = None,
        chunk: Optional[int] = None,
    ):
        if (window is None) == (horizon is None):
            raise ValueError("pass exactly one of window= (count) / horizon= (event-time)")
        self.metrics = dict(metrics)
        self.batch = int(batch)
        self.prepare = prepare
        self.monoid = product_monoid(self.metrics)
        self.horizon = horizon
        if horizon is None:
            self.window = int(window)
            # product Agg is a pytree -> always the generic associative-scan path
            self._engine = ChunkedStream(
                self.monoid, self.window, chunk, use_kernel=False
            )
        else:
            self.window = None
            self._engine = EventTimeChunkedStream(
                self.monoid,
                horizon,
                slack=slack,
                chunk=chunk or 64,
                capacity=capacity,
                buffer=buffer,
                late_policy="merge",
            )
        self._state = self.init_state()
        self._lowered = self.read(self._state)
        self._t0: Optional[float] = None  # anchor for default wall-clock ts
        # no donate_argnums: CPU backends warn on unusable donations, and the
        # telemetry state is tiny relative to any model state
        self._observe_jit = jax.jit(self._observe_impl)
        self._bulk_jit = jax.jit(self._bulk_impl)

    # -- pure functional core (usable inside an outer jit) -----------------

    def init_state(self) -> PyTree:
        """{"carry"|"eng": engine state, "last": per-lane window aggregate}."""
        ident = self.monoid.identity()
        last = jax.tree.map(
            lambda i: jnp.broadcast_to(i, (self.batch,) + i.shape), ident
        )
        if self.horizon is None:
            return {"carry": self._engine.init_carry(self.batch), "last": last}
        return {"eng": self._engine.init_state(self.batch), "last": last}

    def update(self, state: PyTree, values, ts=None) -> PyTree:
        """One observation (pure).  ``values``: per-metric dict (or raw input
        when ``prepare`` is set); leaves must be scalars or (batch,).  In
        event-time mode ``ts`` (a scalar timestamp) is required."""
        row = self._to_row(values)
        if self.horizon is None:
            carry, y = self._engine.chunk_fn(state["carry"], row)
            return {"carry": carry, "last": jax.tree.map(lambda a: a[0], y)}
        if ts is None:
            raise ValueError("event-time telemetry update needs ts=")
        eng, _ = self._engine.chunk_fn(
            state["eng"],
            jnp.reshape(jnp.asarray(ts, self._engine.ts_dtype), (1,)),
            row,
            with_outputs=False,
        )
        return {"eng": eng, "last": self._engine.window_fold(eng)}

    def update_bulk(self, state: PyTree, chunks, ts=None):
        """A whole chunk of observations (pure).  ``chunks``: per-metric dict
        of (C,) / (C, batch)-leading values; event-time mode also needs
        ``ts`` (C,).  Returns (state, per-metric window aggregates): (C,
        batch) rows aligned with the inputs in count mode; in event-time
        mode (buffer + C, batch) rows, one per *released* observation in
        event order (the static length covers a draining reorder buffer
        releasing more than C at once), identity-padded past the release
        count — with in-order timestamps and ``slack=0`` the first C rows
        align with the chunk."""
        vals = self._to_chunk(chunks)
        if self.horizon is None:
            carry, y = self._engine.chunk_fn(state["carry"], vals)
            state = {"carry": carry, "last": jax.tree.map(lambda a: a[-1], y)}
            return state, y
        if ts is None:
            raise ValueError("event-time telemetry update_bulk needs ts=")
        eng, out = self._engine.chunk_fn(
            state["eng"], jnp.asarray(ts, self._engine.ts_dtype), vals
        )
        # keep every released row (a draining buffer can release more than
        # C); rows beyond the release mask are identities, never pad folds
        rel = out["mask"]
        ident = self.monoid.identity()
        y = jax.tree.map(
            lambda a, i: jnp.where(
                rel.reshape(rel.shape + (1,) * (a.ndim - 1)),
                a,
                jnp.asarray(i, a.dtype),
            ),
            out["ys"],
            ident,
        )
        return {"eng": eng, "last": self._engine.window_fold(eng)}, y

    def read(self, state: PyTree) -> dict:
        """Lowered windowed value per metric (pure; (batch,)-leading)."""
        return {k: m.lower(state["last"][k]) for k, m in self.metrics.items()}

    # -- stateful convenience wrappers -------------------------------------

    def observe(self, values, ts=None) -> dict:
        """One windowed observation — exactly ONE jitted device dispatch
        (prepare + lift + window update + lower, fused).  Returns the
        lowered metrics as device values (no host sync).  ``ts`` (event-time
        mode) defaults to ``time.monotonic()``."""
        ts = self._default_ts(ts)
        self._state, self._lowered = self._observe_jit(self._state, values, ts)
        return self._lowered

    def observe_bulk(self, chunks, ts=None) -> dict:
        """Feed a whole (C,) / (C, batch) chunk per metric; returns the
        per-step lowered windowed outputs (device values)."""
        if self.horizon is not None and ts is None:
            raise ValueError("event-time telemetry observe_bulk needs ts=")
        if ts is None:
            ts = 0.0
        self._state, self._lowered, outs = self._bulk_jit(self._state, chunks, ts)
        return outs

    def snapshot(self) -> dict:
        """Host snapshot of every lowered metric in ONE transfer (lane axis
        squeezed away when ``batch == 1``)."""
        vals = jax.device_get(self._lowered)
        if self.batch == 1:
            vals = jax.tree.map(lambda v: v[0], vals)
        return vals

    def aggregate(self, name: str) -> PyTree:
        """Raw windowed Agg of one metric (device value; lane axis squeezed
        when ``batch == 1``) — e.g. the live Bloom filter for membership."""
        agg = self._state["last"][name]
        if self.batch == 1:
            agg = jax.tree.map(lambda a: a[0], agg)
        return agg

    def overflow_count(self) -> int:
        """Event-time mode: observations lost to the engine's static
        capacities (``capacity``/``buffer``) so far.  Non-zero means the
        effective window has degraded to the newest ``capacity`` in-horizon
        observations — raise ``capacity=`` to restore the full horizon.
        Always 0 in count mode (host sync)."""
        if self.horizon is None:
            return 0
        return int(self._state["eng"]["n_overflow"])

    # -- checkpoint/restore -------------------------------------------------

    def state_dict(self) -> PyTree:
        """The full window state as a plain pytree — feed to
        :func:`repro.train.checkpoint.save` (and use as the ``like=``
        template for :func:`~repro.train.checkpoint.restore`)."""
        return {"state": self._state}

    def load_state_dict(self, sd: PyTree) -> None:
        """Adopt a restored :meth:`state_dict` pytree.  The tree structure
        must match this instance's configuration (same metrics, window
        mode, capacities, lanes).  In event-time mode the default-timestamp
        clock is re-anchored to CONTINUE the restored stream: the next
        default-``ts`` observation lands just after the restored watermark
        (a fresh anchor starting at 0 would make every new observation
        "late" against the old watermark and silently dropped)."""
        self._state = _adopt_state_dict(
            sd["state"], self._state, "metrics/window/horizon/capacity/batch"
        )
        self._lowered = self.read(self._state)
        if self.horizon is not None:
            self._t0 = time.monotonic() - self.last_timestamp()

    def last_timestamp(self) -> float:
        """Event-time mode: the largest observation timestamp seen (0.0
        before any observation; host sync).  The epoch callers passing
        explicit ``ts`` should continue from after a restore."""
        if self.horizon is None:
            return 0.0
        tmin = float(jax.device_get(self._engine._tmin))
        mx = float(self._state["eng"]["max_ts"])
        return 0.0 if mx <= tmin else mx

    # -- observability -------------------------------------------------------

    def attach_obs(self, registry, *, prefix: str = "repro_telemetry"):
        """Register a scrape collector: the lowered windowed value of every
        metric (``<prefix>_<metric>``, per-lane ``{lane=}`` labels when
        ``batch > 1``) plus, in event-time mode, the engine health series of
        :meth:`EventTimeChunkedStream.obs_metrics` (watermark lag, reorder
        occupancy, overflow).  Device values; the registry batches the host
        transfer per scrape.  Safe to attach to a live instance — this
        telemetry engine never donates its state."""
        for name in self.metrics:
            registry.describe(f"{prefix}_{name}", "gauge",
                              f"windowed {name} (lowered)")
        if self.horizon is not None:
            for key, typ, help in (
                ("watermark", "gauge", "current watermark (event time)"),
                ("watermark_lag", "gauge",
                 "max observed ts minus watermark"),
                ("buffer_occupancy", "gauge",
                 "events held in the reorder buffer"),
                ("window_occupancy", "gauge",
                 "events live inside the horizon window"),
                ("late_total", "counter",
                 "events that arrived behind the watermark"),
                ("dropped_total", "counter",
                 "late events dropped by policy"),
                ("overflow_total", "counter",
                 "reorder-buffer overflow force-releases"),
            ):
                registry.describe(f"{prefix}_{key}", typ, help)

        def collect():
            out = {}
            for name in self.metrics:
                v = self._lowered[name]
                leaves = jax.tree.leaves(v)
                if not leaves:
                    continue
                leaf = leaves[0]  # first leaf of structured lowered values
                if self.batch == 1:
                    out[f"{prefix}_{name}"] = leaf[0]
                else:
                    for lane in range(self.batch):
                        out[f'{prefix}_{name}{{lane="{lane}"}}'] = leaf[lane]
            if self.horizon is not None:
                eng = self._state["eng"]
                for key, val in self._engine.obs_metrics(eng).items():
                    out[f"{prefix}_{key}"] = val
            return out

        registry.register_collector(collect)
        return collect

    # -- keyed (multi-tenant) view ------------------------------------------

    @staticmethod
    def keyed(
        metrics: Dict[str, Monoid],
        window: int,
        slots: int,
        **kwargs,
    ) -> "KeyedTelemetry":
        """Per-key windowed telemetry: the same N-metrics-one-product-monoid
        design, but each KEY (user, request, tenant) gets its own
        independent count window, backed by
        :class:`repro.core.keyed.KeyedWindowStore` (bounded hot set with
        LRU/TTL eviction over an unbounded key universe).  See
        :class:`KeyedTelemetry`."""
        return KeyedTelemetry(metrics, window, slots, **kwargs)

    # -- impl ---------------------------------------------------------------

    def _default_ts(self, ts):
        if self.horizon is None:
            return 0.0  # unused in count mode; fixed so jit sees one shape
        if ts is not None:
            return ts
        # anchor default wall-clock stamps at the first observation: raw
        # monotonic()/perf_counter() values (seconds since boot) lose
        # float32 precision on long-uptime hosts.  Don't mix default and
        # explicit ts on one instance.
        now = time.monotonic()
        if self._t0 is None:
            self._t0 = now
        return now - self._t0

    def _observe_impl(self, state, values, ts):
        state = self.update(state, values, ts)
        return state, self.read(state)

    def _bulk_impl(self, state, chunks, ts):
        state, y = self.update_bulk(state, chunks, ts)
        outs = {k: m.lower(y[k]) for k, m in self.metrics.items()}
        return state, self.read(state), outs

    def _to_row(self, values) -> dict:
        if self.prepare is not None:
            values = self.prepare(values)

        def bc(leaf):
            leaf = jnp.asarray(leaf)
            if leaf.ndim == 0:
                leaf = jnp.broadcast_to(leaf, (self.batch,))
            elif leaf.shape != (self.batch,):
                raise ValueError(
                    f"per-observation leaves must be scalar or ({self.batch},), "
                    f"got {leaf.shape}"
                )
            return leaf[None]  # (1, batch)

        return {k: jax.tree.map(bc, values[k]) for k in self.metrics}

    def _to_chunk(self, chunks) -> dict:
        def bc(leaf):
            leaf = jnp.asarray(leaf)
            if leaf.ndim == 1 and self.batch == 1:
                leaf = leaf[:, None]
            if leaf.ndim < 2 or leaf.shape[1] != self.batch:
                raise ValueError(
                    f"bulk leaves must be (C, {self.batch})-leading, got {leaf.shape}"
                )
            return leaf

        return {k: jax.tree.map(bc, chunks[k]) for k in self.metrics}


class KeyedTelemetry:
    """Per-key windowed metrics over an unbounded key universe.

    N named monoids live in ONE product-monoid element per key, and the
    per-key count windows are lanes of a
    :class:`repro.core.keyed.KeyedWindowStore`: a mixed-key observation
    chunk is one fused jitted dispatch (sort → segments → directory
    admission → bulk window update), the hot set is bounded by ``slots``
    (LRU eviction, optional idle-key ``ttl``), and the whole thing is a
    plain pytree for the checkpoint layer (:meth:`state_dict` /
    :meth:`load_state_dict`).

    Args:
      metrics: name → :class:`Monoid` (window applies per key, uniformly).
      window: count window length per key.
      slots: hot-set bound (keys live concurrently; LRU beyond that).
      ttl: optional idle eviction, in units of the ``ts`` passed to observe.
      horizon: optional event-time window span — metrics fold only
        observations with ``ts > ts_now - horizon`` (capped at the last
        ``window`` per key; see :class:`repro.core.keyed.KeyedWindowStore`).
        Requires passing ``ts`` to observe with per-key non-decreasing
        timestamps.
      prepare: optional traced map from raw per-row input to the per-metric
        value dict, fused into the dispatch.
      chunk: default bulk chunk length (ragged chunks pad to it).
    """

    def __init__(
        self,
        metrics: Dict[str, Monoid],
        window: int,
        slots: int,
        *,
        ttl: Optional[float] = None,
        horizon: Optional[float] = None,
        prepare: Optional[Callable] = None,
        chunk: int = 256,
    ):
        from repro.core.keyed import KeyedChunkedStream

        self.metrics = dict(metrics)
        self.monoid = product_monoid(self.metrics)
        self.prepare = prepare
        self.window = int(window)
        self.slots = int(slots)
        # donate=False: state_dict() hands out the LIVE state reference for
        # checkpointing — a donated update would delete those buffers out
        # from under the checkpoint payload.
        self._engine = KeyedChunkedStream(
            self.monoid, self.window, self.slots, chunk, ttl=ttl,
            horizon=horizon, donate=False
        )
        self._state = self._engine.init_state()
        self._query_jit = jax.jit(self._engine.store.query)

    # -- observation --------------------------------------------------------

    def observe_bulk(self, keys, values, ts=None, mask=None) -> None:
        """One chunk of mixed-key observations: ``keys`` (C,) int32 ≥ 0,
        ``values`` a per-metric dict of (C,) leaves (or raw input when
        ``prepare`` is set) — ONE fused dispatch, no host sync."""
        if self.prepare is not None:
            values = self.prepare(values)
        vals = {k: values[k] for k in self.metrics}
        self._state, _, _ = self._engine.process_chunk(
            self._state, jnp.asarray(keys, jnp.int32), vals, ts, mask
        )

    def observe(self, key, values, ts=None) -> None:
        """Single-key convenience wrapper (a C=1 chunk)."""
        one = jax.tree.map(lambda v: jnp.asarray(v)[None], values)
        self.observe_bulk(jnp.asarray([key], jnp.int32), one, ts)

    # -- reads --------------------------------------------------------------

    def snapshot(self, keys) -> dict:
        """Lowered windowed metrics for ``keys`` in ONE transfer:
        ``{"found": (K,) bool, <metric>: (K,) lowered}`` (identity-lowered
        values for unknown keys).  Queries are padded to power-of-two
        batches with the -1 sentinel (never found), so a polling caller
        whose key count drifts reuses O(log) compilations instead of one
        per distinct length."""
        keys = jnp.asarray(keys, jnp.int32)
        n = int(keys.shape[0])
        cap = 1
        while cap < n:
            cap *= 2
        if cap > n:
            keys = jnp.concatenate(
                [keys, jnp.full((cap - n,), -1, jnp.int32)]
            )
        aggs, found = self._query_jit(self._state, keys)
        out = {k: m.lower(aggs[k]) for k, m in self.metrics.items()}
        host = jax.device_get({"found": found, **out})
        return jax.tree.map(lambda a: a[:n], host)

    def aggregate(self, key, name: str) -> PyTree:
        """Raw windowed Agg of one metric for one key (device value)."""
        aggs, _ = self._query_jit(
            self._state, jnp.asarray([key], jnp.int32)
        )
        return jax.tree.map(lambda a: a[0], aggs[name])

    def live_keys(self) -> np.ndarray:
        """The keys currently holding a slot (host transfer, unordered)."""
        sk = np.asarray(self._state["dir"]["slot_key"])
        return sk[sk >= 0]

    def counters(self) -> dict:
        """Host snapshot of the admission counters (live/evicted/failed
        keys, dropped rows)."""
        d = self._state["dir"]
        return {
            "n_live": int(d["n_live"]),
            "n_evicted": int(d["n_evicted"]),
            "n_failed": int(d["n_failed"]),
            "n_dropped": int(self._state["n_dropped"]),
        }

    # -- observability -------------------------------------------------------

    def attach_obs(self, registry, *, prefix: str = "repro_keyed_telemetry"):
        """Register a scrape collector for the store health counters
        (live/evicted/failed keys, dropped rows) as device values — the
        registry batches the transfer.  Safe on a live instance
        (``donate=False`` engine: the state reference stays valid)."""
        series = {
            "n_live": (f"{prefix}_live_keys", "gauge",
                       "keys currently holding a slot"),
            "n_evicted": (f"{prefix}_evictions_total", "counter",
                          "LRU + TTL evictions since init"),
            "n_failed": (f"{prefix}_admission_failed_total", "counter",
                         "abandoned admissions"),
            "n_dropped": (f"{prefix}_dropped_rows_total", "counter",
                          "observation rows dropped by failed admission"),
        }
        for key, (name, typ, help) in series.items():
            registry.describe(name, typ, help)

        def collect():
            c = self._engine.store.counters(self._state)
            return {name: c[key] for key, (name, _, _) in series.items()}

        registry.register_collector(collect)
        return collect

    # -- checkpoint/restore -------------------------------------------------

    def state_dict(self) -> PyTree:
        """The full keyed window state (store lanes + key directory) as a
        plain pytree for :mod:`repro.train.checkpoint`."""
        return {"keyed": self._state}

    def load_state_dict(self, sd: PyTree) -> None:
        self._state = _adopt_state_dict(
            sd["keyed"], self._state, "metrics/window/slots"
        )
