"""Unified windowed telemetry — N named metrics, ONE monoid state.

Every consumer of windowed statistics in the system (data-pipeline stream
stats, trainer metric windows, the serve engine's per-slot stats) used to
hand-roll its own per-element DABA Lite loop with one device round-trip per
metric.  ``WindowedTelemetry`` replaces all of them with a single
product-monoid state driven by the chunked streaming engine:

  * **one state**: the N metrics live in one
    :func:`repro.core.monoids.product_monoid` element, so an observation is
    one monoid operation, not N;
  * **one dispatch**: :meth:`observe` runs (prepare → lift → window update →
    lower) as a single jitted call; :meth:`snapshot` is a single host
    transfer of every lowered metric — no per-metric ``float()`` syncs;
  * **chunked bulk**: :meth:`observe_bulk` feeds whole (C,) / (C, B) chunks
    through ``ChunkedStream.chunk_fn`` (~3 combines per element, log depth)
    and returns the per-step windowed outputs;
  * **pure functional core**: :meth:`init_state` / :meth:`update` /
    :meth:`read` are pure, so the same telemetry can live *inside* an outer
    ``jit`` (the trainer embeds it in the fused train step).

Lanes: ``batch > 1`` maintains per-lane windows (e.g. one per serve slot);
per-observation values may be scalars (broadcast to every lane) or
``(batch,)`` arrays.

Cost model: a single :meth:`observe` does O(window) *vectorized* combines at
O(log window) depth (the chunked engine's C=1 case) — uniform and
data-independent, but not the per-element algorithms' O(1) combine count.
The dispatch, not the combine count, dominates telemetry-rate updates; bulk
ingest amortizes to ~3 combines per element.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.chunked import ChunkedStream
from repro.core.monoids import Monoid, product_monoid

PyTree = Any


class WindowedTelemetry:
    """N named sliding-window metrics as one jitted product-monoid state.

    Args:
      metrics: name → :class:`Monoid`; the window semantics (fold of the
        last ``window`` observations, front-truncated during fill) apply to
        every metric uniformly.
      window: number of observations per window.
      batch: number of independent lanes (per-slot / per-key windows).
      prepare: optional traced function mapping raw observe() input to the
        per-metric value dict — reductions fused into the same dispatch.
      chunk: chunk length hint for :meth:`ChunkedStream.stream`-style use;
        :meth:`observe_bulk` adapts to whatever chunk length it is handed.
    """

    def __init__(
        self,
        metrics: Dict[str, Monoid],
        window: int,
        *,
        batch: int = 1,
        prepare: Optional[Callable] = None,
        chunk: Optional[int] = None,
    ):
        self.metrics = dict(metrics)
        self.window = int(window)
        self.batch = int(batch)
        self.prepare = prepare
        self.monoid = product_monoid(self.metrics)
        # product Agg is a pytree -> always the generic associative-scan path
        self._engine = ChunkedStream(
            self.monoid, self.window, chunk, use_kernel=False
        )
        self._state = self.init_state()
        self._lowered = self.read(self._state)
        # no donate_argnums: CPU backends warn on unusable donations, and the
        # telemetry state is tiny relative to any model state
        self._observe_jit = jax.jit(self._observe_impl)
        self._bulk_jit = jax.jit(self._bulk_impl)

    # -- pure functional core (usable inside an outer jit) -----------------

    def init_state(self) -> PyTree:
        """{"carry": engine tail, "last": per-lane window aggregate}."""
        ident = self.monoid.identity()
        last = jax.tree.map(
            lambda i: jnp.broadcast_to(i, (self.batch,) + i.shape), ident
        )
        return {"carry": self._engine.init_carry(self.batch), "last": last}

    def update(self, state: PyTree, values) -> PyTree:
        """One observation (pure).  ``values``: per-metric dict (or raw input
        when ``prepare`` is set); leaves must be scalars or (batch,)."""
        row = self._to_row(values)
        carry, y = self._engine.chunk_fn(state["carry"], row)
        return {"carry": carry, "last": jax.tree.map(lambda a: a[0], y)}

    def update_bulk(self, state: PyTree, chunks):
        """A whole chunk of observations (pure).  ``chunks``: per-metric dict
        of (C,) / (C, batch)-leading values.  Returns (state, (C, batch)
        window aggregates per metric)."""
        vals = self._to_chunk(chunks)
        carry, y = self._engine.chunk_fn(state["carry"], vals)
        state = {"carry": carry, "last": jax.tree.map(lambda a: a[-1], y)}
        return state, y

    def read(self, state: PyTree) -> dict:
        """Lowered windowed value per metric (pure; (batch,)-leading)."""
        return {k: m.lower(state["last"][k]) for k, m in self.metrics.items()}

    # -- stateful convenience wrappers -------------------------------------

    def observe(self, values) -> dict:
        """One windowed observation — exactly ONE jitted device dispatch
        (prepare + lift + window update + lower, fused).  Returns the
        lowered metrics as device values (no host sync)."""
        self._state, self._lowered = self._observe_jit(self._state, values)
        return self._lowered

    def observe_bulk(self, chunks) -> dict:
        """Feed a whole (C,) / (C, batch) chunk per metric; returns the
        per-step lowered windowed outputs (device values)."""
        self._state, self._lowered, outs = self._bulk_jit(self._state, chunks)
        return outs

    def snapshot(self) -> dict:
        """Host snapshot of every lowered metric in ONE transfer (lane axis
        squeezed away when ``batch == 1``)."""
        vals = jax.device_get(self._lowered)
        if self.batch == 1:
            vals = jax.tree.map(lambda v: v[0], vals)
        return vals

    def aggregate(self, name: str) -> PyTree:
        """Raw windowed Agg of one metric (device value; lane axis squeezed
        when ``batch == 1``) — e.g. the live Bloom filter for membership."""
        agg = self._state["last"][name]
        if self.batch == 1:
            agg = jax.tree.map(lambda a: a[0], agg)
        return agg

    # -- impl ---------------------------------------------------------------

    def _observe_impl(self, state, values):
        state = self.update(state, values)
        return state, self.read(state)

    def _bulk_impl(self, state, chunks):
        state, y = self.update_bulk(state, chunks)
        outs = {k: m.lower(y[k]) for k, m in self.metrics.items()}
        return state, self.read(state), outs

    def _to_row(self, values) -> dict:
        if self.prepare is not None:
            values = self.prepare(values)

        def bc(leaf):
            leaf = jnp.asarray(leaf)
            if leaf.ndim == 0:
                leaf = jnp.broadcast_to(leaf, (self.batch,))
            elif leaf.shape != (self.batch,):
                raise ValueError(
                    f"per-observation leaves must be scalar or ({self.batch},), "
                    f"got {leaf.shape}"
                )
            return leaf[None]  # (1, batch)

        return {k: jax.tree.map(bc, values[k]) for k in self.metrics}

    def _to_chunk(self, chunks) -> dict:
        def bc(leaf):
            leaf = jnp.asarray(leaf)
            if leaf.ndim == 1 and self.batch == 1:
                leaf = leaf[:, None]
            if leaf.ndim < 2 or leaf.shape[1] != self.batch:
                raise ValueError(
                    f"bulk leaves must be (C, {self.batch})-leading, got {leaf.shape}"
                )
            return leaf

        return {k: jax.tree.map(bc, chunks[k]) for k in self.metrics}
