"""Event-time windowing: timestamped streams, watermarks, bulk out-of-order
aggregation (cf. the authors' follow-ups arXiv 1810.11308 "Sub-O(log n)
Out-of-Order Sliding-Window Aggregation" and arXiv 2307.11210 "Out-of-Order
SWAG with Efficient Bulk Evictions and Insertions").

The count-based engines (:mod:`repro.core.chunked`, the per-element SWAG
algorithms) define a window as "the last N elements".  Production streams are
*event-time*: every element carries a timestamp, the window is a time span
(``horizon``), elements arrive slightly out of order, and eviction is driven
by a **watermark** — a lower bound on all future event times.  This module
threads those semantics through the bulk-op machinery of
:mod:`repro.core.swag_base`:

  * :class:`TimestampedWindow` — the per-element protocol: any SWAG algorithm
    plus a parallel timestamp queue; ``advance(watermark)`` turns watermark
    movement into ONE ``evict_bulk`` of every expired element.
  * :class:`EventTimeChunkedStream` — the bulk engine: ``(ts, x)`` chunks,
    per-chunk watermark advance, a bounded out-of-order reorder buffer that
    stable-sorts/merges late arrivals into the aggregate, and per-released-
    element window outputs computed with log-depth vectorized scans.
  * :func:`in_order_reference` — the eager oracle the tests hold both
    engines to.

Watermark / late-data semantics
-------------------------------

The engine tracks ``max_ts``, the largest event time seen so far, and sets
the watermark ``wm = max_ts - slack`` (monotone, per-chunk advance).  An
element is **released** — merged into the window, its output emitted — once
``ts <= wm``; until then it waits in the reorder buffer.  An element is
**late** when it arrives with ``ts`` *below* the watermark that was already
published before its chunk.  Late policy:

  * ``"drop"``        — discard, count in ``n_dropped``;
  * ``"side_output"`` — discard from the window, but report the rows so the
    caller can reroute them (:class:`EventTimeResult.late_rows`);
  * ``"merge"``       — merge into the window at the correct event-time
    position as long as the element is still inside the horizon
    (``ts > wm - horizon``; older is dropped).  Future outputs are exact;
    outputs already emitted are not rewritten, and the merged element's OWN
    output may miss in-window peers older than ``wm - horizon`` that were
    already evicted.

Whenever every element's lateness is within ``slack`` (``ts >= running max
of previous chunks - slack``), nothing is ever late, and the concatenated
released outputs equal the in-order per-element reference of the
*timestamp-sorted* stream — bit-exactly for integer monoids (see
tests/test_event_time.py).

Non-commutative merge-order invariant
-------------------------------------

Everything is ordered by ``(event time, arrival order)``: the reorder buffer
is kept time-sorted, chunks are stable-sorted on entry (buffer entries
precede same-timestamp chunk entries; chunk entries keep arrival order on
ties), and released elements stable-merge *after* same-timestamp window
contents.  This is exactly the order a per-element scan of the stable-sorted
stream would use — the FiBA papers' in-order merge discipline — so
non-commutative monoids (argmax tie-breaks, m4 first/last, affine
composition) stay exact: no combine ever sees its operands swapped.

The merge/insert machinery that implements this rule now lives in
:mod:`repro.core.ooo_index` (the vectorized finger-style tail index), and
the engine is **disorder-adaptive**: a per-chunk ``lax.cond`` takes a fast
branch — no sort, no searchsorted merge, released rows append after the
window — whenever the chunk appends at the frontier (out-of-order distance
0), and otherwise stable-sorts only the trailing (buffer ++ chunk) region
and rank-merges it in, the 1810.11308 / 2307.11210 cost shape: work scales
with the out-of-order distance, never the window.  Both branches emit
byte-identical layouts, so outputs are bit-exact across branches (see
README "Out-of-order hot path").

The flip invariant (constant-combine bulk outputs)
--------------------------------------------------

THIS is the one place the sweep contract is stated; README "The keyed hot
path" and :mod:`repro.core.keyed` cross-reference it.

Per-released-element outputs cover a *variable-width* span (everything with
``ts' > ts - horizon``), which a fixed-count sliding pass cannot produce.
Because releases are processed in event order, the query set is **monotone**:
both the span starts and the span ends are non-decreasing over the merged
window-plus-released array.  That is exactly the two-stacks regime: partition
the array at *flip boundaries* chosen so every query's start lands in the
partition cell *before* (or at the start of) the cell holding its end; then

    out[q] = suffix_scan_within_cell[start_q] ⊗ prefix_scan_from_cell_start[end_q]

— one segmented suffix scan + one segmented prefix scan + one combine per
query: a worst-case-constant number of ⊗ per swept element, for ANY monoid
(:func:`flip_range_fold`; the retired O(log(W+C)) doubling table survives as
:func:`range_fold`, kept as the bit-exactness reference).  Invertible
*commutative* monoids — sum, count, mean, … — skip even that and use one
prefix scan plus ``inverse_front`` (:func:`range_fold_invertible`).

**Operand-order rule (non-commutative monoids).**  Every combine keeps the
OLDER operand on the left: the suffix-scan term covers ``[start_q, flip)``
and therefore sits LEFT of the prefix-scan term covering ``[flip, end_q]``;
inside :func:`seg_suffix_scan` the array is flipped, so its pair operator
swaps its operands back (``combine(newer-flipped b, a)``), while
:func:`seg_prefix_scan` combines in natural order.  No combine anywhere in
the sweep ever sees its operands swapped — argmax tie-breaks, m4
first/last, and affine composition stay bit-exact.

Timestamps are any real dtype; values strictly inside (``TS_MIN``,
``TS_MAX``) of that dtype (the extremes are the engine's pad sentinels).
Lanes: like :class:`~repro.core.chunked.ChunkedStream`, streams are
``(T, B)``-leading with ONE shared timestamp per row.
"""

from __future__ import annotations

import collections
import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ooo_index, swag_base
from repro.core.monoids import Monoid
from repro.core.swag_base import chunk_length, tree_index
from repro.obs import counters as obs_counters

PyTree = Any


# ---------------------------------------------------------------------------
# Sentinels
# ---------------------------------------------------------------------------


def ts_limits(dtype) -> tuple:
    """(TS_MIN, TS_MAX) pad sentinels for a timestamp dtype.  Real event
    times must lie strictly between them."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        info = jnp.finfo(dtype)
    else:
        info = jnp.iinfo(dtype)
    return info.min, info.max


def _bc(mask, leaf):
    """Broadcast a (L,) mask over a (L, ...) leaf."""
    return mask.reshape(mask.shape + (1,) * (leaf.ndim - 1))


def _mask_tree(tree: PyTree, mask, ident: PyTree) -> PyTree:
    """Leaves where ``mask`` is False become the (broadcast) identity."""
    return jax.tree.map(
        lambda a, i: jnp.where(_bc(mask, a), a, jnp.asarray(i, a.dtype)),
        tree,
        ident,
    )


def _take0(tree: PyTree, idx) -> PyTree:
    return jax.tree.map(lambda a: a[idx], tree)


def _where_rows(mask, a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(lambda x, y: jnp.where(_bc(mask, x), x, y), a, b)


def fold_axis0(monoid: Monoid, tree_arr: PyTree) -> PyTree:
    """Ordered log-depth fold of a (L, ...) stack: x_0 ⊗ x_1 ⊗ … ⊗ x_{L-1}.

    Pairs adjacent rows (older operand left), padding an odd tail with the
    identity — safe for non-commutative monoids (exactness test:
    tests/test_event_time.py::test_fold_axis0_ordered).  Deliberately NOT
    ``swag_base.chunk_fold``: that computes a full suffix scan (L log L
    combines) to read one entry, while this is the telemetry read path —
    ``window_fold`` runs per observation — and needs only L combines.
    """
    ident = monoid.identity()
    n = chunk_length(tree_arr)
    if n == 0:
        return ident
    while n > 1:
        if n % 2:
            tree_arr = jax.tree.map(
                lambda a, i: jnp.concatenate(
                    [a, jnp.broadcast_to(jnp.asarray(i, a.dtype), (1,) + a.shape[1:])],
                    axis=0,
                ),
                tree_arr,
                ident,
            )
            n += 1
        tree_arr = monoid.combine(
            jax.tree.map(lambda a: a[0::2], tree_arr),
            jax.tree.map(lambda a: a[1::2], tree_arr),
        )
        n //= 2
    return tree_index(tree_arr, 0)


# ---------------------------------------------------------------------------
# Variable-span range folds (the bulk event-time window primitive)
# ---------------------------------------------------------------------------


def range_fold(monoid: Monoid, arr: PyTree, starts, ends) -> PyTree:
    """``out[q] = arr[starts[q]] ⊗ … ⊗ arr[ends[q]]`` for every query q.

    Doubling table + binary span decomposition, left-to-right (exact for
    non-commutative monoids; see module docstring).  ``arr`` is an (M, ...)
    stack; ``starts``/``ends`` are (Q,) int32; an empty span
    (``ends < starts``) yields the identity.  O(M log M) combines to build
    the table, O(log M) per query, everything vectorized.
    """
    ident = monoid.identity()
    M = chunk_length(arr)
    levels = [arr]
    span = 1
    while span < M:
        prev = levels[-1]
        shifted = jax.tree.map(
            lambda a, i: jnp.concatenate(
                [
                    a[span:],
                    jnp.broadcast_to(
                        jnp.asarray(i, a.dtype), (min(span, M),) + a.shape[1:]
                    ),
                ],
                axis=0,
            ),
            prev,
            ident,
        )
        levels.append(monoid.combine(prev, shifted))
        span *= 2

    starts = jnp.asarray(starts, jnp.int32)
    ends = jnp.asarray(ends, jnp.int32)
    length = jnp.maximum(ends - starts + 1, 0)
    acc = jax.tree.map(
        lambda a, i: jnp.broadcast_to(
            jnp.asarray(i, a.dtype), starts.shape + a.shape[1:]
        ),
        arr,
        ident,
    )
    pos = starts
    for k in reversed(range(len(levels))):
        take = ((length >> k) & 1).astype(bool)
        vals = _take0(levels[k], jnp.clip(pos, 0, M - 1))
        acc = _where_rows(~take, acc, monoid.combine(acc, vals))
        pos = pos + jnp.where(take, jnp.int32(1 << k), jnp.int32(0))
    return acc


def range_fold_invertible(monoid: Monoid, arr: PyTree, starts, ends) -> PyTree:
    """Range folds via one prefix scan + ``inverse_front`` — O(1) combines
    per query.  Requires an invertible COMMUTATIVE monoid (the inverse
    removes a whole prefix, which is only order-safe when ⊗ commutes)."""
    ident = monoid.identity()
    M = chunk_length(arr)
    pref = jax.lax.associative_scan(monoid.combine, arr, axis=0)
    starts = jnp.asarray(starts, jnp.int32)
    ends = jnp.asarray(ends, jnp.int32)
    at_end = _take0(pref, jnp.clip(ends, 0, M - 1))
    before = _take0(pref, jnp.clip(starts - 1, 0, M - 1))
    sliced = monoid.inverse_front(at_end, before)
    full = _where_rows(starts > 0, sliced, at_end)
    empty_or_pad = (ends < starts) | (ends < 0)
    identity_rows = jax.tree.map(
        lambda a, i: jnp.broadcast_to(jnp.asarray(i, a.dtype), a.shape), full, ident
    )
    return _where_rows(empty_or_pad, identity_rows, full)


# Host-side ⊗ counters for the flip sweeps (engines built with
# ``instrument_combines=True``): every combine in an instrumented sweep
# bumps its engine's counter by the number of element-rows it touched — the
# regression tests assert combines-per-swept-element stays FLAT as the
# window grows (the constant-combine claim, measured at runtime).  The
# counters now live in :mod:`repro.obs.counters` (one home for the
# effects-barrier-before-read rule); ``COMBINE_COUNTS`` is a thin
# deprecated alias — the dict surface still works, and barriered reads
# should go through ``obs_counters.combines.read()``.
COMBINE_COUNTS = obs_counters.combines


def reset_combine_counts() -> None:
    obs_counters.combines.reset()


def _count_combines(key: str, n: int) -> None:
    obs_counters.combines.bump(key, n)


def _count_release(key: str) -> None:
    obs_counters.releases.bump(key, 1)


# ring length of the per-chunk out-of-order distance gauge in the engine
# state: obs scrapes report max/p95 over the last OOO_RING chunks
OOO_RING = 32


def counting_combines(monoid: Monoid, key: str) -> Monoid:
    """``monoid`` with a combine that bumps the ``obs.counters.combines``
    group (key = engine name) by the static leading-axis length of its
    operands at every runtime invocation (a ``jax.debug.callback``, so
    jitted executions are counted too)."""

    def combine(a, b):
        n = int(chunk_length(a))
        jax.debug.callback(lambda key=key, n=n: _count_combines(key, n))
        return monoid.combine(a, b)

    return dataclasses.replace(
        monoid, name=monoid.name + "#combcount", combine=combine
    )


# ---------------------------------------------------------------------------
# Segmented scans (the flip-sweep building blocks)
# ---------------------------------------------------------------------------


def seg_suffix_scan(monoid: Monoid, end_flags, lifted: PyTree) -> PyTree:
    """Suffix scan that resets at segment ends: ``out[i] = x_i ⊗ … ⊗ x_e(i)``
    where ``e(i)`` is the last index of i's segment (``end_flags[e] = True``).

    Built from the classic segmented-scan pair operator on the flipped
    array with swapped combine operands, keeping the older operand LEFT
    (the operand-order rule in the module docstring) — exact for
    non-commutative monoids.
    """
    flags = jnp.flip(jnp.asarray(end_flags, bool))
    vals = jax.tree.map(lambda a: jnp.flip(a, 0), lifted)

    def comb(a, b):
        fa, va = a
        fb, vb = b
        merged = monoid.combine(vb, va)  # flipped order: b is OLDER
        v = jax.tree.map(
            lambda mv, bv: jnp.where(_bc(fb, bv), bv, mv), merged, vb
        )
        return (fa | fb, v)

    _, out = jax.lax.associative_scan(comb, (flags, vals), axis=0)
    return jax.tree.map(lambda a: jnp.flip(a, 0), out)


def seg_prefix_scan(monoid: Monoid, start_flags, lifted: PyTree) -> PyTree:
    """Prefix scan that resets at segment starts: ``out[i] = x_s(i) ⊗ … ⊗ x_i``
    where ``s(i)`` is the last index ≤ i with ``start_flags`` True (0 when
    none).  Natural-order pair operator, older operand LEFT — the mirror of
    :func:`seg_suffix_scan` and the second half of every flip sweep."""
    flags = jnp.asarray(start_flags, bool)

    def comb(a, b):
        fa, va = a
        fb, vb = b
        merged = monoid.combine(va, vb)  # a is OLDER: left
        v = jax.tree.map(
            lambda mv, bv: jnp.where(_bc(fb, bv), bv, mv), merged, vb
        )
        return (fa | fb, v)

    _, out = jax.lax.associative_scan(comb, (flags, lifted), axis=0)
    return out


def flip_range_fold(monoid: Monoid, arr: PyTree, starts, ends, *,
                    instrument: Optional[str] = None) -> PyTree:
    """:func:`range_fold` for MONOTONE query sets in O(1) combines/element.

    Requires ``starts`` non-decreasing and ``ends`` STRICTLY increasing (the
    flip invariant — see the module docstring; violating it silently returns
    wrong folds: two same-end queries with different starts cannot share one
    flip cell).  Released merge positions satisfy both by construction.
    Flip boundaries are the orbit of ``hop(b) = max(b+1, first i whose
    per-position window start ≥ b)`` from 0, marked by gather-only binary
    lifting (O(M log M) *integer* work, zero ⊗, no scatters — scatters
    lower to sequential loops on CPU and were ~40× slower); outputs are one
    segmented suffix scan + one segmented prefix scan + one combine per
    query.  Empty spans
    (``ends < starts``) yield the identity.  ``instrument`` names a
    ``COMBINE_COUNTS`` key to bump per runtime combine.
    """
    ident = monoid.identity()
    m = counting_combines(monoid, instrument) if instrument else monoid
    M = int(chunk_length(arr))
    starts = jnp.asarray(starts, jnp.int32)
    ends = jnp.asarray(ends, jnp.int32)
    Q = int(starts.shape[0])
    if M == 0 or Q == 0:
        return jax.tree.map(
            lambda a, i: jnp.broadcast_to(
                jnp.asarray(i, a.dtype), (Q,) + a.shape[1:]
            ),
            arr,
            ident,
        )
    idx = jnp.arange(M, dtype=jnp.int32)

    # Per-position window start: the smallest start among queries ending at
    # or after i (monotone), clamped to ≤ i so positions no query ends at
    # never force a boundary of their own.
    qi = jnp.searchsorted(ends, idx, side="left").astype(jnp.int32)
    sbar = jnp.where(qi >= Q, M, starts[jnp.clip(qi, 0, Q - 1)])
    s_pos = jnp.clip(jnp.minimum(sbar, idx), 0, M)

    # hop(b) = max(b+1, first i with s_pos[i] >= b); boundaries = orbit of 0.
    # For every query q with end in cell [B_m, B_{m+1}): B_{m-1} <= start_q
    # <= B_m.  Binary lifting: levels[d] = hop^(2^d); a greedy descent from 0
    # yields, for each position i, the largest orbit element <= i (every step
    # count is a sum of powers of two) — i is a boundary iff that is i itself.
    bpos = jnp.arange(M + 1, dtype=jnp.int32)
    first_ge = jnp.searchsorted(s_pos, bpos, side="left").astype(jnp.int32)
    hop = jnp.minimum(jnp.maximum(bpos + 1, first_ge), M)
    levels = [hop]
    for _ in range(max(1, math.ceil(math.log2(M + 1))) - 1):
        levels.append(levels[-1][levels[-1]])
    cur = jnp.zeros((M + 1,), jnp.int32)
    for lv in reversed(levels):
        nxt = lv[cur]
        cur = jnp.where(nxt <= bpos, nxt, cur)
    mark = cur == bpos

    start_flags = mark[:M]
    end_flags = mark[1:] | (idx == M - 1)
    cellstart = jax.lax.associative_scan(
        jnp.maximum, jnp.where(start_flags, idx, 0)
    )
    bpref = seg_prefix_scan(m, start_flags, arr)
    bsuf = seg_suffix_scan(m, end_flags, arr)

    e_c = jnp.clip(ends, 0, M - 1)
    right = _take0(bpref, e_c)  # [cellstart[e], e]
    left = _take0(bsuf, jnp.clip(starts, 0, M - 1))  # [s, its cell end]
    both = m.combine(left, right)  # older operand LEFT
    out = _where_rows(starts >= cellstart[e_c], right, both)
    identity_rows = jax.tree.map(
        lambda a, i: jnp.broadcast_to(jnp.asarray(i, a.dtype), a.shape),
        out,
        ident,
    )
    return _where_rows((ends < starts) | (ends < 0), identity_rows, out)


# ---------------------------------------------------------------------------
# Per-element protocol
# ---------------------------------------------------------------------------


class TimestampedWindow:
    """Event-time sliding window over any SWAG algorithm (per-element).

    Wraps ``algo.init/insert/evict/query`` with a parallel timestamp queue:
    the window holds every element with ``ts' > newest_watermark - horizon``.
    ``insert`` requires event-time order (out-of-order ingestion is
    :class:`EventTimeChunkedStream`'s job); :meth:`advance` turns a watermark
    movement into ONE :func:`repro.core.swag_base.evict_bulk` call covering
    every expired element — the paper's worst-case O(1) per-evict cost times
    exactly the number of expirations, with a single dispatch.
    """

    def __init__(self, algo, monoid: Monoid, horizon, capacity: int):
        self.algo = algo
        self.monoid = monoid
        self.horizon = horizon
        self.capacity = capacity
        self.state = algo.init(monoid, capacity)
        self._ts: collections.deque = collections.deque()
        self.watermark: Optional[float] = None

    def insert(self, ts, value) -> None:
        if self.watermark is not None and ts < self.watermark:
            raise ValueError(
                f"TimestampedWindow.insert needs event-time order (got {ts} "
                f"below the watermark {self.watermark}); use "
                f"EventTimeChunkedStream for out-of-order streams"
            )
        self.state = self.algo.insert(self.monoid, self.state, value)
        self._ts.append(ts)
        self.advance(ts)

    def advance(self, watermark) -> int:
        """Advance the watermark; bulk-evict expired elements.  Returns the
        number evicted."""
        if self.watermark is not None:
            watermark = max(watermark, self.watermark)
        self.watermark = watermark
        k = 0
        thr = watermark - self.horizon
        while self._ts and self._ts[0] <= thr:
            self._ts.popleft()
            k += 1
        if k:
            self.state = swag_base.evict_bulk(self.algo, self.monoid, self.state, k)
        return k

    def query(self):
        return self.algo.query(self.monoid, self.state)

    def lowered_query(self):
        return self.monoid.lower(self.query())

    def size(self) -> int:
        return len(self._ts)

    def __len__(self) -> int:
        return self.size()


# ---------------------------------------------------------------------------
# The bulk engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EventTimeResult:
    """Compacted output of :meth:`EventTimeChunkedStream.stream` (host).

    ``ts``/``ys``: released event times (event order) and the matching
    (R, B, ...) window aggregates (pre-``lower``).  ``late_rows``: arrival
    indices of every element that arrived below the watermark, under ANY
    policy — check ``n_dropped`` to tell excluded rows from merged ones
    (``"merge"`` flags late rows here but still folds the in-horizon ones
    into the window).  ``state``: the final engine state.
    """

    ts: np.ndarray
    ys: Any
    late_rows: np.ndarray
    n_late: int
    n_dropped: int
    state: Any


class EventTimeChunkedStream:
    """Chunk-at-a-time event-time sliding-window aggregation over (T, B).

    Usage::

        eng = EventTimeChunkedStream(monoid, horizon=60.0, slack=5.0)
        state = eng.init_state(batch)
        state, out = eng.process_chunk(state, ts_chunk, xs_chunk)
        ...
        res = eng.stream(ts, xs)      # whole stream + flush, compacted

    Per chunk: watermark advance, then the **disorder-adaptive release
    path** (:mod:`repro.core.ooo_index`) — a ``lax.cond`` tests whether the
    masked chunk appends at the frontier (non-decreasing, everything at or
    above the previous ``max_ts``); if so (out-of-order distance 0, the
    steady state of an in-order stream) the sorted pending run is ONE
    compacting gather and released rows concatenate after the window with
    no sort and no searchsorted merge, else the trailing (buffer ++ chunk)
    region — never the window — is stable time-sorted and released rows
    rank-merge into the live window.  Then per-released-element window
    outputs via the constant-combine :func:`flip_range_fold` sweep (or the
    invertible-commutative prefix-scan fast path), and a watermark-driven
    bulk eviction of expired window entries (a contiguous slice of the
    merged array — no re-sort).
    All shapes are static — full and (mask-padded) ragged chunks share one
    compilation, mirroring :class:`repro.core.chunked.ChunkedStream`.
    ``instrument_release=True`` counts the branch taken per chunk in
    ``repro.obs.counters.releases`` (barrier before reading: use
    ``.read()``); the per-chunk measured out-of-order distance rides in the
    state (``ooo_recent``) and surfaces as the ``ooo_distance`` obs gauges.

    Capacities (static): ``capacity`` bounds the number of live in-horizon
    elements (overflow loses the OLDEST window entries), ``buffer`` bounds
    the reorder buffer (overflow loses the NEWEST pending arrivals — the
    time-sorted prefix closest to release is kept).  Either overflow bumps
    ``state["n_overflow"]`` (checked — with a raise — by :meth:`stream`;
    other callers should poll the counter).
    """

    def __init__(
        self,
        monoid: Monoid,
        horizon,
        *,
        slack=0,
        chunk: int = 256,
        capacity: int = 1024,
        buffer: Optional[int] = None,
        late_policy: str = "drop",
        ts_dtype=jnp.float32,
        use_inverse: Optional[bool] = None,
        instrument_combines: bool = False,
        instrument_release: bool = False,
    ):
        if late_policy not in ("drop", "side_output", "merge"):
            raise ValueError(f"unknown late_policy {late_policy!r}")
        self.monoid = monoid
        self.chunk = int(chunk)
        self.capacity = int(capacity)
        self.buffer = int(buffer) if buffer is not None else self.chunk
        self.late_policy = late_policy
        self.ts_dtype = jnp.dtype(ts_dtype)
        tmin, tmax = ts_limits(self.ts_dtype)
        self._tmin = jnp.asarray(tmin, self.ts_dtype)
        self._tmax = jnp.asarray(tmax, self.ts_dtype)
        self.horizon = jnp.asarray(horizon, self.ts_dtype)
        self.slack = jnp.asarray(slack, self.ts_dtype)
        if use_inverse is None:
            use_inverse = monoid.invertible and monoid.commutative
        self._use_inverse = use_inverse
        self.instrument_combines = bool(instrument_combines)
        self.instrument_release = bool(instrument_release)
        self._jitted = {}  # (C, with_outputs, path) -> jitted impl
        self._scan_jitted = {}  # (T, n_full, path) -> jitted whole-stream scan
        self._full_masks: dict = {}

    # -- state -------------------------------------------------------------

    def init_state(self, batch: int) -> PyTree:
        ident = self.monoid.identity()
        W, K = self.capacity, self.buffer

        def fill(n):
            return jax.tree.map(
                lambda i: jnp.broadcast_to(
                    jnp.asarray(i), (n, batch) + jnp.asarray(i).shape
                ).copy(),
                ident,
            )

        zero = jnp.zeros((), jnp.int32)
        return {
            "win_ts": jnp.full((W,), self._tmin, self.ts_dtype),
            "win_agg": fill(W),
            "buf_ts": jnp.full((K,), self._tmax, self.ts_dtype),
            "buf_agg": fill(K),
            "wm": self._tmin,
            "max_ts": self._tmin,
            "n_late": zero,
            "n_dropped": zero,
            "n_overflow": zero,
            "ooo_recent": jnp.zeros((OOO_RING,), jnp.int32),
        }

    def window_fold(self, state: PyTree) -> PyTree:
        """Aggregate of the live window (pads are identities): (B, ...)."""
        return fold_axis0(self.monoid, state["win_agg"])

    # -- observability -----------------------------------------------------

    def obs_metrics(self, state: PyTree, now=None) -> dict:
        """Engine health as DEVICE scalars — no host sync here; the obs
        registry batches the transfer at scrape time.

        ``watermark_lag`` is ``now - wm`` when the caller supplies a
        processing-time "now" in event-time units, else the engine-internal
        ``max_ts - wm`` (= ``slack`` in steady state, less before the first
        chunk fills it).
        """
        wm, max_ts = state["wm"], state["max_ts"]
        lag = (now - wm) if now is not None else (max_ts - wm)
        return {
            "watermark": wm,
            "watermark_lag": lag,
            "buffer_occupancy":
                (state["buf_ts"] < self._tmax).sum(dtype=jnp.int32),
            "window_occupancy":
                (state["win_ts"] > self._tmin).sum(dtype=jnp.int32),
            "late_total": state["n_late"],
            "dropped_total": state["n_dropped"],
            "overflow_total": state["n_overflow"],
            "ooo_distance_max": jnp.max(state["ooo_recent"]),
            "ooo_distance_p95": jnp.percentile(
                state["ooo_recent"].astype(jnp.float32), 95.0
            ),
        }

    def attach_obs(self, registry, get_state, *, prefix: str = "repro_eventtime"):
        """Register a scrape collector: ``get_state()`` must return the
        engine's CURRENT state (host-owned, e.g. the variable the caller
        threads through :meth:`process_chunk` — this engine does not donate,
        so the reference stays valid)."""
        names = {
            "watermark": (f"{prefix}_watermark", "gauge",
                          "event-time watermark (max_ts - slack)"),
            "watermark_lag": (f"{prefix}_watermark_lag", "gauge",
                              "event-time distance max_ts - wm"),
            "buffer_occupancy": (f"{prefix}_reorder_buffer_occupancy", "gauge",
                                 "live entries waiting in the reorder buffer"),
            "window_occupancy": (f"{prefix}_window_occupancy", "gauge",
                                 "live entries inside the horizon window"),
            "late_total": (f"{prefix}_late_total", "counter",
                           "elements that arrived below the published watermark"),
            "dropped_total": (f"{prefix}_dropped_total", "counter",
                              "late elements discarded by the drop policy"),
            "overflow_total": (f"{prefix}_overflow_total", "counter",
                               "elements lost to reorder-buffer/window overflow"),
            "ooo_distance_max": (
                f"{prefix}_ooo_distance_max", "gauge",
                f"max measured out-of-order distance over the last "
                f"{OOO_RING} chunks"),
            "ooo_distance_p95": (
                f"{prefix}_ooo_distance_p95", "gauge",
                f"p95 measured out-of-order distance over the last "
                f"{OOO_RING} chunks"),
        }
        for key, (series, typ, help) in names.items():
            registry.describe(series, typ, help)

        def collect():
            metrics = self.obs_metrics(get_state())
            return {names[k][0]: v for k, v in metrics.items()}

        registry.register_collector(collect)
        return collect

    # -- one chunk ---------------------------------------------------------

    def process_chunk(self, state, ts, xs, mask=None, *, final=False,
                      with_outputs: bool = True, path: Optional[str] = None):
        """Consume a chunk: ``ts`` (C,), ``xs`` (C, B, ...) raw inputs.

        ``mask`` (C,) pads a ragged final chunk (False rows are ignored
        entirely).  ``final=True`` pushes the watermark to +∞, draining the
        reorder buffer (end of stream).  ``with_outputs=False`` skips the
        per-released-element outputs (window/buffer upkeep only — the
        telemetry read path).  Returns ``(state, out)`` with ``out`` a dict:
        ``ts``/``ys`` (P = buffer+C rows, ``mask`` selects the released
        prefix, event order) and ``late`` (C,) late-arrival flags.

        ``path`` pins the release branch STATICALLY (its own jit cache
        entry): ``None`` (default) traces the runtime ``lax.cond``;
        ``"slow"`` always sorts (correct for any chunk); ``"fast"``
        compiles the branch-free in-order program — the caller GUARANTEES
        the chunk appends at the frontier (:meth:`stream` proves this on
        the host from the full timestamp array; an unproven "fast" on a
        disordered chunk silently corrupts the window).  XLA:CPU charges a
        conditional in this program shape ~400 us/chunk in lost fusion, so
        the static variants are the hot path.
        """
        C = int(jnp.shape(jnp.asarray(ts))[0])
        if mask is None:
            mask = self._full_mask(C)
        key = (C, bool(with_outputs), path)
        fn = self._jitted.get(key)
        if fn is None:
            fn = self._jitted[key] = jax.jit(
                lambda st, t, x, mk, fin: self._process_impl(
                    st, t, x, mk, fin, with_outputs, path
                )
            )
        return fn(state, ts, xs, mask, jnp.asarray(final, bool))

    def chunk_fn(self, state, ts, xs, mask=None, *, final=False,
                 with_outputs: bool = True, path: Optional[str] = None):
        """Unjitted :meth:`process_chunk` body — pure, for composing into a
        caller's own ``jit`` (the telemetry layer's fused observe)."""
        C = int(jnp.shape(jnp.asarray(ts))[0])
        if mask is None:
            mask = self._full_mask(C)
        return self._process_impl(
            state, ts, xs, mask, jnp.asarray(final, bool), with_outputs, path
        )

    def flush(self, state, example_xs):
        """Drain the reorder buffer (watermark → +∞): every pending element
        is released and the resulting window is fully evicted — terminal,
        for end-of-stream.  ``example_xs`` is any one-row (1, B, ...) input
        tree (values ignored — fully masked); it only fixes the traced
        shapes."""
        ts = jnp.zeros((1,), self.ts_dtype)
        mask = jnp.zeros((1,), bool)
        row = jax.tree.map(lambda a: a[:1], example_xs)
        # a fully-masked chunk is trivially at the frontier (every ts_in row
        # is the TS_MAX sentinel), so the drain always takes the fast path
        return self.process_chunk(state, ts, row, mask, final=True,
                                  path="fast")

    def _full_mask(self, C: int):
        m = self._full_masks.get(C)
        if m is None:
            m = self._full_masks[C] = jnp.ones((C,), bool)
        return m

    def _stream_scan(self, T: int, n_full: int, path: str):
        """Jitted ``lax.scan`` over a stream's full-chunk prefix: ONE
        dispatch for ``n_full`` chunks, every chunk on the statically
        resolved release branch (see :meth:`stream` — the per-chunk python
        dispatch otherwise dominates the fast path).  Outputs come back
        stacked with an (n_full,) leading axis."""
        key = (T, n_full, path)
        fn = self._scan_jitted.get(key)
        if fn is None:
            C = self.chunk
            mask = self._full_mask(C)

            def scan_fn(state, ts, xs):
                tsc = ts[: n_full * C].reshape(n_full, C)
                xsc = jax.tree.map(
                    lambda a: a[: n_full * C].reshape(
                        (n_full, C) + a.shape[1:]
                    ),
                    xs,
                )

                def body(st, inp):
                    t, x = inp
                    return self._process_impl(
                        st, t, x, mask, jnp.asarray(False), True, path
                    )

                return jax.lax.scan(body, state, (tsc, xsc))

            fn = self._scan_jitted[key] = jax.jit(scan_fn)
        return fn

    # -- impl ---------------------------------------------------------------

    def _process_impl(self, state, ts, xs, mask, final, with_outputs,
                      path: Optional[str] = None):
        m = self.monoid
        ident = m.identity()
        W, K = self.capacity, self.buffer
        tmin, tmax = self._tmin, self._tmax

        ts = jnp.asarray(ts, self.ts_dtype)
        C = ts.shape[0]
        valid = jnp.asarray(mask, bool)
        lifted = jax.vmap(jax.vmap(m.lift))(xs)  # (C, B, ...) Agg

        # -- watermark advance (monotone; final drains everything) ---------
        chunk_max = jnp.max(jnp.where(valid, ts, tmin))
        prev_max = state["max_ts"]  # the append frontier the chunk must clear
        max_ts = jnp.maximum(prev_max, chunk_max)
        wm_prev = state["wm"]
        base_wm = jnp.where(max_ts > tmin, max_ts - self.slack, tmin)
        wm = jnp.maximum(jnp.where(final, tmax, base_wm), wm_prev)
        evict_thr = jnp.where(wm > tmin, wm - self.horizon, tmin)

        # -- late-data policy ----------------------------------------------
        late = valid & (wm_prev > tmin) & (ts < wm_prev)
        if self.late_policy == "merge":
            drop = late & (ts <= evict_thr)  # unrepresentable: past the window
        else:
            drop = late
        n_late = state["n_late"] + late.sum(dtype=jnp.int32)
        n_dropped = state["n_dropped"] + drop.sum(dtype=jnp.int32)
        keep_in = valid & ~drop
        ts_in = jnp.where(keep_in, ts, tmax)
        chunk_agg = _mask_tree(lifted, keep_in, ident)

        # -- disorder-adaptive release path (core/ooo_index.py) -------------
        # A lax.cond picks, per chunk, how the sorted pending permutation is
        # produced: the d = 0 fast branch — the chunk appends at the
        # frontier (prev_max), so the permutation is pure index arithmetic
        # (compact_perm): no sort, no timestamp comparisons — vs the
        # general branch's stable argsort of the trailing (buffer ++ chunk)
        # region (never the window), which also measures the chunk's true
        # out-of-order distance from the permutation.  ONLY the (P,)
        # permutation + mask + distance cross the cond: XLA:CPU
        # conditionals copy their operands/results and block fusion, so
        # keeping the branch bodies tiny is worth ~450 us/chunk over
        # putting the merge inside.  The gathers, release split, gather-only
        # rank merge, output sweep and eviction below are branch-free, so
        # outputs are bit-exact whichever branch produced the permutation.
        win_ts, win_agg = state["win_ts"], state["win_agg"]
        buf_ts, buf_agg = state["buf_ts"], state["buf_agg"]
        P = K + C
        Mtot = W + P
        pend_ts0 = jnp.concatenate([buf_ts, ts_in])

        def _fast(_):
            if self.instrument_release:
                jax.debug.callback(lambda: _count_release("fast"))
            src, in_range = ooo_index.compact_perm(buf_ts, C, tmax=tmax)
            return src, in_range, jnp.zeros((), jnp.int32)

        def _slow(_):
            if self.instrument_release:
                jax.debug.callback(lambda: _count_release("slow"))
            order = jnp.argsort(pend_ts0, stable=True).astype(jnp.int32)
            d = ooo_index.displacement(pend_ts0, order, tmax)
            return order, jnp.ones((P,), bool), d

        if path == "fast":  # statically proven in-order (see process_chunk)
            src, in_range, d_chunk = _fast(0)
        elif path == "slow":
            src, in_range, d_chunk = _slow(0)
        else:
            fast_ok = ooo_index.chunk_in_order(ts_in, prev_max)
            src, in_range, d_chunk = jax.lax.cond(fast_ok, _fast, _slow, 0)
        ooo_recent = jnp.concatenate([state["ooo_recent"][1:], d_chunk[None]])

        # apply the permutation (identical math for both branches: the slow
        # permutation has in_range all-True, so the masking is a no-op), then
        # peel the released prefix / shift the remainder into the new buffer
        pend_agg0 = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0), buf_agg, chunk_agg
        )
        pend_ts = jnp.where(in_range, pend_ts0[src], tmax)
        pend_agg = _mask_tree(_take0(pend_agg0, src), in_range, ident)
        rel_ts, rel_agg, rel, buf_ts_new, buf_agg_new, ovf_inc = (
            ooo_index.release_split(
                pend_ts, pend_agg, wm, buffer=K, tmax=tmax, ident=ident
            )
        )
        n_overflow = state["n_overflow"] + ovf_inc

        # stable gather-only merge of released elements into the window
        # (rank-dual searchsorteds — no sort, no combines; in the fast case
        # every released row lands after the window live region, and the
        # ranks come out equal to a plain append)
        mts, magg, pos_rel = ooo_index.rank_merge(
            win_ts, win_agg, rel_ts, rel_agg
        )

        # -- per-released-element outputs: fold over (ts - horizon, ts] -----
        # Released queries are monotone in both start and end — the flip
        # invariant (module docstring) — so the non-invertible path is one
        # constant-combine flip sweep instead of the old doubling table.
        if with_outputs:
            ends = pos_rel
            starts = jnp.searchsorted(
                mts, rel_ts - self.horizon, side="right"
            ).astype(jnp.int32)
            # materialize the gathered merge once: without the barrier XLA
            # re-fuses the merge gathers into every scan round of the sweep
            marr = jax.lax.optimization_barrier(magg)
            if self._use_inverse:
                ys = range_fold_invertible(m, marr, starts, ends)
            else:
                ys = flip_range_fold(
                    m, marr, starts, ends,
                    instrument="eventtime" if self.instrument_combines
                    else None,
                )
        else:
            ys = None

        # -- watermark-driven bulk eviction + window re-pack ----------------
        # Kept entries are a contiguous range of the merged (sorted) array:
        # (max(evict_thr, tmin), tmax).  Right-align its newest W entries
        # into the window with one gather — no argsort re-pack.
        lo = jnp.searchsorted(
            mts, jnp.maximum(evict_thr, tmin), side="right"
        ).astype(jnp.int32)
        hi = jnp.searchsorted(mts, tmax, side="left").astype(jnp.int32)
        n_keep = hi - lo
        wsrc = hi - W + jnp.arange(W, dtype=jnp.int32)
        valid_w = wsrc >= lo
        wsrc_c = jnp.clip(wsrc, 0, Mtot - 1)
        win_ts_new = jnp.where(valid_w, mts[wsrc_c], tmin)
        win_agg_new = _mask_tree(_take0(magg, wsrc_c), valid_w, ident)
        n_overflow = n_overflow + jnp.maximum(n_keep - W, 0)

        state = {
            "win_ts": win_ts_new,
            "win_agg": win_agg_new,
            "buf_ts": buf_ts_new,
            "buf_agg": buf_agg_new,
            "wm": wm,
            "max_ts": max_ts,
            "n_late": n_late,
            "n_dropped": n_dropped,
            "n_overflow": n_overflow,
            "ooo_recent": ooo_recent,
        }
        out = {"ts": rel_ts, "ys": ys, "mask": rel, "late": late}
        return state, out

    # -- whole stream ------------------------------------------------------

    def stream(self, ts, xs, *, state: Optional[PyTree] = None,
               flush: bool = True) -> EventTimeResult:
        """Aggregate a whole timestamped (T, B) stream chunk-by-chunk.

        Outputs are compacted with ONE host transfer at the end.  With
        ``flush=True`` (default) the reorder buffer is drained, so every
        non-dropped element is released and — when disorder ≤ slack — the
        outputs equal the in-order reference of the sorted stream.  Raises
        ``RuntimeError`` if a capacity overflowed (results would be wrong).
        """
        ts = jnp.asarray(ts, self.ts_dtype)
        T = int(ts.shape[0])
        batch = jax.tree.leaves(xs)[0].shape[1]
        if state is None:
            state = self.init_state(batch)
        if T == 0:
            if flush and bool(
                (np.asarray(state["buf_ts"]) < np.asarray(self._tmax)).any()
            ):
                raise ValueError(
                    "stream() got an empty chunk but the carried-in state has "
                    "pending reorder-buffer elements; an empty chunk cannot "
                    "fix the input shapes for the drain — call "
                    "eng.flush(state, example_row) directly"
                )
            return EventTimeResult(
                ts=np.zeros((0,), self.ts_dtype),
                ys=None,
                late_rows=np.zeros((0,), np.int64),
                n_late=int(state["n_late"]),
                n_dropped=int(state["n_dropped"]),
                state=state,
            )
        # Resolve the release branch per chunk on the HOST: the whole ts
        # array is in hand, so the device's frontier/watermark recurrence
        # can be replayed exactly (same-dtype arithmetic, identical
        # comparisons) and every chunk runs the branch-free specialized
        # program — process_chunk(path=...) — instead of the runtime
        # lax.cond (which XLA:CPU charges ~400 us/chunk in lost fusion).
        # "fast" is only claimed when the chunk provably appends at the
        # frontier with nothing late, the exact device predicate.
        ts_host = np.asarray(jax.device_get(ts))
        prev_max, prev_wm = (
            np.asarray(v) for v in jax.device_get(
                (state["max_ts"], state["wm"])
            )
        )
        tmin_h = np.asarray(jax.device_get(self._tmin))
        slack_h = np.asarray(jax.device_get(self.slack))
        C = self.chunk
        paths = []
        for lo in range(0, T, C):
            r = ts_host[lo:min(lo + C, T)]
            in_order = bool(np.all(r[1:] >= r[:-1]))
            fast = in_order and bool(r[0] >= prev_max) and bool(r[0] >= prev_wm)
            paths.append("fast" if fast else "slow")
            prev_max = np.maximum(prev_max, r.max())
            base_wm = prev_max - slack_h if prev_max > tmin_h else tmin_h
            prev_wm = np.maximum(base_wm, prev_wm)

        outs = []  # per-chunk (out dict, #real chunk rows) after the scan
        stacked = None  # (n_full, ...) leading-axis outs of the scanned prefix
        n_full = T // C
        # When every full chunk agrees on the branch — in-order streams are
        # all-fast, heavily disordered ones all-slow — the whole chunk loop
        # runs as ONE jitted lax.scan: a single dispatch for T/C chunks
        # (the per-chunk python dispatch otherwise dominates the fast path).
        use_scan = n_full >= 2 and len(set(paths[:n_full])) == 1
        if use_scan:
            state, stacked = self._stream_scan(
                T, n_full, paths[0]
            )(state, ts, xs)
            start = n_full * C
        else:
            start = 0
        for lo in range(start, T, C):
            hi = min(lo + C, T)
            pts = ts[lo:hi]
            pxs = jax.tree.map(lambda a: a[lo:hi], xs)
            if hi - lo < C:  # ragged final chunk: pad + mask
                pad = C - (hi - lo)
                pts = jnp.concatenate(
                    [pts, jnp.broadcast_to(pts[-1:], (pad,))], axis=0
                )
                pxs = jax.tree.map(
                    lambda a: jnp.concatenate(
                        [a, jnp.broadcast_to(a[-1:], (pad,) + a.shape[1:])], 0
                    ),
                    pxs,
                )
                mask = jnp.arange(C) < (hi - lo)
            else:
                mask = None
            state, out = self.process_chunk(
                state, pts, pxs, mask, path=paths[lo // C]
            )
            outs.append((out, hi - lo))
        if flush and T > 0:
            state, out = self.flush(state, jax.tree.map(lambda a: a[:1], xs))
            outs.append((out, 0))

        # one host transfer for everything; the per-chunk outputs are
        # concatenated HOST-side with numpy (a device jnp.concatenate over
        # ~T/C small operands costs more in dispatch than the chunk loop)
        host = jax.device_get(
            {
                "stacked": stacked,
                "outs": [o for o, _ in outs],
                "counters": {
                    k: state[k] for k in ("n_late", "n_dropped", "n_overflow")
                },
            }
        )
        if int(host["counters"]["n_overflow"]) > 0:
            raise RuntimeError(
                f"event-time engine overflow "
                f"({int(host['counters']['n_overflow'])} elements lost): "
                f"raise capacity= (live in-horizon elements) or buffer= "
                f"(reorder slots) for this stream"
            )

        def flat2(a):  # (n_full, L, ...) scan stack -> (n_full*L, ...)
            return a.reshape((a.shape[0] * a.shape[1],) + a.shape[2:])

        parts_ts, parts_mask, parts_late, parts_ys = [], [], [], []
        if host["stacked"] is not None:
            s = host["stacked"]
            parts_ts.append(flat2(s["ts"]))
            parts_mask.append(flat2(s["mask"]))
            parts_late.append(flat2(s["late"]))
            parts_ys.append(jax.tree.map(flat2, s["ys"]))
        for o, n in zip(host["outs"], (n for _, n in outs)):
            parts_ts.append(o["ts"])
            parts_mask.append(o["mask"])
            parts_late.append(o["late"][:n])
            if o["ys"] is not None:
                parts_ys.append(o["ys"])
        ts_all = np.concatenate(parts_ts)
        sel = np.concatenate(parts_mask)
        late_all = (
            np.concatenate(parts_late) if parts_late
            else np.zeros((0,), bool)
        )
        ys_all = (
            jax.tree.map(
                lambda *ps: np.concatenate(ps, axis=0), *parts_ys
            )
            if parts_ys else None
        )
        return EventTimeResult(
            ts=ts_all[sel],
            ys=jax.tree.map(lambda a: a[sel], ys_all)
            if ys_all is not None else None,
            late_rows=np.nonzero(late_all)[0],
            n_late=int(host["counters"]["n_late"]),
            n_dropped=int(host["counters"]["n_dropped"]),
            state=state,
        )


# ---------------------------------------------------------------------------
# Oracle
# ---------------------------------------------------------------------------


def in_order_reference(monoid: Monoid, ts, xs, horizon):
    """Eager per-element oracle: stable-sort by timestamp, then for each
    element fold (left-to-right) everything with ``ts' > ts - horizon``.

    Returns ``(sorted_ts, (T, B, ...) aggregates)`` — what a per-element
    :class:`TimestampedWindow` scan of the in-order stream emits, and what
    :meth:`EventTimeChunkedStream.stream` must reproduce whenever disorder
    ≤ slack.  O(T · window) combines — a test oracle, not an engine.
    """
    ts = np.asarray(ts)
    order = np.argsort(ts, kind="stable")
    lifted = jax.vmap(jax.vmap(monoid.lift))(xs)
    ident = monoid.identity()
    batch = jax.tree.leaves(lifted)[0].shape[1]
    ident_b = jax.tree.map(
        lambda i: jnp.broadcast_to(jnp.asarray(i), (batch,) + jnp.asarray(i).shape),
        ident,
    )
    win: list = []
    outs = []
    for i in order:
        win.append(i)
        while win and ts[win[0]] <= ts[i] - horizon:
            win.pop(0)
        acc = ident_b
        for j in win:
            acc = monoid.combine(acc, tree_index(lifted, int(j)))
        outs.append(acc)
    stacked = jax.tree.map(lambda *rows: jnp.stack(rows, axis=0), *outs)
    return ts[order], stacked
