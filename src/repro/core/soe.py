"""Subtract-on-evict baseline (paper §8.3) — invertible monoids ONLY.

Keeps a running aggregate plus a FIFO ring of lifted values (needed to know
*what* to subtract).  O(1) ⊗/inverse invocations per op, but requires a left
inverse — precisely the property the paper's algorithms do away with.
"""

from __future__ import annotations

import jax

import jax.numpy as jnp

from repro.core.monoids import Monoid
from repro.core.swag_base import (
    alloc_ring,
    carry_pseudo_elements,
    chunk_length,
    i32,
    ring_gather,
    ring_get,
    ring_set,
    suffix_carry_from_regions,
    swag_state,
    tree_index,
)


@swag_state
class SoeState:
    buf: object
    agg: object
    front: jax.Array
    end: jax.Array
    capacity: int


def init(monoid: Monoid, capacity: int) -> SoeState:
    if not monoid.invertible:
        raise ValueError(
            f"subtract-on-evict requires an invertible monoid, got {monoid.name}"
        )
    return SoeState(
        buf=alloc_ring(monoid, capacity),
        agg=monoid.identity(),
        front=i32(0),
        end=i32(0),
        capacity=capacity,
    )


def size(state: SoeState):
    return state.end - state.front


def insert(monoid: Monoid, state: SoeState, value) -> SoeState:
    v = monoid.lift(value)
    return SoeState(
        buf=ring_set(state.buf, state.end, v, state.capacity),
        agg=monoid.combine(state.agg, v),
        front=state.front,
        end=state.end + 1,
        capacity=state.capacity,
    )


def evict(monoid: Monoid, state: SoeState) -> SoeState:
    oldest = ring_get(state.buf, state.front, state.capacity)
    return SoeState(
        buf=state.buf,
        agg=monoid.inverse_front(state.agg, oldest),
        front=state.front + 1,
        end=state.end,
        capacity=state.capacity,
    )


def query(monoid: Monoid, state: SoeState):
    return state.agg


def state_to_carry(monoid: Monoid, state: SoeState, window: int):
    """Warm-carry extraction: the ring is raw lifted values — one suffix
    scan (all region offsets 0); the running aggregate is not needed."""
    length = state.capacity + 1
    log = ring_gather(state.buf, state.front, state.capacity, length)
    return suffix_carry_from_regions(
        monoid, log, log, state.end - state.front, 0, 0, 0, 0, window
    )


def state_from_chunk(monoid: Monoid, values, capacity: int) -> SoeState:
    """Fresh state from a chunk: raw lifted values plus one fold."""
    from repro.core.swag_base import chunk_fold, lift_chunk

    vs = lift_chunk(monoid, values)
    k = chunk_length(vs)
    if k > capacity:
        raise ValueError(f"chunk of {k} elements exceeds capacity {capacity}")
    state = init(monoid, capacity)
    if k == 0:
        return state
    idx = jnp.arange(k, dtype=jnp.int32)
    buf = jax.tree.map(lambda a, v: a.at[idx].set(v), state.buf, vs)
    return SoeState(
        buf=buf,
        agg=chunk_fold(monoid, vs),
        front=i32(0),
        end=i32(k),
        capacity=capacity,
    )


def carry_to_state(monoid: Monoid, carry, capacity: int) -> SoeState:
    """Carry import via pseudo-elements ``g_t = carry[t] ⊖ carry[t+1]``
    (soe is invertible by construction; commutativity is enforced by
    :func:`~repro.core.swag_base.carry_pseudo_elements`)."""
    h = chunk_length(carry)
    if h > capacity:
        raise ValueError(f"carry of {h} elements exceeds capacity {capacity}")
    state = init(monoid, capacity)
    if h == 0:
        return state
    g = carry_pseudo_elements(monoid, carry)
    idx = jnp.arange(h, dtype=jnp.int32)
    buf = jax.tree.map(lambda a, c: a.at[idx].set(c), state.buf, g)
    return SoeState(
        buf=buf,
        agg=tree_index(carry, 0),
        front=i32(0),
        end=i32(h),
        capacity=capacity,
    )
