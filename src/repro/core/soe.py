"""Subtract-on-evict baseline (paper §8.3) — invertible monoids ONLY.

Keeps a running aggregate plus a FIFO ring of lifted values (needed to know
*what* to subtract).  O(1) ⊗/inverse invocations per op, but requires a left
inverse — precisely the property the paper's algorithms do away with.
"""

from __future__ import annotations

import jax

from repro.core.monoids import Monoid
from repro.core.swag_base import alloc_ring, i32, ring_get, ring_set, swag_state


@swag_state
class SoeState:
    buf: object
    agg: object
    front: jax.Array
    end: jax.Array
    capacity: int


def init(monoid: Monoid, capacity: int) -> SoeState:
    if not monoid.invertible:
        raise ValueError(
            f"subtract-on-evict requires an invertible monoid, got {monoid.name}"
        )
    return SoeState(
        buf=alloc_ring(monoid, capacity),
        agg=monoid.identity(),
        front=i32(0),
        end=i32(0),
        capacity=capacity,
    )


def size(state: SoeState):
    return state.end - state.front


def insert(monoid: Monoid, state: SoeState, value) -> SoeState:
    v = monoid.lift(value)
    return SoeState(
        buf=ring_set(state.buf, state.end, v, state.capacity),
        agg=monoid.combine(state.agg, v),
        front=state.front,
        end=state.end + 1,
        capacity=state.capacity,
    )


def evict(monoid: Monoid, state: SoeState) -> SoeState:
    oldest = ring_get(state.buf, state.front, state.capacity)
    return SoeState(
        buf=state.buf,
        agg=monoid.inverse_front(state.agg, oldest),
        front=state.front + 1,
        end=state.end,
        capacity=state.capacity,
    )


def query(monoid: Monoid, state: SoeState):
    return state.agg
