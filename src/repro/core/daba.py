"""DABA — De-Amortized Banker's Aggregator (paper §5).

Worst-case O(1) SWAG: at most 4 ⊗-invocations per insert, 3 per evict, 1 per
query; space for 2n partial aggregates (each deque slot holds val + agg).

The deque is a ring buffer with six monotone logical pointers

    F ≤ L ≤ R ≤ A ≤ B ≤ E

demarcating sublists (paper Fig. 5):  l_F = [F,B) is the front list whose
leftmost portion [F,L) aggregates rightward to B; l_L = [L,R) aggregates
rightward to R; l_R = [R,A) aggregates leftward from R; l_A = [A,B)
aggregates rightward to B; l_B = [B,E) aggregates leftward from B.  The size
invariants

    (|l_F| = 0 ∧ |l_B| = 0) ∨
    (|l_L| + |l_R| + |l_A| + 1 = |l_F| - |l_B|  ∧  |l_L| = |l_R|)

guarantee the incremental reversal of the last flip completes exactly one
operation before the next flip is due.  ``fixup`` restores the invariants via
the four cases *singleton*, *flip*, *shift*, *shrink* — each O(1).

In eager mode only the taken case executes (counts match Theorem 10); under
``vmap`` all cases lower to selects — uniform constant work per lane.
"""

from __future__ import annotations

import dataclasses

import jax

import jax.numpy as jnp

from repro.core.monoids import Monoid
from repro.core.swag_base import (
    alloc_ring,
    chunk_length,
    i32,
    lazy_cond,
    ring_gather,
    ring_get,
    ring_set,
    suffix_carry_from_regions,
    swag_state,
)

PyTree = object


@swag_state
class DabaState:
    vals: PyTree  # ring: window contents v_i (lifted)
    aggs: PyTree  # ring: partial aggregates per the sublist invariants
    f: jax.Array
    l: jax.Array
    r: jax.Array
    a: jax.Array
    b: jax.Array
    e: jax.Array
    capacity: int


_replace = dataclasses.replace  # @swag_state states are frozen dataclasses


def init(monoid: Monoid, capacity: int) -> DabaState:
    return DabaState(
        vals=alloc_ring(monoid, capacity),
        aggs=alloc_ring(monoid, capacity),
        f=i32(0), l=i32(0), r=i32(0), a=i32(0), b=i32(0), e=i32(0),
        capacity=capacity,
    )


def size(state: DabaState):
    return state.e - state.f


# --- Π helpers (paper lines 1–10): O(1), no ⊗-invocations ------------------


def _pi_f(m: Monoid, s: DabaState):
    return lazy_cond(
        s.f == s.b, lambda: m.identity(),
        lambda: ring_get(s.aggs, s.f, s.capacity),
    )


def _pi_b(m: Monoid, s: DabaState):
    return lazy_cond(
        s.b == s.e, lambda: m.identity(),
        lambda: ring_get(s.aggs, s.e - 1, s.capacity),
    )


def _pi_l(m: Monoid, s: DabaState):
    return lazy_cond(
        s.l == s.r, lambda: m.identity(),
        lambda: ring_get(s.aggs, s.l, s.capacity),
    )


def _pi_r(m: Monoid, s: DabaState):
    return lazy_cond(
        s.r == s.a, lambda: m.identity(),
        lambda: ring_get(s.aggs, s.a - 1, s.capacity),
    )


def _pi_a(m: Monoid, s: DabaState):
    return lazy_cond(
        s.a == s.b, lambda: m.identity(),
        lambda: ring_get(s.aggs, s.a, s.capacity),
    )


def query(monoid: Monoid, state: DabaState):
    return monoid.combine(_pi_f(monoid, state), _pi_b(monoid, state))


# --- fixup (paper lines 21–32) ---------------------------------------------


def _fixup(m: Monoid, s: DabaState) -> DabaState:
    def singleton(s: DabaState) -> DabaState:
        return _replace(s, b=s.e, a=s.e, r=s.e, l=s.e)

    def non_singleton(s: DabaState) -> DabaState:
        def flip(s: DabaState) -> DabaState:
            # Relabel l_F → l_L and l_B → l_R by pointer moves alone; both
            # already aggregate in the direction their new roles require.
            return _replace(s, l=s.f, a=s.e, b=s.e)

        s = lazy_cond(s.l == s.b, flip, lambda s: s, s)

        def shift(s: DabaState) -> DabaState:
            return _replace(s, a=s.a + 1, r=s.r + 1, l=s.l + 1)

        def shrink(s: DabaState) -> DabaState:
            # Top of l_L joins the leftmost front portion:
            #   *L.agg ← Π_L ⊗ Π_R ⊗ Π_A              (2 ⊗-invocations)
            new_l_agg = m.combine(
                m.combine(_pi_l(m, s), _pi_r(m, s)), _pi_a(m, s)
            )
            aggs = ring_set(s.aggs, s.l, new_l_agg, s.capacity)
            s = _replace(s, aggs=aggs, l=s.l + 1)
            # Top of l_R joins the accumulator l_A:
            #   *(A-1).agg ← *(A-1).val ⊗ Π_A          (1 ⊗-invocation)
            new_a_agg = m.combine(
                ring_get(s.vals, s.a - 1, s.capacity), _pi_a(m, s)
            )
            aggs = ring_set(s.aggs, s.a - 1, new_a_agg, s.capacity)
            return _replace(s, aggs=aggs, a=s.a - 1)

        return lazy_cond(s.l == s.r, shift, shrink, s)

    return lazy_cond(s.f == s.b, singleton, non_singleton, s)


def insert(monoid: Monoid, state: DabaState, value) -> DabaState:
    v = monoid.lift(value)
    agg = monoid.combine(_pi_b(monoid, state), v)  # 1 ⊗-invocation
    s = _replace(
        state,
        vals=ring_set(state.vals, state.e, v, state.capacity),
        aggs=ring_set(state.aggs, state.e, agg, state.capacity),
        e=state.e + 1,
    )
    return _fixup(monoid, s)


def evict(monoid: Monoid, state: DabaState) -> DabaState:
    s = _replace(state, f=state.f + 1)
    return _fixup(monoid, s)


# --- warm-carry protocol ----------------------------------------------------


def state_to_carry(monoid: Monoid, state: DabaState, window: int):
    """Warm-carry extraction: same sublist regions as DABA Lite, with the
    ``vals`` ring supplying raw values and ``aggs`` the partial aggregates
    ([B,E) agg slots aggregate leftward-from-B and are bypassed in favour of
    the raw vals)."""
    length = state.capacity + 1
    raw_log = ring_gather(state.vals, state.f, state.capacity, length)
    agg_log = ring_gather(state.aggs, state.f, state.capacity, length)
    f = state.f
    return suffix_carry_from_regions(
        monoid, raw_log, agg_log, state.e - f,
        state.l - f, state.r - f, state.a - f, state.b - f, window,
    )


def carry_to_state(monoid: Monoid, carry, capacity: int) -> DabaState:
    """Carry import with the same F = 0, L = R = A = 1, B = E = h layout as
    DABA Lite.  The pseudo slots' ``vals`` are never read (shrink only reads
    vals inside l_R, which after any flip consists of genuinely-raw inserted
    values), but are filled with the carry for definiteness."""
    h = chunk_length(carry)
    if h > capacity:
        raise ValueError(f"carry of {h} elements exceeds capacity {capacity}")
    state = init(monoid, capacity)
    if h == 0:
        return state
    idx = jnp.arange(h, dtype=jnp.int32)
    filled = jax.tree.map(lambda a, c: a.at[idx].set(c), state.aggs, carry)
    vals = jax.tree.map(lambda a, c: a.at[idx].set(c), state.vals, carry)
    inner = i32(min(1, h))
    return _replace(
        state, vals=vals, aggs=filled,
        l=inner, r=inner, a=inner, b=i32(h), e=i32(h),
    )
