"""Chunked streaming engine — bulk sliding-window aggregation (paper §8.2).

Turns the per-element SWAG scan into chunk-at-a-time bulk work, the
throughput counterpart of DABA's latency bound (cf. the authors' follow-up
on efficient bulk evictions/insertions, arXiv 2307.11210):

  * **intra-chunk** window outputs come from ONE dense sliding-window pass
    over the chunk — the Pallas VHGW kernel for scalar elementwise monoids,
    or a generic log-depth ``associative_scan`` VHGW for arbitrary pytree
    monoids;
  * **cross-chunk** boundaries are carried by a per-lane *tail* of suffix
    aggregates of the last ``window - 1`` elements, updated with one suffix
    scan per chunk (the dense analogue of DABA Lite's front list: output =
    Π_front ⊗ Π_back becomes ``y[i] = tail[i] ⊗ prefix[i]``).

Results equal the per-element ``BatchedSWAG.stream`` outputs exactly for
integer monoids and up to combine reassociation (allclose) for floats.

Layouts: streams are ``(T, B)``-leading like ``BatchedSWAG.stream``; the
Pallas kernels internally work on ``(B, T)``.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import swag_base
from repro.core.monoids import Monoid
from repro.core.swag_base import (
    chunk_length,
    chunk_suffix_scan,
    suffix_scan,
    tree_index,
)
from repro.kernels.ops_registry import combine_fn, identity_for, op_for_monoid
from repro.kernels.sliding_window.kernel import sliding_window_pallas
from repro.kernels.suffix_scan.kernel import suffix_scan_pallas

PyTree = Any


# ---------------------------------------------------------------------------
# Generic (pytree-monoid) VHGW sliding window
# ---------------------------------------------------------------------------


def _axis1_prefix_scan(monoid: Monoid, blocks: PyTree) -> PyTree:
    return jax.lax.associative_scan(monoid.combine, blocks, axis=1)


def _axis1_suffix_scan(monoid: Monoid, blocks: PyTree) -> PyTree:
    # operand-order discipline lives in swag_base.suffix_scan
    return suffix_scan(monoid.combine, blocks, axis=1)


def tree_sliding_window(monoid: Monoid, lifted: PyTree, window: int) -> PyTree:
    """Front-truncated sliding-window fold along axis 0 of a lifted chunk.

    ``out[t] = lifted[max(0, t-window+1)] ⊗ … ⊗ lifted[t]`` — the VHGW
    (two-stacks-in-space) scheme of the Pallas kernel, expressed with
    ``associative_scan`` so it works for ANY pytree monoid: ~3 combines per
    element independent of ``window``, O(log window) depth.  Trailing axes
    (batch, element shape) ride along elementwise.
    """
    C = chunk_length(lifted)
    w = int(window)
    if w <= 1 or C == 0:
        return lifted
    ident = monoid.identity()
    nblk = -(-(C + w) // w)  # blocks of w covering [front pad w] + chunk
    total = nblk * w

    def pad(a, i):
        i = jnp.asarray(i, a.dtype)
        front = jnp.broadcast_to(i, (w,) + a.shape[1:])
        tail = jnp.broadcast_to(i, (total - w - C,) + a.shape[1:])
        return jnp.concatenate([front, a, tail], axis=0)

    padded = jax.tree.map(pad, lifted, ident)
    blocks = jax.tree.map(lambda a: a.reshape((nblk, w) + a.shape[1:]), padded)
    p = _axis1_prefix_scan(monoid, blocks)   # P[j, i] = fold(block_j[0..i])
    s = _axis1_suffix_scan(monoid, blocks)   # S[j, i] = fold(block_j[i..w-1])
    pf = jax.tree.map(lambda a: a.reshape((total,) + a.shape[2:]), p)
    sf = jax.tree.map(lambda a: a.reshape((total,) + a.shape[2:]), s)

    # Window ending at chunk position t covers padded [t+1 .. t+w]:
    # left fragment S[t+1] (identity when t+1 sits on a block boundary —
    # the window is then exactly one block's prefix), right fragment P[t+w].
    idx = jnp.arange(C, dtype=jnp.int32)
    on_boundary = ((idx + 1) % w) == 0
    left = jax.tree.map(
        lambda a, i: jnp.where(
            on_boundary.reshape((C,) + (1,) * (a.ndim - 1)),
            jnp.asarray(i, a.dtype),
            a[idx + 1],
        ),
        sf,
        ident,
    )
    right = jax.tree.map(lambda a: a[idx + w], pf)
    return monoid.combine(left, right)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class ChunkedStream:
    """Chunk-at-a-time count-based sliding-window aggregation over (T, B).

    Usage::

        eng = ChunkedStream(monoid, window=1024, chunk=1024)
        carry = eng.init_carry(batch)
        carry, ys = eng.process_chunk(carry, xs_chunk)   # (C, B) in/out
        ...                                              # or, whole stream:
        ys = eng.stream(xs)                              # (T, B) -> (T, B)

    ``ys[t]`` is the *aggregate* (pre-``lower``) of the last ``window``
    elements ending at t, front-truncated during fill — element-for-element
    what ``BatchedSWAG.stream`` emits, computed ~3 combines/element in bulk
    instead of O(1)-per-element sequential dispatch.

    When the monoid maps onto a registry op (sum/min/max/logsumexp/..., see
    :mod:`repro.kernels.ops_registry`) the intra-chunk passes run on the
    Pallas ``sliding_window``/``suffix_scan`` kernels; any other monoid uses
    the generic ``associative_scan`` path.  The carry is a per-lane tail of
    ``window - 1`` suffix aggregates — the engine never stores raw history —
    and can be initialized cold (identity) or WARM from any live SWAG state
    via ``init_carry(from_state=..., algo=...)`` (the warm-state carry
    protocol, :mod:`repro.core.swag_base`).
    """

    def __init__(
        self,
        monoid: Monoid,
        window: int,
        chunk: Optional[int] = None,
        *,
        use_kernel: bool = True,
        interpret: Optional[bool] = None,
        block_b: int = 8,
        obs: Optional[Any] = None,
    ):
        self.monoid = monoid
        self.window = int(window)
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.chunk = int(chunk) if chunk is not None else max(self.window, 256)
        self.op = op_for_monoid(monoid) if use_kernel else None
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = interpret
        self.block_b = block_b
        self._jitted_pc = jax.jit(self._process_chunk_impl)
        self._full_masks: dict = {}
        # obs: repro.obs.registry.ObsConfig — host-side chunk/row counters
        # only; this engine has no jit-visible instrumentation, so disabled
        # vs enabled never changes the traced computation
        self._obs = obs if (obs is not None and obs.enabled) else None
        self._obs_chunks = 0
        self._obs_rows = 0

    # -- timestamped (event-time) mode -------------------------------------

    @staticmethod
    def timestamped(monoid: Monoid, horizon, **kwargs):
        """Event-time counterpart of this engine: ``(ts, x)`` chunks, a time
        ``horizon`` instead of a count window, per-chunk watermark advance,
        and a bounded out-of-order reorder buffer (late-data policies:
        drop / side_output / merge).  Returns a
        :class:`repro.core.event_time.EventTimeChunkedStream`; see that
        module for the watermark and merge-order semantics."""
        from repro.core.event_time import EventTimeChunkedStream

        return EventTimeChunkedStream(monoid, horizon, **kwargs)

    # -- carry ------------------------------------------------------------

    def init_carry(
        self,
        batch: Optional[int] = None,
        *,
        from_state: Optional[PyTree] = None,
        algo=None,
    ) -> PyTree:
        """Tail of suffix aggregates of the last window-1 elements (per lane).

        Cold start (``from_state=None``): identity-filled, so missing history
        combines away exactly (= the front-truncated fill semantics).

        Warm start: pass a *batched* live SWAG state (leading lane axis, as
        built by ``BatchedSWAG.init``) plus its algorithm module, and the
        carry is extracted through the warm-carry protocol
        (:func:`repro.core.swag_base.state_to_carry`) — the stream then
        continues the live window instead of restarting from empty.  Lane
        sizes may be ragged; each lane is front-truncated independently.
        """
        h = self.window - 1
        if from_state is not None:
            if algo is None:
                raise ValueError("init_carry(from_state=...) needs algo=")
            tails = jax.vmap(
                lambda s: swag_base.state_to_carry(
                    algo, self.monoid, s, self.window
                )
            )(from_state)  # (B, h, ...)-leading
            if self.op is not None:
                return tails  # kernel carry layout is (batch, h)
            # generic carry layout is (h, batch, ...)
            return jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), tails)
        if batch is None:
            raise ValueError("init_carry needs batch= (or from_state=)")
        ident = self.monoid.identity()
        if self.op is not None:
            ident = jnp.asarray(ident)
            return jnp.full((batch, h), ident, ident.dtype)
        return jax.tree.map(
            lambda i: jnp.broadcast_to(i, (h, batch) + i.shape).copy(), ident
        )

    # -- one chunk ---------------------------------------------------------

    def process_chunk(self, carry: PyTree, xs: PyTree, mask=None):
        """Consume a (C, B) chunk of raw inputs; returns (carry, (C, B) aggs).

        ``mask`` is an optional (C,) bool array; False positions enter the
        window as the monoid identity (their output rows are meaningless —
        slice them off).  It exists to pad a ragged FINAL chunk up to the
        engine's static chunk length without a fresh jit trace: the returned
        carry treats masked positions as real identity elements, so only mask
        when no further chunks follow.  A full mask is always passed to the
        jitted function so full and padded chunks share one compilation.
        """
        if mask is None:
            mask = self._full_mask(chunk_length(xs))
        if self._obs is not None:
            self._obs_chunks += 1
            self._obs_rows += int(chunk_length(xs))
        return self._jitted_pc(carry, xs, mask)

    def attach_obs(self, registry, *, prefix: str = "repro_chunked"):
        """Register host-side throughput counters with an obs registry
        (rates come from scrape deltas, e.g. in the dashboard)."""
        registry.describe(f"{prefix}_chunks_total", "counter",
                          "process_chunk dispatches")
        registry.describe(f"{prefix}_rows_total", "counter",
                          "chunk rows ingested (incl. ragged-final padding)")

        def collect():
            return {
                f"{prefix}_chunks_total": self._obs_chunks,
                f"{prefix}_rows_total": self._obs_rows,
            }

        registry.register_collector(collect)
        return collect

    def chunk_fn(self, carry: PyTree, xs: PyTree, mask=None):
        """Unjitted :meth:`process_chunk` body — pure, for composing into a
        caller's own ``jit`` (e.g. the telemetry layer's fused observe)."""
        return self._process_chunk_impl(carry, xs, mask)

    def _full_mask(self, C: int):
        m = self._full_masks.get(C)
        if m is None:
            m = self._full_masks[C] = jnp.ones((C,), bool)
        return m

    def _process_chunk_impl(self, carry, xs, mask=None):
        if self.op is not None:
            return self._chunk_kernel(carry, xs, mask)
        return self._chunk_generic(carry, xs, mask)

    def _chunk_kernel(self, tail, xs, mask=None):
        m = self.monoid
        lifted = jax.vmap(jax.vmap(m.lift))(xs)  # (C, B) scalar Agg
        if lifted.ndim != 2:
            raise ValueError(
                f"kernel path needs scalar aggregates, got shape {lifted.shape}"
            )
        if mask is not None:
            ident = jnp.asarray(identity_for(self.op, lifted.dtype), lifted.dtype)
            lifted = jnp.where(mask[:, None], lifted, ident)
        x = lifted.T  # (B, C) for the kernels
        C = x.shape[1]
        w, h = self.window, min(self.window - 1, x.shape[1])
        comb = combine_fn(self.op)
        y = sliding_window_pallas(
            x, window=w, op=self.op, block_b=self.block_b, interpret=self.interpret
        )
        if h > 0:
            y = y.at[:, :h].set(comb(tail[:, :h], y[:, :h]))
        if w > 1:
            ss = suffix_scan_pallas(
                x, op=self.op, block_b=self.block_b, interpret=self.interpret
            )
            if C >= w - 1:
                tail = ss[:, C - (w - 1):]
            else:
                # shift the old tail down by C and absorb the chunk total
                tail = jnp.concatenate([comb(tail[:, C:], ss[:, :1]), ss], axis=1)
        return tail, y.T

    def _chunk_generic(self, tail, xs, mask=None):
        m = self.monoid
        lifted = jax.vmap(jax.vmap(m.lift))(xs)  # (C, B, ...) Agg pytree
        if mask is not None:
            ident = m.identity()
            lifted = jax.tree.map(
                lambda a, i: jnp.where(
                    mask.reshape((-1,) + (1,) * (a.ndim - 1)),
                    a,
                    jnp.asarray(i, a.dtype),
                ),
                lifted,
                ident,
            )
        C = chunk_length(lifted)
        w, h = self.window, min(self.window - 1, chunk_length(lifted))
        y = tree_sliding_window(m, lifted, w)
        if h > 0:
            fixed = m.combine(
                jax.tree.map(lambda a: a[:h], tail),
                jax.tree.map(lambda a: a[:h], y),
            )
            y = jax.tree.map(lambda a, f: a.at[:h].set(f), y, fixed)
        if w > 1:
            ss = chunk_suffix_scan(m, lifted)
            if C >= w - 1:
                tail = jax.tree.map(lambda a: a[C - (w - 1):], ss)
            else:
                total = tree_index(ss, 0)
                shifted = jax.vmap(m.combine, in_axes=(0, None))(
                    jax.tree.map(lambda a: a[C:], tail), total
                )
                tail = jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b], axis=0), shifted, ss
                )
        return tail, y

    # -- whole stream ------------------------------------------------------

    def stream(self, xs: PyTree, *, carry: Optional[PyTree] = None) -> PyTree:
        """Aggregate a whole (T, B) stream chunk-by-chunk; returns (T, B) aggs.

        ``carry`` continues from an existing tail (see :meth:`init_carry`'s
        ``from_state=`` path for warm windows); default is a cold start.  A
        ragged last chunk is padded to ``self.chunk`` with the monoid
        identity under a mask, so every chunk — ragged included — reuses the
        single ``process_chunk`` compilation.
        """
        T = chunk_length(xs)
        batch = jax.tree.leaves(xs)[0].shape[1]
        if T == 0:  # match the per-element scan: well-formed empty (0, B) aggs
            return jax.vmap(jax.vmap(self.monoid.lift))(xs)
        if carry is None:
            carry = self.init_carry(batch)
        ys = []
        for lo in range(0, T, self.chunk):
            hi = min(lo + self.chunk, T)
            piece = jax.tree.map(lambda a: a[lo:hi], xs)
            if hi - lo < self.chunk:  # final ragged chunk: pad + mask
                pad = self.chunk - (hi - lo)
                piece = jax.tree.map(
                    lambda a: jnp.concatenate(
                        [a, jnp.broadcast_to(a[-1:], (pad,) + a.shape[1:])], 0
                    ),
                    piece,
                )
                mask = jnp.arange(self.chunk) < (hi - lo)
                carry, y = self.process_chunk(carry, piece, mask)
                y = jax.tree.map(lambda a: a[: hi - lo], y)
            else:
                carry, y = self.process_chunk(carry, piece)
            ys.append(y)
        return jax.tree.map(lambda *parts: jnp.concatenate(parts, axis=0), *ys)
